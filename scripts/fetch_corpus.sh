#!/usr/bin/env sh
# Fetch the pinned out-of-core ingest corpus (DESIGN.md §15).
#
#   scripts/fetch_corpus.sh                 # fetch + verify every pinned matrix
#   scripts/fetch_corpus.sh uk-2002         # fetch one by name
#   scripts/fetch_corpus.sh --pin [name...] # trust-on-first-use: record checksums
#   scripts/fetch_corpus.sh --list          # show the pinned set
#   scripts/fetch_corpus.sh --print-path n  # echo the extracted .mtx path (no network)
#
# The set is the paper's large instances, 10-100x beyond the in-tree
# generator presets, from the SuiteSparse collection. Extracted files
# land under corpus/<name>/<name>.mtx (gitignored); point the ingest
# bench at one with
#
#   BGPC_INGEST_GRAPH=mtx:$(scripts/fetch_corpus.sh --print-path uk-2002) \
#       cargo bench --bench ingest
#
# Integrity is trust-on-first-use: scripts/corpus.sha256 pins the sha256
# of each extracted .mtx. The file ships EMPTY of hashes — checksums are
# recorded from a real download via --pin, never typed in by hand — and
# once a matrix is pinned, every later fetch must match or the script
# fails. Fetching an unpinned matrix without --pin fails too, so CI can
# never silently ingest an unverified file.
set -eu

root=$(cd "$(dirname "$0")/.." && pwd)
pins="$root/scripts/corpus.sha256"
dest="$root/corpus"
base="https://sparse.tamu.edu/MM"

# name|group — SuiteSparse coordinates of the pinned set
corpus() {
    cat <<'EOF'
coPapersDBLP|DIMACS10
bone010|Oberwolfach
channel-500x100x100-b050|DIMACS10
uk-2002|LAW
nlpkkt240|Schenk
EOF
}

# group of a pinned matrix, empty when unknown (always exits 0 — the
# caller distinguishes, and set -e must not fire inside the $(...))
group_of() {
    corpus | awk -F'|' -v n="$1" '$1 == n { print $2; exit }'
}

sha256_of() {
    if command -v sha256sum >/dev/null 2>&1; then
        sha256sum "$1" | awk '{print $1}'
    elif command -v shasum >/dev/null 2>&1; then
        shasum -a 256 "$1" | awk '{print $1}'
    else
        echo "fetch_corpus: no sha256 tool on PATH" >&2
        exit 2
    fi
}

pin=0
names=""
for arg in "$@"; do
    case "$arg" in
        --pin) pin=1 ;;
        --list) corpus | while IFS='|' read -r n g; do echo "$n ($g)"; done; exit 0 ;;
        --print-path)
            shift_to_path=1 ;;
        -*) echo "fetch_corpus: unknown flag $arg" >&2; exit 2 ;;
        *)
            if [ "${shift_to_path:-0}" = 1 ]; then
                echo "$dest/$arg/$arg.mtx"
                exit 0
            fi
            names="$names $arg" ;;
    esac
done
if [ "${shift_to_path:-0}" = 1 ]; then
    echo "fetch_corpus: --print-path needs a matrix name" >&2
    exit 2
fi
if [ -z "$names" ]; then
    names=$(corpus | cut -d'|' -f1 | tr '\n' ' ')
fi

fetcher() {
    if command -v curl >/dev/null 2>&1; then
        curl -fsSL --retry 3 -o "$2" "$1"
    elif command -v wget >/dev/null 2>&1; then
        wget -q -O "$2" "$1"
    else
        echo "fetch_corpus: neither curl nor wget on PATH" >&2
        exit 2
    fi
}

mkdir -p "$dest"
fail=0
# word-splitting is the point (same idiom as bench_gate.sh)
# shellcheck disable=SC2086
set -- $names
for name in "$@"; do
    group=$(group_of "$name")
    if [ -z "$group" ]; then
        echo "fetch_corpus: $name is not in the pinned set (--list)" >&2
        fail=1
        continue
    fi
    mtx="$dest/$name/$name.mtx"
    if [ ! -f "$mtx" ]; then
        tarball="$dest/$name.tar.gz"
        url="$base/$group/$name.tar.gz"
        echo "fetch_corpus: $name <- $url"
        fetcher "$url" "$tarball"
        tar -xzf "$tarball" -C "$dest"
        rm -f "$tarball"
        if [ ! -f "$mtx" ]; then
            echo "fetch_corpus: $name: tarball did not contain $name/$name.mtx" >&2
            fail=1
            continue
        fi
    fi
    have=$(sha256_of "$mtx")
    want=$(grep "  $name\$" "$pins" 2>/dev/null | head -n 1 | awk '{print $1}' || true)
    if [ -n "$want" ]; then
        if [ "$have" = "$want" ]; then
            echo "fetch_corpus: $name: sha256 ok"
        else
            echo "fetch_corpus: $name: CHECKSUM MISMATCH (have $have, pinned $want)" >&2
            fail=1
        fi
    elif [ "$pin" = 1 ]; then
        echo "$have  $name" >> "$pins"
        echo "fetch_corpus: $name: pinned $have (trust-on-first-use)"
    else
        echo "fetch_corpus: $name: no pinned checksum — rerun with --pin to record one" >&2
        fail=1
    fi
done
exit "$fail"
