#!/usr/bin/env python3
"""Validate Chrome-trace JSON emitted by the obs tracer (DESIGN.md §13).

Checks, per file:
  1. the file parses as JSON and has a non-empty ``traceEvents`` array;
  2. every required phase name appears in at least one complete ("X")
     event across the checked files (default set covers all four
     instrumented layers: pool, coloring engine, dynamic repair,
     coordinator);
  3. within each (pid, tid), complete events nest strictly — two spans
     on one thread either are disjoint or one contains the other (a
     small epsilon absorbs the exporter's microsecond rounding).

Usage:
  scripts/check_trace.py trace_a.json [trace_b.json ...]
  scripts/check_trace.py --require pool.region --require exec.color t.json

Exit code 0 on success, 1 with a diagnostic on the first failure.
"""

import argparse
import json
import sys

# one span name per instrumented layer — the acceptance surface
DEFAULT_REQUIRED = [
    "pool.region",      # par::pool region dispatch
    "bgpc.speculate",   # coloring engine phase
    "repair.detect_dirty",  # dynamic repair
    "coord.dispatch",   # coordinator
    "exec.color",       # color-parallel execution frontier
]

# exporter rounds ts/dur to 3 decimal places of a microsecond
EPS_US = 0.0011


def fail(msg):
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load_events(path):
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: not readable JSON: {e}")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: missing or empty traceEvents array")
    return events


def check_nesting(path, events):
    """Complete events on one thread must be disjoint or contained."""
    by_tid = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        ts, dur = float(ev["ts"]), float(ev["dur"])
        key = (ev.get("pid", 0), ev.get("tid", 0))
        by_tid.setdefault(key, []).append((ts, ts + dur, ev.get("name", "?")))
    for key, spans in by_tid.items():
        # sort by start asc, end desc: a parent sorts before its children
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack = []
        for start, end, name in spans:
            while stack and start >= stack[-1][1] - EPS_US:
                stack.pop()
            if stack and end > stack[-1][1] + EPS_US:
                fail(
                    f"{path}: tid {key[1]}: span {name!r} [{start:.3f}, {end:.3f}] "
                    f"overlaps {stack[-1][2]!r} [{stack[-1][0]:.3f}, {stack[-1][1]:.3f}] "
                    "without nesting"
                )
            stack.append((start, end, name))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", nargs="+", help="Chrome-trace JSON files")
    ap.add_argument(
        "--require",
        action="append",
        default=None,
        metavar="NAME",
        help="span name that must appear in some X event "
        "(repeatable; replaces the default layer set)",
    )
    opts = ap.parse_args()
    required = opts.require if opts.require else DEFAULT_REQUIRED

    seen = set()
    total_x = 0
    for path in opts.files:
        events = load_events(path)
        n_x = sum(1 for ev in events if ev.get("ph") == "X")
        if n_x == 0:
            fail(f"{path}: no complete ('X') events")
        total_x += n_x
        for ev in events:
            if ev.get("ph") == "X":
                seen.add(ev.get("name"))
        check_nesting(path, events)
        print(f"check_trace: {path}: {len(events)} events, {n_x} spans, nesting ok")

    missing = [name for name in required if name not in seen]
    if missing:
        fail(
            f"missing required span name(s) {missing} across {len(opts.files)} "
            f"file(s); saw: {sorted(seen)}"
        )
    print(
        f"check_trace: OK — {total_x} spans across {len(opts.files)} file(s), "
        f"all {len(required)} required phases present"
    )


if __name__ == "__main__":
    main()
