#!/usr/bin/env sh
# Local mirror of CI: tier-1 gate plus target-coverage builds.
#
#   scripts/verify.sh                  # build + test + benches/examples + example smoke + docs + clippy + fmt
#   TEST_SHARD=threads scripts/verify.sh   # concurrency-focused test shard (CI matrix)
#   TEST_SHARD=sim scripts/verify.sh       # simulator/property test shard (CI matrix)
#   BENCH_SMOKE=1 scripts/verify.sh    # additionally run the gated benches reduced-size
#   SKIP_FMT=1 scripts/verify.sh       # when rustfmt is not installed
#   SKIP_CLIPPY=1 scripts/verify.sh    # when clippy is not installed
#   SKIP_DOCS=1 scripts/verify.sh      # skip the rustdoc warnings gate
set -eu

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

# pick up repo-root artifacts when `make artifacts` has run (tests skip otherwise)
BGPC_ARTIFACTS="${BGPC_ARTIFACTS:-../artifacts}"
export BGPC_ARTIFACTS

# The CI matrix splits the suite into a concurrency-focused shard
# (real-thread drivers, executor, streaming integration) and a
# simulator/property shard (unit tests + the sim-heavy integration
# targets); unset means the full suite. The union guard below fails
# loudly when a new tests/*.rs file is in neither shard — otherwise a
# green matrix could silently skip it forever.
THREADS_SHARD="driver_equivalence exec_properties dynamic_integration d1gc_integration"
SIM_SHARD="paper_properties engine_integration graph_io pjrt_roundtrip strategy_properties packed_scan_properties ingest_properties"
for f in tests/*.rs; do
    t="$(basename "$f" .rs)"
    case " $THREADS_SHARD $SIM_SHARD " in
        *" $t "*) ;;
        *)
            echo "verify: tests/$t.rs is in neither TEST_SHARD list — add it in scripts/verify.sh" >&2
            exit 2
            ;;
    esac
done
shard_args() {
    for t in $1; do
        printf -- '--test %s ' "$t"
    done
}
case "${TEST_SHARD:-all}" in
    threads)
        echo "== cargo test -q (shard: threads) =="
        # shellcheck disable=SC2046  # intentional word splitting of --test flags
        cargo test -q $(shard_args "$THREADS_SHARD")
        ;;
    sim)
        echo "== cargo test -q (shard: sim) =="
        # shellcheck disable=SC2046  # intentional word splitting of --test flags
        cargo test -q --lib --bins $(shard_args "$SIM_SHARD")
        # the obs tracer's recording tests are compiled out by default;
        # a --features trace lib pass keeps them (and the feature-on
        # build) green without touching the shard lists
        echo "== cargo test -q --features trace --lib (obs recording) =="
        cargo test -q --features trace --lib
        ;;
    all)
        echo "== cargo test -q =="
        cargo test -q
        echo "== cargo test -q --features trace --lib (obs recording) =="
        cargo test -q --features trace --lib
        ;;
    *)
        echo "verify: unknown TEST_SHARD '${TEST_SHARD}' (use threads|sim|all)" >&2
        exit 2
        ;;
esac

echo "== cargo build --benches --examples =="
cargo build --benches --examples

# Built targets must also *run*: smoke one real-thread example end to
# end (colored waves on the persistent pool) so bit-rot in the example
# layer fails verify, not a user.
echo "== example smoke: parallel_sweep =="
cargo run --release --example parallel_sweep >/dev/null

# Reduced-size gated benches — delegated to `make bench-smoke` so this
# and the CI bench-smoke job share one command (no drift in the bench
# list): scheduler (pool >= 2x spawn), dynamic (repair >= 5x recolor),
# execute (colored exec valid + B1/B2 flatten the critical path),
# service (sharded submit_async >= 4x the single-mutex baseline),
# microbench (packed scans >= 2x scalar + auto chunk within 10% of the
# best fixed chunk).
# CI then re-checks the emitted CSVs against the committed BENCH_*.json
# floors via scripts/bench_gate.sh.
if [ "${BENCH_SMOKE:-0}" = "1" ]; then
    echo "== bench smoke (BENCH_SMOKE=1; make bench-smoke) =="
    (cd .. && make bench-smoke)
fi

# Rustdoc gate: the public API (exec, dynamic, coordinator, ...) is
# documented; broken intra-doc links and missing docs regress here.
if [ "${SKIP_DOCS:-0}" = "1" ]; then
    echo "== docs skipped (SKIP_DOCS=1) =="
else
    echo '== RUSTDOCFLAGS="-D warnings" cargo doc --no-deps =='
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
fi

if [ "${SKIP_CLIPPY:-0}" = "1" ]; then
    echo "== clippy skipped (SKIP_CLIPPY=1) =="
elif cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -- -D warnings =="
    cargo clippy -- -D warnings
else
    echo "== clippy skipped (cargo-clippy not installed) =="
fi

if [ "${SKIP_FMT:-0}" = "1" ]; then
    echo "== fmt skipped (SKIP_FMT=1) =="
elif command -v rustfmt >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "== fmt skipped (rustfmt not installed) =="
fi

echo "verify: OK"
