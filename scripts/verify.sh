#!/usr/bin/env sh
# Local mirror of CI: tier-1 gate plus target-coverage builds.
#
#   scripts/verify.sh              # build + test + benches/examples + docs + clippy + fmt
#   SKIP_FMT=1 scripts/verify.sh   # when rustfmt is not installed
#   SKIP_CLIPPY=1 scripts/verify.sh# when clippy is not installed
#   SKIP_DOCS=1 scripts/verify.sh  # skip the rustdoc warnings gate
set -eu

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
# pick up repo-root artifacts when `make artifacts` has run (tests skip otherwise)
BGPC_ARTIFACTS="${BGPC_ARTIFACTS:-../artifacts}" cargo test -q

echo "== cargo build --benches --examples =="
cargo build --benches --examples

# Rustdoc gate: the public API (dynamic, coordinator, coloring::d2gc…)
# is documented; broken intra-doc links and missing docs regress here.
if [ "${SKIP_DOCS:-0}" = "1" ]; then
    echo "== docs skipped (SKIP_DOCS=1) =="
else
    echo '== RUSTDOCFLAGS="-D warnings" cargo doc --no-deps =='
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
fi

if [ "${SKIP_CLIPPY:-0}" = "1" ]; then
    echo "== clippy skipped (SKIP_CLIPPY=1) =="
elif cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -- -D warnings =="
    cargo clippy -- -D warnings
else
    echo "== clippy skipped (cargo-clippy not installed) =="
fi

if [ "${SKIP_FMT:-0}" = "1" ]; then
    echo "== fmt skipped (SKIP_FMT=1) =="
elif command -v rustfmt >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "== fmt skipped (rustfmt not installed) =="
fi

echo "verify: OK"
