#!/usr/bin/env sh
# Perf-trajectory gate: compare a bench CSV against its committed floor.
#
#   scripts/bench_gate.sh                      # gate every BENCH_*.json
#   scripts/bench_gate.sh service              # gate one bench by name
#   scripts/bench_gate.sh --update [name...]   # ratchet floors to current
#
# Each repo-root BENCH_<name>.json records, one key per line, the floor
# for one gated metric:
#
#   bench      bench target (cargo bench --bench <bench>)
#   csv        CSV the bench writes under rust/bench_results/
#   column     CSV column holding the gated metric
#   value      committed floor (geomean of the column must stay >= this,
#              within tolerance)
#   tolerance  allowed relative slack, e.g. 0.25
#   note       free-text provenance
#
# The gate passes when geomean(column) >= value * (1 - tolerance).
# Run the bench first (`make bench-smoke` or `cargo bench --bench ...`);
# a missing CSV is a hard failure so CI cannot skip the gate silently.
set -eu

root=$(cd "$(dirname "$0")/.." && pwd)
update=0
names=""
for arg in "$@"; do
    case "$arg" in
        --update) update=1 ;;
        -*) echo "bench_gate: unknown flag $arg" >&2; exit 2 ;;
        *) names="$names $arg" ;;
    esac
done
if [ -z "$names" ]; then
    for f in "$root"/BENCH_*.json; do
        [ -e "$f" ] || { echo "bench_gate: no BENCH_*.json files at $root" >&2; exit 2; }
        n=${f##*/BENCH_}
        names="$names ${n%.json}"
    done
fi

# flat one-key-per-line JSON: pull a string/number field by key
field() {
    sed -n "s/^[[:space:]]*\"$2\"[[:space:]]*:[[:space:]]*\"\{0,1\}\([^\",]*\)\"\{0,1\},\{0,1\}[[:space:]]*$/\1/p" "$1" | head -n 1
}

fail=0
# word-splitting is the point: $names is a space-joined list built above
# shellcheck disable=SC2086
set -- $names
for name in "$@"; do
    spec="$root/BENCH_$name.json"
    if [ ! -f "$spec" ]; then
        echo "bench_gate: $spec not found" >&2
        fail=1
        continue
    fi
    csv_name=$(field "$spec" csv)
    column=$(field "$spec" column)
    floor=$(field "$spec" value)
    tol=$(field "$spec" tolerance)
    csv="$root/rust/bench_results/$csv_name"
    if [ ! -f "$csv" ]; then
        echo "bench_gate: $name: $csv missing — run the bench first (make bench-smoke)" >&2
        fail=1
        continue
    fi
    # geomean of the named column, skipping empty/non-positive cells
    # (the baseline row leaves its speedup cell blank)
    cur=$(awk -F, -v col="$column" '
        NR == 1 { for (i = 1; i <= NF; i++) if ($i == col) ix = i; next }
        ix && $ix + 0 > 0 { s += log($ix); n++ }
        END {
            if (!ix) { print "NOCOL"; exit }
            if (!n) { print "NOVAL"; exit }
            printf "%.6f", exp(s / n)
        }' "$csv")
    case "$cur" in
        NOCOL) echo "bench_gate: $name: column '$column' not in $csv" >&2; fail=1; continue ;;
        NOVAL) echo "bench_gate: $name: no positive '$column' values in $csv" >&2; fail=1; continue ;;
    esac
    if [ "$update" = 1 ]; then
        tmp="$spec.tmp"
        sed "s/^\([[:space:]]*\"value\"[[:space:]]*:[[:space:]]*\)[0-9.]*\(,\{0,1\}\)[[:space:]]*$/\1$cur\2/" "$spec" > "$tmp"
        mv "$tmp" "$spec"
        echo "bench_gate: $name: floor ratcheted to $cur (was $floor)"
        continue
    fi
    ok=$(awk -v c="$cur" -v f="$floor" -v t="$tol" 'BEGIN { print (c >= f * (1 - t)) ? 1 : 0 }')
    if [ "$ok" = 1 ]; then
        echo "bench_gate: $name: geomean($column) = $cur >= $floor*(1-$tol)  [ok]"
    else
        echo "bench_gate: $name: geomean($column) = $cur < $floor*(1-$tol)  [REGRESSION]" >&2
        fail=1
    fi
done
exit "$fail"
