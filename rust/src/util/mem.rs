//! Best-effort process-memory introspection for the ingest benches.
//!
//! Linux exposes the peak resident set size as `VmHWM` in
//! `/proc/self/status`, and lets a process reset that high-water mark by
//! writing `5` to `/proc/self/clear_refs` — which is exactly what a
//! peak-RSS measurement around one ingest run needs. Everything here is
//! strictly best-effort: on other platforms (or sandboxes that hide
//! `/proc`) the probes return `None` and callers report the sample as
//! unavailable instead of failing the bench.

/// Peak resident set size (`VmHWM`) in bytes, if the platform exposes it.
pub fn peak_rss_bytes() -> Option<u64> {
    proc_status_kib("VmHWM:").map(|kib| kib * 1024)
}

/// Current resident set size (`VmRSS`) in bytes, if available.
pub fn current_rss_bytes() -> Option<u64> {
    proc_status_kib("VmRSS:").map(|kib| kib * 1024)
}

/// Reset the peak-RSS high-water mark to the current RSS so the next
/// [`peak_rss_bytes`] reading covers only the work that follows.
/// Returns whether the reset took (needs a writable
/// `/proc/self/clear_refs`).
pub fn reset_peak_rss() -> bool {
    std::fs::write("/proc/self/clear_refs", "5").is_ok()
}

/// Parse a `kB` line out of `/proc/self/status`, e.g. `VmHWM: 1234 kB`.
fn proc_status_kib(key: &str) -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with(key))?;
    line[key.len()..].split_whitespace().next()?.parse::<u64>().ok()
}

/// Bytes as mebibytes for table output.
pub fn mib(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_is_at_least_current_when_available() {
        // On non-Linux hosts both probes are None and the test is vacuous.
        if let (Some(peak), Some(cur)) = (peak_rss_bytes(), current_rss_bytes()) {
            assert!(peak >= cur, "VmHWM {peak} < VmRSS {cur}");
            assert!(peak > 0);
        }
    }

    #[test]
    fn reset_keeps_the_probe_readable() {
        // The reset is allowed to fail (read-only /proc), and VmHWM is
        // process-wide so concurrent tests make exact comparisons racy;
        // the invariant is only that the probe stays readable afterwards.
        if peak_rss_bytes().is_none() {
            return;
        }
        let _ = reset_peak_rss();
        assert!(peak_rss_bytes().unwrap() > 0);
    }

    #[test]
    fn mib_converts() {
        assert!((mib(3 * 1024 * 1024) - 3.0).abs() < 1e-12);
    }
}
