//! Architecture-specific hot-path helpers: best-effort software prefetch
//! behind a portable no-op fallback.
//!
//! The speculate/detect inner loops are gather-bound: `colors[adj[i]]`
//! is a dependent load whose address is only known after the adjacency
//! entry arrives, so the out-of-order window stalls on two chained cache
//! misses per entry on large graphs (Çatalyürek et al., PAPERS.md
//! 1205.3809, measure exactly this). Running [`PREFETCH_DIST`] entries
//! ahead overlaps those misses. Everything here is a *hint*: on
//! non-x86_64 targets (and under `miri`-style interpreters) the helpers
//! compile to nothing, and the simulator's MVCC store keeps the default
//! no-op [`crate::par::ColorStore::prefetch`], so modeled costs and
//! colorings are byte-identical with or without prefetching
//! (DESIGN.md §Perf).

/// How many adjacency entries the marking loops run ahead of themselves.
///
/// Rationale: one entry costs a handful of cycles of real work while a
/// DRAM miss is ~100ns ≈ 60–80 entries of slack; 8 is far enough to
/// cover an L2 miss without thrashing the L1 fill buffers on short rows
/// (most rows in the skewed presets are < 32 entries, so a larger
/// distance would mostly prefetch past the row's end).
pub const PREFETCH_DIST: usize = 8;

/// Best-effort read prefetch of the cache line holding `*p`.
#[inline(always)]
pub fn prefetch_read<T>(p: *const T) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: PREFETCHT0 is architecturally a hint — it never faults,
    // even on unmapped addresses — and requires only baseline SSE,
    // which every x86_64 target has.
    unsafe {
        core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(p as *const i8);
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = p;
    }
}

/// Prefetch element `i` of `slice` when it exists (bounds-safe: the
/// marking loops call this with `i + PREFETCH_DIST`, which runs past the
/// end on the last entries).
#[inline(always)]
pub fn prefetch_slice<T>(slice: &[T], i: usize) {
    if let Some(x) = slice.get(i) {
        prefetch_read(x as *const T);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetch_is_a_pure_hint() {
        // No observable effect, in or out of bounds.
        let v = vec![1u32, 2, 3];
        prefetch_slice(&v, 0);
        prefetch_slice(&v, 2);
        prefetch_slice(&v, 3); // past the end: must be a no-op
        prefetch_slice::<u32>(&[], 0);
        prefetch_read(v.as_ptr());
        assert_eq!(v, vec![1, 2, 3]);
    }
}
