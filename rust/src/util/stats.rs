//! Descriptive statistics used across experiments (cardinality stddev,
//! degree distributions, speedup tables).

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation (the paper's color-cardinality metric).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Max of a slice of usize.
pub fn max_usize(xs: &[usize]) -> usize {
    xs.iter().copied().max().unwrap_or(0)
}

/// Histogram with log2-spaced buckets: returns (bucket_upper_bound, count).
/// Used for Figure 3's cardinality distribution plots.
pub fn log2_histogram(values: &[usize]) -> Vec<(usize, usize)> {
    let mut buckets: Vec<usize> = Vec::new();
    for &v in values {
        let b = if v == 0 { 0 } else { (usize::BITS - (v.leading_zeros())) as usize };
        if b >= buckets.len() {
            buckets.resize(b + 1, 0);
        }
        buckets[b] += 1;
    }
    buckets
        .iter()
        .enumerate()
        .filter(|(_, &c)| c > 0)
        .map(|(b, &c)| ((1usize << b).saturating_sub(1).max(if b == 0 { 0 } else { 1 << (b - 1) }), c))
        .map(|(ub, c)| (ub, c))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mean_empty_nan() {
        assert!(mean(&[]).is_nan());
    }

    #[test]
    fn histogram_counts_sum() {
        let vals = [0usize, 1, 1, 2, 3, 4, 9, 1000];
        let h = log2_histogram(&vals);
        let total: usize = h.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, vals.len());
    }
}
