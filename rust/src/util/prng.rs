//! Deterministic PRNG: splitmix64 seeding + xoshiro256** stream.
//!
//! Reference: Blackman & Vigna, "Scrambled linear pseudorandom number
//! generators" (2018). Used for synthetic graph generation, random
//! orderings and property tests; everything in the repo is seedable so
//! the paper's tables regenerate bit-identically.

/// splitmix64: used to expand a single u64 seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample from a discrete power-law `P(k) ∝ k^-alpha` over `[1, max_k]`
    /// via inverse-CDF on the continuous approximation.
    pub fn powerlaw(&mut self, alpha: f64, max_k: usize) -> usize {
        debug_assert!(alpha > 1.0);
        let u = self.f64();
        let one_minus = 1.0 - alpha;
        let max = (max_k as f64).powf(one_minus);
        let k = (1.0 + u * (max - 1.0)).powf(1.0 / one_minus);
        (k as usize).clamp(1, max_k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        let n = 20_000;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }

    #[test]
    fn powerlaw_bounds_and_skew() {
        let mut r = Rng::new(11);
        let mut count_small = 0;
        let n = 10_000;
        for _ in 0..n {
            let k = r.powerlaw(2.5, 1000);
            assert!((1..=1000).contains(&k));
            if k <= 2 {
                count_small += 1;
            }
        }
        // power-law with alpha=2.5: most mass at small k.
        assert!(count_small > n / 2, "skew missing: {count_small}/{n}");
    }
}
