//! Small in-tree utilities: PRNG, timing, formatting.
//!
//! The offline registry provides no `rand`; the paper's experiments only
//! need reproducible streams, so we ship splitmix64 + xoshiro256**.

pub mod arch;
pub mod error;
pub mod mem;
pub mod prng;
pub mod stats;
pub mod timer;

pub use error::{Context, Error, Result};
pub use prng::Rng;
pub use timer::Stopwatch;

/// Format a float with engineering-style precision for table output.
pub fn fmt_sig(v: f64, digits: usize) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    if v == 0.0 {
        return "0".to_string();
    }
    let mag = v.abs().log10().floor() as i32;
    let dec = (digits as i32 - 1 - mag).max(0) as usize;
    format!("{v:.dec$}")
}

/// Geometric mean of a slice (the paper reports geo-means across matrices).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        assert!(geomean(&[]).is_nan());
        let one = geomean(&[1.0; 8]);
        assert!((one - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_sig_rounds() {
        assert_eq!(fmt_sig(0.0, 3), "0");
        assert_eq!(fmt_sig(1234.6, 3), "1235"); // mag >= digits: no decimals
        assert_eq!(fmt_sig(1.2345, 3), "1.23");
    }
}
