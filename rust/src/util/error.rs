//! Minimal in-tree error type.
//!
//! The offline registry resolves no `anyhow`, so this module provides the
//! small slice of its surface the crate uses: a message-chain [`Error`],
//! a defaulted [`Result`], the [`bail!`](crate::bail) macro and the
//! [`Context`] extension trait for `Result`/`Option`. Both `{e}` and the
//! anyhow-style `{e:#}` print the full context chain.

use std::fmt;

/// A context chain: outermost frame first, root cause last.
#[derive(Clone, Debug)]
pub struct Error {
    chain: Vec<String>,
}

/// Crate-wide result with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create from a single message.
    pub fn msg(m: impl Into<String>) -> Error {
        Error { chain: vec![m.into()] }
    }

    /// Wrap with an outer context frame.
    pub fn context(mut self, m: impl Into<String>) -> Error {
        self.chain.insert(0, m.into());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<String> for Error {
    fn from(m: String) -> Error {
        Error::msg(m)
    }
}

impl From<&str> for Error {
    fn from(m: &str) -> Error {
        Error::msg(m)
    }
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Attach context to a fallible value (mirror of anyhow's trait).
///
/// Caveat: the blanket impl stringifies the source error, so applying it
/// to a `Result<_, Error>` flattens an existing chain (Display output is
/// unchanged, but `root_cause()` coarsens). When the error already is an
/// [`Error`], prefer `.map_err(|e| e.context(..))`.
pub trait Context<T> {
    /// Wrap the error with a fixed message.
    fn context(self, msg: impl Into<String>) -> Result<T>;
    /// Wrap the error with a lazily-built message.
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| Error::msg(e.to_string()).context(msg))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(e.to_string()).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root {}", 42)
    }

    #[test]
    fn bail_and_chain_format() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(e.to_string(), "outer: root 42");
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert_eq!(e.root_cause(), "root 42");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("while formatting").unwrap_err();
        assert!(e.to_string().starts_with("while formatting: "));

        let none: Option<u32> = None;
        assert_eq!(none.with_context(|| "missing".into()).unwrap_err().to_string(), "missing");
        assert_eq!(Some(7u32).context("unused").unwrap(), 7);
    }

    #[test]
    fn io_error_converts() {
        fn open_missing() -> Result<std::fs::File> {
            Ok(std::fs::File::open("/definitely/not/here/bgpc")?)
        }
        assert!(open_missing().is_err());
    }
}
