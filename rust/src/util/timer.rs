//! Wall-clock timing helpers for benches and the engine's phase traces.

use std::time::{Duration, Instant};

/// A simple stopwatch accumulating named laps (used by the per-iteration
/// phase traces that regenerate the paper's Figure 1).
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    laps: Vec<(String, Duration)>,
    last: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, laps: Vec::new(), last: now }
    }

    /// Record a lap since the previous lap (or start).
    pub fn lap(&mut self, name: impl Into<String>) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        self.laps.push((name.into(), d));
        d
    }

    pub fn total(&self) -> Duration {
        Instant::now() - self.start
    }

    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }
}

/// Time a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run `f` `reps` times and return the minimum wall-clock seconds
/// (min is the standard robust estimator for microbenchmarks).
pub fn time_min<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        let out = f();
        std::hint::black_box(&out);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate() {
        let mut sw = Stopwatch::new();
        sw.lap("a");
        sw.lap("b");
        assert_eq!(sw.laps().len(), 2);
        assert_eq!(sw.laps()[0].0, "a");
    }

    #[test]
    fn time_returns_result() {
        let (v, secs) = time(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
