//! `obs` — unified observability: a metrics [`Registry`] and a
//! per-thread span tracer with Chrome-trace export.
//!
//! The paper's performance argument is phase-level (speculate vs.
//! conflict-detect vs. sequential-finish), but wall-clock totals hide
//! that structure. This module gives every layer one shared surface:
//!
//! * [`registry`] — named [`Counter`]s, [`Gauge`]s, and log2
//!   [`Hist`]ograms behind `Arc` handles; registration takes a lock
//!   once, recording is a relaxed atomic op. `coordinator::Metrics` is
//!   a façade over one [`Registry`]; pool and queue stats publish into
//!   it as gauges at snapshot time ([`Registry::exposition`]).
//! * [`trace`] — RAII [`span`](trace::span) guards writing complete
//!   events into per-thread rings, drained on demand and exported as
//!   Chrome trace-event JSON ([`trace::write_chrome`]) for Perfetto.
//!   Compiled in by the `trace` cargo feature, armed by
//!   [`trace::set_enabled`]; free when off.
//!
//! Span names are dotted `layer.phase` (`pool.region`,
//! `bgpc.speculate`, `repair.detect_dirty`, `coord.dispatch`,
//! `exec.color`, ...) so a Perfetto query can group by layer. See
//! DESIGN.md §13 for the architecture and the overhead contract.

pub mod registry;
pub mod trace;

pub use registry::{Counter, Gauge, Hist, Registry, HIST_BUCKETS};
pub use trace::{
    available, drain, enabled, export_chrome, instant, set_enabled, span,
    span_n, write_chrome, Event, Ring, Span, TraceData,
};
