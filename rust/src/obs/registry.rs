//! The unified metrics registry: named counters, gauges, and log₂
//! histograms behind cheap `Arc` handles.
//!
//! Registration (name → handle) takes a short-lived lock on a sorted
//! map; it happens once per metric, at construction time. *Recording*
//! is handle-based and lock-free — a relaxed atomic add on the `Arc`'d
//! cell — so hot paths never touch the map. [`Registry::exposition`]
//! renders every metric as sorted `kind name value` lines, the text
//! snapshot the coordinator's `Stats` job and `serve --stats-interval`
//! print (DESIGN.md §13).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering as AOrd};
use std::sync::{Arc, Mutex};

/// Number of log₂ buckets (bucket `b` holds values in `[2^b, 2^(b+1))`,
/// with 0 landing in bucket 0 — 64 buckets cover the full `u64` range).
pub const HIST_BUCKETS: usize = 64;

/// A monotonically increasing counter. Recording is one relaxed add.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add `n` to the counter.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, AOrd::Relaxed);
    }

    /// Add 1.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(AOrd::Relaxed)
    }
}

/// A last-value-wins gauge (pool utilization, queue depth, ...).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Overwrite the gauge.
    pub fn set(&self, v: u64) {
        self.0.store(v, AOrd::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(AOrd::Relaxed)
    }
}

/// A lock-free log₂ histogram over raw `u64` values (the coordinator
/// records microseconds into it; the unit is the caller's).
///
/// Edge cases are part of the contract: `record(0)` lands in the first
/// bucket, `record(u64::MAX)` in the last, and neither path shifts by
/// 64 anywhere (quantile upper bounds are computed in `f64`, where
/// `2^64` is representable). An empty histogram has no quantiles —
/// [`Hist::quantile`] returns `None`, and renderers print `-`.
#[derive(Debug)]
pub struct Hist {
    counts: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
    n: AtomicU64,
}

impl Default for Hist {
    fn default() -> Hist {
        Hist {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            n: AtomicU64::new(0),
        }
    }
}

impl Hist {
    /// Record one observation: two relaxed adds plus a leading-zeros.
    pub fn record(&self, v: u64) {
        // floor(log2(v)) with 0 clamped into bucket 0; v = u64::MAX has
        // 0 leading zeros and lands in bucket 63 — no shift by 64 here.
        let b = (63 - v.max(1).leading_zeros()) as usize;
        self.counts[b].fetch_add(1, AOrd::Relaxed);
        self.sum.fetch_add(v, AOrd::Relaxed);
        self.n.fetch_add(1, AOrd::Relaxed);
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.n.load(AOrd::Relaxed)
    }

    /// Sum of all recorded values (wrapping on overflow, like the adds).
    pub fn sum(&self) -> u64 {
        self.sum.load(AOrd::Relaxed)
    }

    /// Mean recorded value; `None` when empty.
    pub fn mean(&self) -> Option<f64> {
        let n = self.count();
        if n == 0 {
            return None;
        }
        Some(self.sum() as f64 / n as f64)
    }

    /// The `q`-quantile (0 < q ≤ 1) as the holding bucket's *upper
    /// bound* `2^(b+1)` — a ≤2× overestimate by construction, fine for
    /// trend lines and gates that compare like against like. `None`
    /// when the histogram is empty (there is no garbage midpoint to
    /// report). Computed in `f64` so the last bucket's bound (`2^64`)
    /// needs no u64 shift.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let n = self.count();
        if n == 0 {
            return None;
        }
        let target = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (b, c) in self.counts.iter().enumerate() {
            seen += c.load(AOrd::Relaxed);
            if seen >= target {
                return Some((b as f64 + 1.0).exp2());
            }
        }
        Some((HIST_BUCKETS as f64).exp2())
    }

    /// Per-bucket counts (bucket `b` = values in `[2^b, 2^(b+1))`).
    pub fn buckets(&self) -> [u64; HIST_BUCKETS] {
        std::array::from_fn(|b| self.counts[b].load(AOrd::Relaxed))
    }
}

/// A registered metric (the map's value side).
#[derive(Debug, Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Hist(Arc<Hist>),
}

/// A named-metric registry (see module docs). One per
/// [`crate::coordinator::Service`]; construct more freely — it is just
/// a sorted map of atomic cells.
#[derive(Debug, Default)]
pub struct Registry {
    map: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// The counter registered under `name`, registering it first if
    /// needed. Clones of the returned handle record into the same cell.
    ///
    /// # Panics
    /// If `name` is already registered as a different metric kind.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.map.lock().unwrap();
        match map
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())))
        {
            Metric::Counter(c) => Arc::clone(c),
            _ => panic!("obs: metric {name:?} already registered with another kind"),
        }
    }

    /// The gauge registered under `name` (see [`Registry::counter`]).
    ///
    /// # Panics
    /// If `name` is already registered as a different metric kind.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.map.lock().unwrap();
        match map
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())))
        {
            Metric::Gauge(g) => Arc::clone(g),
            _ => panic!("obs: metric {name:?} already registered with another kind"),
        }
    }

    /// The histogram registered under `name` (see [`Registry::counter`]).
    ///
    /// # Panics
    /// If `name` is already registered as a different metric kind.
    pub fn hist(&self, name: &str) -> Arc<Hist> {
        let mut map = self.map.lock().unwrap();
        match map
            .entry(name.to_string())
            .or_insert_with(|| Metric::Hist(Arc::new(Hist::default())))
        {
            Metric::Hist(h) => Arc::clone(h),
            _ => panic!("obs: metric {name:?} already registered with another kind"),
        }
    }

    /// Number of registered metrics.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// Whether nothing is registered yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Text snapshot: one sorted line per metric.
    ///
    /// ```text
    /// counter coord.jobs 42
    /// gauge pool.threads 8
    /// hist coord.queue_wait_us n=12 mean=103.2 p50=128 p99=2048 max=4096
    /// ```
    ///
    /// Empty histograms render `-` for mean and every quantile.
    pub fn exposition(&self) -> String {
        let snap: Vec<(String, Metric)> = {
            let map = self.map.lock().unwrap();
            map.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
        };
        let mut out = String::new();
        for (name, m) in snap {
            match m {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "counter {name} {}", c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "gauge {name} {}", g.get());
                }
                Metric::Hist(h) => {
                    let disp = |v: Option<f64>| match v {
                        Some(x) => format!("{x:.1}"),
                        None => "-".to_string(),
                    };
                    let _ = writeln!(
                        out,
                        "hist {name} n={} mean={} p50={} p99={} max={}",
                        h.count(),
                        disp(h.mean()),
                        disp(h.quantile(0.50)),
                        disp(h.quantile(0.99)),
                        disp(h.quantile(1.0)),
                    );
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_share_cells_and_exposition_sorts() {
        let r = Registry::new();
        let a = r.counter("z.last");
        let b = r.counter("z.last");
        a.add(2);
        b.inc();
        r.gauge("a.first").set(7);
        r.hist("m.mid").record(100);
        assert_eq!(r.counter("z.last").get(), 3, "same name, same cell");
        assert_eq!(r.len(), 3);
        let text = r.exposition();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "gauge a.first 7");
        assert!(lines[1].starts_with("hist m.mid n=1"));
        assert_eq!(lines[2], "counter z.last 3");
    }

    #[test]
    #[should_panic(expected = "another kind")]
    fn kind_clash_panics() {
        let r = Registry::new();
        let _ = r.counter("x");
        let _ = r.gauge("x");
    }

    #[test]
    fn hist_edge_values_land_in_first_and_last_bucket() {
        let h = Hist::default();
        h.record(0);
        h.record(u64::MAX);
        let b = h.buckets();
        assert_eq!(b[0], 1, "0 lands in the first bucket");
        assert_eq!(b[HIST_BUCKETS - 1], 1, "u64::MAX lands in the last bucket");
        assert_eq!(h.count(), 2);
        // the last bucket's upper bound is 2^64 — representable in f64,
        // no u64 shift overflow on the way there
        let max = h.quantile(1.0).unwrap();
        assert_eq!(max, 64f64.exp2());
        assert!(h.mean().unwrap() > 0.0);
    }

    #[test]
    fn empty_hist_has_no_quantiles() {
        let h = Hist::default();
        assert_eq!(h.quantile(0.5), None);
        assert_eq!(h.quantile(0.99), None);
        assert_eq!(h.mean(), None);
        let r = Registry::new();
        let _ = r.hist("empty");
        let text = r.exposition();
        assert!(
            text.contains("n=0 mean=- p50=- p99=- max=-"),
            "empty histogram renders dashes, got: {text}"
        );
    }

    #[test]
    fn hist_quantiles_walk_buckets() {
        let h = Hist::default();
        for _ in 0..99 {
            h.record(100); // bucket [64,128)
        }
        h.record(50_000); // bucket [32768,65536)
        assert_eq!(h.quantile(0.50), Some(128.0));
        assert_eq!(h.quantile(0.99), Some(128.0));
        assert_eq!(h.quantile(1.0), Some(65536.0));
    }

    #[test]
    fn concurrent_recording_is_exact() {
        // Satellite contract: counts stay exact under contention — no
        // lost updates across threads hammering one registry.
        let r = Arc::new(Registry::new());
        let c = r.counter("hot.counter");
        let h = r.hist("hot.hist");
        const THREADS: usize = 8;
        const PER: u64 = 10_000;
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let c = Arc::clone(&c);
                let h = Arc::clone(&h);
                let r = Arc::clone(&r);
                s.spawn(move || {
                    // half the threads fetch their own handles mid-storm
                    let c = if t % 2 == 0 { c } else { r.counter("hot.counter") };
                    for i in 0..PER {
                        c.inc();
                        h.record(i);
                    }
                });
            }
        });
        assert_eq!(c.get(), THREADS as u64 * PER);
        assert_eq!(h.count(), THREADS as u64 * PER);
        let total: u64 = h.buckets().iter().sum();
        assert_eq!(total, THREADS as u64 * PER, "every observation in exactly one bucket");
    }
}
