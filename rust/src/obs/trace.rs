//! Per-thread span/event tracer with Chrome trace-event export.
//!
//! Recording is two-tier "free when off": the `trace` cargo feature
//! compiles the recording path in at all ([`available`]), and a runtime
//! flag ([`set_enabled`]) arms it. With the feature off, [`span`]
//! returns an inert guard and the whole thing folds away; with the
//! feature on but recording disabled, a span costs one relaxed atomic
//! load — `benches/scheduler.rs` gates that marginal cost at ≤2% of a
//! small pool-region dispatch.
//!
//! Each recording thread owns a fixed-capacity [`Ring`] (oldest events
//! are dropped on wraparound, never the newest) behind a mutex that
//! only the owner and a drain ever touch — recording never contends
//! with other recorders. Spans are RAII guards ([`Span`]) that record
//! one *complete* event at drop, so per-thread events nest strictly by
//! construction (guards drop LIFO) and a wrapped ring drops children
//! before their parents. [`drain`] collects every thread's events;
//! [`export_chrome`] renders them as Chrome trace-event JSON that
//! `chrome://tracing` and [Perfetto](https://ui.perfetto.dev) load
//! directly (see README "Observability").

use std::collections::VecDeque;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering as AOrd};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Per-thread ring capacity: at ~40 B/event this bounds tracing memory
/// to ~0.7 MB per recording thread.
pub const RING_CAP: usize = 1 << 14;

/// One completed span (or instant event, when `start_ns == end_ns`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Event {
    /// Phase name (`"pool.region"`, `"repair.speculate"`, ...).
    pub name: &'static str,
    /// Registration-order thread id (stable across drains).
    pub tid: u64,
    /// Nanoseconds since the tracer epoch (first arm/record).
    pub start_ns: u64,
    /// End of the span; equal to `start_ns` for instant events.
    pub end_ns: u64,
    /// Optional payload (region items, queue length, color index, ...),
    /// exported as `args.n`.
    pub arg: Option<u64>,
}

/// A fixed-capacity event ring: pushing into a full ring drops the
/// *oldest* event — the tail of a long run stays inspectable even when
/// the buffer wraps.
#[derive(Debug)]
pub struct Ring {
    cap: usize,
    buf: VecDeque<Event>,
    dropped: u64,
}

impl Ring {
    /// An empty ring holding at most `cap` events (min 1).
    pub fn new(cap: usize) -> Ring {
        Ring { cap: cap.max(1), buf: VecDeque::new(), dropped: 0 }
    }

    /// Append `ev`, dropping the oldest event when full.
    pub fn push(&mut self, ev: Event) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(ev);
    }

    /// Take every buffered event (oldest first), leaving the ring empty.
    pub fn drain(&mut self) -> Vec<Event> {
        self.buf.drain(..).collect()
    }

    /// Events currently buffered.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether the ring holds no events.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events lost to wraparound since construction.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

/// One registered recording thread: its stable id, name, and ring. The
/// global registry keeps an `Arc` so events survive thread exit.
struct ThreadRing {
    tid: u64,
    name: String,
    ring: Mutex<Ring>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(0);
static THREADS: Mutex<Vec<Arc<ThreadRing>>> = Mutex::new(Vec::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();

thread_local! {
    static RING: Arc<ThreadRing> = {
        let tid = NEXT_TID.fetch_add(1, AOrd::Relaxed);
        let name = std::thread::current()
            .name()
            .map(str::to_string)
            .unwrap_or_else(|| format!("thread-{tid}"));
        let tr = Arc::new(ThreadRing { tid, name, ring: Mutex::new(Ring::new(RING_CAP)) });
        THREADS.lock().unwrap().push(Arc::clone(&tr));
        tr
    };
}

/// Whether the recording path is compiled in (`--features trace`).
pub fn available() -> bool {
    cfg!(feature = "trace")
}

/// Arm or disarm recording. Returns the effective state: always `false`
/// when the `trace` feature is compiled out (the flag is then inert).
pub fn set_enabled(on: bool) -> bool {
    if !available() {
        return false;
    }
    if on {
        // pin the epoch before the first span so all threads share it
        let _ = EPOCH.get_or_init(Instant::now);
    }
    ENABLED.store(on, AOrd::Relaxed);
    on
}

/// Whether recording is currently armed (feature on + runtime flag).
pub fn enabled() -> bool {
    available() && ENABLED.load(AOrd::Relaxed)
}

fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// An RAII phase guard: created by [`span`], records one complete
/// [`Event`] into the calling thread's ring when dropped. Inert (a
/// stack struct and a branch) unless recording was armed at creation.
#[must_use = "a span records its duration when dropped"]
pub struct Span {
    name: &'static str,
    start_ns: u64,
    arg: Option<u64>,
    live: bool,
}

impl Span {
    /// Attach a numeric payload (exported as `args.n`).
    pub fn with_arg(mut self, n: u64) -> Span {
        if self.live {
            self.arg = Some(n);
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.live {
            let end_ns = now_ns();
            record(Event {
                name: self.name,
                tid: 0, // filled from the thread ring
                start_ns: self.start_ns,
                end_ns,
                arg: self.arg,
            });
        }
    }
}

/// Open a phase span. With the `trace` feature off, or recording
/// disarmed, this is an inert guard (no clock read, no allocation).
#[inline]
pub fn span(name: &'static str) -> Span {
    #[cfg(feature = "trace")]
    if ENABLED.load(AOrd::Relaxed) {
        return Span { name, start_ns: now_ns(), arg: None, live: true };
    }
    Span { name, start_ns: 0, arg: None, live: false }
}

/// [`span`] with a numeric payload attached (items, queue length, ...).
#[inline]
pub fn span_n(name: &'static str, n: u64) -> Span {
    span(name).with_arg(n)
}

/// Record a zero-duration instant event (visible as a tick mark).
#[inline]
pub fn instant(name: &'static str) {
    #[cfg(feature = "trace")]
    if ENABLED.load(AOrd::Relaxed) {
        let t = now_ns();
        record(Event { name, tid: 0, start_ns: t, end_ns: t, arg: None });
    }
    #[cfg(not(feature = "trace"))]
    let _ = name;
}

fn record(mut ev: Event) {
    // try_with: a span dropped during thread teardown has no ring left;
    // losing that one event beats aborting the process.
    let _ = RING.try_with(|tr| {
        ev.tid = tr.tid;
        tr.ring.lock().unwrap().push(ev);
    });
}

/// Everything [`drain`] collected: the events, the thread-name table,
/// and how many events were lost to ring wraparound.
#[derive(Debug, Default)]
pub struct TraceData {
    /// All drained events, sorted by start time (parents before
    /// children on ties).
    pub events: Vec<Event>,
    /// `(tid, thread name)` for every thread that ever recorded.
    pub threads: Vec<(u64, String)>,
    /// Events dropped to wraparound across all rings (lifetime total).
    pub dropped: u64,
}

/// Drain every thread's ring (leaving them empty) and return the
/// collected events. Cheap when nothing recorded. Threads keep
/// recording while a drain runs; such racing events land in the next
/// drain.
pub fn drain() -> TraceData {
    let threads: Vec<Arc<ThreadRing>> =
        THREADS.lock().unwrap().iter().map(Arc::clone).collect();
    let mut data = TraceData::default();
    for tr in threads {
        let mut ring = tr.ring.lock().unwrap();
        data.dropped += ring.dropped();
        let events = ring.drain();
        drop(ring);
        if !events.is_empty() {
            data.threads.push((tr.tid, tr.name.clone()));
            data.events.extend(events);
        }
    }
    // parents start no later than their children and end no earlier:
    // sort start-ascending, end-descending, so export order nests.
    data.events.sort_by(|a, b| {
        a.start_ns.cmp(&b.start_ns).then(b.end_ns.cmp(&a.end_ns))
    });
    data.threads.sort();
    data
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Render drained trace data as Chrome trace-event JSON (the
/// `traceEvents` array format) — loadable by `chrome://tracing` and
/// Perfetto. Spans become complete (`"ph":"X"`) events with µs
/// timestamps; instants become `"ph":"i"`; thread names become
/// metadata events.
pub fn export_chrome(data: &TraceData) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    let mut push = |line: String, out: &mut String, first: &mut bool| {
        if !*first {
            out.push_str(",\n");
        }
        *first = false;
        out.push_str(&line);
    };
    for (tid, name) in &data.threads {
        push(
            format!(
                "{{\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\"name\":\"thread_name\",\"args\":{{\"name\":\"{}\"}}}}",
                json_escape(name)
            ),
            &mut out,
            &mut first,
        );
    }
    for e in &data.events {
        let ts = e.start_ns as f64 / 1e3;
        let name = json_escape(e.name);
        let args = match e.arg {
            Some(n) => format!(",\"args\":{{\"n\":{n}}}"),
            None => String::new(),
        };
        let line = if e.end_ns == e.start_ns {
            format!(
                "{{\"ph\":\"i\",\"pid\":1,\"tid\":{},\"name\":\"{name}\",\"s\":\"t\",\"ts\":{ts:.3}{args}}}",
                e.tid
            )
        } else {
            let dur = (e.end_ns - e.start_ns) as f64 / 1e3;
            format!(
                "{{\"ph\":\"X\",\"pid\":1,\"tid\":{},\"name\":\"{name}\",\"cat\":\"bgpc\",\"ts\":{ts:.3},\"dur\":{dur:.3}{args}}}",
                e.tid
            )
        };
        push(line, &mut out, &mut first);
    }
    out.push_str("\n],\"displayTimeUnit\":\"ms\"}\n");
    out
}

/// Drain all rings and write the Chrome trace JSON to `path`.
pub fn write_chrome(path: impl AsRef<Path>) -> std::io::Result<()> {
    let data = drain();
    std::fs::write(path, export_chrome(&data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &'static str, tid: u64, start: u64, end: u64) -> Event {
        Event { name, tid, start_ns: start, end_ns: end, arg: None }
    }

    #[test]
    fn ring_wraparound_drops_oldest_not_newest() {
        let mut r = Ring::new(3);
        for i in 0..5u64 {
            r.push(ev("e", 0, i, i + 1));
        }
        assert_eq!(r.dropped(), 2);
        let out = r.drain();
        let starts: Vec<u64> = out.iter().map(|e| e.start_ns).collect();
        assert_eq!(starts, vec![2, 3, 4], "oldest two dropped, newest kept");
        assert!(r.is_empty());
    }

    #[test]
    fn export_is_valid_json_shape_and_escapes() {
        let data = TraceData {
            events: vec![
                ev("outer", 7, 1_000, 9_000),
                Event { arg: Some(42), ..ev("inner", 7, 2_000, 4_000) },
                ev("tick", 7, 3_000, 3_000),
            ],
            threads: vec![(7, "bgpc-pool-\"0\"".to_string())],
            dropped: 0,
        };
        let json = export_chrome(&data);
        // structural sanity a JSON parser would enforce (the repo has no
        // serde; scripts/check_trace.py does the full parse in CI)
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.trim_end().ends_with("}"));
        assert_eq!(json.matches("\"ph\":\"X\"").count(), 2);
        assert_eq!(json.matches("\"ph\":\"i\"").count(), 1);
        assert_eq!(json.matches("\"ph\":\"M\"").count(), 1);
        assert!(json.contains("\\\"0\\\""), "quotes in thread names are escaped");
        assert!(json.contains("\"args\":{\"n\":42}"));
        assert!(json.contains("\"ts\":1.000"), "ns are exported as µs");
        assert!(json.contains("\"dur\":8.000"));
        assert!(!json.contains(",\n,"), "no empty elements");
    }

    #[cfg(not(feature = "trace"))]
    #[test]
    fn disabled_feature_records_nothing_and_costs_no_clock() {
        assert!(!available());
        assert!(!set_enabled(true), "arming without the feature is inert");
        {
            let _s = span_n("never", 9);
            instant("never-either");
        }
        assert!(!enabled());
        let data = drain();
        assert!(data.events.is_empty(), "feature off: nothing recorded");
    }

    // The recording-path tests need the feature compiled in; CI runs
    // them via `cargo test --features trace --lib` (scripts/verify.sh).
    #[cfg(feature = "trace")]
    mod recording {
        use super::super::*;

        /// Global recording state is process-wide; serialize the tests
        /// that toggle it.
        fn locked() -> std::sync::MutexGuard<'static, ()> {
            static GATE: Mutex<()> = Mutex::new(());
            GATE.lock().unwrap_or_else(|e| e.into_inner())
        }

        #[test]
        fn disarmed_records_nothing() {
            let _g = locked();
            set_enabled(false);
            let _ = drain();
            {
                let _s = span_n("quiet", 1);
                instant("quiet-tick");
            }
            assert!(drain().events.is_empty());
        }

        #[test]
        fn spans_nest_strictly_per_thread_and_export_parses() {
            let _g = locked();
            set_enabled(false);
            let _ = drain(); // discard other tests' leftovers
            set_enabled(true);
            {
                let _outer = span("outer");
                {
                    let _inner = span_n("inner", 3);
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                instant("tick");
            }
            std::thread::spawn(|| {
                let _s = span("other-thread");
            })
            .join()
            .unwrap();
            set_enabled(false);
            let data = drain();
            // other tests run concurrently and may record through the
            // instrumented hot paths while we are armed — count only the
            // spans this test created
            let ours: Vec<&Event> = data
                .events
                .iter()
                .filter(|e| matches!(e.name, "outer" | "inner" | "tick" | "other-thread"))
                .collect();
            assert_eq!(ours.len(), 4);
            let tids: std::collections::HashSet<u64> = ours.iter().map(|e| e.tid).collect();
            assert_eq!(tids.len(), 2, "two recording threads");
            // strict nesting on this thread: guards drop LIFO, so for
            // any two spans on one tid: disjoint or contained.
            let spans: Vec<&Event> = data
                .events
                .iter()
                .filter(|e| e.end_ns > e.start_ns)
                .collect();
            for a in &spans {
                for b in &spans {
                    if a.tid != b.tid || std::ptr::eq(*a, *b) {
                        continue;
                    }
                    let disjoint = a.end_ns <= b.start_ns || b.end_ns <= a.start_ns;
                    let a_in_b = a.start_ns >= b.start_ns && a.end_ns <= b.end_ns;
                    let b_in_a = b.start_ns >= a.start_ns && b.end_ns <= a.end_ns;
                    assert!(
                        disjoint || a_in_b || b_in_a,
                        "spans overlap without nesting: {a:?} vs {b:?}"
                    );
                }
            }
            let outer = data.events.iter().find(|e| e.name == "outer").unwrap();
            let inner = data.events.iter().find(|e| e.name == "inner").unwrap();
            assert_eq!(inner.arg, Some(3));
            assert!(outer.start_ns <= inner.start_ns && inner.end_ns <= outer.end_ns);
            let json = export_chrome(&data);
            assert!(json.contains("\"name\":\"outer\""));
            assert!(json.contains("\"name\":\"other-thread\""));
        }

        #[test]
        fn drain_leaves_rings_empty() {
            let _g = locked();
            set_enabled(true);
            {
                let _s = span("once");
            }
            set_enabled(false);
            assert_eq!(drain().events.iter().filter(|e| e.name == "once").count(), 1);
            assert_eq!(drain().events.iter().filter(|e| e.name == "once").count(), 0);
        }
    }
}
