//! Color-parallel execution — what the coloring is *for*.
//!
//! The paper's premise (§I) is that "a valid graph coloring yields a
//! lock-free processing of the colored tasks": partition the items into
//! color sets, process one set at a time, and within a set no two items
//! conflict — shared state needs no locks, only a barrier between sets.
//! Its B1/B2 balancing heuristics exist *for this step*: "the sets
//! should preferably have similar sizes", because the color-parallel
//! critical path is the costliest set of each wave. Everything below
//! the coordinator produced colorings; this subsystem consumes them
//! (DESIGN.md §11):
//!
//! * [`ColorSchedule`] — per-color frontiers counting-sorted from a
//!   `&[i32]` coloring, position-indexed so the colors dirtied by a
//!   [`crate::dynamic`] repair are rebuilt incrementally
//!   ([`ColorSchedule::refresh`]: O(n) diff + O(changed) moves) instead
//!   of re-sorting the world per batch.
//! * [`Executor`] / [`run_colored`] — drive a `(item, color) -> Cost`
//!   kernel frontier-by-frontier on the shared [`WorkerPool`]: one pool
//!   region per color, the region drain as the barrier, per-color busy
//!   units recorded so skew shows up as [`ExecReport::max_color_busy`]
//!   — wall-clock evidence for the balancing experiments, not just a
//!   cardinality statistic.
//! * [`SharedBuf`] — shared mutable state whose race-freedom
//!   certificate is the coloring itself (unsafe access scoped to the
//!   slots an item owns under the schedule).
//!
//! The coordinator wires this through as
//! [`crate::coordinator::JobInput::Execute`]: a kernel re-runs against
//! a live dynamic session, with the session's cached schedule refreshed
//! from whatever the last repair dirtied (repair → rebuild dirty
//! frontiers → re-run). `benches/execute.rs` gates the payoff end to
//! end; `examples/colored_spmv.rs` is the front door.

pub mod executor;
pub mod schedule;

pub use executor::{ExecReport, Executor, SharedBuf};
pub use schedule::{ColorSchedule, EpochSchedule, RefreshStats};

use std::sync::Arc;

use crate::par::{Cost, WorkerPool};

/// One-shot front door: bucket `colors` and run `kernel` over the
/// frontiers for `rounds` sweeps on `pool`'s full team. Returns the
/// schedule (reuse it — and [`ColorSchedule::refresh`] — for later
/// runs) and the execution report.
pub fn run_colored<K>(
    pool: &Arc<WorkerPool>,
    colors: &[i32],
    rounds: usize,
    kernel: K,
) -> (ColorSchedule, ExecReport)
where
    K: Fn(usize, usize) -> Cost + Sync,
{
    let sched = ColorSchedule::from_colors(colors);
    let report = Executor::new(pool).run(&sched, rounds, kernel);
    (sched, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering as AOrd};

    #[test]
    fn run_colored_front_door_covers_every_item() {
        let colors = [0, 1, 0, 2, 1];
        let pool = Arc::new(WorkerPool::new(2));
        let hits: Vec<AtomicU64> = (0..5).map(|_| AtomicU64::new(0)).collect();
        let (sched, rep) = run_colored(&pool, &colors, 3, |item, color| {
            assert_eq!(colors[item], color as i32);
            hits[item].fetch_add(1, AOrd::Relaxed);
            Cost::new(1)
        });
        assert!(hits.iter().all(|h| h.load(AOrd::Relaxed) == 3));
        assert_eq!(sched.n_colors(), 3);
        assert_eq!(rep.items, 15);
        assert!(rep.summary().contains("rounds=3"));
    }
}
