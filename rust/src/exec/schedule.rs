//! [`ColorSchedule`] — per-color execution frontiers built from a
//! coloring.
//!
//! The schedule buckets the colored items (BGPC columns, D2GC vertices)
//! into one frontier per color with a counting sort, and keeps the
//! buckets position-indexed so a *dynamic repair* — which recolors only
//! a small frontier of the graph (DESIGN.md §8) — costs an O(n) diff
//! scan plus O(changed) bucket surgery instead of a full re-sort
//! ([`ColorSchedule::refresh`]). All allocations are reusable: a
//! rebuild clears and refills, a refresh moves items in place.

use crate::coloring::stats::ColorStats;

/// Outcome of an incremental [`ColorSchedule::refresh`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RefreshStats {
    /// Items moved between color buckets.
    pub moved: usize,
    /// Distinct colors whose bucket changed (sources and destinations).
    pub dirty_colors: usize,
    /// True when the refresh fell back to a full counting-sort rebuild
    /// (item shrink — never produced by the engines — or first build).
    pub rebuilt: bool,
}

/// Per-color frontiers of a complete coloring (see module docs).
///
/// Invariants: `buckets[c]` holds exactly the items whose snapshot
/// color is `c`; `pos[u]` is `u`'s index inside its bucket (what makes
/// a [`ColorSchedule::refresh`] move O(1) per changed item). Bucket
/// order within a color is unspecified — colored execution must not
/// depend on it, and [`super::Executor`] does not.
pub struct ColorSchedule {
    buckets: Vec<Vec<u32>>,
    /// Snapshot of the coloring the buckets currently reflect.
    color_of: Vec<i32>,
    /// Position of each item within its bucket.
    pos: Vec<u32>,
}

impl ColorSchedule {
    /// Bucket `colors` into per-color frontiers (counting sort).
    ///
    /// # Panics
    /// If any item is uncolored (`< 0`) — schedules are built from the
    /// *complete* colorings the engines and sessions hand back.
    pub fn from_colors(colors: &[i32]) -> ColorSchedule {
        let mut s = ColorSchedule { buckets: Vec::new(), color_of: Vec::new(), pos: Vec::new() };
        s.rebuild(colors);
        s
    }

    /// Full counting-sort rebuild, reusing the bucket allocations.
    ///
    /// # Panics
    /// If any item is uncolored (`< 0`).
    pub fn rebuild(&mut self, colors: &[i32]) {
        let nc = (colors.iter().copied().max().unwrap_or(-1) + 1) as usize;
        for b in &mut self.buckets {
            b.clear();
        }
        if self.buckets.len() < nc {
            self.buckets.resize_with(nc, Vec::new);
        } else {
            self.buckets.truncate(nc);
        }
        self.color_of.clear();
        self.color_of.extend_from_slice(colors);
        self.pos.clear();
        self.pos.resize(colors.len(), 0);
        for (u, &c) in colors.iter().enumerate() {
            assert!(c >= 0, "item {u} is uncolored; schedules need a complete coloring");
            let b = &mut self.buckets[c as usize];
            self.pos[u] = b.len() as u32;
            b.push(u as u32);
        }
    }

    /// Incremental refresh against the internal snapshot: an O(n)
    /// compare finds the items a repair recolored, and only the buckets
    /// those items leave or join are touched — the colors dirtied by
    /// the batch, not the whole schedule. Item growth (a session that
    /// gained vertices) extends the snapshot in place; shrink falls
    /// back to [`ColorSchedule::rebuild`]. Returns what moved.
    ///
    /// # Panics
    /// If any item of `colors` is uncolored (`< 0`).
    pub fn refresh(&mut self, colors: &[i32]) -> RefreshStats {
        if colors.len() < self.color_of.len() {
            self.rebuild(colors);
            return RefreshStats {
                moved: colors.len(),
                dirty_colors: self.buckets.len(),
                rebuilt: true,
            };
        }
        if colors.len() > self.color_of.len() {
            // growth tail: snapshot as "uncolored", moved below
            self.color_of.resize(colors.len(), -1);
            self.pos.resize(colors.len(), 0);
        }
        let mut moved = 0usize;
        let mut dirty: Vec<u32> = Vec::new();
        for (u, &c) in colors.iter().enumerate() {
            // checked before the no-change test: a grown tail snapshots
            // as -1, and an uncolored new item must reject, not skip
            assert!(c >= 0, "item {u} became uncolored; schedules need a complete coloring");
            let old = self.color_of[u];
            if c == old {
                continue;
            }
            if old >= 0 {
                dirty.push(old as u32);
            }
            dirty.push(c as u32);
            self.move_item(u, c);
            moved += 1;
        }
        dirty.sort_unstable();
        dirty.dedup();
        RefreshStats { moved, dirty_colors: dirty.len(), rebuilt: false }
    }

    /// O(1) bucket surgery: swap-remove `u` from its old bucket (fixing
    /// the displaced item's position index), append it to the new one.
    fn move_item(&mut self, u: usize, new_c: i32) {
        let old = self.color_of[u];
        if old >= 0 {
            let b = &mut self.buckets[old as usize];
            let p = self.pos[u] as usize;
            b.swap_remove(p);
            if p < b.len() {
                self.pos[b[p] as usize] = p as u32;
            }
        }
        let nc = new_c as usize;
        if nc >= self.buckets.len() {
            self.buckets.resize_with(nc + 1, Vec::new);
        }
        let b = &mut self.buckets[nc];
        self.pos[u] = b.len() as u32;
        b.push(u as u32);
        self.color_of[u] = new_c;
    }

    /// Number of color buckets (refreshes may leave empty ones behind;
    /// [`ColorSchedule::frontiers`] skips them).
    pub fn n_colors(&self) -> usize {
        self.buckets.len()
    }

    /// Items scheduled.
    pub fn n_items(&self) -> usize {
        self.color_of.len()
    }

    /// The frontier of color `c` (possibly empty), in unspecified order.
    pub fn color_set(&self, c: usize) -> &[u32] {
        &self.buckets[c]
    }

    /// Snapshot color of item `u` — what the buckets currently reflect,
    /// which may lag the session until the next [`ColorSchedule::refresh`].
    pub fn color_of(&self, u: usize) -> i32 {
        self.color_of[u]
    }

    /// Non-empty frontiers in color order — the executor's wave sequence.
    pub fn frontiers(&self) -> impl Iterator<Item = (usize, &[u32])> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, b)| !b.is_empty())
            .map(|(c, b)| (c, b.as_slice()))
    }

    /// Cardinality of the largest frontier (the color-parallel critical
    /// path is bounded below by its work).
    pub fn max_set_len(&self) -> usize {
        self.buckets.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Bucket cardinalities, including empty buckets.
    pub fn cardinalities(&self) -> Vec<usize> {
        self.buckets.iter().map(Vec::len).collect()
    }

    /// Color-set statistics straight off the bucket sizes — the same
    /// numbers the balancing experiments report
    /// ([`ColorStats`], Table VI), without another pass over the colors.
    pub fn stats(&self) -> ColorStats {
        ColorStats::from_cards(self.cardinalities())
    }
}

/// A [`ColorSchedule`] tagged with the commit *epoch* of the coloring
/// it reflects (DESIGN.md §12). The coordinator's epoch-snapshot
/// sessions hand executes an `(epoch, colors)` pair; `ensure` makes the
/// schedule current for that epoch at the minimum cost — a no-op when
/// the epoch matches, an incremental [`ColorSchedule::refresh`] when it
/// lags, a full build only the first time.
#[derive(Default)]
pub struct EpochSchedule {
    epoch: Option<u64>,
    sched: Option<ColorSchedule>,
}

impl EpochSchedule {
    /// An empty schedule; the first [`EpochSchedule::ensure`] builds it.
    pub fn new() -> EpochSchedule {
        EpochSchedule::default()
    }

    /// The epoch the cached schedule reflects (`None` before first use).
    pub fn epoch(&self) -> Option<u64> {
        self.epoch
    }

    /// The cached schedule (`None` before first use).
    pub fn sched(&self) -> Option<&ColorSchedule> {
        self.sched.as_ref()
    }

    /// Make the cached schedule reflect `colors` as of `epoch`.
    /// Same epoch ⇒ nothing to do; a newer epoch ⇒ diff-refresh against
    /// the cached buckets; first call ⇒ full counting-sort build
    /// (reported as `rebuilt` with every item "moved", matching what
    /// [`ColorSchedule::from_colors`] pays).
    pub fn ensure(&mut self, epoch: u64, colors: &[i32]) -> RefreshStats {
        match (&mut self.sched, self.epoch) {
            (Some(_), Some(e)) if e == epoch => RefreshStats::default(),
            (Some(s), _) => {
                let rs = s.refresh(colors);
                self.epoch = Some(epoch);
                rs
            }
            (None, _) => {
                let s = ColorSchedule::from_colors(colors);
                let rs = RefreshStats {
                    moved: s.n_items(),
                    dirty_colors: s.n_colors(),
                    rebuilt: true,
                };
                self.sched = Some(s);
                self.epoch = Some(epoch);
                rs
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// Bucket `c` sorted for order-insensitive comparison (empty when
    /// the schedule has no such bucket — refreshes may differ from a
    /// fresh build only by trailing empty buckets).
    fn bucket_sorted(s: &ColorSchedule, c: usize) -> Vec<u32> {
        let mut v = Vec::new();
        if c < s.n_colors() {
            v.extend_from_slice(s.color_set(c));
        }
        v.sort_unstable();
        v
    }

    fn assert_matches(sched: &ColorSchedule, colors: &[i32]) {
        assert_eq!(sched.n_items(), colors.len());
        let total: usize = sched.cardinalities().iter().sum();
        assert_eq!(total, colors.len(), "buckets must partition the items");
        for (c, set) in sched.frontiers() {
            for &u in set {
                assert_eq!(colors[u as usize], c as i32, "item {u} in the wrong bucket");
            }
        }
    }

    #[test]
    fn counting_sort_partitions_items() {
        let colors = [0, 2, 1, 0, 2, 2];
        let s = ColorSchedule::from_colors(&colors);
        assert_eq!(s.n_colors(), 3);
        assert_eq!(s.n_items(), 6);
        assert_eq!(s.max_set_len(), 3);
        assert_eq!(s.cardinalities(), vec![2, 1, 3]);
        assert_matches(&s, &colors);
        let st = s.stats();
        assert_eq!(st.n_colors, 3);
        assert_eq!(st.max_cardinality, 3);
    }

    #[test]
    fn refresh_equals_rebuild_under_random_recolors() {
        let mut rng = Rng::new(0xEC);
        let n = 300usize;
        let mut colors: Vec<i32> = (0..n).map(|_| rng.range(0, 7) as i32).collect();
        let mut sched = ColorSchedule::from_colors(&colors);
        for round in 0..10 {
            // recolor a small frontier, occasionally inventing a color
            for _ in 0..rng.range(1, 25) {
                let u = rng.range(0, n);
                colors[u] = rng.range(0, 9) as i32;
            }
            let rs = sched.refresh(&colors);
            assert!(!rs.rebuilt, "same-size refresh must not rebuild");
            assert!(rs.moved <= 24, "round {round}: moved {}", rs.moved);
            assert_matches(&sched, &colors);
            // bucket contents equal a fresh counting sort (order aside)
            let fresh = ColorSchedule::from_colors(&colors);
            for c in 0..sched.n_colors().max(fresh.n_colors()) {
                assert_eq!(
                    bucket_sorted(&sched, c),
                    bucket_sorted(&fresh, c),
                    "round {round}: bucket {c} diverged"
                );
            }
        }
    }

    #[test]
    fn refresh_counts_only_dirty_colors() {
        let colors = [0, 0, 1, 1, 2, 2];
        let mut s = ColorSchedule::from_colors(&colors);
        let unchanged = s.refresh(&colors);
        assert_eq!(unchanged, RefreshStats { moved: 0, dirty_colors: 0, rebuilt: false });
        // one item moves 1 -> 3: colors 1 and 3 are dirty, 0 and 2 not
        let rs = s.refresh(&[0, 0, 1, 3, 2, 2]);
        assert_eq!(rs.moved, 1);
        assert_eq!(rs.dirty_colors, 2);
        assert!(!rs.rebuilt);
        assert_eq!(s.n_colors(), 4);
        assert_eq!(s.color_set(1), &[2]);
        assert_eq!(s.color_set(3), &[3]);
    }

    #[test]
    fn growth_extends_shrink_rebuilds() {
        let mut s = ColorSchedule::from_colors(&[0, 1]);
        let grown = [0, 1, 1, 2];
        let rs = s.refresh(&grown);
        assert!(!rs.rebuilt);
        assert_eq!(rs.moved, 2, "both new items join buckets");
        assert_matches(&s, &grown);
        let shrunk = [1, 0];
        let rs = s.refresh(&shrunk);
        assert!(rs.rebuilt);
        assert_matches(&s, &shrunk);
    }

    #[test]
    fn epoch_schedule_builds_refreshes_and_skips() {
        let mut es = EpochSchedule::new();
        assert!(es.sched().is_none() && es.epoch().is_none());
        // first ensure: full build
        let rs = es.ensure(0, &[0, 1, 0]);
        assert!(rs.rebuilt);
        assert_eq!(rs.moved, 3);
        assert_eq!(es.epoch(), Some(0));
        assert_eq!(es.sched().unwrap().n_items(), 3);
        // same epoch: no work, even if the slice differs (the epoch is
        // the authority on staleness)
        let rs = es.ensure(0, &[0, 1, 0]);
        assert_eq!(rs, RefreshStats::default());
        // newer epoch: incremental refresh of the dirtied colors only
        let rs = es.ensure(1, &[0, 2, 0]);
        assert!(!rs.rebuilt);
        assert_eq!(rs.moved, 1);
        assert_eq!(es.epoch(), Some(1));
        assert_eq!(es.sched().unwrap().color_set(2), &[1]);
    }

    #[test]
    #[should_panic(expected = "uncolored")]
    fn uncolored_items_are_rejected() {
        ColorSchedule::from_colors(&[0, -1, 1]);
    }

    #[test]
    #[should_panic(expected = "uncolored")]
    fn uncolored_growth_tail_is_rejected_by_refresh() {
        // a grown item whose color is still -1 must panic, not silently
        // land in no bucket (the partition invariant)
        let mut s = ColorSchedule::from_colors(&[0, 1]);
        s.refresh(&[0, 1, -1]);
    }
}
