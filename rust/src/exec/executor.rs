//! [`Executor`] — drive a kernel over a [`ColorSchedule`], color set by
//! color set, on a persistent [`WorkerPool`] team.
//!
//! One pool region per non-empty frontier: the region's drain (the
//! caller blocks until every participant checks in, DESIGN.md §10) *is*
//! the barrier between colors, and within a color the schedule's
//! conflict-freedom is the lock-freedom certificate — the kernel may
//! mutate shared state it owns per item without synchronization
//! ([`SharedBuf`] is the crate's canonical such state). Per-color busy
//! units are recorded so the color-parallel critical path — the paper's
//! motivation for B1/B2: "the sets should preferably have similar
//! sizes" for the execution step — is measurable directly
//! ([`ExecReport::max_color_busy`]).

use std::cell::UnsafeCell;
use std::sync::Arc;
use std::time::Instant;

use crate::par::{Cost, WorkerPool};

use super::schedule::ColorSchedule;

/// What one [`Executor::run`] did, with per-color and per-worker
/// accounting (the `PoolStats`-style imbalance view, but along the
/// color axis as well as the worker axis).
#[derive(Clone, Debug)]
pub struct ExecReport {
    /// Color buckets the schedule held (incl. empty ones, skipped).
    pub colors: usize,
    /// Full sweeps over the color sequence.
    pub rounds: usize,
    /// Kernel invocations (items × rounds).
    pub items: u64,
    /// Wall-clock seconds for the whole run.
    pub seconds: f64,
    /// Busy work units per color, summed over rounds and workers — the
    /// per-frontier cost profile (skewed colorings skew this).
    pub per_color_busy: Vec<u64>,
    /// Wall-clock seconds per color, summed over rounds.
    pub per_color_secs: Vec<f64>,
    /// Busy work units per worker, summed over colors and rounds
    /// (index 0 = the calling thread).
    pub worker_busy: Vec<u64>,
}

impl ExecReport {
    /// Total busy work units.
    pub fn busy_total(&self) -> u64 {
        self.per_color_busy.iter().sum()
    }

    /// Busy units of the costliest color set — the critical-path term
    /// the B1/B2 balancing heuristics exist to shrink.
    pub fn max_color_busy(&self) -> u64 {
        self.per_color_busy.iter().copied().max().unwrap_or(0)
    }

    /// Share of all busy units spent in the costliest color
    /// (`1/colors` = perfectly flat profile, `1.0` = one color is the
    /// whole run).
    pub fn critical_share(&self) -> f64 {
        let total = self.busy_total();
        if total == 0 {
            return 0.0;
        }
        self.max_color_busy() as f64 / total as f64
    }

    /// Mean-over-max busy fraction across workers — same definition as
    /// [`crate::par::PoolStats::utilization`], per run.
    pub fn utilization(&self) -> f64 {
        let max = self.worker_busy.iter().copied().max().unwrap_or(0);
        if max == 0 {
            return 1.0;
        }
        let sum: u64 = self.worker_busy.iter().sum();
        sum as f64 / (max as f64 * self.worker_busy.len() as f64)
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "colors={} rounds={} items={} busy={} max_color_busy={} critical_share={:.3} utilization={:.2} secs={:.4}",
            self.colors,
            self.rounds,
            self.items,
            self.busy_total(),
            self.max_color_busy(),
            self.critical_share(),
            self.utilization(),
            self.seconds
        )
    }
}

/// Cap the dynamic chunk so small frontiers still spread across the
/// team (the dynamic engine's adaptive-chunk rule, applied per color —
/// a 40-item frontier with chunk 64 would otherwise run sequentially).
fn effective_chunk(len: usize, team: usize, chunk: usize) -> usize {
    if chunk == 0 {
        return 0; // schedule(static)
    }
    chunk.min((len / team).max(1))
}

/// Colored-execution driver over a shared [`WorkerPool`] (see module
/// docs). Construction is cheap; the coordinator builds one per
/// `Execute` job on its long-lived pool.
pub struct Executor {
    pool: Arc<WorkerPool>,
    team: usize,
    chunk: usize,
    /// Unit per-thread scratch for the pool regions (kernels carry
    /// their own state; reused across colors and rounds).
    states: Vec<()>,
}

impl Executor {
    /// An executor using the pool's full team and the engine's default
    /// `schedule(dynamic, 64)` chunking.
    pub fn new(pool: &Arc<WorkerPool>) -> Executor {
        Executor::on_team(pool, pool.threads())
    }

    /// An executor with an explicit team size (clamped to the pool's).
    pub fn on_team(pool: &Arc<WorkerPool>, team: usize) -> Executor {
        let team = team.clamp(1, pool.threads());
        Executor { pool: Arc::clone(pool), team, chunk: 64, states: vec![(); team] }
    }

    /// Override the dynamic chunk size (`0` = `schedule(static)`).
    pub fn with_chunk(mut self, chunk: usize) -> Executor {
        self.chunk = chunk;
        self
    }

    /// Team size regions are dispatched with.
    pub fn threads(&self) -> usize {
        self.team
    }

    /// Run `kernel` over every frontier of `sched`, in color order,
    /// `rounds` full sweeps; one pool region per non-empty color, with
    /// the region drain as the inter-color barrier. The kernel sees
    /// `(item, color)` and returns the [`Cost`] it performed; within a
    /// color it may touch shared state lock-free wherever the
    /// schedule's conflict-freedom covers the access ([`SharedBuf`]).
    pub fn run<K>(&mut self, sched: &ColorSchedule, rounds: usize, kernel: K) -> ExecReport
    where
        K: Fn(usize, usize) -> Cost + Sync,
    {
        let nc = sched.n_colors();
        let mut per_color_busy = vec![0u64; nc];
        let mut per_color_secs = vec![0.0f64; nc];
        let mut worker_busy = vec![0u64; self.team];
        let mut items = 0u64;
        let _sp = crate::obs::trace::span_n("exec.run", rounds as u64);
        let t0 = Instant::now();
        for _ in 0..rounds {
            for (c, set) in sched.frontiers() {
                let _sp = crate::obs::trace::span_n("exec.color", c as u64);
                let chunk = effective_chunk(set.len(), self.team, self.chunk);
                let out = self.pool.region(
                    &mut self.states,
                    self.team,
                    set.len(),
                    chunk,
                    |_tid, _ts, i, _now| kernel(set[i] as usize, c),
                );
                per_color_busy[c] += out.busy_units.iter().sum::<u64>();
                per_color_secs[c] += out.real_secs;
                for (w, &b) in worker_busy.iter_mut().zip(out.busy_units.iter()) {
                    *w += b;
                }
                items += set.len() as u64;
            }
        }
        ExecReport {
            colors: nc,
            rounds,
            items,
            seconds: t0.elapsed().as_secs_f64(),
            per_color_busy,
            per_color_secs,
            worker_busy,
        }
    }
}

/// A shared buffer whose race-freedom certificate is the coloring: the
/// paper's "a valid graph coloring yields a lock-free processing of the
/// colored tasks" made into a type. Kernels running under a
/// [`ColorSchedule`] may take [`SharedBuf::slot`] for the slots their
/// item owns (a BGPC column's incident rows, a D2GC vertex's own cell)
/// — no two items in one color share such a slot, and colors are
/// separated by the executor's barrier, so the aliasing contract holds
/// without any synchronization.
pub struct SharedBuf<T> {
    cells: Box<[UnsafeCell<T>]>,
}

// SAFETY: all concurrent access goes through `slot`/`peek`, whose
// contracts push disjointness to the caller — exactly what a
// conflict-free color set certifies.
unsafe impl<T: Send> Sync for SharedBuf<T> {}

impl<T> SharedBuf<T> {
    /// Wrap `init` for colored access.
    pub fn new(init: Vec<T>) -> SharedBuf<T> {
        SharedBuf { cells: init.into_iter().map(UnsafeCell::new).collect() }
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Mutable access to slot `i` from inside a kernel.
    ///
    /// # Safety
    /// No other thread may access slot `i` for the duration of the
    /// borrow. Under a conflict-free [`ColorSchedule`] this holds
    /// whenever the running item owns slot `i` w.r.t. the coloring's
    /// conflict definition (e.g. BGPC: `i` is one of the column's
    /// incident rows).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slot(&self, i: usize) -> &mut T {
        &mut *self.cells[i].get()
    }

    /// Shared read of slot `i` from inside a kernel.
    ///
    /// # Safety
    /// No thread may concurrently *write* slot `i`. Under a distance-2
    /// schedule a kernel may read its item's neighbors this way: no
    /// neighbor is in the running color, so none is being written.
    pub unsafe fn peek(&self, i: usize) -> &T {
        &*self.cells[i].get()
    }

    /// Exclusive view for setup and inspection between runs.
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        // SAFETY: `&mut self` guarantees no concurrent kernel access,
        // and `UnsafeCell<T>` is `repr(transparent)` over `T`.
        unsafe { &mut *(self.cells.as_mut() as *mut [UnsafeCell<T>] as *mut [T]) }
    }

    /// Unwrap into the plain vector.
    pub fn into_vec(self) -> Vec<T> {
        self.cells.into_vec().into_iter().map(UnsafeCell::into_inner).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering as AOrd};

    #[test]
    fn barrier_separates_colors_and_accounting_adds_up() {
        // colors 0/1/2 with frontier sizes 3/2/1
        let colors = [0, 0, 0, 1, 1, 2];
        let sched = ColorSchedule::from_colors(&colors);
        let pool = Arc::new(WorkerPool::new(3));
        let mut ex = Executor::new(&pool);
        // each item records the epoch (number of earlier invocations)
        // it ran at; with the inter-color barrier, every color-0 epoch
        // precedes every color-1 epoch, etc.
        let clock = AtomicU64::new(0);
        let stamp: Vec<AtomicU64> = (0..6).map(|_| AtomicU64::new(0)).collect();
        let rep = ex.run(&sched, 1, |item, color| {
            assert_eq!(colors[item], color as i32);
            stamp[item].store(clock.fetch_add(1, AOrd::SeqCst), AOrd::SeqCst);
            Cost::new(1)
        });
        let s: Vec<u64> = stamp.iter().map(|x| x.load(AOrd::SeqCst)).collect();
        let max0 = s[0..3].iter().max().unwrap();
        let min1 = s[3..5].iter().min().unwrap();
        let max1 = s[3..5].iter().max().unwrap();
        assert!(max0 < min1, "color 0 must drain before color 1 starts: {s:?}");
        assert!(max1 < &s[5], "color 1 must drain before color 2 starts: {s:?}");
        assert_eq!(rep.items, 6);
        assert_eq!(rep.busy_total(), 6);
        assert_eq!(rep.per_color_busy, vec![3, 2, 1]);
        assert_eq!(rep.max_color_busy(), 3);
        assert!((rep.critical_share() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rounds_multiply_work_and_empty_buckets_are_skipped() {
        // bucket 1 left empty by a refresh
        let mut sched = ColorSchedule::from_colors(&[0, 1, 2]);
        sched.refresh(&[0, 2, 2]);
        let pool = Arc::new(WorkerPool::new(2));
        let count = AtomicU64::new(0);
        let rep = Executor::new(&pool).run(&sched, 4, |_item, color| {
            assert_ne!(color, 1, "empty bucket must not dispatch");
            count.fetch_add(1, AOrd::Relaxed);
            Cost::new(2)
        });
        assert_eq!(count.load(AOrd::Relaxed), 12);
        assert_eq!(rep.items, 12);
        assert_eq!(rep.rounds, 4);
        assert_eq!(rep.per_color_busy, vec![8, 0, 16]);
        assert_eq!(rep.worker_busy.iter().sum::<u64>(), 24);
    }

    #[test]
    fn shared_buf_roundtrips_and_colored_writes_land() {
        let mut buf = SharedBuf::new(vec![0u64; 4]);
        buf.as_mut_slice()[1] = 7;
        assert_eq!(buf.len(), 4);
        assert!(!buf.is_empty());
        let sched = ColorSchedule::from_colors(&[0, 0, 1, 1]);
        let pool = Arc::new(WorkerPool::new(2));
        Executor::new(&pool).run(&sched, 1, |item, _color| {
            // SAFETY: each item owns exactly its own slot here.
            unsafe { *buf.slot(item) += item as u64 + 1 };
            Cost::new(1)
        });
        assert_eq!(buf.into_vec(), vec![1, 9, 3, 4]);
    }

    #[test]
    fn effective_chunk_spreads_small_frontiers() {
        assert_eq!(effective_chunk(1000, 4, 64), 64);
        assert_eq!(effective_chunk(40, 4, 64), 10);
        assert_eq!(effective_chunk(3, 4, 64), 1);
        assert_eq!(effective_chunk(1000, 4, 0), 0, "static split passes through");
    }

    #[test]
    fn zero_rounds_is_a_no_op() {
        let sched = ColorSchedule::from_colors(&[0, 1]);
        let pool = Arc::new(WorkerPool::new(2));
        let rep = Executor::new(&pool).run(&sched, 0, |_, _| Cost::new(1));
        assert_eq!(rep.items, 0);
        assert_eq!(rep.busy_total(), 0);
        assert_eq!(rep.critical_share(), 0.0);
        assert_eq!(rep.utilization(), 1.0);
    }
}
