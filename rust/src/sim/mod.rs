//! Deterministic multicore simulator.
//!
//! The paper's experiments ran on a dual-socket 30-core Xeon; this
//! testbed has one core, so 16-thread wall-clock cannot be measured
//! directly. Instead the coloring engine runs unmodified on virtual
//! threads driven by a discrete-event loop (DESIGN.md §4):
//!
//! * Every parallel region starts at a barrier; each virtual thread owns
//!   a clock in abstract *work units* (≈ one adjacency entry touched).
//! * The event loop always advances the thread with the smallest clock:
//!   it claims the next dynamic chunk (charged like an atomic RMW) and
//!   executes one item, whose reads observe the [`MvccColors`] store *as
//!   of the item's start time* — writes committed later are invisible,
//!   so the optimistic races the paper's algorithms tolerate manifest
//!   here too, deterministically.
//! * Region wall-clock = (max clock − barrier) scaled by a calibrated
//!   ns/unit and a memory-/coherence-penalty factor `1 + β(t−1)`
//!   (sub-linear scaling — the paper's best algorithm reaches 11.4× on
//!   16 threads, not 16×).
//! * Atomic RMWs (shared-queue pushes, cursor grabs) are charged
//!   `a₀ + a₁(t−1)` units — contention grows with thread count, which is
//!   what separates chunk-1 `V-V` from chunk-64 `V-V-64`.
//!
//! Everything is integer/deterministic: every table in EXPERIMENTS.md
//! regenerates bit-identically from a seed.

pub mod trace;

use std::cell::UnsafeCell;

use crate::par::{
    auto_adapt, auto_effective, auto_seed, AUTO_SITES, Chunk, ColorStore, Cost, Driver, RegionOut,
};

/// Cost-model constants. `ns_per_unit` is calibrated against a real
/// sequential run on the host (see [`CostModel::calibrate`]); everything
/// downstream reports *ratios* (speedups), which are independent of it.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Nanoseconds per work unit (one adjacency entry touched).
    pub ns_per_unit: f64,
    /// Base cost of an atomic RMW, in units.
    pub atomic_base: u64,
    /// Extra units per additional thread for each atomic RMW (coherence
    /// traffic / cache-line ping-pong).
    pub atomic_scale: f64,
    /// Memory-bandwidth / NUMA penalty: per-unit cost multiplier is
    /// `1 + beta * (t - 1)`.
    pub beta: f64,
    /// Fixed per-item overhead in units (loop control, queue read).
    pub item_base: u64,
    /// Thread start stagger per region, in units: thread `i` begins at
    /// `barrier + i * fork_skew`. Models OpenMP fork/wake skew; without
    /// it, small work queues execute in lockstep and the optimistic loop
    /// exhibits pathological repeated races that real hardware never
    /// shows (threads are never perfectly synchronized).
    pub fork_skew: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            ns_per_unit: 2.5,
            // A contended RMW on a dual-socket Xeon costs ~50-450 ns
            // (cache-line ping-pong grows with the number of threads
            // hammering the line) vs ~2.5 ns per streamed edge — hence
            // the large per-thread scale. This is what separates the
            // chunk-1 `V-V` from `V-V-64` (Table III).
            atomic_base: 16,
            atomic_scale: 9.0,
            beta: 0.027,
            item_base: 2,
            fork_skew: 64,
        }
    }
}

impl CostModel {
    /// Cost in units of one atomic RMW at thread count `t`.
    #[inline]
    pub fn atomic_units(&self, t: usize) -> u64 {
        self.atomic_base + (self.atomic_scale * (t.saturating_sub(1)) as f64) as u64
    }

    /// Convert a span of units at thread count `t` into nanoseconds.
    #[inline]
    pub fn units_to_ns(&self, units: u64, t: usize) -> f64 {
        units as f64 * self.ns_per_unit * (1.0 + self.beta * (t.saturating_sub(1)) as f64)
    }

    /// Calibrate `ns_per_unit` from a measured (seconds, units) pair of a
    /// real sequential run.
    pub fn calibrated(mut self, seconds: f64, units: u64) -> CostModel {
        if units > 0 && seconds > 0.0 {
            self.ns_per_unit = seconds * 1e9 / units as f64;
        }
        self
    }
}

/// Commit-time granularity: times are stored right-shifted by this many
/// bits in the packed hot word. 16-unit (~40 ns) blur on race-window
/// edges — far below any item duration — in exchange for a u32 that
/// cannot overflow before ~68G units (~3 minutes of simulated time).
const T_SHIFT: u32 = 4;

/// MVCC color store for the simulator: reads at time `now` see a write
/// only if it committed at or before `now`. Single real thread drives the
/// event loop, so the `UnsafeCell` access is serialized.
///
/// Layout (§Perf, EXPERIMENTS.md): the read path is the engine's hottest
/// gather, so the hot state is one packed 8-byte word per vertex —
/// `[new color: i32 | commit time >> T_SHIFT: u32]` — and the
/// visible-before value lives in a cold side array that is only touched
/// inside an open race window.
pub struct MvccColors {
    hot: Vec<UnsafeCell<u64>>,
    old: Vec<UnsafeCell<i32>>,
}

unsafe impl Sync for MvccColors {}

#[inline(always)]
fn pack(val: i32, t32: u32) -> u64 {
    ((val as u32 as u64) << 32) | t32 as u64
}

impl MvccColors {
    pub fn new(n: usize) -> MvccColors {
        MvccColors {
            hot: (0..n).map(|_| UnsafeCell::new(pack(-1, 0))).collect(),
            old: (0..n).map(|_| UnsafeCell::new(-1)).collect(),
        }
    }
}

impl ColorStore for MvccColors {
    #[inline]
    fn n(&self) -> usize {
        self.hot.len()
    }

    #[inline]
    fn read(&self, u: usize, now: u64) -> i32 {
        let w = unsafe { *self.hot[u].get() };
        if (w as u32) <= (now >> T_SHIFT) as u32 {
            (w >> 32) as i32
        } else {
            unsafe { *self.old[u].get() }
        }
    }

    #[inline]
    fn write(&self, u: usize, val: i32, commit: u64) {
        let t32 = (commit >> T_SHIFT) as u32;
        let w = unsafe { &mut *self.hot[u].get() };
        // The visible-before value for readers that started earlier than
        // this commit: whatever was visible just before `commit`.
        let prev = *w;
        let prev_val = (prev >> 32) as i32;
        if (prev as u32) > t32 {
            // previous write still in flight: its `old` stays visible
        } else {
            unsafe { *self.old[u].get() = prev_val };
        }
        *w = pack(val, t32);
    }

    #[inline]
    fn committed(&self, u: usize) -> i32 {
        (unsafe { *self.hot[u].get() } >> 32) as i32
    }

    fn fill(&self, val: i32) {
        for (h, o) in self.hot.iter().zip(&self.old) {
            unsafe {
                *h.get() = pack(val, 0);
                *o.get() = val;
            }
        }
    }
}

/// Discrete-event virtual-thread driver.
pub struct SimDriver {
    pub t: usize,
    pub model: CostModel,
    /// Global virtual time (monotone across regions — commit times from a
    /// previous region stay visible in the next).
    barrier: u64,
    /// Per-region trace (busy units per thread), kept for diagnostics.
    pub last_busy: Vec<u64>,
    /// Per-site [`Chunk::Auto`] state (0 = unseeded) — the simulated
    /// twin of the pool's tuners, driven by the same pure feedback
    /// functions so simulated runs stay deterministic.
    auto_chunks: [usize; AUTO_SITES],
}

impl SimDriver {
    pub fn new(t: usize, model: CostModel) -> SimDriver {
        assert!(t >= 1);
        SimDriver {
            t,
            model,
            barrier: 1,
            last_busy: Vec::new(),
            auto_chunks: [0; AUTO_SITES],
        }
    }

    /// Current barrier time (units).
    pub fn now(&self) -> u64 {
        self.barrier
    }
}

impl Driver for SimDriver {
    type Colors = MvccColors;

    fn threads(&self) -> usize {
        self.t
    }

    fn now(&self) -> u64 {
        self.barrier
    }

    fn new_colors(&self, n: usize) -> MvccColors {
        MvccColors::new(n)
    }

    fn region<TS, F>(&mut self, states: &mut [TS], n_items: usize, chunk: usize, body: F) -> RegionOut
    where
        TS: Send,
        F: Fn(usize, &mut TS, usize, u64) -> Cost + Sync,
    {
        assert!(states.len() >= self.t);
        // Resolve the chunk before any cursor arithmetic (an Auto
        // sentinel is numerically near usize::MAX).
        let (static_sched, chunk, auto_site) = match Chunk::decode(chunk) {
            Chunk::Static => (true, 1, None),
            Chunk::Fixed(n) => (false, n.max(1), None),
            Chunk::Auto(site) => {
                let site = site % AUTO_SITES;
                let tuned = self.auto_chunks[site];
                let base = if tuned == 0 { auto_seed(n_items, self.t) } else { tuned };
                (false, auto_effective(base, n_items, self.t), Some(site))
            }
        };
        let t = self.t;
        let atomic_units = self.model.atomic_units(t);
        let item_base = self.model.item_base;

        let mut clocks: Vec<u64> = (0..t as u64)
            .map(|i| self.barrier + i * self.model.fork_skew)
            .collect();
        // (next, end): static = the thread's whole contiguous block;
        // dynamic = the current chunk claimed from the shared cursor.
        let mut chunks: Vec<(usize, usize)> = if static_sched {
            (0..t).map(|i| (n_items * i / t, n_items * (i + 1) / t)).collect()
        } else {
            vec![(0, 0); t]
        };
        let mut done = vec![false; t];
        let mut cursor = 0usize;
        let mut n_done = 0usize;

        while n_done < t {
            // pick the live thread with the smallest clock (t is small —
            // linear scan beats a heap here).
            let mut tid = usize::MAX;
            let mut best = u64::MAX;
            for i in 0..t {
                if !done[i] && clocks[i] < best {
                    best = clocks[i];
                    tid = i;
                }
            }
            let (ref mut next, ref mut end) = chunks[tid];
            if next == end {
                if static_sched || cursor >= n_items {
                    done[tid] = true;
                    n_done += 1;
                    continue;
                }
                // grab a new chunk (one atomic RMW on the shared cursor)
                *next = cursor;
                *end = (cursor + chunk).min(n_items);
                cursor = *end;
                clocks[tid] += atomic_units;
                continue;
            }
            let item = *next;
            *next += 1;
            let now = clocks[tid];
            let cost = body(tid, &mut states[tid], item, now);
            clocks[tid] += item_base + cost.units + cost.atomics as u64 * atomic_units;
        }

        let max_clock = clocks.iter().copied().max().unwrap_or(self.barrier);
        let busy: Vec<u64> = clocks.iter().map(|&c| c - self.barrier).collect();
        let span = max_clock - self.barrier;
        if let Some(site) = auto_site {
            self.auto_chunks[site] = auto_adapt(chunk, &busy);
        }
        self.last_busy = busy.clone();
        // next region starts strictly after everything committed here
        self.barrier = max_clock + 1;
        RegionOut {
            real_secs: 0.0,
            sim_ns: Some(self.model.units_to_ns(span, t)),
            busy_units: busy,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_visits_every_item_once_deterministically() {
        let mut d = SimDriver::new(4, CostModel::default());
        let mut states: Vec<Vec<usize>> = vec![Vec::new(); 4];
        d.region(&mut states, 1000, 16, |_tid, ts, item, _now| {
            ts.push(item);
            Cost::new(3)
        });
        let mut all: Vec<usize> = states.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());

        // re-run: identical assignment (determinism)
        let mut d2 = SimDriver::new(4, CostModel::default());
        let mut states2: Vec<Vec<usize>> = vec![Vec::new(); 4];
        d2.region(&mut states2, 1000, 16, |_tid, ts, item, _now| {
            ts.push(item);
            Cost::new(3)
        });
        assert_eq!(states, states2);
    }

    #[test]
    fn balanced_work_scales_nearly_linearly() {
        let model = CostModel { beta: 0.0, ..CostModel::default() };
        let time = |t: usize| {
            let mut d = SimDriver::new(t, model);
            let mut states = vec![(); t];
            d.region(&mut states, 16_000, 64, |_, _, _, _| Cost::new(100))
                .sim_ns
                .unwrap()
        };
        let t1 = time(1);
        let t16 = time(16);
        let speedup = t1 / t16;
        assert!(speedup > 14.0 && speedup <= 16.5, "speedup {speedup}");
    }

    #[test]
    fn imbalance_caps_speedup_at_max_clock() {
        // one huge item: speedup limited by its cost
        let model = CostModel { beta: 0.0, ..CostModel::default() };
        let mut d1 = SimDriver::new(1, model);
        let mut d8 = SimDriver::new(8, model);
        let cost = |item: usize| if item == 0 { 100_000 } else { 10 };
        let mut s1 = vec![(); 1];
        let mut s8 = vec![(); 8];
        let t1 = d1
            .region(&mut s1, 1000, 1, |_, _, i, _| Cost::new(cost(i)))
            .sim_ns
            .unwrap();
        let t8 = d8
            .region(&mut s8, 1000, 1, |_, _, i, _| Cost::new(cost(i)))
            .sim_ns
            .unwrap();
        let speedup = t1 / t8;
        assert!(speedup < 1.4, "imbalance should kill speedup, got {speedup}");
    }

    #[test]
    fn chunk1_pays_more_cursor_contention_than_chunk64() {
        let model = CostModel::default();
        let run = |chunk: usize| {
            let mut d = SimDriver::new(8, model);
            let mut s = vec![(); 8];
            d.region(&mut s, 50_000, chunk, |_, _, _, _| Cost::new(5)).sim_ns.unwrap()
        };
        assert!(run(1) > run(64) * 1.3, "chunk-1 should be clearly slower");
    }

    #[test]
    fn auto_chunk_is_deterministic_and_adapts_across_regions() {
        let run = || {
            let mut d = SimDriver::new(4, CostModel::default());
            let raw = Chunk::Auto(crate::par::autosite::GENERIC).encode();
            let mut states: Vec<Vec<usize>> = vec![Vec::new(); 4];
            for _ in 0..4 {
                for s in &mut states {
                    s.clear();
                }
                d.region(&mut states, 1000, raw, |_tid, ts, item, _now| {
                    ts.push(item);
                    Cost::new(3)
                });
                let mut all: Vec<usize> = states.iter().flatten().copied().collect();
                all.sort_unstable();
                assert_eq!(all, (0..1000).collect::<Vec<_>>(), "every item exactly once");
            }
            (states, d.auto_chunks)
        };
        let (a, chunks_a) = run();
        let (b, chunks_b) = run();
        assert_eq!(a, b, "virtual scheduling must not depend on host state");
        assert_eq!(chunks_a, chunks_b);
        assert!(chunks_a[crate::par::autosite::GENERIC] >= 1, "tuner seeded by the feedback loop");
    }

    #[test]
    fn mvcc_reads_respect_commit_times() {
        // times in whole T_SHIFT granules: visibility is exact there
        let g = 1u64 << T_SHIFT;
        let c = MvccColors::new(2);
        c.write(0, 5, 100 * g);
        assert_eq!(c.read(0, 99 * g), -1, "write not yet visible");
        assert_eq!(c.read(0, 100 * g), 5, "visible at commit time");
        assert_eq!(c.committed(0), 5);
        // overwrite: old becomes the previously visible value
        c.write(0, 9, 200 * g);
        assert_eq!(c.read(0, 150 * g), 5);
        assert_eq!(c.read(0, 250 * g), 9);
    }

    #[test]
    fn races_manifest_between_overlapping_items() {
        // Two vthreads each color one vertex "greedily" (pick the other's
        // color +1 if visible, else 0). With overlapping execution they
        // must both pick 0 — the optimistic conflict.
        let model = CostModel { atomic_base: 0, atomic_scale: 0.0, item_base: 0, ..CostModel::default() };
        let mut d = SimDriver::new(2, model);
        let colors = MvccColors::new(2);
        let mut states = vec![(); 2];
        d.region(&mut states, 2, 1, |_tid, _ts, item, now| {
            let other = 1 - item;
            let seen = colors.read(other, now);
            let mine = if seen == -1 { 0 } else { seen + 1 };
            // long item: commits well after both started
            colors.write(item, mine, now + 1000);
            Cost::new(1000)
        });
        assert_eq!(colors.committed(0), 0);
        assert_eq!(colors.committed(1), 0, "both picked 0: race reproduced");
    }

    #[test]
    fn barrier_monotone_across_regions() {
        let mut d = SimDriver::new(2, CostModel::default());
        let colors = MvccColors::new(1);
        let mut s = vec![(); 2];
        d.region(&mut s, 1, 1, |_, _, _, now| {
            colors.write(0, 42, now + 10);
            Cost::new(10)
        });
        // next region: the write is committed before the barrier
        d.region(&mut s, 1, 1, |_, _, _, now| {
            assert_eq!(colors.read(0, now), 42);
            Cost::new(1)
        });
    }
}
