//! Per-iteration phase traces — the raw material for Figure 1 and the
//! "78% of runtime in the first iteration" analysis in §III.

/// One engine iteration: coloring phase + conflict-removal phase.
#[derive(Clone, Debug, Default)]
pub struct IterTrace {
    /// Work-queue size entering the iteration.
    pub queue_len: usize,
    /// Coloring-phase time (seconds, simulated or real).
    pub color_secs: f64,
    /// Conflict-removal-phase time (seconds).
    pub conflict_secs: f64,
    /// Which phase implementations ran ("V"/"N" per the paper's naming).
    pub color_kind: char,
    pub conflict_kind: char,
    /// Per-thread busy units in the coloring phase (simulator only).
    pub color_busy: Vec<u64>,
}

impl IterTrace {
    pub fn total_secs(&self) -> f64 {
        self.color_secs + self.conflict_secs
    }
}

/// Full run trace.
#[derive(Clone, Debug, Default)]
pub struct RunTrace {
    pub iters: Vec<IterTrace>,
}

impl RunTrace {
    pub fn total_secs(&self) -> f64 {
        self.iters.iter().map(|i| i.total_secs()).sum()
    }

    /// Fraction of total time spent in the first `k` iterations
    /// (the paper reports 78% for k=1, 89% for k=2).
    pub fn first_k_fraction(&self, k: usize) -> f64 {
        let total = self.total_secs();
        if total == 0.0 {
            return 0.0;
        }
        self.iters.iter().take(k).map(|i| i.total_secs()).sum::<f64>() / total
    }

    /// Load imbalance of the first coloring phase: max/mean busy units.
    pub fn first_color_imbalance(&self) -> f64 {
        let Some(it) = self.iters.first() else { return 1.0 };
        if it.color_busy.is_empty() {
            return 1.0;
        }
        let max = *it.color_busy.iter().max().unwrap() as f64;
        let mean =
            it.color_busy.iter().sum::<u64>() as f64 / it.color_busy.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(c: f64, r: f64) -> IterTrace {
        IterTrace { color_secs: c, conflict_secs: r, ..Default::default() }
    }

    #[test]
    fn fractions() {
        let t = RunTrace { iters: vec![tr(7.0, 1.0), tr(1.0, 0.5), tr(0.4, 0.1)] };
        assert!((t.total_secs() - 10.0).abs() < 1e-12);
        assert!((t.first_k_fraction(1) - 0.8).abs() < 1e-12);
        assert!((t.first_k_fraction(2) - 0.95).abs() < 1e-12);
        assert_eq!(t.first_k_fraction(99), 1.0);
    }

    #[test]
    fn imbalance() {
        let mut it = tr(1.0, 0.0);
        it.color_busy = vec![100, 100, 100, 500];
        let t = RunTrace { iters: vec![it] };
        assert!((t.first_color_imbalance() - 2.5).abs() < 1e-12);
    }
}
