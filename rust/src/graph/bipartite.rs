//! Bipartite graph `G = (V_A ∪ V_B, E)` in the paper's vertex/net view.
//!
//! Following the paper's hypergraph analogy (§II): the `V_A` side holds
//! the *vertices* to be colored, the `V_B` side holds the *nets* that
//! define the neighborhood. For a sparse matrix whose **columns** are
//! colored (the paper's BGPC setup), vertices = columns, nets = rows.

use super::csr::Csr;

/// Bipartite graph stored as both directions of the incidence.
#[derive(Clone, Debug)]
pub struct Bipartite {
    /// `nets(u)` for each vertex `u ∈ V_A` (vertex → incident nets).
    pub vtx_nets: Csr,
    /// `vtxs(v)` for each net `v ∈ V_B` (net → incident vertices).
    pub net_vtxs: Csr,
}

impl Bipartite {
    /// Build from the net-side incidence (rows = nets, cols = vertices),
    /// i.e. directly from a sparse matrix when coloring its columns.
    pub fn from_net_incidence(net_vtxs: Csr) -> Bipartite {
        let vtx_nets = net_vtxs.transpose();
        Bipartite { vtx_nets, net_vtxs }
    }

    /// Number of vertices to color (`|V_A|`).
    #[inline]
    pub fn n_vertices(&self) -> usize {
        self.vtx_nets.n_rows
    }

    /// Number of nets (`|V_B|`).
    #[inline]
    pub fn n_nets(&self) -> usize {
        self.net_vtxs.n_rows
    }

    /// Number of incidences (`|E|`).
    #[inline]
    pub fn nnz(&self) -> usize {
        self.net_vtxs.nnz()
    }

    /// `nets(u)`.
    #[inline]
    pub fn nets(&self, u: usize) -> &[u32] {
        self.vtx_nets.row(u)
    }

    /// `vtxs(v)`.
    #[inline]
    pub fn vtxs(&self, v: usize) -> &[u32] {
        self.net_vtxs.row(v)
    }

    /// Best-effort prefetch of the head of `vtxs(v)` (see
    /// [`Csr::prefetch_row`]).
    #[inline(always)]
    pub fn prefetch_vtxs(&self, v: usize) {
        self.net_vtxs.prefetch_row(v);
    }

    /// Upper bound on the distance-2 degree of vertex `u`:
    /// `Σ_{v ∈ nets(u)} (|vtxs(v)| − 1)`. Also the paper's lower-bound
    /// argument for reverse first-fit never running negative.
    pub fn two_hop_bound(&self, u: usize) -> usize {
        self.nets(u)
            .iter()
            .map(|&v| self.net_vtxs.deg(v as usize).saturating_sub(1))
            .sum()
    }

    /// The cost the paper analyses for vertex-based coloring's first
    /// iteration: `Σ_{v ∈ V_B} |vtxs(v)|²`.
    pub fn net_sq_sum(&self) -> u64 {
        (0..self.n_nets())
            .map(|v| {
                let d = self.net_vtxs.deg(v) as u64;
                d * d
            })
            .sum()
    }

    /// Renumber the vertex side: new id of old vertex `u` is `perm[u]`.
    /// Both incidence directions stay consistent.
    pub fn relabel_vertices(&self, perm: &[u32]) -> Bipartite {
        let mut net_vtxs = self.net_vtxs.clone();
        net_vtxs.relabel_cols(perm);
        Bipartite::from_net_incidence(net_vtxs)
    }

    /// Cross-direction consistency check (property tests).
    pub fn validate(&self) -> Result<(), String> {
        self.vtx_nets.validate()?;
        self.net_vtxs.validate()?;
        if self.vtx_nets.n_rows != self.net_vtxs.n_cols
            || self.vtx_nets.n_cols != self.net_vtxs.n_rows
        {
            return Err("incidence shapes inconsistent".into());
        }
        if self.vtx_nets.nnz() != self.net_vtxs.nnz() {
            return Err("incidence nnz mismatch".into());
        }
        // spot-check round trip on a few rows
        let t = self.net_vtxs.transpose();
        if t.ptr != self.vtx_nets.ptr || t.adj != self.vtx_nets.adj {
            return Err("vtx_nets is not transpose of net_vtxs".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// nets: n0 -> {v0, v1}, n1 -> {v1, v2}, n2 -> {v0, v2, v3}
    pub fn tiny() -> Bipartite {
        let m = Csr::from_edges(3, 4, &[(0, 0), (0, 1), (1, 1), (1, 2), (2, 0), (2, 2), (2, 3)]);
        Bipartite::from_net_incidence(m)
    }

    #[test]
    fn directions_consistent() {
        let g = tiny();
        g.validate().unwrap();
        assert_eq!(g.n_vertices(), 4);
        assert_eq!(g.n_nets(), 3);
        assert_eq!(g.nets(1), &[0, 1]);
        assert_eq!(g.vtxs(2), &[0, 2, 3]);
    }

    #[test]
    fn two_hop_bound_matches_hand_count() {
        let g = tiny();
        // v0 ∈ nets {n0, n2}: (2-1) + (3-1) = 3
        assert_eq!(g.two_hop_bound(0), 3);
        // v3 ∈ {n2}: 2
        assert_eq!(g.two_hop_bound(3), 2);
    }

    #[test]
    fn net_sq_sum_matches() {
        let g = tiny();
        assert_eq!(g.net_sq_sum(), 4 + 4 + 9);
    }

    #[test]
    fn relabel_roundtrip() {
        let g = tiny();
        // reverse ids
        let perm: Vec<u32> = (0..4u32).rev().collect();
        let r = g.relabel_vertices(&perm);
        r.validate().unwrap();
        // old v0 (now 3) was in nets n0 and n2
        assert_eq!(r.nets(3), g.nets(0));
    }
}
