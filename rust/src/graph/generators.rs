//! Synthetic matrix generators calibrated to the paper's test-bed.
//!
//! The paper evaluates on eight UFL/SuiteSparse matrices plus
//! MovieLens-20M (Table II). Those exact matrices are hundreds of MB and
//! unavailable offline, so each gets a *calibrated synthetic preset*
//! matching the shape statistics that drive the algorithms' behaviour:
//! rows/cols ratio, average degree, maximum column degree, and degree
//! skew (DESIGN.md §4). A real Matrix-Market reader ([`super::mtx`])
//! lets the genuine matrices drop in unchanged.
//!
//! Four pattern families cover the test-bed:
//! * [`fem_elements`] — element-clique FE matrices (`af_shell`,
//!   `bone010`, `channel`, `nlpkkt120`): near-constant degree,
//!   structurally symmetric, strongly overlapping nets.
//! * [`banded`] — plain stencil bands (kept for tests/examples).
//! * [`chung_lu_symmetric`] — power-law graphs (`coPapersDBLP`): heavy
//!   degree skew, hub-clustered natural order, symmetric.
//! * [`chung_lu_bipartite`] / [`regularish`] — rectangular / directed
//!   skewed patterns (`20M_movielens`, `uk-2002`, CFD `HV15R`).

use super::bipartite::Bipartite;
use super::csr::Csr;
use crate::util::prng::Rng;

/// Mix two ids and a seed into a decision hash (symmetric edge jitter).
#[inline]
fn pair_hash(a: u32, b: u32, seed: u64) -> u64 {
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    let mut x = ((hi as u64) << 32 | lo as u64) ^ seed.wrapping_mul(0x9E3779B97F4A7C15);
    x ^= x >> 33;
    x = x.wrapping_mul(0xFF51AFD7ED558CCD);
    x ^= x >> 33;
    x = x.wrapping_mul(0xC4CEB9FE1A85EC53);
    x ^ (x >> 33)
}

/// Structurally symmetric banded pattern: row `i` is connected to the
/// window `i ± h` with per-pair keep probability `fill`, plus `extra`
/// random long-range symmetric links per row (lifts max degree / stddev,
/// as in `bone010`). Diagonal always present.
pub fn banded(n: usize, half_band: usize, fill: f64, extra: f64, seed: u64) -> Csr {
    let mut rng = Rng::new(seed ^ 0xB4DED);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n * (half_band + 1) * 2);
    let thresh = (fill * u64::MAX as f64) as u64;
    for i in 0..n {
        edges.push((i as u32, i as u32));
        let hi = (i + half_band).min(n - 1);
        for j in (i + 1)..=hi {
            if pair_hash(i as u32, j as u32, seed) <= thresh {
                edges.push((i as u32, j as u32));
                edges.push((j as u32, i as u32));
            }
        }
        // long-range symmetric extras
        let n_extra = (extra + rng.f64()) as usize;
        for _ in 0..n_extra {
            let j = rng.range(0, n);
            if j != i {
                edges.push((i as u32, j as u32));
                edges.push((j as u32, i as u32));
            }
        }
    }
    Csr::from_edges(n, n, &edges)
}

/// Element-based FEM pattern (`bone010`): nodes belong to ~`epn`
/// elements of `npe` nodes drawn from a locality window; the matrix is
/// the element-connectivity closure (nodes sharing an element are
/// adjacent — every element is a clique). This reproduces the *overlap
/// structure* of real FE matrices: the nets of nearby nodes share whole
/// element cliques, so their forbidden sets largely agree and coherent
/// optimistic colorings survive — the property behind bone010's Table I
/// separation (random-pair local graphs lose nearly every speculative
/// color instead).
pub fn fem_elements(n: usize, npe: usize, epn: usize, window: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed ^ 0xFE31);
    let n_elems = (n * epn / npe).max(1);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n * npe * epn);
    for i in 0..n {
        edges.push((i as u32, i as u32));
    }
    let mut members = Vec::with_capacity(npe);
    for e in 0..n_elems {
        // element centers sweep the id space (mesh locality)
        let center = (e * n) / n_elems;
        let lo = center.saturating_sub(window);
        let hi = (center + window).min(n - 1);
        members.clear();
        for _ in 0..npe {
            members.push(rng.range(lo, hi + 1) as u32);
        }
        for (ai, &a) in members.iter().enumerate() {
            for &b in members.iter().skip(ai + 1) {
                if a != b {
                    edges.push((a, b));
                    edges.push((b, a));
                }
            }
        }
    }
    Csr::from_edges(n, n, &edges)
}

/// Cumulative-weight sampler (binary search over prefix sums).
struct WeightedSampler {
    cum: Vec<f64>,
}

impl WeightedSampler {
    fn new(weights: &[f64]) -> WeightedSampler {
        let mut cum = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            acc += w;
            cum.push(acc);
        }
        WeightedSampler { cum }
    }

    #[inline]
    fn sample(&self, rng: &mut Rng) -> usize {
        let total = *self.cum.last().unwrap();
        let x = rng.f64() * total;
        match self.cum.binary_search_by(|c| c.partial_cmp(&x).unwrap()) {
            Ok(i) | Err(i) => i.min(self.cum.len() - 1),
        }
    }
}

/// Power-law weights `w_i ∝ rank^(−1/(alpha−1))` clamped to `max_w`,
/// laid out in *shuffled blocks*: heavy ids cluster in a few contiguous
/// id ranges, the way real matrices cluster hubs (citation communities,
/// web hosts). This is what makes the natural order imbalanced under
/// static scheduling — the effect behind the paper's `V-V` vs `V-V-64`
/// gap (Table III).
fn powerlaw_weights(n: usize, alpha: f64, max_w: f64, rng: &mut Rng) -> Vec<f64> {
    let exp = 1.0 / (alpha - 1.0);
    let sorted: Vec<f64> = (0..n)
        .map(|i| ((n as f64 / (i + 1) as f64).powf(exp)).min(max_w))
        .collect();
    let n_blocks = 64.min(n.max(1));
    let mut order: Vec<usize> = (0..n_blocks).collect();
    rng.shuffle(&mut order);
    let mut w = Vec::with_capacity(n);
    for &b in &order {
        let lo = n * b / n_blocks;
        let hi = n * (b + 1) / n_blocks;
        w.extend_from_slice(&sorted[lo..hi]);
    }
    w
}

/// Symmetric Chung–Lu power-law graph: `m` undirected edges sampled with
/// endpoint probability ∝ power-law weights; pattern symmetrized.
pub fn chung_lu_symmetric(n: usize, m: usize, alpha: f64, max_deg: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed ^ 0xC1);
    let w = powerlaw_weights(n, alpha, max_deg as f64, &mut rng);
    let sampler = WeightedSampler::new(&w);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(2 * m + n);
    for i in 0..n {
        edges.push((i as u32, i as u32)); // keep every vertex present
    }
    for _ in 0..m {
        let a = sampler.sample(&mut rng) as u32;
        let b = sampler.sample(&mut rng) as u32;
        if a != b {
            edges.push((a, b));
            edges.push((b, a));
        }
    }
    Csr::from_edges(n, n, &edges)
}

/// Bipartite Chung–Lu: `nnz` incidences; net (row) side weighted by
/// `row_alpha` power law (1.0 ⇒ uniform), vertex (column) side by
/// `col_alpha` with max weight `max_col_deg`.
pub fn chung_lu_bipartite(
    n_nets: usize,
    n_vtxs: usize,
    nnz: usize,
    row_alpha: f64,
    col_alpha: f64,
    max_col_deg: usize,
    max_row_deg: usize,
    seed: u64,
) -> Csr {
    let mut rng = Rng::new(seed ^ 0xB1);
    let row_w = if row_alpha <= 1.0 {
        vec![1.0; n_nets]
    } else {
        powerlaw_weights(n_nets, row_alpha, max_row_deg as f64, &mut rng)
    };
    let col_w = powerlaw_weights(n_vtxs, col_alpha, max_col_deg as f64, &mut rng);
    let rows = WeightedSampler::new(&row_w);
    let cols = WeightedSampler::new(&col_w);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        let r = rows.sample(&mut rng) as u32;
        let c = cols.sample(&mut rng) as u32;
        edges.push((r, c));
    }
    Csr::from_edges(n_nets, n_vtxs, &edges)
}

/// Near-constant row degree with random fill and mild locality — the CFD
/// profile (`HV15R`): deg ~ N(avg, sd) clipped to `[1, max]`, neighbors
/// drawn half from a local band, half uniformly.
pub fn regularish(n: usize, avg_deg: f64, sd: f64, max_deg: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed ^ 0x4EAE);
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let band = (avg_deg as usize).max(8) * 4;
    for i in 0..n {
        // Box–Muller
        let (u1, u2) = (rng.f64().max(1e-12), rng.f64());
        let z = (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
        let d = ((avg_deg + sd * z).round() as isize).clamp(1, max_deg as isize) as usize;
        edges.push((i as u32, i as u32));
        for k in 0..d {
            let j = if k % 2 == 0 {
                let lo = i.saturating_sub(band / 2);
                let hi = (i + band / 2).min(n - 1);
                rng.range(lo, hi + 1)
            } else {
                rng.range(0, n)
            };
            edges.push((i as u32, j as u32));
        }
    }
    Csr::from_edges(n, n, &edges)
}

/// One of the paper's eight test matrices, as a calibrated preset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Banded { half_band: usize, fill_pct: u8, extra_x100: u16 },
    FemElems { npe: usize, epn: usize, window: usize },
    ChungLuSym { avg_deg: usize, alpha_x10: u8, max_deg: usize },
    ChungLuBip { n_vtxs_per_mille: u32, avg_col_deg: usize, row_alpha_x10: u8, col_alpha_x10: u8, max_col_deg: usize, max_row_deg_per_mille: u16 },
    Regularish { avg_deg: usize, sd: usize, max_deg: usize },
}

/// A named preset mirroring one row of the paper's Table II.
#[derive(Clone, Copy, Debug)]
pub struct Preset {
    pub name: &'static str,
    /// Base number of nets (rows) at `scale = 1.0` (already ~1/10–1/80 of
    /// the original matrix; see DESIGN.md §4).
    pub base_nets: usize,
    pub family: Family,
    /// Structurally symmetric (⇒ eligible for the D2GC experiments,
    /// mirroring Table II's last column).
    pub symmetric: bool,
}

/// The paper's eight matrices (Table II), calibrated and scaled.
pub const PRESETS: [Preset; 8] = [
    // MovieLens-20M: nets = movies (heavy hubs — a popular movie is rated
    // by ~half the users), vertices = users. The paper's Table II lists a
    // max "column" degree of 67,310 ≈ 49% of one side — preserved here as
    // a net-degree hub ratio.
    Preset {
        name: "20M_movielens",
        base_nets: 2_674,
        family: Family::ChungLuBip {
            n_vtxs_per_mille: 5_179, // 13.8k users per 2.7k movies
            avg_col_deg: 14,
            row_alpha_x10: 16, // heavy movie-popularity skew
            col_alpha_x10: 25, // mild user-activity skew
            max_col_deg: 400,
            max_row_deg_per_mille: 485, // hit movie ≈ half the users (Table II)
        },
        symmetric: false,
    },
    Preset {
        name: "af_shell",
        base_nets: 75_000,
        family: Family::FemElems { npe: 10, epn: 2, window: 150 },
        symmetric: true,
    },
    Preset {
        name: "bone010",
        base_nets: 49_000,
        family: Family::FemElems { npe: 14, epn: 3, window: 260 },
        symmetric: true,
    },
    Preset {
        name: "channel",
        base_nets: 120_000,
        family: Family::FemElems { npe: 6, epn: 2, window: 200 },
        symmetric: true,
    },
    Preset {
        name: "coPapersDBLP",
        base_nets: 54_000,
        // max degree scales with n to preserve the paper's relative hub
        // size (3,299 / 540,486 ≈ 0.6% → 330 at 54k).
        family: Family::ChungLuSym { avg_deg: 28, alpha_x10: 26, max_deg: 330 },
        symmetric: true,
    },
    Preset {
        name: "HV15R",
        base_nets: 25_000,
        family: Family::Regularish { avg_deg: 140, sd: 54, max_deg: 484 },
        symmetric: false,
    },
    Preset {
        name: "nlpkkt120",
        base_nets: 88_000,
        family: Family::FemElems { npe: 8, epn: 2, window: 300 },
        symmetric: true,
    },
    Preset {
        name: "uk-2002",
        base_nets: 230_000,
        family: Family::ChungLuBip {
            n_vtxs_per_mille: 1_000, // square
            avg_col_deg: 16,
            row_alpha_x10: 21,
            col_alpha_x10: 21,
            // 2,450 / 18.5M is a *small* relative hub; preserved ratio
            // would be ~31 at this scale — keep a little extra tail.
            max_col_deg: 64,
            max_row_deg_per_mille: 2, // nets stay small relative to |V_A|
        },
        symmetric: false,
    },
];

impl Preset {
    /// Look up a preset by (case-insensitive) name.
    pub fn by_name(name: &str) -> Option<&'static Preset> {
        PRESETS.iter().find(|p| p.name.eq_ignore_ascii_case(name))
    }

    /// Instantiate the net-side incidence matrix at a given scale.
    pub fn net_incidence(&self, scale: f64, seed: u64) -> Csr {
        let n = ((self.base_nets as f64 * scale) as usize).max(64);
        match self.family {
            Family::Banded { half_band, fill_pct, extra_x100 } => banded(
                n,
                half_band,
                fill_pct as f64 / 100.0,
                extra_x100 as f64 / 100.0,
                seed,
            ),
            Family::FemElems { npe, epn, window } => fem_elements(n, npe, epn, window, seed),
            Family::ChungLuSym { avg_deg, alpha_x10, max_deg } => chung_lu_symmetric(
                n,
                n * avg_deg / 2,
                alpha_x10 as f64 / 10.0,
                max_deg,
                seed,
            ),
            Family::ChungLuBip {
                n_vtxs_per_mille,
                avg_col_deg,
                row_alpha_x10,
                col_alpha_x10,
                max_col_deg,
                max_row_deg_per_mille,
            } => {
                let n_vtxs = ((n as u64 * n_vtxs_per_mille as u64 / 1000) as usize).max(64);
                let max_row =
                    ((n_vtxs as u64 * max_row_deg_per_mille as u64 / 1000) as usize).max(16);
                chung_lu_bipartite(
                    n,
                    n_vtxs,
                    n_vtxs * avg_col_deg,
                    row_alpha_x10 as f64 / 10.0,
                    col_alpha_x10 as f64 / 10.0,
                    max_col_deg,
                    max_row,
                    seed,
                )
            }
            Family::Regularish { avg_deg, sd, max_deg } => {
                regularish(n, avg_deg as f64, sd as f64, max_deg, seed)
            }
        }
    }

    /// Instantiate as a bipartite BGPC instance (columns are colored).
    pub fn bipartite(&self, scale: f64, seed: u64) -> Bipartite {
        Bipartite::from_net_incidence(self.net_incidence(scale, seed))
    }
}

/// Small uniform random bipartite instance (tests / property tests).
pub fn random_bipartite(n_nets: usize, n_vtxs: usize, nnz: usize, seed: u64) -> Bipartite {
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        edges.push((rng.range(0, n_nets) as u32, rng.range(0, n_vtxs) as u32));
    }
    Bipartite::from_net_incidence(Csr::from_edges(n_nets, n_vtxs, &edges))
}

/// Small random symmetric square graph (tests).
pub fn random_symmetric(n: usize, m: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(2 * m + n);
    for i in 0..n {
        edges.push((i as u32, i as u32));
    }
    for _ in 0..m {
        let a = rng.range(0, n) as u32;
        let b = rng.range(0, n) as u32;
        edges.push((a, b));
        edges.push((b, a));
    }
    Csr::from_edges(n, n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banded_is_symmetric_and_near_constant_degree() {
        let g = banded(500, 9, 0.85, 0.0, 1);
        g.validate().unwrap();
        assert!(g.is_structurally_symmetric());
        let avg = g.nnz() as f64 / 500.0;
        assert!(avg > 10.0 && avg < 20.0, "avg {avg}");
        assert!(g.max_deg() <= 19);
    }

    #[test]
    fn chung_lu_sym_is_symmetric_and_skewed() {
        let g = chung_lu_symmetric(2000, 2000 * 14, 2.0, 400, 2);
        g.validate().unwrap();
        assert!(g.is_structurally_symmetric());
        let max = g.max_deg();
        let avg = g.nnz() as f64 / 2000.0;
        assert!(max as f64 > 5.0 * avg, "max {max} avg {avg}: no skew");
    }

    #[test]
    fn bipartite_generator_hits_target_sizes() {
        let m = chung_lu_bipartite(1000, 5000, 40_000, 1.0, 1.8, 500, 400, 3);
        m.validate().unwrap();
        assert_eq!(m.n_rows, 1000);
        assert_eq!(m.n_cols, 5000);
        // dedup loses some, but the bulk should remain
        assert!(m.nnz() > 30_000, "nnz {}", m.nnz());
        let t = m.transpose();
        assert!(t.max_deg() <= 5000);
    }

    #[test]
    fn regularish_degrees_clipped() {
        let g = regularish(1000, 40.0, 15.0, 80, 4);
        g.validate().unwrap();
        assert!(g.max_deg() <= 81); // +1 for the diagonal
        let avg = g.nnz() as f64 / 1000.0;
        assert!(avg > 25.0 && avg < 55.0, "avg {avg}");
    }

    #[test]
    fn presets_instantiate_small() {
        for p in PRESETS.iter() {
            let g = p.bipartite(0.01, 7);
            g.validate().unwrap();
            assert!(g.n_vertices() >= 64, "{}", p.name);
            assert!(g.nnz() > 0, "{}", p.name);
            if p.symmetric {
                assert!(
                    p.net_incidence(0.01, 7).is_structurally_symmetric(),
                    "{} should be symmetric",
                    p.name
                );
            }
        }
    }

    #[test]
    fn preset_lookup() {
        assert!(Preset::by_name("bone010").is_some());
        assert!(Preset::by_name("BONE010").is_some());
        assert!(Preset::by_name("nope").is_none());
    }

    #[test]
    fn generators_are_deterministic() {
        let a = chung_lu_symmetric(500, 4000, 2.0, 100, 42);
        let b = chung_lu_symmetric(500, 4000, 2.0, 100, 42);
        assert_eq!(a, b);
        let c = chung_lu_symmetric(500, 4000, 2.0, 100, 43);
        assert_ne!(a, c);
    }
}
