//! Vertex orderings for the coloring loop.
//!
//! The paper evaluates the *natural* column order (Table III) and
//! ColPack's *smallest-last* order (Table IV). We add *random* and
//! *largest-first* for completeness. An ordering here is a visit
//! sequence `order[i] = vertex visited i-th`; the engines consume it as
//! the initial work-queue order.
//!
//! Smallest-last for BGPC/D2GC operates on the distance-2 structure: we
//! maintain the dynamic two-hop degree bound `Σ_{v∈nets(u)}
//! (|vtxs_remaining(v)|−1)` in a bucket queue — initializing or
//! maintaining the *exact* two-hop degree costs `Θ(Σ|vtxs|²)` which is
//! precisely the blow-up the paper's §III analyses, hence the bound
//! (DESIGN.md §7).

use super::bipartite::Bipartite;
use crate::util::prng::Rng;

/// Supported orderings.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ordering {
    /// The matrix's own column order.
    Natural,
    /// Uniform random permutation (seeded).
    Random(u64),
    /// Decreasing two-hop degree bound (Welsh–Powell flavoured).
    LargestFirst,
    /// ColPack's smallest-last, on the two-hop degree bound.
    SmallestLast,
}

impl Ordering {
    /// Parse from CLI text.
    pub fn parse(s: &str) -> Option<Ordering> {
        match s.to_ascii_lowercase().as_str() {
            "natural" => Some(Ordering::Natural),
            "random" => Some(Ordering::Random(0x5EED)),
            "largest-first" | "lf" => Some(Ordering::LargestFirst),
            "smallest-last" | "sl" => Some(Ordering::SmallestLast),
            _ => None,
        }
    }

    /// Compute the visit order for the vertices of `g`.
    pub fn compute(&self, g: &Bipartite) -> Vec<u32> {
        match *self {
            Ordering::Natural => (0..g.n_vertices() as u32).collect(),
            Ordering::Random(seed) => {
                let mut order: Vec<u32> = (0..g.n_vertices() as u32).collect();
                Rng::new(seed).shuffle(&mut order);
                order
            }
            Ordering::LargestFirst => {
                let mut order: Vec<u32> = (0..g.n_vertices() as u32).collect();
                let key: Vec<usize> =
                    (0..g.n_vertices()).map(|u| g.two_hop_bound(u)).collect();
                order.sort_by(|&a, &b| key[b as usize].cmp(&key[a as usize]).then(a.cmp(&b)));
                order
            }
            Ordering::SmallestLast => smallest_last(g),
        }
    }
}

/// Bucket-queue smallest-last on the dynamic two-hop degree bound.
///
/// Repeatedly removes the minimum-degree vertex and prepends it to the
/// order; on removal every distance-2 neighbor (via still-live nets)
/// loses one from its bound. Total cost `O(Σ_v |vtxs(v)|²)` — the same
/// order as sequential vertex-based coloring, matching the paper's
/// observation that ordering is slower than natural (Table II).
pub fn smallest_last(g: &Bipartite) -> Vec<u32> {
    let n = g.n_vertices();
    let mut deg: Vec<usize> = (0..n).map(|u| g.two_hop_bound(u)).collect();
    // live vertex count per net; a net with <= 1 live vertex no longer
    // contributes to anyone's bound.
    let mut net_live: Vec<usize> = (0..g.n_nets()).map(|v| g.vtxs(v).len()).collect();
    let max_deg = deg.iter().copied().max().unwrap_or(0);

    // bucket queue with lazy deletion
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); max_deg + 1];
    for u in 0..n {
        buckets[deg[u]].push(u as u32);
    }
    let mut removed = vec![false; n];
    let mut order_rev: Vec<u32> = Vec::with_capacity(n);
    let mut cur = 0usize;

    for _ in 0..n {
        // find the non-stale minimum
        let u = loop {
            while cur < buckets.len() && buckets[cur].is_empty() {
                cur += 1;
            }
            debug_assert!(cur < buckets.len(), "bucket queue exhausted early");
            let cand = buckets[cur].pop().unwrap();
            let cu = cand as usize;
            if !removed[cu] && deg[cu] == cur {
                break cu;
            }
            // stale entry: either already removed or degree changed
        };
        removed[u] = true;
        order_rev.push(u as u32);

        for &v in g.nets(u) {
            let v = v as usize;
            net_live[v] -= 1;
            if net_live[v] >= 1 {
                for &w in g.vtxs(v) {
                    let w = w as usize;
                    if !removed[w] && deg[w] > 0 {
                        deg[w] -= 1;
                        buckets[deg[w]].push(w as u32);
                        if deg[w] < cur {
                            cur = deg[w];
                        }
                    }
                }
            }
        }
    }
    order_rev.reverse();
    order_rev
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::graph::generators::random_bipartite;

    fn path_graph() -> Bipartite {
        // nets connect consecutive vertices: a path 0-1-2-3-4 at distance 2
        let m = Csr::from_edges(4, 5, &[(0, 0), (0, 1), (1, 1), (1, 2), (2, 2), (2, 3), (3, 3), (3, 4)]);
        Bipartite::from_net_incidence(m)
    }

    #[test]
    fn natural_is_identity() {
        let g = path_graph();
        assert_eq!(Ordering::Natural.compute(&g), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn random_is_permutation_and_seeded() {
        let g = random_bipartite(50, 80, 400, 1);
        let a = Ordering::Random(9).compute(&g);
        let b = Ordering::Random(9).compute(&g);
        assert_eq!(a, b);
        let mut s = a.clone();
        s.sort_unstable();
        assert_eq!(s, (0..80u32).collect::<Vec<_>>());
    }

    #[test]
    fn largest_first_sorted_by_bound() {
        let g = path_graph();
        let o = Ordering::LargestFirst.compute(&g);
        let bounds: Vec<usize> = o.iter().map(|&u| g.two_hop_bound(u as usize)).collect();
        for w in bounds.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn smallest_last_is_permutation() {
        let g = random_bipartite(100, 150, 900, 2);
        let o = smallest_last(&g);
        let mut s = o.clone();
        s.sort_unstable();
        assert_eq!(s, (0..150u32).collect::<Vec<_>>());
    }

    #[test]
    fn smallest_last_on_path_ends_with_low_degree() {
        let g = path_graph();
        let o = smallest_last(&g);
        // On a path, endpoints have the smallest two-hop degree; smallest-
        // last removes a minimum first, so an endpoint appears *last*.
        let last = *o.last().unwrap() as usize;
        assert!(
            g.two_hop_bound(last) <= g.two_hop_bound(o[0] as usize),
            "order {o:?}"
        );
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Ordering::parse("natural"), Some(Ordering::Natural));
        assert_eq!(Ordering::parse("sl"), Some(Ordering::SmallestLast));
        assert_eq!(Ordering::parse("junk"), None);
    }
}
