//! One front door for "where does the graph come from" — the
//! [`GraphSource`] spec shared by the CLI's `--graph` flag, the bench
//! harness, and the examples (DESIGN.md §15).
//!
//! A source is CLI text with a [`GraphSource::parse`] /
//! [`GraphSource::label`] round trip, mirroring
//! [`Strategy`](crate::coloring::Strategy):
//!
//! ```text
//! preset:coPapersDBLP@0.1@1    calibrated synthetic preset (scale, seed)
//! coPapersDBLP                 bare preset name (default scale/seed)
//! mtx:matrices/bone010.mtx     .mtx file, streamed parse (bounded memory)
//! mtxmem:small.mtx             .mtx file, in-memory reference parser
//! csrb:big.csrb                prebuilt CSR store, opened via mmap
//! random:500x800x4000@7        uniform random bipartite (nets x vtxs x nnz)
//! ```
//!
//! Bare paths ending in `.mtx` / `.csrb` are accepted too (they label
//! back in prefixed form). Loading returns the net-side incidence
//! [`Csr`] or its [`Bipartite`] view; `*_on` variants route the
//! streaming parser onto a caller's [`WorkerPool`] instead of a
//! transient one.

use std::path::PathBuf;
use std::sync::Arc;

use crate::par::WorkerPool;
use crate::util::error::Result;

use super::csr::Csr;
use super::generators::{random_bipartite, Preset};
use super::{mtx, storage, Bipartite};

/// Default preset scale when a bare preset name is given.
pub const DEFAULT_SCALE: f64 = 0.1;
/// Default seed for presets and random instances.
pub const DEFAULT_SEED: u64 = 1;

/// A parsed graph-source spec — see the module docs for the grammar.
#[derive(Clone, Debug, PartialEq)]
pub enum GraphSource {
    /// Calibrated synthetic preset (Table II test-bed) at a scale.
    Preset { name: String, scale: f64, seed: u64 },
    /// Matrix-Market file, parsed by the streaming tier.
    Mtx(PathBuf),
    /// Matrix-Market file, parsed by the in-memory reference reader.
    MtxMem(PathBuf),
    /// Prebuilt `.csrb` store, opened read-only via mmap.
    CsrBin(PathBuf),
    /// Uniform random bipartite instance (tests, smoke benches).
    Random { n_nets: usize, n_vtxs: usize, nnz: usize, seed: u64 },
}

impl GraphSource {
    /// Parse CLI text; `None` if the spec (or bare preset name) is
    /// unknown. Inverse of [`GraphSource::label`].
    pub fn parse(s: &str) -> Option<GraphSource> {
        let s = s.trim();
        if let Some(rest) = s.strip_prefix("preset:") {
            let mut it = rest.split('@');
            let name = it.next()?.to_string();
            Preset::by_name(&name)?;
            let scale = match it.next() {
                Some(t) => t.parse::<f64>().ok().filter(|x| *x > 0.0)?,
                None => DEFAULT_SCALE,
            };
            let seed = match it.next() {
                Some(t) => t.parse::<u64>().ok()?,
                None => DEFAULT_SEED,
            };
            if it.next().is_some() {
                return None;
            }
            return Some(GraphSource::Preset { name, scale, seed });
        }
        if let Some(rest) = s.strip_prefix("mtx:") {
            return Some(GraphSource::Mtx(PathBuf::from(rest)));
        }
        if let Some(rest) = s.strip_prefix("mtxmem:") {
            return Some(GraphSource::MtxMem(PathBuf::from(rest)));
        }
        if let Some(rest) = s.strip_prefix("csrb:") {
            return Some(GraphSource::CsrBin(PathBuf::from(rest)));
        }
        if let Some(rest) = s.strip_prefix("random:") {
            let (dims, seed) = match rest.split_once('@') {
                Some((d, t)) => (d, t.parse::<u64>().ok()?),
                None => (rest, DEFAULT_SEED),
            };
            let mut it = dims.split('x');
            let n_nets = it.next()?.parse::<usize>().ok()?;
            let n_vtxs = it.next()?.parse::<usize>().ok()?;
            let nnz = it.next()?.parse::<usize>().ok()?;
            if it.next().is_some() || n_nets == 0 || n_vtxs == 0 {
                return None;
            }
            return Some(GraphSource::Random { n_nets, n_vtxs, nnz, seed });
        }
        if s.ends_with(".mtx") {
            return Some(GraphSource::Mtx(PathBuf::from(s)));
        }
        if s.ends_with(".csrb") {
            return Some(GraphSource::CsrBin(PathBuf::from(s)));
        }
        Preset::by_name(s).map(|p| GraphSource::Preset {
            name: p.name.to_string(),
            scale: DEFAULT_SCALE,
            seed: DEFAULT_SEED,
        })
    }

    /// Stable display label (job names, bench CSVs); parses back to
    /// `self` — the same contract as
    /// [`Strategy::label`](crate::coloring::Strategy::label).
    pub fn label(&self) -> String {
        match self {
            GraphSource::Preset { name, scale, seed } => format!("preset:{name}@{scale}@{seed}"),
            GraphSource::Mtx(p) => format!("mtx:{}", p.display()),
            GraphSource::MtxMem(p) => format!("mtxmem:{}", p.display()),
            GraphSource::CsrBin(p) => format!("csrb:{}", p.display()),
            GraphSource::Random { n_nets, n_vtxs, nnz, seed } => {
                format!("random:{n_nets}x{n_vtxs}x{nnz}@{seed}")
            }
        }
    }

    /// Load the net-side incidence pattern, running any streamed parse
    /// on `pool`.
    pub fn load_csr_on(&self, pool: &WorkerPool) -> Result<Csr> {
        match self {
            GraphSource::Mtx(p) => mtx::stream_mtx_to_csr(p, pool),
            _ => self.load_poolless(),
        }
    }

    /// [`GraphSource::load_csr_on`] with a transient pool for the
    /// streamed-`.mtx` case (other sources never need one).
    pub fn load_csr(&self) -> Result<Csr> {
        match self {
            GraphSource::Mtx(p) => {
                let t = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
                mtx::stream_mtx_to_csr(p, &WorkerPool::new(t.min(8)))
            }
            _ => self.load_poolless(),
        }
    }

    /// Every source except the streamed `.mtx` path (which wants a
    /// worker team).
    fn load_poolless(&self) -> Result<Csr> {
        match self {
            GraphSource::Preset { name, scale, seed } => {
                // parse() validated the name; re-validate for hand-built values
                let p = Preset::by_name(name).ok_or_else(|| {
                    crate::util::error::Error::msg(format!("unknown preset {name}"))
                })?;
                Ok(p.net_incidence(*scale, *seed))
            }
            GraphSource::Mtx(p) | GraphSource::MtxMem(p) => mtx::read_mtx(p),
            GraphSource::CsrBin(p) => storage::open_csr(p),
            GraphSource::Random { n_nets, n_vtxs, nnz, seed } => {
                Ok(random_bipartite(*n_nets, *n_vtxs, *nnz, *seed).net_vtxs)
            }
        }
    }

    /// Load as a bipartite BGPC instance (both incidence directions).
    pub fn load(&self) -> Result<Bipartite> {
        Ok(Bipartite::from_net_incidence(self.load_csr()?))
    }

    /// [`GraphSource::load`] with streamed parses routed onto `pool`.
    pub fn load_on(&self, pool: &Arc<WorkerPool>) -> Result<Bipartite> {
        Ok(Bipartite::from_net_incidence(self.load_csr_on(pool)?))
    }

    /// Short instance name for tables: the preset name, file stem, or
    /// the full label for random specs.
    pub fn name(&self) -> String {
        match self {
            GraphSource::Preset { name, .. } => name.clone(),
            GraphSource::Mtx(p) | GraphSource::MtxMem(p) | GraphSource::CsrBin(p) => p
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| p.display().to_string()),
            GraphSource::Random { .. } => self.label(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_label_round_trip() {
        for s in [
            "preset:coPapersDBLP@0.1@1",
            "preset:uk-2002@0.05@9",
            "mtx:dir/a.mtx",
            "mtxmem:b.mtx",
            "csrb:store.csrb",
            "random:10x20x55@3",
        ] {
            let src = GraphSource::parse(s).unwrap_or_else(|| panic!("parse {s}"));
            assert_eq!(src.label(), s);
            assert_eq!(GraphSource::parse(&src.label()), Some(src), "round trip {s}");
        }
    }

    #[test]
    fn bare_forms_normalise() {
        assert_eq!(
            GraphSource::parse("coPapersDBLP"),
            Some(GraphSource::Preset {
                name: "coPapersDBLP".into(),
                scale: DEFAULT_SCALE,
                seed: DEFAULT_SEED
            })
        );
        assert_eq!(GraphSource::parse("x/y.mtx"), Some(GraphSource::Mtx("x/y.mtx".into())));
        assert_eq!(GraphSource::parse("z.csrb"), Some(GraphSource::CsrBin("z.csrb".into())));
        assert_eq!(GraphSource::parse("random:4x5x9"), GraphSource::parse("random:4x5x9@1"));
    }

    #[test]
    fn rejects_unknown_specs() {
        for s in ["preset:not-a-preset", "random:0x5x9", "random:4x5", "nosuchpreset", ""] {
            assert_eq!(GraphSource::parse(s), None, "should reject {s:?}");
        }
    }

    #[test]
    fn random_loads_deterministically() {
        let src = GraphSource::parse("random:8x12x30@5").unwrap();
        let a = src.load().unwrap();
        let b = src.load().unwrap();
        assert_eq!(a.net_vtxs, b.net_vtxs);
        assert_eq!(a.vtx_nets.n_rows, 12);
    }

    #[test]
    fn preset_load_matches_generator() {
        let src = GraphSource::parse("preset:coPapersDBLP@0.02@3").unwrap();
        let direct = Preset::by_name("coPapersDBLP").unwrap().net_incidence(0.02, 3);
        assert_eq!(src.load_csr().unwrap(), direct);
        assert_eq!(src.name(), "coPapersDBLP");
    }

    #[test]
    fn mtx_sources_agree() {
        let dir = std::env::temp_dir().join(format!("bgpc_source_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("src.mtx");
        let g = random_bipartite(6, 9, 25, 2).net_vtxs;
        mtx::write_mtx(&g, &p).unwrap();
        let streamed = GraphSource::Mtx(p.clone()).load_csr().unwrap();
        let memory = GraphSource::MtxMem(p.clone()).load_csr().unwrap();
        assert_eq!(streamed, g);
        assert_eq!(memory, g);
        let store = dir.join("src.csrb");
        storage::write_csr(&g, &store).unwrap();
        assert_eq!(GraphSource::CsrBin(store).load_csr().unwrap(), g);
    }
}
