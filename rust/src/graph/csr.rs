//! Compressed sparse row adjacency — the only graph storage in the repo.
//!
//! Matches the paper's data layout ("all the algorithms are implemented
//! ... using the same data structures"): a `ptr` offset array plus a flat
//! `adj` id array, ids are `u32` (the in-memory kernels are u32-wide; the
//! on-disk tier in [`storage`](super::storage) carries a u64 width and
//! checks every conversion back down). Both arrays live behind
//! [`Buf`] — heap-owned by default, or a read-only file mapping when the
//! CSR was opened from a `.csrb` store; kernels read either identically
//! through `Deref`, and the first mutation of a mapped buffer promotes it
//! to a private heap copy.

use super::storage::Buf;

/// CSR adjacency from `n_rows` entities into an id space of `n_cols`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub ptr: Buf<usize>,
    pub adj: Buf<u32>,
}

impl Csr {
    /// Build from an (unsorted) edge list; duplicates are removed.
    pub fn from_edges(n_rows: usize, n_cols: usize, edges: &[(u32, u32)]) -> Csr {
        let mut deg = vec![0usize; n_rows];
        for &(r, _) in edges {
            deg[r as usize] += 1;
        }
        let mut ptr = vec![0usize; n_rows + 1];
        for i in 0..n_rows {
            ptr[i + 1] = ptr[i] + deg[i];
        }
        let mut adj = vec![0u32; edges.len()];
        let mut cursor = ptr.clone();
        for &(r, c) in edges {
            adj[cursor[r as usize]] = c;
            cursor[r as usize] += 1;
        }
        let mut csr = Csr { n_rows, n_cols, ptr: ptr.into(), adj: adj.into() };
        csr.sort_dedup_rows();
        csr
    }

    /// Sort each row and drop duplicate ids (in place, compacting).
    pub fn sort_dedup_rows(&mut self) {
        let mut out_ptr = Vec::with_capacity(self.n_rows + 1);
        out_ptr.push(0usize);
        let mut w = 0usize;
        for r in 0..self.n_rows {
            let (s, e) = (self.ptr[r], self.ptr[r + 1]);
            self.adj[s..e].sort_unstable();
            let mut prev: Option<u32> = None;
            for i in s..e {
                let v = self.adj[i];
                if prev != Some(v) {
                    self.adj[w] = v;
                    w += 1;
                    prev = Some(v);
                }
            }
            out_ptr.push(w);
        }
        self.adj.truncate(w);
        self.ptr = out_ptr.into();
    }

    /// Number of stored edges.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.adj.len()
    }

    /// Adjacency slice of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[u32] {
        &self.adj[self.ptr[r]..self.ptr[r + 1]]
    }

    /// Best-effort prefetch of the head of row `r`'s adjacency — a pure
    /// hint (no-op out of range or off x86_64). The marking loops run
    /// one net/row ahead so the next gather's dependent loads are in
    /// flight before the scan arrives (DESIGN.md §Perf).
    #[inline(always)]
    pub fn prefetch_row(&self, r: usize) {
        if r < self.n_rows {
            crate::util::arch::prefetch_slice(&self.adj, self.ptr[r]);
        }
    }

    /// Degree of row `r`.
    #[inline]
    pub fn deg(&self, r: usize) -> usize {
        self.ptr[r + 1] - self.ptr[r]
    }

    /// Maximum row degree.
    pub fn max_deg(&self) -> usize {
        (0..self.n_rows).map(|r| self.deg(r)).max().unwrap_or(0)
    }

    /// Transpose (counting sort; output rows are sorted by construction).
    pub fn transpose(&self) -> Csr {
        let mut deg = vec![0usize; self.n_cols];
        for &c in self.adj.iter() {
            deg[c as usize] += 1;
        }
        let mut ptr = vec![0usize; self.n_cols + 1];
        for i in 0..self.n_cols {
            ptr[i + 1] = ptr[i] + deg[i];
        }
        let mut adj = vec![0u32; self.adj.len()];
        let mut cursor = ptr.clone();
        for r in 0..self.n_rows {
            for &c in self.row(r) {
                adj[cursor[c as usize]] = r as u32;
                cursor[c as usize] += 1;
            }
        }
        Csr { n_rows: self.n_cols, n_cols: self.n_rows, ptr: ptr.into(), adj: adj.into() }
    }

    /// Apply a permutation to the *column id space*: new id of old column
    /// `c` is `perm[c]`. Rows keep their order; rows are re-sorted.
    pub fn relabel_cols(&mut self, perm: &[u32]) {
        assert_eq!(perm.len(), self.n_cols);
        for c in self.adj.iter_mut() {
            *c = perm[*c as usize];
        }
        for r in 0..self.n_rows {
            let (s, e) = (self.ptr[r], self.ptr[r + 1]);
            self.adj[s..e].sort_unstable();
        }
    }

    /// Reorder rows: new row `i` is old row `order[i]`.
    pub fn permute_rows(&self, order: &[u32]) -> Csr {
        assert_eq!(order.len(), self.n_rows);
        let mut ptr = Vec::with_capacity(self.n_rows + 1);
        ptr.push(0usize);
        let mut adj = Vec::with_capacity(self.adj.len());
        for &old in order {
            adj.extend_from_slice(self.row(old as usize));
            ptr.push(adj.len());
        }
        Csr { n_rows: self.n_rows, n_cols: self.n_cols, ptr: ptr.into(), adj: adj.into() }
    }

    /// Splice-rebuild: a new CSR that keeps every row verbatim except
    /// the listed replacements (each a sorted, deduped id list). The
    /// shape may grow (`n_rows >= self.n_rows`, `n_cols >= self.n_cols`);
    /// rows beyond the old shape default to empty unless replaced. This
    /// is the compaction primitive of the dynamic delta overlay: only
    /// dirty rows are rebuilt, clean rows are a straight memcpy.
    pub fn with_replaced_rows(
        &self,
        n_rows: usize,
        n_cols: usize,
        replace: &std::collections::BTreeMap<u32, Vec<u32>>,
    ) -> Csr {
        assert!(n_rows >= self.n_rows, "splice cannot drop rows");
        assert!(n_cols >= self.n_cols, "splice cannot drop columns");
        let mut ptr = Vec::with_capacity(n_rows + 1);
        ptr.push(0usize);
        let mut adj: Vec<u32> = Vec::with_capacity(self.adj.len());
        for r in 0..n_rows {
            if let Some(row) = replace.get(&(r as u32)) {
                adj.extend_from_slice(row);
            } else if r < self.n_rows {
                adj.extend_from_slice(self.row(r));
            }
            ptr.push(adj.len());
        }
        Csr { n_rows, n_cols, ptr: ptr.into(), adj: adj.into() }
    }

    /// True if the matrix is square and its pattern is symmetric.
    pub fn is_structurally_symmetric(&self) -> bool {
        if self.n_rows != self.n_cols {
            return false;
        }
        let t = self.transpose();
        t.ptr == self.ptr && t.adj == self.adj
    }

    /// Check internal invariants (used by property tests).
    pub fn validate(&self) -> Result<(), String> {
        if self.ptr.len() != self.n_rows + 1 {
            return Err(format!("ptr len {} != n_rows+1", self.ptr.len()));
        }
        if self.ptr[0] != 0 || *self.ptr.last().unwrap() != self.adj.len() {
            return Err("ptr endpoints broken".into());
        }
        for r in 0..self.n_rows {
            if self.ptr[r] > self.ptr[r + 1] {
                return Err(format!("ptr not monotone at {r}"));
            }
            let row = self.row(r);
            for w in row.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {r} not sorted/deduped"));
                }
            }
            if let Some(&m) = row.last() {
                if (m as usize) >= self.n_cols {
                    return Err(format!("row {r} id {m} out of range"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // 3 rows, 4 cols: r0 -> {0, 2}, r1 -> {1, 2, 3}, r2 -> {}
        Csr::from_edges(3, 4, &[(0, 2), (0, 0), (1, 3), (1, 1), (1, 2), (0, 2)])
    }

    #[test]
    fn from_edges_sorts_and_dedups() {
        let g = sample();
        assert_eq!(g.row(0), &[0, 2]);
        assert_eq!(g.row(1), &[1, 2, 3]);
        assert_eq!(g.row(2), &[] as &[u32]);
        assert_eq!(g.nnz(), 5);
        g.validate().unwrap();
    }

    #[test]
    fn transpose_roundtrip() {
        let g = sample();
        let t = g.transpose();
        assert_eq!(t.n_rows, 4);
        assert_eq!(t.row(2), &[0, 1]);
        let back = t.transpose();
        assert_eq!(back, g);
        t.validate().unwrap();
    }

    #[test]
    fn symmetric_detection() {
        let sym = Csr::from_edges(3, 3, &[(0, 1), (1, 0), (1, 2), (2, 1)]);
        assert!(sym.is_structurally_symmetric());
        let asym = Csr::from_edges(3, 3, &[(0, 1), (1, 2)]);
        assert!(!asym.is_structurally_symmetric());
    }

    #[test]
    fn permute_rows_moves_adjacency() {
        let g = sample();
        let p = g.permute_rows(&[2, 0, 1]);
        assert_eq!(p.row(0), &[] as &[u32]);
        assert_eq!(p.row(1), &[0, 2]);
        assert_eq!(p.row(2), &[1, 2, 3]);
    }

    #[test]
    fn relabel_cols_keeps_sorted() {
        let mut g = sample();
        // swap col ids 0 <-> 3
        g.relabel_cols(&[3, 1, 2, 0]);
        g.validate().unwrap();
        assert_eq!(g.row(0), &[2, 3]);
        assert_eq!(g.row(1), &[0, 1, 2]);
    }

    #[test]
    fn with_replaced_rows_splices_and_grows() {
        let g = sample(); // r0 -> {0,2}, r1 -> {1,2,3}, r2 -> {}
        let mut replace = std::collections::BTreeMap::new();
        replace.insert(1u32, vec![0u32, 4]);
        replace.insert(4u32, vec![2u32]);
        let s = g.with_replaced_rows(5, 6, &replace);
        s.validate().unwrap();
        assert_eq!(s.n_rows, 5);
        assert_eq!(s.n_cols, 6);
        assert_eq!(s.row(0), &[0, 2], "clean row copied verbatim");
        assert_eq!(s.row(1), &[0, 4], "replaced row");
        assert_eq!(s.row(2), &[] as &[u32]);
        assert_eq!(s.row(3), &[] as &[u32], "new row defaults empty");
        assert_eq!(s.row(4), &[2], "new row replaced");
    }

    #[test]
    fn empty_graph_ok() {
        let g = Csr::from_edges(0, 0, &[]);
        g.validate().unwrap();
        assert_eq!(g.nnz(), 0);
        assert_eq!(g.max_deg(), 0);
    }
}
