//! Matrix Market (`.mtx`) I/O.
//!
//! The paper's matrices come from the UFL/SuiteSparse collection in this
//! format; the reader accepts `coordinate` `pattern|real|integer|complex`
//! with `general|symmetric|skew-symmetric|hermitian` storage (values are
//! ignored — coloring only needs the pattern). Two reading tiers share
//! one header parser:
//!
//! * [`read_mtx`] / [`read_mtx_from`] — the in-memory reference path:
//!   collect an edge list, build through [`Csr::from_edges`]. Simple,
//!   and the ground truth the streaming tier is property-tested against.
//! * [`stream_mtx_to_csr`] / [`stream_mtx_to_file`] — the out-of-core
//!   path (DESIGN.md §15): a chunked **two-pass** scan of the data
//!   section, each pass parsing coordinate lines **in parallel** on the
//!   [`WorkerPool`] (no `lines().collect()`, no materialised edge list).
//!   Pass 1 counts row degrees into an atomic array; pass 2 re-parses
//!   and places ids through per-row atomic cursors straight into the
//!   final adjacency (heap, or the writable `.csrb` mapping); a
//!   sequential sort+dedup compaction then makes the result bit-for-bit
//!   identical to the reference path. Transient memory is
//!   `O(n_rows + chunk)`, not `O(nnz)`.
//!
//! Index handling is checked end-to-end: ids and dimensions are parsed
//! as `u64`, validated against the header, and only narrowed through
//! [`checked_u32`] / [`checked_usize`] — an overflowing value is a
//! contextual error, never a silent `as` wrap.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering as AOrd};
use std::sync::Mutex;

use crate::bail;
use crate::par::{Cost, WorkerPool};
use crate::util::error::{Context, Error, Result};

use super::csr::Csr;
use super::storage::{
    checked_u32, checked_usize, csr_file_info, CsrFileInfo, CsrWriter, IndexWidth, SharedSlots,
};

// ---------------------------------------------------------------------------
// Header.
// ---------------------------------------------------------------------------

/// Parsed `.mtx` banner + size line.
#[derive(Clone, Copy, Debug)]
pub struct MtxHeader {
    /// Declared row count.
    pub n_rows: u64,
    /// Declared column count.
    pub n_cols: u64,
    /// Declared entry count (lower-triangle count for symmetric files);
    /// a capacity hint only — the readers trust the actual data lines.
    pub declared_nnz: u64,
    /// True for `symmetric` / `skew-symmetric` / `hermitian` storage:
    /// every off-diagonal entry is mirrored.
    pub symmetric: bool,
    /// Byte offset of the first line after the size line — where the
    /// streaming passes start.
    pub data_start: u64,
}

/// Parse the banner and size line, counting consumed bytes so streaming
/// callers know where the data section starts. Tolerated edge cases: a
/// UTF-8 BOM, blank lines before the banner, CRLF endings, and comment /
/// blank lines between banner and size line.
fn parse_header(r: &mut impl BufRead) -> Result<MtxHeader> {
    let mut line: Vec<u8> = Vec::new();
    let mut consumed: u64 = 0;
    let banner = loop {
        line.clear();
        let n = r.read_until(b'\n', &mut line).context("read mtx banner")?;
        if n == 0 {
            bail!("empty mtx file");
        }
        consumed += n as u64;
        let mut t: &[u8] = &line;
        if consumed == n as u64 && t.starts_with(&[0xEF, 0xBB, 0xBF]) {
            t = &t[3..]; // UTF-8 BOM on the very first line
        }
        let s = std::str::from_utf8(t)
            .map_err(|_| Error::msg("mtx banner is not valid UTF-8"))?
            .trim();
        if !s.is_empty() {
            break s.to_string();
        }
    };
    let h: Vec<String> = banner.split_whitespace().map(|s| s.to_ascii_lowercase()).collect();
    if h.len() < 4 || h[0] != "%%matrixmarket" || h[1] != "matrix" {
        bail!("not a MatrixMarket header: {banner}");
    }
    if h[2] != "coordinate" {
        bail!("only coordinate format supported, got {}", h[2]);
    }
    let field = h[3].as_str();
    if !matches!(field, "pattern" | "real" | "integer" | "complex") {
        bail!("unsupported field {field}");
    }
    let symmetric = match h.get(4).map(|s| s.as_str()) {
        None | Some("general") => false,
        Some("symmetric") | Some("skew-symmetric") | Some("hermitian") => true,
        Some(other) => bail!("unsupported symmetry {other}"),
    };

    let size_line = loop {
        line.clear();
        let n = r.read_until(b'\n', &mut line).context("read mtx size line")?;
        if n == 0 {
            bail!("missing size line");
        }
        consumed += n as u64;
        let s = std::str::from_utf8(&line)
            .map_err(|_| Error::msg("mtx size line is not valid UTF-8"))?
            .trim();
        if s.is_empty() || s.starts_with('%') {
            continue;
        }
        break s.to_string();
    };
    let dims: Vec<u64> = size_line
        .split_whitespace()
        .take(3)
        .map(|t| t.parse::<u64>().with_context(|| format!("size line token {t:?}")))
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        bail!("bad size line: {size_line}");
    }
    Ok(MtxHeader {
        n_rows: dims[0],
        n_cols: dims[1],
        declared_nnz: dims[2],
        symmetric,
        data_start: consumed,
    })
}

/// Read just the banner + size line of `path` (no data lines touched).
pub fn read_mtx_header(path: impl AsRef<Path>) -> Result<MtxHeader> {
    let f = File::open(path.as_ref()).with_context(|| format!("open {:?}", path.as_ref()))?;
    parse_header(&mut BufReader::new(f))
}

fn check_bounds(r: u64, c: u64, n_rows: u64, n_cols: u64) -> Result<()> {
    if r == 0 || c == 0 || r > n_rows || c > n_cols {
        bail!("index out of range: {r} {c} (1-based, {n_rows}x{n_cols})");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// In-memory reference path.
// ---------------------------------------------------------------------------

/// Read a Matrix-Market coordinate file into a CSR pattern
/// (rows = nets when used for BGPC column coloring).
pub fn read_mtx(path: impl AsRef<Path>) -> Result<Csr> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {:?}", path.as_ref()))?;
    read_mtx_from(BufReader::new(f))
}

/// Reader-based variant (unit tests use in-memory buffers). Ids are
/// checked against the u32 kernel id space — a 5-billion-row header is a
/// contextual error here, not a wrapped id (use the streaming tier +
/// `.csrb` storage for wide graphs).
pub fn read_mtx_from(mut r: impl BufRead) -> Result<Csr> {
    let h = parse_header(&mut r)?;
    checked_u32(h.n_rows, "n_rows")?;
    checked_u32(h.n_cols, "n_cols")?;
    let cap = checked_usize(h.declared_nnz, "declared nnz")?;
    let cap = if h.symmetric { cap.saturating_mul(2) } else { cap };
    // Capacity is a hint from the header; cap it so a malformed header
    // cannot force an absurd allocation before the first data line.
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(cap.min(1 << 24));
    let mut line = String::new();
    loop {
        line.clear();
        if r.read_line(&mut line).context("read mtx entry")? == 0 {
            break;
        }
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (Some(rs), Some(cs)) = (it.next(), it.next()) else {
            bail!("bad entry line: {t}");
        };
        let row: u64 = rs.parse().with_context(|| format!("row index {rs:?}"))?;
        let col: u64 = cs.parse().with_context(|| format!("col index {cs:?}"))?;
        check_bounds(row, col, h.n_rows, h.n_cols)?;
        // In range ⇒ fits u32 (dims were checked above).
        let (ri, ci) = ((row - 1) as u32, (col - 1) as u32);
        edges.push((ri, ci));
        if h.symmetric && ri != ci {
            edges.push((ci, ri));
        }
    }
    Ok(Csr::from_edges(h.n_rows as usize, h.n_cols as usize, &edges))
}

// ---------------------------------------------------------------------------
// Streaming path.
// ---------------------------------------------------------------------------

/// Default bytes of data section handed to one parallel parse item.
const STREAM_CHUNK: u64 = 4 << 20;
/// Maximum supported data-line length (chunks read this much past their
/// end to finish a straddling line).
const LINE_OVERHANG: u64 = 64 << 10;

#[derive(Clone, Copy)]
struct Span {
    start: u64,
    end: u64,
    data_start: u64,
    file_len: u64,
}

fn span_of(data_start: u64, file_len: u64, chunk_bytes: u64, item: usize) -> Span {
    let start = data_start + item as u64 * chunk_bytes;
    Span { start, end: (start + chunk_bytes).min(file_len), data_start, file_len }
}

/// Per-worker streaming state: an independent file handle (seek cursors
/// must not be shared across the team) plus a reusable chunk buffer.
struct ChunkState {
    file: File,
    buf: Vec<u8>,
}

fn chunk_states(path: &Path, team: usize) -> Result<Vec<ChunkState>> {
    (0..team)
        .map(|_| {
            Ok(ChunkState {
                file: File::open(path).with_context(|| format!("open {path:?}"))?,
                buf: Vec::new(),
            })
        })
        .collect()
}

/// First error wins; later chunks bail out early once one is recorded.
#[derive(Default)]
struct ParseErrs {
    flag: AtomicBool,
    first: Mutex<Option<Error>>,
}

impl ParseErrs {
    fn seen(&self) -> bool {
        self.flag.load(AOrd::Relaxed)
    }
    fn record(&self, e: Error) {
        self.flag.store(true, AOrd::Relaxed);
        let mut g = self.first.lock().unwrap();
        if g.is_none() {
            *g = Some(e);
        }
    }
    fn take(&self) -> Result<()> {
        match self.first.lock().unwrap().take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Iterate the data lines *owned* by `span`: lines whose first byte lies
/// in `[span.start, span.end)`. The chunk reads one byte early (except at
/// the data start) to tell whether `span.start` begins a line, and
/// [`LINE_OVERHANG`] bytes past its end to finish a straddling last line.
fn for_each_owned_line(
    st: &mut ChunkState,
    span: Span,
    mut f: impl FnMut(&[u8]) -> Result<()>,
) -> Result<()> {
    let lead: u64 = if span.start > span.data_start { 1 } else { 0 };
    let read_from = span.start - lead;
    let read_to = (span.end + LINE_OVERHANG).min(span.file_len);
    let want = (read_to - read_from) as usize;
    st.buf.clear();
    st.buf.reserve(want);
    st.file.seek(SeekFrom::Start(read_from)).context("seek mtx chunk")?;
    let got =
        (&mut st.file).take(want as u64).read_to_end(&mut st.buf).context("read mtx chunk")?;
    if got < (span.end - read_from) as usize {
        bail!("mtx file shrank during streaming parse");
    }
    let buf = &st.buf[..got];
    let mut pos = if lead == 1 {
        if buf[0] == b'\n' {
            1 // the previous byte ends a line: span.start begins one
        } else {
            match buf.iter().position(|&b| b == b'\n') {
                Some(i) => i + 1,
                // The line straddling span.start runs past this whole
                // read window; it belongs to the chunk it started in.
                None => return Ok(()),
            }
        }
    } else {
        0
    };
    let own_end = (span.end - read_from) as usize;
    while pos < own_end {
        match buf[pos..].iter().position(|&b| b == b'\n') {
            Some(i) => {
                f(&buf[pos..pos + i])?;
                pos += i + 1;
            }
            None => {
                if read_to < span.file_len {
                    bail!(
                        "mtx data line at byte {} exceeds the {} byte limit",
                        read_from + pos as u64,
                        LINE_OVERHANG
                    );
                }
                f(&buf[pos..])?; // final line without trailing newline
                break;
            }
        }
    }
    Ok(())
}

fn parse_ascii_u64(s: &[u8]) -> Option<u64> {
    let s = if s.first() == Some(&b'+') { &s[1..] } else { s };
    if s.is_empty() {
        return None;
    }
    let mut v: u64 = 0;
    for &b in s {
        if !b.is_ascii_digit() {
            return None;
        }
        v = v.checked_mul(10)?.checked_add((b - b'0') as u64)?;
    }
    Some(v)
}

/// Parse one data line to 1-based `(row, col)`; `None` for blank/comment.
fn parse_coord_bytes(line: &[u8]) -> Result<Option<(u64, u64)>> {
    let t = line.trim_ascii();
    if t.is_empty() || t[0] == b'%' {
        return Ok(None);
    }
    let mut it = t.split(|b| b.is_ascii_whitespace()).filter(|s| !s.is_empty());
    let (Some(rs), Some(cs)) = (it.next(), it.next()) else {
        bail!("bad entry line: {}", String::from_utf8_lossy(t));
    };
    let (Some(r), Some(c)) = (parse_ascii_u64(rs), parse_ascii_u64(cs)) else {
        bail!("bad coordinate in entry line: {}", String::from_utf8_lossy(t));
    };
    Ok(Some((r, c)))
}

/// Pass 1: count per-row placement degrees (mirrored entries included)
/// into an atomic array, parsing chunks in parallel.
fn degree_pass(
    pool: &WorkerPool,
    states: &mut [ChunkState],
    n_chunks: usize,
    h: MtxHeader,
    file_len: u64,
    chunk_bytes: u64,
    deg: &[AtomicU64],
) -> Result<()> {
    let errs = ParseErrs::default();
    let team = states.len();
    let _ = pool.region(states, team, n_chunks, 1, |_w, st, item, _now| {
        if errs.seen() {
            return Cost::new(0);
        }
        let mut lines = 0u64;
        let span = span_of(h.data_start, file_len, chunk_bytes, item);
        let res = for_each_owned_line(st, span, |line| {
            let Some((r, c)) = parse_coord_bytes(line)? else {
                return Ok(());
            };
            check_bounds(r, c, h.n_rows, h.n_cols)?;
            lines += 1;
            deg[(r - 1) as usize].fetch_add(1, AOrd::Relaxed);
            if h.symmetric && r != c {
                deg[(c - 1) as usize].fetch_add(1, AOrd::Relaxed);
            }
            Ok(())
        });
        if let Err(e) = res {
            errs.record(e);
        }
        Cost::new(lines.max(1))
    });
    errs.take()
}

/// Pass 2: re-parse the same chunks and place ids through the per-row
/// atomic cursors into disjoint adjacency slots.
fn place_pass<T: Copy + Send + Sync + 'static>(
    pool: &WorkerPool,
    states: &mut [ChunkState],
    n_chunks: usize,
    h: MtxHeader,
    file_len: u64,
    chunk_bytes: u64,
    cursors: &[AtomicU64],
    slots: &SharedSlots<T>,
    conv: impl Fn(u64) -> T + Sync,
) -> Result<()> {
    let errs = ParseErrs::default();
    let team = states.len();
    let _ = pool.region(states, team, n_chunks, 1, |_w, st, item, _now| {
        if errs.seen() {
            return Cost::new(0);
        }
        let mut lines = 0u64;
        let span = span_of(h.data_start, file_len, chunk_bytes, item);
        let res = for_each_owned_line(st, span, |line| {
            let Some((r, c)) = parse_coord_bytes(line)? else {
                return Ok(());
            };
            check_bounds(r, c, h.n_rows, h.n_cols)?;
            lines += 1;
            let slot = cursors[(r - 1) as usize].fetch_add(1, AOrd::Relaxed) as usize;
            // SAFETY: the cursor hands every placement a distinct slot
            // (pass 1 sized the regions from the same file bytes);
            // `write` still bounds-checks against the total.
            unsafe { slots.write(slot, conv(c - 1)) };
            if h.symmetric && r != c {
                let slot = cursors[(c - 1) as usize].fetch_add(1, AOrd::Relaxed) as usize;
                // SAFETY: as above.
                unsafe { slots.write(slot, conv(r - 1)) };
            }
            Ok(())
        });
        if let Err(e) = res {
            errs.record(e);
        }
        Cost::new(lines.max(1))
    });
    errs.take()
}

/// Sort each row and drop duplicates in place (same pass as
/// [`Csr::sort_dedup_rows`], so streamed results are bit-for-bit equal
/// to the reference path); returns the compacted row pointers and the
/// final nnz.
fn sort_dedup_compact<T: Copy + Ord>(ptr_in: &[u64], adj: &mut [T]) -> (Vec<u64>, usize) {
    let n_rows = ptr_in.len() - 1;
    let mut out_ptr = Vec::with_capacity(n_rows + 1);
    out_ptr.push(0u64);
    let mut w = 0usize;
    for r in 0..n_rows {
        let (s, e) = (ptr_in[r] as usize, ptr_in[r + 1] as usize);
        adj[s..e].sort_unstable();
        let mut prev: Option<T> = None;
        for i in s..e {
            let v = adj[i];
            if prev != Some(v) {
                adj[w] = v;
                w += 1;
                prev = Some(v);
            }
        }
        out_ptr.push(w as u64);
    }
    (out_ptr, w)
}

struct StreamPrep {
    h: MtxHeader,
    file_len: u64,
    n_chunks: usize,
    states: Vec<ChunkState>,
    /// Degrees after pass 1 (reused as placement cursors in pass 2).
    deg: Vec<AtomicU64>,
    /// Pre-dedup row pointers (placement regions).
    raw_ptr: Vec<u64>,
    /// Total placements (pre-dedup nnz, mirrors included).
    total: u64,
}

/// Shared front half of both streaming paths: header, chunk layout,
/// degree pass, prefix sum, cursor reset.
fn stream_prep(path: &Path, pool: &WorkerPool, chunk_bytes: u64) -> Result<StreamPrep> {
    let chunk_bytes = chunk_bytes.max(1);
    let h = read_mtx_header(path)?;
    let n_rows = checked_usize(h.n_rows, "n_rows")?;
    let file_len = std::fs::metadata(path).with_context(|| format!("stat {path:?}"))?.len();
    if file_len < h.data_start {
        bail!("{path:?} shorter than its own header");
    }
    let data_len = file_len - h.data_start;
    let n_chunks = checked_usize(data_len.div_ceil(chunk_bytes), "chunk count")?;
    let team = pool.threads().max(1);
    let mut states = chunk_states(path, team)?;

    let mut deg: Vec<AtomicU64> = Vec::with_capacity(n_rows);
    deg.resize_with(n_rows, || AtomicU64::new(0));
    degree_pass(pool, &mut states, n_chunks, h, file_len, chunk_bytes, &deg)?;

    let mut raw_ptr = Vec::with_capacity(n_rows + 1);
    raw_ptr.push(0u64);
    let mut acc = 0u64;
    for d in deg.iter() {
        acc = acc
            .checked_add(d.load(AOrd::Relaxed))
            .context("placement count overflows u64")?;
        raw_ptr.push(acc);
    }
    // Reuse the degree array as placement cursors: row r starts writing
    // at raw_ptr[r].
    for (r, d) in deg.iter().enumerate() {
        d.store(raw_ptr[r], AOrd::Relaxed);
    }
    Ok(StreamPrep { h, file_len, n_chunks, states, deg, raw_ptr, total: acc })
}

/// Stream-parse `path` into an in-memory [`Csr`] with the default chunk
/// size. Transient memory is `O(n_rows + chunk)` on top of the output
/// CSR itself — the edge list is never materialised.
pub fn stream_mtx_to_csr(path: impl AsRef<Path>, pool: &WorkerPool) -> Result<Csr> {
    stream_mtx_to_csr_chunked(path, pool, STREAM_CHUNK)
}

/// [`stream_mtx_to_csr`] with an explicit chunk size (exposed so tests
/// can force many-chunk layouts on small files).
pub fn stream_mtx_to_csr_chunked(
    path: impl AsRef<Path>,
    pool: &WorkerPool,
    chunk_bytes: u64,
) -> Result<Csr> {
    let path = path.as_ref();
    // The in-memory kernels are u32-wide; reject oversized dims *before*
    // stream_prep sizes its O(n_rows) degree array off the header.
    let h0 = read_mtx_header(path)?;
    checked_u32(h0.n_rows, "n_rows")?;
    checked_u32(h0.n_cols, "n_cols")?;
    let mut prep = stream_prep(path, pool, chunk_bytes)?;
    let h = prep.h;
    let total = checked_usize(prep.total, "pre-dedup nnz")?;
    let mut adj: Vec<u32> = vec![0u32; total];
    let slots = SharedSlots::from_mut_slice(&mut adj);
    place_pass(
        pool,
        &mut prep.states,
        prep.n_chunks,
        h,
        prep.file_len,
        chunk_bytes.max(1),
        &prep.deg,
        &slots,
        |id| id as u32, // in range: ids were bounds-checked against u32 dims
    )?;
    let (out_ptr, w) = sort_dedup_compact(&prep.raw_ptr, &mut adj);
    adj.truncate(w);
    let ptr: Vec<usize> = out_ptr.iter().map(|&x| x as usize).collect();
    Ok(Csr {
        n_rows: h.n_rows as usize,
        n_cols: h.n_cols as usize,
        ptr: ptr.into(),
        adj: adj.into(),
    })
}

/// Stream-parse `path` into an on-disk `.csrb` store at `out` with the
/// default chunk size: placement writes go straight into the writable
/// file mapping, so peak transient memory stays `O(n_rows + chunk)` even
/// when the graph itself dwarfs RAM. The index width is chosen from the
/// header dims ([`IndexWidth::for_dims`]); open the result with
/// [`super::storage::open_csr`].
pub fn stream_mtx_to_file(
    path: impl AsRef<Path>,
    out: impl AsRef<Path>,
    pool: &WorkerPool,
) -> Result<CsrFileInfo> {
    stream_mtx_to_file_chunked(path, out, pool, STREAM_CHUNK)
}

/// [`stream_mtx_to_file`] with an explicit chunk size (for tests).
pub fn stream_mtx_to_file_chunked(
    path: impl AsRef<Path>,
    out: impl AsRef<Path>,
    pool: &WorkerPool,
    chunk_bytes: u64,
) -> Result<CsrFileInfo> {
    let path = path.as_ref();
    let out = out.as_ref();
    let mut prep = stream_prep(path, pool, chunk_bytes)?;
    let h = prep.h;
    let width = IndexWidth::for_dims(h.n_rows, h.n_cols);
    let mut w = CsrWriter::create(out, h.n_rows, h.n_cols, prep.total, width)?;
    {
        let ptr = w.ptr_mut();
        ptr.copy_from_slice(&prep.raw_ptr);
    }
    let final_nnz = match width {
        IndexWidth::U32 => {
            let slots = w.adj_slots_u32();
            place_pass(
                pool,
                &mut prep.states,
                prep.n_chunks,
                h,
                prep.file_len,
                chunk_bytes.max(1),
                &prep.deg,
                &slots,
                |id| id as u32, // in range: U32 width ⇒ dims fit u32
            )?;
            let (out_ptr, nnz) = sort_dedup_compact(&prep.raw_ptr, w.adj_mut_u32());
            w.ptr_mut().copy_from_slice(&out_ptr);
            nnz
        }
        IndexWidth::U64 => {
            let slots = w.adj_slots_u64();
            place_pass(
                pool,
                &mut prep.states,
                prep.n_chunks,
                h,
                prep.file_len,
                chunk_bytes.max(1),
                &prep.deg,
                &slots,
                |id| id,
            )?;
            let (out_ptr, nnz) = sort_dedup_compact(&prep.raw_ptr, w.adj_mut_u64());
            w.ptr_mut().copy_from_slice(&out_ptr);
            nnz
        }
    };
    w.finish(final_nnz as u64)?;
    csr_file_info(out)
}

// ---------------------------------------------------------------------------
// Writing.
// ---------------------------------------------------------------------------

/// Write a CSR pattern as `coordinate pattern general`.
pub fn write_mtx(csr: &Csr, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("create {:?}", path.as_ref()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate pattern general")?;
    writeln!(w, "% written by bgpc")?;
    writeln!(w, "{} {} {}", csr.n_rows, csr.n_cols, csr.nnz())?;
    for r in 0..csr.n_rows {
        for &c in csr.row(r) {
            writeln!(w, "{} {}", r + 1, c + 1)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("bgpc_mtx_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn parse_general_pattern() {
        let txt = "%%MatrixMarket matrix coordinate pattern general\n% comment\n3 4 4\n1 1\n1 3\n2 2\n3 4\n";
        let m = read_mtx_from(Cursor::new(txt)).unwrap();
        assert_eq!(m.n_rows, 3);
        assert_eq!(m.n_cols, 4);
        assert_eq!(m.row(0), &[0, 2]);
        assert_eq!(m.row(2), &[3]);
    }

    #[test]
    fn parse_symmetric_real_mirrors() {
        let txt = "%%MatrixMarket matrix coordinate real symmetric\n3 3 3\n1 1 1.5\n2 1 2.0\n3 2 -1\n";
        let m = read_mtx_from(Cursor::new(txt)).unwrap();
        assert!(m.is_structurally_symmetric());
        assert_eq!(m.row(0), &[0, 1]);
    }

    #[test]
    fn banner_and_comment_edge_cases() {
        // BOM + CRLF + blank line before the banner + comments/blank
        // lines between banner and size line + '+'-prefixed indices.
        let txt = "\u{feff}\r\n%%MatrixMarket MATRIX Coordinate Pattern General\r\n\r\n% c1\r\n% c2\r\n2 2 2\r\n+1 2\r\n2 1\r\n";
        let m = read_mtx_from(Cursor::new(txt)).unwrap();
        assert_eq!(m.n_rows, 2);
        assert_eq!(m.row(0), &[1]);
        assert_eq!(m.row(1), &[0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(read_mtx_from(Cursor::new("hello\n1 1 1\n")).is_err());
        assert!(read_mtx_from(Cursor::new("%%MatrixMarket matrix array real general\n2 2\n")).is_err());
        let oob = "%%MatrixMarket matrix coordinate pattern general\n2 2 1\n3 1\n";
        assert!(read_mtx_from(Cursor::new(oob)).is_err());
    }

    #[test]
    fn rejects_malformed_headers_with_context() {
        let cases: &[(&str, &str)] = &[
            ("", "empty mtx file"),
            ("%%MatrixMarket matrix coordinate pattern general\n", "missing size line"),
            ("%%MatrixMarket matrix coordinate pattern general\n3 4\n", "bad size line"),
            ("%%MatrixMarket matrix coordinate pattern general\n3 x 4\n", "size line"),
            ("%%MatrixMarket matrix coordinate quaternion general\n1 1 1\n", "unsupported field"),
            ("%%MatrixMarket matrix coordinate real sideways\n1 1 1\n", "unsupported symmetry"),
            ("%%MatrixMarket tensor coordinate real general\n1 1 1\n", "MatrixMarket header"),
            ("%%MatrixMarket matrix array real general\n2 2\n", "coordinate"),
        ];
        for (txt, needle) in cases {
            let err = read_mtx_from(Cursor::new(*txt)).unwrap_err().to_string();
            assert!(err.contains(needle), "input {txt:?}: error {err:?} missing {needle:?}");
        }
    }

    #[test]
    fn rejects_bad_entries() {
        let one_token = "%%MatrixMarket matrix coordinate pattern general\n2 2 1\n1\n";
        let err = read_mtx_from(Cursor::new(one_token)).unwrap_err().to_string();
        assert!(err.contains("bad entry"), "{err}");
        let zero_based = "%%MatrixMarket matrix coordinate pattern general\n2 2 1\n0 1\n";
        let err = read_mtx_from(Cursor::new(zero_based)).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn oversized_dims_error_not_wrap() {
        // 2^32 rows: the old reader wrapped ids with `as u32`; now the
        // header is rejected with a contextual overflow error.
        let txt = "%%MatrixMarket matrix coordinate pattern general\n4294967296 2 1\n1 1\n";
        let err = read_mtx_from(Cursor::new(txt)).unwrap_err().to_string();
        assert!(err.contains("overflows the u32"), "got: {err}");
        assert!(err.contains("n_rows"), "got: {err}");
    }

    #[test]
    fn write_read_roundtrip() {
        let m = Csr::from_edges(3, 3, &[(0, 1), (1, 2), (2, 0), (0, 0)]);
        let p = tmp("rt.mtx");
        write_mtx(&m, &p).unwrap();
        let back = read_mtx(&p).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn streamed_equals_reference_across_chunk_sizes() {
        // An asymmetric pattern with duplicates and a trailing
        // comment, streamed at pathological chunk sizes so chunk
        // boundaries fall mid-line.
        let txt = "%%MatrixMarket matrix coordinate pattern general\n5 7 9\n1 1\n1 7\n2 3\n5 1\n5 1\n% mid comment\n3 4\n4 2\n1 7\n5 6\n";
        let p = tmp("chunks.mtx");
        std::fs::write(&p, txt).unwrap();
        let reference = read_mtx(&p).unwrap();
        let pool = WorkerPool::new(3);
        for chunk in [1u64, 2, 3, 5, 16, 1 << 20] {
            let streamed = stream_mtx_to_csr_chunked(&p, &pool, chunk).unwrap();
            assert_eq!(streamed, reference, "chunk_bytes = {chunk}");
        }
    }

    #[test]
    fn streamed_symmetric_equals_reference() {
        let txt = "%%MatrixMarket matrix coordinate real symmetric\n4 4 5\n1 1 0.5\n2 1 1.0\n3 2 2.0\n4 4 1\n4 1 9\n";
        let p = tmp("sym.mtx");
        std::fs::write(&p, txt).unwrap();
        let reference = read_mtx(&p).unwrap();
        let pool = WorkerPool::new(2);
        for chunk in [4u64, 1 << 20] {
            let streamed = stream_mtx_to_csr_chunked(&p, &pool, chunk).unwrap();
            assert_eq!(streamed, reference, "chunk_bytes = {chunk}");
        }
    }

    #[test]
    fn streamed_to_file_roundtrips() {
        let txt = "%%MatrixMarket matrix coordinate pattern general\n4 5 6\n1 2\n1 5\n2 1\n3 3\n4 4\n4 1\n";
        let p = tmp("tofile.mtx");
        std::fs::write(&p, txt).unwrap();
        let reference = read_mtx(&p).unwrap();
        let pool = WorkerPool::new(2);
        let out = tmp("tofile.csrb");
        let info = stream_mtx_to_file_chunked(&p, &out, &pool, 7).unwrap();
        assert_eq!(info.nnz, reference.nnz() as u64);
        assert_eq!(info.width, IndexWidth::U32);
        let opened = crate::graph::storage::open_csr(&out).unwrap();
        assert_eq!(opened, reference);
    }

    #[test]
    fn streaming_rejects_bad_data() {
        let txt = "%%MatrixMarket matrix coordinate pattern general\n2 2 2\n1 1\n9 1\n";
        let p = tmp("bad.mtx");
        std::fs::write(&p, txt).unwrap();
        let pool = WorkerPool::new(2);
        let err = stream_mtx_to_csr(&p, &pool).unwrap_err().to_string();
        assert!(err.contains("out of range"), "got: {err}");
    }

    #[test]
    fn header_reports_data_start() {
        let txt = "%%MatrixMarket matrix coordinate pattern general\n% c\n3 3 1\n1 1\n";
        let p = tmp("hdr.mtx");
        std::fs::write(&p, txt).unwrap();
        let h = read_mtx_header(&p).unwrap();
        assert_eq!(h.n_rows, 3);
        assert_eq!(h.declared_nnz, 1);
        assert!(!h.symmetric);
        assert_eq!(&txt[h.data_start as usize..], "1 1\n");
    }
}
