//! Matrix Market (`.mtx`) I/O.
//!
//! The paper's matrices come from the UFL/SuiteSparse collection in this
//! format; the reader accepts `coordinate` `pattern|real|integer` with
//! `general|symmetric` storage (values are ignored — coloring only needs
//! the pattern). The writer emits `pattern general`, good enough to
//! round-trip instances between tools.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

use super::csr::Csr;

/// Read a Matrix-Market coordinate file into a CSR pattern
/// (rows = nets when used for BGPC column coloring).
pub fn read_mtx(path: impl AsRef<Path>) -> Result<Csr> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {:?}", path.as_ref()))?;
    read_mtx_from(BufReader::new(f))
}

/// Reader-based variant (unit tests use in-memory buffers).
pub fn read_mtx_from(r: impl BufRead) -> Result<Csr> {
    let mut lines = r.lines();
    let header = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                if !l.trim().is_empty() {
                    break l;
                }
            }
            None => bail!("empty mtx file"),
        }
    };
    let h: Vec<String> = header.split_whitespace().map(|s| s.to_ascii_lowercase()).collect();
    if h.len() < 4 || h[0] != "%%matrixmarket" || h[1] != "matrix" {
        bail!("not a MatrixMarket header: {header}");
    }
    if h[2] != "coordinate" {
        bail!("only coordinate format supported, got {}", h[2]);
    }
    let field = h[3].as_str();
    if !matches!(field, "pattern" | "real" | "integer" | "complex") {
        bail!("unsupported field {field}");
    }
    let sym = match h.get(4).map(|s| s.as_str()) {
        None | Some("general") => false,
        Some("symmetric") | Some("skew-symmetric") | Some("hermitian") => true,
        Some(other) => bail!("unsupported symmetry {other}"),
    };

    // size line (skipping comments)
    let size_line = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                let t = l.trim();
                if t.is_empty() || t.starts_with('%') {
                    continue;
                }
                break l;
            }
            None => bail!("missing size line"),
        }
    };
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .take(3)
        .map(|t| t.parse().context("size line"))
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        bail!("bad size line: {size_line}");
    }
    let (n_rows, n_cols, nnz) = (dims[0], dims[1], dims[2]);

    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(if sym { 2 * nnz } else { nnz });
    for l in lines {
        let l = l?;
        let t = l.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (Some(rs), Some(cs)) = (it.next(), it.next()) else {
            bail!("bad entry line: {t}");
        };
        let r: usize = rs.parse().context("row index")?;
        let c: usize = cs.parse().context("col index")?;
        if r == 0 || c == 0 || r > n_rows || c > n_cols {
            bail!("index out of range: {r} {c} (1-based, {n_rows}x{n_cols})");
        }
        let (r, c) = (r as u32 - 1, c as u32 - 1);
        edges.push((r, c));
        if sym && r != c {
            edges.push((c, r));
        }
    }
    Ok(Csr::from_edges(n_rows, n_cols, &edges))
}

/// Write a CSR pattern as `coordinate pattern general`.
pub fn write_mtx(csr: &Csr, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("create {:?}", path.as_ref()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate pattern general")?;
    writeln!(w, "% written by bgpc")?;
    writeln!(w, "{} {} {}", csr.n_rows, csr.n_cols, csr.nnz())?;
    for r in 0..csr.n_rows {
        for &c in csr.row(r) {
            writeln!(w, "{} {}", r + 1, c + 1)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_general_pattern() {
        let txt = "%%MatrixMarket matrix coordinate pattern general\n% comment\n3 4 4\n1 1\n1 3\n2 2\n3 4\n";
        let m = read_mtx_from(Cursor::new(txt)).unwrap();
        assert_eq!(m.n_rows, 3);
        assert_eq!(m.n_cols, 4);
        assert_eq!(m.row(0), &[0, 2]);
        assert_eq!(m.row(2), &[3]);
    }

    #[test]
    fn parse_symmetric_real_mirrors() {
        let txt = "%%MatrixMarket matrix coordinate real symmetric\n3 3 3\n1 1 1.5\n2 1 2.0\n3 2 -1\n";
        let m = read_mtx_from(Cursor::new(txt)).unwrap();
        assert!(m.is_structurally_symmetric());
        assert_eq!(m.row(0), &[0, 1]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(read_mtx_from(Cursor::new("hello\n1 1 1\n")).is_err());
        assert!(read_mtx_from(Cursor::new("%%MatrixMarket matrix array real general\n2 2\n")).is_err());
        let oob = "%%MatrixMarket matrix coordinate pattern general\n2 2 1\n3 1\n";
        assert!(read_mtx_from(Cursor::new(oob)).is_err());
    }

    #[test]
    fn write_read_roundtrip() {
        let m = Csr::from_edges(3, 3, &[(0, 1), (1, 2), (2, 0), (0, 0)]);
        let dir = std::env::temp_dir().join("bgpc_mtx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.mtx");
        write_mtx(&m, &p).unwrap();
        let back = read_mtx(&p).unwrap();
        assert_eq!(back, m);
    }
}
