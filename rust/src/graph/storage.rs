//! Out-of-core CSR backing store: build-to-disk, then `mmap` read-only.
//!
//! The ingestion tier (DESIGN.md §15) decouples *where a CSR lives* from
//! *how the kernels read it*:
//!
//! * [`Buf`] — the heap-or-mapped backing behind [`Csr::ptr`] /
//!   [`Csr::adj`]. It derefs to a slice, so every kernel reads it exactly
//!   like the `Vec` it replaced; the first mutation of a mapped buffer
//!   materialises a private heap copy (copy-on-write at buffer
//!   granularity).
//! * [`IndexWidth`] — the explicit u32-or-u64 seam. On-disk files carry
//!   their width; conversions back into the u32 kernel id space are
//!   *checked* ([`checked_u32`] / [`checked_usize`]) and fail with a
//!   contextual error instead of silently truncating.
//! * `.csrb` files — a flat native-endian container (header, `u64` row
//!   pointers, u32-or-u64 adjacency) written by [`CsrWriter`] and opened
//!   by [`open_csr`]. On 64-bit unix targets `open_csr` maps the file and
//!   the returned [`Csr`] reads straight from the page cache; elsewhere
//!   it falls back to a checked heap copy.
//!
//! [`Csr::ptr`]: super::csr::Csr
//! [`Csr::adj`]: super::csr::Csr

use std::fs::{File, OpenOptions};
use std::io::Read;
use std::marker::PhantomData;
use std::ops::{Deref, DerefMut};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::bail;
use crate::util::error::{Context, Result};

use super::csr::Csr;

// ---------------------------------------------------------------------------
// Checked index conversions — the u64 story.
// ---------------------------------------------------------------------------

/// Convert a file-width id to the `u32` kernel id space, or fail with a
/// contextual error naming the offending value (never a silent `as` wrap).
#[inline]
pub fn checked_u32(v: u64, what: &str) -> Result<u32> {
    u32::try_from(v).map_err(|_| {
        crate::util::error::Error::msg(format!(
            "{what} {v} overflows the u32 kernel id space (max {})",
            u32::MAX
        ))
    })
}

/// Convert a file offset/count to `usize`, or fail with a contextual error
/// (relevant on 32-bit hosts opening u64-scale files).
#[inline]
pub fn checked_usize(v: u64, what: &str) -> Result<usize> {
    usize::try_from(v).map_err(|_| {
        crate::util::error::Error::msg(format!(
            "{what} {v} overflows usize on this host (max {})",
            usize::MAX
        ))
    })
}

/// Width of the adjacency ids in an on-disk CSR (the `ptr` array is always
/// stored as `u64`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexWidth {
    /// 4-byte ids — everything the in-memory kernels can color.
    U32,
    /// 8-byte ids — storable and stream-parsable; converting into the
    /// kernel [`Csr`] checks every id (errors on overflow, never wraps).
    U64,
}

impl IndexWidth {
    /// Bytes per adjacency id.
    #[inline]
    pub fn bytes(self) -> usize {
        match self {
            IndexWidth::U32 => 4,
            IndexWidth::U64 => 8,
        }
    }

    /// Smallest width that can hold ids below `n_rows`/`n_cols`.
    pub fn for_dims(n_rows: u64, n_cols: u64) -> IndexWidth {
        if n_rows <= u32::MAX as u64 && n_cols <= u32::MAX as u64 {
            IndexWidth::U32
        } else {
            IndexWidth::U64
        }
    }

    fn code(self) -> u32 {
        match self {
            IndexWidth::U32 => 4,
            IndexWidth::U64 => 8,
        }
    }

    fn from_code(c: u32) -> Result<IndexWidth> {
        match c {
            4 => Ok(IndexWidth::U32),
            8 => Ok(IndexWidth::U64),
            other => bail!("bad index width code {other} (expect 4 or 8)"),
        }
    }
}

// ---------------------------------------------------------------------------
// Mapping — a read-only or read-write byte mapping of a whole file.
//
// On 64-bit unix this is real mmap via the libc already linked by std (no
// external crates); elsewhere it degrades to an owned in-memory copy with
// the same API, so every caller is portable and only the *residency*
// differs.
// ---------------------------------------------------------------------------

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::ffi::c_void;
    use std::os::raw::c_int;

    pub const PROT_READ: c_int = 1;
    pub const PROT_WRITE: c_int = 2;
    pub const MAP_SHARED: c_int = 1;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// Whole-file byte mapping (see module docs for the fallback story).
pub struct Mapping {
    #[cfg(all(unix, target_pointer_width = "64"))]
    ptr: *mut u8,
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    buf: Vec<u8>,
    len: usize,
}

// SAFETY: the mapping is either private heap memory or a file mapping whose
// lifetime we own; `&Mapping` only hands out shared reads, and the one
// mutable accessor takes `&mut self`.
unsafe impl Send for Mapping {}
unsafe impl Sync for Mapping {}

impl Mapping {
    /// Map `len` bytes of `file` (shared, optionally writable).
    #[cfg(all(unix, target_pointer_width = "64"))]
    pub fn map(file: &File, len: usize, writable: bool) -> Result<Mapping> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            return Ok(Mapping { ptr: std::ptr::null_mut(), len: 0 });
        }
        let prot = if writable { sys::PROT_READ | sys::PROT_WRITE } else { sys::PROT_READ };
        // SAFETY: fd is a valid open file descriptor for the duration of
        // the call; we map the whole file shared at offset 0 and check the
        // MAP_FAILED sentinel before use.
        let p = unsafe {
            sys::mmap(std::ptr::null_mut(), len, prot, sys::MAP_SHARED, file.as_raw_fd(), 0)
        };
        if p as isize == -1 {
            bail!("mmap of {len} bytes failed: {}", std::io::Error::last_os_error());
        }
        Ok(Mapping { ptr: p as *mut u8, len })
    }

    /// Fallback: read `len` bytes of `file` into an owned buffer.
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    pub fn map(file: &File, len: usize, _writable: bool) -> Result<Mapping> {
        let mut buf = vec![0u8; len];
        let mut f = file;
        use std::io::Seek;
        f.seek(std::io::SeekFrom::Start(0)).context("seek for fallback mapping")?;
        f.read_exact(&mut buf).context("read for fallback mapping")?;
        Ok(Mapping { buf, len })
    }

    /// Mapped length in bytes.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing is mapped.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    #[inline]
    fn base(&self) -> *const u8 {
        self.ptr
    }

    #[cfg(not(all(unix, target_pointer_width = "64")))]
    #[inline]
    fn base(&self) -> *const u8 {
        self.buf.as_ptr()
    }

    /// The whole mapping as bytes.
    pub fn bytes(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: base()..base()+len is the live mapping (or owned buffer).
        unsafe { std::slice::from_raw_parts(self.base(), self.len) }
    }

    /// Typed view of `count` elements of `T` at byte offset `off`.
    /// Panics (debug) / errors on misalignment or out-of-range.
    fn typed<T: Copy>(&self, off: usize, count: usize) -> Result<&[T]> {
        let bytes = count
            .checked_mul(std::mem::size_of::<T>())
            .and_then(|b| b.checked_add(off))
            .context("typed view overflows")?;
        if bytes > self.len {
            bail!("typed view [{off}; {count}] past end of {}-byte mapping", self.len);
        }
        let p = if self.len == 0 {
            std::ptr::NonNull::<T>::dangling().as_ptr() as *const T
        } else {
            unsafe { self.base().add(off) as *const T }
        };
        if (p as usize) % std::mem::align_of::<T>() != 0 {
            bail!("typed view at offset {off} misaligned for {}", std::any::type_name::<T>());
        }
        // SAFETY: range-checked above; T: Copy with no invalid bit patterns
        // at the call sites (u32/u64/usize).
        Ok(unsafe { std::slice::from_raw_parts(p, count) })
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if self.len > 0 {
            // SAFETY: ptr/len are the live mapping created in `map`.
            unsafe {
                sys::munmap(self.ptr as *mut std::ffi::c_void, self.len);
            }
        }
    }
}

impl std::fmt::Debug for Mapping {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mapping({} bytes)", self.len)
    }
}

// ---------------------------------------------------------------------------
// Buf — heap-or-mapped backing with copy-on-write promotion.
// ---------------------------------------------------------------------------

struct MapSlice {
    map: Arc<Mapping>,
    byte_off: usize,
    len: usize,
}

impl Clone for MapSlice {
    fn clone(&self) -> MapSlice {
        MapSlice { map: Arc::clone(&self.map), byte_off: self.byte_off, len: self.len }
    }
}

/// A `Vec<T>`-shaped buffer that may be backed by a shared read-only file
/// mapping instead of the heap. Reads go through `Deref<Target = [T]>`
/// either way; the first mutable access of a mapped buffer copies it to
/// the heap ([`Buf::make_mut`]), so mutation keeps `Vec` semantics.
pub struct Buf<T: Copy + 'static> {
    vec: Vec<T>,
    map: Option<MapSlice>,
    _marker: PhantomData<T>,
}

impl<T: Copy + 'static> Buf<T> {
    /// An owned (heap) buffer.
    pub fn owned(vec: Vec<T>) -> Buf<T> {
        Buf { vec, map: None, _marker: PhantomData }
    }

    /// A buffer viewing `len` elements at `byte_off` inside `map`.
    pub(crate) fn mapped(map: Arc<Mapping>, byte_off: usize, len: usize) -> Result<Buf<T>> {
        // Validate once at construction so Deref can be unchecked.
        map.typed::<T>(byte_off, len)?;
        Ok(Buf {
            vec: Vec::new(),
            map: Some(MapSlice { map, byte_off, len }),
            _marker: PhantomData,
        })
    }

    /// True when the data lives in a file mapping (not the heap).
    pub fn is_mapped(&self) -> bool {
        self.map.is_some()
    }

    /// The elements as a slice (heap or mapped).
    #[inline]
    pub fn as_slice(&self) -> &[T] {
        match &self.map {
            Some(s) => {
                if s.len == 0 {
                    return &[];
                }
                // SAFETY: validated at construction (range + alignment);
                // the Arc keeps the mapping alive for &self's lifetime.
                unsafe {
                    std::slice::from_raw_parts(
                        s.map.base().add(s.byte_off) as *const T,
                        s.len,
                    )
                }
            }
            None => &self.vec,
        }
    }

    /// Promote to an owned heap vector (no-op when already owned) and
    /// return it mutably — the copy-on-write point.
    pub fn make_mut(&mut self) -> &mut Vec<T> {
        if self.map.is_some() {
            let copied = self.as_slice().to_vec();
            self.vec = copied;
            self.map = None;
        }
        &mut self.vec
    }

    /// Shorten to `len` elements (promotes a mapped buffer first).
    pub fn truncate(&mut self, len: usize) {
        self.make_mut().truncate(len);
    }
}

impl<T: Copy + 'static> Deref for Buf<T> {
    type Target = [T];
    #[inline]
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Copy + 'static> DerefMut for Buf<T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut [T] {
        self.make_mut().as_mut_slice()
    }
}

impl<T: Copy + 'static> From<Vec<T>> for Buf<T> {
    fn from(vec: Vec<T>) -> Buf<T> {
        Buf::owned(vec)
    }
}

impl<T: Copy + 'static> Clone for Buf<T> {
    fn clone(&self) -> Buf<T> {
        match &self.map {
            Some(s) => Buf { vec: Vec::new(), map: Some(s.clone()), _marker: PhantomData },
            None => Buf::owned(self.vec.clone()),
        }
    }
}

impl<T: Copy + PartialEq + 'static> PartialEq for Buf<T> {
    fn eq(&self, other: &Buf<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Copy + Eq + 'static> Eq for Buf<T> {}

impl<T: Copy + std::fmt::Debug + 'static> std::fmt::Debug for Buf<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        std::fmt::Debug::fmt(self.as_slice(), f)
    }
}

impl<T: Copy + 'static> Default for Buf<T> {
    fn default() -> Buf<T> {
        Buf::owned(Vec::new())
    }
}

// ---------------------------------------------------------------------------
// The .csrb on-disk format.
//
//   offset  size  field
//   0       8     magic  "BGPCCSR1"
//   8       4     endianness marker 0x01020304 (native-endian files only)
//   12      4     adjacency id width in bytes (4 | 8)
//   16      8     n_rows  (u64)
//   24      8     n_cols  (u64)
//   32      8     nnz     (u64)
//   40      8*(n_rows+1)        row pointers (u64)
//   ...     nnz*width           adjacency ids (u32 | u64)
//
// Everything is naturally aligned because the header is 40 bytes and the
// ptr region is 8-byte elements.
// ---------------------------------------------------------------------------

const MAGIC: [u8; 8] = *b"BGPCCSR1";
const ENDIAN_MARK: u32 = 0x0102_0304;
const HEADER_LEN: usize = 40;

#[derive(Clone, Copy, Debug)]
struct Header {
    width: IndexWidth,
    n_rows: u64,
    n_cols: u64,
    nnz: u64,
}

impl Header {
    fn encode(&self) -> [u8; HEADER_LEN] {
        let mut h = [0u8; HEADER_LEN];
        h[0..8].copy_from_slice(&MAGIC);
        h[8..12].copy_from_slice(&ENDIAN_MARK.to_ne_bytes());
        h[12..16].copy_from_slice(&self.width.code().to_ne_bytes());
        h[16..24].copy_from_slice(&self.n_rows.to_ne_bytes());
        h[24..32].copy_from_slice(&self.n_cols.to_ne_bytes());
        h[32..40].copy_from_slice(&self.nnz.to_ne_bytes());
        h
    }

    fn decode(h: &[u8]) -> Result<Header> {
        if h.len() < HEADER_LEN {
            bail!("csrb file shorter than its {HEADER_LEN}-byte header");
        }
        if h[0..8] != MAGIC {
            bail!("not a bgpc csrb file (bad magic)");
        }
        let mark = u32::from_ne_bytes(h[8..12].try_into().unwrap());
        if mark != ENDIAN_MARK {
            bail!("csrb file written on a foreign-endian host (marker {mark:#010x})");
        }
        let width = IndexWidth::from_code(u32::from_ne_bytes(h[12..16].try_into().unwrap()))?;
        Ok(Header {
            width,
            n_rows: u64::from_ne_bytes(h[16..24].try_into().unwrap()),
            n_cols: u64::from_ne_bytes(h[24..32].try_into().unwrap()),
            nnz: u64::from_ne_bytes(h[32..40].try_into().unwrap()),
        })
    }

    fn ptr_off(&self) -> usize {
        HEADER_LEN
    }

    fn adj_off(&self) -> Result<usize> {
        let rows = checked_usize(self.n_rows, "n_rows")?;
        Ok(HEADER_LEN + 8 * (rows + 1))
    }

    fn file_len(&self) -> Result<usize> {
        let adj = checked_usize(self.nnz, "nnz")?
            .checked_mul(self.width.bytes())
            .context("adjacency byte size overflows")?;
        self.adj_off()?.checked_add(adj).context("csrb file size overflows")
    }
}

// ---------------------------------------------------------------------------
// CsrWriter — build a .csrb on disk with direct (optionally parallel)
// placement into the writable mapping.
// ---------------------------------------------------------------------------

/// Shared raw slot array for disjoint-index parallel placement writes.
/// Each slot must be written by exactly one thread (the atomic row
/// cursors in the streaming parser guarantee disjointness).
pub(crate) struct SharedSlots<T> {
    base: *mut T,
    len: usize,
}

// SAFETY: only `write` is exposed and callers guarantee disjoint indices;
// the underlying region outlives the parallel section (owned by CsrWriter).
unsafe impl<T> Send for SharedSlots<T> {}
unsafe impl<T> Sync for SharedSlots<T> {}

impl<T> SharedSlots<T> {
    /// View an exclusive slice as shared disjoint slots. The raw pointer
    /// outlives the borrow — callers must keep the slice allocation
    /// alive and un-reallocated for the slots' useful lifetime.
    pub(crate) fn from_mut_slice(s: &mut [T]) -> SharedSlots<T> {
        SharedSlots { base: s.as_mut_ptr(), len: s.len() }
    }

    /// Write `v` into slot `i` (always bounds-checked: an overrun is a
    /// panic, never a stray write).
    ///
    /// # Safety
    /// No other thread may concurrently access slot `i`, and the backing
    /// allocation must still be alive.
    #[inline]
    pub(crate) unsafe fn write(&self, i: usize, v: T) {
        assert!(i < self.len, "SharedSlots overrun: {i} >= {}", self.len);
        // SAFETY: in-range per the assert; caller guarantees exclusive
        // slot access and liveness.
        unsafe {
            self.base.add(i).write(v);
        }
    }
}

/// Streaming `.csrb` builder: size the file up front, place pointers and
/// adjacency directly into a shared writable mapping (the OS pages it
/// out), then [`CsrWriter::finish`] compacts the header/ptr for the final
/// (post-dedup) nnz and truncates.
pub struct CsrWriter {
    file: File,
    path: PathBuf,
    region: Mapping,
    header: Header,
}

impl CsrWriter {
    /// Create `path` sized for `nnz` adjacency ids of `width`.
    pub fn create(
        path: impl AsRef<Path>,
        n_rows: u64,
        n_cols: u64,
        nnz: u64,
        width: IndexWidth,
    ) -> Result<CsrWriter> {
        let path = path.as_ref().to_path_buf();
        let header = Header { width, n_rows, n_cols, nnz };
        let len = header.file_len()?;
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("create {path:?}"))?;
        file.set_len(len as u64).with_context(|| format!("size {path:?} to {len} bytes"))?;
        let mut region = Mapping::map(&file, len, true)?;
        write_at(&mut region, 0, &header.encode());
        Ok(CsrWriter { file, path, region, header })
    }

    /// The row-pointer array (`n_rows + 1` entries, element `0` must be 0).
    pub fn ptr_mut(&mut self) -> &mut [u64] {
        let off = self.header.ptr_off();
        let rows = self.header.n_rows as usize;
        // SAFETY: the region covers header + ptr + adj by construction;
        // alignment holds (off = 40, 8-aligned on a page-aligned base);
        // exclusivity via &mut self.
        unsafe {
            std::slice::from_raw_parts_mut(self.region.base_mut().add(off) as *mut u64, rows + 1)
        }
    }

    /// The adjacency array as u32 slots (width must be [`IndexWidth::U32`]).
    pub fn adj_mut_u32(&mut self) -> &mut [u32] {
        assert_eq!(self.header.width, IndexWidth::U32, "adj width is not u32");
        let off = self.header.adj_off().expect("sized at create");
        // SAFETY: as ptr_mut; 4-aligned because adj_off is 8*(rows+1)+40.
        unsafe {
            std::slice::from_raw_parts_mut(
                self.region.base_mut().add(off) as *mut u32,
                self.header.nnz as usize,
            )
        }
    }

    /// The adjacency array as u64 slots (width must be [`IndexWidth::U64`]).
    pub fn adj_mut_u64(&mut self) -> &mut [u64] {
        assert_eq!(self.header.width, IndexWidth::U64, "adj width is not u64");
        let off = self.header.adj_off().expect("sized at create");
        // SAFETY: as ptr_mut.
        unsafe {
            std::slice::from_raw_parts_mut(
                self.region.base_mut().add(off) as *mut u64,
                self.header.nnz as usize,
            )
        }
    }

    /// Raw disjoint-slot view of the u32 adjacency for parallel placement.
    pub(crate) fn adj_slots_u32(&mut self) -> SharedSlots<u32> {
        let s = self.adj_mut_u32();
        SharedSlots { base: s.as_mut_ptr(), len: s.len() }
    }

    /// Raw disjoint-slot view of the u64 adjacency for parallel placement.
    pub(crate) fn adj_slots_u64(&mut self) -> SharedSlots<u64> {
        let s = self.adj_mut_u64();
        SharedSlots { base: s.as_mut_ptr(), len: s.len() }
    }

    /// Declared capacity (pre-dedup nnz) of the adjacency region.
    pub fn capacity(&self) -> u64 {
        self.header.nnz
    }

    /// Finalise with the post-compaction `final_nnz` (≤ the capacity the
    /// file was created with), truncate the tail, and flush.
    pub fn finish(mut self, final_nnz: u64) -> Result<PathBuf> {
        if final_nnz > self.header.nnz {
            bail!("finish({final_nnz}) exceeds created capacity {}", self.header.nnz);
        }
        self.header.nnz = final_nnz;
        let enc = self.header.encode();
        write_at(&mut self.region, 0, &enc);
        let final_len = self.header.file_len()?;
        // Persist the fallback buffer before truncating; on the mmap path
        // the kernel already owns the dirty pages.
        self.flush_fallback()?;
        // Unmap before shrinking the file (accessing a mapping past EOF is
        // a bus error on unix).
        let file = self.file;
        let path = self.path;
        drop(self.region);
        file.set_len(final_len as u64)
            .with_context(|| format!("truncate {path:?} to {final_len} bytes"))?;
        file.sync_all().with_context(|| format!("sync {path:?}"))?;
        Ok(path)
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    fn flush_fallback(&mut self) -> Result<()> {
        Ok(())
    }

    #[cfg(not(all(unix, target_pointer_width = "64")))]
    fn flush_fallback(&mut self) -> Result<()> {
        use std::io::{Seek, SeekFrom, Write};
        self.file.seek(SeekFrom::Start(0)).context("seek for csrb flush")?;
        self.file.write_all(self.region.bytes()).context("write csrb buffer")?;
        Ok(())
    }
}

impl Mapping {
    #[cfg(all(unix, target_pointer_width = "64"))]
    #[inline]
    fn base_mut(&mut self) -> *mut u8 {
        self.ptr
    }

    #[cfg(not(all(unix, target_pointer_width = "64")))]
    #[inline]
    fn base_mut(&mut self) -> *mut u8 {
        self.buf.as_mut_ptr()
    }
}

fn write_at(region: &mut Mapping, off: usize, bytes: &[u8]) {
    assert!(off + bytes.len() <= region.len());
    // SAFETY: in-range per the assert; exclusive via &mut.
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), region.base_mut().add(off), bytes.len());
    }
}

// ---------------------------------------------------------------------------
// Opening.
// ---------------------------------------------------------------------------

/// Shape of an on-disk CSR, readable without loading the payload.
#[derive(Clone, Copy, Debug)]
pub struct CsrFileInfo {
    /// Row count.
    pub n_rows: u64,
    /// Column-id space size.
    pub n_cols: u64,
    /// Stored edges.
    pub nnz: u64,
    /// Adjacency id width.
    pub width: IndexWidth,
}

/// Read just the header of a `.csrb` file.
pub fn csr_file_info(path: impl AsRef<Path>) -> Result<CsrFileInfo> {
    let mut f = File::open(path.as_ref()).with_context(|| format!("open {:?}", path.as_ref()))?;
    let mut h = [0u8; HEADER_LEN];
    f.read_exact(&mut h).with_context(|| format!("read header of {:?}", path.as_ref()))?;
    let header = Header::decode(&h)?;
    Ok(CsrFileInfo {
        n_rows: header.n_rows,
        n_cols: header.n_cols,
        nnz: header.nnz,
        width: header.width,
    })
}

/// Open a `.csrb` file as a [`Csr`].
///
/// * U32 files on a 64-bit unix host: zero-copy — `ptr` and `adj` stay in
///   the shared read-only mapping ([`Buf::is_mapped`] is true).
/// * U64 files: the adjacency is converted id-by-id through
///   [`checked_u32`]; any id past `u32::MAX` fails with a contextual
///   error (the kernels are u32-wide — see DESIGN.md §15).
/// * Dimensions past `u32::MAX` rows/cols fail the same way: the coloring
///   kernels address vertices as u32.
pub fn open_csr(path: impl AsRef<Path>) -> Result<Csr> {
    let path = path.as_ref();
    let file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let meta = file.metadata().with_context(|| format!("stat {path:?}"))?;
    let len = checked_usize(meta.len(), "file length")?;
    let map = Arc::new(Mapping::map(&file, len, false)?);
    let header = Header::decode(map.bytes())
        .with_context(|| format!("parse csrb header of {path:?}"))?;
    let want = header.file_len()?;
    if len < want {
        bail!("{path:?} truncated: {len} bytes on disk, header implies {want}");
    }
    // The in-memory kernels address rows/cols as u32.
    checked_u32(header.n_rows, "n_rows").with_context(|| format!("open {path:?}"))?;
    checked_u32(header.n_cols, "n_cols").with_context(|| format!("open {path:?}"))?;
    let n_rows = checked_usize(header.n_rows, "n_rows")?;
    let n_cols = checked_usize(header.n_cols, "n_cols")?;
    let nnz = checked_usize(header.nnz, "nnz")?;

    // Row pointers: stored u64; on 64-bit hosts view them as usize
    // in place, otherwise copy with per-element checks.
    let ptr: Buf<usize> = ptr_buf(&map, &header, n_rows, nnz)?;

    let adj: Buf<u32> = match header.width {
        IndexWidth::U32 => Buf::mapped(Arc::clone(&map), header.adj_off()?, nnz)?,
        IndexWidth::U64 => {
            let wide: &[u64] = map.typed(header.adj_off()?, nnz)?;
            let mut narrow = Vec::with_capacity(nnz);
            for (i, &v) in wide.iter().enumerate() {
                narrow.push(
                    checked_u32(v, "adjacency id")
                        .with_context(|| format!("{path:?} adj[{i}]"))?,
                );
            }
            Buf::owned(narrow)
        }
    };
    let csr = Csr { n_rows, n_cols, ptr, adj };
    csr.validate().map_err(crate::util::error::Error::msg)?;
    Ok(csr)
}

#[cfg(target_pointer_width = "64")]
fn ptr_buf(map: &Arc<Mapping>, header: &Header, n_rows: usize, nnz: usize) -> Result<Buf<usize>> {
    // usize == u64 here: reinterpret the stored u64 pointers in place.
    let buf: Buf<usize> = Buf::mapped(Arc::clone(map), header.ptr_off(), n_rows + 1)?;
    if buf.last().copied() != Some(nnz) {
        bail!("csrb ptr tail {:?} != nnz {nnz}", buf.last());
    }
    Ok(buf)
}

#[cfg(not(target_pointer_width = "64"))]
fn ptr_buf(map: &Arc<Mapping>, header: &Header, n_rows: usize, nnz: usize) -> Result<Buf<usize>> {
    let wide: &[u64] = map.typed(header.ptr_off(), n_rows + 1)?;
    let mut out = Vec::with_capacity(n_rows + 1);
    for (i, &v) in wide.iter().enumerate() {
        out.push(checked_usize(v, "row pointer").with_context(|| format!("ptr[{i}]"))?);
    }
    if out.last().copied() != Some(nnz) {
        bail!("csrb ptr tail {:?} != nnz {nnz}", out.last());
    }
    Ok(Buf::owned(out))
}

/// Write a heap [`Csr`] as a `.csrb` file (u32 adjacency).
pub fn write_csr(csr: &Csr, path: impl AsRef<Path>) -> Result<PathBuf> {
    let mut w = CsrWriter::create(
        path,
        csr.n_rows as u64,
        csr.n_cols as u64,
        csr.nnz() as u64,
        IndexWidth::U32,
    )?;
    {
        let ptr = w.ptr_mut();
        for (i, &p) in csr.ptr.iter().enumerate() {
            ptr[i] = p as u64;
        }
    }
    w.adj_mut_u32().copy_from_slice(&csr.adj);
    w.finish(csr.nnz() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("bgpc_storage_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample() -> Csr {
        Csr::from_edges(4, 5, &[(0, 1), (0, 4), (1, 0), (2, 2), (2, 3), (2, 1), (3, 0)])
    }

    #[test]
    fn roundtrip_u32_mapped() {
        let g = sample();
        let p = tmp("rt_u32.csrb");
        write_csr(&g, &p).unwrap();
        let back = open_csr(&p).unwrap();
        assert_eq!(back, g);
        #[cfg(all(unix, target_pointer_width = "64"))]
        assert!(back.adj.is_mapped(), "u32 adjacency should stay mapped");
        back.validate().unwrap();
    }

    #[test]
    fn info_reads_header_only() {
        let g = sample();
        let p = tmp("info.csrb");
        write_csr(&g, &p).unwrap();
        let info = csr_file_info(&p).unwrap();
        assert_eq!(info.n_rows, 4);
        assert_eq!(info.n_cols, 5);
        assert_eq!(info.nnz, g.nnz() as u64);
        assert_eq!(info.width, IndexWidth::U32);
    }

    #[test]
    fn u64_file_converts_checked() {
        // Small ids stored wide: opening converts through checked_u32.
        let p = tmp("wide_ok.csrb");
        let mut w = CsrWriter::create(&p, 2, 3, 3, IndexWidth::U64).unwrap();
        w.ptr_mut().copy_from_slice(&[0, 2, 3]);
        w.adj_mut_u64().copy_from_slice(&[0, 2, 1]);
        w.finish(3).unwrap();
        let g = open_csr(&p).unwrap();
        assert_eq!(g.row(0), &[0, 2]);
        assert_eq!(g.row(1), &[1]);
        assert!(!g.adj.is_mapped(), "wide adjacency is heap-converted");
    }

    #[test]
    fn u64_adj_overflow_rejected_with_context() {
        // Dims fit u32, but one stored id does not: the per-id checked
        // conversion must fail (never wrap).
        let p = tmp("wide_overflow.csrb");
        let mut w = CsrWriter::create(&p, 1, 2, 1, IndexWidth::U64).unwrap();
        w.ptr_mut().copy_from_slice(&[0, 1]);
        w.adj_mut_u64()[0] = u32::MAX as u64 + 7;
        w.finish(1).unwrap();
        let err = open_csr(&p).unwrap_err().to_string();
        assert!(err.contains("overflows the u32"), "got: {err}");
        assert!(err.contains("adj[0]"), "got: {err}");
    }

    #[test]
    fn oversized_dims_rejected() {
        let p = tmp("wide_rows.csrb");
        let w = CsrWriter::create(&p, u32::MAX as u64 + 2, 1, 0, IndexWidth::U64).unwrap();
        // ptr is (n_rows + 1) zeros already; finish with 0 edges.
        w.finish(0).unwrap();
        let err = open_csr(&p).unwrap_err().to_string();
        assert!(err.contains("overflows the u32"), "got: {err}");
    }

    #[test]
    fn garbage_and_truncation_rejected() {
        let p = tmp("garbage.csrb");
        std::fs::write(&p, b"definitely not a csrb file").unwrap();
        assert!(open_csr(&p).unwrap_err().to_string().contains("header"));

        let g = sample();
        let p2 = tmp("trunc.csrb");
        write_csr(&g, &p2).unwrap();
        let full = std::fs::read(&p2).unwrap();
        std::fs::write(&p2, &full[..full.len() - 4]).unwrap();
        assert!(open_csr(&p2).unwrap_err().to_string().contains("truncated"));
    }

    #[test]
    fn buf_copy_on_write() {
        let g = sample();
        let p = tmp("cow.csrb");
        write_csr(&g, &p).unwrap();
        let mut back = open_csr(&p).unwrap();
        // mutate through the seam: promotes to heap, file untouched
        back.sort_dedup_rows();
        assert!(!back.adj.is_mapped());
        assert_eq!(back, g);
        let again = open_csr(&p).unwrap();
        assert_eq!(again, g);
    }

    #[test]
    fn width_for_dims() {
        assert_eq!(IndexWidth::for_dims(10, 10), IndexWidth::U32);
        assert_eq!(IndexWidth::for_dims(u32::MAX as u64 + 1, 1), IndexWidth::U64);
        assert_eq!(checked_u32(7, "x").unwrap(), 7);
        assert!(checked_u32(u32::MAX as u64 + 1, "x").is_err());
    }
}
