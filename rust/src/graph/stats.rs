//! Degree statistics — regenerates the "Properties" block of Table II.

use super::bipartite::Bipartite;
use crate::util::stats::{mean, stddev};

/// Shape statistics of a BGPC instance (Table II columns 2–6).
#[derive(Clone, Debug)]
pub struct InstanceStats {
    pub n_nets: usize,
    pub n_vertices: usize,
    pub nnz: usize,
    pub max_vertex_deg: usize,
    pub vertex_deg_stddev: f64,
    pub max_net_deg: usize,
    pub avg_net_deg: f64,
    /// `Σ_v |vtxs(v)|²` — drives vertex-based first-iteration cost.
    pub net_sq_sum: u64,
}

impl InstanceStats {
    pub fn compute(g: &Bipartite) -> InstanceStats {
        let vdegs: Vec<f64> = (0..g.n_vertices())
            .map(|u| g.nets(u).len() as f64)
            .collect();
        let ndegs: Vec<f64> = (0..g.n_nets()).map(|v| g.vtxs(v).len() as f64).collect();
        InstanceStats {
            n_nets: g.n_nets(),
            n_vertices: g.n_vertices(),
            nnz: g.nnz(),
            max_vertex_deg: g.vtx_nets.max_deg(),
            vertex_deg_stddev: stddev(&vdegs),
            max_net_deg: g.net_vtxs.max_deg(),
            avg_net_deg: mean(&ndegs),
            net_sq_sum: g.net_sq_sum(),
        }
    }

    /// One Table-II-style row: rows, cols, nnz, max col deg, col deg stddev.
    pub fn table_row(&self, name: &str) -> String {
        format!(
            "{name:<16} {:>9} {:>9} {:>10} {:>7} {:>10.2}",
            self.n_nets, self.n_vertices, self.nnz, self.max_vertex_deg, self.vertex_deg_stddev
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;

    #[test]
    fn stats_match_hand_counts() {
        // nets: {0,1}, {1,2,3}
        let m = Csr::from_edges(2, 4, &[(0, 0), (0, 1), (1, 1), (1, 2), (1, 3)]);
        let g = Bipartite::from_net_incidence(m);
        let s = InstanceStats::compute(&g);
        assert_eq!(s.n_nets, 2);
        assert_eq!(s.n_vertices, 4);
        assert_eq!(s.nnz, 5);
        assert_eq!(s.max_vertex_deg, 2); // vertex 1 in both nets
        assert_eq!(s.max_net_deg, 3);
        assert_eq!(s.net_sq_sum, 4 + 9);
        assert!((s.avg_net_deg - 2.5).abs() < 1e-12);
    }
}
