//! Graph substrate: CSR storage, bipartite views, Matrix-Market I/O,
//! calibrated synthetic generators, orderings and shape statistics.

pub mod bipartite;
pub mod csr;
pub mod generators;
pub mod mtx;
pub mod ordering;
pub mod source;
pub mod stats;
pub mod storage;

pub use bipartite::Bipartite;
pub use csr::Csr;
pub use generators::{Preset, PRESETS};
pub use ordering::Ordering;
pub use source::GraphSource;
pub use stats::InstanceStats;
pub use storage::{open_csr, write_csr, Buf, IndexWidth};
