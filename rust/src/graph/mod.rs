//! Graph substrate: CSR storage, bipartite views, Matrix-Market I/O,
//! calibrated synthetic generators, orderings and shape statistics.

pub mod bipartite;
pub mod csr;
pub mod generators;
pub mod mtx;
pub mod ordering;
pub mod stats;

pub use bipartite::Bipartite;
pub use csr::Csr;
pub use generators::{Preset, PRESETS};
pub use ordering::Ordering;
pub use stats::InstanceStats;
