//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas net-step
//! artifacts from Rust. Python runs only at `make artifacts` time; this
//! module is the entire accelerator story on the request path.

pub mod offload;
pub mod pjrt;

pub use offload::{step_rows_native, NetStepOffload};
pub use pjrt::{Bucket, Runtime};
