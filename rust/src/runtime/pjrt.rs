//! PJRT client + artifact registry.
//!
//! Artifacts are HLO *text* (`artifacts/net_step_b{B}_k{K}.hlo.txt`),
//! produced once by `python/compile/aot.py`. Text is the interchange
//! format because jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction
//! ids that the crate's xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §3).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One compiled `(B, K)` bucket of the net-step executable.
pub struct Bucket {
    pub b: usize,
    pub k: usize,
    exe: xla::PjRtLoadedExecutable,
}

impl Bucket {
    /// Execute the fused conflict-removal + recolor step on a padded
    /// batch. `colors` is row-major `[B, K]`, `degs` is `[B]` (0 pads).
    /// Returns `(new_colors, keep)` both `[B, K]` row-major.
    pub fn step(&self, colors: &[i32], degs: &[i32]) -> Result<(Vec<i32>, Vec<i32>)> {
        if colors.len() != self.b * self.k || degs.len() != self.b {
            bail!(
                "bucket b={} k={}: got colors {} degs {}",
                self.b,
                self.k,
                colors.len(),
                degs.len()
            );
        }
        let colors_lit =
            xla::Literal::vec1(colors).reshape(&[self.b as i64, self.k as i64])?;
        let degs_lit = xla::Literal::vec1(degs);
        let result = self.exe.execute::<xla::Literal>(&[colors_lit, degs_lit])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (new_colors, keep)
        let (new_colors, keep) = result.to_tuple2()?;
        Ok((new_colors.to_vec::<i32>()?, keep.to_vec::<i32>()?))
    }
}

/// A PJRT CPU client plus every bucket found in the artifacts directory.
pub struct Runtime {
    pub platform: String,
    buckets: Vec<Bucket>,
}

impl Runtime {
    /// Default artifacts directory: `$BGPC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("BGPC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Load every `net_step_b{B}_k{K}.hlo.txt` under `dir` and compile it
    /// on a fresh PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut buckets = Vec::new();
        let entries = std::fs::read_dir(dir)
            .with_context(|| format!("read artifacts dir {dir:?} (run `make artifacts`)"))?;
        for e in entries {
            let path = e?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            let Some((b, k)) = parse_bucket_name(name) else {
                continue;
            };
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parse HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compile {name}"))?;
            buckets.push(Bucket { b, k, exe });
        }
        if buckets.is_empty() {
            bail!("no net_step_b*_k*.hlo.txt artifacts in {dir:?} (run `make artifacts`)");
        }
        buckets.sort_by_key(|b| b.k);
        Ok(Runtime { platform: client.platform_name(), buckets })
    }

    /// All buckets, sorted by K ascending.
    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    /// Smallest bucket whose K fits degree `deg`, if any.
    pub fn bucket_for(&self, deg: usize) -> Option<&Bucket> {
        self.buckets.iter().find(|b| b.k >= deg)
    }

    /// Largest available K (nets above this stay on the native path).
    pub fn max_k(&self) -> usize {
        self.buckets.last().map(|b| b.k).unwrap_or(0)
    }
}

/// Parse `net_step_b{B}_k{K}.hlo.txt` → `(B, K)`.
pub fn parse_bucket_name(name: &str) -> Option<(usize, usize)> {
    let rest = name.strip_prefix("net_step_b")?;
    let rest = rest.strip_suffix(".hlo.txt")?;
    let (b, k) = rest.split_once("_k")?;
    Some((b.parse().ok()?, k.parse().ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_name_parsing() {
        assert_eq!(parse_bucket_name("net_step_b512_k32.hlo.txt"), Some((512, 32)));
        assert_eq!(parse_bucket_name("net_step_b1_k1.hlo.txt"), Some((1, 1)));
        assert_eq!(parse_bucket_name("manifest.json"), None);
        assert_eq!(parse_bucket_name("net_step_bx_k1.hlo.txt"), None);
    }
}
