//! PJRT-artifact runtime: load the AOT-compiled JAX/Pallas net-step
//! artifacts and execute them from Rust.
//!
//! Artifacts are HLO *text* (`artifacts/net_step_b{B}_k{K}.hlo.txt`),
//! produced once by `python/compile/aot.py` (`make artifacts`). Text is
//! the interchange format because jax ≥ 0.5 emits HloModuleProtos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (DESIGN.md §3).
//!
//! Execution backend: the offline build resolves no `xla` crate, so
//! [`Bucket::step`] runs the artifact's semantics through the bit-exact
//! native mirror of the kernel ([`super::offload::step_rows_native`] /
//! [`super::offload::keep_rows_native`] — the same functions the
//! integration tests pin the kernel against). `Runtime::load` still
//! validates the real artifact files (presence, HLO-text header, bucket
//! shape), so the artifact pipeline is exercised end to end; swapping in
//! the FFI-backed PJRT client is a drop-in change confined to
//! [`Bucket::step`] (DESIGN.md §3 documents the seam).

use std::path::{Path, PathBuf};

use crate::bail;
use crate::util::error::{Context, Result};

/// One compiled `(B, K)` bucket of the net-step executable.
pub struct Bucket {
    pub b: usize,
    pub k: usize,
    /// The HLO text artifact this bucket was loaded from.
    path: PathBuf,
}

impl Bucket {
    /// Execute the fused conflict-removal + recolor step on a padded
    /// batch. `colors` is row-major `[B, K]`, `degs` is `[B]` (0 pads).
    /// Returns `(new_colors, keep)`: `new_colors` is `[B, K]` row-major,
    /// `keep` marks the first occurrence of each color per row (the
    /// kernel's Alg. 7 output; `aot.py` lowers with `return_tuple=True`).
    pub fn step(&self, colors: &[i32], degs: &[i32]) -> Result<(Vec<i32>, Vec<i32>)> {
        if colors.len() != self.b * self.k || degs.len() != self.b {
            bail!(
                "bucket b={} k={}: got colors {} degs {}",
                self.b,
                self.k,
                colors.len(),
                degs.len()
            );
        }
        let keep = super::offload::keep_rows_native(colors, degs, self.k);
        let mut new_colors = colors.to_vec();
        super::offload::step_rows_native(&mut new_colors, degs, self.k);
        Ok((new_colors, keep))
    }

    /// Path of the backing artifact (diagnostics).
    pub fn artifact_path(&self) -> &Path {
        &self.path
    }
}

/// The runtime: every bucket found in the artifacts directory.
pub struct Runtime {
    pub platform: String,
    buckets: Vec<Bucket>,
}

impl Runtime {
    /// Default artifacts directory: `$BGPC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("BGPC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Load and validate every `net_step_b{B}_k{K}.hlo.txt` under `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        let mut buckets = Vec::new();
        let entries = std::fs::read_dir(dir)
            .with_context(|| format!("read artifacts dir {dir:?} (run `make artifacts`)"))?;
        for e in entries {
            let path = e?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            let Some((b, k)) = parse_bucket_name(name) else {
                continue;
            };
            if b == 0 || k == 0 {
                bail!("degenerate bucket shape in artifact name {name}");
            }
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("read HLO text {path:?}"))?;
            // map_err keeps the Error chain intact (the blanket Context
            // impl would flatten it through Display)
            validate_hlo_text(&text, b, k)
                .map_err(|e| e.context(format!("parse HLO text {path:?}")))?;
            buckets.push(Bucket { b, k, path });
        }
        if buckets.is_empty() {
            bail!("no net_step_b*_k*.hlo.txt artifacts in {dir:?} (run `make artifacts`)");
        }
        buckets.sort_by_key(|b| b.k);
        Ok(Runtime { platform: "cpu (native mirror)".to_string(), buckets })
    }

    /// All buckets, sorted by K ascending.
    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    /// Smallest bucket whose K fits degree `deg`, if any.
    pub fn bucket_for(&self, deg: usize) -> Option<&Bucket> {
        self.buckets.iter().find(|b| b.k >= deg)
    }

    /// Largest available K (nets above this stay on the native path).
    pub fn max_k(&self) -> usize {
        self.buckets.last().map(|b| b.k).unwrap_or(0)
    }
}

/// Parse `net_step_b{B}_k{K}.hlo.txt` → `(B, K)`.
pub fn parse_bucket_name(name: &str) -> Option<(usize, usize)> {
    let rest = name.strip_prefix("net_step_b")?;
    let rest = rest.strip_suffix(".hlo.txt")?;
    let (b, k) = rest.split_once("_k")?;
    Some((b.parse().ok()?, k.parse().ok()?))
}

/// Structural sanity check on an HLO text artifact: non-empty, has an
/// `HloModule` header, an entry computation, and — when the header
/// declares an entry layout — an `s32[B, K]` operand matching the
/// filename-derived bucket shape (catches renamed/stale artifacts).
fn validate_hlo_text(text: &str, b: usize, k: usize) -> Result<()> {
    let mut lines = text.lines().map(str::trim).filter(|l| !l.is_empty());
    match lines.next() {
        Some(first) if first.starts_with("HloModule") => {}
        Some(first) => bail!("expected HloModule header, got {first:?}"),
        None => bail!("empty artifact"),
    }
    if !text.contains("ENTRY") {
        bail!("no ENTRY computation in artifact");
    }
    if text.contains("entry_computation_layout") {
        let want = format!("s32[{b},{k}]");
        if !text.contains(&want) {
            bail!("artifact does not declare a {want} operand (bucket/filename mismatch)");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_name_parsing() {
        assert_eq!(parse_bucket_name("net_step_b512_k32.hlo.txt"), Some((512, 32)));
        assert_eq!(parse_bucket_name("net_step_b1_k1.hlo.txt"), Some((1, 1)));
        assert_eq!(parse_bucket_name("manifest.json"), None);
        assert_eq!(parse_bucket_name("net_step_bx_k1.hlo.txt"), None);
    }

    #[test]
    fn hlo_text_validation() {
        assert!(validate_hlo_text("HloModule m\n\nENTRY main {\n}\n", 2, 4).is_ok());
        assert!(validate_hlo_text("", 2, 4).is_err());
        assert!(validate_hlo_text("garbage\nENTRY x", 2, 4).is_err());
        assert!(validate_hlo_text("HloModule m\nno entry here\n", 2, 4).is_err());
        // declared entry layout must match the filename-derived shape
        let good = "HloModule m, entry_computation_layout={(s32[2,4]{1,0}, s32[2]{0})->(s32[2,4]{1,0}, s32[2,4]{1,0})}\n\nENTRY main {\n}\n";
        assert!(validate_hlo_text(good, 2, 4).is_ok());
        assert!(validate_hlo_text(good, 8, 16).is_err(), "shape mismatch must be rejected");
    }

    #[test]
    fn load_from_synthetic_artifact_dir() {
        let dir = std::env::temp_dir().join("bgpc_pjrt_test_artifacts");
        let _ = std::fs::remove_dir_all(&dir); // stale state from aborted runs
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("net_step_b2_k4.hlo.txt"),
            "HloModule net_step, entry_computation_layout={(s32[2,4]{1,0}, s32[2]{0})->(s32[2,4]{1,0}, s32[2,4]{1,0})}\n\nENTRY main.1 {\n}\n",
        )
        .unwrap();
        std::fs::write(dir.join("manifest.json"), "{}").unwrap();
        let rt = Runtime::load(&dir).unwrap();
        assert_eq!(rt.buckets().len(), 1);
        assert_eq!(rt.max_k(), 4);
        assert!(rt.bucket_for(3).is_some());
        assert!(rt.bucket_for(5).is_none());

        // step executes the kernel semantics on the padded tile
        let bucket = &rt.buckets()[0];
        let colors = vec![0, 0, -1, 9, /* row 2 */ 1, 1, 1, 1];
        let degs = vec![3, 4];
        let (new_colors, keep) = bucket.step(&colors, &degs).unwrap();
        assert_eq!(keep, vec![1, 0, 0, 0, 1, 0, 0, 0]);
        // row 0 deg 3: kept {0}; recolor slots 1,2 by reverse fit from 2
        assert_eq!(&new_colors[..4], &[0, 2, 1, 9]);
        // row 1 deg 4: kept {1@0}; recolor 1..3 -> 3,2,0
        assert_eq!(&new_colors[4..], &[1, 3, 2, 0]);

        // shape mismatch errors
        assert!(bucket.step(&colors[..4], &degs).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_missing_dir_mentions_make_artifacts() {
        let e = Runtime::load("/definitely/not/here/bgpc_artifacts").unwrap_err();
        assert!(e.to_string().contains("make artifacts"), "{e}");
    }
}
