//! Batched net-step offload: the paper's §VIII future-work manycore port,
//! run through the AOT-compiled JAX/Pallas kernel (DESIGN.md
//! §Hardware-Adaptation).
//!
//! The offload path degree-buckets the nets, gathers each net's adjacency
//! colors into a padded `[B, K]` tile, executes the fused Alg. 7 + Alg. 8
//! step on the PJRT executable, and scatters the recolored slots back.
//! Nets larger than the biggest bucket stay on the native Rust path.
//! [`step_rows_native`] is the bit-exact Rust mirror of the kernel; the
//! integration tests pin `PJRT == native` on every bucket shape.

use crate::util::error::Result;

use super::pjrt::Runtime;
use crate::coloring::forbidden::StampSet;
use crate::graph::Bipartite;

/// Bit-exact Rust mirror of the L1 kernel (Alg. 8 over gathered rows):
/// keep the first occurrence of each color; recolor every other valid
/// slot by reverse first-fit over `[0, deg) \ kept`.
pub fn step_rows_native(colors: &mut [i32], degs: &[i32], k: usize) {
    assert_eq!(colors.len(), degs.len() * k);
    let mut forbidden = StampSet::new(k + 1);
    let mut wlocal: Vec<usize> = Vec::with_capacity(k);
    for (b, &deg) in degs.iter().enumerate() {
        let row = &mut colors[b * k..(b + 1) * k];
        let deg = deg as usize;
        forbidden.next_gen();
        wlocal.clear();
        for (j, &c) in row.iter().enumerate().take(deg) {
            if c >= 0 && !forbidden.contains(c) {
                forbidden.insert(c);
            } else {
                wlocal.push(j);
            }
        }
        let mut col = deg as i32 - 1;
        for &j in &wlocal {
            while col >= 0 && forbidden.contains(col) {
                col -= 1;
            }
            debug_assert!(col >= 0, "reverse first-fit exhausted");
            row[j] = col;
            col -= 1;
        }
    }
}

/// Native keep-mask (Alg. 7 over gathered rows) — mirror of the kernel's
/// second output.
pub fn keep_rows_native(colors: &[i32], degs: &[i32], k: usize) -> Vec<i32> {
    let mut keep = vec![0i32; colors.len()];
    let mut seen = StampSet::new(k + 1);
    for (b, &deg) in degs.iter().enumerate() {
        seen.next_gen();
        for j in 0..deg as usize {
            let c = colors[b * k + j];
            if c >= 0 && !seen.contains(c) {
                seen.insert(c);
                keep[b * k + j] = 1;
            }
        }
    }
    keep
}

/// Statistics from one offloaded coloring run.
#[derive(Clone, Debug, Default)]
pub struct OffloadStats {
    pub iterations: usize,
    pub kernel_calls: usize,
    pub offloaded_nets: usize,
    pub native_nets: usize,
    /// Wall-clock seconds inside PJRT execute calls.
    pub kernel_secs: f64,
}

/// Driver for the offloaded BGPC coloring.
pub struct NetStepOffload<'a> {
    pub rt: &'a Runtime,
}

impl<'a> NetStepOffload<'a> {
    pub fn new(rt: &'a Runtime) -> Self {
        NetStepOffload { rt }
    }

    /// One pass over `nets`: buckets are gathered, stepped on the
    /// accelerator, and scattered back (last-writer-wins across buckets —
    /// the optimism; later passes repair). Oversized nets run natively.
    /// Returns the number of slots recolored this pass.
    pub fn pass(
        &self,
        g: &Bipartite,
        nets: &[usize],
        colors: &mut [i32],
        stats: &mut OffloadStats,
    ) -> Result<usize> {
        let max_k = self.rt.max_k();
        let mut recolored = 0usize;

        // group nets by bucket
        for bucket in self.rt.buckets() {
            let (bcap, k) = (bucket.b, bucket.k);
            let min_k = self.rt.buckets().iter().map(|b| b.k).filter(|&kk| kk < k).max();
            let mut batch_nets: Vec<usize> = Vec::with_capacity(bcap);
            let mut tile = vec![-1i32; bcap * k];
            let mut degs = vec![0i32; bcap];

            let flush = |batch_nets: &mut Vec<usize>,
                             tile: &mut Vec<i32>,
                             degs: &mut Vec<i32>,
                             colors: &mut [i32],
                             stats: &mut OffloadStats|
             -> Result<usize> {
                if batch_nets.is_empty() {
                    return Ok(0);
                }
                let t0 = std::time::Instant::now();
                let (new_colors, keep) = bucket.step(tile, degs)?;
                stats.kernel_secs += t0.elapsed().as_secs_f64();
                stats.kernel_calls += 1;
                let mut changed = 0usize;
                for (bi, &v) in batch_nets.iter().enumerate() {
                    for (j, &u) in g.vtxs(v).iter().enumerate() {
                        let idx = bi * k + j;
                        if keep[idx] == 0 {
                            changed += 1;
                        }
                        colors[u as usize] = new_colors[idx];
                    }
                }
                stats.offloaded_nets += batch_nets.len();
                batch_nets.clear();
                tile.fill(-1);
                degs.fill(0);
                Ok(changed)
            };

            for &v in nets {
                let deg = g.vtxs(v).len();
                // this bucket handles degrees in (previous K, K]
                if deg > k || deg == 0 || min_k.map_or(false, |m| deg <= m) {
                    continue;
                }
                let bi = batch_nets.len();
                degs[bi] = deg as i32;
                for (j, &u) in g.vtxs(v).iter().enumerate() {
                    tile[bi * k + j] = colors[u as usize];
                }
                batch_nets.push(v);
                if batch_nets.len() == bcap {
                    recolored +=
                        flush(&mut batch_nets, &mut tile, &mut degs, colors, stats)?;
                }
            }
            recolored += flush(&mut batch_nets, &mut tile, &mut degs, colors, stats)?;
        }

        // oversized nets: native mirror, row at a time
        for &v in nets {
            let deg = g.vtxs(v).len();
            if deg <= max_k {
                continue;
            }
            stats.native_nets += 1;
            let mut row: Vec<i32> =
                g.vtxs(v).iter().map(|&u| colors[u as usize]).collect();
            let degs = [deg as i32];
            let before = row.clone();
            step_rows_native(&mut row, &degs, deg);
            for (j, &u) in g.vtxs(v).iter().enumerate() {
                if row[j] != before[j] {
                    recolored += 1;
                }
                colors[u as usize] = row[j];
            }
        }
        Ok(recolored)
    }

    /// Iterate passes until the coloring is conflict-free. After the
    /// first full pass, only *dirty* nets — those still containing an
    /// uncolored vertex or a duplicate — are re-gathered (the offload
    /// analogue of the engine's shrinking work queue; re-stepping clean
    /// nets would undo settled colors forever). Returns the coloring and
    /// stats.
    pub fn color(&self, g: &Bipartite, max_iters: usize) -> Result<(Vec<i32>, OffloadStats)> {
        let mut colors = vec![-1i32; g.n_vertices()];
        let mut stats = OffloadStats::default();
        let mut nets: Vec<usize> = (0..g.n_nets()).collect();
        let mut prev_dirty = usize::MAX;
        for _ in 0..max_iters {
            stats.iterations += 1;
            self.pass(g, &nets, &mut colors, &mut stats)?;
            nets = dirty_nets(g, &colors);
            if nets.is_empty() && colors_complete(g, &colors) {
                debug_assert!(crate::coloring::verify::bgpc_valid(g, &colors).is_ok());
                return Ok((colors, stats));
            }
            if nets.is_empty() || nets.len() >= prev_dirty {
                // plateau: nets sharing vertices keep re-breaking each
                // other deterministically — switch to the exact repair,
                // exactly like the engine's N1 -> vertex-based handoff.
                break;
            }
            prev_dirty = nets.len();
        }
        // final exact repair: sequential greedy over conflicting vertices
        repair_sequential(g, &mut colors);
        Ok((colors, stats))
    }
}

/// Nets that still contain an uncolored vertex or an intra-net duplicate.
pub fn dirty_nets(g: &Bipartite, colors: &[i32]) -> Vec<usize> {
    let mut seen = StampSet::new(1024);
    let mut dirty = Vec::new();
    'nets: for v in 0..g.n_nets() {
        seen.next_gen();
        for &u in g.vtxs(v) {
            let c = colors[u as usize];
            if c < 0 || seen.contains(c) {
                dirty.push(v);
                continue 'nets;
            }
            seen.insert(c);
        }
    }
    dirty
}

/// True when every vertex is colored (isolated vertices included).
fn colors_complete(g: &Bipartite, colors: &[i32]) -> bool {
    let _ = g;
    colors.iter().all(|&c| c >= 0)
}

/// Uncolor every later-duplicate per net, then greedily finish — an exact
/// sequential repair used when the optimistic passes plateau.
pub fn repair_sequential(g: &Bipartite, colors: &mut [i32]) {
    let mut seen = StampSet::new(1024);
    for v in 0..g.n_nets() {
        seen.next_gen();
        for &u in g.vtxs(v) {
            let u = u as usize;
            let c = colors[u];
            if c >= 0 {
                if seen.contains(c) {
                    colors[u] = -1;
                } else {
                    seen.insert(c);
                }
            }
        }
    }
    let mut f = StampSet::new(1024);
    for u in 0..g.n_vertices() {
        if colors[u] >= 0 {
            continue;
        }
        f.next_gen();
        for &v in g.nets(u) {
            for &x in g.vtxs(v as usize) {
                let x = x as usize;
                if x != u && colors[x] >= 0 {
                    f.insert(colors[x]);
                }
            }
        }
        let (c, _) = f.first_fit();
        colors[u] = c;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn native_step_matches_python_oracle_semantics() {
        // mirrors python/tests: keep-first + reverse first-fit
        let k = 6;
        let mut colors = vec![
            2, 2, -1, 0, 1, -1, // deg 6: slots 1,2,5 recolored
            -1, -1, -1, 0, 0, 0, // deg 3: all recolored (pad ignored)
        ];
        let degs = vec![6, 3];
        step_rows_native(&mut colors, &degs, k);
        // row 0: kept {2@0, 0@3, 1@4}; avail {5,4,3}; recolor slots 1,2,5
        assert_eq!(&colors[..6], &[2, 5, 4, 0, 1, 3]);
        // row 1: all uncolored -> 2,1,0; pads untouched
        assert_eq!(&colors[6..], &[2, 1, 0, 0, 0, 0]);
    }

    #[test]
    fn native_step_rows_produce_valid_rows() {
        let mut rng = Rng::new(42);
        for _case in 0..200 {
            let k = [4usize, 8, 16][rng.range(0, 3)];
            let b = rng.range(1, 6);
            let mut colors: Vec<i32> = (0..b * k)
                .map(|_| rng.range(0, k + 3) as i32 - 1)
                .collect();
            let degs: Vec<i32> = (0..b).map(|_| rng.range(0, k + 1) as i32).collect();
            let before = colors.clone();
            step_rows_native(&mut colors, &degs, k);
            for bi in 0..b {
                let deg = degs[bi] as usize;
                let row = &colors[bi * k..bi * k + k];
                // valid slots distinct & colored
                let mut seen = std::collections::HashSet::new();
                for j in 0..deg {
                    assert!(row[j] >= 0, "uncolored slot");
                    assert!(seen.insert(row[j]), "dup in row {row:?} deg {deg}");
                }
                // pads untouched
                for j in deg..k {
                    assert_eq!(row[j], before[bi * k + j]);
                }
            }
        }
    }

    #[test]
    fn keep_mask_marks_first_occurrences() {
        let colors = vec![3, 3, -1, 1];
        let keep = keep_rows_native(&colors, &[4], 4);
        assert_eq!(keep, vec![1, 0, 0, 1]);
    }

    #[test]
    fn repair_sequential_fixes_anything() {
        let g = crate::graph::generators::random_bipartite(50, 80, 600, 9);
        let mut colors = vec![0i32; 80]; // everything clashes
        repair_sequential(&g, &mut colors);
        assert!(crate::coloring::verify::bgpc_valid(&g, &colors).is_ok());
    }
}
