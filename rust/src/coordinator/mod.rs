//! Coloring job coordinator — the L3 service layer.
//!
//! A [`Service`] owns a set of native *dispatchers*, one shared
//! region-execution [`WorkerPool`] (DESIGN.md §10), and (optionally)
//! one PJRT worker that holds the compiled net-step artifacts. Clients
//! [`Service::submit`] jobs (a graph + a [`crate::coloring::Config`] +
//! an engine selector); the router dispatches each job to the right
//! queue and the caller gets a receiver for the outcome. Dispatchers
//! never execute parallel regions themselves: every threads-mode job
//! and session runs its regions on the single persistent pool (size
//! via [`Service::start_with`]). Sessions own private scratch banks
//! and interleave on the team region-by-region; full-recolor jobs
//! share the one pool-resident bank and therefore serialize with each
//! other for their whole run (the team is one machine-wide resource
//! either way — concurrency buys overlap of between-region
//! bookkeeping, not extra parallelism). Engine panics come back as
//! failed [`JobOutcome`]s instead of poisoning a worker thread, and a
//! panic mid-update closes and unregisters the session so torn state
//! is never served. [`Service::pool_stats`]
//! exposes the substrate's region-dispatch and worker-utilization
//! counters. The PJRT executable is compiled once and reused across
//! jobs (one executable per bucket, per DESIGN.md §3); Python is never
//! involved.
//!
//! **Dynamic sessions** (the [`crate::dynamic`] subsystem, DESIGN.md
//! §8–§9): sessions are *problem-tagged* — [`Service::open_session`]
//! opens a BGPC session over a [`Bipartite`],
//! [`Service::open_session_d2gc`] a D2GC session over a square
//! symmetric [`Csr`] — and the service keeps the
//! [`crate::dynamic::DynamicSession`] alive internally. Clients then
//! stream [`JobInput::Update`] jobs carrying
//! [`crate::dynamic::UpdateBatch`] edits; the update path is shared,
//! and the service routes each batch to the repair path of the
//! session's problem (reported back in [`JobOutcome::problem`] and
//! counted per-problem by [`Metrics`]). Updates always run on the
//! native pool, are applied strictly in submit order per session (a
//! seq/condvar handshake — concurrent workers may *pick up* batches out
//! of order but never apply them out of order), and each outcome
//! carries the per-batch [`crate::dynamic::BatchStats`] in
//! [`JobOutcome::batch`].

pub mod metrics;

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering as AOrd};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::coloring::{color_bgpc_on, color_d2gc_on, Config, Problem};
use crate::dynamic::{BatchStats, BgpcSession, D2gcSession, UpdateBatch};
use crate::graph::{Bipartite, Csr};
use crate::par::pool::panic_message;
use crate::par::{PoolStats, WorkerPool};
use crate::runtime::{NetStepOffload, Runtime};

pub use metrics::Metrics;

/// Default size of the shared region-execution [`WorkerPool`] (see
/// [`Service::start_with`] to pick another).
pub const DEFAULT_POOL_THREADS: usize = 4;

/// Identifier of an open dynamic session (see [`Service::open_session`]
/// and [`Service::open_session_d2gc`]).
pub type SessionId = u64;

/// A problem-tagged dynamic session as the service stores it. The two
/// instantiations of [`crate::dynamic::DynamicSession`] share one
/// update path; this enum is the runtime dispatch point that routes a
/// batch to the right repair engine.
enum AnySession {
    Bgpc(BgpcSession),
    D2gc(D2gcSession),
}

impl AnySession {
    fn problem(&self) -> Problem {
        match self {
            AnySession::Bgpc(_) => Problem::Bgpc,
            AnySession::D2gc(_) => Problem::D2gc,
        }
    }

    fn apply(&mut self, batch: &UpdateBatch) -> BatchStats {
        match self {
            AnySession::Bgpc(s) => s.apply(batch),
            AnySession::D2gc(s) => s.apply(batch),
        }
    }

    fn verify_ok(&mut self) -> bool {
        match self {
            AnySession::Bgpc(s) => s.verify().is_ok(),
            AnySession::D2gc(s) => s.verify().is_ok(),
        }
    }

    fn colors(&self) -> &[i32] {
        match self {
            AnySession::Bgpc(s) => s.colors(),
            AnySession::D2gc(s) => s.colors(),
        }
    }
}

/// A session as the service holds it: the mutable state under a lock,
/// an admission counter assigning each update its sequence number at
/// submit time, and a condvar that parks workers holding a batch whose
/// predecessors are still being applied.
struct SessionSlot {
    submitted: AtomicU64,
    state: Mutex<SessionInner>,
    cv: Condvar,
}

struct SessionInner {
    session: AnySession,
    /// Batches applied so far == the next admissible seq.
    applied: u64,
    /// Set by [`Service::close_session`]; wakes and fails parked workers
    /// whose predecessor batches can no longer arrive.
    closed: bool,
}

type SessionMap = Mutex<HashMap<SessionId, Arc<SessionSlot>>>;

/// Which engine a job should run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineSel {
    /// Router decides: PJRT for BGPC jobs whose nets fit a bucket (when
    /// artifacts are loaded), native otherwise.
    Auto,
    /// Native engine (simulator or real threads per the job's Config).
    Native,
    /// The AOT JAX/Pallas net-step path.
    Pjrt,
}

/// A coloring job.
#[derive(Clone)]
pub struct Job {
    pub name: String,
    pub input: JobInput,
    pub cfg: Config,
    pub engine: EngineSel,
}

/// Job payload (graphs are shared; the service never copies them).
#[derive(Clone)]
pub enum JobInput {
    Bgpc(Arc<Bipartite>),
    D2gc(Arc<Csr>),
    /// Incremental update batch against an open dynamic session. Always
    /// runs on the native pool (the job's `cfg`/`engine` are ignored —
    /// the session carries its own [`Config`]); applied strictly in
    /// submit order per session.
    Update { session: SessionId, batch: Arc<UpdateBatch> },
}

impl JobInput {
    /// The coloring problem this input runs, when it is statically
    /// known. `Update` jobs return `None`: the problem is a property of
    /// the open session — BGPC and D2GC sessions share the update path
    /// — and the service resolves it when the batch is applied (see
    /// [`Service::session_problem`] and [`JobOutcome::problem`]).
    pub fn problem(&self) -> Option<Problem> {
        match self {
            JobInput::Bgpc(_) => Some(Problem::Bgpc),
            JobInput::D2gc(_) => Some(Problem::D2gc),
            JobInput::Update { .. } => None,
        }
    }
}

/// Outcome delivered to the submitter.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub name: String,
    pub engine: &'static str,
    /// The problem that actually ran — for update jobs, the open
    /// session's problem. `None` only on routing errors where it is
    /// unknowable (e.g. an update against an unknown session).
    pub problem: Option<Problem>,
    pub n_colors: usize,
    pub iterations: usize,
    pub seconds: f64,
    pub valid: bool,
    pub error: Option<String>,
    /// Per-batch repair metrics (update jobs only).
    pub batch: Option<BatchStats>,
}

enum Message {
    /// A job plus its session seq (0 and unused for non-update jobs).
    Run(Job, u64, Sender<JobOutcome>),
    Stop,
}

/// The coordinator service.
pub struct Service {
    native_tx: Sender<Message>,
    pjrt_tx: Option<Sender<Message>>,
    workers: Vec<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    seq: AtomicU64,
    sessions: Arc<SessionMap>,
    session_seq: AtomicU64,
    /// The shared region-execution team every native job and session
    /// multiplexes onto (DESIGN.md §10).
    pool: Arc<WorkerPool>,
}

/// A zeroed failure [`JobOutcome`] — the shape every coordinator error
/// path reports, differing only in identity and message.
fn fail_outcome(
    name: &str,
    engine: &'static str,
    problem: Option<Problem>,
    error: String,
) -> JobOutcome {
    JobOutcome {
        name: name.to_string(),
        engine,
        problem,
        n_colors: 0,
        iterations: 0,
        seconds: 0.0,
        valid: false,
        error: Some(error),
        batch: None,
    }
}

fn run_native(job: &Job, sessions: &SessionMap, seq: u64, pool: &Arc<WorkerPool>) -> JobOutcome {
    match &job.input {
        JobInput::Bgpc(g) => {
            let r = color_bgpc_on(g, &job.cfg, pool);
            let valid = crate::coloring::verify::bgpc_valid(g, &r.colors).is_ok();
            JobOutcome {
                name: job.name.clone(),
                engine: "native",
                problem: Some(Problem::Bgpc),
                n_colors: r.n_colors,
                iterations: r.iterations,
                seconds: r.seconds,
                valid,
                error: None,
                batch: None,
            }
        }
        JobInput::D2gc(g) => {
            let r = color_d2gc_on(g, &job.cfg, pool);
            let valid = crate::coloring::verify::d2gc_valid(g, &r.colors).is_ok();
            JobOutcome {
                name: job.name.clone(),
                engine: "native",
                problem: Some(Problem::D2gc),
                n_colors: r.n_colors,
                iterations: r.iterations,
                seconds: r.seconds,
                valid,
                error: None,
                batch: None,
            }
        }
        JobInput::Update { session, batch } => run_update(sessions, *session, seq, batch, &job.name),
    }
}

/// Apply one update batch in session order: wait (on the slot's condvar)
/// until every earlier-seq batch has been applied, then repair.
fn run_update(
    sessions: &SessionMap,
    id: SessionId,
    seq: u64,
    batch: &UpdateBatch,
    name: &str,
) -> JobOutcome {
    let slot = sessions.lock().unwrap().get(&id).cloned();
    let Some(slot) = slot else {
        return fail_outcome(name, "native", None, format!("unknown session {id}"));
    };
    let mut inner = slot.state.lock().unwrap();
    let problem = inner.session.problem();
    while inner.applied != seq {
        if inner.closed {
            // a predecessor batch was dropped by close_session: fail
            // cleanly instead of parking forever
            return fail_outcome(
                name,
                "native",
                Some(problem),
                format!("session {id} closed before batch applied"),
            );
        }
        inner = slot.cv.wait(inner).unwrap();
    }
    if inner.closed {
        // in-order but the session was closed while this batch was
        // queued: refuse to mutate state the client can no longer see
        return fail_outcome(
            name,
            "native",
            Some(problem),
            format!("session {id} closed before batch applied"),
        );
    }
    // Apply + verify under catch_unwind: a panic here would otherwise
    // unwind while holding the slot mutex, poisoning it for every later
    // client call and hanging successors parked on `applied` — instead
    // the session is marked closed (its state may be torn mid-apply),
    // parked successors wake and fail cleanly, and the panic surfaces
    // as this job's error. The verify pass is the service contract:
    // every outcome the coordinator hands back is checked with the
    // session's own problem checker (bgpc_valid / d2gc_valid), O(|E|)
    // under the session lock; latency-sensitive clients that trust the
    // repair invariants can use DynamicSession directly.
    let applied = catch_unwind(AssertUnwindSafe(|| {
        let stats = inner.session.apply(batch);
        let valid = inner.session.verify_ok();
        (stats, valid)
    }));
    let (stats, valid) = match applied {
        Ok(x) => x,
        Err(p) => {
            // The session state may be torn mid-apply: close it AND
            // drop it from the map (exactly like close_session), so
            // clients get `None` from session_colors/session_problem
            // instead of a possibly-invalid coloring, and the dead
            // slot does not leak.
            inner.closed = true;
            slot.cv.notify_all();
            drop(inner);
            sessions.lock().unwrap().remove(&id);
            return fail_outcome(
                name,
                "native",
                Some(problem),
                format!("engine panicked: {}; session {id} closed", panic_message(p.as_ref())),
            );
        }
    };
    inner.applied += 1;
    slot.cv.notify_all();
    JobOutcome {
        name: name.to_string(),
        engine: "native",
        problem: Some(problem),
        n_colors: stats.n_colors,
        iterations: stats.iterations,
        seconds: stats.seconds,
        valid,
        error: None,
        batch: Some(stats),
    }
}

fn run_pjrt(rt: &Runtime, job: &Job) -> JobOutcome {
    match &job.input {
        JobInput::Bgpc(g) => {
            let t0 = std::time::Instant::now();
            match NetStepOffload::new(rt).color(g, 50) {
                Ok((colors, stats)) => {
                    let valid = crate::coloring::verify::bgpc_valid(g, &colors).is_ok();
                    JobOutcome {
                        name: job.name.clone(),
                        engine: "pjrt",
                        problem: Some(Problem::Bgpc),
                        n_colors: crate::coloring::stats::distinct_colors(&colors),
                        iterations: stats.iterations,
                        seconds: t0.elapsed().as_secs_f64(),
                        valid,
                        error: None,
                        batch: None,
                    }
                }
                Err(e) => JobOutcome {
                    seconds: t0.elapsed().as_secs_f64(),
                    ..fail_outcome(&job.name, "pjrt", Some(Problem::Bgpc), format!("{e:#}"))
                },
            }
        }
        JobInput::D2gc(_) | JobInput::Update { .. } => fail_outcome(
            &job.name,
            "pjrt",
            job.input.problem(),
            "PJRT engine only supports BGPC jobs".into(),
        ),
    }
}

impl Service {
    /// Start `n_native` native dispatchers over a
    /// [`DEFAULT_POOL_THREADS`]-wide shared pool; if `artifacts` is
    /// given and loads, also start one PJRT worker owning the compiled
    /// executables. See [`Service::start_with`] for the pool knob.
    pub fn start(n_native: usize, artifacts: Option<std::path::PathBuf>) -> Service {
        Service::start_with(n_native, DEFAULT_POOL_THREADS, artifacts)
    }

    /// [`Service::start`] with an explicit region-execution pool size.
    ///
    /// Two thread populations exist, spawned here once and never again:
    /// `n_native` *dispatchers* (they pop the job queue, order session
    /// updates, and block on outcomes — control plane) and one
    /// `pool_threads`-wide [`WorkerPool`] that executes every parallel
    /// region of every threads-mode job and session (data plane).
    /// Sessions interleave on the team region-by-region; full-recolor
    /// jobs additionally serialize on the pool-resident scratch bank
    /// for their whole run. A job's `cfg.threads` is clamped to the
    /// pool size. A panic inside an
    /// engine (a structural assert, a driver contract violation)
    /// surfaces as a failed [`JobOutcome`] — the dispatcher and the
    /// pool both survive.
    pub fn start_with(
        n_native: usize,
        pool_threads: usize,
        artifacts: Option<std::path::PathBuf>,
    ) -> Service {
        let metrics = Arc::new(Metrics::default());
        let sessions: Arc<SessionMap> = Arc::new(Mutex::new(HashMap::new()));
        let pool = Arc::new(WorkerPool::new(pool_threads.max(1)));
        let (native_tx, native_rx) = channel::<Message>();
        let native_rx = Arc::new(std::sync::Mutex::new(native_rx));
        let mut workers = Vec::new();
        for _ in 0..n_native.max(1) {
            let rx = Arc::clone(&native_rx);
            let m = Arc::clone(&metrics);
            let sess = Arc::clone(&sessions);
            let pl = Arc::clone(&pool);
            workers.push(std::thread::spawn(move || loop {
                let msg = { rx.lock().unwrap().recv() };
                match msg {
                    Ok(Message::Run(job, seq, out)) => {
                        let o = catch_unwind(AssertUnwindSafe(|| run_native(&job, &sess, seq, &pl)))
                            .unwrap_or_else(|p| {
                                fail_outcome(
                                    &job.name,
                                    "native",
                                    job.input.problem(),
                                    format!("engine panicked: {}", panic_message(p.as_ref())),
                                )
                            });
                        m.record(&o);
                        let _ = out.send(o);
                    }
                    Ok(Message::Stop) | Err(_) => break,
                }
            }));
        }

        // PJRT handles are not Send: the runtime must be created *inside*
        // its worker thread; a oneshot reports whether loading succeeded.
        let pjrt_tx = artifacts.and_then(|dir| {
            let (tx, rx) = channel::<Message>();
            let (ready_tx, ready_rx) = channel::<Result<(), String>>();
            let m = Arc::clone(&metrics);
            let handle = std::thread::spawn(move || {
                let rt = match Runtime::load(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                loop {
                    match rx.recv() {
                        Ok(Message::Run(job, _seq, out)) => {
                            let o = run_pjrt(&rt, &job);
                            m.record(&o);
                            let _ = out.send(o);
                        }
                        Ok(Message::Stop) | Err(_) => break,
                    }
                }
            });
            match ready_rx.recv() {
                Ok(Ok(())) => {
                    workers.push(handle);
                    Some(tx)
                }
                Ok(Err(e)) => {
                    eprintln!("coordinator: PJRT engine unavailable: {e}");
                    let _ = handle.join();
                    None
                }
                Err(_) => None,
            }
        });

        Service {
            native_tx,
            pjrt_tx,
            workers,
            metrics,
            seq: AtomicU64::new(0),
            sessions,
            session_seq: AtomicU64::new(0),
            pool,
        }
    }

    /// Route a job; returns the outcome receiver.
    pub fn submit(&self, mut job: Job) -> Receiver<JobOutcome> {
        if job.name.is_empty() {
            job.name = format!("job-{}", self.seq.fetch_add(1, AOrd::Relaxed));
        }
        let (tx, rx) = channel();
        // Updates bypass engine selection: they are session-ordered and
        // always native. The seq assignment and the channel send happen
        // under one lock so seq order == queue order — otherwise two
        // racing submitters could enqueue seq 1 ahead of seq 0 and park
        // a worker (or the whole pool) on a predecessor stuck behind it.
        if let JobInput::Update { session, .. } = &job.input {
            let id = *session;
            let sessions = self.sessions.lock().unwrap();
            match sessions.get(&id) {
                Some(slot) => {
                    let seq = slot.submitted.fetch_add(1, AOrd::SeqCst);
                    let _ = self.native_tx.send(Message::Run(job, seq, tx));
                }
                None => {
                    let _ = tx.send(fail_outcome(
                        &job.name,
                        "native",
                        None,
                        format!("unknown session {id}"),
                    ));
                }
            }
            return rx;
        }
        let use_pjrt = match job.engine {
            EngineSel::Pjrt => true,
            EngineSel::Native => false,
            EngineSel::Auto => {
                self.pjrt_tx.is_some() && matches!(job.input, JobInput::Bgpc(_))
            }
        };
        if use_pjrt {
            match &self.pjrt_tx {
                Some(ptx) => {
                    let _ = ptx.send(Message::Run(job, 0, tx));
                }
                None => {
                    let _ = tx.send(fail_outcome(
                        &job.name,
                        "pjrt",
                        job.input.problem(),
                        "PJRT engine not loaded (run `make artifacts`)".into(),
                    ));
                }
            }
        } else {
            let _ = self.native_tx.send(Message::Run(job, 0, tx));
        }
        rx
    }

    /// Open a BGPC dynamic session: color `g` from scratch under `cfg`
    /// (synchronously, on the caller's thread) and keep the session
    /// alive inside the service. Stream [`JobInput::Update`] jobs
    /// against the returned id, then [`Service::close_session`].
    pub fn open_session(&self, name: &str, g: &Bipartite, cfg: Config) -> (SessionId, JobOutcome) {
        let (mut session, init) =
            crate::dynamic::DynamicSession::start_on(g.clone(), cfg, &self.pool);
        let valid = session.verify().is_ok();
        self.install_session(name, AnySession::Bgpc(session), &init, valid)
    }

    /// Open a D2GC dynamic session over a square, structurally
    /// symmetric graph: same contract as [`Service::open_session`], but
    /// updates are undirected edge edits repaired at distance 2 (the
    /// overlay keeps the pattern symmetric across the stream).
    ///
    /// # Panics
    /// If `g` is not square and structurally symmetric.
    pub fn open_session_d2gc(&self, name: &str, g: &Csr, cfg: Config) -> (SessionId, JobOutcome) {
        let (mut session, init) =
            crate::dynamic::DynamicSession::start_on(g.clone(), cfg, &self.pool);
        let valid = session.verify().is_ok();
        self.install_session(name, AnySession::D2gc(session), &init, valid)
    }

    /// Shared tail of the `open_session*` pair: record the bring-up
    /// outcome and park the session under a fresh id.
    fn install_session(
        &self,
        name: &str,
        session: AnySession,
        init: &crate::coloring::ColoringResult,
        valid: bool,
    ) -> (SessionId, JobOutcome) {
        let outcome = JobOutcome {
            name: name.to_string(),
            engine: "native",
            problem: Some(session.problem()),
            n_colors: init.n_colors,
            iterations: init.iterations,
            seconds: init.seconds,
            valid,
            error: None,
            batch: None,
        };
        self.metrics.record(&outcome);
        let id = self.session_seq.fetch_add(1, AOrd::Relaxed) + 1;
        self.sessions.lock().unwrap().insert(
            id,
            Arc::new(SessionSlot {
                submitted: AtomicU64::new(0),
                state: Mutex::new(SessionInner { session, applied: 0, closed: false }),
                cv: Condvar::new(),
            }),
        );
        (id, outcome)
    }

    /// Snapshot a session's current committed coloring (batches applied
    /// so far; does not wait for still-queued updates).
    pub fn session_colors(&self, id: SessionId) -> Option<Vec<i32>> {
        let slot = self.sessions.lock().unwrap().get(&id).cloned()?;
        let inner = slot.state.lock().unwrap();
        Some(inner.session.colors().to_vec())
    }

    /// The problem an open session repairs (`None` if the id is
    /// unknown) — the authoritative answer [`JobInput::problem`] cannot
    /// give for `Update` jobs.
    pub fn session_problem(&self, id: SessionId) -> Option<Problem> {
        let slot = self.sessions.lock().unwrap().get(&id).cloned()?;
        let inner = slot.state.lock().unwrap();
        Some(inner.session.problem())
    }

    /// Close a session. The update a worker is currently applying still
    /// completes; updates parked behind a predecessor that can no longer
    /// arrive are woken and fail cleanly ("session closed"); later
    /// submits error with "unknown session". Returns whether the id was
    /// open.
    pub fn close_session(&self, id: SessionId) -> bool {
        let slot = self.sessions.lock().unwrap().remove(&id);
        match slot {
            Some(slot) => {
                slot.state.lock().unwrap().closed = true;
                slot.cv.notify_all();
                true
            }
            None => false,
        }
    }

    /// Whether the PJRT engine is up.
    pub fn has_pjrt(&self) -> bool {
        self.pjrt_tx.is_some()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The shared region-execution pool (open sessions against it,
    /// inspect it, or borrow it for ad-hoc drivers).
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Region-dispatch and worker-utilization counters of the shared
    /// pool — the execution-substrate metrics that complement the
    /// per-job [`Metrics`].
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Stop all workers and join them.
    pub fn shutdown(self) {
        for _ in 0..self.workers.len() {
            let _ = self.native_tx.send(Message::Stop);
        }
        if let Some(ptx) = &self.pjrt_tx {
            let _ = ptx.send(Message::Stop);
        }
        drop(self.native_tx);
        drop(self.pjrt_tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::schedule;
    use crate::graph::generators::random_bipartite;

    #[test]
    fn native_jobs_round_trip() {
        let svc = Service::start(2, None);
        let g = Arc::new(random_bipartite(100, 150, 1200, 21));
        let mut rxs = Vec::new();
        for (i, spec) in schedule::ALL.iter().enumerate() {
            rxs.push(svc.submit(Job {
                name: format!("j{i}"),
                input: JobInput::Bgpc(Arc::clone(&g)),
                cfg: Config::sim(*spec, 4),
                engine: EngineSel::Native,
            }));
        }
        for rx in rxs {
            let o = rx.recv().unwrap();
            assert!(o.valid, "{}: {:?}", o.name, o.error);
            assert!(o.n_colors > 0);
        }
        assert_eq!(svc.metrics().jobs_done(), 8);
        svc.shutdown();
    }

    #[test]
    fn threads_jobs_multiplex_onto_the_shared_pool() {
        use crate::graph::generators::random_symmetric;
        let svc = Service::start_with(2, 4, None);
        assert_eq!(svc.pool_stats().threads, 4);
        let g = Arc::new(random_bipartite(120, 180, 1400, 5));
        let m = Arc::new(random_symmetric(80, 300, 7));
        let mut rxs = Vec::new();
        for i in 0..4 {
            rxs.push(svc.submit(Job {
                name: format!("t{i}"),
                // cfg.threads is clamped to the pool size (8 -> 4)
                input: JobInput::Bgpc(Arc::clone(&g)),
                cfg: Config::threads(schedule::ALL[i % schedule::ALL.len()], 8),
                engine: EngineSel::Native,
            }));
        }
        rxs.push(svc.submit(Job {
            name: "t-d2".into(),
            input: JobInput::D2gc(Arc::clone(&m)),
            cfg: Config::threads(schedule::V_N2, 4),
            engine: EngineSel::Native,
        }));
        for rx in rxs {
            let o = rx.recv().unwrap();
            assert!(o.valid, "{}: {:?}", o.name, o.error);
        }
        let st = svc.pool_stats();
        assert!(st.regions > 0, "regions must dispatch onto the shared pool");
        assert!(st.items > 0);
        assert!(st.utilization() > 0.0 && st.utilization() <= 1.0);
        svc.shutdown();
    }

    #[test]
    fn engine_panic_becomes_job_error_and_worker_survives() {
        // A non-square D2GC job trips the engine's structural assert on
        // the dispatcher. The old behaviour poisoned the worker thread;
        // now the panic surfaces through JobOutcome and the service
        // keeps serving.
        let svc = Service::start(1, None);
        let bad = Arc::new(crate::graph::Csr::from_edges(3, 4, &[(0, 1), (1, 0), (2, 3)]));
        let o = svc
            .submit(Job {
                name: "bad".into(),
                input: JobInput::D2gc(bad),
                cfg: Config::sim(schedule::N1_N2, 2),
                engine: EngineSel::Native,
            })
            .recv()
            .unwrap();
        assert!(!o.valid);
        let err = o.error.expect("panic must surface as an error");
        assert!(err.contains("square"), "unexpected message: {err}");
        assert_eq!(svc.metrics().failures(), 1);
        // the single dispatcher survived: a healthy job still completes
        let g = Arc::new(random_bipartite(40, 60, 300, 2));
        let o = svc
            .submit(Job {
                name: "good".into(),
                input: JobInput::Bgpc(g),
                cfg: Config::sim(schedule::V_N2, 2),
                engine: EngineSel::Native,
            })
            .recv()
            .unwrap();
        assert!(o.valid, "{:?}", o.error);
        svc.shutdown();
    }

    #[test]
    fn pjrt_request_without_artifacts_errors_cleanly() {
        let svc = Service::start(1, None);
        let g = Arc::new(random_bipartite(10, 20, 60, 1));
        let rx = svc.submit(Job {
            name: "x".into(),
            input: JobInput::Bgpc(g),
            cfg: Config::sim(schedule::N1_N2, 2),
            engine: EngineSel::Pjrt,
        });
        let o = rx.recv().unwrap();
        assert!(!o.valid);
        assert!(o.error.unwrap().contains("artifacts"));
        svc.shutdown();
    }

    #[test]
    fn dynamic_session_streams_ordered_batches() {
        use crate::dynamic::UpdateBatch;
        let svc = Service::start(2, None);
        let g = random_bipartite(80, 120, 900, 77);
        let (sid, init) = svc.open_session("sess", &g, Config::sim(schedule::N1_N2, 4));
        assert!(init.valid, "initial coloring must verify");
        assert!(init.n_colors > 0);
        // three dependent batches streamed through two workers: the
        // seq/condvar handshake must apply them in submit order.
        let mut rxs = Vec::new();
        for k in 0..3u32 {
            let mut batch = UpdateBatch::default();
            for i in 0..10u32 {
                batch.add_edges.push(((k * 7 + i) % 80, (k * 11 + i * 3) % 120));
            }
            rxs.push(svc.submit(Job {
                name: format!("u{k}"),
                input: JobInput::Update { session: sid, batch: Arc::new(batch) },
                cfg: Config::sim(schedule::N1_N2, 4),
                engine: EngineSel::Auto,
            }));
        }
        for rx in rxs {
            let o = rx.recv().unwrap();
            assert!(o.valid, "{}: {:?}", o.name, o.error);
            assert_eq!(o.problem, Some(Problem::Bgpc), "update reports the session's problem");
            let b = o.batch.expect("update outcomes carry batch stats");
            assert!(b.dirty_nets > 0 || b.batch_edits == 0);
        }
        assert_eq!(svc.session_problem(sid), Some(Problem::Bgpc));
        let colors = svc.session_colors(sid).expect("session open");
        assert_eq!(colors.len(), 120);
        assert!(colors.iter().all(|&c| c >= 0));
        assert!(svc.close_session(sid));
        assert!(!svc.close_session(sid), "second close is a no-op");
        assert!(svc.session_colors(sid).is_none());
        svc.shutdown();
    }

    #[test]
    fn d2gc_session_streams_through_the_same_update_path() {
        use crate::dynamic::UpdateBatch;
        use crate::graph::generators::random_symmetric;
        let svc = Service::start(2, None);
        let g = random_symmetric(100, 500, 9);
        let (sid, init) = svc.open_session_d2gc("hessian", &g, Config::sim(schedule::N1_N2, 4));
        assert!(init.valid, "initial D2GC coloring must verify");
        assert_eq!(init.problem, Some(Problem::D2gc));
        assert_eq!(svc.session_problem(sid), Some(Problem::D2gc));
        let mut rxs = Vec::new();
        for k in 0..2u32 {
            let mut batch = UpdateBatch::default();
            for i in 0..8u32 {
                let a = (k * 13 + i * 7) % 100;
                let b = (k * 31 + i * 11) % 100;
                batch.add_edges.push((a, b));
            }
            rxs.push(svc.submit(Job {
                name: format!("h{k}"),
                input: JobInput::Update { session: sid, batch: Arc::new(batch) },
                cfg: Config::sim(schedule::N1_N2, 4),
                engine: EngineSel::Auto,
            }));
        }
        for rx in rxs {
            let o = rx.recv().unwrap();
            assert!(o.valid, "{}: {:?}", o.name, o.error);
            assert_eq!(o.problem, Some(Problem::D2gc), "update reports the session's problem");
            assert!(o.batch.is_some());
        }
        assert_eq!(svc.metrics().updates_d2gc(), 2);
        assert_eq!(svc.metrics().updates_bgpc(), 0);
        let colors = svc.session_colors(sid).expect("session open");
        assert_eq!(colors.len(), 100);
        assert!(colors.iter().all(|&c| c >= 0));
        assert!(svc.close_session(sid));
        svc.shutdown();
    }

    #[test]
    fn update_to_unknown_session_errors_cleanly() {
        use crate::dynamic::UpdateBatch;
        let svc = Service::start(1, None);
        let rx = svc.submit(Job {
            name: "nope".into(),
            input: JobInput::Update { session: 999, batch: Arc::new(UpdateBatch::default()) },
            cfg: Config::sim(schedule::N1_N2, 2),
            engine: EngineSel::Native,
        });
        let o = rx.recv().unwrap();
        assert!(!o.valid);
        assert!(o.error.unwrap().contains("unknown session"));
        assert!(o.batch.is_none());
        svc.shutdown();
    }

    #[test]
    fn auto_routes_to_native_without_pjrt() {
        let svc = Service::start(1, None);
        assert!(!svc.has_pjrt());
        let g = Arc::new(random_bipartite(50, 60, 300, 3));
        let o = svc
            .submit(Job {
                name: String::new(),
                input: JobInput::Bgpc(g),
                cfg: Config::sim(schedule::V_N2, 2),
                engine: EngineSel::Auto,
            })
            .recv()
            .unwrap();
        assert_eq!(o.engine, "native");
        assert!(o.valid);
        svc.shutdown();
    }
}
