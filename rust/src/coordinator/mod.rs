//! Coloring job coordinator — the L3 service layer.
//!
//! A [`Service`] owns a set of native *dispatchers*, one shared
//! region-execution [`WorkerPool`] (DESIGN.md §10), and (optionally)
//! one PJRT worker that holds the compiled net-step artifacts. Clients
//! [`Service::submit`] jobs (a graph + a [`crate::coloring::Config`] +
//! an engine selector); the router dispatches each job to the right
//! queue and the caller gets a receiver for the outcome. Dispatchers
//! never execute parallel regions themselves: every threads-mode job
//! and session runs its regions on the single persistent pool (size
//! via [`Service::start_with`]). Sessions own private scratch banks
//! and interleave on the team region-by-region; full-recolor jobs
//! share the one pool-resident bank and therefore serialize with each
//! other for their whole run (the team is one machine-wide resource
//! either way — concurrency buys overlap of between-region
//! bookkeeping, not extra parallelism). Engine panics come back as
//! failed [`JobOutcome`]s instead of poisoning a worker thread, and a
//! panic mid-update closes and unregisters the session so torn state
//! is never served. [`Service::pool_stats`]
//! exposes the substrate's region-dispatch and worker-utilization
//! counters. The PJRT executable is compiled once and reused across
//! jobs (one executable per bucket, per DESIGN.md §3); Python is never
//! involved.
//!
//! **Dynamic sessions** (the [`crate::dynamic`] subsystem, DESIGN.md
//! §8–§9): sessions are *problem-tagged* — [`Service::open_session`]
//! opens a BGPC session over a [`Bipartite`],
//! [`Service::open_session_d2gc`] a D2GC session over a square
//! symmetric [`Csr`] — and the service keeps the
//! [`crate::dynamic::DynamicSession`] alive internally. Clients then
//! stream [`JobInput::Update`] jobs carrying
//! [`crate::dynamic::UpdateBatch`] edits; the update path is shared,
//! and the service routes each batch to the repair path of the
//! session's problem (reported back in [`JobOutcome::problem`] and
//! counted per-problem by [`Metrics`]). Updates always run on the
//! native pool, are applied strictly in submit order per session (a
//! seq/condvar handshake — concurrent workers may *pick up* batches out
//! of order but never apply them out of order), and each outcome
//! carries the per-batch [`crate::dynamic::BatchStats`] in
//! [`JobOutcome::batch`].
//!
//! **Colored execution** (the [`crate::exec`] subsystem, DESIGN.md
//! §11): [`JobInput::Execute`] runs a client [`ExecKernel`] over an
//! open session's *current* coloring, color set by color set on the
//! shared pool. The service caches one [`crate::exec::ColorSchedule`]
//! per session and refreshes it incrementally before each run — after
//! an update batch, only the colors the repair dirtied are rebuilt
//! (repair → rebuild dirty frontiers → re-run), and the per-run
//! [`JobOutcome::exec`] stats report both the execution profile
//! (max-color-set busy units, utilization) and what the refresh moved.
//! Execute jobs always run native; they observe the committed coloring
//! at lock time and serialize with the session's updates on the
//! session lock.

pub mod metrics;

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering as AOrd};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::coloring::{color_bgpc_on, color_d2gc_on, Config, Problem};
use crate::dynamic::{BatchStats, BgpcSession, D2gcSession, UpdateBatch};
use crate::exec::{ColorSchedule, Executor, RefreshStats};
use crate::graph::{Bipartite, Csr};
use crate::par::pool::panic_message;
use crate::par::{Cost, PoolStats, WorkerPool};
use crate::runtime::{NetStepOffload, Runtime};

pub use metrics::Metrics;

/// Default size of the shared region-execution [`WorkerPool`] (see
/// [`Service::start_with`] to pick another).
pub const DEFAULT_POOL_THREADS: usize = 4;

/// Identifier of an open dynamic session (see [`Service::open_session`]
/// and [`Service::open_session_d2gc`]).
pub type SessionId = u64;

/// A problem-tagged dynamic session as the service stores it. The two
/// instantiations of [`crate::dynamic::DynamicSession`] share one
/// update path; this enum is the runtime dispatch point that routes a
/// batch to the right repair engine.
enum AnySession {
    Bgpc(BgpcSession),
    D2gc(D2gcSession),
}

impl AnySession {
    fn problem(&self) -> Problem {
        match self {
            AnySession::Bgpc(_) => Problem::Bgpc,
            AnySession::D2gc(_) => Problem::D2gc,
        }
    }

    fn apply(&mut self, batch: &UpdateBatch) -> BatchStats {
        match self {
            AnySession::Bgpc(s) => s.apply(batch),
            AnySession::D2gc(s) => s.apply(batch),
        }
    }

    fn verify_ok(&mut self) -> bool {
        match self {
            AnySession::Bgpc(s) => s.verify().is_ok(),
            AnySession::D2gc(s) => s.verify().is_ok(),
        }
    }

    fn colors(&self) -> &[i32] {
        match self {
            AnySession::Bgpc(s) => s.colors(),
            AnySession::D2gc(s) => s.colors(),
        }
    }
}

/// A session as the service holds it: the mutable state under a lock,
/// an admission counter assigning each update its sequence number at
/// submit time, and a condvar that parks workers holding a batch whose
/// predecessors are still being applied.
struct SessionSlot {
    submitted: AtomicU64,
    state: Mutex<SessionInner>,
    cv: Condvar,
}

struct SessionInner {
    session: AnySession,
    /// Batches applied so far == the next admissible seq.
    applied: u64,
    /// Set by [`Service::close_session`]; wakes and fails parked workers
    /// whose predecessor batches can no longer arrive.
    closed: bool,
    /// Cached per-color execution frontiers ([`crate::exec`]), built on
    /// the first [`JobInput::Execute`] and diff-refreshed afterwards —
    /// an update batch dirties only the colors its repair touched, and
    /// only those buckets are rebuilt before the next run.
    sched: Option<ColorSchedule>,
}

type SessionMap = Mutex<HashMap<SessionId, Arc<SessionSlot>>>;

/// Which engine a job should run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineSel {
    /// Router decides: PJRT for BGPC jobs whose nets fit a bucket (when
    /// artifacts are loaded), native otherwise.
    Auto,
    /// Native engine (simulator or real threads per the job's Config).
    Native,
    /// The AOT JAX/Pallas net-step path.
    Pjrt,
}

/// A type-erased colored-execution kernel: `(item, color) -> Cost`
/// (see [`crate::exec::Executor::run`]). Shared state lives in the
/// closure's captures (e.g. an `Arc<`[`crate::exec::SharedBuf`]`>`);
/// the schedule's conflict-freedom is what makes lock-free mutation of
/// it sound. Cheap to clone — jobs carry it by `Arc`.
#[derive(Clone)]
pub struct ExecKernel(Arc<dyn Fn(usize, usize) -> Cost + Send + Sync>);

impl ExecKernel {
    pub fn new(f: impl Fn(usize, usize) -> Cost + Send + Sync + 'static) -> ExecKernel {
        ExecKernel(Arc::new(f))
    }

    /// Invoke the kernel on `(item, color)`.
    pub fn call(&self, item: usize, color: usize) -> Cost {
        (self.0)(item, color)
    }
}

/// A coloring job.
#[derive(Clone)]
pub struct Job {
    pub name: String,
    pub input: JobInput,
    pub cfg: Config,
    pub engine: EngineSel,
}

/// Job payload (graphs are shared; the service never copies them).
#[derive(Clone)]
pub enum JobInput {
    Bgpc(Arc<Bipartite>),
    D2gc(Arc<Csr>),
    /// Incremental update batch against an open dynamic session. Always
    /// runs on the native pool (the job's `cfg`/`engine` are ignored —
    /// the session carries its own [`Config`]); applied strictly in
    /// submit order per session.
    Update { session: SessionId, batch: Arc<UpdateBatch> },
    /// Colored execution of `kernel` over an open session's current
    /// coloring, `rounds` full sweeps (see [`crate::exec`]). Always
    /// runs on the native pool with its full team (the job's `cfg` is
    /// ignored); the session's cached schedule is refreshed — dirty
    /// colors only — before the run.
    Execute { session: SessionId, kernel: ExecKernel, rounds: usize },
}

impl JobInput {
    /// The coloring problem this input runs, when it is statically
    /// known. `Update` and `Execute` jobs return `None`: the problem is
    /// a property of the open session — both session kinds share those
    /// paths — and the service resolves it when the job runs (see
    /// [`Service::session_problem`] and [`JobOutcome::problem`]).
    pub fn problem(&self) -> Option<Problem> {
        match self {
            JobInput::Bgpc(_) => Some(Problem::Bgpc),
            JobInput::D2gc(_) => Some(Problem::D2gc),
            JobInput::Update { .. } | JobInput::Execute { .. } => None,
        }
    }
}

/// Outcome delivered to the submitter.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub name: String,
    pub engine: &'static str,
    /// The problem that actually ran — for update jobs, the open
    /// session's problem. `None` only on routing errors where it is
    /// unknowable (e.g. an update against an unknown session).
    pub problem: Option<Problem>,
    pub n_colors: usize,
    pub iterations: usize,
    pub seconds: f64,
    pub valid: bool,
    pub error: Option<String>,
    /// Per-batch repair metrics (update jobs only).
    pub batch: Option<BatchStats>,
    /// Colored-execution metrics (execute jobs only).
    pub exec: Option<ExecStats>,
}

/// Per-run colored-execution metrics (execute jobs, see
/// [`crate::exec::ExecReport`] for the full per-color profile —
/// this is the service-outcome digest).
#[derive(Clone, Debug)]
pub struct ExecStats {
    /// Non-empty color frontiers driven per sweep.
    pub colors: usize,
    /// Full sweeps over the color sequence.
    pub rounds: usize,
    /// Kernel invocations (items × rounds).
    pub items: u64,
    /// Total busy work units reported by the kernel.
    pub busy_units: u64,
    /// Busy units of the costliest color set — the color-parallel
    /// critical-path term B1/B2 exist to shrink.
    pub max_color_busy: u64,
    /// Mean-over-max busy fraction across the team.
    pub utilization: f64,
    /// Items the pre-run schedule refresh moved between buckets.
    pub sched_moved: usize,
    /// Colors the refresh dirtied (0 when the coloring was unchanged).
    pub sched_dirty_colors: usize,
    /// True when the schedule was (re)built from scratch (first execute
    /// on a session) rather than diff-refreshed.
    pub sched_rebuilt: bool,
}

enum Message {
    /// A job plus its session seq (0 and unused for non-update jobs).
    Run(Job, u64, Sender<JobOutcome>),
    Stop,
}

/// The coordinator service.
pub struct Service {
    native_tx: Sender<Message>,
    pjrt_tx: Option<Sender<Message>>,
    workers: Vec<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    seq: AtomicU64,
    sessions: Arc<SessionMap>,
    session_seq: AtomicU64,
    /// The shared region-execution team every native job and session
    /// multiplexes onto (DESIGN.md §10).
    pool: Arc<WorkerPool>,
}

/// A zeroed failure [`JobOutcome`] — the shape every coordinator error
/// path reports, differing only in identity and message.
fn fail_outcome(
    name: &str,
    engine: &'static str,
    problem: Option<Problem>,
    error: String,
) -> JobOutcome {
    JobOutcome {
        name: name.to_string(),
        engine,
        problem,
        n_colors: 0,
        iterations: 0,
        seconds: 0.0,
        valid: false,
        error: Some(error),
        batch: None,
        exec: None,
    }
}

fn run_native(job: &Job, sessions: &SessionMap, seq: u64, pool: &Arc<WorkerPool>) -> JobOutcome {
    match &job.input {
        JobInput::Bgpc(g) => {
            let r = color_bgpc_on(g, &job.cfg, pool);
            let valid = crate::coloring::verify::bgpc_valid(g, &r.colors).is_ok();
            JobOutcome {
                name: job.name.clone(),
                engine: "native",
                problem: Some(Problem::Bgpc),
                n_colors: r.n_colors,
                iterations: r.iterations,
                seconds: r.seconds,
                valid,
                error: None,
                batch: None,
                exec: None,
            }
        }
        JobInput::D2gc(g) => {
            let r = color_d2gc_on(g, &job.cfg, pool);
            let valid = crate::coloring::verify::d2gc_valid(g, &r.colors).is_ok();
            JobOutcome {
                name: job.name.clone(),
                engine: "native",
                problem: Some(Problem::D2gc),
                n_colors: r.n_colors,
                iterations: r.iterations,
                seconds: r.seconds,
                valid,
                error: None,
                batch: None,
                exec: None,
            }
        }
        JobInput::Update { session, batch } => run_update(sessions, *session, seq, batch, &job.name),
        JobInput::Execute { session, kernel, rounds } => {
            run_execute(sessions, *session, kernel, *rounds, &job.name, pool)
        }
    }
}

/// Apply one update batch in session order: wait (on the slot's condvar)
/// until every earlier-seq batch has been applied, then repair.
fn run_update(
    sessions: &SessionMap,
    id: SessionId,
    seq: u64,
    batch: &UpdateBatch,
    name: &str,
) -> JobOutcome {
    let slot = sessions.lock().unwrap().get(&id).cloned();
    let Some(slot) = slot else {
        return fail_outcome(name, "native", None, format!("unknown session {id}"));
    };
    let mut inner = slot.state.lock().unwrap();
    let problem = inner.session.problem();
    while inner.applied != seq {
        if inner.closed {
            // a predecessor batch was dropped by close_session: fail
            // cleanly instead of parking forever
            return fail_outcome(
                name,
                "native",
                Some(problem),
                format!("session {id} closed before batch applied"),
            );
        }
        inner = slot.cv.wait(inner).unwrap();
    }
    if inner.closed {
        // in-order but the session was closed while this batch was
        // queued: refuse to mutate state the client can no longer see
        return fail_outcome(
            name,
            "native",
            Some(problem),
            format!("session {id} closed before batch applied"),
        );
    }
    // Apply + verify under catch_unwind: a panic here would otherwise
    // unwind while holding the slot mutex, poisoning it for every later
    // client call and hanging successors parked on `applied` — instead
    // the session is marked closed (its state may be torn mid-apply),
    // parked successors wake and fail cleanly, and the panic surfaces
    // as this job's error. The verify pass is the service contract:
    // every outcome the coordinator hands back is checked with the
    // session's own problem checker (bgpc_valid / d2gc_valid), O(|E|)
    // under the session lock; latency-sensitive clients that trust the
    // repair invariants can use DynamicSession directly.
    let applied = catch_unwind(AssertUnwindSafe(|| {
        let stats = inner.session.apply(batch);
        let valid = inner.session.verify_ok();
        (stats, valid)
    }));
    let (stats, valid) = match applied {
        Ok(x) => x,
        Err(p) => {
            // The session state may be torn mid-apply: close it AND
            // drop it from the map (exactly like close_session), so
            // clients get `None` from session_colors/session_problem
            // instead of a possibly-invalid coloring, and the dead
            // slot does not leak.
            inner.closed = true;
            slot.cv.notify_all();
            drop(inner);
            sessions.lock().unwrap().remove(&id);
            return fail_outcome(
                name,
                "native",
                Some(problem),
                format!("engine panicked: {}; session {id} closed", panic_message(p.as_ref())),
            );
        }
    };
    inner.applied += 1;
    slot.cv.notify_all();
    JobOutcome {
        name: name.to_string(),
        engine: "native",
        problem: Some(problem),
        n_colors: stats.n_colors,
        iterations: stats.iterations,
        seconds: stats.seconds,
        valid,
        error: None,
        batch: Some(stats),
        exec: None,
    }
}

/// Run a colored-execution kernel over a session's committed coloring:
/// refresh the cached [`ColorSchedule`] (dirty colors only), then drive
/// the kernel frontier-by-frontier on the shared pool. Holds the
/// session lock for the run, so executes serialize with the session's
/// updates and never observe a torn coloring. A kernel panic surfaces
/// as this job's error — the session and its schedule are *not* torn
/// by execution (kernels cannot touch them), so the session stays open.
fn run_execute(
    sessions: &SessionMap,
    id: SessionId,
    kernel: &ExecKernel,
    rounds: usize,
    name: &str,
    pool: &Arc<WorkerPool>,
) -> JobOutcome {
    let slot = sessions.lock().unwrap().get(&id).cloned();
    let Some(slot) = slot else {
        return fail_outcome(name, "native", None, format!("unknown session {id}"));
    };
    let mut guard = slot.state.lock().unwrap();
    let inner = &mut *guard;
    let problem = inner.session.problem();
    if inner.closed {
        return fail_outcome(
            name,
            "native",
            Some(problem),
            format!("session {id} closed before execute"),
        );
    }
    let colors = inner.session.colors();
    let refresh = match inner.sched.as_mut() {
        Some(s) => s.refresh(colors),
        None => {
            let s = ColorSchedule::from_colors(colors);
            let (moved, dirty_colors) = (s.n_items(), s.n_colors());
            inner.sched = Some(s);
            RefreshStats { moved, dirty_colors, rebuilt: true }
        }
    };
    let sched = inner.sched.as_ref().unwrap();
    // The kernel is client code: contain its panics like the engines'
    // (the pool resumes them on this thread; unwinding past the session
    // lock would poison it for every later job).
    let run = catch_unwind(AssertUnwindSafe(|| {
        Executor::new(pool).run(sched, rounds, |item, color| kernel.call(item, color))
    }));
    let report = match run {
        Ok(r) => r,
        Err(p) => {
            return fail_outcome(
                name,
                "native",
                Some(problem),
                format!("kernel panicked: {}", panic_message(p.as_ref())),
            )
        }
    };
    let stats = ExecStats {
        colors: sched.cardinalities().iter().filter(|&&c| c > 0).count(),
        rounds,
        items: report.items,
        busy_units: report.busy_total(),
        max_color_busy: report.max_color_busy(),
        utilization: report.utilization(),
        sched_moved: refresh.moved,
        sched_dirty_colors: refresh.dirty_colors,
        sched_rebuilt: refresh.rebuilt,
    };
    JobOutcome {
        name: name.to_string(),
        engine: "native",
        problem: Some(problem),
        n_colors: stats.colors,
        iterations: rounds,
        seconds: report.seconds,
        valid: true,
        error: None,
        batch: None,
        exec: Some(stats),
    }
}

fn run_pjrt(rt: &Runtime, job: &Job) -> JobOutcome {
    match &job.input {
        JobInput::Bgpc(g) => {
            let t0 = std::time::Instant::now();
            match NetStepOffload::new(rt).color(g, 50) {
                Ok((colors, stats)) => {
                    let valid = crate::coloring::verify::bgpc_valid(g, &colors).is_ok();
                    JobOutcome {
                        name: job.name.clone(),
                        engine: "pjrt",
                        problem: Some(Problem::Bgpc),
                        n_colors: crate::coloring::stats::distinct_colors(&colors),
                        iterations: stats.iterations,
                        seconds: t0.elapsed().as_secs_f64(),
                        valid,
                        error: None,
                        batch: None,
                        exec: None,
                    }
                }
                Err(e) => JobOutcome {
                    seconds: t0.elapsed().as_secs_f64(),
                    ..fail_outcome(&job.name, "pjrt", Some(Problem::Bgpc), format!("{e:#}"))
                },
            }
        }
        JobInput::D2gc(_) | JobInput::Update { .. } | JobInput::Execute { .. } => fail_outcome(
            &job.name,
            "pjrt",
            job.input.problem(),
            "PJRT engine only supports BGPC jobs".into(),
        ),
    }
}

impl Service {
    /// Start `n_native` native dispatchers over a
    /// [`DEFAULT_POOL_THREADS`]-wide shared pool; if `artifacts` is
    /// given and loads, also start one PJRT worker owning the compiled
    /// executables. See [`Service::start_with`] for the pool knob.
    pub fn start(n_native: usize, artifacts: Option<std::path::PathBuf>) -> Service {
        Service::start_with(n_native, DEFAULT_POOL_THREADS, artifacts)
    }

    /// [`Service::start`] with an explicit region-execution pool size.
    ///
    /// Two thread populations exist, spawned here once and never again:
    /// `n_native` *dispatchers* (they pop the job queue, order session
    /// updates, and block on outcomes — control plane) and one
    /// `pool_threads`-wide [`WorkerPool`] that executes every parallel
    /// region of every threads-mode job and session (data plane).
    /// Sessions interleave on the team region-by-region; full-recolor
    /// jobs additionally serialize on the pool-resident scratch bank
    /// for their whole run. A job's `cfg.threads` is clamped to the
    /// pool size. A panic inside an
    /// engine (a structural assert, a driver contract violation)
    /// surfaces as a failed [`JobOutcome`] — the dispatcher and the
    /// pool both survive.
    pub fn start_with(
        n_native: usize,
        pool_threads: usize,
        artifacts: Option<std::path::PathBuf>,
    ) -> Service {
        let metrics = Arc::new(Metrics::default());
        let sessions: Arc<SessionMap> = Arc::new(Mutex::new(HashMap::new()));
        let pool = Arc::new(WorkerPool::new(pool_threads.max(1)));
        let (native_tx, native_rx) = channel::<Message>();
        let native_rx = Arc::new(std::sync::Mutex::new(native_rx));
        let mut workers = Vec::new();
        for _ in 0..n_native.max(1) {
            let rx = Arc::clone(&native_rx);
            let m = Arc::clone(&metrics);
            let sess = Arc::clone(&sessions);
            let pl = Arc::clone(&pool);
            workers.push(std::thread::spawn(move || loop {
                let msg = { rx.lock().unwrap().recv() };
                match msg {
                    Ok(Message::Run(job, seq, out)) => {
                        let o = catch_unwind(AssertUnwindSafe(|| run_native(&job, &sess, seq, &pl)))
                            .unwrap_or_else(|p| {
                                fail_outcome(
                                    &job.name,
                                    "native",
                                    job.input.problem(),
                                    format!("engine panicked: {}", panic_message(p.as_ref())),
                                )
                            });
                        m.record(&o);
                        let _ = out.send(o);
                    }
                    Ok(Message::Stop) | Err(_) => break,
                }
            }));
        }

        // PJRT handles are not Send: the runtime must be created *inside*
        // its worker thread; a oneshot reports whether loading succeeded.
        let pjrt_tx = artifacts.and_then(|dir| {
            let (tx, rx) = channel::<Message>();
            let (ready_tx, ready_rx) = channel::<Result<(), String>>();
            let m = Arc::clone(&metrics);
            let handle = std::thread::spawn(move || {
                let rt = match Runtime::load(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                loop {
                    match rx.recv() {
                        Ok(Message::Run(job, _seq, out)) => {
                            let o = run_pjrt(&rt, &job);
                            m.record(&o);
                            let _ = out.send(o);
                        }
                        Ok(Message::Stop) | Err(_) => break,
                    }
                }
            });
            match ready_rx.recv() {
                Ok(Ok(())) => {
                    workers.push(handle);
                    Some(tx)
                }
                Ok(Err(e)) => {
                    eprintln!("coordinator: PJRT engine unavailable: {e}");
                    let _ = handle.join();
                    None
                }
                Err(_) => None,
            }
        });

        Service {
            native_tx,
            pjrt_tx,
            workers,
            metrics,
            seq: AtomicU64::new(0),
            sessions,
            session_seq: AtomicU64::new(0),
            pool,
        }
    }

    /// Route a job; returns the outcome receiver.
    pub fn submit(&self, mut job: Job) -> Receiver<JobOutcome> {
        if job.name.is_empty() {
            job.name = format!("job-{}", self.seq.fetch_add(1, AOrd::Relaxed));
        }
        let (tx, rx) = channel();
        // Updates bypass engine selection: they are session-ordered and
        // always native. The seq assignment and the channel send happen
        // under one lock so seq order == queue order — otherwise two
        // racing submitters could enqueue seq 1 ahead of seq 0 and park
        // a worker (or the whole pool) on a predecessor stuck behind it.
        if let JobInput::Update { session, .. } = &job.input {
            let id = *session;
            let sessions = self.sessions.lock().unwrap();
            match sessions.get(&id) {
                Some(slot) => {
                    let seq = slot.submitted.fetch_add(1, AOrd::SeqCst);
                    let _ = self.native_tx.send(Message::Run(job, seq, tx));
                }
                None => {
                    let _ = tx.send(fail_outcome(
                        &job.name,
                        "native",
                        None,
                        format!("unknown session {id}"),
                    ));
                }
            }
            return rx;
        }
        let use_pjrt = match job.engine {
            EngineSel::Pjrt => true,
            EngineSel::Native => false,
            EngineSel::Auto => {
                self.pjrt_tx.is_some() && matches!(job.input, JobInput::Bgpc(_))
            }
        };
        if use_pjrt {
            match &self.pjrt_tx {
                Some(ptx) => {
                    let _ = ptx.send(Message::Run(job, 0, tx));
                }
                None => {
                    let _ = tx.send(fail_outcome(
                        &job.name,
                        "pjrt",
                        job.input.problem(),
                        "PJRT engine not loaded (run `make artifacts`)".into(),
                    ));
                }
            }
        } else {
            let _ = self.native_tx.send(Message::Run(job, 0, tx));
        }
        rx
    }

    /// Open a BGPC dynamic session: color `g` from scratch under `cfg`
    /// (synchronously, on the caller's thread) and keep the session
    /// alive inside the service. Stream [`JobInput::Update`] jobs
    /// against the returned id, then [`Service::close_session`].
    pub fn open_session(&self, name: &str, g: &Bipartite, cfg: Config) -> (SessionId, JobOutcome) {
        let (mut session, init) =
            crate::dynamic::DynamicSession::start_on(g.clone(), cfg, &self.pool);
        let valid = session.verify().is_ok();
        self.install_session(name, AnySession::Bgpc(session), &init, valid)
    }

    /// Open a D2GC dynamic session over a square, structurally
    /// symmetric graph: same contract as [`Service::open_session`], but
    /// updates are undirected edge edits repaired at distance 2 (the
    /// overlay keeps the pattern symmetric across the stream).
    ///
    /// # Panics
    /// If `g` is not square and structurally symmetric.
    pub fn open_session_d2gc(&self, name: &str, g: &Csr, cfg: Config) -> (SessionId, JobOutcome) {
        let (mut session, init) =
            crate::dynamic::DynamicSession::start_on(g.clone(), cfg, &self.pool);
        let valid = session.verify().is_ok();
        self.install_session(name, AnySession::D2gc(session), &init, valid)
    }

    /// Shared tail of the `open_session*` pair: record the bring-up
    /// outcome and park the session under a fresh id.
    fn install_session(
        &self,
        name: &str,
        session: AnySession,
        init: &crate::coloring::ColoringResult,
        valid: bool,
    ) -> (SessionId, JobOutcome) {
        let outcome = JobOutcome {
            name: name.to_string(),
            engine: "native",
            problem: Some(session.problem()),
            n_colors: init.n_colors,
            iterations: init.iterations,
            seconds: init.seconds,
            valid,
            error: None,
            batch: None,
            exec: None,
        };
        self.metrics.record(&outcome);
        let id = self.session_seq.fetch_add(1, AOrd::Relaxed) + 1;
        self.sessions.lock().unwrap().insert(
            id,
            Arc::new(SessionSlot {
                submitted: AtomicU64::new(0),
                state: Mutex::new(SessionInner {
                    session,
                    applied: 0,
                    closed: false,
                    sched: None,
                }),
                cv: Condvar::new(),
            }),
        );
        (id, outcome)
    }

    /// Submit a colored-execution job against an open session: run
    /// `kernel` over the session's current coloring, `rounds` full
    /// color sweeps, on the shared pool (see [`JobInput::Execute`]).
    /// Convenience over [`Service::submit`]; returns the outcome
    /// receiver. Queued-but-unapplied updates are not waited for — the
    /// run observes the committed coloring when it acquires the
    /// session.
    pub fn execute(
        &self,
        name: &str,
        session: SessionId,
        rounds: usize,
        kernel: ExecKernel,
    ) -> Receiver<JobOutcome> {
        self.submit(Job {
            name: name.to_string(),
            input: JobInput::Execute { session, kernel, rounds },
            // Execute jobs ignore the config (the executor runs on the
            // shared pool with its full team); any well-formed value
            // satisfies the Job shape.
            cfg: Config::threads(crate::coloring::schedule::N1_N2, self.pool.threads()),
            engine: EngineSel::Native,
        })
    }

    /// Snapshot a session's current committed coloring (batches applied
    /// so far; does not wait for still-queued updates).
    pub fn session_colors(&self, id: SessionId) -> Option<Vec<i32>> {
        let slot = self.sessions.lock().unwrap().get(&id).cloned()?;
        let inner = slot.state.lock().unwrap();
        Some(inner.session.colors().to_vec())
    }

    /// The problem an open session repairs (`None` if the id is
    /// unknown) — the authoritative answer [`JobInput::problem`] cannot
    /// give for `Update` jobs.
    pub fn session_problem(&self, id: SessionId) -> Option<Problem> {
        let slot = self.sessions.lock().unwrap().get(&id).cloned()?;
        let inner = slot.state.lock().unwrap();
        Some(inner.session.problem())
    }

    /// Close a session. The update a worker is currently applying still
    /// completes; updates parked behind a predecessor that can no longer
    /// arrive are woken and fail cleanly ("session closed"); later
    /// submits error with "unknown session". Returns whether the id was
    /// open.
    pub fn close_session(&self, id: SessionId) -> bool {
        let slot = self.sessions.lock().unwrap().remove(&id);
        match slot {
            Some(slot) => {
                slot.state.lock().unwrap().closed = true;
                slot.cv.notify_all();
                true
            }
            None => false,
        }
    }

    /// Whether the PJRT engine is up.
    pub fn has_pjrt(&self) -> bool {
        self.pjrt_tx.is_some()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The shared region-execution pool (open sessions against it,
    /// inspect it, or borrow it for ad-hoc drivers).
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Region-dispatch and worker-utilization counters of the shared
    /// pool — the execution-substrate metrics that complement the
    /// per-job [`Metrics`].
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Stop all workers and join them.
    pub fn shutdown(self) {
        for _ in 0..self.workers.len() {
            let _ = self.native_tx.send(Message::Stop);
        }
        if let Some(ptx) = &self.pjrt_tx {
            let _ = ptx.send(Message::Stop);
        }
        drop(self.native_tx);
        drop(self.pjrt_tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::schedule;
    use crate::graph::generators::random_bipartite;

    #[test]
    fn native_jobs_round_trip() {
        let svc = Service::start(2, None);
        let g = Arc::new(random_bipartite(100, 150, 1200, 21));
        let mut rxs = Vec::new();
        for (i, spec) in schedule::ALL.iter().enumerate() {
            rxs.push(svc.submit(Job {
                name: format!("j{i}"),
                input: JobInput::Bgpc(Arc::clone(&g)),
                cfg: Config::sim(*spec, 4),
                engine: EngineSel::Native,
            }));
        }
        for rx in rxs {
            let o = rx.recv().unwrap();
            assert!(o.valid, "{}: {:?}", o.name, o.error);
            assert!(o.n_colors > 0);
        }
        assert_eq!(svc.metrics().jobs_done(), 8);
        svc.shutdown();
    }

    #[test]
    fn threads_jobs_multiplex_onto_the_shared_pool() {
        use crate::graph::generators::random_symmetric;
        let svc = Service::start_with(2, 4, None);
        assert_eq!(svc.pool_stats().threads, 4);
        let g = Arc::new(random_bipartite(120, 180, 1400, 5));
        let m = Arc::new(random_symmetric(80, 300, 7));
        let mut rxs = Vec::new();
        for i in 0..4 {
            rxs.push(svc.submit(Job {
                name: format!("t{i}"),
                // cfg.threads is clamped to the pool size (8 -> 4)
                input: JobInput::Bgpc(Arc::clone(&g)),
                cfg: Config::threads(schedule::ALL[i % schedule::ALL.len()], 8),
                engine: EngineSel::Native,
            }));
        }
        rxs.push(svc.submit(Job {
            name: "t-d2".into(),
            input: JobInput::D2gc(Arc::clone(&m)),
            cfg: Config::threads(schedule::V_N2, 4),
            engine: EngineSel::Native,
        }));
        for rx in rxs {
            let o = rx.recv().unwrap();
            assert!(o.valid, "{}: {:?}", o.name, o.error);
        }
        let st = svc.pool_stats();
        assert!(st.regions > 0, "regions must dispatch onto the shared pool");
        assert!(st.items > 0);
        assert!(st.utilization() > 0.0 && st.utilization() <= 1.0);
        svc.shutdown();
    }

    #[test]
    fn engine_panic_becomes_job_error_and_worker_survives() {
        // A non-square D2GC job trips the engine's structural assert on
        // the dispatcher. The old behaviour poisoned the worker thread;
        // now the panic surfaces through JobOutcome and the service
        // keeps serving.
        let svc = Service::start(1, None);
        let bad = Arc::new(crate::graph::Csr::from_edges(3, 4, &[(0, 1), (1, 0), (2, 3)]));
        let o = svc
            .submit(Job {
                name: "bad".into(),
                input: JobInput::D2gc(bad),
                cfg: Config::sim(schedule::N1_N2, 2),
                engine: EngineSel::Native,
            })
            .recv()
            .unwrap();
        assert!(!o.valid);
        let err = o.error.expect("panic must surface as an error");
        assert!(err.contains("square"), "unexpected message: {err}");
        assert_eq!(svc.metrics().failures(), 1);
        // the single dispatcher survived: a healthy job still completes
        let g = Arc::new(random_bipartite(40, 60, 300, 2));
        let o = svc
            .submit(Job {
                name: "good".into(),
                input: JobInput::Bgpc(g),
                cfg: Config::sim(schedule::V_N2, 2),
                engine: EngineSel::Native,
            })
            .recv()
            .unwrap();
        assert!(o.valid, "{:?}", o.error);
        svc.shutdown();
    }

    #[test]
    fn pjrt_request_without_artifacts_errors_cleanly() {
        let svc = Service::start(1, None);
        let g = Arc::new(random_bipartite(10, 20, 60, 1));
        let rx = svc.submit(Job {
            name: "x".into(),
            input: JobInput::Bgpc(g),
            cfg: Config::sim(schedule::N1_N2, 2),
            engine: EngineSel::Pjrt,
        });
        let o = rx.recv().unwrap();
        assert!(!o.valid);
        assert!(o.error.unwrap().contains("artifacts"));
        svc.shutdown();
    }

    #[test]
    fn dynamic_session_streams_ordered_batches() {
        use crate::dynamic::UpdateBatch;
        let svc = Service::start(2, None);
        let g = random_bipartite(80, 120, 900, 77);
        let (sid, init) = svc.open_session("sess", &g, Config::sim(schedule::N1_N2, 4));
        assert!(init.valid, "initial coloring must verify");
        assert!(init.n_colors > 0);
        // three dependent batches streamed through two workers: the
        // seq/condvar handshake must apply them in submit order.
        let mut rxs = Vec::new();
        for k in 0..3u32 {
            let mut batch = UpdateBatch::default();
            for i in 0..10u32 {
                batch.add_edges.push(((k * 7 + i) % 80, (k * 11 + i * 3) % 120));
            }
            rxs.push(svc.submit(Job {
                name: format!("u{k}"),
                input: JobInput::Update { session: sid, batch: Arc::new(batch) },
                cfg: Config::sim(schedule::N1_N2, 4),
                engine: EngineSel::Auto,
            }));
        }
        for rx in rxs {
            let o = rx.recv().unwrap();
            assert!(o.valid, "{}: {:?}", o.name, o.error);
            assert_eq!(o.problem, Some(Problem::Bgpc), "update reports the session's problem");
            let b = o.batch.expect("update outcomes carry batch stats");
            assert!(b.dirty_nets > 0 || b.batch_edits == 0);
        }
        assert_eq!(svc.session_problem(sid), Some(Problem::Bgpc));
        let colors = svc.session_colors(sid).expect("session open");
        assert_eq!(colors.len(), 120);
        assert!(colors.iter().all(|&c| c >= 0));
        assert!(svc.close_session(sid));
        assert!(!svc.close_session(sid), "second close is a no-op");
        assert!(svc.session_colors(sid).is_none());
        svc.shutdown();
    }

    #[test]
    fn d2gc_session_streams_through_the_same_update_path() {
        use crate::dynamic::UpdateBatch;
        use crate::graph::generators::random_symmetric;
        let svc = Service::start(2, None);
        let g = random_symmetric(100, 500, 9);
        let (sid, init) = svc.open_session_d2gc("hessian", &g, Config::sim(schedule::N1_N2, 4));
        assert!(init.valid, "initial D2GC coloring must verify");
        assert_eq!(init.problem, Some(Problem::D2gc));
        assert_eq!(svc.session_problem(sid), Some(Problem::D2gc));
        let mut rxs = Vec::new();
        for k in 0..2u32 {
            let mut batch = UpdateBatch::default();
            for i in 0..8u32 {
                let a = (k * 13 + i * 7) % 100;
                let b = (k * 31 + i * 11) % 100;
                batch.add_edges.push((a, b));
            }
            rxs.push(svc.submit(Job {
                name: format!("h{k}"),
                input: JobInput::Update { session: sid, batch: Arc::new(batch) },
                cfg: Config::sim(schedule::N1_N2, 4),
                engine: EngineSel::Auto,
            }));
        }
        for rx in rxs {
            let o = rx.recv().unwrap();
            assert!(o.valid, "{}: {:?}", o.name, o.error);
            assert_eq!(o.problem, Some(Problem::D2gc), "update reports the session's problem");
            assert!(o.batch.is_some());
        }
        assert_eq!(svc.metrics().updates_d2gc(), 2);
        assert_eq!(svc.metrics().updates_bgpc(), 0);
        let colors = svc.session_colors(sid).expect("session open");
        assert_eq!(colors.len(), 100);
        assert!(colors.iter().all(|&c| c >= 0));
        assert!(svc.close_session(sid));
        svc.shutdown();
    }

    #[test]
    fn update_to_unknown_session_errors_cleanly() {
        use crate::dynamic::UpdateBatch;
        let svc = Service::start(1, None);
        let rx = svc.submit(Job {
            name: "nope".into(),
            input: JobInput::Update { session: 999, batch: Arc::new(UpdateBatch::default()) },
            cfg: Config::sim(schedule::N1_N2, 2),
            engine: EngineSel::Native,
        });
        let o = rx.recv().unwrap();
        assert!(!o.valid);
        assert!(o.error.unwrap().contains("unknown session"));
        assert!(o.batch.is_none());
        svc.shutdown();
    }

    #[test]
    fn execute_runs_colored_kernel_over_a_session() {
        use crate::exec::SharedBuf;
        let svc = Service::start(2, None);
        let g = Arc::new(random_bipartite(80, 120, 900, 13));
        let (sid, init) = svc.open_session("exec", &g, Config::sim(schedule::N1_N2, 4));
        assert!(init.valid);
        let acc = Arc::new(SharedBuf::new(vec![0u64; g.n_nets()]));
        let kernel = {
            let g = Arc::clone(&g);
            let acc = Arc::clone(&acc);
            ExecKernel::new(move |item, _color| {
                let mut units = 0u64;
                for &v in g.nets(item) {
                    // SAFETY: no two columns in one color share a net,
                    // and colors are separated by the executor barrier.
                    unsafe { *acc.slot(v as usize) += (item as u64 + 1) * (v as u64 + 1) };
                    units += 1;
                }
                Cost::new(units)
            })
        };
        let o = svc.execute("run", sid, 2, kernel).recv().unwrap();
        assert!(o.valid, "{:?}", o.error);
        assert_eq!(o.problem, Some(Problem::Bgpc));
        let e = o.exec.expect("execute outcomes carry exec stats");
        assert!(e.sched_rebuilt, "first execute builds the schedule");
        assert_eq!(e.rounds, 2);
        assert_eq!(e.items, 2 * g.n_vertices() as u64);
        assert_eq!(e.busy_units, 2 * g.nnz() as u64);
        assert!(e.max_color_busy > 0 && e.max_color_busy <= e.busy_units);
        // bit-for-bit equal to the sequential sweep (integer arithmetic)
        let mut want = vec![0u64; g.n_nets()];
        for u in 0..g.n_vertices() {
            for &v in g.nets(u) {
                want[v as usize] += 2 * (u as u64 + 1) * (v as u64 + 1);
            }
        }
        // SAFETY: the job completed — no kernel is writing.
        let got: Vec<u64> = (0..g.n_nets()).map(|v| unsafe { *acc.peek(v) }).collect();
        assert_eq!(got, want, "colored execution must equal the sequential sweep");
        assert_eq!(svc.metrics().executes(), 1);
        assert_eq!(svc.metrics().exec_items(), e.items);
        assert!(svc.close_session(sid));
        svc.shutdown();
    }

    #[test]
    fn execute_refreshes_only_dirty_colors_after_updates() {
        use crate::dynamic::UpdateBatch;
        let svc = Service::start(1, None);
        let g = random_bipartite(100, 150, 1200, 31);
        let (sid, _init) = svc.open_session("s", &g, Config::sim(schedule::N1_N2, 4));
        let noop = ExecKernel::new(|_item, _color| Cost::new(1));
        let e0 = svc.execute("e0", sid, 1, noop.clone()).recv().unwrap().exec.unwrap();
        assert!(e0.sched_rebuilt);
        assert_eq!(e0.sched_moved, 150, "first build places every item");
        // no updates in between: nothing moves
        let e1 = svc.execute("e1", sid, 1, noop.clone()).recv().unwrap().exec.unwrap();
        assert!(!e1.sched_rebuilt);
        assert_eq!(e1.sched_moved, 0);
        assert_eq!(e1.sched_dirty_colors, 0);
        // an update batch dirties only the repaired frontier
        let mut batch = UpdateBatch::default();
        for i in 0..12u32 {
            batch.add_edges.push((i % 100, (i * 7) % 150));
        }
        let u = svc
            .submit(Job {
                name: "u".into(),
                input: JobInput::Update { session: sid, batch: Arc::new(batch) },
                cfg: Config::sim(schedule::N1_N2, 4),
                engine: EngineSel::Auto,
            })
            .recv()
            .unwrap();
        assert!(u.valid, "{:?}", u.error);
        let recolored = u.batch.unwrap().recolored;
        let e2 = svc.execute("e2", sid, 1, noop).recv().unwrap().exec.unwrap();
        assert!(!e2.sched_rebuilt, "post-update refresh must be incremental");
        assert!(
            e2.sched_moved <= recolored,
            "refresh moved {} items but the repair recolored only {recolored}",
            e2.sched_moved
        );
        svc.shutdown();
    }

    #[test]
    fn execute_errors_cleanly_and_survives_kernel_panics() {
        let svc = Service::start(1, None);
        let o = svc
            .execute("nope", 777, 1, ExecKernel::new(|_, _| Cost::new(1)))
            .recv()
            .unwrap();
        assert!(!o.valid);
        assert!(o.error.unwrap().contains("unknown session"));
        let g = random_bipartite(40, 60, 300, 7);
        let (sid, _init) = svc.open_session("s", &g, Config::sim(schedule::V_N2, 2));
        let bomb = ExecKernel::new(|item, _color| {
            assert!(item != 3, "planted kernel failure");
            Cost::new(1)
        });
        let o = svc.execute("boom", sid, 1, bomb).recv().unwrap();
        assert!(!o.valid);
        let err = o.error.expect("kernel panic must surface as an error");
        assert!(err.contains("kernel panicked"), "unexpected message: {err}");
        // the session and the dispatcher both survive the client's bug
        let o = svc.execute("ok", sid, 1, ExecKernel::new(|_, _| Cost::new(1))).recv().unwrap();
        assert!(o.valid, "{:?}", o.error);
        assert!(svc.close_session(sid));
        svc.shutdown();
    }

    #[test]
    fn auto_routes_to_native_without_pjrt() {
        let svc = Service::start(1, None);
        assert!(!svc.has_pjrt());
        let g = Arc::new(random_bipartite(50, 60, 300, 3));
        let o = svc
            .submit(Job {
                name: String::new(),
                input: JobInput::Bgpc(g),
                cfg: Config::sim(schedule::V_N2, 2),
                engine: EngineSel::Auto,
            })
            .recv()
            .unwrap();
        assert_eq!(o.engine, "native");
        assert!(o.valid);
        svc.shutdown();
    }
}
