//! Coloring job coordinator — the L3 service layer, sharded and async.
//!
//! A [`Service`] owns a finely-sharded MPMC admission queue
//! ([`crate::par::ShardedQueue`]), a set of native *dispatchers* that
//! pop it (stealing from sibling shards when their home shard is dry),
//! a [`crate::par::PoolSet`] of region-execution [`WorkerPool`] teams
//! (one per shard, DESIGN.md §10/§12), and (optionally) one PJRT worker
//! holding the compiled net-step artifacts. Clients
//! [`Service::submit_async`] jobs and get a [`JobHandle`] back
//! immediately — `wait` blocks for the [`JobOutcome`], `try_poll` never
//! blocks. Admission takes no service-wide lock and no lock is ever
//! held while a dispatcher waits for work (the queue parks on its own
//! tick condvar, not on a shard mutex around a channel).
//!
//! **Sessions and epochs** (DESIGN.md §12): each open dynamic session
//! is pinned to a shard (`id % shards`) and runs its repairs on that
//! shard's pool. Updates are *admitted* to a per-session pending queue
//! (seq assigned under the pending lock, so seq order == queue order)
//! and *applied* by whichever dispatcher drains the session — the drain
//! holds the session state lock, pulls up to `fuse_updates` contiguous
//! batches, and applies them as ONE fused
//! [`crate::dynamic::DynamicSession::apply_many`] group: one overlay
//! edit pass per batch, then a single compact + repair + verify for the
//! whole group. Every committed group publishes a fresh immutable
//! [`Snapshot`] — `{epoch, Arc<colors>}` — *before* completing its
//! handles, so [`Service::session_colors`] and [`JobInput::Execute`]
//! runs read the last committed epoch without touching the session
//! state lock: reads and executes proceed while a repair is in flight
//! (they may lag it by exactly one epoch, never observe a torn one).
//!
//! **Colored execution** (DESIGN.md §11): [`JobInput::Execute`] runs a
//! client [`ExecKernel`] over the session's snapshot coloring on the
//! session's shard pool. The per-session [`EpochSchedule`] caches the
//! [`crate::exec::ColorSchedule`] keyed by epoch — same epoch: no
//! refresh at all; new epoch: only the colors the repair dirtied are
//! rebuilt. Engine and kernel panics surface as failed outcomes; a
//! panic mid-repair closes and unregisters the session (torn state is
//! never served), a kernel panic leaves the session and its shard
//! healthy. [`Metrics`] additionally histograms per-job queue-wait and
//! service time (p50/p99 via [`Metrics::queue_wait_quantile`] /
//! [`Metrics::service_time_quantile`]).

pub mod metrics;

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering as AOrd};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coloring::{Colorer, Config, Problem};
use crate::dynamic::{BatchStats, BgpcSession, D1Graph, D1gcSession, D2gcSession, UpdateBatch};
use crate::exec::{EpochSchedule, Executor};
use crate::graph::{Bipartite, Csr};
use crate::obs::trace::{span, span_n};
use crate::par::pool::panic_message;
use crate::par::{Cost, PoolSet, PoolStats, QueueStats, ShardedQueue, WorkerPool};
use crate::runtime::{NetStepOffload, Runtime};

pub use metrics::Metrics;

/// Default per-shard size of the region-execution [`WorkerPool`]s (see
/// [`ServiceOpts::pool_threads`] to pick another).
pub const DEFAULT_POOL_THREADS: usize = 4;

/// Identifier of an open dynamic session (see [`Service::open_session`],
/// [`Service::open_session_d2gc`], and [`Service::open_session_d1gc`]).
pub type SessionId = u64;

/// A problem-tagged dynamic session as the service stores it. The
/// instantiations of [`crate::dynamic::DynamicSession`] share one
/// update path; this enum is the runtime dispatch point that routes a
/// fused batch group to the right repair engine.
enum AnySession {
    Bgpc(BgpcSession),
    D2gc(D2gcSession),
    D1gc(D1gcSession),
}

impl AnySession {
    fn problem(&self) -> Problem {
        match self {
            AnySession::Bgpc(_) => Problem::Bgpc,
            AnySession::D2gc(_) => Problem::D2gc,
            AnySession::D1gc(_) => Problem::D1gc,
        }
    }

    /// Apply a contiguous group of batches as one fused repair (one
    /// compact + repair + verify for the whole group; per-batch edit
    /// order is preserved — see `DynamicSession::apply_many`).
    fn apply_many(&mut self, batches: &[&UpdateBatch]) -> BatchStats {
        match self {
            AnySession::Bgpc(s) => s.apply_many(batches),
            AnySession::D2gc(s) => s.apply_many(batches),
            AnySession::D1gc(s) => s.apply_many(batches),
        }
    }

    fn verify_ok(&mut self) -> bool {
        match self {
            AnySession::Bgpc(s) => s.verify().is_ok(),
            AnySession::D2gc(s) => s.verify().is_ok(),
            AnySession::D1gc(s) => s.verify().is_ok(),
        }
    }

    /// The committed coloring as a shared immutable snapshot (repairs
    /// install a fresh `Arc`, they never mutate a published one).
    fn colors_arc(&self) -> Arc<Vec<i32>> {
        match self {
            AnySession::Bgpc(s) => s.colors_arc(),
            AnySession::D2gc(s) => s.colors_arc(),
            AnySession::D1gc(s) => s.colors_arc(),
        }
    }
}

/// An immutable committed-coloring snapshot, double-buffered behind the
/// session's `snap` slot: epoch `k` means "after the `k`-th committed
/// update batch" (0 = the bring-up coloring). Readers and executes
/// clone the `Arc` and drop the lock — a repair in flight never blocks
/// them and never tears what they see.
struct Snapshot {
    epoch: u64,
    colors: Arc<Vec<i32>>,
}

/// One update admitted to a session's pending queue but not yet
/// applied.
struct PendingUpdate {
    seq: u64,
    batch: Arc<UpdateBatch>,
    name: String,
    handle: JobHandle,
    submitted: Instant,
}

/// Per-session admission queue: seq assignment and FIFO order live
/// under one small lock, taken only for queue surgery — never while a
/// repair runs or a dispatcher waits.
#[derive(Default)]
struct PendingQueue {
    next_seq: u64,
    items: VecDeque<PendingUpdate>,
    closed: bool,
}

/// A session as the service holds it. Lock order (when holding more
/// than one): `state` → `pending`; `snap` and `sched` are leaf locks.
/// The submit path takes map → `pending` only; the read/execute paths
/// take `snap` (+ `sched`) only — neither ever touches `state`, which
/// is exactly what lets them proceed while a drain holds it.
struct SessionSlot {
    /// The shard (pool + queue lane) this session is pinned to.
    shard: usize,
    /// The session's problem, readable without any lock.
    problem: Problem,
    pending: Mutex<PendingQueue>,
    state: Mutex<SessionInner>,
    /// Last committed epoch snapshot (published before handles
    /// complete; swapped, never mutated).
    snap: Mutex<Arc<Snapshot>>,
    /// Epoch-keyed cached execution frontiers ([`crate::exec`]).
    sched: Mutex<EpochSchedule>,
}

struct SessionInner {
    session: AnySession,
    /// Batches committed so far == the current epoch == the next
    /// admissible seq.
    applied: u64,
    /// Set by close or a mid-repair panic; pending items fail cleanly.
    closed: bool,
}

type SessionMap = Mutex<HashMap<SessionId, Arc<SessionSlot>>>;

/// Which engine a job should run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineSel {
    /// Router decides: PJRT for BGPC jobs whose nets fit a bucket (when
    /// artifacts are loaded), native otherwise.
    Auto,
    /// Native engine (simulator or real threads per the job's Config).
    Native,
    /// The AOT JAX/Pallas net-step path.
    Pjrt,
}

/// A type-erased colored-execution kernel: `(item, color) -> Cost`
/// (see [`crate::exec::Executor::run`]). Shared state lives in the
/// closure's captures (e.g. an `Arc<`[`crate::exec::SharedBuf`]`>`);
/// the schedule's conflict-freedom is what makes lock-free mutation of
/// it sound. Cheap to clone — jobs carry it by `Arc`.
#[derive(Clone)]
pub struct ExecKernel(Arc<dyn Fn(usize, usize) -> Cost + Send + Sync>);

impl ExecKernel {
    pub fn new(f: impl Fn(usize, usize) -> Cost + Send + Sync + 'static) -> ExecKernel {
        ExecKernel(Arc::new(f))
    }

    /// Invoke the kernel on `(item, color)`.
    pub fn call(&self, item: usize, color: usize) -> Cost {
        (self.0)(item, color)
    }
}

/// A coloring job.
#[derive(Clone)]
pub struct Job {
    pub name: String,
    pub input: JobInput,
    pub cfg: Config,
    pub engine: EngineSel,
}

/// Job payload (graphs are shared; the service never copies them).
#[derive(Clone)]
pub enum JobInput {
    Bgpc(Arc<Bipartite>),
    D2gc(Arc<Csr>),
    /// Distance-1 coloring of a square, structurally symmetric graph
    /// (the survey baseline at full engine parity — DESIGN.md §14).
    D1gc(Arc<Csr>),
    /// Incremental update batch against an open dynamic session. Always
    /// runs on the session's shard pool (the job's `cfg`/`engine` are
    /// ignored — the session carries its own [`Config`]); applied
    /// strictly in submit order per session, possibly fused with
    /// adjacent tiny batches into one repair.
    Update { session: SessionId, batch: Arc<UpdateBatch> },
    /// Colored execution of `kernel` over an open session's last
    /// committed epoch snapshot, `rounds` full sweeps (see
    /// [`crate::exec`]). Always runs native on the session's shard pool
    /// (the job's `cfg` is ignored); the session's epoch-keyed schedule
    /// is refreshed — dirty colors only — before the run.
    Execute { session: SessionId, kernel: ExecKernel, rounds: usize },
    /// Observability snapshot: completes with the service's registry
    /// exposition (job counters, latency histograms, pool and queue
    /// gauges) in [`JobOutcome::text`]. Flows through the same
    /// admission queue as real work, so the snapshot is ordered after
    /// everything admitted before it on its shard. The job's
    /// `cfg`/`engine` are ignored.
    Stats,
}

impl JobInput {
    /// The coloring problem this input runs, when it is statically
    /// known. `Update` and `Execute` jobs return `None`: the problem is
    /// a property of the open session — both session kinds share those
    /// paths — and the service resolves it when the job runs (see
    /// [`Service::session_problem`] and [`JobOutcome::problem`]).
    pub fn problem(&self) -> Option<Problem> {
        match self {
            JobInput::Bgpc(_) => Some(Problem::Bgpc),
            JobInput::D2gc(_) => Some(Problem::D2gc),
            JobInput::D1gc(_) => Some(Problem::D1gc),
            JobInput::Update { .. } | JobInput::Execute { .. } | JobInput::Stats => None,
        }
    }
}

/// Outcome delivered through the [`JobHandle`].
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub name: String,
    pub engine: &'static str,
    /// The problem that actually ran — for update jobs, the open
    /// session's problem. `None` only on routing errors where it is
    /// unknowable (e.g. an update against an unknown session).
    pub problem: Option<Problem>,
    pub n_colors: usize,
    pub iterations: usize,
    pub seconds: f64,
    pub valid: bool,
    pub error: Option<String>,
    /// Per-group repair metrics (update jobs only; shared by every
    /// member of a fused group).
    pub batch: Option<BatchStats>,
    /// Colored-execution metrics (execute jobs only).
    pub exec: Option<ExecStats>,
    /// Text payload ([`JobInput::Stats`] jobs only): the registry
    /// exposition snapshot at the moment the job was dispatched.
    pub text: Option<String>,
    /// Size of the fused drain group this update committed with: 0 for
    /// non-update jobs, 1 when the batch was applied alone, N when N
    /// contiguous batches shared one compact + repair + verify.
    pub fused: usize,
    /// The session epoch this outcome observed or committed: update
    /// jobs report the epoch their group committed (== batches applied
    /// so far), execute jobs the snapshot epoch the run was scheduled
    /// against, session bring-up `Some(0)`. `None` for stateless jobs
    /// and routing errors.
    pub epoch: Option<u64>,
}

/// Per-run colored-execution metrics (execute jobs, see
/// [`crate::exec::ExecReport`] for the full per-color profile —
/// this is the service-outcome digest).
#[derive(Clone, Debug)]
pub struct ExecStats {
    /// Non-empty color frontiers driven per sweep.
    pub colors: usize,
    /// Full sweeps over the color sequence.
    pub rounds: usize,
    /// Kernel invocations (items × rounds).
    pub items: u64,
    /// Total busy work units reported by the kernel.
    pub busy_units: u64,
    /// Busy units of the costliest color set — the color-parallel
    /// critical-path term B1/B2 exist to shrink.
    pub max_color_busy: u64,
    /// Mean-over-max busy fraction across the team.
    pub utilization: f64,
    /// Items the pre-run schedule refresh moved between buckets.
    pub sched_moved: usize,
    /// Colors the refresh dirtied (0 when the epoch was unchanged).
    pub sched_dirty_colors: usize,
    /// True when the schedule was (re)built from scratch (first execute
    /// on a session) rather than diff-refreshed.
    pub sched_rebuilt: bool,
}

/// Async outcome slot: `submit_async` returns one immediately; the
/// dispatcher that finishes the job completes it. Clone freely —
/// every clone observes the same slot. Completion is idempotent
/// (first writer wins), so racing failure paths are harmless.
#[derive(Clone)]
pub struct JobHandle(Arc<HandleInner>);

struct HandleInner {
    slot: Mutex<Option<JobOutcome>>,
    cv: Condvar,
}

impl JobHandle {
    fn new() -> JobHandle {
        JobHandle(Arc::new(HandleInner { slot: Mutex::new(None), cv: Condvar::new() }))
    }

    /// Block until the outcome arrives, then clone it out. The outcome
    /// stays readable — `wait`/`try_poll` can be called repeatedly.
    pub fn wait(&self) -> JobOutcome {
        let mut slot = self.0.slot.lock().unwrap();
        loop {
            if let Some(o) = slot.as_ref() {
                return o.clone();
            }
            slot = self.0.cv.wait(slot).unwrap();
        }
    }

    /// Non-blocking peek: `None` while the job is still in flight.
    pub fn try_poll(&self) -> Option<JobOutcome> {
        self.0.slot.lock().unwrap().clone()
    }

    /// Whether the outcome has been delivered.
    pub fn is_done(&self) -> bool {
        self.0.slot.lock().unwrap().is_some()
    }

    fn complete(&self, o: JobOutcome) {
        let mut slot = self.0.slot.lock().unwrap();
        if slot.is_none() {
            *slot = Some(o);
            self.0.cv.notify_all();
        }
    }
}

/// What flows through the sharded admission queue.
enum Task {
    /// A stateless or execute job, pinned to `shard`'s pool (a stealing
    /// dispatcher still runs it on the task's shard, not its own).
    Run { job: Job, handle: JobHandle, submitted: Instant, shard: usize },
    /// "Session `id` has pending updates" — the drain pulls and fuses
    /// whatever is queued. One Drain is pushed per admitted update; a
    /// drain that finds the queue empty (a sibling fused its work) is
    /// a no-op.
    Drain(SessionId),
}

/// PJRT worker mailbox (the runtime is not Send; it lives on one
/// thread).
enum Message {
    Run(Job, JobHandle, Instant),
    Stop,
}

/// Knobs for [`Service::start_sharded`].
#[derive(Clone, Debug)]
pub struct ServiceOpts {
    /// Queue lanes / pool teams / session homes. Sessions pin to
    /// `id % shards`; stateless jobs round-robin.
    pub shards: usize,
    /// Dispatcher threads popping the queue (home lane `i % shards`,
    /// stealing from the others when home is dry).
    pub dispatchers: usize,
    /// Worker threads per shard pool.
    pub pool_threads: usize,
    /// Max contiguous update batches fused into one repair per drain.
    pub fuse_updates: usize,
    /// PJRT artifact directory (None: native only).
    pub artifacts: Option<std::path::PathBuf>,
}

impl Default for ServiceOpts {
    fn default() -> ServiceOpts {
        ServiceOpts {
            shards: 1,
            dispatchers: 2,
            pool_threads: DEFAULT_POOL_THREADS,
            fuse_updates: 16,
            artifacts: None,
        }
    }
}

/// The coordinator service.
pub struct Service {
    queue: Arc<ShardedQueue<Task>>,
    pjrt_tx: Option<Sender<Message>>,
    workers: Vec<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    seq: AtomicU64,
    sessions: Arc<SessionMap>,
    session_seq: AtomicU64,
    /// The sharded region-execution teams (DESIGN.md §10/§12).
    pools: Arc<PoolSet>,
    /// Round-robin cursor for stateless-job shard assignment.
    rr: AtomicU64,
}

/// A zeroed failure [`JobOutcome`] — the shape every coordinator error
/// path reports, differing only in identity and message.
fn fail_outcome(
    name: &str,
    engine: &'static str,
    problem: Option<Problem>,
    error: String,
) -> JobOutcome {
    JobOutcome {
        name: name.to_string(),
        engine,
        problem,
        n_colors: 0,
        iterations: 0,
        seconds: 0.0,
        valid: false,
        error: Some(error),
        batch: None,
        exec: None,
        text: None,
        fused: 0,
        epoch: None,
    }
}

/// Refresh the pool/queue gauges in `metrics`' registry from the live
/// counters, then render the full exposition snapshot — the payload of
/// a [`JobInput::Stats`] job and of `serve --stats-interval`.
fn stats_text(metrics: &Metrics, pools: &PoolSet, queue: &QueueStats) -> String {
    let reg = metrics.registry();
    let ps = pools.stats();
    reg.gauge("pool.threads").set(ps.threads as u64);
    reg.gauge("pool.regions").set(ps.regions);
    reg.gauge("pool.items").set(ps.items);
    reg.gauge("pool.utilization_pct").set((ps.utilization() * 100.0) as u64);
    reg.gauge("queue.pushed").set(queue.pushed);
    reg.gauge("queue.popped").set(queue.popped);
    reg.gauge("queue.stolen").set(queue.stolen);
    metrics.exposition()
}

/// Run a non-update job on `shard`'s pool. Update jobs never reach
/// here — they drain through the session's pending queue.
fn run_stateless(
    job: &Job,
    sessions: &SessionMap,
    pools: &Arc<PoolSet>,
    metrics: &Metrics,
    queue: &ShardedQueue<Task>,
    shard: usize,
) -> JobOutcome {
    match &job.input {
        JobInput::Bgpc(g) => {
            let r = Colorer::new(&job.cfg).on(pools.shard(shard)).color(g);
            let valid = crate::coloring::verify::bgpc_valid(g, &r.colors).is_ok();
            JobOutcome {
                name: job.name.clone(),
                engine: "native",
                problem: Some(Problem::Bgpc),
                n_colors: r.n_colors,
                iterations: r.iterations,
                seconds: r.seconds,
                valid,
                error: None,
                batch: None,
                exec: None,
                text: None,
                fused: 0,
                epoch: None,
            }
        }
        JobInput::D2gc(g) => {
            let r = Colorer::new(&job.cfg).on(pools.shard(shard)).color(g);
            let valid = crate::coloring::verify::d2gc_valid(g, &r.colors).is_ok();
            JobOutcome {
                name: job.name.clone(),
                engine: "native",
                problem: Some(Problem::D2gc),
                n_colors: r.n_colors,
                iterations: r.iterations,
                seconds: r.seconds,
                valid,
                error: None,
                batch: None,
                exec: None,
                text: None,
                fused: 0,
                epoch: None,
            }
        }
        JobInput::D1gc(g) => {
            let r = Colorer::new(&job.cfg)
                .on(pools.shard(shard))
                .color(crate::dynamic::D1Graph::from_ref(g));
            let valid = crate::coloring::verify::d1gc_valid(g, &r.colors).is_ok();
            JobOutcome {
                name: job.name.clone(),
                engine: "native",
                problem: Some(Problem::D1gc),
                n_colors: r.n_colors,
                iterations: r.iterations,
                seconds: r.seconds,
                valid,
                error: None,
                batch: None,
                exec: None,
                text: None,
                fused: 0,
                epoch: None,
            }
        }
        JobInput::Execute { session, kernel, rounds } => {
            run_execute(sessions, pools, *session, kernel, *rounds, &job.name)
        }
        JobInput::Stats => JobOutcome {
            name: job.name.clone(),
            engine: "native",
            problem: None,
            n_colors: 0,
            iterations: 0,
            seconds: 0.0,
            valid: true,
            error: None,
            batch: None,
            exec: None,
            text: Some(stats_text(metrics, pools, &queue.stats())),
            fused: 0,
            epoch: None,
        },
        JobInput::Update { .. } => fail_outcome(
            &job.name,
            "native",
            None,
            "update jobs drain via the session queue".into(),
        ),
    }
}

/// Drain a session's pending queue: pull up to `fuse` contiguous
/// batches, apply them as one fused repair, publish the new epoch
/// snapshot, then complete every member handle. Holds the session
/// state lock across the loop — a concurrent `close_session` blocks
/// until the in-flight group commits, and a sibling Drain for the same
/// session parks on `state` and finds the queue empty afterwards.
fn drain_session(sessions: &SessionMap, metrics: &Metrics, id: SessionId, fuse: usize) {
    let slot = sessions.lock().unwrap().get(&id).cloned();
    let Some(slot) = slot else {
        return; // closed between admission and drain; close failed the items
    };
    let problem = slot.problem;
    let mut inner = slot.state.lock().unwrap();
    loop {
        let group: Vec<PendingUpdate> = {
            let mut pq = slot.pending.lock().unwrap();
            let take = fuse.max(1).min(pq.items.len());
            pq.items.drain(..take).collect()
        };
        if group.is_empty() {
            return;
        }
        if inner.closed {
            for p in &group {
                let o = fail_outcome(
                    &p.name,
                    "native",
                    Some(problem),
                    format!("session {id} closed before batch applied"),
                );
                metrics.record(&o);
                p.handle.complete(o);
            }
            continue;
        }
        debug_assert_eq!(group[0].seq, inner.applied, "pending queue is FIFO in seq order");
        let picked = Instant::now();
        let batches: Vec<&UpdateBatch> = group.iter().map(|p| p.batch.as_ref()).collect();
        // Apply + verify under catch_unwind: a panic mid-repair leaves
        // torn session state, so the session is closed and removed
        // (clients get None / "unknown session"), every queued handle
        // fails cleanly, and the dispatcher survives. The verify pass
        // is the service contract: every outcome handed back is checked
        // with the session's own problem checker.
        let applied = catch_unwind(AssertUnwindSafe(|| {
            let stats = inner.session.apply_many(&batches);
            let valid = inner.session.verify_ok();
            (stats, valid)
        }));
        match applied {
            Ok((stats, valid)) => {
                let _commit = span_n("coord.commit", group.len() as u64);
                inner.applied += group.len() as u64;
                let epoch = inner.applied;
                // Publish the snapshot BEFORE completing handles: a
                // client that sees its outcome and immediately reads
                // session_colors observes at least this epoch.
                *slot.snap.lock().unwrap() =
                    Arc::new(Snapshot { epoch, colors: inner.session.colors_arc() });
                let fused = group.len();
                if fused > 1 {
                    // record() skips per-outcome recolored counts for
                    // fused groups; charge the group's repair once.
                    metrics.add_recolored(stats.recolored as u64);
                }
                let service = picked.elapsed();
                for p in group {
                    let wait = picked.saturating_duration_since(p.submitted);
                    metrics.observe_job(wait, service);
                    let o = JobOutcome {
                        name: p.name,
                        engine: "native",
                        problem: Some(problem),
                        n_colors: stats.n_colors,
                        iterations: stats.iterations,
                        seconds: stats.seconds,
                        valid,
                        error: None,
                        batch: Some(stats.clone()),
                        exec: None,
                        text: None,
                        fused,
                        epoch: Some(epoch),
                    };
                    metrics.record(&o);
                    p.handle.complete(o);
                }
            }
            Err(p) => {
                inner.closed = true;
                let msg = format!(
                    "engine panicked: {}; session {id} closed",
                    panic_message(p.as_ref())
                );
                let service = picked.elapsed();
                for pu in group {
                    let wait = picked.saturating_duration_since(pu.submitted);
                    metrics.observe_job(wait, service);
                    let o = fail_outcome(&pu.name, "native", Some(problem), msg.clone());
                    metrics.record(&o);
                    pu.handle.complete(o);
                }
                let leftovers: Vec<PendingUpdate> = {
                    let mut pq = slot.pending.lock().unwrap();
                    pq.closed = true;
                    pq.items.drain(..).collect()
                };
                for pu in leftovers {
                    let o = fail_outcome(
                        &pu.name,
                        "native",
                        Some(problem),
                        format!("session {id} closed before batch applied"),
                    );
                    metrics.record(&o);
                    pu.handle.complete(o);
                }
                drop(inner);
                sessions.lock().unwrap().remove(&id);
                return;
            }
        }
    }
}

/// Run a colored-execution kernel over a session's last committed
/// epoch snapshot: clone the snapshot `Arc` (no session state lock —
/// an in-flight repair does not block this), ensure the epoch-keyed
/// [`EpochSchedule`] is current (same epoch: free; new epoch: dirty
/// colors only), then drive the kernel frontier-by-frontier on the
/// session's shard pool. A kernel panic surfaces as this job's error —
/// the session and its schedule are not torn by execution (kernels
/// cannot touch them), so the session stays open.
fn run_execute(
    sessions: &SessionMap,
    pools: &Arc<PoolSet>,
    id: SessionId,
    kernel: &ExecKernel,
    rounds: usize,
    name: &str,
) -> JobOutcome {
    let slot = sessions.lock().unwrap().get(&id).cloned();
    let Some(slot) = slot else {
        return fail_outcome(name, "native", None, format!("unknown session {id}"));
    };
    let problem = slot.problem;
    let snap = slot.snap.lock().unwrap().clone();
    let mut es = slot.sched.lock().unwrap();
    let refresh = es.ensure(snap.epoch, &snap.colors);
    let sched = es.sched().expect("ensure installs a schedule");
    let run = catch_unwind(AssertUnwindSafe(|| {
        Executor::new(pools.shard(slot.shard)).run(sched, rounds, |item, color| {
            kernel.call(item, color)
        })
    }));
    let report = match run {
        Ok(r) => r,
        Err(p) => {
            return fail_outcome(
                name,
                "native",
                Some(problem),
                format!("kernel panicked: {}", panic_message(p.as_ref())),
            )
        }
    };
    let stats = ExecStats {
        colors: sched.cardinalities().iter().filter(|&&c| c > 0).count(),
        rounds,
        items: report.items,
        busy_units: report.busy_total(),
        max_color_busy: report.max_color_busy(),
        utilization: report.utilization(),
        sched_moved: refresh.moved,
        sched_dirty_colors: refresh.dirty_colors,
        sched_rebuilt: refresh.rebuilt,
    };
    JobOutcome {
        name: name.to_string(),
        engine: "native",
        problem: Some(problem),
        n_colors: stats.colors,
        iterations: rounds,
        seconds: report.seconds,
        valid: true,
        error: None,
        batch: None,
        exec: Some(stats),
        text: None,
        fused: 0,
        epoch: Some(snap.epoch),
    }
}

fn run_pjrt(rt: &Runtime, job: &Job) -> JobOutcome {
    match &job.input {
        JobInput::Bgpc(g) => {
            let t0 = std::time::Instant::now();
            match NetStepOffload::new(rt).color(g, 50) {
                Ok((colors, stats)) => {
                    let valid = crate::coloring::verify::bgpc_valid(g, &colors).is_ok();
                    JobOutcome {
                        name: job.name.clone(),
                        engine: "pjrt",
                        problem: Some(Problem::Bgpc),
                        n_colors: crate::coloring::stats::distinct_colors(&colors),
                        iterations: stats.iterations,
                        seconds: t0.elapsed().as_secs_f64(),
                        valid,
                        error: None,
                        batch: None,
                        exec: None,
                        text: None,
                        fused: 0,
                        epoch: None,
                    }
                }
                Err(e) => JobOutcome {
                    seconds: t0.elapsed().as_secs_f64(),
                    ..fail_outcome(&job.name, "pjrt", Some(Problem::Bgpc), format!("{e:#}"))
                },
            }
        }
        JobInput::D2gc(_) | JobInput::D1gc(_) | JobInput::Update { .. }
        | JobInput::Execute { .. } | JobInput::Stats => fail_outcome(
            &job.name,
            "pjrt",
            job.input.problem(),
            "PJRT engine only supports BGPC jobs".into(),
        ),
    }
}

impl Service {
    /// Start `n_native` dispatchers over one shard with a
    /// [`DEFAULT_POOL_THREADS`]-wide pool; if `artifacts` is given and
    /// loads, also start one PJRT worker owning the compiled
    /// executables. See [`Service::start_sharded`] for every knob.
    pub fn start(n_native: usize, artifacts: Option<std::path::PathBuf>) -> Service {
        Service::start_sharded(ServiceOpts {
            dispatchers: n_native,
            artifacts,
            ..ServiceOpts::default()
        })
    }

    /// [`Service::start`] with an explicit per-shard pool size.
    pub fn start_with(
        n_native: usize,
        pool_threads: usize,
        artifacts: Option<std::path::PathBuf>,
    ) -> Service {
        Service::start_sharded(ServiceOpts {
            dispatchers: n_native,
            pool_threads,
            artifacts,
            ..ServiceOpts::default()
        })
    }

    /// Start the sharded service. Two thread populations exist, spawned
    /// here once and never again: `opts.dispatchers` dispatcher threads
    /// popping the sharded admission queue (control plane — they order
    /// and fuse session updates and run jobs to completion) and
    /// `opts.shards` pools of `opts.pool_threads` workers executing
    /// every parallel region (data plane). No dispatcher ever holds a
    /// lock while waiting for work, and no client lock is held across
    /// a repair's parallel regions. A panic inside an engine surfaces
    /// as a failed [`JobOutcome`] — dispatcher and pools survive.
    pub fn start_sharded(opts: ServiceOpts) -> Service {
        let shards = opts.shards.max(1);
        let fuse = opts.fuse_updates.max(1);
        let metrics = Arc::new(Metrics::default());
        let sessions: Arc<SessionMap> = Arc::new(Mutex::new(HashMap::new()));
        let pools = Arc::new(PoolSet::new(shards, opts.pool_threads.max(1)));
        let queue: Arc<ShardedQueue<Task>> = Arc::new(ShardedQueue::new(shards));
        let mut workers = Vec::new();
        for i in 0..opts.dispatchers.max(1) {
            let home = i % shards;
            let q = Arc::clone(&queue);
            let m = Arc::clone(&metrics);
            let sess = Arc::clone(&sessions);
            let pl = Arc::clone(&pools);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bgpc-dispatch-{i}"))
                    .spawn(move || {
                        while let Some(task) = q.pop(home) {
                            match task {
                                Task::Run { job, handle, submitted, shard } => {
                                    let _sp = span("coord.dispatch");
                                    let wait =
                                        Instant::now().saturating_duration_since(submitted);
                                    let t0 = Instant::now();
                                    let o = catch_unwind(AssertUnwindSafe(|| {
                                        run_stateless(&job, &sess, &pl, &m, &q, shard)
                                    }))
                                    .unwrap_or_else(|p| {
                                        fail_outcome(
                                            &job.name,
                                            "native",
                                            job.input.problem(),
                                            format!(
                                                "engine panicked: {}",
                                                panic_message(p.as_ref())
                                            ),
                                        )
                                    });
                                    m.observe_job(wait, t0.elapsed());
                                    m.record(&o);
                                    handle.complete(o);
                                }
                                Task::Drain(id) => {
                                    let _sp = span("coord.drain");
                                    drain_session(&sess, &m, id, fuse)
                                }
                            }
                        }
                    })
                    .expect("spawn dispatcher"),
            );
        }

        // PJRT handles are not Send: the runtime must be created *inside*
        // its worker thread; a oneshot reports whether loading succeeded.
        let pjrt_tx = opts.artifacts.and_then(|dir| {
            let (tx, rx) = channel::<Message>();
            let (ready_tx, ready_rx) = channel::<Result<(), String>>();
            let m = Arc::clone(&metrics);
            let handle = std::thread::spawn(move || {
                let rt = match Runtime::load(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                loop {
                    match rx.recv() {
                        Ok(Message::Run(job, handle, submitted)) => {
                            let wait = Instant::now().saturating_duration_since(submitted);
                            let t0 = Instant::now();
                            let o = run_pjrt(&rt, &job);
                            m.observe_job(wait, t0.elapsed());
                            m.record(&o);
                            handle.complete(o);
                        }
                        Ok(Message::Stop) | Err(_) => break,
                    }
                }
            });
            match ready_rx.recv() {
                Ok(Ok(())) => {
                    workers.push(handle);
                    Some(tx)
                }
                Ok(Err(e)) => {
                    eprintln!("coordinator: PJRT engine unavailable: {e}");
                    let _ = handle.join();
                    None
                }
                Err(_) => None,
            }
        });

        Service {
            queue,
            pjrt_tx,
            workers,
            metrics,
            seq: AtomicU64::new(0),
            sessions,
            session_seq: AtomicU64::new(0),
            pools,
            rr: AtomicU64::new(0),
        }
    }

    fn next_shard(&self) -> usize {
        self.rr.fetch_add(1, AOrd::Relaxed) as usize % self.pools.n_shards()
    }

    /// Enqueue a Run task for `shard`'s lane; fail the handle if the
    /// service has stopped.
    fn push_run(&self, job: Job, handle: &JobHandle, shard: usize) {
        let name = job.name.clone();
        let problem = job.input.problem();
        let task = Task::Run { job, handle: handle.clone(), submitted: Instant::now(), shard };
        if self.queue.push(shard, task).is_err() {
            handle.complete(fail_outcome(&name, "native", problem, "service stopped".into()));
        }
    }

    /// Non-blocking admission: route the job and return a [`JobHandle`]
    /// immediately. Updates are admitted to their session's pending
    /// queue (seq assigned under the pending lock, so admission order
    /// is apply order) and a Drain token is pushed to the session's
    /// shard lane; everything else is queued as a Run task. No
    /// service-wide lock is taken.
    pub fn submit_async(&self, mut job: Job) -> JobHandle {
        let _sp = span("coord.admit");
        if job.name.is_empty() {
            job.name = format!("job-{}", self.seq.fetch_add(1, AOrd::Relaxed));
        }
        let handle = JobHandle::new();
        match &job.input {
            JobInput::Update { session, batch } => {
                let id = *session;
                let batch = Arc::clone(batch);
                let slot = self.sessions.lock().unwrap().get(&id).cloned();
                let Some(slot) = slot else {
                    handle.complete(fail_outcome(
                        &job.name,
                        "native",
                        None,
                        format!("unknown session {id}"),
                    ));
                    return handle;
                };
                let seq = {
                    let mut pq = slot.pending.lock().unwrap();
                    if pq.closed {
                        drop(pq);
                        handle.complete(fail_outcome(
                            &job.name,
                            "native",
                            Some(slot.problem),
                            format!("session {id} closed before batch applied"),
                        ));
                        return handle;
                    }
                    let seq = pq.next_seq;
                    pq.next_seq += 1;
                    pq.items.push_back(PendingUpdate {
                        seq,
                        batch,
                        name: job.name.clone(),
                        handle: handle.clone(),
                        submitted: Instant::now(),
                    });
                    seq
                };
                if self.queue.push(slot.shard, Task::Drain(id)).is_err() {
                    let mut pq = slot.pending.lock().unwrap();
                    if let Some(pos) = pq.items.iter().position(|p| p.seq == seq) {
                        pq.items.remove(pos);
                    }
                    drop(pq);
                    handle.complete(fail_outcome(
                        &job.name,
                        "native",
                        Some(slot.problem),
                        "service stopped".into(),
                    ));
                }
            }
            JobInput::Execute { session, .. } => {
                let shard = self
                    .sessions
                    .lock()
                    .unwrap()
                    .get(session)
                    .map(|s| s.shard)
                    .unwrap_or_else(|| self.next_shard());
                self.push_run(job, &handle, shard);
            }
            JobInput::Stats => {
                let shard = self.next_shard();
                self.push_run(job, &handle, shard);
            }
            JobInput::Bgpc(_) | JobInput::D2gc(_) | JobInput::D1gc(_) => {
                let use_pjrt = match job.engine {
                    EngineSel::Pjrt => true,
                    EngineSel::Native => false,
                    EngineSel::Auto => {
                        self.pjrt_tx.is_some() && matches!(job.input, JobInput::Bgpc(_))
                    }
                };
                if use_pjrt {
                    match &self.pjrt_tx {
                        Some(ptx) => {
                            let _ =
                                ptx.send(Message::Run(job, handle.clone(), Instant::now()));
                        }
                        None => handle.complete(fail_outcome(
                            &job.name,
                            "pjrt",
                            job.input.problem(),
                            "PJRT engine not loaded (run `make artifacts`)".into(),
                        )),
                    }
                } else {
                    let shard = self.next_shard();
                    self.push_run(job, &handle, shard);
                }
            }
        }
        handle
    }

    /// Route a job (alias of [`Service::submit_async`] — kept as the
    /// historical front door; `.wait()` the handle for the old blocking
    /// behaviour).
    pub fn submit(&self, job: Job) -> JobHandle {
        self.submit_async(job)
    }

    /// Open a BGPC dynamic session: color `g` from scratch under `cfg`
    /// (synchronously, on the caller's thread, using the session's
    /// shard pool) and keep the session alive inside the service.
    /// Stream [`JobInput::Update`] jobs against the returned id, then
    /// [`Service::close_session`].
    pub fn open_session(&self, name: &str, g: &Bipartite, cfg: Config) -> (SessionId, JobOutcome) {
        let id = self.session_seq.fetch_add(1, AOrd::Relaxed) + 1;
        let shard = id as usize % self.pools.n_shards();
        let (mut session, init) =
            crate::dynamic::DynamicSession::start_on(g.clone(), cfg, self.pools.shard(shard));
        let valid = session.verify().is_ok();
        self.install_session(id, shard, name, AnySession::Bgpc(session), &init, valid)
    }

    /// Open a D2GC dynamic session over a square, structurally
    /// symmetric graph: same contract as [`Service::open_session`], but
    /// updates are undirected edge edits repaired at distance 2 (the
    /// overlay keeps the pattern symmetric across the stream).
    ///
    /// # Panics
    /// If `g` is not square and structurally symmetric.
    pub fn open_session_d2gc(&self, name: &str, g: &Csr, cfg: Config) -> (SessionId, JobOutcome) {
        let id = self.session_seq.fetch_add(1, AOrd::Relaxed) + 1;
        let shard = id as usize % self.pools.n_shards();
        let (mut session, init) =
            crate::dynamic::DynamicSession::start_on(g.clone(), cfg, self.pools.shard(shard));
        let valid = session.verify().is_ok();
        self.install_session(id, shard, name, AnySession::D2gc(session), &init, valid)
    }

    /// Open a D1GC dynamic session over a square, structurally
    /// symmetric graph: same contract as [`Service::open_session_d2gc`],
    /// but clashes are repaired at distance 1 (the survey baseline,
    /// DESIGN.md §14).
    ///
    /// # Panics
    /// If `g` is not square and structurally symmetric.
    pub fn open_session_d1gc(&self, name: &str, g: &Csr, cfg: Config) -> (SessionId, JobOutcome) {
        let id = self.session_seq.fetch_add(1, AOrd::Relaxed) + 1;
        let shard = id as usize % self.pools.n_shards();
        let (mut session, init) = crate::dynamic::DynamicSession::start_on(
            D1Graph::new(g.clone()),
            cfg,
            self.pools.shard(shard),
        );
        let valid = session.verify().is_ok();
        self.install_session(id, shard, name, AnySession::D1gc(session), &init, valid)
    }

    /// Shared tail of the `open_session*` pair: record the bring-up
    /// outcome, publish the epoch-0 snapshot, and park the session
    /// under its id.
    fn install_session(
        &self,
        id: SessionId,
        shard: usize,
        name: &str,
        session: AnySession,
        init: &crate::coloring::ColoringResult,
        valid: bool,
    ) -> (SessionId, JobOutcome) {
        let problem = session.problem();
        let outcome = JobOutcome {
            name: name.to_string(),
            engine: "native",
            problem: Some(problem),
            n_colors: init.n_colors,
            iterations: init.iterations,
            seconds: init.seconds,
            valid,
            error: None,
            batch: None,
            exec: None,
            text: None,
            fused: 0,
            epoch: Some(0),
        };
        self.metrics.record(&outcome);
        let snap = Arc::new(Snapshot { epoch: 0, colors: session.colors_arc() });
        self.sessions.lock().unwrap().insert(
            id,
            Arc::new(SessionSlot {
                shard,
                problem,
                pending: Mutex::new(PendingQueue::default()),
                state: Mutex::new(SessionInner { session, applied: 0, closed: false }),
                snap: Mutex::new(snap),
                sched: Mutex::new(EpochSchedule::new()),
            }),
        );
        (id, outcome)
    }

    /// Submit a colored-execution job against an open session: run
    /// `kernel` over the session's last committed epoch snapshot,
    /// `rounds` full color sweeps, on the session's shard pool (see
    /// [`JobInput::Execute`]). Convenience over [`Service::submit_async`].
    /// Queued-but-unapplied updates are not waited for — the run
    /// observes the last committed epoch.
    pub fn execute(
        &self,
        name: &str,
        session: SessionId,
        rounds: usize,
        kernel: ExecKernel,
    ) -> JobHandle {
        self.submit_async(Job {
            name: name.to_string(),
            input: JobInput::Execute { session, kernel, rounds },
            // Execute jobs ignore the config (the executor runs on the
            // session's shard pool with its full team); any well-formed
            // value satisfies the Job shape.
            cfg: Config::threads(crate::coloring::schedule::N1_N2, self.pools.shard(0).threads()),
            engine: EngineSel::Native,
        })
    }

    /// Snapshot a session's last committed coloring (epoch snapshot —
    /// never blocks on an in-flight repair; does not wait for
    /// still-queued updates).
    pub fn session_colors(&self, id: SessionId) -> Option<Arc<Vec<i32>>> {
        let slot = self.sessions.lock().unwrap().get(&id).cloned()?;
        let snap = slot.snap.lock().unwrap().clone();
        Some(Arc::clone(&snap.colors))
    }

    /// The session's last committed epoch (== update batches applied so
    /// far; 0 right after open). Never blocks on an in-flight repair.
    pub fn session_epoch(&self, id: SessionId) -> Option<u64> {
        let slot = self.sessions.lock().unwrap().get(&id).cloned()?;
        let epoch = slot.snap.lock().unwrap().epoch;
        Some(epoch)
    }

    /// The problem an open session repairs (`None` if the id is
    /// unknown) — the authoritative answer [`JobInput::problem`] cannot
    /// give for `Update` jobs. Lock-free beyond the map read.
    pub fn session_problem(&self, id: SessionId) -> Option<Problem> {
        let slot = self.sessions.lock().unwrap().get(&id).cloned()?;
        Some(slot.problem)
    }

    /// Close a session. The fused group a dispatcher is currently
    /// applying still completes (this call blocks on the state lock
    /// until it commits); updates still pending afterwards are failed
    /// cleanly ("session closed"); later submits error with "unknown
    /// session". Returns whether the id was open.
    pub fn close_session(&self, id: SessionId) -> bool {
        let slot = self.sessions.lock().unwrap().remove(&id);
        let Some(slot) = slot else {
            return false;
        };
        {
            let mut inner = slot.state.lock().unwrap();
            inner.closed = true;
        }
        let leftovers: Vec<PendingUpdate> = {
            let mut pq = slot.pending.lock().unwrap();
            pq.closed = true;
            pq.items.drain(..).collect()
        };
        for p in leftovers {
            let o = fail_outcome(
                &p.name,
                "native",
                Some(slot.problem),
                format!("session {id} closed before batch applied"),
            );
            self.metrics.record(&o);
            p.handle.complete(o);
        }
        true
    }

    /// Whether the PJRT engine is up.
    pub fn has_pjrt(&self) -> bool {
        self.pjrt_tx.is_some()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Live observability snapshot: refresh the pool/queue gauges in
    /// the service registry, then render the sorted exposition text —
    /// the same payload a [`JobInput::Stats`] job delivers, taken
    /// directly without going through the admission queue.
    pub fn stats_text(&self) -> String {
        stats_text(&self.metrics, &self.pools, &self.queue.stats())
    }

    /// Shard 0's region-execution pool (open ad-hoc drivers against it,
    /// inspect it). See [`Service::pools`] for the full set.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        self.pools.shard(0)
    }

    /// The sharded region-execution pool set.
    pub fn pools(&self) -> &Arc<PoolSet> {
        &self.pools
    }

    /// Aggregated region-dispatch and worker-utilization counters
    /// across every shard pool — the execution-substrate metrics that
    /// complement the per-job [`Metrics`].
    pub fn pool_stats(&self) -> PoolStats {
        self.pools.stats()
    }

    /// Per-shard pool counters, in shard order.
    pub fn shard_stats(&self) -> Vec<PoolStats> {
        self.pools.shard_stats()
    }

    /// Admission-queue counters (pushed / popped / stolen across
    /// lanes) — `stolen > 0` is work stealing paying off.
    pub fn queue_stats(&self) -> QueueStats {
        self.queue.stats()
    }

    fn shutdown_impl(&mut self) {
        self.queue.close();
        if let Some(ptx) = self.pjrt_tx.take() {
            let _ = ptx.send(Message::Stop);
        }
        for w in std::mem::take(&mut self.workers) {
            let _ = w.join();
        }
    }

    /// Stop all workers and join them (queued-but-unpopped tasks are
    /// still drained first — the queue rejects new pushes but hands
    /// out what it holds).
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::schedule;
    use crate::graph::generators::random_bipartite;

    #[test]
    fn native_jobs_round_trip() {
        let svc = Service::start(2, None);
        let g = Arc::new(random_bipartite(100, 150, 1200, 21));
        let mut handles = Vec::new();
        for (i, spec) in schedule::ALL.iter().enumerate() {
            handles.push(svc.submit(Job {
                name: format!("j{i}"),
                input: JobInput::Bgpc(Arc::clone(&g)),
                cfg: Config::sim(*spec, 4),
                engine: EngineSel::Native,
            }));
        }
        for h in handles {
            let o = h.wait();
            assert!(o.valid, "{}: {:?}", o.name, o.error);
            assert!(o.n_colors > 0);
        }
        assert_eq!(svc.metrics().jobs_done(), 8);
        svc.shutdown();
    }

    #[test]
    fn threads_jobs_multiplex_onto_the_shared_pool() {
        use crate::graph::generators::random_symmetric;
        let svc = Service::start_with(2, 4, None);
        assert_eq!(svc.pool_stats().threads, 4);
        let g = Arc::new(random_bipartite(120, 180, 1400, 5));
        let m = Arc::new(random_symmetric(80, 300, 7));
        let mut handles = Vec::new();
        for i in 0..4 {
            handles.push(svc.submit(Job {
                name: format!("t{i}"),
                // cfg.threads is clamped to the pool size (8 -> 4)
                input: JobInput::Bgpc(Arc::clone(&g)),
                cfg: Config::threads(schedule::ALL[i % schedule::ALL.len()], 8),
                engine: EngineSel::Native,
            }));
        }
        handles.push(svc.submit(Job {
            name: "t-d2".into(),
            input: JobInput::D2gc(Arc::clone(&m)),
            cfg: Config::threads(schedule::V_N2, 4),
            engine: EngineSel::Native,
        }));
        for h in handles {
            let o = h.wait();
            assert!(o.valid, "{}: {:?}", o.name, o.error);
        }
        let st = svc.pool_stats();
        assert!(st.regions > 0, "regions must dispatch onto the shared pool");
        assert!(st.items > 0);
        assert!(st.utilization() > 0.0 && st.utilization() <= 1.0);
        svc.shutdown();
    }

    #[test]
    fn engine_panic_becomes_job_error_and_worker_survives() {
        // A non-square D2GC job trips the engine's structural assert on
        // the dispatcher. The old behaviour poisoned the worker thread;
        // now the panic surfaces through JobOutcome and the service
        // keeps serving.
        let svc = Service::start(1, None);
        let bad = Arc::new(crate::graph::Csr::from_edges(3, 4, &[(0, 1), (1, 0), (2, 3)]));
        let o = svc
            .submit(Job {
                name: "bad".into(),
                input: JobInput::D2gc(bad),
                cfg: Config::sim(schedule::N1_N2, 2),
                engine: EngineSel::Native,
            })
            .wait();
        assert!(!o.valid);
        let err = o.error.expect("panic must surface as an error");
        assert!(err.contains("square"), "unexpected message: {err}");
        assert_eq!(svc.metrics().failures(), 1);
        // the single dispatcher survived: a healthy job still completes
        let g = Arc::new(random_bipartite(40, 60, 300, 2));
        let o = svc
            .submit(Job {
                name: "good".into(),
                input: JobInput::Bgpc(g),
                cfg: Config::sim(schedule::V_N2, 2),
                engine: EngineSel::Native,
            })
            .wait();
        assert!(o.valid, "{:?}", o.error);
        svc.shutdown();
    }

    #[test]
    fn pjrt_request_without_artifacts_errors_cleanly() {
        let svc = Service::start(1, None);
        let g = Arc::new(random_bipartite(10, 20, 60, 1));
        let o = svc
            .submit(Job {
                name: "x".into(),
                input: JobInput::Bgpc(g),
                cfg: Config::sim(schedule::N1_N2, 2),
                engine: EngineSel::Pjrt,
            })
            .wait();
        assert!(!o.valid);
        assert!(o.error.unwrap().contains("artifacts"));
        svc.shutdown();
    }

    #[test]
    fn dynamic_session_streams_ordered_batches() {
        use crate::dynamic::UpdateBatch;
        let svc = Service::start(2, None);
        let g = random_bipartite(80, 120, 900, 77);
        let (sid, init) = svc.open_session("sess", &g, Config::sim(schedule::N1_N2, 4));
        assert!(init.valid, "initial coloring must verify");
        assert!(init.n_colors > 0);
        assert_eq!(init.epoch, Some(0));
        // three dependent batches streamed through two dispatchers: the
        // pending-queue admission must apply them in submit order.
        let mut handles = Vec::new();
        for k in 0..3u32 {
            let mut batch = UpdateBatch::default();
            for i in 0..10u32 {
                batch.add_edges.push(((k * 7 + i) % 80, (k * 11 + i * 3) % 120));
            }
            handles.push(svc.submit(Job {
                name: format!("u{k}"),
                input: JobInput::Update { session: sid, batch: Arc::new(batch) },
                cfg: Config::sim(schedule::N1_N2, 4),
                engine: EngineSel::Auto,
            }));
        }
        for h in handles {
            let o = h.wait();
            assert!(o.valid, "{}: {:?}", o.name, o.error);
            assert_eq!(o.problem, Some(Problem::Bgpc), "update reports the session's problem");
            assert!(o.fused >= 1, "update outcomes report their fuse group size");
            let b = o.batch.expect("update outcomes carry batch stats");
            assert!(b.dirty_nets > 0 || b.batch_edits == 0);
        }
        assert_eq!(svc.session_problem(sid), Some(Problem::Bgpc));
        assert_eq!(svc.session_epoch(sid), Some(3), "three batches committed three epochs");
        let colors = svc.session_colors(sid).expect("session open");
        assert_eq!(colors.len(), 120);
        assert!(colors.iter().all(|&c| c >= 0));
        assert!(svc.close_session(sid));
        assert!(!svc.close_session(sid), "second close is a no-op");
        assert!(svc.session_colors(sid).is_none());
        svc.shutdown();
    }

    #[test]
    fn d2gc_session_streams_through_the_same_update_path() {
        use crate::dynamic::UpdateBatch;
        use crate::graph::generators::random_symmetric;
        let svc = Service::start(2, None);
        let g = random_symmetric(100, 500, 9);
        let (sid, init) = svc.open_session_d2gc("hessian", &g, Config::sim(schedule::N1_N2, 4));
        assert!(init.valid, "initial D2GC coloring must verify");
        assert_eq!(init.problem, Some(Problem::D2gc));
        assert_eq!(svc.session_problem(sid), Some(Problem::D2gc));
        let mut handles = Vec::new();
        for k in 0..2u32 {
            let mut batch = UpdateBatch::default();
            for i in 0..8u32 {
                let a = (k * 13 + i * 7) % 100;
                let b = (k * 31 + i * 11) % 100;
                batch.add_edges.push((a, b));
            }
            handles.push(svc.submit(Job {
                name: format!("h{k}"),
                input: JobInput::Update { session: sid, batch: Arc::new(batch) },
                cfg: Config::sim(schedule::N1_N2, 4),
                engine: EngineSel::Auto,
            }));
        }
        for h in handles {
            let o = h.wait();
            assert!(o.valid, "{}: {:?}", o.name, o.error);
            assert_eq!(o.problem, Some(Problem::D2gc), "update reports the session's problem");
            assert!(o.batch.is_some());
        }
        assert_eq!(svc.metrics().updates_d2gc(), 2);
        assert_eq!(svc.metrics().updates_bgpc(), 0);
        let colors = svc.session_colors(sid).expect("session open");
        assert_eq!(colors.len(), 100);
        assert!(colors.iter().all(|&c| c >= 0));
        assert!(svc.close_session(sid));
        svc.shutdown();
    }

    #[test]
    fn update_to_unknown_session_errors_cleanly() {
        use crate::dynamic::UpdateBatch;
        let svc = Service::start(1, None);
        let o = svc
            .submit(Job {
                name: "nope".into(),
                input: JobInput::Update { session: 999, batch: Arc::new(UpdateBatch::default()) },
                cfg: Config::sim(schedule::N1_N2, 2),
                engine: EngineSel::Native,
            })
            .wait();
        assert!(!o.valid);
        assert!(o.error.unwrap().contains("unknown session"));
        assert!(o.batch.is_none());
        svc.shutdown();
    }

    #[test]
    fn execute_runs_colored_kernel_over_a_session() {
        use crate::exec::SharedBuf;
        let svc = Service::start(2, None);
        let g = Arc::new(random_bipartite(80, 120, 900, 13));
        let (sid, init) = svc.open_session("exec", &g, Config::sim(schedule::N1_N2, 4));
        assert!(init.valid);
        let acc = Arc::new(SharedBuf::new(vec![0u64; g.n_nets()]));
        let kernel = {
            let g = Arc::clone(&g);
            let acc = Arc::clone(&acc);
            ExecKernel::new(move |item, _color| {
                let mut units = 0u64;
                for &v in g.nets(item) {
                    // SAFETY: no two columns in one color share a net,
                    // and colors are separated by the executor barrier.
                    unsafe { *acc.slot(v as usize) += (item as u64 + 1) * (v as u64 + 1) };
                    units += 1;
                }
                Cost::new(units)
            })
        };
        let o = svc.execute("run", sid, 2, kernel).wait();
        assert!(o.valid, "{:?}", o.error);
        assert_eq!(o.problem, Some(Problem::Bgpc));
        assert_eq!(o.epoch, Some(0), "no updates yet: the run observed epoch 0");
        let e = o.exec.expect("execute outcomes carry exec stats");
        assert!(e.sched_rebuilt, "first execute builds the schedule");
        assert_eq!(e.rounds, 2);
        assert_eq!(e.items, 2 * g.n_vertices() as u64);
        assert_eq!(e.busy_units, 2 * g.nnz() as u64);
        assert!(e.max_color_busy > 0 && e.max_color_busy <= e.busy_units);
        // bit-for-bit equal to the sequential sweep (integer arithmetic)
        let mut want = vec![0u64; g.n_nets()];
        for u in 0..g.n_vertices() {
            for &v in g.nets(u) {
                want[v as usize] += 2 * (u as u64 + 1) * (v as u64 + 1);
            }
        }
        // SAFETY: the job completed — no kernel is writing.
        let got: Vec<u64> = (0..g.n_nets()).map(|v| unsafe { *acc.peek(v) }).collect();
        assert_eq!(got, want, "colored execution must equal the sequential sweep");
        assert_eq!(svc.metrics().executes(), 1);
        assert_eq!(svc.metrics().exec_items(), e.items);
        assert!(svc.close_session(sid));
        svc.shutdown();
    }

    #[test]
    fn execute_refreshes_only_dirty_colors_after_updates() {
        use crate::dynamic::UpdateBatch;
        let svc = Service::start(1, None);
        let g = random_bipartite(100, 150, 1200, 31);
        let (sid, _init) = svc.open_session("s", &g, Config::sim(schedule::N1_N2, 4));
        let noop = ExecKernel::new(|_item, _color| Cost::new(1));
        let e0 = svc.execute("e0", sid, 1, noop.clone()).wait().exec.unwrap();
        assert!(e0.sched_rebuilt);
        assert_eq!(e0.sched_moved, 150, "first build places every item");
        // same epoch in between: nothing moves, nothing is even diffed
        let e1 = svc.execute("e1", sid, 1, noop.clone()).wait().exec.unwrap();
        assert!(!e1.sched_rebuilt);
        assert_eq!(e1.sched_moved, 0);
        assert_eq!(e1.sched_dirty_colors, 0);
        // an update batch dirties only the repaired frontier
        let mut batch = UpdateBatch::default();
        for i in 0..12u32 {
            batch.add_edges.push((i % 100, (i * 7) % 150));
        }
        let u = svc
            .submit(Job {
                name: "u".into(),
                input: JobInput::Update { session: sid, batch: Arc::new(batch) },
                cfg: Config::sim(schedule::N1_N2, 4),
                engine: EngineSel::Auto,
            })
            .wait();
        assert!(u.valid, "{:?}", u.error);
        assert_eq!(u.epoch, Some(1), "first committed batch is epoch 1");
        let recolored = u.batch.unwrap().recolored;
        let o2 = svc.execute("e2", sid, 1, noop).wait();
        assert_eq!(o2.epoch, Some(1), "execute observes the committed epoch");
        let e2 = o2.exec.unwrap();
        assert!(!e2.sched_rebuilt, "post-update refresh must be incremental");
        assert!(
            e2.sched_moved <= recolored,
            "refresh moved {} items but the repair recolored only {recolored}",
            e2.sched_moved
        );
        svc.shutdown();
    }

    #[test]
    fn execute_errors_cleanly_and_survives_kernel_panics() {
        let svc = Service::start(1, None);
        let o = svc
            .execute("nope", 777, 1, ExecKernel::new(|_, _| Cost::new(1)))
            .wait();
        assert!(!o.valid);
        assert!(o.error.unwrap().contains("unknown session"));
        let g = random_bipartite(40, 60, 300, 7);
        let (sid, _init) = svc.open_session("s", &g, Config::sim(schedule::V_N2, 2));
        let bomb = ExecKernel::new(|item, _color| {
            assert!(item != 3, "planted kernel failure");
            Cost::new(1)
        });
        let o = svc.execute("boom", sid, 1, bomb).wait();
        assert!(!o.valid);
        let err = o.error.expect("kernel panic must surface as an error");
        assert!(err.contains("kernel panicked"), "unexpected message: {err}");
        // the session and the dispatcher both survive the client's bug
        let o = svc.execute("ok", sid, 1, ExecKernel::new(|_, _| Cost::new(1))).wait();
        assert!(o.valid, "{:?}", o.error);
        assert!(svc.close_session(sid));
        svc.shutdown();
    }

    #[test]
    fn auto_routes_to_native_without_pjrt() {
        let svc = Service::start(1, None);
        assert!(!svc.has_pjrt());
        let g = Arc::new(random_bipartite(50, 60, 300, 3));
        let o = svc
            .submit(Job {
                name: String::new(),
                input: JobInput::Bgpc(g),
                cfg: Config::sim(schedule::V_N2, 2),
                engine: EngineSel::Auto,
            })
            .wait();
        assert_eq!(o.engine, "native");
        assert!(o.valid);
        svc.shutdown();
    }

    #[test]
    fn submit_async_handle_polls_then_waits() {
        let svc = Service::start(1, None);
        let g = Arc::new(random_bipartite(60, 90, 500, 11));
        let h = svc.submit_async(Job {
            name: "async".into(),
            input: JobInput::Bgpc(g),
            cfg: Config::sim(schedule::N1_N2, 4),
            engine: EngineSel::Native,
        });
        let o = h.wait();
        assert!(o.valid, "{:?}", o.error);
        assert!(h.is_done());
        let again = h.try_poll().expect("outcome stays readable after wait");
        assert_eq!(again.name, "async");
        assert_eq!(again.fused, 0);
        assert_eq!(again.epoch, None);
        svc.shutdown();
    }

    #[test]
    fn stats_job_returns_registry_snapshot() {
        let svc = Service::start(1, None);
        let g = Arc::new(random_bipartite(40, 60, 300, 5));
        let o = svc
            .submit(Job {
                name: "warm".into(),
                input: JobInput::Bgpc(g),
                cfg: Config::sim(schedule::N1_N2, 2),
                engine: EngineSel::Native,
            })
            .wait();
        assert!(o.valid, "{:?}", o.error);
        let o = svc
            .submit(Job {
                name: "stats".into(),
                input: JobInput::Stats,
                cfg: Config::sim(schedule::N1_N2, 1),
                engine: EngineSel::Auto,
            })
            .wait();
        assert!(o.valid, "{:?}", o.error);
        assert_eq!(o.engine, "native");
        assert_eq!(o.problem, None);
        let text = o.text.expect("stats outcomes carry the exposition");
        assert!(
            text.contains("counter coord.jobs 1"),
            "snapshot is taken before the stats job records itself:\n{text}"
        );
        assert!(text.contains("gauge pool.threads"), "pool gauges joined:\n{text}");
        assert!(text.contains("gauge queue.pushed"), "queue gauges joined:\n{text}");
        assert!(text.contains("hist coord.queue_wait_us"), "latency hists joined:\n{text}");
        // the direct convenience renders the same surface
        assert!(svc.stats_text().contains("counter coord.jobs 2"));
        svc.shutdown();
    }

    #[test]
    fn snapshot_reads_and_executes_complete_while_repair_holds_the_session() {
        // The acceptance property of the epoch-snapshot design: with
        // the session *state* lock held (exactly what an in-flight
        // repair holds for its whole apply+verify), colors reads,
        // epoch reads, and a full Execute job all run to completion
        // against the last committed epoch. Under the old design every
        // one of these parked on the session lock.
        let svc = Service::start_sharded(ServiceOpts { dispatchers: 2, ..ServiceOpts::default() });
        let g = random_bipartite(80, 120, 900, 41);
        let (sid, init) = svc.open_session("snap", &g, Config::sim(schedule::N1_N2, 4));
        assert!(init.valid);
        let slot = svc.sessions.lock().unwrap().get(&sid).cloned().unwrap();
        let repair_guard = slot.state.lock().unwrap();
        let colors = svc.session_colors(sid).expect("snapshot read must not block");
        assert_eq!(colors.len(), 120);
        assert_eq!(svc.session_epoch(sid), Some(0));
        let o = svc
            .execute("during-repair", sid, 1, ExecKernel::new(|_, _| Cost::new(1)))
            .wait();
        assert!(o.valid, "{:?}", o.error);
        assert_eq!(o.epoch, Some(0), "execute ran against the committed snapshot");
        drop(repair_guard);
        assert!(svc.close_session(sid));
        svc.shutdown();
    }

    #[test]
    fn tiny_updates_fuse_into_one_repair() {
        use crate::dynamic::UpdateBatch;
        let svc = Service::start_sharded(ServiceOpts {
            shards: 1,
            dispatchers: 1,
            pool_threads: 1,
            fuse_updates: 64,
            artifacts: None,
        });
        let g = random_bipartite(60, 90, 600, 17);
        let (sid, init) = svc.open_session("fuse", &g, Config::sim(schedule::N1_N2, 4));
        assert!(init.valid);
        // Occupy the lone dispatcher with a gated kernel so the updates
        // pile up in the pending queue, then open the gate: the drain
        // must pick all five up as ONE fused group — one compact +
        // repair + verify, one committed epoch.
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let kernel = {
            let gate = Arc::clone(&gate);
            ExecKernel::new(move |_item, _color| {
                let (lock, cv) = &*gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
                Cost::new(1)
            })
        };
        let exec_h = svc.execute("gate", sid, 1, kernel);
        let mut handles = Vec::new();
        for k in 0..5u32 {
            let mut batch = UpdateBatch::default();
            batch.add_edges.push((k % 60, (k * 7) % 90));
            handles.push(svc.submit_async(Job {
                name: format!("tiny{k}"),
                input: JobInput::Update { session: sid, batch: Arc::new(batch) },
                cfg: Config::sim(schedule::N1_N2, 4),
                engine: EngineSel::Auto,
            }));
        }
        assert!(
            handles.iter().all(|h| h.try_poll().is_none()),
            "updates must be parked behind the gated execute"
        );
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        assert!(exec_h.wait().valid);
        for h in handles {
            let o = h.wait();
            assert!(o.valid, "{}: {:?}", o.name, o.error);
            assert_eq!(o.fused, 5, "all five tiny updates drained as one group");
            assert_eq!(o.epoch, Some(5), "the fused group committed all five batches");
        }
        assert_eq!(svc.session_epoch(sid), Some(5));
        svc.shutdown();
    }

    #[test]
    fn sharded_service_spreads_sessions_across_pools() {
        let svc = Service::start_sharded(ServiceOpts {
            shards: 2,
            dispatchers: 2,
            pool_threads: 1,
            fuse_updates: 16,
            artifacts: None,
        });
        let g1 = random_bipartite(50, 70, 400, 3);
        let g2 = random_bipartite(60, 80, 500, 4);
        let (s1, i1) = svc.open_session("a", &g1, Config::sim(schedule::N1_N2, 4));
        let (s2, i2) = svc.open_session("b", &g2, Config::sim(schedule::N1_N2, 4));
        assert!(i1.valid && i2.valid);
        let noop = ExecKernel::new(|_, _| Cost::new(1));
        let o1 = svc.execute("e1", s1, 1, noop.clone()).wait();
        let o2 = svc.execute("e2", s2, 1, noop).wait();
        assert!(o1.valid && o2.valid, "{:?} / {:?}", o1.error, o2.error);
        let per = svc.shard_stats();
        assert_eq!(per.len(), 2);
        assert!(
            per.iter().all(|s| s.regions > 0),
            "sessions pin to distinct shards, so both pools dispatch regions"
        );
        let qs = svc.queue_stats();
        assert_eq!(qs.pushed, qs.popped, "admission queue fully drained");
        assert!(svc.close_session(s1) && svc.close_session(s2));
        svc.shutdown();
    }
}
