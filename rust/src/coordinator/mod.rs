//! Coloring job coordinator — the L3 service layer.
//!
//! A [`Service`] owns a pool of native workers plus (optionally) one
//! PJRT worker that holds the compiled net-step artifacts. Clients
//! [`Service::submit`] jobs (a graph + a [`crate::coloring::Config`] +
//! an engine selector); the router dispatches each job to the right
//! worker queue and the caller gets a receiver for the outcome. The
//! PJRT executable is compiled once and reused across jobs (one
//! executable per bucket, per DESIGN.md §3); Python is never involved.

pub mod metrics;

use std::sync::atomic::{AtomicU64, Ordering as AOrd};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coloring::{color_bgpc, color_d2gc, Config, Problem};
use crate::graph::{Bipartite, Csr};
use crate::runtime::{NetStepOffload, Runtime};

pub use metrics::Metrics;

/// Which engine a job should run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineSel {
    /// Router decides: PJRT for BGPC jobs whose nets fit a bucket (when
    /// artifacts are loaded), native otherwise.
    Auto,
    /// Native engine (simulator or real threads per the job's Config).
    Native,
    /// The AOT JAX/Pallas net-step path.
    Pjrt,
}

/// A coloring job.
#[derive(Clone)]
pub struct Job {
    pub name: String,
    pub input: JobInput,
    pub cfg: Config,
    pub engine: EngineSel,
}

/// Job payload (graphs are shared; the service never copies them).
#[derive(Clone)]
pub enum JobInput {
    Bgpc(Arc<Bipartite>),
    D2gc(Arc<Csr>),
}

impl JobInput {
    pub fn problem(&self) -> Problem {
        match self {
            JobInput::Bgpc(_) => Problem::Bgpc,
            JobInput::D2gc(_) => Problem::D2gc,
        }
    }
}

/// Outcome delivered to the submitter.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub name: String,
    pub engine: &'static str,
    pub n_colors: usize,
    pub iterations: usize,
    pub seconds: f64,
    pub valid: bool,
    pub error: Option<String>,
}

enum Message {
    Run(Job, Sender<JobOutcome>),
    Stop,
}

/// The coordinator service.
pub struct Service {
    native_tx: Sender<Message>,
    pjrt_tx: Option<Sender<Message>>,
    workers: Vec<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    seq: AtomicU64,
}

fn run_native(job: &Job) -> JobOutcome {
    match &job.input {
        JobInput::Bgpc(g) => {
            let r = color_bgpc(g, &job.cfg);
            let valid = crate::coloring::verify::bgpc_valid(g, &r.colors).is_ok();
            JobOutcome {
                name: job.name.clone(),
                engine: "native",
                n_colors: r.n_colors,
                iterations: r.iterations,
                seconds: r.seconds,
                valid,
                error: None,
            }
        }
        JobInput::D2gc(g) => {
            let r = color_d2gc(g, &job.cfg);
            let valid = crate::coloring::verify::d2gc_valid(g, &r.colors).is_ok();
            JobOutcome {
                name: job.name.clone(),
                engine: "native",
                n_colors: r.n_colors,
                iterations: r.iterations,
                seconds: r.seconds,
                valid,
                error: None,
            }
        }
    }
}

fn run_pjrt(rt: &Runtime, job: &Job) -> JobOutcome {
    match &job.input {
        JobInput::Bgpc(g) => {
            let t0 = std::time::Instant::now();
            match NetStepOffload::new(rt).color(g, 50) {
                Ok((colors, stats)) => {
                    let valid = crate::coloring::verify::bgpc_valid(g, &colors).is_ok();
                    JobOutcome {
                        name: job.name.clone(),
                        engine: "pjrt",
                        n_colors: crate::coloring::stats::distinct_colors(&colors),
                        iterations: stats.iterations,
                        seconds: t0.elapsed().as_secs_f64(),
                        valid,
                        error: None,
                    }
                }
                Err(e) => JobOutcome {
                    name: job.name.clone(),
                    engine: "pjrt",
                    n_colors: 0,
                    iterations: 0,
                    seconds: t0.elapsed().as_secs_f64(),
                    valid: false,
                    error: Some(format!("{e:#}")),
                },
            }
        }
        JobInput::D2gc(_) => JobOutcome {
            name: job.name.clone(),
            engine: "pjrt",
            n_colors: 0,
            iterations: 0,
            seconds: 0.0,
            valid: false,
            error: Some("PJRT engine only supports BGPC jobs".into()),
        },
    }
}

impl Service {
    /// Start `n_native` native workers; if `artifacts` is given and loads,
    /// also start one PJRT worker owning the compiled executables.
    pub fn start(n_native: usize, artifacts: Option<std::path::PathBuf>) -> Service {
        let metrics = Arc::new(Metrics::default());
        let (native_tx, native_rx) = channel::<Message>();
        let native_rx = Arc::new(std::sync::Mutex::new(native_rx));
        let mut workers = Vec::new();
        for _ in 0..n_native.max(1) {
            let rx = Arc::clone(&native_rx);
            let m = Arc::clone(&metrics);
            workers.push(std::thread::spawn(move || loop {
                let msg = { rx.lock().unwrap().recv() };
                match msg {
                    Ok(Message::Run(job, out)) => {
                        let o = run_native(&job);
                        m.record(&o);
                        let _ = out.send(o);
                    }
                    Ok(Message::Stop) | Err(_) => break,
                }
            }));
        }

        // PJRT handles are not Send: the runtime must be created *inside*
        // its worker thread; a oneshot reports whether loading succeeded.
        let pjrt_tx = artifacts.and_then(|dir| {
            let (tx, rx) = channel::<Message>();
            let (ready_tx, ready_rx) = channel::<Result<(), String>>();
            let m = Arc::clone(&metrics);
            let handle = std::thread::spawn(move || {
                let rt = match Runtime::load(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                loop {
                    match rx.recv() {
                        Ok(Message::Run(job, out)) => {
                            let o = run_pjrt(&rt, &job);
                            m.record(&o);
                            let _ = out.send(o);
                        }
                        Ok(Message::Stop) | Err(_) => break,
                    }
                }
            });
            match ready_rx.recv() {
                Ok(Ok(())) => {
                    workers.push(handle);
                    Some(tx)
                }
                Ok(Err(e)) => {
                    eprintln!("coordinator: PJRT engine unavailable: {e}");
                    let _ = handle.join();
                    None
                }
                Err(_) => None,
            }
        });

        Service { native_tx, pjrt_tx, workers, metrics, seq: AtomicU64::new(0) }
    }

    /// Route a job; returns the outcome receiver.
    pub fn submit(&self, mut job: Job) -> Receiver<JobOutcome> {
        if job.name.is_empty() {
            job.name = format!("job-{}", self.seq.fetch_add(1, AOrd::Relaxed));
        }
        let (tx, rx) = channel();
        let use_pjrt = match job.engine {
            EngineSel::Pjrt => true,
            EngineSel::Native => false,
            EngineSel::Auto => {
                self.pjrt_tx.is_some() && matches!(job.input, JobInput::Bgpc(_))
            }
        };
        if use_pjrt {
            match &self.pjrt_tx {
                Some(ptx) => {
                    let _ = ptx.send(Message::Run(job, tx));
                }
                None => {
                    let _ = tx.send(JobOutcome {
                        name: job.name,
                        engine: "pjrt",
                        n_colors: 0,
                        iterations: 0,
                        seconds: 0.0,
                        valid: false,
                        error: Some("PJRT engine not loaded (run `make artifacts`)".into()),
                    });
                }
            }
        } else {
            let _ = self.native_tx.send(Message::Run(job, tx));
        }
        rx
    }

    /// Whether the PJRT engine is up.
    pub fn has_pjrt(&self) -> bool {
        self.pjrt_tx.is_some()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Stop all workers and join them.
    pub fn shutdown(self) {
        for _ in 0..self.workers.len() {
            let _ = self.native_tx.send(Message::Stop);
        }
        if let Some(ptx) = &self.pjrt_tx {
            let _ = ptx.send(Message::Stop);
        }
        drop(self.native_tx);
        drop(self.pjrt_tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::schedule;
    use crate::graph::generators::random_bipartite;

    #[test]
    fn native_jobs_round_trip() {
        let svc = Service::start(2, None);
        let g = Arc::new(random_bipartite(100, 150, 1200, 21));
        let mut rxs = Vec::new();
        for (i, spec) in schedule::ALL.iter().enumerate() {
            rxs.push(svc.submit(Job {
                name: format!("j{i}"),
                input: JobInput::Bgpc(Arc::clone(&g)),
                cfg: Config::sim(*spec, 4),
                engine: EngineSel::Native,
            }));
        }
        for rx in rxs {
            let o = rx.recv().unwrap();
            assert!(o.valid, "{}: {:?}", o.name, o.error);
            assert!(o.n_colors > 0);
        }
        assert_eq!(svc.metrics().jobs_done(), 8);
        svc.shutdown();
    }

    #[test]
    fn pjrt_request_without_artifacts_errors_cleanly() {
        let svc = Service::start(1, None);
        let g = Arc::new(random_bipartite(10, 20, 60, 1));
        let rx = svc.submit(Job {
            name: "x".into(),
            input: JobInput::Bgpc(g),
            cfg: Config::sim(schedule::N1_N2, 2),
            engine: EngineSel::Pjrt,
        });
        let o = rx.recv().unwrap();
        assert!(!o.valid);
        assert!(o.error.unwrap().contains("artifacts"));
        svc.shutdown();
    }

    #[test]
    fn auto_routes_to_native_without_pjrt() {
        let svc = Service::start(1, None);
        assert!(!svc.has_pjrt());
        let g = Arc::new(random_bipartite(50, 60, 300, 3));
        let o = svc
            .submit(Job {
                name: String::new(),
                input: JobInput::Bgpc(g),
                cfg: Config::sim(schedule::V_N2, 2),
                engine: EngineSel::Auto,
            })
            .recv()
            .unwrap();
        assert_eq!(o.engine, "native");
        assert!(o.valid);
        svc.shutdown();
    }
}
