//! Service metrics: lock-free counters recorded per completed job,
//! plus log-bucketed latency histograms (queue wait / service time)
//! feeding the p50/p99 figures the `serve --pool` summary prints.
//!
//! Since the `obs` layer landed this is a thin façade: every counter
//! and histogram lives in the service's [`Registry`] under a
//! `coord.*` name, so the same cells the methods below read also show
//! up in [`Registry::exposition`] — the text snapshot the `Stats` job
//! and `serve --stats-interval` print — next to the pool and queue
//! gauges. The façade keeps the typed recording API (`record`,
//! `observe_job`, `add_recolored`) and the summary line stable.

use std::sync::Arc;
use std::time::Duration;

use crate::coloring::Problem;
use crate::obs::{Counter, Hist, Registry};

/// A lock-free log-2 latency histogram over microseconds: a [`Duration`]
/// façade over [`obs::Hist`](crate::obs::Hist). Observation is two
/// relaxed atomic adds; quantiles are bucket upper bounds (a ≤2×
/// overestimate by construction — fine for p50/p99 trend lines and
/// regression gates, which compare like against like).
///
/// Edge cases: a 0µs observation lands in the first bucket and a
/// `u64::MAX`-µs one in the last (durations past `u64` microseconds
/// saturate instead of truncating); no path shifts a `u64` by 64.
#[derive(Debug, Clone)]
pub struct Histogram(Arc<Hist>);

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram(Arc::new(Hist::default()))
    }
}

impl Histogram {
    /// The histogram registered in `reg` under `name` (shared cells:
    /// the registry exposition renders the same data this reads).
    fn registered(reg: &Registry, name: &str) -> Histogram {
        Histogram(reg.hist(name))
    }

    pub fn observe(&self, d: Duration) {
        // saturate, don't truncate: a >584-millennium duration is a
        // bug, but it should land in the last bucket, not a random one
        let us = u64::try_from(d.as_micros()).unwrap_or(u64::MAX);
        self.0.record(us);
    }

    pub fn count(&self) -> u64 {
        self.0.count()
    }

    pub fn mean_secs(&self) -> f64 {
        self.0.mean().map_or(0.0, |us| us * 1e-6)
    }

    /// The `q`-quantile (0 < q <= 1) in seconds: the holding bucket's
    /// upper bound. 0.0 when empty ([`Histogram::quantile_secs`]
    /// distinguishes that case).
    pub fn quantile(&self, q: f64) -> f64 {
        self.quantile_secs(q).unwrap_or(0.0)
    }

    /// The `q`-quantile in seconds, `None` when the histogram is empty
    /// (renderers print `-` rather than a garbage bucket bound).
    pub fn quantile_secs(&self, q: f64) -> Option<f64> {
        self.0.quantile(q).map(|us| us * 1e-6)
    }
}

/// Aggregated job counters, all living in one [`Registry`] under
/// `coord.*` names.
#[derive(Debug)]
pub struct Metrics {
    registry: Arc<Registry>,
    jobs: Arc<Counter>,
    failures: Arc<Counter>,
    pjrt_jobs: Arc<Counter>,
    total_colors: Arc<Counter>,
    /// Total engine seconds, in microseconds (atomic f64 substitute).
    total_us: Arc<Counter>,
    /// BGPC dynamic-session update batches applied.
    updates_bgpc: Arc<Counter>,
    /// D2GC dynamic-session update batches applied.
    updates_d2gc: Arc<Counter>,
    /// D1GC dynamic-session update batches applied.
    updates_d1gc: Arc<Counter>,
    /// Vertices recolored across all update batches.
    recolored: Arc<Counter>,
    /// Colored-execution jobs completed.
    executes: Arc<Counter>,
    /// Kernel invocations across all execute jobs.
    exec_items: Arc<Counter>,
    /// Admission → dispatcher pickup, per job.
    queue_wait: Histogram,
    /// Pickup → outcome, per job (members of a fused group share the
    /// group's service time — that IS their latency).
    service_time: Histogram,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::with_registry(Arc::new(Registry::new()))
    }
}

impl Metrics {
    /// Metrics recording into `registry` (one registry per service; the
    /// pool/queue gauges join it at snapshot time, see
    /// `Service::stats_text`).
    pub fn with_registry(registry: Arc<Registry>) -> Metrics {
        Metrics {
            jobs: registry.counter("coord.jobs"),
            failures: registry.counter("coord.failures"),
            pjrt_jobs: registry.counter("coord.pjrt_jobs"),
            total_colors: registry.counter("coord.total_colors"),
            total_us: registry.counter("coord.engine_us"),
            updates_bgpc: registry.counter("coord.updates_bgpc"),
            updates_d2gc: registry.counter("coord.updates_d2gc"),
            updates_d1gc: registry.counter("coord.updates_d1gc"),
            recolored: registry.counter("coord.recolored"),
            executes: registry.counter("coord.executes"),
            exec_items: registry.counter("coord.exec_items"),
            queue_wait: Histogram::registered(&registry, "coord.queue_wait_us"),
            service_time: Histogram::registered(&registry, "coord.service_us"),
            registry,
        }
    }

    /// The registry these metrics record into.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Text snapshot of every registered metric (sorted `kind name
    /// value` lines) — the `Stats` job's payload.
    pub fn exposition(&self) -> String {
        self.registry.exposition()
    }

    pub fn record(&self, o: &super::JobOutcome) {
        self.jobs.inc();
        if !o.valid {
            self.failures.inc();
        }
        if o.engine == "pjrt" {
            self.pjrt_jobs.inc();
        }
        if let Some(b) = &o.batch {
            // updates are counted per problem (every session kind
            // shares the update path but not the repair engine)
            match o.problem {
                Some(Problem::D2gc) => self.updates_d2gc.inc(),
                Some(Problem::D1gc) => self.updates_d1gc.inc(),
                _ => self.updates_bgpc.inc(),
            };
            // A fused group shares one BatchStats: counting it per
            // member would multiply the repair's work by the group
            // size. The drain charges the group once via
            // add_recolored; lone batches (fused <= 1) count here.
            if o.fused <= 1 {
                self.recolored.add(b.recolored as u64);
            }
        }
        if let Some(e) = &o.exec {
            self.executes.inc();
            self.exec_items.add(e.items);
        }
        self.total_colors.add(o.n_colors as u64);
        self.total_us.add((o.seconds * 1e6) as u64);
    }

    /// Observe one job's queue wait (admission → pickup) and service
    /// time (pickup → outcome). Called by dispatchers for every job,
    /// including failures — tail latency includes the unlucky.
    pub fn observe_job(&self, wait: Duration, service: Duration) {
        self.queue_wait.observe(wait);
        self.service_time.observe(service);
    }

    /// Charge a fused group's recolored-vertices total once (see
    /// [`Metrics::record`] for why members must not each add it).
    pub fn add_recolored(&self, n: u64) {
        self.recolored.add(n);
    }

    pub fn jobs_done(&self) -> u64 {
        self.jobs.get()
    }

    pub fn failures(&self) -> u64 {
        self.failures.get()
    }

    pub fn pjrt_jobs(&self) -> u64 {
        self.pjrt_jobs.get()
    }

    /// Dynamic-session update batches applied (all problems).
    pub fn updates(&self) -> u64 {
        self.updates_bgpc() + self.updates_d2gc() + self.updates_d1gc()
    }

    /// BGPC update batches applied.
    pub fn updates_bgpc(&self) -> u64 {
        self.updates_bgpc.get()
    }

    /// D2GC update batches applied.
    pub fn updates_d2gc(&self) -> u64 {
        self.updates_d2gc.get()
    }

    /// D1GC update batches applied.
    pub fn updates_d1gc(&self) -> u64 {
        self.updates_d1gc.get()
    }

    /// Vertices recolored across all update batches (fused groups
    /// counted once).
    pub fn recolored(&self) -> u64 {
        self.recolored.get()
    }

    /// Colored-execution jobs completed.
    pub fn executes(&self) -> u64 {
        self.executes.get()
    }

    /// Kernel invocations across all execute jobs.
    pub fn exec_items(&self) -> u64 {
        self.exec_items.get()
    }

    pub fn total_seconds(&self) -> f64 {
        self.total_us.get() as f64 * 1e-6
    }

    /// The queue-wait histogram (admission → dispatcher pickup).
    pub fn queue_wait(&self) -> &Histogram {
        &self.queue_wait
    }

    /// The service-time histogram (pickup → outcome).
    pub fn service_time(&self) -> &Histogram {
        &self.service_time
    }

    /// Queue-wait `q`-quantile in seconds (0.0 when no jobs ran).
    pub fn queue_wait_quantile(&self, q: f64) -> f64 {
        self.queue_wait.quantile(q)
    }

    /// Service-time `q`-quantile in seconds (0.0 when no jobs ran).
    pub fn service_time_quantile(&self, q: f64) -> f64 {
        self.service_time.quantile(q)
    }

    /// One-line summary for logs. Latency quantiles render `-` until a
    /// job has actually been observed (an empty histogram has no p50).
    pub fn summary(&self) -> String {
        let ms = |v: Option<f64>| match v {
            Some(secs) => format!("{:.3}ms", secs * 1e3),
            None => "-".to_string(),
        };
        format!(
            "jobs={} failures={} pjrt={} updates={} (bgpc={} d2gc={} d1gc={}) recolored={} executes={} exec_items={} engine_secs={:.3} wait_p50={} wait_p99={} service_p50={} service_p99={}",
            self.jobs_done(),
            self.failures(),
            self.pjrt_jobs(),
            self.updates(),
            self.updates_bgpc(),
            self.updates_d2gc(),
            self.updates_d1gc(),
            self.recolored(),
            self.executes(),
            self.exec_items(),
            self.total_seconds(),
            ms(self.queue_wait.quantile_secs(0.50)),
            ms(self.queue_wait.quantile_secs(0.99)),
            ms(self.service_time.quantile_secs(0.50)),
            ms(self.service_time.quantile_secs(0.99)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        let ok = crate::coordinator::JobOutcome {
            name: "a".into(),
            engine: "native",
            problem: Some(Problem::Bgpc),
            n_colors: 5,
            iterations: 1,
            seconds: 0.25,
            valid: true,
            error: None,
            batch: None,
            exec: None,
            text: None,
            fused: 0,
            epoch: None,
        };
        let bad = crate::coordinator::JobOutcome { valid: false, engine: "pjrt", ..ok.clone() };
        m.record(&ok);
        m.record(&bad);
        assert_eq!(m.jobs_done(), 2);
        assert_eq!(m.failures(), 1);
        assert_eq!(m.pjrt_jobs(), 1);
        assert!((m.total_seconds() - 0.5).abs() < 1e-3);
        assert!(m.summary().contains("jobs=2"));
        // the façade shares cells with the registry exposition
        let text = m.exposition();
        assert!(text.contains("counter coord.jobs 2"), "exposition: {text}");
        assert!(text.contains("counter coord.failures 1"));
    }

    #[test]
    fn update_batches_counted_per_problem() {
        let m = Metrics::default();
        let stats = crate::dynamic::BatchStats { recolored: 7, ..Default::default() };
        let upd = crate::coordinator::JobOutcome {
            name: "u".into(),
            engine: "native",
            problem: Some(Problem::Bgpc),
            n_colors: 5,
            iterations: 1,
            seconds: 0.01,
            valid: true,
            error: None,
            batch: Some(stats),
            exec: None,
            text: None,
            fused: 1,
            epoch: Some(1),
        };
        let upd2 = crate::coordinator::JobOutcome {
            problem: Some(Problem::D2gc),
            ..upd.clone()
        };
        let upd1 = crate::coordinator::JobOutcome {
            problem: Some(Problem::D1gc),
            ..upd.clone()
        };
        m.record(&upd);
        m.record(&upd);
        m.record(&upd2);
        m.record(&upd1);
        assert_eq!(m.updates(), 4);
        assert_eq!(m.updates_bgpc(), 2, "D1GC must not fold into the BGPC count");
        assert_eq!(m.updates_d2gc(), 1);
        assert_eq!(m.updates_d1gc(), 1);
        assert_eq!(m.recolored(), 28);
        assert!(m.summary().contains("updates=4"));
        assert!(m.summary().contains("d2gc=1"));
        assert!(m.summary().contains("d1gc=1"));
        // D1GC updates are their own kind in the registry exposition
        let text = m.exposition();
        assert!(text.contains("counter coord.updates_d1gc 1"), "exposition: {text}");
        assert!(text.contains("counter coord.updates_bgpc 2"), "exposition: {text}");
    }

    #[test]
    fn fused_group_members_share_one_recolored_charge() {
        let m = Metrics::default();
        let stats = crate::dynamic::BatchStats { recolored: 9, ..Default::default() };
        let member = crate::coordinator::JobOutcome {
            name: "f".into(),
            engine: "native",
            problem: Some(Problem::Bgpc),
            n_colors: 5,
            iterations: 1,
            seconds: 0.01,
            valid: true,
            error: None,
            batch: Some(stats),
            exec: None,
            text: None,
            fused: 3,
            epoch: Some(3),
        };
        // the drain records each member, then charges the group once
        m.record(&member);
        m.record(&member);
        m.record(&member);
        m.add_recolored(9);
        assert_eq!(m.updates(), 3, "each member still counts as an applied batch");
        assert_eq!(m.recolored(), 9, "the shared repair is charged exactly once");
    }

    #[test]
    fn execute_jobs_counted_with_items() {
        let m = Metrics::default();
        let ex = crate::coordinator::JobOutcome {
            name: "x".into(),
            engine: "native",
            problem: Some(Problem::Bgpc),
            n_colors: 4,
            iterations: 2,
            seconds: 0.01,
            valid: true,
            error: None,
            batch: None,
            exec: Some(crate::coordinator::ExecStats {
                colors: 4,
                rounds: 2,
                items: 120,
                busy_units: 600,
                max_color_busy: 300,
                utilization: 0.9,
                sched_moved: 0,
                sched_dirty_colors: 0,
                sched_rebuilt: false,
            }),
            text: None,
            fused: 0,
            epoch: Some(0),
        };
        m.record(&ex);
        m.record(&ex);
        assert_eq!(m.executes(), 2);
        assert_eq!(m.exec_items(), 240);
        assert_eq!(m.updates(), 0);
        assert!(m.summary().contains("executes=2"));
    }

    #[test]
    fn histogram_quantiles_walk_log_buckets() {
        let h = Histogram::default();
        assert_eq!(h.quantile(0.99), 0.0, "empty histogram reports 0");
        assert_eq!(h.quantile_secs(0.99), None, "…and None when asked honestly");
        // 99 fast observations (~100µs) and one slow outlier (~50ms)
        for _ in 0..99 {
            h.observe(Duration::from_micros(100));
        }
        h.observe(Duration::from_millis(50));
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        let p100 = h.quantile(1.0);
        // 100µs lands in [64µs,128µs): upper bound 128µs
        assert!((p50 - 128e-6).abs() < 1e-9, "p50={p50}");
        assert!((p99 - 128e-6).abs() < 1e-9, "p99 is still a fast bucket");
        // 50ms lands in [32.768ms,65.536ms): upper bound 65.536ms
        assert!((p100 - 65.536e-3).abs() < 1e-9, "max={p100}");
        assert!(h.mean_secs() > 100e-6 && h.mean_secs() < 1e-3);
        // latency histograms feed the summary line
        let m = Metrics::default();
        assert!(m.summary().contains("wait_p50=-"), "no jobs yet: quantiles are dashes");
        m.observe_job(Duration::from_micros(10), Duration::from_micros(300));
        assert!(m.summary().contains("wait_p50="));
        assert!(!m.summary().contains("wait_p50=-"));
        assert!(m.queue_wait_quantile(0.5) > 0.0);
        assert!(m.service_time_quantile(0.5) > 0.0);
        assert_eq!(m.queue_wait().count(), 1);
        assert_eq!(m.service_time().count(), 1);
    }

    #[test]
    fn histogram_edge_durations_saturate_into_last_bucket() {
        let h = Histogram::default();
        h.observe(Duration::ZERO);
        h.observe(Duration::MAX); // > u64::MAX µs — saturates, no wrap
        assert_eq!(h.count(), 2);
        // p100 is the last bucket's upper bound, 2^64 µs, computed in
        // f64 (no u64 shift overflow)
        let p100 = h.quantile(1.0);
        assert!((p100 - 64f64.exp2() * 1e-6).abs() / p100 < 1e-12, "p100={p100}");
        assert!(h.quantile(0.01) > 0.0, "0µs lands in the first bucket");
    }
}
