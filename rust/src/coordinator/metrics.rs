//! Service metrics: lock-free counters recorded per completed job,
//! plus log-bucketed latency histograms (queue wait / service time)
//! feeding the p50/p99 figures the `serve --pool` summary prints.

use std::sync::atomic::{AtomicU64, Ordering as AOrd};
use std::time::Duration;

use crate::coloring::Problem;

/// Number of log-2 microsecond buckets (bucket `b` holds durations in
/// `[2^b, 2^(b+1))` µs — 64 buckets cover anything a u64 can express).
const BUCKETS: usize = 64;

/// A lock-free log-2 latency histogram over microseconds. Observation
/// is two relaxed atomic adds; quantiles are bucket upper bounds (a
/// ≤2× overestimate by construction — fine for p50/p99 trend lines and
/// regression gates, which compare like against like).
#[derive(Debug)]
pub struct Histogram {
    counts: Vec<AtomicU64>,
    sum_us: AtomicU64,
    n: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_us: AtomicU64::new(0),
            n: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe(&self, d: Duration) {
        let us = d.as_micros() as u64;
        // bucket = floor(log2(us)), with 0µs landing in bucket 0
        let b = 63 - us.max(1).leading_zeros() as usize;
        self.counts[b].fetch_add(1, AOrd::Relaxed);
        self.sum_us.fetch_add(us, AOrd::Relaxed);
        self.n.fetch_add(1, AOrd::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.n.load(AOrd::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(AOrd::Relaxed) as f64 * 1e-6 / n as f64
    }

    /// The `q`-quantile (0 < q <= 1) in seconds: walk the buckets to
    /// the one holding the ceil(q·n)-th observation and report its
    /// upper bound. 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (b, c) in self.counts.iter().enumerate() {
            seen += c.load(AOrd::Relaxed);
            if seen >= target {
                return (1u128 << (b + 1)) as f64 * 1e-6;
            }
        }
        (1u128 << BUCKETS) as f64 * 1e-6
    }
}

/// Aggregated job counters.
#[derive(Debug, Default)]
pub struct Metrics {
    jobs: AtomicU64,
    failures: AtomicU64,
    pjrt_jobs: AtomicU64,
    total_colors: AtomicU64,
    /// Total engine seconds, in microseconds (atomic f64 substitute).
    total_us: AtomicU64,
    /// BGPC dynamic-session update batches applied.
    updates_bgpc: AtomicU64,
    /// D2GC dynamic-session update batches applied.
    updates_d2gc: AtomicU64,
    /// Vertices recolored across all update batches.
    recolored: AtomicU64,
    /// Colored-execution jobs completed.
    executes: AtomicU64,
    /// Kernel invocations across all execute jobs.
    exec_items: AtomicU64,
    /// Admission → dispatcher pickup, per job.
    queue_wait: Histogram,
    /// Pickup → outcome, per job (members of a fused group share the
    /// group's service time — that IS their latency).
    service_time: Histogram,
}

impl Metrics {
    pub fn record(&self, o: &super::JobOutcome) {
        self.jobs.fetch_add(1, AOrd::Relaxed);
        if !o.valid {
            self.failures.fetch_add(1, AOrd::Relaxed);
        }
        if o.engine == "pjrt" {
            self.pjrt_jobs.fetch_add(1, AOrd::Relaxed);
        }
        if let Some(b) = &o.batch {
            // updates are counted per problem (BGPC and D2GC sessions
            // share the update path but not the repair engine)
            match o.problem {
                Some(Problem::D2gc) => self.updates_d2gc.fetch_add(1, AOrd::Relaxed),
                _ => self.updates_bgpc.fetch_add(1, AOrd::Relaxed),
            };
            // A fused group shares one BatchStats: counting it per
            // member would multiply the repair's work by the group
            // size. The drain charges the group once via
            // add_recolored; lone batches (fused <= 1) count here.
            if o.fused <= 1 {
                self.recolored.fetch_add(b.recolored as u64, AOrd::Relaxed);
            }
        }
        if let Some(e) = &o.exec {
            self.executes.fetch_add(1, AOrd::Relaxed);
            self.exec_items.fetch_add(e.items, AOrd::Relaxed);
        }
        self.total_colors.fetch_add(o.n_colors as u64, AOrd::Relaxed);
        self.total_us.fetch_add((o.seconds * 1e6) as u64, AOrd::Relaxed);
    }

    /// Observe one job's queue wait (admission → pickup) and service
    /// time (pickup → outcome). Called by dispatchers for every job,
    /// including failures — tail latency includes the unlucky.
    pub fn observe_job(&self, wait: Duration, service: Duration) {
        self.queue_wait.observe(wait);
        self.service_time.observe(service);
    }

    /// Charge a fused group's recolored-vertices total once (see
    /// [`Metrics::record`] for why members must not each add it).
    pub fn add_recolored(&self, n: u64) {
        self.recolored.fetch_add(n, AOrd::Relaxed);
    }

    pub fn jobs_done(&self) -> u64 {
        self.jobs.load(AOrd::Relaxed)
    }

    pub fn failures(&self) -> u64 {
        self.failures.load(AOrd::Relaxed)
    }

    pub fn pjrt_jobs(&self) -> u64 {
        self.pjrt_jobs.load(AOrd::Relaxed)
    }

    /// Dynamic-session update batches applied (all problems).
    pub fn updates(&self) -> u64 {
        self.updates_bgpc() + self.updates_d2gc()
    }

    /// BGPC update batches applied.
    pub fn updates_bgpc(&self) -> u64 {
        self.updates_bgpc.load(AOrd::Relaxed)
    }

    /// D2GC update batches applied.
    pub fn updates_d2gc(&self) -> u64 {
        self.updates_d2gc.load(AOrd::Relaxed)
    }

    /// Vertices recolored across all update batches (fused groups
    /// counted once).
    pub fn recolored(&self) -> u64 {
        self.recolored.load(AOrd::Relaxed)
    }

    /// Colored-execution jobs completed.
    pub fn executes(&self) -> u64 {
        self.executes.load(AOrd::Relaxed)
    }

    /// Kernel invocations across all execute jobs.
    pub fn exec_items(&self) -> u64 {
        self.exec_items.load(AOrd::Relaxed)
    }

    pub fn total_seconds(&self) -> f64 {
        self.total_us.load(AOrd::Relaxed) as f64 * 1e-6
    }

    /// The queue-wait histogram (admission → dispatcher pickup).
    pub fn queue_wait(&self) -> &Histogram {
        &self.queue_wait
    }

    /// The service-time histogram (pickup → outcome).
    pub fn service_time(&self) -> &Histogram {
        &self.service_time
    }

    /// Queue-wait `q`-quantile in seconds (0.0 when no jobs ran).
    pub fn queue_wait_quantile(&self, q: f64) -> f64 {
        self.queue_wait.quantile(q)
    }

    /// Service-time `q`-quantile in seconds (0.0 when no jobs ran).
    pub fn service_time_quantile(&self, q: f64) -> f64 {
        self.service_time.quantile(q)
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "jobs={} failures={} pjrt={} updates={} (bgpc={} d2gc={}) recolored={} executes={} exec_items={} engine_secs={:.3} wait_p50={:.3}ms wait_p99={:.3}ms service_p50={:.3}ms service_p99={:.3}ms",
            self.jobs_done(),
            self.failures(),
            self.pjrt_jobs(),
            self.updates(),
            self.updates_bgpc(),
            self.updates_d2gc(),
            self.recolored(),
            self.executes(),
            self.exec_items(),
            self.total_seconds(),
            self.queue_wait_quantile(0.50) * 1e3,
            self.queue_wait_quantile(0.99) * 1e3,
            self.service_time_quantile(0.50) * 1e3,
            self.service_time_quantile(0.99) * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        let ok = crate::coordinator::JobOutcome {
            name: "a".into(),
            engine: "native",
            problem: Some(Problem::Bgpc),
            n_colors: 5,
            iterations: 1,
            seconds: 0.25,
            valid: true,
            error: None,
            batch: None,
            exec: None,
            fused: 0,
            epoch: None,
        };
        let bad = crate::coordinator::JobOutcome { valid: false, engine: "pjrt", ..ok.clone() };
        m.record(&ok);
        m.record(&bad);
        assert_eq!(m.jobs_done(), 2);
        assert_eq!(m.failures(), 1);
        assert_eq!(m.pjrt_jobs(), 1);
        assert!((m.total_seconds() - 0.5).abs() < 1e-3);
        assert!(m.summary().contains("jobs=2"));
    }

    #[test]
    fn update_batches_counted_per_problem() {
        let m = Metrics::default();
        let stats = crate::dynamic::BatchStats { recolored: 7, ..Default::default() };
        let upd = crate::coordinator::JobOutcome {
            name: "u".into(),
            engine: "native",
            problem: Some(Problem::Bgpc),
            n_colors: 5,
            iterations: 1,
            seconds: 0.01,
            valid: true,
            error: None,
            batch: Some(stats),
            exec: None,
            fused: 1,
            epoch: Some(1),
        };
        let upd2 = crate::coordinator::JobOutcome {
            problem: Some(Problem::D2gc),
            ..upd.clone()
        };
        m.record(&upd);
        m.record(&upd);
        m.record(&upd2);
        assert_eq!(m.updates(), 3);
        assert_eq!(m.updates_bgpc(), 2);
        assert_eq!(m.updates_d2gc(), 1);
        assert_eq!(m.recolored(), 21);
        assert!(m.summary().contains("updates=3"));
        assert!(m.summary().contains("d2gc=1"));
    }

    #[test]
    fn fused_group_members_share_one_recolored_charge() {
        let m = Metrics::default();
        let stats = crate::dynamic::BatchStats { recolored: 9, ..Default::default() };
        let member = crate::coordinator::JobOutcome {
            name: "f".into(),
            engine: "native",
            problem: Some(Problem::Bgpc),
            n_colors: 5,
            iterations: 1,
            seconds: 0.01,
            valid: true,
            error: None,
            batch: Some(stats),
            exec: None,
            fused: 3,
            epoch: Some(3),
        };
        // the drain records each member, then charges the group once
        m.record(&member);
        m.record(&member);
        m.record(&member);
        m.add_recolored(9);
        assert_eq!(m.updates(), 3, "each member still counts as an applied batch");
        assert_eq!(m.recolored(), 9, "the shared repair is charged exactly once");
    }

    #[test]
    fn execute_jobs_counted_with_items() {
        let m = Metrics::default();
        let ex = crate::coordinator::JobOutcome {
            name: "x".into(),
            engine: "native",
            problem: Some(Problem::Bgpc),
            n_colors: 4,
            iterations: 2,
            seconds: 0.01,
            valid: true,
            error: None,
            batch: None,
            exec: Some(crate::coordinator::ExecStats {
                colors: 4,
                rounds: 2,
                items: 120,
                busy_units: 600,
                max_color_busy: 300,
                utilization: 0.9,
                sched_moved: 0,
                sched_dirty_colors: 0,
                sched_rebuilt: false,
            }),
            fused: 0,
            epoch: Some(0),
        };
        m.record(&ex);
        m.record(&ex);
        assert_eq!(m.executes(), 2);
        assert_eq!(m.exec_items(), 240);
        assert_eq!(m.updates(), 0);
        assert!(m.summary().contains("executes=2"));
    }

    #[test]
    fn histogram_quantiles_walk_log_buckets() {
        let h = Histogram::default();
        assert_eq!(h.quantile(0.99), 0.0, "empty histogram reports 0");
        // 99 fast observations (~100µs) and one slow outlier (~50ms)
        for _ in 0..99 {
            h.observe(Duration::from_micros(100));
        }
        h.observe(Duration::from_millis(50));
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        let p100 = h.quantile(1.0);
        // 100µs lands in [64µs,128µs): upper bound 128µs
        assert!((p50 - 128e-6).abs() < 1e-9, "p50={p50}");
        assert!((p99 - 128e-6).abs() < 1e-9, "p99 is still a fast bucket");
        // 50ms lands in [32.768ms,65.536ms): upper bound 65.536ms
        assert!((p100 - 65.536e-3).abs() < 1e-9, "max={p100}");
        assert!(h.mean_secs() > 100e-6 && h.mean_secs() < 1e-3);
        // latency histograms feed the summary line
        let m = Metrics::default();
        m.observe_job(Duration::from_micros(10), Duration::from_micros(300));
        assert!(m.summary().contains("wait_p50="));
        assert!(m.queue_wait_quantile(0.5) > 0.0);
        assert!(m.service_time_quantile(0.5) > 0.0);
        assert_eq!(m.queue_wait().count(), 1);
        assert_eq!(m.service_time().count(), 1);
    }
}
