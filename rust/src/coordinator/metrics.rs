//! Service metrics: lock-free counters recorded per completed job.

use std::sync::atomic::{AtomicU64, Ordering as AOrd};

use crate::coloring::Problem;

/// Aggregated job counters.
#[derive(Debug, Default)]
pub struct Metrics {
    jobs: AtomicU64,
    failures: AtomicU64,
    pjrt_jobs: AtomicU64,
    total_colors: AtomicU64,
    /// Total engine seconds, in microseconds (atomic f64 substitute).
    total_us: AtomicU64,
    /// BGPC dynamic-session update batches applied.
    updates_bgpc: AtomicU64,
    /// D2GC dynamic-session update batches applied.
    updates_d2gc: AtomicU64,
    /// Vertices recolored across all update batches.
    recolored: AtomicU64,
    /// Colored-execution jobs completed.
    executes: AtomicU64,
    /// Kernel invocations across all execute jobs.
    exec_items: AtomicU64,
}

impl Metrics {
    pub fn record(&self, o: &super::JobOutcome) {
        self.jobs.fetch_add(1, AOrd::Relaxed);
        if !o.valid {
            self.failures.fetch_add(1, AOrd::Relaxed);
        }
        if o.engine == "pjrt" {
            self.pjrt_jobs.fetch_add(1, AOrd::Relaxed);
        }
        if let Some(b) = &o.batch {
            // updates are counted per problem (BGPC and D2GC sessions
            // share the update path but not the repair engine)
            match o.problem {
                Some(Problem::D2gc) => self.updates_d2gc.fetch_add(1, AOrd::Relaxed),
                _ => self.updates_bgpc.fetch_add(1, AOrd::Relaxed),
            };
            self.recolored.fetch_add(b.recolored as u64, AOrd::Relaxed);
        }
        if let Some(e) = &o.exec {
            self.executes.fetch_add(1, AOrd::Relaxed);
            self.exec_items.fetch_add(e.items, AOrd::Relaxed);
        }
        self.total_colors.fetch_add(o.n_colors as u64, AOrd::Relaxed);
        self.total_us.fetch_add((o.seconds * 1e6) as u64, AOrd::Relaxed);
    }

    pub fn jobs_done(&self) -> u64 {
        self.jobs.load(AOrd::Relaxed)
    }

    pub fn failures(&self) -> u64 {
        self.failures.load(AOrd::Relaxed)
    }

    pub fn pjrt_jobs(&self) -> u64 {
        self.pjrt_jobs.load(AOrd::Relaxed)
    }

    /// Dynamic-session update batches applied (all problems).
    pub fn updates(&self) -> u64 {
        self.updates_bgpc() + self.updates_d2gc()
    }

    /// BGPC update batches applied.
    pub fn updates_bgpc(&self) -> u64 {
        self.updates_bgpc.load(AOrd::Relaxed)
    }

    /// D2GC update batches applied.
    pub fn updates_d2gc(&self) -> u64 {
        self.updates_d2gc.load(AOrd::Relaxed)
    }

    /// Vertices recolored across all update batches.
    pub fn recolored(&self) -> u64 {
        self.recolored.load(AOrd::Relaxed)
    }

    /// Colored-execution jobs completed.
    pub fn executes(&self) -> u64 {
        self.executes.load(AOrd::Relaxed)
    }

    /// Kernel invocations across all execute jobs.
    pub fn exec_items(&self) -> u64 {
        self.exec_items.load(AOrd::Relaxed)
    }

    pub fn total_seconds(&self) -> f64 {
        self.total_us.load(AOrd::Relaxed) as f64 * 1e-6
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "jobs={} failures={} pjrt={} updates={} (bgpc={} d2gc={}) recolored={} executes={} exec_items={} engine_secs={:.3}",
            self.jobs_done(),
            self.failures(),
            self.pjrt_jobs(),
            self.updates(),
            self.updates_bgpc(),
            self.updates_d2gc(),
            self.recolored(),
            self.executes(),
            self.exec_items(),
            self.total_seconds()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        let ok = crate::coordinator::JobOutcome {
            name: "a".into(),
            engine: "native",
            problem: Some(Problem::Bgpc),
            n_colors: 5,
            iterations: 1,
            seconds: 0.25,
            valid: true,
            error: None,
            batch: None,
            exec: None,
        };
        let bad = crate::coordinator::JobOutcome { valid: false, engine: "pjrt", ..ok.clone() };
        m.record(&ok);
        m.record(&bad);
        assert_eq!(m.jobs_done(), 2);
        assert_eq!(m.failures(), 1);
        assert_eq!(m.pjrt_jobs(), 1);
        assert!((m.total_seconds() - 0.5).abs() < 1e-3);
        assert!(m.summary().contains("jobs=2"));
    }

    #[test]
    fn update_batches_counted_per_problem() {
        let m = Metrics::default();
        let stats = crate::dynamic::BatchStats { recolored: 7, ..Default::default() };
        let upd = crate::coordinator::JobOutcome {
            name: "u".into(),
            engine: "native",
            problem: Some(Problem::Bgpc),
            n_colors: 5,
            iterations: 1,
            seconds: 0.01,
            valid: true,
            error: None,
            batch: Some(stats),
            exec: None,
        };
        let upd2 = crate::coordinator::JobOutcome {
            problem: Some(Problem::D2gc),
            ..upd.clone()
        };
        m.record(&upd);
        m.record(&upd);
        m.record(&upd2);
        assert_eq!(m.updates(), 3);
        assert_eq!(m.updates_bgpc(), 2);
        assert_eq!(m.updates_d2gc(), 1);
        assert_eq!(m.recolored(), 21);
        assert!(m.summary().contains("updates=3"));
        assert!(m.summary().contains("d2gc=1"));
    }

    #[test]
    fn execute_jobs_counted_with_items() {
        let m = Metrics::default();
        let ex = crate::coordinator::JobOutcome {
            name: "x".into(),
            engine: "native",
            problem: Some(Problem::Bgpc),
            n_colors: 4,
            iterations: 2,
            seconds: 0.01,
            valid: true,
            error: None,
            batch: None,
            exec: Some(crate::coordinator::ExecStats {
                colors: 4,
                rounds: 2,
                items: 120,
                busy_units: 600,
                max_color_busy: 300,
                utilization: 0.9,
                sched_moved: 0,
                sched_dirty_colors: 0,
                sched_rebuilt: false,
            }),
        };
        m.record(&ex);
        m.record(&ex);
        assert_eq!(m.executes(), 2);
        assert_eq!(m.exec_items(), 240);
        assert_eq!(m.updates(), 0);
        assert!(m.summary().contains("executes=2"));
    }
}
