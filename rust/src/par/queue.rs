//! Lock-free shared work queue (ColPack's `V-V` next-iteration queue).
//!
//! The paper's baseline pushes each conflicting vertex to a *shared*
//! queue with an atomic increment ("a conflicting vertex is immediately
//! added to the shared work queue"); the `-D` variants replace this with
//! lazy per-thread queues merged at the barrier. This is the shared one:
//! a pre-allocated buffer plus an atomic tail — push is a single
//! `fetch_add` and a plain store, which is safe because every slot is
//! claimed by exactly one pusher and reads only happen after the region
//! barrier.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering as AOrd};

/// Bounded multi-producer queue; drained single-threaded after a barrier.
pub struct SharedQueue {
    buf: UnsafeCell<Vec<u32>>,
    tail: AtomicUsize,
}

// Safety: slots are claimed uniquely via fetch_add; consumers only read
// after all producers have passed the region barrier.
unsafe impl Sync for SharedQueue {}

impl SharedQueue {
    /// Create with fixed capacity (the work-queue never exceeds |V_A|).
    pub fn with_capacity(cap: usize) -> SharedQueue {
        SharedQueue { buf: UnsafeCell::new(vec![0u32; cap]), tail: AtomicUsize::new(0) }
    }

    /// Push from any thread. Panics (debug) on overflow — capacity is an
    /// invariant, not a soft limit.
    #[inline]
    pub fn push(&self, v: u32) {
        let i = self.tail.fetch_add(1, AOrd::Relaxed);
        let buf = unsafe { &mut *self.buf.get() };
        debug_assert!(i < buf.len(), "SharedQueue overflow");
        unsafe {
            *buf.get_unchecked_mut(i) = v;
        }
    }

    /// Number of pushed elements.
    pub fn len(&self) -> usize {
        self.tail.load(AOrd::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain into a Vec and reset (single-threaded, post-barrier).
    pub fn drain(&self) -> Vec<u32> {
        let n = self.tail.swap(0, AOrd::Relaxed);
        let buf = unsafe { &*self.buf.get() };
        buf[..n].to_vec()
    }

    /// Reset without reading.
    pub fn clear(&self) {
        self.tail.store(0, AOrd::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::{Cost, Driver, ThreadsDriver};

    #[test]
    fn concurrent_pushes_all_land() {
        let q = SharedQueue::with_capacity(10_000);
        let mut d = ThreadsDriver::new(4);
        let mut states = vec![(); 4];
        d.region(&mut states, 10_000, 16, |_, _, item, _| {
            q.push(item as u32);
            Cost::new(1)
        });
        let mut got = q.drain();
        got.sort_unstable();
        assert_eq!(got, (0..10_000u32).collect::<Vec<_>>());
        assert!(q.is_empty());
    }

    #[test]
    fn drain_resets() {
        let q = SharedQueue::with_capacity(4);
        q.push(7);
        assert_eq!(q.len(), 1);
        assert_eq!(q.drain(), vec![7]);
        assert_eq!(q.len(), 0);
        q.push(9);
        assert_eq!(q.drain(), vec![9]);
    }
}
