//! [`ShardedQueue`] — a finely-sharded MPMC job queue for the
//! coordinator's admission path (DESIGN.md §12).
//!
//! The previous admission path funneled every job through one
//! `mpsc::Sender` and parked dispatchers on an
//! `Arc<Mutex<mpsc::Receiver>>` — a lock *around* a channel, held while
//! a worker waited, so admission serialized on a single mutex exactly
//! the way the paper says coloring itself must not (§I: remove
//! synchronization from the hot path). This queue shards the storage so
//! producers and consumers on different shards never contend:
//!
//! * **Shards.** `n` independent `Mutex<VecDeque<T>>` rings. A push
//!   locks only its target shard; a pop scans from the consumer's
//!   *home* shard and steals round-robin from the others when home is
//!   empty — Bogle & Slota's (arXiv:2107.00075) bulk-handoff shape:
//!   affinity first, work conservation second.
//! * **Parking.** Blocking consumers park on one `Condvar` guarding a
//!   *tick* counter, never on a shard lock. A producer bumps the tick
//!   after releasing the shard lock; a waking consumer re-scans all
//!   shards before re-parking, which closes the lost-wakeup window
//!   (the tick changed ⇒ something was pushed after our last scan).
//!   No lock is ever held across a wait except the tick mutex itself,
//!   which no producer holds while doing work.
//! * **Close.** `close()` flips a flag and wakes everyone: pushes fail
//!   (the item is handed back), pops drain whatever is left and then
//!   return `None` — a drain-then-stop shutdown, so no accepted job is
//!   dropped.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering as AOrd};
use std::sync::{Condvar, Mutex, MutexGuard};

/// Poison-tolerant lock (a consumer panicking mid-`pop` must not brick
/// admission for every later job).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Cumulative queue counters (see [`ShardedQueue::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueStats {
    /// Items accepted by `push`.
    pub pushed: u64,
    /// Items handed out by `pop`/`try_pop`.
    pub popped: u64,
    /// Pops satisfied from a non-home shard (work stealing).
    pub stolen: u64,
}

/// A sharded multi-producer multi-consumer queue (see module docs).
pub struct ShardedQueue<T> {
    shards: Vec<Mutex<VecDeque<T>>>,
    /// Bumped once per successful push; consumers park on changes.
    tick: Mutex<u64>,
    cv: Condvar,
    closed: AtomicBool,
    pushed: AtomicU64,
    popped: AtomicU64,
    stolen: AtomicU64,
}

impl<T> ShardedQueue<T> {
    /// A queue with `n` shards (clamped to at least 1).
    pub fn new(n: usize) -> ShardedQueue<T> {
        let n = n.max(1);
        ShardedQueue {
            shards: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            tick: Mutex::new(0),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
            pushed: AtomicU64::new(0),
            popped: AtomicU64::new(0),
            stolen: AtomicU64::new(0),
        }
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Enqueue `item` on shard `shard % n_shards`. Returns the item
    /// back when the queue is closed. The shard lock is released
    /// *before* the wakeup tick is taken — a producer never holds two
    /// locks, so pushes on distinct shards proceed fully in parallel.
    pub fn push(&self, shard: usize, item: T) -> Result<(), T> {
        if self.closed.load(AOrd::SeqCst) {
            return Err(item);
        }
        {
            let mut q = lock(&self.shards[shard % self.shards.len()]);
            q.push_back(item);
        }
        self.pushed.fetch_add(1, AOrd::Relaxed);
        {
            let mut t = lock(&self.tick);
            *t = t.wrapping_add(1);
        }
        self.cv.notify_one();
        Ok(())
    }

    /// Non-blocking dequeue: try `home` first, then steal round-robin
    /// from the other shards. `None` means every shard was empty at the
    /// moment it was scanned.
    pub fn try_pop(&self, home: usize) -> Option<T> {
        let n = self.shards.len();
        for k in 0..n {
            let s = (home + k) % n;
            let item = lock(&self.shards[s]).pop_front();
            if let Some(item) = item {
                if k != 0 {
                    self.stolen.fetch_add(1, AOrd::Relaxed);
                }
                self.popped.fetch_add(1, AOrd::Relaxed);
                return Some(item);
            }
        }
        None
    }

    /// Blocking dequeue with stealing: returns `None` only when the
    /// queue is closed *and* fully drained. Waits on the tick condvar —
    /// no shard lock is held while parked.
    pub fn pop(&self, home: usize) -> Option<T> {
        if let Some(item) = self.try_pop(home) {
            return Some(item);
        }
        let mut t = lock(&self.tick);
        loop {
            // Re-scan under the tick lock: a push that completed after
            // our failed scan has already bumped the tick (or is about
            // to, blocked on this lock) — either way we cannot sleep
            // through it.
            if let Some(item) = self.try_pop(home) {
                return Some(item);
            }
            if self.closed.load(AOrd::SeqCst) {
                return None;
            }
            let cur = *t;
            while *t == cur && !self.closed.load(AOrd::SeqCst) {
                t = self.cv.wait(t).unwrap_or_else(|e| e.into_inner());
            }
        }
    }

    /// Close the queue: subsequent pushes fail, blocked consumers wake,
    /// remaining items stay poppable until drained.
    pub fn close(&self) {
        self.closed.store(true, AOrd::SeqCst);
        let _t = lock(&self.tick);
        self.cv.notify_all();
    }

    /// Whether `close` has been called.
    pub fn is_closed(&self) -> bool {
        self.closed.load(AOrd::SeqCst)
    }

    /// Items currently enqueued across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| lock(s).len()).sum()
    }

    /// True when every shard is empty.
    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| lock(s).is_empty())
    }

    /// Snapshot of the cumulative counters.
    pub fn stats(&self) -> QueueStats {
        QueueStats {
            pushed: self.pushed.load(AOrd::Relaxed),
            popped: self.popped.load(AOrd::Relaxed),
            stolen: self.stolen.load(AOrd::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_pop_roundtrip_single_shard() {
        let q = ShardedQueue::new(1);
        q.push(0, 1).unwrap();
        q.push(0, 2).unwrap();
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(0), Some(1));
        assert_eq!(q.pop(0), Some(2));
        assert!(q.is_empty());
        assert_eq!(q.try_pop(0), None);
    }

    #[test]
    fn stealing_finds_work_on_other_shards() {
        let q = ShardedQueue::new(4);
        q.push(2, 42).unwrap();
        // home shard 0 is empty; the pop must steal from shard 2
        assert_eq!(q.pop(0), Some(42));
        let st = q.stats();
        assert_eq!(st.pushed, 1);
        assert_eq!(st.popped, 1);
        assert_eq!(st.stolen, 1);
    }

    #[test]
    fn home_shard_preferred_over_steal() {
        let q = ShardedQueue::new(2);
        q.push(0, 10).unwrap();
        q.push(1, 11).unwrap();
        assert_eq!(q.pop(1), Some(11), "home first");
        assert_eq!(q.stats().stolen, 0);
    }

    #[test]
    fn close_drains_then_stops() {
        let q = ShardedQueue::new(2);
        q.push(0, 1).unwrap();
        q.push(1, 2).unwrap();
        q.close();
        assert_eq!(q.push(0, 3), Err(3), "closed queue rejects pushes");
        let mut got = vec![q.pop(0).unwrap(), q.pop(0).unwrap()];
        got.sort_unstable();
        assert_eq!(got, vec![1, 2], "items enqueued before close still drain");
        assert_eq!(q.pop(0), None, "then the queue reports closed");
    }

    #[test]
    fn concurrent_producers_consumers_deliver_everything_exactly_once() {
        const PRODUCERS: usize = 4;
        const CONSUMERS: usize = 3;
        const PER: usize = 500;
        let q = Arc::new(ShardedQueue::new(4));
        let seen: Arc<Vec<AtomicU64>> =
            Arc::new((0..PRODUCERS * PER).map(|_| AtomicU64::new(0)).collect());
        std::thread::scope(|s| {
            for c in 0..CONSUMERS {
                let q = Arc::clone(&q);
                let seen = Arc::clone(&seen);
                s.spawn(move || {
                    while let Some(i) = q.pop(c) {
                        seen[i].fetch_add(1, AOrd::Relaxed);
                    }
                });
            }
            for p in 0..PRODUCERS {
                let q = Arc::clone(&q);
                s.spawn(move || {
                    for i in 0..PER {
                        q.push(p + i, p * PER + i).unwrap();
                    }
                });
            }
            // producers finish, then close; consumers drain and exit
            s.spawn({
                let q = Arc::clone(&q);
                move || {
                    while q.stats().pushed < (PRODUCERS * PER) as u64 {
                        std::thread::yield_now();
                    }
                    q.close();
                }
            });
        });
        assert!(
            seen.iter().all(|c| c.load(AOrd::Relaxed) == 1),
            "every item delivered exactly once"
        );
        let st = q.stats();
        assert_eq!(st.pushed, (PRODUCERS * PER) as u64);
        assert_eq!(st.popped, (PRODUCERS * PER) as u64);
    }

    #[test]
    fn blocking_pop_wakes_on_push() {
        let q = Arc::new(ShardedQueue::new(2));
        std::thread::scope(|s| {
            let h = {
                let q = Arc::clone(&q);
                s.spawn(move || q.pop(0))
            };
            std::thread::sleep(std::time::Duration::from_millis(20));
            q.push(1, 7usize).unwrap();
            assert_eq!(h.join().unwrap(), Some(7), "parked consumer stole the push");
        });
    }
}
