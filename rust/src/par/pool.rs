//! [`WorkerPool`] — a persistent team of parked worker threads that
//! executes parallel regions without ever spawning on the hot path.
//!
//! The paper's engine is a sequence of short `#pragma omp parallel for`
//! regions: each speculate → detect iteration runs two or three of
//! them, a dynamic repair batch a handful more, and an OpenMP runtime
//! keeps one thread team alive for the whole process. The previous
//! `ThreadsDriver` instead paid `std::thread::scope` — thread creation
//! *and* join — for every region, which on small queues (conflict-
//! removal rounds, ≤1% update batches) rivals the useful work. Rokos et
//! al. (arXiv:1505.04086) and Çatalyürek et al. (arXiv:1205.3809) both
//! observe that the scheduling substrate, not the coloring arithmetic,
//! decides speculative-coloring performance at this granularity.
//!
//! Design (DESIGN.md §10):
//!
//! * **Epoch handoff.** Workers park on a condvar guarding an epoch
//!   counter. A region publishes a type-erased [`Job`] (a monomorphized
//!   trampoline plus a pointer to the caller's stack-held context),
//!   bumps the epoch and broadcasts; workers that see a new epoch run
//!   the trampoline and check back in. The calling thread always
//!   participates as tid 0, so a `team == 1` region is a plain inline
//!   loop with zero synchronization — the sequential driver for free.
//! * **Scheduling.** `chunk >= 1` claims chunks from a shared atomic
//!   cursor (`schedule(dynamic, chunk)`); `chunk == 0` splits the
//!   index space contiguously (`schedule(static)`), exactly as the
//!   simulator models them. A [`Chunk::Auto`] sentinel selects a
//!   self-tuning dynamic chunk: seeded from item count and team size,
//!   then adapted per tuner *site* from the observed busy-unit
//!   imbalance of previous regions (DESIGN.md §Perf).
//! * **Scratch residency.** The pool carries one type-erased scratch
//!   slot ([`WorkerPool::with_scratch`]) so callers that run many
//!   independent jobs (the coordinator) can keep a `ThreadState` bank —
//!   the paper's "allocated only once, never reset" arrays — alive
//!   across jobs, not just across the iterations of one run.
//! * **Containment.** A panic inside a region body (an engine assert)
//!   is caught on the worker, the team still checks in, and the panic
//!   resumes on the *calling* thread — same observable behaviour as the
//!   old scoped join, but the pool, its workers, and its locks stay
//!   usable. The coordinator converts such panics into failed
//!   [`crate::coordinator::JobOutcome`]s instead of losing a worker.
//! * **Counters.** The pool counts dispatched regions, executed items
//!   and per-worker busy units ([`WorkerPool::stats`]); every region
//!   also reports per-worker busy units in
//!   [`RegionOut::busy_units`], so imbalance diagnostics work on real
//!   threads, not only under the simulator.
//!
//! Multiple OS threads may call [`WorkerPool::region`] concurrently on
//! one shared pool (the coordinator multiplexes its whole job queue
//! onto a single team): callers serialize region-by-region on an
//! internal lock, which is the intended behaviour — one machine-wide
//! team, never thread oversubscription.
//!
//! The region drain doubles as a barrier primitive: the caller returns
//! only after every participant has checked in, and the epoch mutex
//! orders all of region *k*'s writes before region *k + 1*'s reads.
//! [`crate::exec::Executor`] builds colored execution on exactly this —
//! one region per color frontier, the drain as the inter-color barrier
//! (DESIGN.md §11).

use std::any::Any;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering as AOrd};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use super::{Cost, RegionOut};

/// Poison-tolerant lock: a panic that unwinds through a region caller
/// must not brick the pool for every later job.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Number of distinct [`Chunk::Auto`] tuner sites a pool tracks. Each
/// site owns an independent feedback loop, so the engine's speculate and
/// detect regions (very different item costs) never fight over one
/// chunk estimate.
pub const AUTO_SITES: usize = 8;

/// Raw `usize` values `>= AUTO_MIN_RAW` encode `Chunk::Auto(site)` —
/// far above any meaningful fixed chunk, so the existing `chunk: usize`
/// plumbing (driver trait, schedules, phase signatures) carries the
/// sentinel unchanged.
const AUTO_MIN_RAW: usize = usize::MAX - (AUTO_SITES - 1);

/// Well-known tuner sites (see [`Chunk::Auto`]). `GENERIC` is what the
/// CLI's `--chunk auto` selects; the engines re-aim it per phase via
/// [`Chunk::resite`] so speculation and detection tune independently.
pub mod autosite {
    /// Unsited auto (CLI/default before an engine re-aims it).
    pub const GENERIC: usize = 0;
    /// Full-run speculate (color) regions.
    pub const SPECULATE: usize = 1;
    /// Full-run detect (conflict/rebuild) regions.
    pub const DETECT: usize = 2;
    /// Dynamic-repair speculate regions (dirty frontiers).
    pub const REPAIR_SPECULATE: usize = 3;
    /// Dynamic-repair detect regions.
    pub const REPAIR_DETECT: usize = 4;
}

/// Chunk-size selection for a parallel region.
///
/// The [`crate::par::Driver`] trait (and every schedule/phase signature
/// above it) threads a plain `usize`; this enum is the typed view with a
/// reversible encoding: `0` = `Static`, `1..` = `Fixed(n)`, and a high
/// sentinel range for `Auto(site)`. The pool, the simulator and the
/// reference spawn driver all [`Chunk::decode`] before scheduling, so an
/// `Auto` sentinel can never reach a cursor `fetch_add`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Chunk {
    /// `schedule(static)`: contiguous per-thread blocks.
    Static,
    /// `schedule(dynamic, n)` with a fixed chunk (`n >= 1`).
    Fixed(usize),
    /// Self-tuning dynamic chunk, tracked per tuner site (`site <
    /// AUTO_SITES`): seeded by [`auto_seed`], clamped per dispatch by
    /// [`auto_effective`], adapted across epochs by [`auto_adapt`] from
    /// the region's [`RegionOut::busy_units`] imbalance.
    Auto(usize),
}

impl Chunk {
    /// Encode into the raw `usize` the driver plumbing carries.
    /// `Fixed(n)` requires `1 <= n < AUTO_MIN_RAW` (any practical chunk).
    pub const fn encode(self) -> usize {
        match self {
            Chunk::Static => 0,
            Chunk::Fixed(n) => n,
            Chunk::Auto(site) => usize::MAX - (site % AUTO_SITES),
        }
    }

    /// Decode a raw `usize` chunk (inverse of [`Chunk::encode`]).
    pub const fn decode(raw: usize) -> Chunk {
        if raw == 0 {
            Chunk::Static
        } else if raw >= AUTO_MIN_RAW {
            Chunk::Auto(usize::MAX - raw)
        } else {
            Chunk::Fixed(raw)
        }
    }

    /// Re-aim a raw chunk at tuner `site` when it is `Auto`; static and
    /// fixed values pass through untouched. The engines call this so one
    /// `--chunk auto` spec feeds per-phase tuner sites.
    pub const fn resite(raw: usize, site: usize) -> usize {
        match Chunk::decode(raw) {
            Chunk::Auto(_) => Chunk::Auto(site).encode(),
            _ => raw,
        }
    }
}

/// Mean-over-max busy fraction of one region (1.0 = perfectly balanced,
/// `1/len` = one participant did everything; 1.0 when nobody recorded
/// busy units — an idle region is not "imbalanced"). Shared by
/// [`PoolStats::utilization`] and the [`Chunk::Auto`] feedback loop.
pub fn utilization_of(busy_units: &[u64]) -> f64 {
    let max = busy_units.iter().copied().max().unwrap_or(0);
    if max == 0 {
        return 1.0;
    }
    let sum: u64 = busy_units.iter().sum();
    sum as f64 / (max as f64 * busy_units.len() as f64)
}

/// Seed chunk for a fresh [`Chunk::Auto`] site: aim for ~8 chunks per
/// participant (enough granularity to rebalance, few enough cursor
/// grabs to stay cheap), clamped to `[1, 1024]`.
pub fn auto_seed(n_items: usize, team: usize) -> usize {
    (n_items / (team.max(1) * 8)).clamp(1, 1024)
}

/// Clamp a tuned chunk for one dispatch: never larger than a `1/team`
/// share of the region (that would serialize it), never below 1.
pub fn auto_effective(tuned: usize, n_items: usize, team: usize) -> usize {
    let cap = (n_items / team.max(1)).max(1);
    tuned.clamp(1, cap)
}

/// One feedback step for a [`Chunk::Auto`] site: low utilization means
/// the tail was stuck behind a big chunk — halve; near-perfect balance
/// means cursor traffic is the only remaining cost — double. The
/// half/double step converges in O(log) epochs from any seed and the
/// same pure function drives the pool and the simulator, so sim runs
/// stay deterministic.
pub fn auto_adapt(cur: usize, busy_units: &[u64]) -> usize {
    let util = utilization_of(busy_units);
    if util < 0.80 {
        (cur / 2).max(1)
    } else if util > 0.95 {
        (cur * 2).min(65_536)
    } else {
        cur
    }
}

/// Best-effort human-readable panic payload (panics carry `&str` or
/// `String` in practice). Shared with the coordinator's job-outcome
/// conversion.
pub fn panic_message(p: &(dyn Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic payload".to_string()
    }
}

/// A type-erased parallel region: `run` is the monomorphized trampoline
/// ([`run_region`]) and `data` points to the publishing caller's
/// stack-held [`Ctx`].
#[derive(Clone, Copy)]
struct Job {
    run: unsafe fn(*const (), usize),
    data: *const (),
    /// Worker tids `1..team` participate; the caller is tid 0.
    team: usize,
}

// SAFETY: `data` points into the stack frame of the `region` call that
// published the job. That frame provably outlives every worker's use of
// it — the caller blocks until all participants have checked in — and
// each participant touches only its own disjoint `tid` slot of the
// mutable state (the `TS: Send` / `F: Sync` bounds on `region` make the
// transfer itself sound).
unsafe impl Send for Job {}

struct Gate {
    /// Bumped once per dispatched region; workers compare against the
    /// last epoch they served to detect fresh work after a wakeup.
    epoch: u64,
    job: Option<Job>,
    /// Participants that have not yet checked in for the current epoch.
    outstanding: usize,
    /// First panic message from a region body on a worker this epoch.
    panic_msg: Option<String>,
    shutdown: bool,
}

struct Shared {
    sync: Mutex<Gate>,
    work_cv: Condvar,
    done_cv: Condvar,
}

/// The caller-side context a [`Job`] points at. One per region, on the
/// caller's stack; workers reach it only through the trampoline.
struct Ctx<TS, F> {
    states: *mut TS,
    body: *const F,
    cursor: AtomicUsize,
    n_items: usize,
    /// `0` = contiguous static split, `>= 1` = dynamic chunk size.
    chunk: usize,
    team: usize,
    /// Per-participant busy work units for this region (the pool's
    /// reusable buffer; at least `team` entries, zeroed at publish).
    busy: *const AtomicU64,
}

/// The monomorphized region trampoline: claims work for `tid` and runs
/// the body over it, accumulating the returned [`Cost`] units.
///
/// # Safety
/// `data` must point to a live `Ctx<TS, F>` whose `states` array holds
/// at least `team` elements, and each `tid` must be used by exactly one
/// thread per region.
unsafe fn run_region<TS, F>(data: *const (), tid: usize)
where
    TS: Send,
    F: Fn(usize, &mut TS, usize, u64) -> Cost + Sync,
{
    let ctx = &*(data as *const Ctx<TS, F>);
    let body = &*ctx.body;
    let ts = &mut *ctx.states.add(tid);
    let mut units = 0u64;
    if ctx.chunk == 0 {
        // schedule(static): contiguous blocks
        let lo = ctx.n_items * tid / ctx.team;
        let hi = ctx.n_items * (tid + 1) / ctx.team;
        for item in lo..hi {
            units += body(tid, ts, item, 0).units;
        }
    } else {
        // schedule(dynamic, chunk): shared atomic cursor
        loop {
            let start = ctx.cursor.fetch_add(ctx.chunk, AOrd::Relaxed);
            if start >= ctx.n_items {
                break;
            }
            let end = (start + ctx.chunk).min(ctx.n_items);
            for item in start..end {
                units += body(tid, ts, item, 0).units;
            }
        }
    }
    (*ctx.busy.add(tid)).fetch_add(units, AOrd::Relaxed);
}

fn worker_loop(shared: &Shared, wid: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut g = lock(&shared.sync);
            loop {
                if g.shutdown {
                    return;
                }
                if g.epoch != seen {
                    seen = g.epoch;
                    break g.job;
                }
                g = shared.work_cv.wait(g).unwrap_or_else(|e| e.into_inner());
            }
        };
        // `job` is always `Some` while a region is in flight; a stale
        // `None` can only be seen by a non-participant that slept
        // through a whole region, and it simply re-parks.
        let Some(job) = job else { continue };
        if wid < job.team {
            // SAFETY: see `Job` — the publishing caller keeps the
            // context alive until this worker checks in below.
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
                (job.run)(job.data, wid)
            }));
            let mut g = lock(&shared.sync);
            if let Err(p) = r {
                let msg = panic_message(p.as_ref());
                g.panic_msg.get_or_insert(msg);
            }
            g.outstanding -= 1;
            if g.outstanding == 0 {
                shared.done_cv.notify_all();
            }
        }
    }
}

/// Cumulative pool counters (see [`WorkerPool::stats`]).
#[derive(Clone, Debug)]
pub struct PoolStats {
    /// Team size (caller + parked workers).
    pub threads: usize,
    /// Regions dispatched over the pool's lifetime.
    pub regions: u64,
    /// Work items executed across all regions.
    pub items: u64,
    /// Cumulative busy work units per worker (index 0 = the callers).
    pub busy_units: Vec<u64>,
}

impl PoolStats {
    /// Mean-over-max busy fraction across workers: 1.0 = perfectly
    /// balanced, `1/threads` = one worker did everything, 1.0 when no
    /// busy units were recorded at all (never NaN — see
    /// [`utilization_of`]).
    pub fn utilization(&self) -> f64 {
        utilization_of(&self.busy_units)
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "threads={} regions={} items={} utilization={:.2}",
            self.threads,
            self.regions,
            self.items,
            self.utilization()
        )
    }
}

/// A persistent team of parked workers executing parallel regions (see
/// the module docs). Constructed once, shared via `Arc`, dropped when
/// the last driver/service holding it goes away.
pub struct WorkerPool {
    t: usize,
    shared: Arc<Shared>,
    /// Serializes concurrent callers: one region in flight at a time.
    region_lock: Mutex<()>,
    /// Resident type-erased scratch (see [`WorkerPool::with_scratch`]).
    scratch: Mutex<Option<Box<dyn Any + Send>>>,
    regions: AtomicU64,
    items: AtomicU64,
    busy: Vec<AtomicU64>,
    /// Per-participant counters of the in-flight region, reused across
    /// dispatches (exclusive via `region_lock`) — tiny regions pay no
    /// allocation for their counters.
    region_busy: Vec<AtomicU64>,
    /// Per-site [`Chunk::Auto`] state: the last adapted chunk (0 =
    /// unseeded). Relaxed atomics — a lost update just replays one
    /// feedback step.
    tuners: Vec<AtomicUsize>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `t` threads total: the calling thread (tid 0 of
    /// every region) plus `t - 1` parked workers. This is the only
    /// place in the crate that creates threads for region execution.
    pub fn new(t: usize) -> WorkerPool {
        assert!(t >= 1, "a pool needs at least the calling thread");
        let shared = Arc::new(Shared {
            sync: Mutex::new(Gate {
                epoch: 0,
                job: None,
                outstanding: 0,
                panic_msg: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..t)
            .map(|wid| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("bgpc-pool-{wid}"))
                    .spawn(move || worker_loop(&shared, wid))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool {
            t,
            shared,
            region_lock: Mutex::new(()),
            scratch: Mutex::new(None),
            regions: AtomicU64::new(0),
            items: AtomicU64::new(0),
            busy: (0..t).map(|_| AtomicU64::new(0)).collect(),
            region_busy: (0..t).map(|_| AtomicU64::new(0)).collect(),
            tuners: (0..AUTO_SITES).map(|_| AtomicUsize::new(0)).collect(),
            handles,
        }
    }

    /// The current tuned chunk of auto site `site` (0 = not yet seeded).
    /// Diagnostic/test hook for the [`Chunk::Auto`] feedback loop.
    pub fn tuned_chunk(&self, site: usize) -> usize {
        self.tuners[site % AUTO_SITES].load(AOrd::Relaxed)
    }

    /// Team size (caller + parked workers).
    pub fn threads(&self) -> usize {
        self.t
    }

    /// Regions dispatched so far.
    pub fn regions_dispatched(&self) -> u64 {
        self.regions.load(AOrd::Relaxed)
    }

    /// Snapshot of the cumulative counters.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            threads: self.t,
            regions: self.regions.load(AOrd::Relaxed),
            items: self.items.load(AOrd::Relaxed),
            busy_units: self.busy.iter().map(|b| b.load(AOrd::Relaxed)).collect(),
        }
    }

    /// Run `f` against the pool-resident scratch value, creating it
    /// with `init` on first use (or if a previous caller left a
    /// different type behind). The slot keeps the value alive across
    /// calls — this is how the coordinator reuses one `ThreadState`
    /// bank for every job it multiplexes onto the pool, extending the
    /// paper's "allocated only once" invariant across job boundaries.
    /// Callers are serialized for the duration of `f`.
    pub fn with_scratch<S, R>(&self, init: impl FnOnce() -> S, f: impl FnOnce(&mut S) -> R) -> R
    where
        S: Send + 'static,
    {
        let mut slot = lock(&self.scratch);
        let fresh = match slot.as_ref() {
            Some(b) => !b.is::<S>(),
            None => true,
        };
        if fresh {
            *slot = Some(Box::new(init()));
        }
        f(slot.as_mut().unwrap().downcast_mut::<S>().unwrap())
    }

    /// Execute one parallel region over `0..n_items` with `team`
    /// threads (clamped to the pool size), one scratch state per
    /// participant. `chunk == 0` is `schedule(static)`, `chunk >= 1`
    /// is `schedule(dynamic, chunk)`, and a [`Chunk::Auto`] sentinel
    /// (see [`Chunk::encode`]) resolves through the pool's per-site
    /// tuner before dispatch and feeds the observed imbalance back
    /// afterwards. The returned [`RegionOut::busy_units`] holds
    /// per-participant work units.
    ///
    /// # Panics
    /// If `states` holds fewer than `team` entries (a driver contract
    /// violation — the coordinator surfaces it as a failed job, see
    /// DESIGN.md §10), or to propagate a panic from the region body.
    pub fn region<TS, F>(
        &self,
        states: &mut [TS],
        team: usize,
        n_items: usize,
        chunk: usize,
        body: F,
    ) -> RegionOut
    where
        TS: Send,
        F: Fn(usize, &mut TS, usize, u64) -> Cost + Sync,
    {
        let team = team.clamp(1, self.t);
        assert!(
            states.len() >= team,
            "worker pool: {} scratch states for a team of {team} (one per thread required)",
            states.len()
        );
        // Resolve a Chunk::Auto sentinel before it can reach the cursor.
        let (chunk, auto_site) = match Chunk::decode(chunk) {
            Chunk::Auto(site) => {
                let site = site % AUTO_SITES;
                let tuned = self.tuners[site].load(AOrd::Relaxed);
                let base = if tuned == 0 { auto_seed(n_items, team) } else { tuned };
                (auto_effective(base, n_items, team), Some(site))
            }
            _ => (chunk, None),
        };
        // one span per region, covering both the inline and the
        // dispatch path — the pool-layer phase in the Chrome trace
        let _sp = crate::obs::trace::span_n("pool.region", n_items as u64);
        let t0 = std::time::Instant::now();
        self.regions.fetch_add(1, AOrd::Relaxed);

        if team == 1 || n_items == 0 {
            // Inline sequential path: no handoff, no synchronization.
            let ts = &mut states[0];
            let mut units = 0u64;
            for item in 0..n_items {
                units += body(0, ts, item, 0).units;
            }
            self.items.fetch_add(n_items as u64, AOrd::Relaxed);
            self.busy[0].fetch_add(units, AOrd::Relaxed);
            let mut busy_units = vec![0u64; team];
            busy_units[0] = units;
            return RegionOut {
                real_secs: t0.elapsed().as_secs_f64(),
                sim_ns: None,
                busy_units,
            };
        }

        let _serialize = lock(&self.region_lock);
        // region_lock is held and every previous participant has checked
        // in, so the reusable counter buffer has no concurrent writers.
        for b in self.region_busy.iter().take(team) {
            b.store(0, AOrd::Relaxed);
        }
        let ctx = Ctx::<TS, F> {
            states: states.as_mut_ptr(),
            body: &body,
            cursor: AtomicUsize::new(0),
            n_items,
            chunk,
            team,
            busy: self.region_busy.as_ptr(),
        };
        let job = Job {
            run: run_region::<TS, F>,
            data: &ctx as *const Ctx<TS, F> as *const (),
            team,
        };
        {
            let mut g = lock(&self.shared.sync);
            g.job = Some(job);
            g.epoch = g.epoch.wrapping_add(1);
            g.outstanding = team - 1;
            g.panic_msg = None;
            self.shared.work_cv.notify_all();
        }
        // The caller is always participant 0: region handoff costs one
        // broadcast, never a spawn. Catch its panics so the workers can
        // finish with the context still alive, then resume below.
        // SAFETY: `ctx` outlives the wait loop that follows.
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            run_region::<TS, F>(job.data, 0)
        }));
        let worker_panic = {
            let mut g = lock(&self.shared.sync);
            while g.outstanding > 0 {
                g = self.shared.done_cv.wait(g).unwrap_or_else(|e| e.into_inner());
            }
            g.job = None;
            g.panic_msg.take()
        };
        if let Err(p) = caller {
            std::panic::resume_unwind(p);
        }
        if let Some(msg) = worker_panic {
            panic!("pool worker panicked in region body: {msg}");
        }

        let busy_units: Vec<u64> =
            self.region_busy.iter().take(team).map(|b| b.load(AOrd::Relaxed)).collect();
        for (slot, &b) in self.busy.iter().zip(busy_units.iter()) {
            slot.fetch_add(b, AOrd::Relaxed);
        }
        self.items.fetch_add(n_items as u64, AOrd::Relaxed);
        if let Some(site) = auto_site {
            // feedback: next dispatch at this site starts from here
            self.tuners[site].store(auto_adapt(chunk, &busy_units), AOrd::Relaxed);
        }
        RegionOut {
            real_secs: t0.elapsed().as_secs_f64(),
            sim_ns: None,
            busy_units,
        }
    }
}

/// A fixed set of independent [`WorkerPool`] teams — the execution side
/// of the coordinator's shard layout (DESIGN.md §12). Sessions and jobs
/// are pinned to a shard (`id % n_shards`), so two shards never
/// serialize on one `region_lock`; aggregate accounting still reads as
/// one pool.
pub struct PoolSet {
    pools: Vec<Arc<WorkerPool>>,
}

impl PoolSet {
    /// `shards` independent teams of `threads_each` threads (both
    /// clamped to at least 1).
    pub fn new(shards: usize, threads_each: usize) -> PoolSet {
        let shards = shards.max(1);
        let threads_each = threads_each.max(1);
        PoolSet {
            pools: (0..shards).map(|_| Arc::new(WorkerPool::new(threads_each))).collect(),
        }
    }

    /// Number of shards (always ≥ 1).
    pub fn n_shards(&self) -> usize {
        self.pools.len()
    }

    /// The pool owning shard `i % n_shards`.
    pub fn shard(&self, i: usize) -> &Arc<WorkerPool> {
        &self.pools[i % self.pools.len()]
    }

    /// Aggregated counters across all shards: threads, regions and
    /// items sum; the per-worker busy vectors concatenate (shard 0's
    /// workers first). With one shard this is exactly that pool's
    /// [`WorkerPool::stats`].
    pub fn stats(&self) -> PoolStats {
        let mut agg = PoolStats { threads: 0, regions: 0, items: 0, busy_units: Vec::new() };
        for p in &self.pools {
            let s = p.stats();
            agg.threads += s.threads;
            agg.regions += s.regions;
            agg.items += s.items;
            agg.busy_units.extend_from_slice(&s.busy_units);
        }
        agg
    }

    /// Per-shard counter snapshots, indexed by shard.
    pub fn shard_stats(&self) -> Vec<PoolStats> {
        self.pools.iter().map(|p| p.stats()).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut g = lock(&self.shared.sync);
            g.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_reuses_workers_across_regions() {
        let pool = WorkerPool::new(4);
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        let mut states = vec![(); 4];
        for _ in 0..10 {
            pool.region(&mut states, 4, 1000, 64, |_tid, _ts, item, _now| {
                hits[item].fetch_add(1, AOrd::Relaxed);
                Cost::new(1)
            });
        }
        assert!(hits.iter().all(|h| h.load(AOrd::Relaxed) == 10));
        let st = pool.stats();
        assert_eq!(st.threads, 4);
        assert_eq!(st.regions, 10);
        assert_eq!(st.items, 10_000);
        assert_eq!(st.busy_units.iter().sum::<u64>(), 10_000);
    }

    #[test]
    fn static_split_covers_disjointly() {
        let pool = WorkerPool::new(3);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        let mut states = vec![(); 3];
        let out = pool.region(&mut states, 3, 100, 0, |_tid, _ts, item, _now| {
            hits[item].fetch_add(1, AOrd::Relaxed);
            Cost::new(2)
        });
        assert!(hits.iter().all(|h| h.load(AOrd::Relaxed) == 1));
        assert_eq!(out.busy_units.len(), 3);
        assert_eq!(out.busy_units.iter().sum::<u64>(), 200);
    }

    #[test]
    fn smaller_team_than_pool_is_fine() {
        let pool = WorkerPool::new(8);
        let count = AtomicU64::new(0);
        let mut states = vec![(); 2];
        let out = pool.region(&mut states, 2, 500, 16, |_, _, _, _| {
            count.fetch_add(1, AOrd::Relaxed);
            Cost::new(1)
        });
        assert_eq!(count.load(AOrd::Relaxed), 500);
        assert_eq!(out.busy_units.len(), 2);
    }

    #[test]
    fn shared_pool_serializes_concurrent_callers() {
        let pool = Arc::new(WorkerPool::new(4));
        let total = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            for _ in 0..3 {
                let pool = Arc::clone(&pool);
                let total = Arc::clone(&total);
                s.spawn(move || {
                    let mut states = vec![(); 4];
                    for _ in 0..5 {
                        pool.region(&mut states, 4, 200, 8, |_, _, _, _| {
                            total.fetch_add(1, AOrd::Relaxed);
                            Cost::new(1)
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(AOrd::Relaxed), 3 * 5 * 200);
        assert_eq!(pool.stats().regions, 15);
    }

    #[test]
    fn scratch_slot_persists_across_uses() {
        let pool = WorkerPool::new(2);
        let first = pool.with_scratch(|| vec![0u64; 4], |v: &mut Vec<u64>| {
            v[0] += 1;
            v[0]
        });
        assert_eq!(first, 1);
        let second = pool.with_scratch(|| vec![0u64; 4], |v: &mut Vec<u64>| {
            v[0] += 1;
            v[0]
        });
        assert_eq!(second, 2, "the slot must survive between calls");
        // a different type replaces the slot
        let replaced = pool.with_scratch(|| 7i64, |x: &mut i64| *x);
        assert_eq!(replaced, 7);
    }

    #[test]
    fn body_panic_resumes_on_caller_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let mut states = vec![(); 4];
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.region(&mut states, 4, 100, 1, |_tid, _ts, item, _now| {
                assert!(item != 37, "planted failure");
                Cost::new(1)
            });
        }));
        assert!(r.is_err(), "the region body panic must propagate");
        // the team is intact: the next region completes normally
        let count = AtomicU64::new(0);
        pool.region(&mut states, 4, 100, 8, |_, _, _, _| {
            count.fetch_add(1, AOrd::Relaxed);
            Cost::new(1)
        });
        assert_eq!(count.load(AOrd::Relaxed), 100);
    }

    #[test]
    fn pool_set_shards_are_independent_and_aggregate() {
        let set = PoolSet::new(2, 2);
        assert_eq!(set.n_shards(), 2);
        let mut states = vec![(); 2];
        set.shard(0).region(&mut states, 2, 100, 8, |_, _, _, _| Cost::new(1));
        set.shard(1).region(&mut states, 2, 50, 8, |_, _, _, _| Cost::new(2));
        // shard(i) wraps modulo n_shards
        assert!(Arc::ptr_eq(set.shard(0), set.shard(2)));
        let agg = set.stats();
        assert_eq!(agg.threads, 4, "2 shards x 2 threads");
        assert_eq!(agg.regions, 2);
        assert_eq!(agg.items, 150);
        assert_eq!(agg.busy_units.len(), 4);
        assert_eq!(agg.busy_units.iter().sum::<u64>(), 100 + 100);
        let per = set.shard_stats();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].items, 100);
        assert_eq!(per[1].items, 50);
    }

    #[test]
    fn utilization_reflects_skew() {
        let even = PoolStats { threads: 2, regions: 1, items: 2, busy_units: vec![50, 50] };
        assert!((even.utilization() - 1.0).abs() < 1e-12);
        let skewed = PoolStats { threads: 2, regions: 1, items: 2, busy_units: vec![100, 0] };
        assert!((skewed.utilization() - 0.5).abs() < 1e-12);
        let idle = PoolStats { threads: 2, regions: 0, items: 0, busy_units: vec![0, 0] };
        assert_eq!(idle.utilization(), 1.0);
        assert!(idle.summary().contains("regions=0"));
        // degenerate inputs stay finite
        assert_eq!(utilization_of(&[]), 1.0);
        assert_eq!(utilization_of(&[0]), 1.0);
    }

    #[test]
    fn chunk_encoding_roundtrips_and_resites() {
        assert_eq!(Chunk::Static.encode(), 0);
        assert_eq!(Chunk::Fixed(64).encode(), 64);
        assert!(matches!(Chunk::decode(0), Chunk::Static));
        assert!(matches!(Chunk::decode(64), Chunk::Fixed(64)));
        for site in 0..AUTO_SITES {
            let raw = Chunk::Auto(site).encode();
            assert!(raw >= AUTO_MIN_RAW, "sentinel range");
            assert!(matches!(Chunk::decode(raw), Chunk::Auto(s) if s == site));
        }
        // site index wraps into range instead of escaping the sentinel band
        assert!(matches!(Chunk::decode(Chunk::Auto(AUTO_SITES + 1).encode()), Chunk::Auto(1)));
        // resite re-aims Auto and leaves Static/Fixed untouched
        let generic = Chunk::Auto(autosite::GENERIC).encode();
        assert_eq!(
            Chunk::resite(generic, autosite::DETECT),
            Chunk::Auto(autosite::DETECT).encode()
        );
        assert_eq!(Chunk::resite(0, autosite::DETECT), 0);
        assert_eq!(Chunk::resite(64, autosite::DETECT), 64);
    }

    #[test]
    fn auto_tuner_seeds_and_adapts() {
        // seed: ~8 chunks per worker, clamped to [1, 1024]
        assert_eq!(auto_seed(0, 4), 1);
        assert_eq!(auto_seed(6400, 4), 200);
        assert_eq!(auto_seed(10_000_000, 1), 1024);
        // effective: never larger than one team-share of the items
        assert_eq!(auto_effective(1024, 8, 4), 2);
        assert_eq!(auto_effective(16, 6400, 4), 16);
        assert_eq!(auto_effective(16, 0, 4), 1);
        // adapt: shrink on imbalance, grow when fully balanced, hold between
        assert_eq!(auto_adapt(64, &[100, 10]), 32);
        assert_eq!(auto_adapt(1, &[100, 0]), 1);
        assert_eq!(auto_adapt(64, &[100, 100]), 128);
        assert_eq!(auto_adapt(65_536, &[100, 100]), 65_536);
        assert_eq!(auto_adapt(64, &[100, 85]), 64);
    }

    #[test]
    fn auto_chunk_region_covers_items_and_feeds_the_tuner() {
        let pool = WorkerPool::new(4);
        let raw = Chunk::Auto(autosite::GENERIC).encode();
        let hits: Vec<AtomicU64> = (0..2000).map(|_| AtomicU64::new(0)).collect();
        let mut states = vec![(); 4];
        for _ in 0..3 {
            let out = pool.region(&mut states, 4, 2000, raw, |_tid, _ts, item, _now| {
                hits[item].fetch_add(1, AOrd::Relaxed);
                Cost::new(1)
            });
            assert_eq!(out.busy_units.iter().sum::<u64>(), 2000);
        }
        assert!(hits.iter().all(|h| h.load(AOrd::Relaxed) == 3), "every item exactly once per epoch");
        let tuned = pool.tuned_chunk(autosite::GENERIC);
        assert!(tuned >= 1, "the dispatch feedback must seed the tuner");
        // other sites stay untouched
        assert_eq!(pool.tuned_chunk(autosite::DETECT), 0);
    }

    #[test]
    fn auto_chunk_single_thread_team_takes_the_inline_path() {
        let pool = WorkerPool::new(2);
        let raw = Chunk::Auto(autosite::SPECULATE).encode();
        let mut states = vec![(); 1];
        let order = Mutex::new(Vec::new());
        let out = pool.region(&mut states, 1, 10, raw, |_tid, _ts, item, _now| {
            lock(&order).push(item);
            Cost::new(1)
        });
        assert_eq!(*lock(&order), (0..10).collect::<Vec<_>>(), "inline = sequential order");
        assert_eq!(out.busy_units, vec![10]);
    }
}
