//! OpenMP-equivalent parallel runtime.
//!
//! The paper's kernels are `#pragma omp parallel for schedule(dynamic,
//! chunk)` loops over a work array, with the chunk size itself a studied
//! knob (`V-V` ⇒ chunk 1, `V-V-64*` ⇒ chunk 64). This module provides the
//! same construct three ways behind one [`Driver`] trait:
//!
//! * [`ThreadsDriver`] — real threads from a persistent [`WorkerPool`]
//!   (parked workers, epoch handoff, shared atomic cursor for dynamic
//!   scheduling; DESIGN.md §10). Used for concurrency correctness on
//!   any host; regions never spawn threads.
//! * [`crate::sim::SimDriver`] — deterministic discrete-event virtual
//!   threads with a calibrated cost model; reproduces the paper's
//!   16-thread behaviour on this 1-core testbed (DESIGN.md §4).
//! * `ThreadsDriver` with `t = 1` — the sequential baseline (an inline
//!   loop on the calling thread, no synchronization at all).
//!
//! A region body is `Fn(tid, &mut TS, item, now) -> Cost`: `TS` is the
//! thread-private scratch (forbidden arrays, local queues — the paper's
//! "allocated only once, never reset" state), `now` is the virtual clock
//! (0 under real threads), and the returned [`Cost`] is the work the item
//! actually performed (edges traversed, atomics issued): the simulator
//! charges it to virtual clocks, the pool counts it into the per-worker
//! busy counters.

pub mod mpmc;
pub mod pool;
pub mod queue;

use std::sync::atomic::Ordering as AOrd;
use std::sync::Arc;

pub use mpmc::{QueueStats, ShardedQueue};
pub use pool::{
    auto_adapt, auto_effective, auto_seed, autosite, utilization_of, AUTO_SITES, Chunk, PoolSet,
    PoolStats, WorkerPool,
};
pub use queue::SharedQueue;

/// Work performed by one item, reported by region bodies.
#[derive(Clone, Copy, Debug, Default)]
pub struct Cost {
    /// Abstract work units (≈ adjacency entries touched).
    pub units: u64,
    /// Atomic RMW operations issued (shared-queue pushes etc.); the
    /// simulator charges these with a contention factor.
    pub atomics: u32,
}

impl Cost {
    #[inline]
    pub fn new(units: u64) -> Cost {
        Cost { units, atomics: 0 }
    }
}

/// Result of one parallel region.
#[derive(Clone, Debug, Default)]
pub struct RegionOut {
    /// Measured wall-clock seconds (real executions).
    pub real_secs: f64,
    /// Simulated nanoseconds (None for real executions).
    pub sim_ns: Option<f64>,
    /// Per-thread busy work units, used for imbalance diagnostics and
    /// the balancing experiments. The simulator reports modeled units
    /// (item base + atomics included); the real-thread pool reports the
    /// [`Cost::units`] each participant accumulated.
    pub busy_units: Vec<u64>,
}

impl RegionOut {
    /// The time this region contributes to the engine's notion of
    /// wall-clock: simulated if available, else measured.
    pub fn seconds(&self) -> f64 {
        match self.sim_ns {
            Some(ns) => ns * 1e-9,
            None => self.real_secs,
        }
    }
}

/// Color storage abstraction: real executions use atomics; the simulator
/// uses a two-version (MVCC) store so optimistic races manifest
/// deterministically (reads at an item's start time do not observe writes
/// committed later — exactly the stale-read behaviour the paper's
/// speculative coloring tolerates).
pub trait ColorStore: Sync {
    fn n(&self) -> usize;
    /// Read as seen by an item that started at virtual time `now`.
    fn read(&self, u: usize, now: u64) -> i32;
    /// Write `val`, committing at virtual time `commit`.
    fn write(&self, u: usize, val: i32, commit: u64);
    /// Read the fully-committed value (between regions / at the end).
    fn committed(&self, u: usize) -> i32;
    /// Best-effort prefetch of the cell backing `u` — a pure hint with
    /// no observable effect. The atomic store pulls the cache line
    /// early for the gather loops; the simulator keeps this default
    /// no-op so modeled costs and colorings are byte-identical with or
    /// without prefetching (DESIGN.md §Perf).
    #[inline]
    fn prefetch(&self, u: usize) {
        let _ = u;
    }
    /// Snapshot all committed values.
    fn to_vec(&self) -> Vec<i32> {
        (0..self.n()).map(|u| self.committed(u)).collect()
    }
    /// Reset every cell to `val` (between runs).
    fn fill(&self, val: i32);
}

/// Atomic color array for real (threaded/sequential) executions.
pub struct AtomicColors {
    cells: Vec<std::sync::atomic::AtomicI32>,
}

impl AtomicColors {
    pub fn new(n: usize) -> AtomicColors {
        AtomicColors {
            cells: (0..n).map(|_| std::sync::atomic::AtomicI32::new(-1)).collect(),
        }
    }
}

impl ColorStore for AtomicColors {
    #[inline]
    fn n(&self) -> usize {
        self.cells.len()
    }
    #[inline]
    fn read(&self, u: usize, _now: u64) -> i32 {
        self.cells[u].load(AOrd::Relaxed)
    }
    #[inline]
    fn write(&self, u: usize, val: i32, _commit: u64) {
        self.cells[u].store(val, AOrd::Relaxed);
    }
    #[inline]
    fn committed(&self, u: usize) -> i32 {
        self.cells[u].load(AOrd::Relaxed)
    }
    #[inline]
    fn prefetch(&self, u: usize) {
        crate::util::arch::prefetch_slice(&self.cells, u);
    }
    fn fill(&self, val: i32) {
        for c in &self.cells {
            c.store(val, AOrd::Relaxed);
        }
    }
}

/// One parallel-for execution backend.
pub trait Driver {
    type Colors: ColorStore;

    /// Number of (virtual) threads.
    fn threads(&self) -> usize;

    /// Current virtual time (0 for real executions); writes issued
    /// outside a region should commit at this time.
    fn now(&self) -> u64 {
        0
    }

    /// Allocate the color store this driver pairs with.
    fn new_colors(&self, n: usize) -> Self::Colors;

    /// Run `body` over items `0..n_items`, one scratch `TS` per thread.
    /// `chunk == 0` means OpenMP `schedule(static)` (contiguous blocks,
    /// ColPack's plain `parallel for` — the paper's `V-V` baseline);
    /// `chunk >= 1` means `schedule(dynamic, chunk)` via a shared cursor.
    /// A [`Chunk::Auto`] sentinel (see [`Chunk::encode`]) selects a
    /// self-tuning dynamic chunk; every driver decodes it before any
    /// cursor arithmetic.
    fn region<TS, F>(&mut self, states: &mut [TS], n_items: usize, chunk: usize, body: F) -> RegionOut
    where
        TS: Send,
        F: Fn(usize, &mut TS, usize, u64) -> Cost + Sync;
}

/// Real-thread driver: a thin [`Driver`] veneer over a persistent
/// [`WorkerPool`] (the OpenMP `parallel for schedule(dynamic, chunk)`
/// equivalent with a long-lived team — DESIGN.md §10). The old
/// spawn-per-region implementation is gone from the hot path; it
/// survives only as the reference driver in `tests/driver_equivalence`
/// and `benches/scheduler`.
///
/// With `t == 1` every region is an inline loop on the calling thread —
/// this doubles as the sequential driver.
pub struct ThreadsDriver {
    pool: Arc<WorkerPool>,
    team: usize,
}

impl ThreadsDriver {
    /// A driver with its own private `t`-thread pool (spawned here,
    /// once — regions only park/wake it).
    pub fn new(t: usize) -> ThreadsDriver {
        assert!(t >= 1);
        ThreadsDriver { pool: Arc::new(WorkerPool::new(t)), team: t }
    }

    /// Borrow an existing shared pool, using its full team. This is how
    /// the coordinator multiplexes every job onto one machine-wide team.
    pub fn on(pool: &Arc<WorkerPool>) -> ThreadsDriver {
        ThreadsDriver { pool: Arc::clone(pool), team: pool.threads() }
    }

    /// Borrow an existing shared pool with an explicit team size
    /// (clamped to the pool's — a shared pool never oversubscribes).
    pub fn on_team(pool: &Arc<WorkerPool>, team: usize) -> ThreadsDriver {
        let team = team.clamp(1, pool.threads());
        ThreadsDriver { pool: Arc::clone(pool), team }
    }

    /// The pool this driver dispatches onto.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }
}

impl Driver for ThreadsDriver {
    type Colors = AtomicColors;

    fn threads(&self) -> usize {
        self.team
    }

    fn new_colors(&self, n: usize) -> AtomicColors {
        AtomicColors::new(n)
    }

    fn region<TS, F>(&mut self, states: &mut [TS], n_items: usize, chunk: usize, body: F) -> RegionOut
    where
        TS: Send,
        F: Fn(usize, &mut TS, usize, u64) -> Cost + Sync,
    {
        self.pool.region(states, self.team, n_items, chunk, body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize};

    #[test]
    fn threads_driver_visits_every_item_once() {
        for t in [1usize, 2, 4, 8] {
            let mut d = ThreadsDriver::new(t);
            let n = 10_000usize;
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            let mut states = vec![(); t];
            d.region(&mut states, n, 64, |_tid, _ts, item, _now| {
                hits[item].fetch_add(1, AOrd::Relaxed);
                Cost::new(1)
            });
            assert!(hits.iter().all(|h| h.load(AOrd::Relaxed) == 1), "t={t}");
        }
    }

    #[test]
    fn thread_states_are_private() {
        let t = 4;
        let mut d = ThreadsDriver::new(t);
        let mut states = vec![0u64; t];
        d.region(&mut states, 1000, 8, |_tid, ts, _item, _now| {
            *ts += 1;
            Cost::new(1)
        });
        let sum: u64 = states.iter().sum();
        assert_eq!(sum, 1000);
    }

    #[test]
    fn chunk_one_and_huge_chunk_both_cover() {
        let mut d = ThreadsDriver::new(3);
        let n = 100usize;
        let count = AtomicUsize::new(0);
        let mut states = vec![(); 3];
        d.region(&mut states, n, 1, |_, _, _, _| {
            count.fetch_add(1, AOrd::Relaxed);
            Cost::new(1)
        });
        d.region(&mut states, n, 10_000, |_, _, _, _| {
            count.fetch_add(1, AOrd::Relaxed);
            Cost::new(1)
        });
        assert_eq!(count.load(AOrd::Relaxed), 200);
    }

    #[test]
    fn atomic_colors_roundtrip() {
        let c = AtomicColors::new(4);
        assert_eq!(c.read(2, 0), -1);
        c.write(2, 7, 0);
        assert_eq!(c.committed(2), 7);
        c.fill(-1);
        assert_eq!(c.to_vec(), vec![-1; 4]);
    }

    #[test]
    fn zero_items_region_is_fine() {
        let mut d = ThreadsDriver::new(2);
        let mut states = vec![(); 2];
        let out = d.region(&mut states, 0, 64, |_, _, _, _| Cost::new(1));
        assert!(out.real_secs >= 0.0);
    }

    #[test]
    fn real_regions_report_per_thread_busy_units() {
        // The spawn-per-region driver returned an empty vec here; the
        // pool populates it so imbalance diagnostics work off-simulator.
        for t in [1usize, 4] {
            let mut d = ThreadsDriver::new(t);
            let mut states = vec![(); t];
            let out = d.region(&mut states, 1_000, 16, |_, _, _, _| Cost::new(3));
            assert_eq!(out.busy_units.len(), t, "t={t}");
            assert_eq!(out.busy_units.iter().sum::<u64>(), 3_000, "t={t}");
        }
    }

    #[test]
    fn drivers_share_one_pool() {
        let pool = std::sync::Arc::new(WorkerPool::new(4));
        let mut a = ThreadsDriver::on(&pool);
        let mut b = ThreadsDriver::on_team(&pool, 2);
        assert_eq!(a.threads(), 4);
        assert_eq!(b.threads(), 2);
        let count = AtomicU64::new(0);
        let mut states = vec![(); 4];
        a.region(&mut states, 100, 8, |_, _, _, _| {
            count.fetch_add(1, AOrd::Relaxed);
            Cost::new(1)
        });
        b.region(&mut states, 100, 8, |_, _, _, _| {
            count.fetch_add(1, AOrd::Relaxed);
            Cost::new(1)
        });
        assert_eq!(count.load(AOrd::Relaxed), 200);
        assert_eq!(pool.regions_dispatched(), 2, "both drivers dispatch onto one team");
    }
}
