//! OpenMP-equivalent parallel runtime.
//!
//! The paper's kernels are `#pragma omp parallel for schedule(dynamic,
//! chunk)` loops over a work array, with the chunk size itself a studied
//! knob (`V-V` ⇒ chunk 1, `V-V-64*` ⇒ chunk 64). This module provides the
//! same construct three ways behind one [`Driver`] trait:
//!
//! * [`ThreadsDriver`] — real `std::thread` workers with a shared atomic
//!   cursor (lock-free dynamic scheduling). Used for concurrency
//!   correctness on any host.
//! * [`crate::sim::SimDriver`] — deterministic discrete-event virtual
//!   threads with a calibrated cost model; reproduces the paper's
//!   16-thread behaviour on this 1-core testbed (DESIGN.md §4).
//! * `ThreadsDriver` with `t = 1` — the sequential baseline.
//!
//! A region body is `Fn(tid, &mut TS, item, now) -> Cost`: `TS` is the
//! thread-private scratch (forbidden arrays, local queues — the paper's
//! "allocated only once, never reset" state), `now` is the virtual clock
//! (0 under real threads), and the returned [`Cost`] is the work the item
//! actually performed (edges traversed, atomics issued) which only the
//! simulator consumes.

pub mod queue;

use std::sync::atomic::{AtomicUsize, Ordering as AOrd};

pub use queue::SharedQueue;

/// Work performed by one item, reported by region bodies.
#[derive(Clone, Copy, Debug, Default)]
pub struct Cost {
    /// Abstract work units (≈ adjacency entries touched).
    pub units: u64,
    /// Atomic RMW operations issued (shared-queue pushes etc.); the
    /// simulator charges these with a contention factor.
    pub atomics: u32,
}

impl Cost {
    #[inline]
    pub fn new(units: u64) -> Cost {
        Cost { units, atomics: 0 }
    }
}

/// Result of one parallel region.
#[derive(Clone, Debug, Default)]
pub struct RegionOut {
    /// Measured wall-clock seconds (real executions).
    pub real_secs: f64,
    /// Simulated nanoseconds (None for real executions).
    pub sim_ns: Option<f64>,
    /// Per-thread busy work units (simulator only; used for imbalance
    /// diagnostics and the balancing experiments).
    pub busy_units: Vec<u64>,
}

impl RegionOut {
    /// The time this region contributes to the engine's notion of
    /// wall-clock: simulated if available, else measured.
    pub fn seconds(&self) -> f64 {
        match self.sim_ns {
            Some(ns) => ns * 1e-9,
            None => self.real_secs,
        }
    }
}

/// Color storage abstraction: real executions use atomics; the simulator
/// uses a two-version (MVCC) store so optimistic races manifest
/// deterministically (reads at an item's start time do not observe writes
/// committed later — exactly the stale-read behaviour the paper's
/// speculative coloring tolerates).
pub trait ColorStore: Sync {
    fn n(&self) -> usize;
    /// Read as seen by an item that started at virtual time `now`.
    fn read(&self, u: usize, now: u64) -> i32;
    /// Write `val`, committing at virtual time `commit`.
    fn write(&self, u: usize, val: i32, commit: u64);
    /// Read the fully-committed value (between regions / at the end).
    fn committed(&self, u: usize) -> i32;
    /// Snapshot all committed values.
    fn to_vec(&self) -> Vec<i32> {
        (0..self.n()).map(|u| self.committed(u)).collect()
    }
    /// Reset every cell to `val` (between runs).
    fn fill(&self, val: i32);
}

/// Atomic color array for real (threaded/sequential) executions.
pub struct AtomicColors {
    cells: Vec<std::sync::atomic::AtomicI32>,
}

impl AtomicColors {
    pub fn new(n: usize) -> AtomicColors {
        AtomicColors {
            cells: (0..n).map(|_| std::sync::atomic::AtomicI32::new(-1)).collect(),
        }
    }
}

impl ColorStore for AtomicColors {
    #[inline]
    fn n(&self) -> usize {
        self.cells.len()
    }
    #[inline]
    fn read(&self, u: usize, _now: u64) -> i32 {
        self.cells[u].load(AOrd::Relaxed)
    }
    #[inline]
    fn write(&self, u: usize, val: i32, _commit: u64) {
        self.cells[u].store(val, AOrd::Relaxed);
    }
    #[inline]
    fn committed(&self, u: usize) -> i32 {
        self.cells[u].load(AOrd::Relaxed)
    }
    fn fill(&self, val: i32) {
        for c in &self.cells {
            c.store(val, AOrd::Relaxed);
        }
    }
}

/// One parallel-for execution backend.
pub trait Driver {
    type Colors: ColorStore;

    /// Number of (virtual) threads.
    fn threads(&self) -> usize;

    /// Current virtual time (0 for real executions); writes issued
    /// outside a region should commit at this time.
    fn now(&self) -> u64 {
        0
    }

    /// Allocate the color store this driver pairs with.
    fn new_colors(&self, n: usize) -> Self::Colors;

    /// Run `body` over items `0..n_items`, one scratch `TS` per thread.
    /// `chunk == 0` means OpenMP `schedule(static)` (contiguous blocks,
    /// ColPack's plain `parallel for` — the paper's `V-V` baseline);
    /// `chunk >= 1` means `schedule(dynamic, chunk)` via a shared cursor.
    fn region<TS, F>(&mut self, states: &mut [TS], n_items: usize, chunk: usize, body: F) -> RegionOut
    where
        TS: Send,
        F: Fn(usize, &mut TS, usize, u64) -> Cost + Sync;
}

/// Real-thread driver: `std::thread::scope` workers + shared atomic
/// cursor (the OpenMP `schedule(dynamic, chunk)` equivalent). With
/// `t == 1` no thread is spawned — this doubles as the sequential driver.
pub struct ThreadsDriver {
    pub t: usize,
}

impl ThreadsDriver {
    pub fn new(t: usize) -> ThreadsDriver {
        assert!(t >= 1);
        ThreadsDriver { t }
    }
}

impl Driver for ThreadsDriver {
    type Colors = AtomicColors;

    fn threads(&self) -> usize {
        self.t
    }

    fn new_colors(&self, n: usize) -> AtomicColors {
        AtomicColors::new(n)
    }

    fn region<TS, F>(&mut self, states: &mut [TS], n_items: usize, chunk: usize, body: F) -> RegionOut
    where
        TS: Send,
        F: Fn(usize, &mut TS, usize, u64) -> Cost + Sync,
    {
        assert!(states.len() >= self.t, "one scratch state per thread required");
        let t0 = std::time::Instant::now();
        if self.t == 1 {
            let ts = &mut states[0];
            for item in 0..n_items {
                body(0, ts, item, 0);
            }
        } else if chunk == 0 {
            // schedule(static): contiguous blocks
            let t = self.t;
            let body = &body;
            std::thread::scope(|s| {
                for (tid, ts) in states.iter_mut().enumerate().take(t) {
                    s.spawn(move || {
                        let lo = n_items * tid / t;
                        let hi = n_items * (tid + 1) / t;
                        for item in lo..hi {
                            body(tid, ts, item, 0);
                        }
                    });
                }
            });
        } else {
            let cursor = AtomicUsize::new(0);
            let body = &body;
            let cursor = &cursor;
            std::thread::scope(|s| {
                for (tid, ts) in states.iter_mut().enumerate().take(self.t) {
                    s.spawn(move || loop {
                        let start = cursor.fetch_add(chunk, AOrd::Relaxed);
                        if start >= n_items {
                            break;
                        }
                        let end = (start + chunk).min(n_items);
                        for item in start..end {
                            body(tid, ts, item, 0);
                        }
                    });
                }
            });
        }
        RegionOut { real_secs: t0.elapsed().as_secs_f64(), sim_ns: None, busy_units: Vec::new() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn threads_driver_visits_every_item_once() {
        for t in [1usize, 2, 4, 8] {
            let mut d = ThreadsDriver::new(t);
            let n = 10_000usize;
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            let mut states = vec![(); t];
            d.region(&mut states, n, 64, |_tid, _ts, item, _now| {
                hits[item].fetch_add(1, AOrd::Relaxed);
                Cost::new(1)
            });
            assert!(hits.iter().all(|h| h.load(AOrd::Relaxed) == 1), "t={t}");
        }
    }

    #[test]
    fn thread_states_are_private() {
        let t = 4;
        let mut d = ThreadsDriver::new(t);
        let mut states = vec![0u64; t];
        d.region(&mut states, 1000, 8, |_tid, ts, _item, _now| {
            *ts += 1;
            Cost::new(1)
        });
        let sum: u64 = states.iter().sum();
        assert_eq!(sum, 1000);
    }

    #[test]
    fn chunk_one_and_huge_chunk_both_cover() {
        let mut d = ThreadsDriver::new(3);
        let n = 100usize;
        let count = AtomicUsize::new(0);
        let mut states = vec![(); 3];
        d.region(&mut states, n, 1, |_, _, _, _| {
            count.fetch_add(1, AOrd::Relaxed);
            Cost::new(1)
        });
        d.region(&mut states, n, 10_000, |_, _, _, _| {
            count.fetch_add(1, AOrd::Relaxed);
            Cost::new(1)
        });
        assert_eq!(count.load(AOrd::Relaxed), 200);
    }

    #[test]
    fn atomic_colors_roundtrip() {
        let c = AtomicColors::new(4);
        assert_eq!(c.read(2, 0), -1);
        c.write(2, 7, 0);
        assert_eq!(c.committed(2), 7);
        c.fill(-1);
        assert_eq!(c.to_vec(), vec![-1; 4]);
    }

    #[test]
    fn zero_items_region_is_fine() {
        let mut d = ThreadsDriver::new(2);
        let mut states = vec![(); 2];
        let out = d.region(&mut states, 0, 64, |_, _, _, _| Cost::new(1));
        assert!(out.real_secs >= 0.0);
    }
}
