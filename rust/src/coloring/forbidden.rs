//! Thread-private scratch state: the stamped forbidden-color set and the
//! local queues.
//!
//! Paper §III "Implementation details": *"the memories for the forbidden
//! color set F and the local vertex queues W_local are allocated only
//! once and simple arrays are used to realize them. Furthermore, these
//! structures are never actually emptied or reset. For each thread, F is
//! repetitively used for different nets/vertices via different markers
//! without any reset operation."* — [`StampSet`] is exactly that marker
//! array; [`ThreadState`] bundles it with `W_local`, the lazy `-D`
//! next-iteration queue and the B1/B2 per-thread color trackers.

/// Marker-stamped integer set over a dense color domain (no clears).
///
/// Layout note (§Perf): slots are offset by one — color `c` lives at
/// `stamp[c + 1]` — so the hot gather loops can mark *any* value
/// `c >= -1` without first branching on "is it colored" ([`Self::mark`]);
/// the uncolored sentinel `-1` lands in the trash slot 0.
#[derive(Clone, Debug)]
pub struct StampSet {
    stamp: Vec<u32>,
    cur: u32,
}

impl StampSet {
    /// `cap` is the initial color-domain size; the set grows on demand.
    pub fn new(cap: usize) -> StampSet {
        StampSet { stamp: vec![0u32; cap.max(8) + 1], cur: 0 }
    }

    /// Start a new logical set (O(1); the paper's "different markers").
    #[inline]
    pub fn next_gen(&mut self) {
        self.cur = self.cur.wrapping_add(1);
        if self.cur == 0 {
            // u32 wrapped (once every 4B generations): hard reset.
            self.stamp.fill(0);
            self.cur = 1;
        }
    }

    /// Insert color `c` (non-negative), growing on demand.
    #[inline]
    pub fn insert(&mut self, c: i32) {
        debug_assert!(c >= 0);
        let i = c as usize + 1;
        if i >= self.stamp.len() {
            self.stamp.resize((i + 1).next_power_of_two(), 0);
        }
        self.stamp[i] = self.cur;
    }

    /// Branch-free insert for the hot gather loops: accepts any `c >= -1`
    /// (`-1` is parked in the trash slot). Requires the domain to have
    /// been pre-sized via [`StampSet::ensure`].
    #[inline(always)]
    pub fn mark(&mut self, c: i32) {
        let i = (c + 1) as usize;
        debug_assert!(c >= -1 && i < self.stamp.len());
        unsafe { *self.stamp.get_unchecked_mut(i) = self.cur };
    }

    /// Membership test.
    #[inline(always)]
    pub fn contains(&self, c: i32) -> bool {
        if c < 0 {
            return false;
        }
        let i = c as usize + 1;
        i < self.stamp.len() && self.stamp[i] == self.cur
    }

    /// Pre-size the domain for colors up to `max_color` inclusive.
    pub fn ensure(&mut self, max_color: usize) {
        if self.stamp.len() < max_color + 2 {
            self.stamp.resize(max_color + 2, 0);
        }
    }

    /// First-fit: smallest non-negative color not in the set.
    /// Returns (color, scan cost in probes).
    #[inline]
    pub fn first_fit(&self) -> (i32, u64) {
        let mut col = 0i32;
        let mut probes = 1u64;
        while self.contains(col) {
            col += 1;
            probes += 1;
        }
        (col, probes)
    }

    /// Reverse first-fit from `start` downward: largest color `<= start`
    /// not in the set, or `None` if the whole range is forbidden.
    #[inline]
    pub fn reverse_fit(&self, start: i32) -> (Option<i32>, u64) {
        let mut col = start;
        let mut probes = 1u64;
        while col >= 0 && self.contains(col) {
            col -= 1;
            probes += 1;
        }
        (if col >= 0 { Some(col) } else { None }, probes)
    }

    /// First-fit starting at `start` upward.
    #[inline]
    pub fn first_fit_from(&self, start: i32) -> (i32, u64) {
        let mut col = start.max(0);
        let mut probes = 1u64;
        while self.contains(col) {
            col += 1;
            probes += 1;
        }
        (col, probes)
    }
}

/// Per-thread scratch, allocated once per run (never reset between items).
#[derive(Clone, Debug)]
pub struct ThreadState {
    /// Forbidden color set `F`.
    pub forbidden: StampSet,
    /// Net-local recolor queue `W_local` (Alg. 8/9).
    pub wlocal: Vec<u32>,
    /// Lazy private next-iteration queue (the `D` in `V-V-64D`).
    pub next_local: Vec<u32>,
    /// B1/B2: maximum color this thread has used (`col_max`).
    pub col_max: i32,
    /// B2: next color to start the search from (`col_next`).
    pub col_next: i32,
}

impl ThreadState {
    pub fn new(color_cap: usize) -> ThreadState {
        ThreadState {
            forbidden: StampSet::new(color_cap),
            wlocal: Vec::with_capacity(256),
            next_local: Vec::new(),
            col_max: 0,
            col_next: 0,
        }
    }

    /// A fresh bank of `t` states sized for `color_cap` colors.
    pub fn bank(t: usize, color_cap: usize) -> Vec<ThreadState> {
        (0..t).map(|_| ThreadState::new(color_cap)).collect()
    }

    /// Reset the per-run state (balancing trackers, local queues) while
    /// keeping every allocation. A pool-resident bank calls this
    /// between unrelated jobs so reuse is observably identical to a
    /// fresh [`ThreadState::bank`] — the forbidden array needs no touch
    /// at all, its generation stamps already isolate runs.
    pub fn reset_for_run(&mut self) {
        self.wlocal.clear();
        self.next_local.clear();
        self.col_max = 0;
        self.col_next = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generations_isolate_without_reset() {
        let mut f = StampSet::new(4);
        f.next_gen();
        f.insert(2);
        assert!(f.contains(2));
        f.next_gen();
        assert!(!f.contains(2), "previous generation must be invisible");
        f.insert(0);
        assert!(f.contains(0));
        assert!(!f.contains(-1));
    }

    #[test]
    fn grows_on_demand() {
        let mut f = StampSet::new(2);
        f.next_gen();
        f.insert(1000);
        assert!(f.contains(1000));
        assert!(!f.contains(999));
    }

    #[test]
    fn first_fit_skips_forbidden() {
        let mut f = StampSet::new(8);
        f.next_gen();
        f.insert(0);
        f.insert(1);
        f.insert(3);
        let (c, probes) = f.first_fit();
        assert_eq!(c, 2);
        assert_eq!(probes, 3);
    }

    #[test]
    fn reverse_fit_descends_and_detects_exhaustion() {
        let mut f = StampSet::new(8);
        f.next_gen();
        f.insert(3);
        f.insert(2);
        assert_eq!(f.reverse_fit(3).0, Some(1));
        f.insert(1);
        f.insert(0);
        assert_eq!(f.reverse_fit(3).0, None);
        assert_eq!(f.reverse_fit(5).0, Some(5));
    }

    #[test]
    fn first_fit_from_start() {
        let mut f = StampSet::new(8);
        f.next_gen();
        f.insert(4);
        assert_eq!(f.first_fit_from(4).0, 5);
        assert_eq!(f.first_fit_from(2).0, 2);
    }

    #[test]
    fn reset_for_run_clears_state_but_keeps_capacity() {
        let mut s = ThreadState::new(16);
        s.forbidden.next_gen();
        s.forbidden.insert(200); // grows the domain
        s.wlocal.push(1);
        s.next_local.push(2);
        s.col_max = 9;
        s.col_next = 3;
        let cap_before = s.forbidden.stamp.len();
        s.reset_for_run();
        assert!(s.wlocal.is_empty() && s.next_local.is_empty());
        assert_eq!((s.col_max, s.col_next), (0, 0));
        assert_eq!(s.forbidden.stamp.len(), cap_before, "allocations must survive");
        s.forbidden.next_gen();
        assert!(!s.forbidden.contains(200), "old generations stay invisible");
    }

    #[test]
    fn wrapping_generation_resets_cleanly() {
        let mut f = StampSet::new(4);
        f.cur = u32::MAX - 1;
        f.next_gen();
        f.insert(1);
        assert!(f.contains(1));
        f.next_gen(); // wraps to 0 -> hard reset to 1
        assert!(!f.contains(1));
        f.insert(2);
        assert!(f.contains(2));
    }
}
