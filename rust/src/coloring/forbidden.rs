//! Thread-private scratch state: the stamped forbidden-color set and the
//! local queues.
//!
//! Paper §III "Implementation details": *"the memories for the forbidden
//! color set F and the local vertex queues W_local are allocated only
//! once and simple arrays are used to realize them. Furthermore, these
//! structures are never actually emptied or reset. For each thread, F is
//! repetitively used for different nets/vertices via different markers
//! without any reset operation."* — [`StampSet`] is exactly that marker
//! array; [`ThreadState`] bundles it with `W_local`, the lazy `-D`
//! next-iteration queue and the B1/B2 per-thread color trackers.

/// Marker-stamped integer set over a dense color domain (no clears).
///
/// Layout note (DESIGN.md §Perf): slots are offset by one — color `c`
/// lives at slot `c + 1` — so the hot gather loops can mark *any* value
/// `c >= -1` without first branching on "is it colored" ([`Self::mark`]);
/// the uncolored sentinel `-1` lands in the trash slot 0.
///
/// Two tiers share the generation clock:
///
/// * `stamp` — one `u32` marker per slot; `stamp[i] == cur` means slot
///   `i` is in the current set. This is the membership tier
///   ([`Self::contains`]) and the reference for the differential tests.
/// * `words`/`word_gen` — a packed mirror, one bit per slot in `u64`
///   words plus one generation marker per *word*. A word's bits are
///   only meaningful when `word_gen[w] == cur`; otherwise the word
///   reads as empty, so `next_gen` stays O(1) for both tiers. The scan
///   family ([`Self::first_fit`], [`Self::first_fit_from`],
///   [`Self::reverse_fit`]) walks inverted words with
///   `trailing_zeros`/`leading_zeros` instead of probing one color per
///   iteration, and the returned probe cost counts *words touched*.
///
/// Bits at slots `>= domain` are never set (every write path sizes the
/// domain first), so a packed scan that runs past the sized domain finds
/// a free bit exactly where the scalar scan's bounds check would stop —
/// the two tiers return bit-for-bit identical colors
/// (`*_scalar` are kept as the reference implementations).
#[derive(Clone, Debug)]
pub struct StampSet {
    stamp: Vec<u32>,
    words: Vec<u64>,
    word_gen: Vec<u32>,
    cur: u32,
}

#[inline]
fn n_words(slots: usize) -> usize {
    slots.div_ceil(64)
}

impl StampSet {
    /// `cap` is the initial color-domain size; the set grows on demand.
    ///
    /// Generation 0 is reserved as the never-current stamp (a fresh or
    /// grown slot reads as absent), so `cur` starts at 1 and the wrap
    /// hard-reset returns to 1.
    pub fn new(cap: usize) -> StampSet {
        let slots = cap.max(8) + 1;
        StampSet {
            stamp: vec![0u32; slots],
            words: vec![0u64; n_words(slots)],
            word_gen: vec![0u32; n_words(slots)],
            cur: 1,
        }
    }

    /// Start a new logical set (O(1); the paper's "different markers").
    #[inline]
    pub fn next_gen(&mut self) {
        self.cur = self.cur.wrapping_add(1);
        if self.cur == 0 {
            // u32 wrapped (once every 4B generations): hard reset both
            // tiers so stale stamps can never collide with a reused
            // generation value.
            self.stamp.fill(0);
            self.word_gen.fill(0);
            self.cur = 1;
        }
    }

    /// Grow the packed mirror to cover `self.stamp` (new words read
    /// empty: generation 0 is never current).
    #[inline]
    fn grow_words(&mut self) {
        let nw = n_words(self.stamp.len());
        if self.words.len() < nw {
            self.words.resize(nw, 0);
            self.word_gen.resize(nw, 0);
        }
    }

    /// Insert color `c` (non-negative), growing on demand.
    #[inline]
    pub fn insert(&mut self, c: i32) {
        debug_assert!(c >= 0);
        let i = c as usize + 1;
        if i >= self.stamp.len() {
            self.stamp.resize((i + 1).next_power_of_two(), 0);
            self.grow_words();
        }
        self.stamp[i] = self.cur;
        let (w, bit) = (i >> 6, 1u64 << (i & 63));
        self.words[w] = if self.word_gen[w] == self.cur { self.words[w] | bit } else { bit };
        self.word_gen[w] = self.cur;
    }

    /// Branch-free insert for the hot gather loops: accepts any `c >= -1`
    /// (`-1` is parked in the trash slot). Requires the domain to have
    /// been pre-sized via [`StampSet::ensure`].
    #[inline(always)]
    pub fn mark(&mut self, c: i32) {
        let i = (c + 1) as usize;
        debug_assert!(
            c >= -1 && i < self.stamp.len(),
            "StampSet::mark({c}) outside the sized domain ({} slots): hot-loop callers \
             must StampSet::ensure(color_cap) before the marking loop (see the \
             run_capped/repair preludes); release builds would write out of bounds here",
            self.stamp.len()
        );
        // SAFETY: the caller contract above guarantees `i < stamp.len()`,
        // and `words`/`word_gen` always cover `stamp` (every resize of
        // `stamp` calls `grow_words`), so `i >> 6 < words.len()`.
        unsafe {
            *self.stamp.get_unchecked_mut(i) = self.cur;
            let (w, bit) = (i >> 6, 1u64 << (i & 63));
            let gen = self.word_gen.get_unchecked_mut(w);
            let word = self.words.get_unchecked_mut(w);
            *word = if *gen == self.cur { *word | bit } else { bit };
            *gen = self.cur;
        }
    }

    /// Membership test.
    #[inline(always)]
    pub fn contains(&self, c: i32) -> bool {
        if c < 0 {
            return false;
        }
        let i = c as usize + 1;
        i < self.stamp.len() && self.stamp[i] == self.cur
    }

    /// Pre-size the domain for colors up to `max_color` inclusive.
    pub fn ensure(&mut self, max_color: usize) {
        if self.stamp.len() < max_color + 2 {
            self.stamp.resize(max_color + 2, 0);
            self.grow_words();
        }
    }

    /// Current-generation view of packed word `w` (stale words are empty).
    #[inline(always)]
    fn word(&self, w: usize) -> u64 {
        if self.word_gen[w] == self.cur {
            self.words[w]
        } else {
            0
        }
    }

    /// First-fit: smallest non-negative color not in the set.
    /// Returns (color, scan cost in words touched).
    #[inline]
    pub fn first_fit(&self) -> (i32, u64) {
        let nw = self.words.len();
        let mut probes = 0u64;
        for w in 0..nw {
            probes += 1;
            let mut free = !self.word(w);
            if w == 0 {
                free &= !1; // slot 0 is the -1 trash slot, never a color
            }
            if free != 0 {
                let i = (w << 6) + free.trailing_zeros() as usize;
                return ((i - 1) as i32, probes);
            }
        }
        // Every packed slot is stamped; the first free slot is one past
        // the domain — exactly where the scalar bounds check stops.
        (((nw << 6) - 1) as i32, probes.max(1))
    }

    /// Reverse first-fit from `start` downward: largest color `<= start`
    /// not in the set, or `None` if the whole range is forbidden.
    #[inline]
    pub fn reverse_fit(&self, start: i32) -> (Option<i32>, u64) {
        if start < 0 {
            return (None, 1);
        }
        let i0 = (start + 1) as usize;
        let nw = self.words.len();
        if i0 >= nw << 6 {
            return (Some(start), 1); // past the sized domain: trivially free
        }
        let w0 = i0 >> 6;
        let mut probes = 0u64;
        for w in (0..=w0).rev() {
            probes += 1;
            let mut free = !self.word(w);
            if w == w0 && (i0 & 63) != 63 {
                free &= (1u64 << ((i0 & 63) + 1)) - 1; // keep bits <= i0
            }
            if w == 0 {
                free &= !1;
            }
            if free != 0 {
                let i = (w << 6) + (63 - free.leading_zeros() as usize);
                return (Some((i - 1) as i32), probes);
            }
        }
        (None, probes.max(1))
    }

    /// First-fit starting at `start` upward.
    #[inline]
    pub fn first_fit_from(&self, start: i32) -> (i32, u64) {
        let i0 = (start.max(0) + 1) as usize;
        let nw = self.words.len();
        if i0 >= nw << 6 {
            return (i0 as i32 - 1, 1); // past the sized domain: trivially free
        }
        let w0 = i0 >> 6;
        let mut probes = 0u64;
        for w in w0..nw {
            probes += 1;
            let mut free = !self.word(w);
            if w == w0 {
                free &= !0u64 << (i0 & 63); // keep bits >= i0
            }
            if free != 0 {
                let i = (w << 6) + free.trailing_zeros() as usize;
                return ((i - 1) as i32, probes);
            }
        }
        (((nw << 6) - 1) as i32, probes.max(1))
    }

    /// Reference scalar first-fit (one membership probe per color).
    ///
    /// Kept verbatim for the differential property tests and the
    /// packed-vs-scalar microbench; the public [`Self::first_fit`] is
    /// the packed-word scan.
    #[inline]
    pub fn first_fit_scalar(&self) -> (i32, u64) {
        let mut col = 0i32;
        let mut probes = 1u64;
        while self.contains(col) {
            col += 1;
            probes += 1;
        }
        (col, probes)
    }

    /// Reference scalar reverse-fit (see [`Self::first_fit_scalar`]).
    #[inline]
    pub fn reverse_fit_scalar(&self, start: i32) -> (Option<i32>, u64) {
        let mut col = start;
        let mut probes = 1u64;
        while col >= 0 && self.contains(col) {
            col -= 1;
            probes += 1;
        }
        (if col >= 0 { Some(col) } else { None }, probes)
    }

    /// Reference scalar first-fit-from (see [`Self::first_fit_scalar`]).
    #[inline]
    pub fn first_fit_from_scalar(&self, start: i32) -> (i32, u64) {
        let mut col = start.max(0);
        let mut probes = 1u64;
        while self.contains(col) {
            col += 1;
            probes += 1;
        }
        (col, probes)
    }
}

/// Per-thread scratch, allocated once per run (never reset between items).
#[derive(Clone, Debug)]
pub struct ThreadState {
    /// Forbidden color set `F`.
    pub forbidden: StampSet,
    /// Net-local recolor queue `W_local` (Alg. 8/9).
    pub wlocal: Vec<u32>,
    /// Lazy private next-iteration queue (the `D` in `V-V-64D`).
    pub next_local: Vec<u32>,
    /// B1/B2: maximum color this thread has used (`col_max`).
    pub col_max: i32,
    /// B2: next color to start the search from (`col_next`).
    pub col_next: i32,
}

impl ThreadState {
    pub fn new(color_cap: usize) -> ThreadState {
        ThreadState {
            forbidden: StampSet::new(color_cap),
            wlocal: Vec::with_capacity(256),
            next_local: Vec::new(),
            col_max: 0,
            col_next: 0,
        }
    }

    /// A fresh bank of `t` states sized for `color_cap` colors.
    pub fn bank(t: usize, color_cap: usize) -> Vec<ThreadState> {
        (0..t).map(|_| ThreadState::new(color_cap)).collect()
    }

    /// Reset the per-run state (balancing trackers, local queues) while
    /// keeping every allocation. A pool-resident bank calls this
    /// between unrelated jobs so reuse is observably identical to a
    /// fresh [`ThreadState::bank`] — the forbidden array needs no touch
    /// at all, its generation stamps already isolate runs.
    pub fn reset_for_run(&mut self) {
        self.wlocal.clear();
        self.next_local.clear();
        self.col_max = 0;
        self.col_next = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn generations_isolate_without_reset() {
        let mut f = StampSet::new(4);
        f.next_gen();
        f.insert(2);
        assert!(f.contains(2));
        f.next_gen();
        assert!(!f.contains(2), "previous generation must be invisible");
        f.insert(0);
        assert!(f.contains(0));
        assert!(!f.contains(-1));
    }

    #[test]
    fn grows_on_demand() {
        let mut f = StampSet::new(2);
        f.next_gen();
        f.insert(1000);
        assert!(f.contains(1000));
        assert!(!f.contains(999));
    }

    #[test]
    fn first_fit_skips_forbidden() {
        let mut f = StampSet::new(8);
        f.next_gen();
        f.insert(0);
        f.insert(1);
        f.insert(3);
        let (c, probes) = f.first_fit();
        assert_eq!(c, 2);
        assert_eq!(probes, 1, "packed scan resolves a one-word domain in one probe");
        let (c_ref, probes_ref) = f.first_fit_scalar();
        assert_eq!(c_ref, 2);
        assert_eq!(probes_ref, 3, "scalar reference still counts per-color probes");
    }

    #[test]
    fn reverse_fit_descends_and_detects_exhaustion() {
        let mut f = StampSet::new(8);
        f.next_gen();
        f.insert(3);
        f.insert(2);
        assert_eq!(f.reverse_fit(3).0, Some(1));
        f.insert(1);
        f.insert(0);
        assert_eq!(f.reverse_fit(3).0, None);
        assert_eq!(f.reverse_fit(5).0, Some(5));
    }

    #[test]
    fn first_fit_from_start() {
        let mut f = StampSet::new(8);
        f.next_gen();
        f.insert(4);
        assert_eq!(f.first_fit_from(4).0, 5);
        assert_eq!(f.first_fit_from(2).0, 2);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "StampSet::ensure")]
    fn mark_panics_in_debug_when_domain_not_ensured() {
        let mut f = StampSet::new(8); // 9 slots: colors 0..=7
        f.next_gen();
        f.mark(42); // caller forgot ensure(42) — must panic, not scribble
    }

    /// The packed scans must agree with the scalar reference on *colors*
    /// for every mixture of generations, growth and start points
    /// (probes differ by design: words touched vs colors probed).
    fn assert_all_scans_match(f: &StampSet, ctx: &str) {
        assert_eq!(f.first_fit().0, f.first_fit_scalar().0, "first_fit {ctx}");
        for start in [-3, -1, 0, 1, 62, 63, 64, 65, 126, 127, 128, 129, 500, 5000] {
            assert_eq!(
                f.reverse_fit(start).0,
                f.reverse_fit_scalar(start).0,
                "reverse_fit({start}) {ctx}"
            );
            assert_eq!(
                f.first_fit_from(start).0,
                f.first_fit_from_scalar(start).0,
                "first_fit_from({start}) {ctx}"
            );
        }
    }

    #[test]
    fn packed_matches_scalar_randomized() {
        let mut rng = Rng::new(0x9e3779b9);
        for case in 0..200u32 {
            let cap = [4usize, 48, 63, 64, 65, 120, 127, 128, 129, 300][rng.range(0, 10)];
            let mut f = StampSet::new(cap);
            for gen in 0..4 {
                f.next_gen();
                let dense = rng.range(0, 3) == 0;
                let n = if dense { rng.range(cap, 4 * cap + 2) } else { rng.range(0, cap + 1) };
                for _ in 0..n {
                    // occasionally grow far past the initial domain
                    let hi = if rng.range(0, 8) == 0 { 4 * cap + 64 } else { cap };
                    f.insert(rng.range(0, hi + 1) as i32);
                }
                assert_all_scans_match(&f, &format!("case {case} gen {gen}"));
            }
        }
    }

    #[test]
    fn packed_matches_scalar_at_word_boundaries_and_exhaustion() {
        // Saturate domains that end exactly on / just around a word edge,
        // so the fall-through (“every slot stamped”) paths are exercised.
        for cap in [61usize, 62, 63, 64, 65, 126, 127, 128] {
            let mut f = StampSet::new(cap);
            f.next_gen();
            for c in 0..(cap as i32 + 8) {
                f.insert(c);
                assert_all_scans_match(&f, &format!("cap {cap} after insert({c})"));
            }
        }
    }

    #[test]
    fn mark_through_ensure_matches_insert_semantics() {
        let mut a = StampSet::new(4);
        let mut b = StampSet::new(4);
        a.ensure(200);
        b.ensure(200);
        a.next_gen();
        b.next_gen();
        for c in [-1, 0, 63, 64, 127, 199, 5, -1] {
            a.mark(c);
            if c >= 0 {
                b.insert(c);
            }
            assert_eq!(a.first_fit().0, b.first_fit().0);
            assert_all_scans_match(&a, &format!("mark({c})"));
        }
    }

    #[test]
    fn reset_for_run_clears_state_but_keeps_capacity() {
        let mut s = ThreadState::new(16);
        s.forbidden.next_gen();
        s.forbidden.insert(200); // grows the domain
        s.wlocal.push(1);
        s.next_local.push(2);
        s.col_max = 9;
        s.col_next = 3;
        let cap_before = s.forbidden.stamp.len();
        s.reset_for_run();
        assert!(s.wlocal.is_empty() && s.next_local.is_empty());
        assert_eq!((s.col_max, s.col_next), (0, 0));
        assert_eq!(s.forbidden.stamp.len(), cap_before, "allocations must survive");
        s.forbidden.next_gen();
        assert!(!s.forbidden.contains(200), "old generations stay invisible");
    }

    #[test]
    fn wrapping_generation_resets_cleanly() {
        let mut f = StampSet::new(4);
        f.cur = u32::MAX - 1;
        f.next_gen();
        f.insert(1);
        assert!(f.contains(1));
        assert_all_scans_match(&f, "pre-wrap");
        f.next_gen(); // wraps to 0 -> hard reset to 1
        assert!(!f.contains(1));
        assert_all_scans_match(&f, "post-wrap empty");
        f.insert(2);
        assert!(f.contains(2));
        assert_all_scans_match(&f, "post-wrap reinsert");
    }
}
