//! The paper's eight algorithm schedules (§VI).
//!
//! An algorithm `X-Y` uses `X`-based coloring and `Y`-based conflict
//! removal; a number `n` after `N` means the net-based phase runs for the
//! first `n` iterations before switching to the vertex-based (`64D`)
//! variant. The chunk size and the lazy-queue (`D`) option are part of
//! the schedule, exactly as in the paper's list:
//!
//! | name     | coloring      | conflict removal | chunk | lazy queues |
//! |----------|---------------|------------------|-------|-------------|
//! | V-V      | vertex        | vertex           | static| no          |
//! | V-V-64   | vertex        | vertex           | 64    | no          |
//! | V-V-64D  | vertex        | vertex           | 64    | yes         |
//! | V-N∞     | vertex        | net (always)     | 64    | yes         |
//! | V-N1     | vertex        | net (iter 1)     | 64    | yes         |
//! | V-N2     | vertex        | net (iters 1–2)  | 64    | yes         |
//! | N1-N2    | net (iter 1)  | net (iters 1–2)  | 64    | yes         |
//! | N2-N2    | net (iters 1–2)| net (iters 1–2) | 64    | yes         |

/// Which net-based *coloring* algorithm a net iteration runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetColorAlg {
    /// Algorithm 6 — most optimistic, first-fit inline recolor.
    V1,
    /// Algorithm 6 with the reverse policy (Table I's middle column).
    V1Reverse,
    /// Algorithm 8 — two-pass with reverse first-fit (the contribution).
    TwoPass,
}

/// A hybrid schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AlgSpec {
    pub name: &'static str,
    /// Net-based coloring for the first `net_color_iters` iterations.
    pub net_color_iters: usize,
    /// Net-based conflict removal for the first `net_conflict_iters`
    /// iterations (`usize::MAX` = always, the `∞` variants).
    pub net_conflict_iters: usize,
    /// Dynamic-scheduling chunk size.
    pub chunk: usize,
    /// Lazy per-thread next-queues (the `D` option).
    pub lazy_queues: bool,
    /// Which net coloring algorithm net iterations use.
    pub net_alg: NetColorAlg,
}

impl AlgSpec {
    const fn new(
        name: &'static str,
        net_color_iters: usize,
        net_conflict_iters: usize,
        chunk: usize,
        lazy_queues: bool,
    ) -> AlgSpec {
        AlgSpec {
            name,
            net_color_iters,
            net_conflict_iters,
            chunk,
            lazy_queues,
            net_alg: NetColorAlg::TwoPass,
        }
    }

    pub fn with_net_alg(mut self, a: NetColorAlg) -> AlgSpec {
        self.net_alg = a;
        self
    }

    /// Look up by the paper's name (`"N1-N2"`, `"V-V-64D"`, ...), plus
    /// the repo's `"V-V-AUTO"` extension.
    pub fn by_name(name: &str) -> Option<AlgSpec> {
        let needle = name.to_ascii_uppercase().replace("INF", "∞");
        if V_V_AUTO.name.eq_ignore_ascii_case(&needle) {
            return Some(V_V_AUTO);
        }
        ALL.iter().find(|s| s.name.eq_ignore_ascii_case(&needle)).copied()
    }
}

/// `V-V`: ColPack's parallel BGPC baseline — a plain `omp parallel for`
/// (static scheduling, `chunk == 0` here) with the shared next-queue.
pub const V_V: AlgSpec = AlgSpec::new("V-V", 0, 0, 0, false);
/// `V-V-64`: chunk 64.
pub const V_V_64: AlgSpec = AlgSpec::new("V-V-64", 0, 0, 64, false);
/// `V-V-64D`: chunk 64 + lazy private next-queues.
pub const V_V_64D: AlgSpec = AlgSpec::new("V-V-64D", 0, 0, 64, true);
/// `V-N∞`: net-based conflict removal every iteration.
pub const V_NINF: AlgSpec = AlgSpec::new("V-N∞", 0, usize::MAX, 64, true);
/// `V-N1`: net-based conflict removal in the first iteration only.
pub const V_N1: AlgSpec = AlgSpec::new("V-N1", 0, 1, 64, true);
/// `V-N2`: net-based conflict removal in the first two iterations.
pub const V_N2: AlgSpec = AlgSpec::new("V-N2", 0, 2, 64, true);
/// `N1-N2`: net coloring iter 1, net conflict removal iters 1–2
/// (the paper's headline algorithm).
pub const N1_N2: AlgSpec = AlgSpec::new("N1-N2", 1, 2, 64, true);
/// `N2-N2`: net coloring and conflict removal in the first two iterations.
pub const N2_N2: AlgSpec = AlgSpec::new("N2-N2", 2, 2, 64, true);
/// `V-V-AUTO`: vertex phases with the self-tuning dynamic chunk
/// ([`crate::par::Chunk::Auto`]); the engines re-aim the generic site
/// per phase. Not one of the paper's eight schedules — the repo's
/// architecture-aware extension (DESIGN.md §Perf) — so it is not part
/// of [`ALL`] and the paper tables never run it implicitly.
pub const V_V_AUTO: AlgSpec = AlgSpec::new(
    "V-V-AUTO",
    0,
    0,
    crate::par::Chunk::Auto(crate::par::autosite::GENERIC).encode(),
    true,
);

/// All eight schedules, in the paper's table order.
pub const ALL: [AlgSpec; 8] =
    [V_V, V_V_64, V_V_64D, V_NINF, V_N1, V_N2, N1_N2, N2_N2];

/// The four schedules of the D2GC experiment (Table V).
pub const D2GC_SET: [AlgSpec; 4] = [V_V_64D, V_N1, V_N2, N1_N2];

/// Back-compat alias used by the public API surface.
pub type Schedule = AlgSpec;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(AlgSpec::by_name("n1-n2"), Some(N1_N2));
        assert_eq!(AlgSpec::by_name("V-NINF"), Some(V_NINF));
        assert_eq!(AlgSpec::by_name("V-N∞"), Some(V_NINF));
        assert!(AlgSpec::by_name("X-Y").is_none());
    }

    #[test]
    fn paper_invariant_net_color_implies_net_conflict() {
        for s in ALL {
            assert!(
                s.net_conflict_iters >= s.net_color_iters,
                "{}: net coloring must be paired with net conflict removal",
                s.name
            );
        }
    }

    #[test]
    fn chunk_and_lazy_flags() {
        assert_eq!(V_V.chunk, 0, "V-V is schedule(static)");
        assert!(!V_V.lazy_queues);
        assert_eq!(V_V_64.chunk, 64);
        assert!(!V_V_64.lazy_queues);
        assert!(V_V_64D.lazy_queues);
        assert!(ALL.iter().skip(3).all(|s| s.lazy_queues));
    }

    #[test]
    fn auto_schedule_is_an_extension_not_a_paper_row() {
        use crate::par::{autosite, Chunk};
        assert!(matches!(Chunk::decode(V_V_AUTO.chunk), Chunk::Auto(s) if s == autosite::GENERIC));
        assert!(V_V_AUTO.lazy_queues);
        assert!(!ALL.iter().any(|s| s.name == V_V_AUTO.name), "paper tables must not run it");
        assert_eq!(AlgSpec::by_name("v-v-auto"), Some(V_V_AUTO));
    }
}
