//! The strategy seam: visit orderings × post-coloring improvement.
//!
//! The paper's speculate → detect loop is ordering- and post-pass-
//! agnostic: Çatalyürek et al. (PAPERS.md, 1205.3809) show ordering
//! choice (LDF / smallest-last) materially shifts the colors-vs-time
//! Pareto, and Rokos et al. (PAPERS.md, 1505.04086) show an iterative
//! detect-and-recolor improvement pass is nearly free on top of
//! speculation. A [`Strategy`] bundles both knobs; the engines consume
//! the ordering as their initial work queue and [`color_and_fix`] runs
//! the improvement pass over any [`Problem`] (DESIGN.md §14).
//!
//! The fix pass recolors the *highest color class* each round. That
//! class is an independent set at the problem's distance (it shared a
//! color in a valid coloring), so uncoloring and first-fit-recoloring
//! its members in parallel cannot create conflicts: no member reads
//! another member through its neighborhood, every neighbor keeps its
//! color, and `cmax` itself never appears in a member's forbidden set —
//! each member lands at a color ≤ its old one. Color count is therefore
//! monotone non-increasing round over round; a defensive revert keeps
//! the previous coloring whenever a round fails to improve, and stops.

use crate::coloring::balance::Balance;
use crate::coloring::forbidden::ThreadState;
use crate::coloring::stats::distinct_colors;
use crate::dynamic::Problem;
use crate::graph::Ordering;
use crate::par::{ColorStore, Driver};

/// Post-coloring improvement pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PostPass {
    /// Keep the engine's coloring as-is.
    None,
    /// Up to this many reduce-and-repair rounds of [`color_and_fix`].
    ColorAndFix(usize),
}

/// Rounds used by the `+fix` shorthand (each round retires at most one
/// color class; diminishing returns set in quickly).
pub const DEFAULT_FIX_ROUNDS: usize = 4;

/// A complete strategy: visit ordering + post pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Strategy {
    pub ordering: Ordering,
    pub post_pass: PostPass,
}

impl Strategy {
    /// The engines' default: natural order, no post pass.
    pub fn natural() -> Strategy {
        Strategy { ordering: Ordering::Natural, post_pass: PostPass::None }
    }

    /// Parse CLI text: an ordering name (`natural`, `random`, `ldf` /
    /// `lf` / `largest-first`, `sl` / `smallest-last`) with an optional
    /// `+fix` or `+fixN` suffix, e.g. `ldf+fix`, `sl+fix8`, `natural`.
    pub fn parse(s: &str) -> Option<Strategy> {
        let lower = s.to_ascii_lowercase();
        let (ord_s, fix_s) = match lower.split_once('+') {
            Some((o, f)) => (o, Some(f)),
            None => (lower.as_str(), None),
        };
        let ordering = match ord_s {
            // `ldf` (largest-degree-first) is the literature's name for
            // what `Ordering` calls largest-first
            "ldf" => Ordering::LargestFirst,
            other => Ordering::parse(other)?,
        };
        let post_pass = match fix_s {
            None => PostPass::None,
            Some("fix") => PostPass::ColorAndFix(DEFAULT_FIX_ROUNDS),
            Some(f) => {
                let rounds: usize = f.strip_prefix("fix")?.parse().ok()?;
                if rounds == 0 {
                    return None;
                }
                PostPass::ColorAndFix(rounds)
            }
        };
        Some(Strategy { ordering, post_pass })
    }

    /// Stable display label (bench CSVs, `serve` job names).
    pub fn label(&self) -> String {
        let ord = match self.ordering {
            Ordering::Natural => "natural".to_string(),
            Ordering::Random(seed) => format!("random{seed:x}"),
            Ordering::LargestFirst => "ldf".to_string(),
            Ordering::SmallestLast => "sl".to_string(),
        };
        match self.post_pass {
            PostPass::None => ord,
            PostPass::ColorAndFix(r) => format!("{ord}+fix{r}"),
        }
    }
}

/// Iterative reduce-and-repair: up to `rounds` rounds, each uncoloring
/// the highest color class and first-fit-recoloring it through the
/// problem's own speculate phase (see the module docs for why this is
/// conflict-free and monotone). Returns the improved coloring and the
/// pass's seconds (simulated under a sim driver, wall-clock otherwise).
///
/// Balancing is forced to first-fit inside the pass: B1/B2 deliberately
/// spread mass *upward*, which fights the reduction.
pub fn color_and_fix<P: Problem, D: Driver>(
    g: &P,
    base: Vec<i32>,
    rounds: usize,
    chunk: usize,
    d: &mut D,
    ts: &mut [ThreadState],
) -> (Vec<i32>, f64) {
    let mut colors = base;
    let mut best = distinct_colors(&colors);
    let mut secs = 0.0f64;
    let cap = g.color_cap();
    for s in ts.iter_mut() {
        s.forbidden.ensure(cap);
    }
    for _ in 0..rounds {
        let cmax = colors.iter().copied().max().unwrap_or(-1);
        if cmax <= 0 {
            break; // one color (or nothing colored): nothing to reduce
        }
        let w: Vec<u32> = colors
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c == cmax)
            .map(|(u, _)| u as u32)
            .collect();
        // seed a fresh store with everything but the class
        let store = d.new_colors(colors.len());
        for (u, &c) in colors.iter().enumerate() {
            if c >= 0 && c != cmax {
                store.write(u, c, 0);
            }
        }
        let r = {
            let _sp = crate::obs::trace::span_n("strategy.fix", w.len() as u64);
            g.color_phase(&w, &store, d, ts, chunk, Balance::None)
        };
        secs += r.seconds();
        let cand = store.to_vec();
        let n2 = distinct_colors(&cand);
        if n2 < best {
            colors = cand;
            best = n2;
        } else {
            break; // no improvement: keep the previous coloring, stop
        }
    }
    (colors, secs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::bgpc;
    use crate::coloring::schedule;
    use crate::graph::generators::{random_bipartite, random_symmetric};
    use crate::par::ThreadsDriver;

    #[test]
    fn parse_accepts_the_full_grammar() {
        assert_eq!(
            Strategy::parse("natural"),
            Some(Strategy { ordering: Ordering::Natural, post_pass: PostPass::None })
        );
        assert_eq!(
            Strategy::parse("ldf+fix"),
            Some(Strategy {
                ordering: Ordering::LargestFirst,
                post_pass: PostPass::ColorAndFix(DEFAULT_FIX_ROUNDS),
            })
        );
        assert_eq!(
            Strategy::parse("SL+FIX8"),
            Some(Strategy {
                ordering: Ordering::SmallestLast,
                post_pass: PostPass::ColorAndFix(8),
            })
        );
        assert_eq!(
            Strategy::parse("lf"),
            Some(Strategy { ordering: Ordering::LargestFirst, post_pass: PostPass::None })
        );
        assert!(Strategy::parse("random+fix").is_some());
        assert!(Strategy::parse("ldf+fix0").is_none(), "zero rounds is a typo");
        assert!(Strategy::parse("ldf+repair").is_none());
        assert!(Strategy::parse("junk").is_none());
    }

    #[test]
    fn labels_roundtrip_through_parse() {
        for s in ["natural", "ldf+fix4", "sl", "sl+fix8"] {
            let st = Strategy::parse(s).unwrap();
            assert_eq!(Strategy::parse(&st.label()), Some(st), "{s}");
        }
    }

    #[test]
    fn fix_is_valid_and_monotone_bgpc() {
        let g = random_bipartite(80, 120, 900, 17);
        let order: Vec<u32> = (0..120u32).collect();
        let mut d = ThreadsDriver::new(4);
        let mut ts = ThreadState::bank(4, bgpc::color_cap(&g));
        let r = bgpc::run(&g, &order, &schedule::V_V_64D, Balance::None, &mut d);
        let before = distinct_colors(&r.colors);
        let (fixed, _) = color_and_fix(&g, r.colors, 8, 64, &mut d, &mut ts);
        assert!(crate::coloring::verify::bgpc_valid(&g, &fixed).is_ok());
        assert!(distinct_colors(&fixed) <= before, "fix must never add colors");
    }

    #[test]
    fn fix_is_valid_and_monotone_d2gc() {
        let g = random_symmetric(120, 500, 23);
        let order: Vec<u32> = (0..120u32).collect();
        let mut d = ThreadsDriver::new(4);
        let mut ts = ThreadState::bank(4, crate::coloring::d2gc::color_cap(&g));
        let r = crate::coloring::d2gc::run(&g, &order, &schedule::V_V_64D, Balance::None, &mut d);
        let before = distinct_colors(&r.colors);
        let (fixed, _) = color_and_fix(&g, r.colors, 8, 64, &mut d, &mut ts);
        assert!(crate::coloring::verify::d2gc_valid(&g, &fixed).is_ok());
        assert!(distinct_colors(&fixed) <= before);
    }

    #[test]
    fn fix_reduces_a_planted_wasteful_class() {
        // a 4-vertex independent set (no shared net) colored 0,0,0,9:
        // one round must retire color 9 without touching anyone else
        let m = crate::graph::Csr::from_edges(2, 4, &[(0, 0), (0, 1), (1, 2), (1, 3)]);
        let g = crate::graph::Bipartite::from_net_incidence(m);
        let base = vec![0, 1, 0, 9];
        let mut d = ThreadsDriver::new(2);
        let mut ts = ThreadState::bank(2, 16);
        let (fixed, _) = color_and_fix(&g, base, 4, 64, &mut d, &mut ts);
        assert!(crate::coloring::verify::bgpc_valid(&g, &fixed).is_ok());
        assert_eq!(distinct_colors(&fixed), 2, "color 9 retired: {fixed:?}");
    }
}
