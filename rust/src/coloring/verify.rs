//! Coloring validity checkers — the ground truth every test and bench
//! asserts against.

use crate::coloring::forbidden::StampSet;
use crate::graph::{Bipartite, Csr};

/// A detected violation, for diagnostics.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    pub kind: &'static str,
    pub a: usize,
    pub b: usize,
    pub color: i32,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: vertices {} and {} share color {}", self.kind, self.a, self.b, self.color)
    }
}

/// BGPC validity: within every net, colored vertices are pairwise
/// distinct, and every vertex is colored. Net-based check — `O(|E|)`.
pub fn bgpc_valid(g: &Bipartite, colors: &[i32]) -> Result<(), Violation> {
    assert_eq!(colors.len(), g.n_vertices());
    for (u, &c) in colors.iter().enumerate() {
        if c < 0 {
            return Err(Violation { kind: "uncolored", a: u, b: u, color: c });
        }
    }
    let mut seen = StampSet::new(1024);
    let mut owner: Vec<u32> = vec![0; 1024];
    for v in 0..g.n_nets() {
        seen.next_gen();
        for &u in g.vtxs(v) {
            let u = u as usize;
            let c = colors[u];
            if seen.contains(c) {
                return Err(Violation {
                    kind: "bgpc-conflict",
                    a: owner[c as usize] as usize,
                    b: u,
                    color: c,
                });
            }
            seen.insert(c);
            if c as usize >= owner.len() {
                owner.resize((c as usize + 1).next_power_of_two(), 0);
            }
            owner[c as usize] = u as u32;
        }
    }
    Ok(())
}

/// D2GC validity: for every vertex `m`, the colors of `{m} ∪ nbor(m)` are
/// pairwise distinct (covers both distance-1 and distance-2 clashes).
pub fn d2gc_valid(g: &Csr, colors: &[i32]) -> Result<(), Violation> {
    assert_eq!(colors.len(), g.n_rows);
    for (u, &c) in colors.iter().enumerate() {
        if c < 0 {
            return Err(Violation { kind: "uncolored", a: u, b: u, color: c });
        }
    }
    let mut seen = StampSet::new(1024);
    let mut owner: Vec<u32> = vec![0; 1024];
    for m in 0..g.n_rows {
        seen.next_gen();
        let note = |u: usize, seen: &mut StampSet, owner: &mut Vec<u32>| -> Option<Violation> {
            let c = colors[u];
            if seen.contains(c) {
                return Some(Violation {
                    kind: "d2gc-conflict",
                    a: owner[c as usize] as usize,
                    b: u,
                    color: c,
                });
            }
            seen.insert(c);
            if c as usize >= owner.len() {
                owner.resize((c as usize + 1).next_power_of_two(), 0);
            }
            owner[c as usize] = u as u32;
            None
        };
        if let Some(v) = note(m, &mut seen, &mut owner) {
            return Err(v);
        }
        for &u in g.row(m) {
            let u = u as usize;
            if u == m {
                continue; // self-loop (diagonal entry)
            }
            if let Some(v) = note(u, &mut seen, &mut owner) {
                return Err(v);
            }
        }
    }
    Ok(())
}

/// D1GC validity: adjacent vertices differ.
pub fn d1gc_valid(g: &Csr, colors: &[i32]) -> Result<(), Violation> {
    assert_eq!(colors.len(), g.n_rows);
    for (u, &c) in colors.iter().enumerate() {
        if c < 0 {
            return Err(Violation { kind: "uncolored", a: u, b: u, color: c });
        }
        for &v in g.row(u) {
            let v = v as usize;
            if v != u && colors[v] == c {
                return Err(Violation { kind: "d1gc-conflict", a: u, b: v, color: c });
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;

    fn tiny_bgpc() -> Bipartite {
        // net 0: {0,1}, net 1: {1,2}
        Bipartite::from_net_incidence(Csr::from_edges(2, 3, &[(0, 0), (0, 1), (1, 1), (1, 2)]))
    }

    #[test]
    fn bgpc_accepts_valid_rejects_conflict_and_uncolored() {
        let g = tiny_bgpc();
        assert!(bgpc_valid(&g, &[0, 1, 0]).is_ok());
        let e = bgpc_valid(&g, &[0, 0, 1]).unwrap_err();
        assert_eq!(e.kind, "bgpc-conflict");
        assert_eq!((e.a, e.b), (0, 1));
        assert_eq!(bgpc_valid(&g, &[0, -1, 1]).unwrap_err().kind, "uncolored");
    }

    #[test]
    fn d2gc_catches_distance_two() {
        // path 0-1-2: c(0) == c(2) is a distance-2 violation
        let g = Csr::from_edges(3, 3, &[(0, 1), (1, 0), (1, 2), (2, 1)]);
        assert!(d2gc_valid(&g, &[0, 1, 2]).is_ok());
        let e = d2gc_valid(&g, &[0, 1, 0]).unwrap_err();
        assert_eq!(e.kind, "d2gc-conflict");
        // distance-1 violation also caught
        assert!(d2gc_valid(&g, &[0, 0, 1]).is_err());
    }

    #[test]
    fn d1gc_allows_distance_two_reuse() {
        let g = Csr::from_edges(3, 3, &[(0, 1), (1, 0), (1, 2), (2, 1)]);
        assert!(d1gc_valid(&g, &[0, 1, 0]).is_ok());
        assert!(d1gc_valid(&g, &[0, 0, 1]).is_err());
    }

    #[test]
    fn self_loops_do_not_false_positive() {
        let g = Csr::from_edges(2, 2, &[(0, 0), (0, 1), (1, 0), (1, 1)]);
        assert!(d2gc_valid(&g, &[0, 1]).is_ok());
        assert!(d1gc_valid(&g, &[0, 1]).is_ok());
    }
}
