//! Distance-2 graph coloring (D2GC) — Algorithms 9 and 10, plus the
//! vertex-based variants and the same hybrid schedules as BGPC.
//!
//! The input is a square (typically structurally symmetric) graph; the
//! paper runs D2GC on five of its eight matrices (Table V). The phases
//! mirror the BGPC ones with one addition: distance-1 neighbors count,
//! so every item first processes the *visited vertex itself* (Alg. 9
//! lines 4–7, Alg. 10 lines 3–4). Self-loops (diagonal entries) are
//! skipped explicitly.
//!
//! Like BGPC, the engine comes in two entry points: [`run`] (one-shot,
//! private thread state) and [`run_capped`] (caller-owned
//! [`ThreadState`] bank plus an iteration cap) — the latter is what the
//! [`crate::dynamic`] subsystem threads a persistent bank through so
//! B1/B2 balancing trackers survive a stream of update batches. The
//! dirty-frontier detection half of that subsystem lives here too:
//! [`conflict_phase_on`] is Algorithm 10 restricted to an explicit row
//! subset (DESIGN.md §9).

pub mod vertex;

use crate::coloring::balance::Balance;
use crate::coloring::bgpc::MAX_ITERS;
use crate::coloring::forbidden::ThreadState;
use crate::coloring::schedule::AlgSpec;
use crate::coloring::ColoringResult;
use crate::graph::Csr;
use crate::par::{ColorStore, Cost, Driver, RegionOut, SharedQueue};
use crate::sim::trace::{IterTrace, RunTrace};

/// Algorithm 9: net-style D2GC coloring (two-pass, reverse first-fit
/// starting at `|nbor(v)|`).
pub fn net_color_phase<D: Driver>(
    g: &Csr,
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
) -> RegionOut {
    d.region(ts, g.n_rows, chunk, |_tid, s, v, now| {
        let mut units = 1u64;
        s.forbidden.next_gen();
        s.wlocal.clear();
        // the visited vertex itself (distance-1 requirement)
        let cv = colors.read(v, now);
        if cv >= 0 {
            s.forbidden.insert(cv);
        } else {
            s.wlocal.push(v as u32);
        }
        for &u in g.row(v) {
            let u = u as usize;
            if u == v {
                continue;
            }
            units += 1;
            let c = colors.read(u, now + units);
            if c >= 0 && !s.forbidden.contains(c) {
                s.forbidden.insert(c);
            } else {
                s.wlocal.push(u as u32);
            }
        }
        // reverse first-fit from |nbor(v)| (one more than BGPC: the
        // visited vertex itself also needs a color)
        let mut col = g.deg(v) as i32;
        let wlocal = std::mem::take(&mut s.wlocal);
        for &u in &wlocal {
            let (found, p) = s.forbidden.reverse_fit(col);
            units += p;
            let c = match found {
                Some(c) => c,
                None => {
                    let (c, p2) = s.forbidden.first_fit_from(g.deg(v) as i32 + 1);
                    units += p2;
                    c
                }
            };
            s.forbidden.insert(c);
            colors.write(u as usize, c, now + units);
            col = c - 1;
        }
        s.wlocal = wlocal;
        Cost::new(units)
    })
}

/// Algorithm 10: net-style D2GC conflict removal (the visited vertex's
/// color is processed first and always kept).
pub fn net_conflict_phase<D: Driver>(
    g: &Csr,
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
) -> RegionOut {
    d.region(ts, g.n_rows, chunk, |_tid, s, v, now| {
        conflict_one_row(g, v, colors, s, now)
    })
}

/// Algorithm 10 restricted to an explicit row subset — the dynamic
/// subsystem's dirty-frontier detection. After a batch of symmetric
/// edge insertions, every new distance-≤2 clash runs through a new edge
/// `(a, b)`, and both endpoints are insertion-dirty rows; scanning just
/// `{v} ∪ nbor(v)` for each dirty row `v` therefore uncolors every
/// clash loser at the cost of the batch's neighborhood footprint, not
/// `O(|E|)` (DESIGN.md §9).
pub fn conflict_phase_on<D: Driver>(
    g: &Csr,
    rows: &[u32],
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
) -> RegionOut {
    d.region(ts, rows.len(), chunk, |_tid, s, i, now| {
        conflict_one_row(g, rows[i] as usize, colors, s, now)
    })
}

/// Shared body of the two conflict-removal drivers: the visited
/// vertex's color is processed first and always kept; duplicates among
/// its neighbors are uncolored.
#[inline]
fn conflict_one_row<C: ColorStore>(
    g: &Csr,
    v: usize,
    colors: &C,
    s: &mut ThreadState,
    now: u64,
) -> Cost {
    let mut units = 1u64;
    s.forbidden.next_gen();
    let cv = colors.read(v, now);
    if cv >= 0 {
        s.forbidden.insert(cv);
    }
    for &u in g.row(v) {
        let u = u as usize;
        if u == v {
            continue;
        }
        units += 1;
        let c = colors.read(u, now + units);
        if c >= 0 {
            if s.forbidden.contains(c) {
                colors.write(u, -1, now + units);
            } else {
                s.forbidden.insert(c);
            }
        }
    }
    Cost::new(units)
}

/// Gather uncolored vertices after a net-style removal.
pub fn rebuild_queue<D: Driver>(
    g: &Csr,
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
    lazy: bool,
    shared: &SharedQueue,
) -> RegionOut {
    d.region(ts, g.n_rows, chunk, |_tid, s, u, now| {
        let mut atomics = 0u32;
        if colors.read(u, now) == -1 {
            if lazy {
                s.next_local.push(u as u32);
            } else {
                shared.push(u as u32);
                atomics = 1;
            }
        }
        Cost { units: 1, atomics }
    })
}

fn collect_next(lazy: bool, ts: &mut [ThreadState], shared: &SharedQueue) -> Vec<u32> {
    if lazy {
        let mut w = Vec::new();
        for s in ts.iter_mut() {
            w.append(&mut s.next_local);
        }
        w
    } else {
        shared.drain()
    }
}

/// Upper bound on any color the D2GC engine can produce, for sizing
/// forbidden arrays: first-fit never exceeds the closed distance-2
/// degree, and the net-style reverse fit starts at `|nbor(v)|`. Public
/// because the dynamic subsystem sizes persistent [`ThreadState`] banks
/// with it.
pub fn color_cap(g: &Csr) -> usize {
    let max2: usize = (0..g.n_rows)
        .map(|v| g.row(v).iter().map(|&u| g.deg(u as usize)).sum())
        .max()
        .unwrap_or(0);
    max2 + 4
}

/// The `MAX_ITERS` safety net: exact sequential greedy over the
/// remaining queue at distance 2, reading and writing through the color
/// store at time `now`. Also the last line of defense of the
/// incremental repair loop, and (with the whole queue) the `cap = 0`
/// baseline that must reproduce [`seq_greedy`].
pub fn sequential_finish<C: ColorStore>(
    g: &Csr,
    w: &[u32],
    colors: &C,
    ts0: &mut ThreadState,
    now: u64,
) {
    for &wv in w {
        let wv = wv as usize;
        ts0.forbidden.next_gen();
        for &u in g.row(wv) {
            let u = u as usize;
            if u == wv {
                continue;
            }
            let c = colors.read(u, now);
            if c >= 0 {
                ts0.forbidden.insert(c);
            }
            for &x in g.row(u) {
                let x = x as usize;
                if x != wv {
                    let c = colors.read(x, now);
                    if c >= 0 {
                        ts0.forbidden.insert(c);
                    }
                }
            }
        }
        let (c, _) = ts0.forbidden.first_fit();
        colors.write(wv, c, now);
    }
}

/// Run a full D2GC coloring with driver `d` (same loop as BGPC).
pub fn run<D: Driver>(
    g: &Csr,
    order: &[u32],
    spec: &AlgSpec,
    bal: Balance,
    d: &mut D,
) -> ColoringResult {
    let mut ts = ThreadState::bank(d.threads(), color_cap(g));
    run_capped(g, order, spec, bal, d, &mut ts, MAX_ITERS)
}

/// [`run`] with an explicit iteration cap and a caller-owned
/// [`ThreadState`] bank — the D2GC mirror of
/// [`crate::coloring::bgpc::run_capped`]. The bank is how per-thread
/// state (B1/B2 `col_max`/`col_next` trackers, forbidden arrays)
/// persists across calls; the forbidden domains are re-`ensure`d here,
/// so a bank sized for a previous (smaller) graph stays safe.
pub fn run_capped<D: Driver>(
    g: &Csr,
    order: &[u32],
    spec: &AlgSpec,
    bal: Balance,
    d: &mut D,
    ts: &mut [ThreadState],
    max_iters: usize,
) -> ColoringResult {
    let n = g.n_rows;
    let t0 = std::time::Instant::now();
    let colors = d.new_colors(n);
    let cap = color_cap(g);
    for s in ts.iter_mut() {
        s.forbidden.ensure(cap);
    }
    let shared = SharedQueue::with_capacity(n);
    // Auto chunks tune per phase (see bgpc::run_capped); fixed/static
    // specs pass through untouched.
    let color_chunk = crate::par::Chunk::resite(spec.chunk, crate::par::autosite::SPECULATE);
    let detect_chunk = crate::par::Chunk::resite(spec.chunk, crate::par::autosite::DETECT);
    let mut w: Vec<u32> = order.to_vec();
    let mut trace = RunTrace::default();
    let mut sim_secs = 0.0f64;
    let mut work_units = 0u64;
    let mut iterations = 0usize;
    let mut is_sim = false;

    while !w.is_empty() && iterations < max_iters {
        iterations += 1;
        let net_color = iterations <= spec.net_color_iters;
        let net_conflict = iterations <= spec.net_conflict_iters;
        let mut it = IterTrace {
            queue_len: w.len(),
            color_kind: if net_color { 'N' } else { 'V' },
            conflict_kind: if net_conflict { 'N' } else { 'V' },
            ..Default::default()
        };

        let cr = {
            let _sp = crate::obs::trace::span_n("d2gc.speculate", w.len() as u64);
            if net_color {
                net_color_phase(g, &colors, d, ts, color_chunk)
            } else {
                vertex::color_phase(g, &w, &colors, d, ts, color_chunk, bal)
            }
        };
        it.color_secs = cr.seconds();
        it.color_busy = cr.busy_units.clone();
        work_units += cr.busy_units.iter().sum::<u64>();
        is_sim = cr.sim_ns.is_some();

        let (rr, w_next) = {
            let _sp = crate::obs::trace::span_n("d2gc.detect", w.len() as u64);
            if net_conflict {
                let r1 = net_conflict_phase(g, &colors, d, ts, detect_chunk);
                let r2 =
                    rebuild_queue(g, &colors, d, ts, detect_chunk, spec.lazy_queues, &shared);
                let wn = collect_next(spec.lazy_queues, ts, &shared);
                work_units +=
                    r1.busy_units.iter().sum::<u64>() + r2.busy_units.iter().sum::<u64>();
                let combined = RegionOut {
                    real_secs: r1.real_secs + r2.real_secs,
                    sim_ns: match (r1.sim_ns, r2.sim_ns) {
                        (Some(a), Some(b)) => Some(a + b),
                        _ => None,
                    },
                    busy_units: Vec::new(),
                };
                (combined, wn)
            } else {
                let r = vertex::conflict_phase(
                    g,
                    &w,
                    &colors,
                    d,
                    ts,
                    detect_chunk,
                    spec.lazy_queues,
                    &shared,
                );
                work_units += r.busy_units.iter().sum::<u64>();
                let wn = collect_next(spec.lazy_queues, ts, &shared);
                (r, wn)
            }
        };
        it.conflict_secs = rr.seconds();
        sim_secs += it.color_secs + it.conflict_secs;
        trace.iters.push(it);
        w = w_next;
    }

    if !w.is_empty() {
        // safety net: finish sequentially (exact greedy over what's left)
        let _sp = crate::obs::trace::span_n("d2gc.seq_finish", w.len() as u64);
        sequential_finish(g, &w, &colors, &mut ts[0], d.now());
    }

    let colors_vec = colors.to_vec();
    let n_colors = crate::coloring::stats::distinct_colors(&colors_vec);
    ColoringResult {
        colors: colors_vec,
        n_colors,
        iterations,
        seconds: if is_sim { sim_secs } else { t0.elapsed().as_secs_f64() },
        trace,
        work_units,
    }
}

/// Sequential D2GC greedy (the Table V baseline; ColPack ships only a
/// sequential D2GC). Returns `(colors, work_units)`.
pub fn seq_greedy(g: &Csr, order: &[u32]) -> (Vec<i32>, u64) {
    let mut colors = vec![-1i32; g.n_rows];
    let mut f = crate::coloring::forbidden::StampSet::new(1024);
    let mut units = 0u64;
    for &w in order {
        let w = w as usize;
        f.next_gen();
        for &u in g.row(w) {
            let u = u as usize;
            if u == w {
                continue;
            }
            units += 1;
            if colors[u] >= 0 {
                f.insert(colors[u]);
            }
            for &x in g.row(u) {
                let x = x as usize;
                units += 1;
                if x != w && colors[x] >= 0 {
                    f.insert(colors[x]);
                }
            }
        }
        let (c, probes) = f.first_fit();
        units += probes;
        colors[w] = c;
    }
    (colors, units)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::schedule;
    use crate::coloring::verify::d2gc_valid;
    use crate::graph::generators::random_symmetric;
    use crate::par::ThreadsDriver;
    use crate::sim::{CostModel, SimDriver};

    #[test]
    fn seq_greedy_valid() {
        let g = random_symmetric(200, 800, 3);
        let order: Vec<u32> = (0..200u32).collect();
        let (c, _) = seq_greedy(&g, &order);
        assert!(d2gc_valid(&g, &c).is_ok());
    }

    #[test]
    fn all_d2gc_schedules_valid() {
        let g = random_symmetric(150, 600, 7);
        let order: Vec<u32> = (0..150u32).collect();
        for spec in schedule::D2GC_SET {
            let mut d = ThreadsDriver::new(4);
            let r = run(&g, &order, &spec, Balance::None, &mut d);
            assert!(d2gc_valid(&g, &r.colors).is_ok(), "{} threads", spec.name);

            let mut d = SimDriver::new(8, CostModel::default());
            let r = run(&g, &order, &spec, Balance::None, &mut d);
            assert!(d2gc_valid(&g, &r.colors).is_ok(), "{} sim", spec.name);
        }
    }

    #[test]
    fn d2gc_uses_more_colors_than_d1gc_needs() {
        // on a star, D2GC must give every leaf its own color
        let mut edges = vec![];
        for i in 1..6u32 {
            edges.push((0u32, i));
            edges.push((i, 0u32));
        }
        let g = crate::graph::Csr::from_edges(6, 6, &edges);
        let order: Vec<u32> = (0..6u32).collect();
        let (c, _) = seq_greedy(&g, &order);
        assert!(d2gc_valid(&g, &c).is_ok());
        let distinct = crate::coloring::stats::distinct_colors(&c);
        assert_eq!(distinct, 6, "star K1,5 needs 6 colors at distance 2");
    }

    #[test]
    fn deterministic_sim() {
        let g = random_symmetric(100, 400, 11);
        let order: Vec<u32> = (0..100u32).collect();
        let once = || {
            let mut d = SimDriver::new(4, CostModel::default());
            run(&g, &order, &schedule::N1_N2, Balance::None, &mut d)
        };
        assert_eq!(once().colors, once().colors);
    }
}
