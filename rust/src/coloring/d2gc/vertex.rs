//! Vertex-based D2GC phases — the BGPC Algorithms 4–5 "with the
//! corresponding statements for distance-1 neighbors added" (§VI V-V).
//! The paper implemented these for the parallel ColPack baseline; they
//! are the `V` halves of every Table V schedule.

use crate::coloring::balance::{select_color, Balance};
use crate::coloring::forbidden::ThreadState;
use crate::graph::Csr;
use crate::par::{ColorStore, Cost, Driver, RegionOut, SharedQueue};
use crate::util::arch::PREFETCH_DIST;

/// Vertex-based D2GC coloring: forbid the colors of all distance-1 and
/// distance-2 neighbors, then pick by the configured policy.
pub fn color_phase<D: Driver>(
    g: &Csr,
    w: &[u32],
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
    bal: Balance,
) -> RegionOut {
    d.region(ts, w.len(), chunk, |_tid, s, i, now| {
        let wv = w[i] as usize;
        let mut units = 0u64;
        s.forbidden.next_gen();
        let row = g.row(wv);
        for (k, &u) in row.iter().enumerate() {
            let u = u as usize;
            if u == wv {
                continue;
            }
            if let Some(&nu) = row.get(k + 1) {
                // next distance-1 neighbor: its color and its row head
                colors.prefetch(nu as usize);
                g.prefetch_row(nu as usize);
            }
            units += 1;
            s.forbidden.mark(colors.read(u, now + units));
            let r2 = g.row(u);
            for (j, &x) in r2.iter().enumerate() {
                if let Some(&fx) = r2.get(j + PREFETCH_DIST) {
                    colors.prefetch(fx as usize);
                }
                let x = x as usize;
                units += 1;
                if x != wv {
                    // branch-free: -1 lands in the trash slot (§Perf)
                    s.forbidden.mark(colors.read(x, now + units));
                }
            }
        }
        let col = select_color(bal, s, wv, &mut units);
        colors.write(wv, col, now + units);
        Cost { units, atomics: 0 }
    })
}

/// Vertex-based D2GC conflict detection with the `w > u` tie-break, over
/// both distance-1 and distance-2 neighbors.
pub fn conflict_phase<D: Driver>(
    g: &Csr,
    w: &[u32],
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
    lazy: bool,
    shared: &SharedQueue,
) -> RegionOut {
    d.region(ts, w.len(), chunk, |_tid, s, i, now| {
        let wv = w[i] as usize;
        let cw = colors.read(wv, now);
        let mut units = 1u64;
        let mut atomics = 0u32;
        let mut conflicted = false;
        'outer: for &u in g.row(wv) {
            let u = u as usize;
            if u == wv {
                continue;
            }
            units += 1;
            if wv > u && colors.read(u, now + units) == cw {
                conflicted = true;
                break 'outer;
            }
            for &x in g.row(u) {
                let x = x as usize;
                units += 1;
                if x != wv && wv > x && colors.read(x, now + units) == cw {
                    conflicted = true;
                    break 'outer;
                }
            }
        }
        if conflicted {
            if lazy {
                s.next_local.push(wv as u32);
            } else {
                shared.push(wv as u32);
                atomics += 1;
            }
        }
        Cost { units, atomics }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::verify::d2gc_valid;
    use crate::graph::generators::random_symmetric;
    use crate::par::ThreadsDriver;

    #[test]
    fn single_thread_pass_is_valid() {
        let g = random_symmetric(100, 300, 5);
        let order: Vec<u32> = (0..100u32).collect();
        let mut d = ThreadsDriver::new(1);
        let colors = d.new_colors(100);
        let mut ts = ThreadState::bank(1, 4096);
        color_phase(&g, &order, &colors, &mut d, &mut ts, 64, Balance::None);
        assert!(d2gc_valid(&g, &colors.to_vec()).is_ok());
    }

    #[test]
    fn conflict_phase_catches_planted_distance2_clash() {
        // path 0-1-2, plant c(0)=c(2)=0
        let g = crate::graph::Csr::from_edges(3, 3, &[(0, 1), (1, 0), (1, 2), (2, 1)]);
        let mut d = ThreadsDriver::new(1);
        let colors = d.new_colors(3);
        colors.write(0, 0, 0);
        colors.write(1, 1, 0);
        colors.write(2, 0, 0);
        let mut ts = ThreadState::bank(1, 8);
        let shared = SharedQueue::with_capacity(3);
        let w: Vec<u32> = vec![0, 1, 2];
        conflict_phase(&g, &w, &colors, &mut d, &mut ts, 64, false, &shared);
        assert_eq!(shared.drain(), vec![2], "larger endpoint requeued");
    }
}
