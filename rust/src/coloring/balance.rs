//! Balancing heuristics B1 and B2 (Algorithms 11–12).
//!
//! Both are *online and costless*: they only change which color the
//! first-fit search starts from, using two thread-private trackers
//! (`col_max`, `col_next`) — no shared cardinality counters.
//!
//! * **B1** alternates per vertex/net id parity: odd ids use plain
//!   first-fit; even ids search *downward* from the thread's `col_max`
//!   (falling back to first-fit from `col_max + 1` when the interval is
//!   exhausted), spreading mass across `[0, col_max]` without adding
//!   colors unless forced.
//! * **B2** keeps a rolling start color `col_next`, searches upward from
//!   it, wraps to 0 past `col_max`, then advances
//!   `col_next = min(col + 1, col_max/3 + 1)` — Alg. 12 as printed (the
//!   prose says "minimum color to start" while the pseudocode applies
//!   `min`; we follow the pseudocode, see DESIGN.md §7).

use super::forbidden::ThreadState;

/// Balancing mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Balance {
    /// Unbalanced (plain first-fit / reverse first-fit) — the `-U` rows.
    None,
    /// Algorithm 11.
    B1,
    /// Algorithm 12.
    B2,
}

impl Balance {
    pub fn parse(s: &str) -> Option<Balance> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "u" => Some(Balance::None),
            "b1" => Some(Balance::B1),
            "b2" => Some(Balance::B2),
            _ => None,
        }
    }
}

/// Pick a color for item with id `id` given the thread's forbidden set
/// (already populated). Updates `col_max`/`col_next`. Returns the color
/// and accumulates probe cost into `units`.
#[inline]
pub fn select_color(bal: Balance, ts: &mut ThreadState, id: usize, units: &mut u64) -> i32 {
    let col = match bal {
        Balance::None => {
            let (c, probes) = ts.forbidden.first_fit();
            *units += probes;
            c
        }
        Balance::B1 => {
            if id % 2 == 0 {
                // reverse first-fit from col_max, safety first-fit past it
                let (found, probes) = ts.forbidden.reverse_fit(ts.col_max);
                *units += probes;
                match found {
                    Some(c) => c,
                    None => {
                        let (c, probes) = ts.forbidden.first_fit_from(ts.col_max + 1);
                        *units += probes;
                        c
                    }
                }
            } else {
                let (c, probes) = ts.forbidden.first_fit();
                *units += probes;
                c
            }
        }
        Balance::B2 => {
            let (mut c, probes) = ts.forbidden.first_fit_from(ts.col_next);
            *units += probes;
            if c > ts.col_max {
                let (c0, probes0) = ts.forbidden.first_fit();
                *units += probes0;
                c = c0;
            }
            c
        }
    };
    ts.col_max = ts.col_max.max(col);
    if bal == Balance::B2 {
        ts.col_next = (col + 1).min(ts.col_max / 3 + 1);
    }
    col
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ts_with(forbidden: &[i32]) -> ThreadState {
        let mut ts = ThreadState::new(32);
        ts.forbidden.next_gen();
        for &c in forbidden {
            ts.forbidden.insert(c);
        }
        ts
    }

    #[test]
    fn unbalanced_is_first_fit() {
        let mut ts = ts_with(&[0, 1, 3]);
        let mut u = 0;
        assert_eq!(select_color(Balance::None, &mut ts, 0, &mut u), 2);
        assert!(u > 0);
    }

    #[test]
    fn b1_even_goes_high_odd_goes_low() {
        let mut ts = ts_with(&[0]);
        ts.col_max = 5;
        let mut u = 0;
        // even id: reverse from col_max=5 -> 5 free
        assert_eq!(select_color(Balance::B1, &mut ts, 4, &mut u), 5);
        // odd id: first-fit -> 1
        let mut ts = ts_with(&[0]);
        ts.col_max = 5;
        assert_eq!(select_color(Balance::B1, &mut ts, 3, &mut u), 1);
    }

    #[test]
    fn b1_safety_extends_interval() {
        // all of [0, col_max] forbidden -> fall to col_max+1 upward
        let mut ts = ts_with(&[0, 1, 2]);
        ts.col_max = 2;
        let mut u = 0;
        assert_eq!(select_color(Balance::B1, &mut ts, 0, &mut u), 3);
        assert_eq!(ts.col_max, 3, "col_max tracks the new color");
    }

    #[test]
    fn b2_rolls_start_and_wraps() {
        let mut ts = ts_with(&[]);
        ts.col_max = 6;
        ts.col_next = 4;
        let mut u = 0;
        let c = select_color(Balance::B2, &mut ts, 0, &mut u);
        assert_eq!(c, 4);
        // col_next = min(5, 6/3+1=3) = 3
        assert_eq!(ts.col_next, 3);
        // now forbid 3.. past col_max to force the wrap path
        let mut ts = ts_with(&[6]);
        ts.col_max = 6;
        ts.col_next = 6;
        let c = select_color(Balance::B2, &mut ts, 1, &mut u);
        assert_eq!(c, 0, "wrapped to first-fit from 0");
    }

    #[test]
    fn col_max_monotone() {
        let mut ts = ts_with(&[0, 1, 2, 3, 4]);
        let mut u = 0;
        let c = select_color(Balance::None, &mut ts, 0, &mut u);
        assert_eq!(c, 5);
        assert_eq!(ts.col_max, 5);
        let mut ts2 = ts_with(&[]);
        ts2.col_max = 9;
        select_color(Balance::None, &mut ts2, 0, &mut u);
        assert_eq!(ts2.col_max, 9, "never decreases");
    }
}
