//! Color-set statistics — the balancing experiments' metrics (Table VI,
//! Figure 3): number of color sets, average cardinality, standard
//! deviation of cardinalities, and the cardinality histogram.

use crate::util::stats::{mean, stddev};

/// Number of distinct colors used (ignores `-1`).
pub fn distinct_colors(colors: &[i32]) -> usize {
    cardinalities(colors).iter().filter(|&&c| c > 0).count()
}

/// Cardinality of each color class `0..=max`.
pub fn cardinalities(colors: &[i32]) -> Vec<usize> {
    let max = colors.iter().copied().max().unwrap_or(-1);
    if max < 0 {
        return Vec::new();
    }
    let mut card = vec![0usize; max as usize + 1];
    for &c in colors {
        if c >= 0 {
            card[c as usize] += 1;
        }
    }
    card
}

/// Summary statistics over the color classes.
#[derive(Clone, Debug)]
pub struct ColorStats {
    /// Number of non-empty color sets.
    pub n_colors: usize,
    /// Average cardinality over non-empty sets.
    pub avg_cardinality: f64,
    /// Population stddev of non-empty set cardinalities (Table VI).
    pub stddev_cardinality: f64,
    /// Largest set.
    pub max_cardinality: usize,
    /// Sets with fewer than 2 vertices (the paper's skewness symptom:
    /// "thousands of color sets with less than 2 elements").
    pub tiny_sets: usize,
    /// Full cardinality vector (Figure 3 raw data).
    pub cards: Vec<usize>,
}

impl ColorStats {
    pub fn from_colors(colors: &[i32]) -> ColorStats {
        ColorStats::from_cards(cardinalities(colors))
    }

    /// Same statistics computed from per-color cardinalities directly —
    /// what [`crate::exec::ColorSchedule`] already tracks as bucket
    /// sizes — skipping the pass over the colors. Empty classes are
    /// dropped, as in [`ColorStats::from_colors`].
    pub fn from_cards(cards: Vec<usize>) -> ColorStats {
        let cards: Vec<usize> = cards.into_iter().filter(|&c| c > 0).collect();
        let f: Vec<f64> = cards.iter().map(|&c| c as f64).collect();
        ColorStats {
            n_colors: cards.len(),
            avg_cardinality: if f.is_empty() { 0.0 } else { mean(&f) },
            stddev_cardinality: if f.is_empty() { 0.0 } else { stddev(&f) },
            max_cardinality: cards.iter().copied().max().unwrap_or(0),
            tiny_sets: cards.iter().filter(|&&c| c < 2).count(),
            cards,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_stats() {
        let colors = [0, 0, 0, 1, 1, 3]; // color 2 unused
        assert_eq!(distinct_colors(&colors), 3);
        let s = ColorStats::from_colors(&colors);
        assert_eq!(s.n_colors, 3);
        assert_eq!(s.max_cardinality, 3);
        assert_eq!(s.tiny_sets, 1); // color 3 has one vertex
        assert!((s.avg_cardinality - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_uncolored() {
        assert_eq!(distinct_colors(&[]), 0);
        assert_eq!(distinct_colors(&[-1, -1]), 0);
        let s = ColorStats::from_colors(&[-1]);
        assert_eq!(s.n_colors, 0);
        assert_eq!(s.avg_cardinality, 0.0);
    }

    #[test]
    fn from_cards_matches_from_colors() {
        let colors = [0, 0, 0, 1, 1, 3];
        let a = ColorStats::from_colors(&colors);
        let b = ColorStats::from_cards(cardinalities(&colors));
        assert_eq!(a.n_colors, b.n_colors);
        assert_eq!(a.cards, b.cards);
        assert_eq!(a.max_cardinality, b.max_cardinality);
        assert!((a.stddev_cardinality - b.stddev_cardinality).abs() < 1e-12);
    }

    #[test]
    fn balanced_has_smaller_stddev() {
        let skewed = [0, 0, 0, 0, 0, 0, 1, 2];
        let flat = [0, 0, 0, 1, 1, 1, 2, 2];
        let a = ColorStats::from_colors(&skewed).stddev_cardinality;
        let b = ColorStats::from_colors(&flat).stddev_cardinality;
        assert!(b < a);
    }
}
