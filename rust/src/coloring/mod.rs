//! Parallel greedy optimistic coloring — the paper's contribution.
//!
//! The engine implements the speculate → detect-conflicts → repeat loop
//! (Algorithms 1–3) with every phase variant the paper studies:
//!
//! * BGPC vertex-based coloring / conflict removal (Alg. 4–5, ColPack's
//!   baseline) — [`bgpc::vertex`];
//! * BGPC net-based coloring v1 / v1+reverse / two-pass (Alg. 6 / Table I
//!   middle column / Alg. 8) and net-based conflict removal (Alg. 7) —
//!   [`bgpc::net`];
//! * D2GC analogues (Alg. 9–10) — [`d2gc`];
//! * the hybrid schedules `V-V` … `N2-N2` — [`schedule`];
//! * balancing heuristics B1/B2 (Alg. 11–12) — [`balance`];
//! * D1GC at full engine parity — [`d1gc`];
//! * the strategy seam (orderings × color-and-fix post pass) —
//!   [`strategy`] (DESIGN.md §14).
//!
//! All of it is driven through one problem-generic front door:
//! [`color`] for one-shot runs, [`Colorer`] to route a run onto a shared
//! [`WorkerPool`]. The graph type picks the problem (BGPC on
//! [`Bipartite`], D2GC on [`Csr`], D1GC on [`crate::dynamic::D1Graph`]);
//! the old per-problem `color_*` functions survive as deprecated
//! aliases.

pub mod balance;
pub mod bgpc;
pub mod d1gc;
pub mod d2gc;
pub mod forbidden;
pub mod schedule;
pub mod stats;
pub mod strategy;
pub mod verify;

pub use balance::Balance;
pub use forbidden::{StampSet, ThreadState};
pub use schedule::{AlgSpec, NetColorAlg, Schedule};
pub use stats::ColorStats;
pub use strategy::{PostPass, Strategy};

use std::sync::Arc;

use crate::graph::{Bipartite, Csr, Ordering};
use crate::sim::trace::RunTrace;
use crate::sim::{CostModel, SimDriver};
use crate::par::{ThreadsDriver, WorkerPool};

/// Which coloring problem to solve.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Problem {
    /// Bipartite-graph partial coloring (color `V_A`; nets define
    /// the neighborhood).
    Bgpc,
    /// Distance-2 graph coloring on a square graph.
    D2gc,
    /// Distance-1 coloring (survey baseline).
    D1gc,
}

/// Execution backend.
#[derive(Clone, Copy, Debug)]
pub enum ExecMode {
    /// Real `std::thread` workers (concurrency-correctness path).
    Threads,
    /// Deterministic multicore simulator (the paper's 16-thread testbed
    /// substitute; see DESIGN.md §4).
    Sim(CostModel),
}

/// A complete run configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub spec: AlgSpec,
    pub balance: Balance,
    pub threads: usize,
    pub mode: ExecMode,
    pub ordering: Ordering,
    /// Post-coloring improvement pass (DESIGN.md §14).
    pub post_pass: PostPass,
}

impl Config {
    /// The paper's default experimental setup: simulator, natural order.
    pub fn sim(spec: AlgSpec, threads: usize) -> Config {
        Config {
            spec,
            balance: Balance::None,
            threads,
            mode: ExecMode::Sim(CostModel::default()),
            ordering: Ordering::Natural,
            post_pass: PostPass::None,
        }
    }

    /// Real threads (tests).
    pub fn threads(spec: AlgSpec, threads: usize) -> Config {
        Config {
            spec,
            balance: Balance::None,
            threads,
            mode: ExecMode::Threads,
            ordering: Ordering::Natural,
            post_pass: PostPass::None,
        }
    }

    pub fn with_balance(mut self, b: Balance) -> Config {
        self.balance = b;
        self
    }

    pub fn with_ordering(mut self, o: Ordering) -> Config {
        self.ordering = o;
        self
    }

    pub fn with_post_pass(mut self, p: PostPass) -> Config {
        self.post_pass = p;
        self
    }

    /// Apply both halves of a [`Strategy`] at once.
    pub fn with_strategy(mut self, s: Strategy) -> Config {
        self.ordering = s.ordering;
        self.post_pass = s.post_pass;
        self
    }
}

/// Outcome of a coloring run.
#[derive(Clone, Debug)]
pub struct ColoringResult {
    /// Final color per vertex (all `>= 0` on success).
    pub colors: Vec<i32>,
    /// Number of distinct colors used.
    pub n_colors: usize,
    /// Speculate/repair iterations executed.
    pub iterations: usize,
    /// Total time: simulated seconds under `ExecMode::Sim`, measured
    /// wall-clock under `ExecMode::Threads`.
    pub seconds: f64,
    /// Per-iteration phase trace (Figure 1 raw data).
    pub trace: RunTrace,
    /// Total work units: modeled units under the simulator, summed
    /// per-worker [`crate::par::Cost::units`] on real threads.
    pub work_units: u64,
}

impl ColoringResult {
    pub fn stats(&self) -> ColorStats {
        ColorStats::from_colors(&self.colors)
    }
}

/// Color any coloring problem with the given configuration — the one
/// generic entry point (BGPC on [`Bipartite`], D2GC on [`Csr`], D1GC on
/// [`crate::dynamic::D1Graph`]; the problem is selected by the graph
/// type through the [`crate::dynamic::Problem`] seam). Threads mode
/// builds a private [`WorkerPool`] for the run; long-lived callers (the
/// coordinator, sessions) should prefer [`Colorer::on`] /
/// [`crate::dynamic::DynamicSession::start_on`], which reuse a shared
/// team and its resident scratch.
pub fn color<P: crate::dynamic::Problem>(g: &P, cfg: &Config) -> ColoringResult {
    Colorer::new(cfg).color(g)
}

/// Builder form of [`color`]: bind a [`Config`], optionally route the
/// run onto a shared [`WorkerPool`] with [`Colorer::on`], then color any
/// number of graphs.
///
/// ```no_run
/// # use bgpc::coloring::{AlgSpec, Colorer, Config};
/// # use bgpc::graph::Preset;
/// # use bgpc::par::WorkerPool;
/// # use std::sync::Arc;
/// let g = Preset::by_name("coPapersDBLP").unwrap().bipartite(0.05, 1);
/// let cfg = Config::threads(AlgSpec::by_name("N1-N2").unwrap(), 4);
/// let pool = Arc::new(WorkerPool::new(4));
/// let r = Colorer::new(&cfg).on(&pool).color(&g);
/// assert!(r.n_colors > 0);
/// ```
pub struct Colorer<'a> {
    cfg: &'a Config,
    pool: Option<&'a Arc<WorkerPool>>,
}

impl<'a> Colorer<'a> {
    /// A colorer with a private driver per run (no shared pool).
    pub fn new(cfg: &'a Config) -> Colorer<'a> {
        Colorer { cfg, pool: None }
    }

    /// Route threads-mode runs onto `pool` (sim configs ignore it). The
    /// run borrows the pool's team — clamped to its size, never a
    /// spawn — and the pool-resident [`ThreadState`] bank, so forbidden
    /// arrays are allocated once across *jobs*, not just across the
    /// iterations of one run (DESIGN.md §10).
    pub fn on(mut self, pool: &'a Arc<WorkerPool>) -> Colorer<'a> {
        self.pool = Some(pool);
        self
    }

    /// Color `g` under the bound configuration.
    pub fn color<P: crate::dynamic::Problem>(&self, g: &P) -> ColoringResult {
        let cfg = self.cfg;
        g.check_colorable();
        let order = g.order(&cfg.ordering);
        match (self.pool, cfg.mode) {
            (Some(pool), ExecMode::Threads) => {
                let mut d = ThreadsDriver::on_team(pool, cfg.threads);
                let t = d.threads();
                with_pool_bank(pool, t, g.color_cap(), |bank| {
                    let mut r = g.run_capped(
                        &order,
                        &cfg.spec,
                        cfg.balance,
                        &mut d,
                        bank,
                        bgpc::MAX_ITERS,
                    );
                    post_pass_on_bank(g, cfg, &mut d, bank, &mut r);
                    r
                })
            }
            (None, ExecMode::Threads) => {
                let mut d = ThreadsDriver::new(cfg.threads);
                run_owned(g, &order, cfg, &mut d)
            }
            (_, ExecMode::Sim(model)) => {
                let mut d = SimDriver::new(cfg.threads, model);
                run_owned(g, &order, cfg, &mut d)
            }
        }
    }
}

/// Owned-driver run: a fresh per-run [`ThreadState`] bank for the engine
/// loop, and (matching the historical one-shot entry points bit for bit)
/// a second fresh bank inside [`post_pass_owned`] for the fix pass.
fn run_owned<P: crate::dynamic::Problem, D: crate::par::Driver>(
    g: &P,
    order: &[u32],
    cfg: &Config,
    d: &mut D,
) -> ColoringResult {
    let mut bank = ThreadState::bank(d.threads(), g.color_cap());
    let mut r = g.run_capped(order, &cfg.spec, cfg.balance, d, &mut bank, bgpc::MAX_ITERS);
    post_pass_owned(g, cfg, d, &mut r);
    r
}

/// Run the configured [`PostPass`] (if any) against `r`, with a private
/// per-run [`ThreadState`] bank — the helper the one-shot entry points
/// share. `P` is the [`crate::dynamic::Problem`] view of the graph, so
/// one generic fix pass serves BGPC, D2GC, and D1GC (DESIGN.md §14).
fn post_pass_owned<P: crate::dynamic::Problem, D: crate::par::Driver>(
    g: &P,
    cfg: &Config,
    d: &mut D,
    r: &mut ColoringResult,
) {
    if matches!(cfg.post_pass, PostPass::ColorAndFix(_)) {
        let mut bank = ThreadState::bank(d.threads(), g.color_cap());
        post_pass_on_bank(g, cfg, d, &mut bank, r);
    }
}

/// [`post_pass_owned`] with a caller-owned bank (the `_on` entry points
/// reuse the pool-resident one).
fn post_pass_on_bank<P: crate::dynamic::Problem, D: crate::par::Driver>(
    g: &P,
    cfg: &Config,
    d: &mut D,
    ts: &mut [ThreadState],
    r: &mut ColoringResult,
) {
    if let PostPass::ColorAndFix(rounds) = cfg.post_pass {
        let base = std::mem::take(&mut r.colors);
        let (colors, secs) =
            strategy::color_and_fix(g, base, rounds, cfg.spec.chunk, d, ts);
        r.colors = colors;
        r.n_colors = stats::distinct_colors(&r.colors);
        r.seconds += secs;
    }
}

/// Borrow the pool-resident [`ThreadState`] bank for one job: grow it
/// to the team size if needed, reset the per-run state of the slots the
/// team will use (allocations survive — DESIGN.md §10), and hand the
/// team-sized slice to `f`. All pool-routed runs go through here, so
/// the reuse protocol cannot diverge per problem.
fn with_pool_bank<R>(
    pool: &Arc<WorkerPool>,
    t: usize,
    cap: usize,
    f: impl FnOnce(&mut [ThreadState]) -> R,
) -> R {
    pool.with_scratch(Vec::new, |bank: &mut Vec<ThreadState>| {
        if bank.len() < t {
            bank.resize_with(t, || ThreadState::new(cap));
        }
        for s in bank.iter_mut().take(t) {
            s.reset_for_run();
        }
        f(&mut bank[..t])
    })
}

// ---------------------------------------------------------------------------
// Deprecated per-problem aliases. The six-way `color_{bgpc,d2gc,d1gc}` /
// `*_on` surface predates the generic entry point; each alias forwards
// to [`color`] / [`Colorer`] unchanged (bit-for-bit identical results)
// and will be removed once out-of-tree callers migrate.
// ---------------------------------------------------------------------------

/// Color a BGPC instance.
#[deprecated(
    since = "0.1.0",
    note = "use the problem-generic `coloring::color(g, cfg)` instead"
)]
pub fn color_bgpc(g: &Bipartite, cfg: &Config) -> ColoringResult {
    color(g, cfg)
}

/// Color a BGPC instance on a shared pool.
#[deprecated(
    since = "0.1.0",
    note = "use `coloring::Colorer::new(cfg).on(pool).color(g)` instead"
)]
pub fn color_bgpc_on(g: &Bipartite, cfg: &Config, pool: &Arc<WorkerPool>) -> ColoringResult {
    Colorer::new(cfg).on(pool).color(g)
}

/// Color a D2GC instance (square graph).
#[deprecated(
    since = "0.1.0",
    note = "use the problem-generic `coloring::color(g, cfg)` instead"
)]
pub fn color_d2gc(g: &Csr, cfg: &Config) -> ColoringResult {
    color(g, cfg)
}

/// Color a D2GC instance on a shared pool.
#[deprecated(
    since = "0.1.0",
    note = "use `coloring::Colorer::new(cfg).on(pool).color(g)` instead"
)]
pub fn color_d2gc_on(g: &Csr, cfg: &Config, pool: &Arc<WorkerPool>) -> ColoringResult {
    Colorer::new(cfg).on(pool).color(g)
}

/// Color a D1GC instance (square graph).
#[deprecated(
    since = "0.1.0",
    note = "use `coloring::color(D1Graph::from_ref(g), cfg)` instead"
)]
pub fn color_d1gc(g: &Csr, cfg: &Config) -> ColoringResult {
    color(crate::dynamic::D1Graph::from_ref(g), cfg)
}

/// Color a D1GC instance on a shared pool.
#[deprecated(
    since = "0.1.0",
    note = "use `coloring::Colorer::new(cfg).on(pool).color(D1Graph::from_ref(g))` instead"
)]
pub fn color_d1gc_on(g: &Csr, cfg: &Config, pool: &Arc<WorkerPool>) -> ColoringResult {
    Colorer::new(cfg).on(pool).color(crate::dynamic::D1Graph::from_ref(g))
}
