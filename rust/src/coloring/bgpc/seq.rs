//! Sequential BGPC — ColPack's sequential greedy (the paper's speedup
//! baseline, Table II columns 7–10).
//!
//! A single pass in queue order with first-fit; no conflict phase is
//! needed ("since the executions are sequential, a conflict detection
//! phase is not performed" — Table II caption). Returns the coloring and
//! the abstract work units, which calibrate the simulator's
//! `ns_per_unit` and anchor every "speedup over sequential V-V" row.

use crate::coloring::forbidden::StampSet;
use crate::graph::Bipartite;

/// Sequential vertex-based greedy coloring in `order`.
/// Returns `(colors, work_units)`.
pub fn greedy(g: &Bipartite, order: &[u32]) -> (Vec<i32>, u64) {
    let mut colors = vec![-1i32; g.n_vertices()];
    let mut f = StampSet::new(1024);
    let mut units = 0u64;
    for &w in order {
        let w = w as usize;
        f.next_gen();
        for &v in g.nets(w) {
            for &u in g.vtxs(v as usize) {
                units += 1;
                let u = u as usize;
                if u != w && colors[u] >= 0 {
                    f.insert(colors[u]);
                }
            }
        }
        let (c, probes) = f.first_fit();
        units += probes;
        colors[w] = c;
    }
    (colors, units)
}

/// Sequential greedy + wall-clock measurement.
/// Returns `(colors, units, seconds)`.
pub fn greedy_timed(g: &Bipartite, order: &[u32]) -> (Vec<i32>, u64, f64) {
    let t0 = std::time::Instant::now();
    let (colors, units) = greedy(g, order);
    (colors, units, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::verify::bgpc_valid;
    use crate::graph::generators::random_bipartite;
    use crate::graph::Ordering;

    #[test]
    fn sequential_is_always_valid() {
        let g = random_bipartite(200, 300, 2000, 3);
        let order: Vec<u32> = (0..300u32).collect();
        let (c, units) = greedy(&g, &order);
        assert!(bgpc_valid(&g, &c).is_ok());
        assert!(c.iter().all(|&x| x >= 0));
        assert!(units > 0);
    }

    #[test]
    fn smallest_last_tends_to_fewer_colors() {
        // The paper's Table II: smallest-last reduces #colors on most
        // matrices. On a skewed random instance it should not be worse.
        let g = crate::graph::generators::Preset::by_name("coPapersDBLP")
            .unwrap()
            .bipartite(0.01, 9);
        let natural = Ordering::Natural.compute(&g);
        let sl = Ordering::SmallestLast.compute(&g);
        let (cn, _) = greedy(&g, &natural);
        let (cs, _) = greedy(&g, &sl);
        let n_nat = crate::coloring::stats::distinct_colors(&cn);
        let n_sl = crate::coloring::stats::distinct_colors(&cs);
        assert!(
            n_sl <= n_nat + n_nat / 10,
            "smallest-last should not blow up colors: {n_sl} vs {n_nat}"
        );
    }

    #[test]
    fn deterministic() {
        let g = random_bipartite(50, 80, 400, 5);
        let order: Vec<u32> = (0..80u32).collect();
        assert_eq!(greedy(&g, &order), greedy(&g, &order));
    }
}
