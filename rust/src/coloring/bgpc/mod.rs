//! The BGPC engine: the speculate → detect → repeat loop (Algorithm 1)
//! assembled from the phase variants according to an [`AlgSpec`].

pub mod net;
pub mod seq;
pub mod vertex;

use crate::coloring::balance::Balance;
use crate::coloring::forbidden::ThreadState;
use crate::coloring::schedule::AlgSpec;
use crate::coloring::ColoringResult;
use crate::graph::Bipartite;
use crate::par::{autosite, Chunk, ColorStore, Driver, SharedQueue};
use crate::sim::trace::{IterTrace, RunTrace};

/// Iteration-count safety net: beyond this the remaining vertices are
/// finished sequentially (never observed in practice; present so
/// adversarial inputs cannot livelock the optimistic loop).
pub const MAX_ITERS: usize = 200;

/// Gather the next work queue from the lazy per-thread queues or the
/// shared queue, whichever the spec uses. Shared with the incremental
/// repair loop in [`crate::dynamic`].
pub(crate) fn collect_next(lazy: bool, ts: &mut [ThreadState], shared: &SharedQueue) -> Vec<u32> {
    if lazy {
        let cap: usize = ts.iter().map(|s| s.next_local.len()).sum();
        let mut w = Vec::with_capacity(cap);
        for s in ts.iter_mut() {
            w.append(&mut s.next_local);
        }
        w
    } else {
        shared.drain()
    }
}

/// Upper bound on any color the engine can produce, for sizing the
/// forbidden arrays: vertex-based first-fit stays ≤ the max two-hop
/// degree; net-based stays < the max net degree; B1 can add one.
/// Public because the dynamic subsystem and the property tests size
/// persistent [`ThreadState`] banks with it.
pub fn color_cap(g: &Bipartite) -> usize {
    let max2hop = (0..g.n_vertices()).map(|u| g.two_hop_bound(u)).max().unwrap_or(0);
    max2hop.max(g.net_vtxs.max_deg()) + 4
}

/// The `MAX_ITERS` safety net: exact sequential greedy over the
/// remaining queue, reading and writing through the color store at time
/// `now`. Also the last line of defense of the incremental repair loop.
pub fn sequential_finish<C: ColorStore>(
    g: &Bipartite,
    w: &[u32],
    colors: &C,
    ts0: &mut ThreadState,
    now: u64,
) {
    for &wv in w {
        let wv = wv as usize;
        ts0.forbidden.next_gen();
        for &v in g.nets(wv) {
            for &u in g.vtxs(v as usize) {
                let u = u as usize;
                if u != wv {
                    let c = colors.read(u, now);
                    if c >= 0 {
                        ts0.forbidden.insert(c);
                    }
                }
            }
        }
        let (c, _) = ts0.forbidden.first_fit();
        colors.write(wv, c, now);
    }
}

/// Run a full BGPC coloring with driver `d`.
pub fn run<D: Driver>(
    g: &Bipartite,
    order: &[u32],
    spec: &AlgSpec,
    bal: Balance,
    d: &mut D,
) -> ColoringResult {
    let mut ts = ThreadState::bank(d.threads(), color_cap(g));
    run_capped(g, order, spec, bal, d, &mut ts, MAX_ITERS)
}

/// [`run`] with an explicit iteration cap and a caller-owned
/// [`ThreadState`] bank. The bank is how per-thread state (B1/B2
/// `col_max`/`col_next` trackers, forbidden arrays) persists across
/// calls — the dynamic subsystem threads one bank through a whole
/// update stream. The forbidden domains are re-`ensure`d here, so a
/// bank sized for a previous (smaller) graph stays safe.
pub fn run_capped<D: Driver>(
    g: &Bipartite,
    order: &[u32],
    spec: &AlgSpec,
    bal: Balance,
    d: &mut D,
    ts: &mut [ThreadState],
    max_iters: usize,
) -> ColoringResult {
    let n = g.n_vertices();
    let t0 = std::time::Instant::now();
    let colors = d.new_colors(n);
    let cap = color_cap(g);
    for s in ts.iter_mut() {
        s.forbidden.ensure(cap);
    }
    let shared = SharedQueue::with_capacity(n);
    // Re-aim a generic Auto chunk per phase: speculation and detection
    // have very different per-item costs, so they tune independently.
    let color_chunk = Chunk::resite(spec.chunk, autosite::SPECULATE);
    let detect_chunk = Chunk::resite(spec.chunk, autosite::DETECT);
    let mut w: Vec<u32> = order.to_vec();
    let mut trace = RunTrace::default();
    let mut sim_secs = 0.0f64;
    let mut work_units = 0u64;
    let mut iterations = 0usize;
    let mut is_sim = false;

    while !w.is_empty() && iterations < max_iters {
        iterations += 1;
        let net_color = iterations <= spec.net_color_iters;
        let net_conflict = iterations <= spec.net_conflict_iters;
        let mut it = IterTrace {
            queue_len: w.len(),
            color_kind: if net_color { 'N' } else { 'V' },
            conflict_kind: if net_conflict { 'N' } else { 'V' },
            ..Default::default()
        };

        // --- coloring phase (Alg. 4 / 6 / 8) ---
        let cr = {
            let _sp = crate::obs::trace::span_n("bgpc.speculate", w.len() as u64);
            if net_color {
                net::color_phase(g, &colors, d, ts, color_chunk, spec.net_alg, bal)
            } else {
                vertex::color_phase(g, &w, &colors, d, ts, color_chunk, bal)
            }
        };
        it.color_secs = cr.seconds();
        it.color_busy = cr.busy_units.clone();
        work_units += cr.busy_units.iter().sum::<u64>();
        is_sim = cr.sim_ns.is_some();

        // --- conflict removal phase (Alg. 5 / 7) ---
        let (rr, w_next) = {
            let _sp = crate::obs::trace::span_n("bgpc.detect", w.len() as u64);
            if net_conflict {
                let r1 = net::conflict_phase(g, &colors, d, ts, detect_chunk);
                let r2 = net::rebuild_queue(
                    n,
                    &colors,
                    d,
                    ts,
                    detect_chunk,
                    spec.lazy_queues,
                    &shared,
                );
                let wn = collect_next(spec.lazy_queues, ts, &shared);
                let combined = crate::par::RegionOut {
                    real_secs: r1.real_secs + r2.real_secs,
                    sim_ns: match (r1.sim_ns, r2.sim_ns) {
                        (Some(a), Some(b)) => Some(a + b),
                        _ => None,
                    },
                    busy_units: Vec::new(),
                };
                work_units += r1.busy_units.iter().sum::<u64>()
                    + r2.busy_units.iter().sum::<u64>();
                (combined, wn)
            } else {
                let r = vertex::conflict_phase(
                    g,
                    &w,
                    &colors,
                    d,
                    ts,
                    detect_chunk,
                    spec.lazy_queues,
                    &shared,
                );
                work_units += r.busy_units.iter().sum::<u64>();
                let wn = collect_next(spec.lazy_queues, ts, &shared);
                (r, wn)
            }
        };
        it.conflict_secs = rr.seconds();
        sim_secs += it.color_secs + it.conflict_secs;
        trace.iters.push(it);
        w = w_next;
    }

    if !w.is_empty() {
        // safety net: finish sequentially (exact greedy over what's left)
        let _sp = crate::obs::trace::span_n("bgpc.seq_finish", w.len() as u64);
        sequential_finish(g, &w, &colors, &mut ts[0], d.now());
    }

    let colors_vec = colors.to_vec();
    let n_colors = crate::coloring::stats::distinct_colors(&colors_vec);
    ColoringResult {
        colors: colors_vec,
        n_colors,
        iterations,
        seconds: if is_sim { sim_secs } else { t0.elapsed().as_secs_f64() },
        trace,
        work_units,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::schedule;
    use crate::coloring::verify::bgpc_valid;
    use crate::graph::generators::{random_bipartite, Preset};
    use crate::par::ThreadsDriver;
    use crate::sim::{CostModel, SimDriver};

    fn check_all_specs(g: &Bipartite, t: usize) {
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        for spec in schedule::ALL {
            // real threads
            let mut d = ThreadsDriver::new(t);
            let r = run(g, &order, &spec, Balance::None, &mut d);
            assert!(
                bgpc_valid(g, &r.colors).is_ok(),
                "{} threads={} invalid",
                spec.name,
                t
            );
            // simulator
            let mut d = SimDriver::new(t, CostModel::default());
            let r = run(g, &order, &spec, Balance::None, &mut d);
            assert!(
                bgpc_valid(g, &r.colors).is_ok(),
                "{} sim t={} invalid",
                spec.name,
                t
            );
            assert!(r.seconds > 0.0);
            assert!(r.n_colors > 0);
        }
    }

    #[test]
    fn all_schedules_produce_valid_colorings() {
        let g = random_bipartite(300, 400, 3000, 11);
        check_all_specs(&g, 1);
        check_all_specs(&g, 4);
    }

    #[test]
    fn all_schedules_valid_on_skewed_preset() {
        let g = Preset::by_name("coPapersDBLP").unwrap().bipartite(0.02, 3);
        check_all_specs(&g, 8);
    }

    #[test]
    fn balancing_preserves_validity() {
        let g = random_bipartite(200, 300, 2500, 13);
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        for bal in [Balance::B1, Balance::B2] {
            for spec in [schedule::V_N2, schedule::N1_N2] {
                let mut d = SimDriver::new(8, CostModel::default());
                let r = run(&g, &order, &spec, bal, &mut d);
                assert!(
                    bgpc_valid(&g, &r.colors).is_ok(),
                    "{:?} {} invalid",
                    bal,
                    spec.name
                );
            }
        }
    }

    #[test]
    fn simulator_runs_are_deterministic() {
        let g = random_bipartite(150, 200, 1500, 17);
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        let run_once = || {
            let mut d = SimDriver::new(4, CostModel::default());
            run(&g, &order, &schedule::N1_N2, Balance::None, &mut d)
        };
        let a = run_once();
        let b = run_once();
        assert_eq!(a.colors, b.colors);
        assert_eq!(a.seconds, b.seconds);
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn net_first_iteration_leaves_work_for_iter_two() {
        // Under the simulator with several threads, Alg. 8's optimism must
        // leave *some* conflicts on a shared-heavy graph (Table I behaviour).
        let g = Preset::by_name("coPapersDBLP").unwrap().bipartite(0.02, 5);
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        let mut d = SimDriver::new(16, CostModel::default());
        let r = run(&g, &order, &schedule::N1_N2, Balance::None, &mut d);
        assert!(r.iterations >= 2, "expected speculative conflicts");
    }

    #[test]
    fn max_iters_fallback_yields_valid_coloring() {
        // Adversarially tiny iteration caps: the optimistic loop is cut
        // short and the sequential safety net must finish the job.
        let g = Preset::by_name("coPapersDBLP").unwrap().bipartite(0.02, 5);
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        for cap in [0usize, 1, 2] {
            let mut ts = ThreadState::bank(16, color_cap(&g));
            let mut d = SimDriver::new(16, CostModel::default());
            let r = run_capped(&g, &order, &schedule::N1_N2, Balance::None, &mut d, &mut ts, cap);
            assert!(bgpc_valid(&g, &r.colors).is_ok(), "cap={cap} invalid");
            assert!(r.iterations <= cap, "cap={cap} ran {} iterations", r.iterations);
            assert!(r.colors.iter().all(|&c| c >= 0), "cap={cap} left uncolored vertices");
        }
        // This graph provably leaves conflicts after one 16-thread
        // speculative iteration (see net_first_iteration_leaves_work_for
        // _iter_two), so cap=1 above genuinely exercised the fallback.
    }

    #[test]
    fn max_iters_zero_fallback_is_exact_sequential_greedy() {
        // With cap=0 the whole queue goes straight to the safety net,
        // which must reproduce the sequential greedy baseline bit-for-bit.
        let g = random_bipartite(120, 180, 1400, 23);
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        let mut ts = ThreadState::bank(1, color_cap(&g));
        let mut d = ThreadsDriver::new(1);
        let r = run_capped(&g, &order, &schedule::V_V, Balance::None, &mut d, &mut ts, 0);
        let (seq_colors, _) = super::seq::greedy(&g, &order);
        assert_eq!(r.colors, seq_colors, "cap=0 fallback must equal greedy");
        assert_eq!(r.iterations, 0);
    }

    #[test]
    fn sequential_finish_repairs_adversarial_store() {
        // Feed the safety net a store where *every* vertex of a shared
        // net clashes; it must still emit a valid coloring.
        let g = random_bipartite(40, 60, 400, 31);
        let mut d = ThreadsDriver::new(1);
        let colors = d.new_colors(g.n_vertices());
        for u in 0..g.n_vertices() {
            colors.write(u, 0, 0); // all vertices share color 0
        }
        let w: Vec<u32> = (0..g.n_vertices() as u32).collect();
        let mut ts0 = ThreadState::new(color_cap(&g));
        sequential_finish(&g, &w, &colors, &mut ts0, d.now());
        let c = colors.to_vec();
        assert!(bgpc_valid(&g, &c).is_ok(), "fallback left conflicts");
    }

    #[test]
    fn empty_and_degenerate_graphs() {
        let g = random_bipartite(10, 20, 0, 1); // no edges at all
        let order: Vec<u32> = (0..20u32).collect();
        let mut d = ThreadsDriver::new(2);
        let r = run(&g, &order, &schedule::V_V, Balance::None, &mut d);
        assert!(bgpc_valid(&g, &r.colors).is_ok());
        assert_eq!(r.n_colors, 1, "independent vertices all take color 0");
    }
}
