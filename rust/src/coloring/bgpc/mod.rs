//! The BGPC engine: the speculate → detect → repeat loop (Algorithm 1)
//! assembled from the phase variants according to an [`AlgSpec`].

pub mod net;
pub mod seq;
pub mod vertex;

use crate::coloring::balance::Balance;
use crate::coloring::forbidden::ThreadState;
use crate::coloring::schedule::AlgSpec;
use crate::coloring::ColoringResult;
use crate::graph::Bipartite;
use crate::par::{ColorStore, Driver, SharedQueue};
use crate::sim::trace::{IterTrace, RunTrace};

/// Iteration-count safety net: beyond this the remaining vertices are
/// finished sequentially (never observed in practice; present so
/// adversarial inputs cannot livelock the optimistic loop).
pub const MAX_ITERS: usize = 200;

/// Gather the next work queue from the lazy per-thread queues or the
/// shared queue, whichever the spec uses.
fn collect_next(lazy: bool, ts: &mut [ThreadState], shared: &SharedQueue) -> Vec<u32> {
    if lazy {
        let cap: usize = ts.iter().map(|s| s.next_local.len()).sum();
        let mut w = Vec::with_capacity(cap);
        for s in ts.iter_mut() {
            w.append(&mut s.next_local);
        }
        w
    } else {
        shared.drain()
    }
}

/// Upper bound on any color the engine can produce, for sizing the
/// forbidden arrays: vertex-based first-fit stays ≤ the max two-hop
/// degree; net-based stays < the max net degree; B1 can add one.
fn color_cap(g: &Bipartite) -> usize {
    let max2hop = (0..g.n_vertices()).map(|u| g.two_hop_bound(u)).max().unwrap_or(0);
    max2hop.max(g.net_vtxs.max_deg()) + 4
}

/// Run a full BGPC coloring with driver `d`.
pub fn run<D: Driver>(
    g: &Bipartite,
    order: &[u32],
    spec: &AlgSpec,
    bal: Balance,
    d: &mut D,
) -> ColoringResult {
    let n = g.n_vertices();
    let t0 = std::time::Instant::now();
    let colors = d.new_colors(n);
    let mut ts = ThreadState::bank(d.threads(), color_cap(g));
    let shared = SharedQueue::with_capacity(n);
    let mut w: Vec<u32> = order.to_vec();
    let mut trace = RunTrace::default();
    let mut sim_secs = 0.0f64;
    let mut work_units = 0u64;
    let mut iterations = 0usize;

    while !w.is_empty() && iterations < MAX_ITERS {
        iterations += 1;
        let net_color = iterations <= spec.net_color_iters;
        let net_conflict = iterations <= spec.net_conflict_iters;
        let mut it = IterTrace {
            queue_len: w.len(),
            color_kind: if net_color { 'N' } else { 'V' },
            conflict_kind: if net_conflict { 'N' } else { 'V' },
            ..Default::default()
        };

        // --- coloring phase (Alg. 4 / 6 / 8) ---
        let cr = if net_color {
            net::color_phase(g, &colors, d, &mut ts, spec.chunk, spec.net_alg, bal)
        } else {
            vertex::color_phase(g, &w, &colors, d, &mut ts, spec.chunk, bal)
        };
        it.color_secs = cr.seconds();
        it.color_busy = cr.busy_units.clone();
        work_units += cr.busy_units.iter().sum::<u64>();

        // --- conflict removal phase (Alg. 5 / 7) ---
        let (rr, w_next) = if net_conflict {
            let r1 = net::conflict_phase(g, &colors, d, &mut ts, spec.chunk);
            let r2 = net::rebuild_queue(
                n,
                &colors,
                d,
                &mut ts,
                spec.chunk,
                spec.lazy_queues,
                &shared,
            );
            let wn = collect_next(spec.lazy_queues, &mut ts, &shared);
            let combined = crate::par::RegionOut {
                real_secs: r1.real_secs + r2.real_secs,
                sim_ns: match (r1.sim_ns, r2.sim_ns) {
                    (Some(a), Some(b)) => Some(a + b),
                    _ => None,
                },
                busy_units: Vec::new(),
            };
            work_units += r1.busy_units.iter().sum::<u64>()
                + r2.busy_units.iter().sum::<u64>();
            (combined, wn)
        } else {
            let r = vertex::conflict_phase(
                g,
                &w,
                &colors,
                d,
                &mut ts,
                spec.chunk,
                spec.lazy_queues,
                &shared,
            );
            work_units += r.busy_units.iter().sum::<u64>();
            let wn = collect_next(spec.lazy_queues, &mut ts, &shared);
            (r, wn)
        };
        it.conflict_secs = rr.seconds();
        sim_secs += it.color_secs + it.conflict_secs;
        trace.iters.push(it);
        w = w_next;
    }

    if !w.is_empty() {
        // safety net: finish sequentially (exact greedy over what's left)
        let ts0 = &mut ts[0];
        let now = d.now();
        for &wv in &w {
            let wv = wv as usize;
            ts0.forbidden.next_gen();
            for &v in g.nets(wv) {
                for &u in g.vtxs(v as usize) {
                    let u = u as usize;
                    if u != wv {
                        let c = colors.read(u, now);
                        if c >= 0 {
                            ts0.forbidden.insert(c);
                        }
                    }
                }
            }
            let (c, _) = ts0.forbidden.first_fit();
            colors.write(wv, c, now);
        }
    }

    let colors_vec = colors.to_vec();
    let n_colors = crate::coloring::stats::distinct_colors(&colors_vec);
    let is_sim = trace.iters.first().map(|i| i.color_busy.len() > 0).unwrap_or(false);
    ColoringResult {
        colors: colors_vec,
        n_colors,
        iterations,
        seconds: if is_sim { sim_secs } else { t0.elapsed().as_secs_f64() },
        trace,
        work_units,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::schedule;
    use crate::coloring::verify::bgpc_valid;
    use crate::graph::generators::{random_bipartite, Preset};
    use crate::par::ThreadsDriver;
    use crate::sim::{CostModel, SimDriver};

    fn check_all_specs(g: &Bipartite, t: usize) {
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        for spec in schedule::ALL {
            // real threads
            let mut d = ThreadsDriver::new(t);
            let r = run(g, &order, &spec, Balance::None, &mut d);
            assert!(
                bgpc_valid(g, &r.colors).is_ok(),
                "{} threads={} invalid",
                spec.name,
                t
            );
            // simulator
            let mut d = SimDriver::new(t, CostModel::default());
            let r = run(g, &order, &spec, Balance::None, &mut d);
            assert!(
                bgpc_valid(g, &r.colors).is_ok(),
                "{} sim t={} invalid",
                spec.name,
                t
            );
            assert!(r.seconds > 0.0);
            assert!(r.n_colors > 0);
        }
    }

    #[test]
    fn all_schedules_produce_valid_colorings() {
        let g = random_bipartite(300, 400, 3000, 11);
        check_all_specs(&g, 1);
        check_all_specs(&g, 4);
    }

    #[test]
    fn all_schedules_valid_on_skewed_preset() {
        let g = Preset::by_name("coPapersDBLP").unwrap().bipartite(0.02, 3);
        check_all_specs(&g, 8);
    }

    #[test]
    fn balancing_preserves_validity() {
        let g = random_bipartite(200, 300, 2500, 13);
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        for bal in [Balance::B1, Balance::B2] {
            for spec in [schedule::V_N2, schedule::N1_N2] {
                let mut d = SimDriver::new(8, CostModel::default());
                let r = run(&g, &order, &spec, bal, &mut d);
                assert!(
                    bgpc_valid(&g, &r.colors).is_ok(),
                    "{:?} {} invalid",
                    bal,
                    spec.name
                );
            }
        }
    }

    #[test]
    fn simulator_runs_are_deterministic() {
        let g = random_bipartite(150, 200, 1500, 17);
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        let run_once = || {
            let mut d = SimDriver::new(4, CostModel::default());
            run(&g, &order, &schedule::N1_N2, Balance::None, &mut d)
        };
        let a = run_once();
        let b = run_once();
        assert_eq!(a.colors, b.colors);
        assert_eq!(a.seconds, b.seconds);
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn net_first_iteration_leaves_work_for_iter_two() {
        // Under the simulator with several threads, Alg. 8's optimism must
        // leave *some* conflicts on a shared-heavy graph (Table I behaviour).
        let g = Preset::by_name("coPapersDBLP").unwrap().bipartite(0.02, 5);
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        let mut d = SimDriver::new(16, CostModel::default());
        let r = run(&g, &order, &schedule::N1_N2, Balance::None, &mut d);
        assert!(r.iterations >= 2, "expected speculative conflicts");
    }

    #[test]
    fn empty_and_degenerate_graphs() {
        let g = random_bipartite(10, 20, 0, 1); // no edges at all
        let order: Vec<u32> = (0..20u32).collect();
        let mut d = ThreadsDriver::new(2);
        let r = run(&g, &order, &schedule::V_V, Balance::None, &mut d);
        assert!(bgpc_valid(&g, &r.colors).is_ok());
        assert_eq!(r.n_colors, 1, "independent vertices all take color 0");
    }
}
