//! Vertex-based BGPC phases — Algorithms 4 and 5 (ColPack's approach).
//!
//! Coloring traverses `w → nets(w) → vtxs(v)` to build the forbidden set
//! (first-iteration cost `Θ(Σ_v |vtxs(v)|²)`, the paper's §III analysis);
//! conflict removal does the same walk with early termination and the
//! `w > u` tie-break, pushing losers to the next-iteration queue
//! (shared+atomic for `V-V`/`V-V-64`, lazy per-thread for the `D`
//! variants).

use crate::coloring::balance::{select_color, Balance};
use crate::coloring::forbidden::ThreadState;
use crate::graph::Bipartite;
use crate::par::{ColorStore, Cost, Driver, RegionOut, SharedQueue};
use crate::util::arch::PREFETCH_DIST;

/// Algorithm 4: optimistic vertex-based coloring of the work queue `w`.
pub fn color_phase<D: Driver>(
    g: &Bipartite,
    w: &[u32],
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
    bal: Balance,
) -> RegionOut {
    d.region(ts, w.len(), chunk, |_tid, s, i, now| {
        let wv = w[i] as usize;
        let mut units = 0u64;
        s.forbidden.next_gen();
        let ns = g.nets(wv);
        for (k, &v) in ns.iter().enumerate() {
            if let Some(&nv) = ns.get(k + 1) {
                // start the next net's gather before this one finishes
                g.prefetch_vtxs(nv as usize);
            }
            let vt = g.vtxs(v as usize);
            for (j, &u) in vt.iter().enumerate() {
                if let Some(&fu) = vt.get(j + PREFETCH_DIST) {
                    colors.prefetch(fu as usize);
                }
                units += 1;
                let u = u as usize;
                if u != wv {
                    // branch-free: -1 lands in the trash slot (§Perf)
                    s.forbidden.mark(colors.read(u, now + units));
                }
            }
        }
        let col = select_color(bal, s, wv, &mut units);
        colors.write(wv, col, now + units);
        Cost { units, atomics: 0 }
    })
}

/// Algorithm 5: vertex-based conflict detection over the work queue `w`.
/// Conflicting vertices (the larger id of each clash) are pushed to the
/// next queue; their color stays until they are recolored next iteration.
pub fn conflict_phase<D: Driver>(
    g: &Bipartite,
    w: &[u32],
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
    lazy: bool,
    shared: &SharedQueue,
) -> RegionOut {
    d.region(ts, w.len(), chunk, |_tid, s, i, now| {
        let wv = w[i] as usize;
        let cw = colors.read(wv, now);
        let mut units = 1u64;
        let mut atomics = 0u32;
        'outer: for &v in g.nets(wv) {
            for &u in g.vtxs(v as usize) {
                units += 1;
                let u = u as usize;
                if u != wv && wv > u && colors.read(u, now + units) == cw {
                    if lazy {
                        s.next_local.push(wv as u32);
                    } else {
                        shared.push(wv as u32);
                        atomics += 1;
                    }
                    break 'outer;
                }
            }
        }
        Cost { units, atomics }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::random_bipartite;
    use crate::par::ThreadsDriver;

    #[test]
    fn single_thread_coloring_is_conflict_free() {
        // Sequential execution sees every prior write: one pass must be a
        // valid coloring (no conflict phase needed).
        let g = random_bipartite(60, 100, 500, 5);
        let mut d = ThreadsDriver::new(1);
        let colors = d.new_colors(g.n_vertices());
        let mut ts = ThreadState::bank(1, 512);
        let w: Vec<u32> = (0..g.n_vertices() as u32).collect();
        color_phase(&g, &w, &colors, &mut d, &mut ts, 64, Balance::None);
        let c = colors.to_vec();
        assert!(c.iter().all(|&x| x >= 0));
        assert!(crate::coloring::verify::bgpc_valid(&g, &c).is_ok());
    }

    #[test]
    fn conflict_phase_flags_planted_conflicts() {
        // two vertices in one net share a color -> the larger id is pushed
        let g = random_bipartite(1, 4, 0, 0); // empty; build manually below
        let _ = g;
        let m = crate::graph::Csr::from_edges(1, 3, &[(0, 0), (0, 1), (0, 2)]);
        let g = Bipartite::from_net_incidence(m);
        let mut d = ThreadsDriver::new(1);
        let colors = d.new_colors(3);
        colors.write(0, 0, 0);
        colors.write(1, 0, 0); // clash with 0
        colors.write(2, 1, 0);
        let mut ts = ThreadState::bank(1, 8);
        let shared = SharedQueue::with_capacity(3);
        let w: Vec<u32> = vec![0, 1, 2];
        conflict_phase(&g, &w, &colors, &mut d, &mut ts, 64, false, &shared);
        let mut next = shared.drain();
        next.sort_unstable();
        assert_eq!(next, vec![1], "only the larger id of the clash");
    }

    #[test]
    fn lazy_queues_collect_privately() {
        let m = crate::graph::Csr::from_edges(1, 4, &[(0, 0), (0, 1), (0, 2), (0, 3)]);
        let g = Bipartite::from_net_incidence(m);
        let mut d = ThreadsDriver::new(2);
        let colors = d.new_colors(4);
        for u in 0..4 {
            colors.write(u, 0, 0); // all clash
        }
        let mut ts = ThreadState::bank(2, 8);
        let shared = SharedQueue::with_capacity(4);
        let w: Vec<u32> = vec![0, 1, 2, 3];
        conflict_phase(&g, &w, &colors, &mut d, &mut ts, 1, true, &shared);
        assert!(shared.is_empty());
        let mut all: Vec<u32> =
            ts.iter_mut().flat_map(|s| s.next_local.drain(..)).collect();
        all.sort_unstable();
        assert_eq!(all, vec![1, 2, 3], "vertex 0 wins the tie-break");
    }
}
