//! Net-based BGPC phases — Algorithms 6, 7, 8 (the paper's contribution).
//!
//! Net-based phases iterate the *nets*; each iteration is linear in the
//! graph size instead of `Θ(Σ|vtxs|²)`. Coloring comes in three levels of
//! optimism (Table I):
//!
//! * [`NetColorAlg::V1`] — Algorithm 6: inline first-fit recoloring, the
//!   "most optimistic" variant ("maleficent" in the paper's words);
//! * [`NetColorAlg::V1Reverse`] — the same with the reverse policy;
//! * [`NetColorAlg::TwoPass`] — Algorithm 8: a marking pass over the
//!   adjacency, then reverse first-fit over the local queue `W_local` —
//!   colors stay below `|vtxs(v)|`, which is itself a lower bound on the
//!   optimal, so the color count barely grows.
//!
//! Conflict removal (Algorithm 7) keeps each color's first occurrence per
//! net and uncolors later duplicates.

use crate::coloring::balance::Balance;
use crate::coloring::forbidden::ThreadState;
use crate::coloring::schedule::NetColorAlg;
use crate::graph::Bipartite;
use crate::par::{ColorStore, Cost, Driver, RegionOut, SharedQueue};
use crate::util::arch::PREFETCH_DIST;

/// Net-based coloring phase over all nets.
pub fn color_phase<D: Driver>(
    g: &Bipartite,
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
    alg: NetColorAlg,
    bal: Balance,
) -> RegionOut {
    match alg {
        NetColorAlg::TwoPass => two_pass_phase(g, colors, d, ts, chunk, bal),
        NetColorAlg::V1 => v1_phase(g, colors, d, ts, chunk, false),
        NetColorAlg::V1Reverse => v1_phase(g, colors, d, ts, chunk, true),
    }
}

/// Algorithm 8 (plus the paper's "net-based variants are similar" B1/B2
/// adaptations — see [`assign_local`]).
fn two_pass_phase<D: Driver>(
    g: &Bipartite,
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
    bal: Balance,
) -> RegionOut {
    d.region(ts, g.n_nets(), chunk, |_tid, s, v, now| {
        let vt = g.vtxs(v);
        let mut units = 0u64;
        s.forbidden.next_gen();
        s.wlocal.clear();
        // pass 1: mark forbidden colors, queue the rest (Alg. 8 lines 4-8)
        for (j, &u) in vt.iter().enumerate() {
            if let Some(&fu) = vt.get(j + PREFETCH_DIST) {
                colors.prefetch(fu as usize);
            }
            units += 1;
            let c = colors.read(u as usize, now + units);
            if c >= 0 && !s.forbidden.contains(c) {
                s.forbidden.insert(c);
            } else {
                s.wlocal.push(u);
            }
        }
        // pass 2: color W_local (Alg. 8 lines 9-14 / B1 / B2)
        units += assign_local(s, v, vt.len(), bal, colors, now, units);
        Cost { units, atomics: 0 }
    })
}

/// Color the thread-local queue of net `v` (degree `deg`). Returns probe
/// cost. Assigned colors are inserted into `F` so every policy —
/// including the non-monotonic B1/B2 scans — yields distinct colors
/// within the net.
fn assign_local<C: ColorStore>(
    s: &mut ThreadState,
    v: usize,
    deg: usize,
    bal: Balance,
    colors: &C,
    now: u64,
    base_units: u64,
) -> u64 {
    let mut probes = 0u64;
    // Move the queue out to appease the borrow checker; swapped back below.
    let wlocal = std::mem::take(&mut s.wlocal);
    match bal {
        Balance::None => {
            // reverse first-fit from |vtxs(v)| - 1 (Alg. 8)
            let mut col = deg as i32 - 1;
            for &u in &wlocal {
                let (found, p) = s.forbidden.reverse_fit(col);
                probes += p;
                let c = match found {
                    Some(c) => c,
                    None => {
                        // unreachable per the paper's counting argument;
                        // kept as a safety net for adversarial stores.
                        debug_assert!(false, "reverse first-fit exhausted");
                        let (c, p2) = s.forbidden.first_fit_from(deg as i32);
                        probes += p2;
                        c
                    }
                };
                s.forbidden.insert(c);
                colors.write(u as usize, c, now + base_units + probes);
                s.col_max = s.col_max.max(c);
                col = c - 1;
            }
        }
        Balance::B1 => {
            if v % 2 == 0 {
                // even net: spread down from the thread's col_max
                let mut col = s.col_max.max(deg as i32 - 1);
                for &u in &wlocal {
                    let (found, p) = s.forbidden.reverse_fit(col);
                    probes += p;
                    let c = match found {
                        Some(c) => c,
                        None => {
                            let (c, p2) = s.forbidden.first_fit_from(s.col_max + 1);
                            probes += p2;
                            c
                        }
                    };
                    s.forbidden.insert(c);
                    colors.write(u as usize, c, now + base_units + probes);
                    s.col_max = s.col_max.max(c);
                    col = c - 1;
                }
            } else {
                // odd net: plain ascending first-fit
                for &u in &wlocal {
                    let (c, p) = s.forbidden.first_fit();
                    probes += p;
                    s.forbidden.insert(c);
                    colors.write(u as usize, c, now + base_units + probes);
                    s.col_max = s.col_max.max(c);
                }
            }
        }
        Balance::B2 => {
            for &u in &wlocal {
                let (mut c, p) = s.forbidden.first_fit_from(s.col_next);
                probes += p;
                if c > s.col_max {
                    let (c0, p0) = s.forbidden.first_fit();
                    probes += p0;
                    c = c0;
                }
                s.forbidden.insert(c);
                colors.write(u as usize, c, now + base_units + probes);
                s.col_max = s.col_max.max(c);
                s.col_next = (c + 1).min(s.col_max / 3 + 1);
            }
        }
    }
    s.wlocal = wlocal;
    probes
}

/// Algorithm 6 (`V1`) and its reverse variant: inline recoloring during a
/// single pass over the adjacency.
fn v1_phase<D: Driver>(
    g: &Bipartite,
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
    reverse: bool,
) -> RegionOut {
    d.region(ts, g.n_nets(), chunk, |_tid, s, v, now| {
        let vt = g.vtxs(v);
        let mut units = 0u64;
        s.forbidden.next_gen();
        let mut col: i32 = if reverse { vt.len() as i32 - 1 } else { 0 };
        for &u in vt {
            units += 1;
            let u = u as usize;
            let c = colors.read(u, now + units);
            if c < 0 || s.forbidden.contains(c) {
                // recolor u now (lines 6-8 of Alg. 6)
                if reverse {
                    let (found, p) = s.forbidden.reverse_fit(col);
                    units += p;
                    let cc = match found {
                        Some(cc) => cc,
                        None => {
                            let (cc, p2) = s.forbidden.first_fit_from(vt.len() as i32);
                            units += p2;
                            cc
                        }
                    };
                    colors.write(u, cc, now + units);
                    s.forbidden.insert(cc);
                    col = cc - 1;
                } else {
                    let (cc, p) = s.forbidden.first_fit_from(col);
                    units += p;
                    colors.write(u, cc, now + units);
                    s.forbidden.insert(cc);
                    col = cc; // next search resumes here
                }
            } else {
                s.forbidden.insert(c);
            }
        }
        Cost { units, atomics: 0 }
    })
}

/// Algorithm 7: net-based conflict removal — keep the first occurrence of
/// each color per net, uncolor later duplicates.
pub fn conflict_phase<D: Driver>(
    g: &Bipartite,
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
) -> RegionOut {
    d.region(ts, g.n_nets(), chunk, |_tid, s, v, now| {
        conflict_one_net(g, v, colors, s, now)
    })
}

/// Algorithm 7 restricted to an explicit net subset — the dynamic
/// subsystem's dirty-net detection: after a batch of edge insertions,
/// only nets whose member lists changed can hold a stale duplicate, so
/// scanning just those repairs the coloring at the cost of the batch
/// footprint instead of `O(|E|)`.
pub fn conflict_phase_on<D: Driver>(
    g: &Bipartite,
    nets: &[u32],
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
) -> RegionOut {
    d.region(ts, nets.len(), chunk, |_tid, s, i, now| {
        conflict_one_net(g, nets[i] as usize, colors, s, now)
    })
}

/// Shared body of the two conflict-removal drivers: scan net `v`, keep
/// each color's first occurrence, uncolor later duplicates.
#[inline]
fn conflict_one_net<C: ColorStore>(
    g: &Bipartite,
    v: usize,
    colors: &C,
    s: &mut ThreadState,
    now: u64,
) -> Cost {
    let mut units = 0u64;
    s.forbidden.next_gen();
    for &u in g.vtxs(v) {
        units += 1;
        let u = u as usize;
        let c = colors.read(u, now + units);
        if c >= 0 {
            if s.forbidden.contains(c) {
                colors.write(u, -1, now + units);
            } else {
                s.forbidden.insert(c);
            }
        }
    }
    Cost::new(units)
}

/// Rebuild the work queue after net-based conflict removal: gather every
/// still-uncolored vertex (net removal leaves no other trace of who lost).
pub fn rebuild_queue<D: Driver>(
    n_vertices: usize,
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
    lazy: bool,
    shared: &SharedQueue,
) -> RegionOut {
    d.region(ts, n_vertices, chunk, |_tid, s, u, now| {
        let mut atomics = 0u32;
        if colors.read(u, now) == -1 {
            if lazy {
                s.next_local.push(u as u32);
            } else {
                shared.push(u as u32);
                atomics = 1;
            }
        }
        Cost { units: 1, atomics }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;
    use crate::par::ThreadsDriver;

    fn star_net(deg: usize) -> Bipartite {
        let edges: Vec<(u32, u32)> = (0..deg as u32).map(|u| (0, u)).collect();
        Bipartite::from_net_incidence(Csr::from_edges(1, deg, &edges))
    }

    #[test]
    fn two_pass_colors_one_net_within_degree() {
        let g = star_net(6);
        let mut d = ThreadsDriver::new(1);
        let colors = d.new_colors(6);
        let mut ts = ThreadState::bank(1, 16);
        color_phase(&g, &colors, &mut d, &mut ts, 64, NetColorAlg::TwoPass, Balance::None);
        let c = colors.to_vec();
        assert!(c.iter().all(|&x| (0..6).contains(&x)), "{c:?}");
        let mut sorted = c.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 6, "all distinct within the net: {c:?}");
        // reverse first-fit on an all-uncolored net: 5,4,3,2,1,0
        assert_eq!(c, vec![5, 4, 3, 2, 1, 0]);
    }

    #[test]
    fn two_pass_respects_kept_colors() {
        let g = star_net(4);
        let mut d = ThreadsDriver::new(1);
        let colors = d.new_colors(4);
        colors.write(1, 3, 0); // pre-colored, kept
        colors.write(2, 3, 0); // duplicate: must be requeued + recolored
        let mut ts = ThreadState::bank(1, 16);
        color_phase(&g, &colors, &mut d, &mut ts, 64, NetColorAlg::TwoPass, Balance::None);
        let c = colors.to_vec();
        assert_eq!(c[1], 3, "first occurrence kept");
        assert_ne!(c[2], 3, "duplicate recolored");
        let mut sorted = c.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4);
    }

    #[test]
    fn v1_first_fit_uses_small_colors() {
        let g = star_net(5);
        let mut d = ThreadsDriver::new(1);
        let colors = d.new_colors(5);
        let mut ts = ThreadState::bank(1, 16);
        color_phase(&g, &colors, &mut d, &mut ts, 64, NetColorAlg::V1, Balance::None);
        assert_eq!(colors.to_vec(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn v1_reverse_uses_large_colors() {
        let g = star_net(5);
        let mut d = ThreadsDriver::new(1);
        let colors = d.new_colors(5);
        let mut ts = ThreadState::bank(1, 16);
        color_phase(&g, &colors, &mut d, &mut ts, 64, NetColorAlg::V1Reverse, Balance::None);
        assert_eq!(colors.to_vec(), vec![4, 3, 2, 1, 0]);
    }

    #[test]
    fn conflict_removal_keeps_first_uncolors_rest() {
        let g = star_net(4);
        let mut d = ThreadsDriver::new(1);
        let colors = d.new_colors(4);
        colors.write(0, 2, 0);
        colors.write(1, 2, 0);
        colors.write(2, 1, 0);
        colors.write(3, 2, 0);
        let mut ts = ThreadState::bank(1, 16);
        conflict_phase(&g, &colors, &mut d, &mut ts, 64);
        assert_eq!(colors.to_vec(), vec![2, -1, 1, -1]);
    }

    #[test]
    fn rebuild_queue_finds_uncolored() {
        let mut d = ThreadsDriver::new(1);
        let colors = d.new_colors(5);
        colors.write(0, 1, 0);
        colors.write(2, 0, 0);
        colors.write(4, 2, 0);
        let mut ts = ThreadState::bank(1, 4);
        let shared = SharedQueue::with_capacity(5);
        rebuild_queue(5, &colors, &mut d, &mut ts, 64, false, &shared);
        let mut q = shared.drain();
        q.sort_unstable();
        assert_eq!(q, vec![1, 3]);
    }

    #[test]
    fn b2_balance_still_valid_per_net() {
        let g = star_net(8);
        let mut d = ThreadsDriver::new(1);
        let colors = d.new_colors(8);
        let mut ts = ThreadState::bank(1, 32);
        ts[0].col_max = 7;
        color_phase(&g, &colors, &mut d, &mut ts, 64, NetColorAlg::TwoPass, Balance::B2);
        let mut c = colors.to_vec();
        assert!(c.iter().all(|&x| x >= 0));
        c.sort_unstable();
        c.dedup();
        assert_eq!(c.len(), 8, "distinct within the net");
    }
}
