//! Distance-1 greedy coloring — the survey baseline (§VII), promoted to
//! engine parity with BGPC/D2GC: the same speculate → detect → repeat
//! loop ([`run`] / [`run_capped`]), the dirty-frontier detection pass
//! the dynamic subsystem needs ([`conflict_phase_on`]), and the exact
//! sequential safety net ([`sequential_finish`]). The neighborhood is
//! the plain adjacency row — every phase is the D2GC one with the
//! distance-2 inner loop removed — so D1GC rides the problem-generic
//! repair engine and the coordinator unchanged (DESIGN.md §14).

use crate::coloring::balance::{select_color, Balance};
use crate::coloring::bgpc::MAX_ITERS;
use crate::coloring::forbidden::{StampSet, ThreadState};
use crate::coloring::schedule::AlgSpec;
use crate::coloring::ColoringResult;
use crate::graph::Csr;
use crate::par::{ColorStore, Cost, Driver, RegionOut, SharedQueue};
use crate::sim::trace::{IterTrace, RunTrace};
use crate::util::arch::PREFETCH_DIST;

/// Sequential greedy D1GC in `order`. Returns `(colors, work_units)`.
pub fn seq_greedy(g: &Csr, order: &[u32]) -> (Vec<i32>, u64) {
    let mut colors = vec![-1i32; g.n_rows];
    let mut f = StampSet::new(256);
    let mut units = 0u64;
    for &w in order {
        let w = w as usize;
        f.next_gen();
        for &u in g.row(w) {
            units += 1;
            let u = u as usize;
            if u != w && colors[u] >= 0 {
                f.insert(colors[u]);
            }
        }
        let (c, probes) = f.first_fit();
        units += probes;
        colors[w] = c;
    }
    (colors, units)
}

/// Upper bound on any color the D1GC engine can produce (forbidden-array
/// sizing): first-fit never exceeds the degree. Public because the
/// dynamic subsystem sizes persistent [`ThreadState`] banks with it.
pub fn color_cap(g: &Csr) -> usize {
    g.max_deg() + 4
}

/// Optimistic vertex-based D1GC coloring of the work queue `w` — the
/// D2GC speculate phase without the distance-2 inner loop.
pub fn color_phase<D: Driver>(
    g: &Csr,
    w: &[u32],
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
    bal: Balance,
) -> RegionOut {
    d.region(ts, w.len(), chunk, |_tid, s, i, now| {
        let wv = w[i] as usize;
        let mut units = 0u64;
        s.forbidden.next_gen();
        let row = g.row(wv);
        for (j, &u) in row.iter().enumerate() {
            if let Some(&fu) = row.get(j + PREFETCH_DIST) {
                colors.prefetch(fu as usize);
            }
            units += 1;
            let u = u as usize;
            if u != wv {
                // branch-free: -1 lands in the trash slot (§Perf)
                s.forbidden.mark(colors.read(u, now + units));
            }
        }
        let col = select_color(bal, s, wv, &mut units);
        colors.write(wv, col, now + units);
        Cost { units, atomics: 0 }
    })
}

/// Vertex-based D1GC conflict detection with the `w > u` tie-break:
/// the larger id of each clash is requeued, its color kept until it is
/// recolored next iteration.
pub fn conflict_phase<D: Driver>(
    g: &Csr,
    w: &[u32],
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
    lazy: bool,
    shared: &SharedQueue,
) -> RegionOut {
    d.region(ts, w.len(), chunk, |_tid, s, i, now| {
        let wv = w[i] as usize;
        let cw = colors.read(wv, now);
        let mut units = 1u64;
        let mut atomics = 0u32;
        for &u in g.row(wv) {
            units += 1;
            let u = u as usize;
            if u != wv && wv > u && colors.read(u, now + units) == cw {
                if lazy {
                    s.next_local.push(wv as u32);
                } else {
                    shared.push(wv as u32);
                    atomics += 1;
                }
                break;
            }
        }
        Cost { units, atomics }
    })
}

/// Conflict removal restricted to an explicit row subset — the dynamic
/// subsystem's dirty-frontier detection. Every new distance-1 clash
/// runs through an inserted edge `(a, b)` and both endpoints are
/// insertion-dirty, so scanning each dirty row `v` and uncoloring
/// same-colored neighbors removes every clash the batch could have
/// created at the cost of the batch's footprint (DESIGN.md §14).
pub fn conflict_phase_on<D: Driver>(
    g: &Csr,
    rows: &[u32],
    colors: &D::Colors,
    d: &mut D,
    ts: &mut [ThreadState],
    chunk: usize,
) -> RegionOut {
    d.region(ts, rows.len(), chunk, |_tid, _s, i, now| {
        let v = rows[i] as usize;
        let mut units = 1u64;
        let cv = colors.read(v, now);
        if cv >= 0 {
            for &u in g.row(v) {
                let u = u as usize;
                if u == v {
                    continue;
                }
                units += 1;
                if colors.read(u, now + units) == cv {
                    // the visited row's color is kept; the neighbor loses
                    colors.write(u, -1, now + units);
                }
            }
        }
        Cost::new(units)
    })
}

/// The `MAX_ITERS` safety net: exact sequential greedy over the
/// remaining queue, reading and writing through the color store at time
/// `now`. With the whole queue this is the `cap = 0` baseline that must
/// reproduce [`seq_greedy`] bit-for-bit.
pub fn sequential_finish<C: ColorStore>(
    g: &Csr,
    w: &[u32],
    colors: &C,
    ts0: &mut ThreadState,
    now: u64,
) {
    for &wv in w {
        let wv = wv as usize;
        ts0.forbidden.next_gen();
        for &u in g.row(wv) {
            let u = u as usize;
            if u != wv {
                let c = colors.read(u, now);
                if c >= 0 {
                    ts0.forbidden.insert(c);
                }
            }
        }
        let (c, _) = ts0.forbidden.first_fit();
        colors.write(wv, c, now);
    }
}

/// Run a full D1GC coloring with driver `d` (same loop as BGPC/D2GC).
pub fn run<D: Driver>(
    g: &Csr,
    order: &[u32],
    spec: &AlgSpec,
    bal: Balance,
    d: &mut D,
) -> ColoringResult {
    let mut ts = ThreadState::bank(d.threads(), color_cap(g));
    run_capped(g, order, spec, bal, d, &mut ts, MAX_ITERS)
}

/// [`run`] with an explicit iteration cap and a caller-owned
/// [`ThreadState`] bank — the D1GC mirror of
/// [`crate::coloring::bgpc::run_capped`]. D1GC has no net-based phase
/// (its "net" *is* the adjacency row), so every iteration runs the
/// vertex phases; the schedule still supplies chunking and the
/// lazy-queue option.
pub fn run_capped<D: Driver>(
    g: &Csr,
    order: &[u32],
    spec: &AlgSpec,
    bal: Balance,
    d: &mut D,
    ts: &mut [ThreadState],
    max_iters: usize,
) -> ColoringResult {
    let n = g.n_rows;
    let t0 = std::time::Instant::now();
    let colors = d.new_colors(n);
    let cap = color_cap(g);
    for s in ts.iter_mut() {
        s.forbidden.ensure(cap);
    }
    let shared = SharedQueue::with_capacity(n);
    // Auto chunks tune per phase (see bgpc::run_capped); fixed/static
    // specs pass through untouched.
    let color_chunk = crate::par::Chunk::resite(spec.chunk, crate::par::autosite::SPECULATE);
    let detect_chunk = crate::par::Chunk::resite(spec.chunk, crate::par::autosite::DETECT);
    let mut w: Vec<u32> = order.to_vec();
    let mut trace = RunTrace::default();
    let mut sim_secs = 0.0f64;
    let mut work_units = 0u64;
    let mut iterations = 0usize;
    let mut is_sim = false;

    while !w.is_empty() && iterations < max_iters {
        iterations += 1;
        let mut it = IterTrace {
            queue_len: w.len(),
            color_kind: 'V',
            conflict_kind: 'V',
            ..Default::default()
        };

        let cr = {
            let _sp = crate::obs::trace::span_n("d1gc.speculate", w.len() as u64);
            color_phase(g, &w, &colors, d, ts, color_chunk, bal)
        };
        it.color_secs = cr.seconds();
        it.color_busy = cr.busy_units.clone();
        work_units += cr.busy_units.iter().sum::<u64>();
        is_sim = cr.sim_ns.is_some();

        let (rr, w_next) = {
            let _sp = crate::obs::trace::span_n("d1gc.detect", w.len() as u64);
            let r = conflict_phase(g, &w, &colors, d, ts, detect_chunk, spec.lazy_queues, &shared);
            work_units += r.busy_units.iter().sum::<u64>();
            let wn = crate::coloring::bgpc::collect_next(spec.lazy_queues, ts, &shared);
            (r, wn)
        };
        it.conflict_secs = rr.seconds();
        sim_secs += it.color_secs + it.conflict_secs;
        trace.iters.push(it);
        w = w_next;
    }

    if !w.is_empty() {
        // safety net: finish sequentially (exact greedy over what's left)
        let _sp = crate::obs::trace::span_n("d1gc.seq_finish", w.len() as u64);
        sequential_finish(g, &w, &colors, &mut ts[0], d.now());
    }

    let colors_vec = colors.to_vec();
    let n_colors = crate::coloring::stats::distinct_colors(&colors_vec);
    ColoringResult {
        colors: colors_vec,
        n_colors,
        iterations,
        seconds: if is_sim { sim_secs } else { t0.elapsed().as_secs_f64() },
        trace,
        work_units,
    }
}

/// Parallel optimistic D1GC in natural order (back-compat shim over
/// [`run`]). Returns `(colors, iterations)`.
pub fn parallel<D: Driver>(g: &Csr, d: &mut D, chunk: usize) -> (Vec<i32>, usize) {
    let order: Vec<u32> = (0..g.n_rows as u32).collect();
    let spec = AlgSpec {
        name: "V-V",
        net_color_iters: 0,
        net_conflict_iters: 0,
        chunk,
        lazy_queues: false,
        net_alg: crate::coloring::schedule::NetColorAlg::TwoPass,
    };
    let r = run(g, &order, &spec, Balance::None, d);
    (r.colors, r.iterations)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::schedule;
    use crate::coloring::verify::d1gc_valid;
    use crate::graph::generators::random_symmetric;
    use crate::par::ThreadsDriver;
    use crate::sim::{CostModel, SimDriver};

    #[test]
    fn seq_valid_and_bounded() {
        let g = random_symmetric(300, 1500, 2);
        let order: Vec<u32> = (0..300u32).collect();
        let (c, _) = seq_greedy(&g, &order);
        assert!(d1gc_valid(&g, &c).is_ok());
        let n_colors = crate::coloring::stats::distinct_colors(&c);
        assert!(n_colors <= g.max_deg() + 1, "greedy bound Δ+1");
    }

    #[test]
    fn parallel_valid_under_threads_and_sim() {
        let g = random_symmetric(300, 1500, 4);
        let (c, _) = parallel(&g, &mut ThreadsDriver::new(4), 64);
        assert!(d1gc_valid(&g, &c).is_ok());
        let (c, _) = parallel(&g, &mut SimDriver::new(8, CostModel::default()), 64);
        assert!(d1gc_valid(&g, &c).is_ok());
    }

    #[test]
    fn run_capped_valid_across_schedules() {
        let g = random_symmetric(250, 1200, 9);
        let order: Vec<u32> = (0..250u32).collect();
        for spec in [schedule::V_V, schedule::V_V_64, schedule::V_V_64D] {
            let mut d = ThreadsDriver::new(4);
            let r = run(&g, &order, &spec, Balance::None, &mut d);
            assert!(d1gc_valid(&g, &r.colors).is_ok(), "{} threads", spec.name);
            let mut d = SimDriver::new(8, CostModel::default());
            let r = run(&g, &order, &spec, Balance::None, &mut d);
            assert!(d1gc_valid(&g, &r.colors).is_ok(), "{} sim", spec.name);
        }
    }

    #[test]
    fn max_iters_zero_fallback_is_exact_sequential_greedy() {
        // cap = 0 routes the whole queue through sequential_finish, which
        // must reproduce seq_greedy bit-for-bit (the invariant BGPC and
        // D2GC also hold — the dynamic engine's last line of defense).
        let g = random_symmetric(200, 900, 13);
        let order: Vec<u32> = (0..200u32).collect();
        let (seq, _) = seq_greedy(&g, &order);
        let mut d = ThreadsDriver::new(1);
        let mut ts = ThreadState::bank(1, color_cap(&g));
        let r = run_capped(&g, &order, &schedule::V_V, Balance::None, &mut d, &mut ts, 0);
        assert_eq!(r.colors, seq);
    }

    #[test]
    fn conflict_phase_on_uncolors_planted_clash() {
        // edge 0-1 with equal colors: scanning dirty row 0 keeps 0's
        // color and uncolors 1
        let g = crate::graph::Csr::from_edges(3, 3, &[(0, 1), (1, 0), (1, 2), (2, 1)]);
        let mut d = ThreadsDriver::new(1);
        let colors = d.new_colors(3);
        colors.write(0, 0, 0);
        colors.write(1, 0, 0); // clash with 0
        colors.write(2, 1, 0);
        let mut ts = ThreadState::bank(1, 8);
        conflict_phase_on(&g, &[0], &colors, &mut d, &mut ts, 64);
        let c = colors.to_vec();
        assert_eq!(c, vec![0, -1, 1], "neighbor loses, visited row keeps");
    }

    #[test]
    fn deterministic_sim() {
        let g = random_symmetric(150, 700, 21);
        let order: Vec<u32> = (0..150u32).collect();
        let once = || {
            let mut d = SimDriver::new(4, CostModel::default());
            run(&g, &order, &schedule::V_V_64D, Balance::None, &mut d)
        };
        assert_eq!(once().colors, once().colors);
    }
}
