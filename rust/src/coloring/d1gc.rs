//! Distance-1 greedy coloring — the survey baseline (§VII). Included for
//! library completeness: sequential greedy plus the standard optimistic
//! parallel variant (speculate / detect / repeat over adjacency).

use crate::coloring::forbidden::{StampSet, ThreadState};
use crate::graph::Csr;
use crate::par::{ColorStore, Cost, Driver, SharedQueue};

/// Sequential greedy D1GC in `order`. Returns `(colors, work_units)`.
pub fn seq_greedy(g: &Csr, order: &[u32]) -> (Vec<i32>, u64) {
    let mut colors = vec![-1i32; g.n_rows];
    let mut f = StampSet::new(256);
    let mut units = 0u64;
    for &w in order {
        let w = w as usize;
        f.next_gen();
        for &u in g.row(w) {
            units += 1;
            let u = u as usize;
            if u != w && colors[u] >= 0 {
                f.insert(colors[u]);
            }
        }
        let (c, probes) = f.first_fit();
        units += probes;
        colors[w] = c;
    }
    (colors, units)
}

/// Parallel optimistic D1GC (speculative color + conflict removal loop).
pub fn parallel<D: Driver>(g: &Csr, d: &mut D, chunk: usize) -> (Vec<i32>, usize) {
    let n = g.n_rows;
    let colors = d.new_colors(n);
    let mut ts = ThreadState::bank(d.threads(), g.max_deg() + 2);
    let shared = SharedQueue::with_capacity(n);
    let mut w: Vec<u32> = (0..n as u32).collect();
    let mut iters = 0usize;
    while !w.is_empty() && iters < 100 {
        iters += 1;
        d.region(&mut ts, w.len(), chunk, |_tid, s, i, now| {
            let wv = w[i] as usize;
            let mut units = 0u64;
            s.forbidden.next_gen();
            for &u in g.row(wv) {
                units += 1;
                let u = u as usize;
                if u != wv {
                    let c = colors.read(u, now + units);
                    if c >= 0 {
                        s.forbidden.insert(c);
                    }
                }
            }
            let (c, probes) = s.forbidden.first_fit();
            units += probes;
            colors.write(wv, c, now + units);
            Cost::new(units)
        });
        d.region(&mut ts, w.len(), chunk, |_tid, _s, i, now| {
            let wv = w[i] as usize;
            let cw = colors.read(wv, now);
            let mut units = 1u64;
            for &u in g.row(wv) {
                units += 1;
                let u = u as usize;
                if u != wv && wv > u && colors.read(u, now + units) == cw {
                    shared.push(wv as u32);
                    return Cost { units, atomics: 1 };
                }
            }
            Cost::new(units)
        });
        w = shared.drain();
    }
    // safety net
    if !w.is_empty() {
        let mut f = StampSet::new(g.max_deg() + 2);
        let now = d.now();
        for &wv in &w {
            let wv = wv as usize;
            f.next_gen();
            for &u in g.row(wv) {
                let u = u as usize;
                if u != wv {
                    let c = colors.read(u, now);
                    if c >= 0 {
                        f.insert(c);
                    }
                }
            }
            let (c, _) = f.first_fit();
            colors.write(wv, c, now);
        }
    }
    (colors.to_vec(), iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::verify::d1gc_valid;
    use crate::graph::generators::random_symmetric;
    use crate::par::ThreadsDriver;
    use crate::sim::{CostModel, SimDriver};

    #[test]
    fn seq_valid_and_bounded() {
        let g = random_symmetric(300, 1500, 2);
        let order: Vec<u32> = (0..300u32).collect();
        let (c, _) = seq_greedy(&g, &order);
        assert!(d1gc_valid(&g, &c).is_ok());
        let n_colors = crate::coloring::stats::distinct_colors(&c);
        assert!(n_colors <= g.max_deg() + 1, "greedy bound Δ+1");
    }

    #[test]
    fn parallel_valid_under_threads_and_sim() {
        let g = random_symmetric(300, 1500, 4);
        let (c, _) = parallel(&g, &mut ThreadsDriver::new(4), 64);
        assert!(d1gc_valid(&g, &c).is_ok());
        let (c, _) = parallel(&g, &mut SimDriver::new(8, CostModel::default()), 64);
        assert!(d1gc_valid(&g, &c).is_ok());
    }
}
