//! [`DynamicSession`] — a long-lived coloring that absorbs update
//! batches, generic over the coloring [`Problem`].
//!
//! The session owns the four pieces of state that make incremental
//! coloring work: the problem's delta overlay (graph of record — a
//! [`super::DeltaBipartite`] for BGPC, a [`super::DeltaSymmetric`] for
//! D2GC), the current coloring, the per-thread [`ThreadState`] bank,
//! and its execution driver. The bank and the driver are created once
//! at [`DynamicSession::start`] (or [`DynamicSession::start_on`], which
//! borrows a shared [`WorkerPool`] team) and threaded through every
//! repair: the B1/B2 balancing trackers (`col_max`, `col_next`) keep
//! spreading color mass exactly as they would in one long run —
//! streaming updates does not degrade color-set balance — and in
//! threads mode the forbidden arrays stay pinned to one persistent
//! team, so a batch costs a pool wakeup, never a thread spawn
//! (DESIGN.md §10).
//!
//! Jacobian-style clients (Çatalyürek et al., arXiv:1205.3809 motivate
//! coloring as a *recurring* cost in iterative solvers) submit the
//! sparsity pattern once, then stream nonzero gains/losses between
//! solves; Hessian-style clients do the same with symmetric patterns
//! through a D2GC session ([`D2gcSession`]). Each
//! [`DynamicSession::apply`] returns per-batch metrics.

use std::sync::Arc;

use crate::coloring::bgpc::MAX_ITERS;
use crate::coloring::forbidden::ThreadState;
use crate::coloring::verify::Violation;
use crate::coloring::{ColoringResult, Config, ExecMode, Problem as ProblemKind};
use crate::graph::{Bipartite, Csr};
use crate::par::{ThreadsDriver, WorkerPool};
use crate::sim::{CostModel, SimDriver};

use super::problem::{DeltaOps, Problem};
use super::{engine, BatchStats, UpdateBatch};

/// The session's persistent execution backend. Threads mode pins one
/// pool-backed driver for the session's lifetime, so a stream of
/// batches parks/wakes one team instead of spawning per batch (let
/// alone per region); the simulator is rebuilt per batch — it is a
/// plain struct, and a fresh virtual clock keeps per-batch timings
/// independent and deterministic.
enum SessionDriver {
    Threads(ThreadsDriver),
    Sim(CostModel),
}

/// A long-lived incremental coloring (see module docs). `P` is the
/// graph-cum-problem type: [`Bipartite`] for BGPC, a square symmetric
/// [`Csr`] for D2GC.
pub struct DynamicSession<P: Problem> {
    delta: P::Delta,
    /// The committed coloring, shared by refcount so the coordinator's
    /// epoch snapshots (DESIGN.md §12) can hand out immutable views
    /// without copying; a repair installs a fresh `Arc`, never mutates
    /// the published one.
    colors: Arc<Vec<i32>>,
    /// Per-thread scratch, persistent across batches (B1/B2 trackers).
    ts: Vec<ThreadState>,
    cfg: Config,
    driver: SessionDriver,
    batches: usize,
}

/// A BGPC streaming session (column coloring of a drifting sparse
/// pattern — Jacobians, constraint sets).
pub type BgpcSession = DynamicSession<Bipartite>;

/// A D2GC streaming session (distance-2 coloring of a drifting square
/// symmetric pattern — Hessians, evolving meshes and social graphs).
pub type D2gcSession = DynamicSession<Csr>;

/// A D1GC streaming session (distance-1 coloring of a drifting square
/// symmetric pattern — the survey baseline at full engine parity,
/// DESIGN.md §14).
pub type D1gcSession = DynamicSession<super::problem::D1Graph>;

impl<P: Problem> DynamicSession<P> {
    /// Color `g` from scratch under `cfg` and open the session around
    /// the result. Returns the session and the initial full-run result.
    ///
    /// # Panics
    /// When `g` violates the problem's structural contract
    /// ([`Problem::validate_input`] — for D2GC, a square structurally
    /// symmetric graph). The check runs before any coloring work.
    pub fn start(g: P, cfg: Config) -> (DynamicSession<P>, ColoringResult) {
        Self::start_impl(g, cfg, None)
    }

    /// [`DynamicSession::start`] on a shared [`WorkerPool`]: in threads
    /// mode the session's driver borrows the pool (team clamped to its
    /// size) instead of owning a private one — this is how the
    /// coordinator multiplexes every session onto one machine-wide
    /// team. Sim-mode configs ignore the pool.
    pub fn start_on(
        g: P,
        cfg: Config,
        pool: &Arc<WorkerPool>,
    ) -> (DynamicSession<P>, ColoringResult) {
        Self::start_impl(g, cfg, Some(pool))
    }

    fn start_impl(
        g: P,
        cfg: Config,
        pool: Option<&Arc<WorkerPool>>,
    ) -> (DynamicSession<P>, ColoringResult) {
        g.validate_input();
        let mut driver = match cfg.mode {
            ExecMode::Threads => SessionDriver::Threads(match pool {
                Some(p) => ThreadsDriver::on_team(p, cfg.threads),
                None => ThreadsDriver::new(cfg.threads),
            }),
            ExecMode::Sim(model) => SessionDriver::Sim(model),
        };
        let t = match &driver {
            SessionDriver::Threads(d) => d.threads(),
            SessionDriver::Sim(_) => cfg.threads,
        };
        let mut ts = ThreadState::bank(t, g.color_cap());
        let order = g.order(&cfg.ordering);
        let mut r = match &mut driver {
            SessionDriver::Threads(d) => {
                g.run_capped(&order, &cfg.spec, cfg.balance, d, &mut ts, MAX_ITERS)
            }
            SessionDriver::Sim(model) => {
                let mut d = SimDriver::new(cfg.threads, *model);
                g.run_capped(&order, &cfg.spec, cfg.balance, &mut d, &mut ts, MAX_ITERS)
            }
        };
        // Strategy post pass at bring-up only: batches repair, they do
        // not re-reduce — the improved coloring is the session baseline
        // (DESIGN.md §14).
        if let crate::coloring::PostPass::ColorAndFix(rounds) = cfg.post_pass {
            let base = std::mem::take(&mut r.colors);
            let (colors, secs) = match &mut driver {
                SessionDriver::Threads(d) => crate::coloring::strategy::color_and_fix(
                    &g,
                    base,
                    rounds,
                    cfg.spec.chunk,
                    d,
                    &mut ts,
                ),
                SessionDriver::Sim(model) => {
                    let mut d = SimDriver::new(cfg.threads, *model);
                    crate::coloring::strategy::color_and_fix(
                        &g,
                        base,
                        rounds,
                        cfg.spec.chunk,
                        &mut d,
                        &mut ts,
                    )
                }
            };
            r.colors = colors;
            r.n_colors = crate::coloring::stats::distinct_colors(&r.colors);
            r.seconds += secs;
        }
        let colors = Arc::new(r.colors.clone());
        let session =
            DynamicSession { delta: g.into_delta(), colors, ts, cfg, driver, batches: 0 };
        (session, r)
    }

    /// The tag of the problem this session repairs (what the service
    /// reports in metrics).
    pub fn kind(&self) -> ProblemKind {
        P::KIND
    }

    /// Apply one update batch: record the edits in the overlay, compact,
    /// and repair the coloring from the dirty frontier. Returns the
    /// batch metrics (dirty-set size, recolored count, colors added…).
    ///
    /// Edit pairs are problem-shaped: `(net, vertex)` incidences for
    /// BGPC, undirected `{a, b}` edges for D2GC (the overlay mirrors
    /// them to preserve structural symmetry); `add_nets` entries are
    /// new constraint rows for BGPC and new vertices (adjacent to the
    /// listed members) for D2GC.
    pub fn apply(&mut self, batch: &UpdateBatch) -> BatchStats {
        self.apply_many(&[batch])
    }

    /// Apply several batches as one *fused* repair: each batch's edits
    /// are recorded in the overlay in submission order (so the graph of
    /// record is exactly what sequential [`DynamicSession::apply`] calls
    /// would produce — a later batch may remove an edge an earlier one
    /// added), then the session pays one compaction and one repair for
    /// the union dirty frontier. This is the coordinator's
    /// tiny-update-batching seam (DESIGN.md §12): a firehose of 2-edit
    /// batches costs one pool region group, not one per batch.
    ///
    /// The returned stats describe the fused repair; `batch_edits` sums
    /// the effective edits across all batches, and [`Self::batches`]
    /// advances by `batches.len()`. An empty slice is a no-op repair.
    pub fn apply_many(&mut self, batches: &[&UpdateBatch]) -> BatchStats {
        let mut edits = 0usize;
        for batch in batches {
            for &(v, u) in &batch.add_edges {
                if self.delta.add_edge(v, u) {
                    edits += 1;
                }
            }
            for &(v, u) in &batch.remove_edges {
                if self.delta.remove_edge(v, u) {
                    edits += 1;
                }
            }
            for members in &batch.add_nets {
                // one edit for the row itself plus its *effective*
                // member edits (duplicates are no-ops; the symmetric
                // overlay's mirrored incidences count once)
                edits += 1 + self.delta.add_net(members);
            }
        }
        let (dirty, seeds) = self.delta.take_dirty();
        // The engines consume CSR, so the session compacts every batch.
        // This is a splice + transpose — memcpy-speed, not coloring work
        // — and is reported separately (compact_seconds, wall-clock)
        // from the repair cost the simulator models. The overlay's
        // lazy threshold matters for clients buffering edits directly.
        let tc = std::time::Instant::now();
        let g = {
            let _sp = crate::obs::trace::span_n("session.compact", dirty.len() as u64);
            self.delta.graph()
        };
        let compact_seconds = tc.elapsed().as_secs_f64();
        // The session's driver persists across batches: in threads mode
        // this parks/wakes the pinned pool team — no spawn anywhere on
        // the repair path.
        let _sp = crate::obs::trace::span_n("session.repair", dirty.len() as u64);
        let (colors, mut stats) = match &mut self.driver {
            SessionDriver::Threads(d) => engine::repair(
                g,
                &self.colors,
                &dirty,
                &seeds,
                &self.cfg.spec,
                self.cfg.balance,
                d,
                &mut self.ts,
            ),
            SessionDriver::Sim(model) => {
                let mut d = SimDriver::new(self.cfg.threads, *model);
                engine::repair(
                    g,
                    &self.colors,
                    &dirty,
                    &seeds,
                    &self.cfg.spec,
                    self.cfg.balance,
                    &mut d,
                    &mut self.ts,
                )
            }
        };
        stats.batch_edits = edits;
        stats.compact_seconds = compact_seconds;
        self.colors = Arc::new(colors);
        self.batches += batches.len();
        stats
    }

    /// The current graph (compacting the overlay if needed).
    pub fn graph(&mut self) -> &P {
        self.delta.graph()
    }

    /// Direct access to the overlay (tests, ad-hoc edits between
    /// batches; remember that [`Self::apply`] is what repairs colors).
    pub fn delta(&mut self) -> &mut P::Delta {
        &mut self.delta
    }

    /// The current committed coloring.
    pub fn colors(&self) -> &[i32] {
        &self.colors
    }

    /// The committed coloring as a shared handle — what the coordinator
    /// publishes in its epoch snapshots: cloning is a refcount bump, and
    /// the next repair replaces (never mutates) the shared vector.
    pub fn colors_arc(&self) -> Arc<Vec<i32>> {
        Arc::clone(&self.colors)
    }

    /// Number of distinct colors in the current coloring.
    pub fn n_colors(&self) -> usize {
        crate::coloring::stats::distinct_colors(&self.colors)
    }

    /// Batches applied so far.
    pub fn batches(&self) -> usize {
        self.batches
    }

    /// The persistent per-thread state (inspect B1/B2 trackers).
    pub fn thread_states(&self) -> &[ThreadState] {
        &self.ts
    }

    /// The session's run configuration.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// Check the current coloring against the current graph with the
    /// problem's ground-truth checker ([`crate::coloring::verify`]).
    pub fn verify(&mut self) -> Result<(), Violation> {
        let _sp = crate::obs::trace::span("session.verify");
        let g = self.delta.graph();
        Problem::verify(g, &self.colors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::{schedule, Balance};
    use crate::graph::generators::{random_bipartite, random_symmetric};
    use crate::testing::forall_bipartite;
    use crate::util::prng::Rng;

    #[test]
    fn session_survives_random_edit_streams() {
        forall_bipartite(12, 0xD11A, |g0, case| {
            let mut rng = Rng::new(case.seed ^ 0x1234);
            let (mut s, init) = DynamicSession::start(g0.clone(), Config::sim(schedule::N1_N2, 4));
            assert!(init.colors.iter().all(|&c| c >= 0));
            for round in 0..3 {
                let mut batch = UpdateBatch::default();
                let n_nets = g0.n_nets();
                let n_vtxs = g0.n_vertices();
                for _ in 0..rng.range(1, 12) {
                    let v = rng.range(0, n_nets) as u32;
                    let u = rng.range(0, n_vtxs) as u32;
                    if rng.chance(0.6) {
                        batch.add_edges.push((v, u));
                    } else {
                        batch.remove_edges.push((v, u));
                    }
                }
                if rng.chance(0.3) {
                    // occasionally grow: a new net over (possibly new) vertices
                    let k = rng.range(0, 4);
                    let members: Vec<u32> =
                        (0..k).map(|_| rng.range(0, n_vtxs + 2) as u32).collect();
                    batch.add_nets.push(members);
                }
                let st = s.apply(&batch);
                assert!(
                    s.verify().is_ok(),
                    "invalid after round {round} on {case:?} ({st:?})"
                );
                assert_eq!(s.batches(), round + 1);
            }
        });
    }

    #[test]
    fn balancing_trackers_persist_across_batches() {
        let g = random_bipartite(60, 90, 700, 5);
        let cfg = Config::sim(schedule::V_N2, 4).with_balance(Balance::B2);
        let (mut s, _init) = DynamicSession::start(g, cfg);
        let before: Vec<i32> = s.thread_states().iter().map(|t| t.col_max).collect();
        assert!(before.iter().any(|&m| m > 0), "initial run populated the trackers");
        let mut batch = UpdateBatch::default();
        batch.add_edges.push((0, 0));
        batch.add_edges.push((1, 5));
        batch.add_edges.push((2, 9));
        s.apply(&batch);
        let after: Vec<i32> = s.thread_states().iter().map(|t| t.col_max).collect();
        for (b, a) in before.iter().zip(after.iter()) {
            assert!(a >= b, "col_max must never reset across batches");
        }
        assert!(s.verify().is_ok());
    }

    #[test]
    fn untouched_regions_keep_their_colors() {
        let g = random_bipartite(100, 150, 1000, 11);
        let (mut s, init) = DynamicSession::start(g, Config::sim(schedule::V_N2, 8));
        let mut batch = UpdateBatch::default();
        batch.add_edges.push((0, 0));
        batch.add_edges.push((0, 1));
        let st = s.apply(&batch);
        let changed = init
            .colors
            .iter()
            .zip(s.colors().iter())
            .filter(|(a, b)| a != b)
            .count();
        assert!(
            changed <= st.recolored,
            "only repaired vertices may change ({changed} vs {})",
            st.recolored
        );
        assert!(s.verify().is_ok());
    }

    #[test]
    fn threads_session_pins_one_pool_across_batches() {
        let pool = Arc::new(WorkerPool::new(2));
        let g = random_bipartite(50, 80, 500, 3);
        let cfg = Config::threads(schedule::V_V_64D, 2);
        let (mut s, init) = DynamicSession::start_on(g, cfg, &pool);
        assert!(init.colors.iter().all(|&c| c >= 0));
        let after_start = pool.regions_dispatched();
        assert!(after_start > 0, "bring-up must run on the shared pool");
        let mut batch = UpdateBatch::default();
        batch.add_edges.push((0, 0));
        batch.add_edges.push((1, 3));
        batch.add_edges.push((2, 7));
        s.apply(&batch);
        assert!(s.verify().is_ok());
        assert!(
            pool.regions_dispatched() > after_start,
            "repair regions must dispatch onto the same pinned team"
        );
    }

    #[test]
    fn apply_many_matches_sequential_applies_on_the_graph_of_record() {
        // Fusion must preserve per-batch edit order: batch 2 removes an
        // edge batch 1 added, batch 3 re-adds an edge batch 2 removed —
        // a concat-and-apply fusion would get both wrong.
        let g = random_bipartite(40, 60, 500, 7);
        let cfg = Config::sim(schedule::N1_N2, 4);
        let (mut seq, _) = DynamicSession::start(g.clone(), cfg.clone());
        let (mut fused, _) = DynamicSession::start(g, cfg);
        let mut b1 = UpdateBatch::default();
        b1.add_edges.push((3, 10));
        b1.remove_edges.push((5, seq.graph().vtxs(5).first().copied().unwrap_or(0)));
        let mut b2 = UpdateBatch::default();
        b2.remove_edges.push((3, 10)); // undoes b1's add
        b2.add_edges.push((7, 20));
        let mut b3 = UpdateBatch::default();
        b3.add_edges.push((3, 10)); // re-adds what b2 removed
        b3.add_nets.push(vec![1, 2, 61]); // grows the vertex side
        let mut total_edits = 0;
        for b in [&b1, &b2, &b3] {
            total_edits += seq.apply(b).batch_edits;
        }
        let st = fused.apply_many(&[&b1, &b2, &b3]);
        assert_eq!(st.batch_edits, total_edits, "effective edits must agree");
        assert_eq!(fused.batches(), 3, "fusion still counts every batch");
        assert!(seq.verify().is_ok() && fused.verify().is_ok());
        // the graphs of record are identical net by net
        let (a, b) = (seq.graph().clone(), fused.graph().clone());
        assert_eq!(a.n_nets(), b.n_nets());
        assert_eq!(a.n_vertices(), b.n_vertices());
        for v in 0..a.n_nets() {
            let mut x = a.vtxs(v).to_vec();
            let mut y = b.vtxs(v).to_vec();
            x.sort_unstable();
            y.sort_unstable();
            assert_eq!(x, y, "net {v} diverged between fused and sequential");
        }
        assert!(fused.graph().vtxs(3).contains(&10), "b3's re-add must win");
    }

    #[test]
    fn d2gc_session_streams_symmetric_edits() {
        let g0 = random_symmetric(80, 300, 21);
        let (mut s, init) = DynamicSession::start(g0.clone(), Config::sim(schedule::N1_N2, 4));
        assert_eq!(s.kind(), ProblemKind::D2gc);
        assert!(init.colors.iter().all(|&c| c >= 0));
        let mut rng = Rng::new(0xD2);
        for round in 0..4 {
            let mut batch = UpdateBatch::default();
            for _ in 0..10 {
                let a = rng.range(0, 80) as u32;
                let b = rng.range(0, 80) as u32;
                if rng.chance(0.6) {
                    batch.add_edges.push((a, b));
                } else {
                    batch.remove_edges.push((a, b));
                }
            }
            let st = s.apply(&batch);
            assert!(s.verify().is_ok(), "invalid after round {round} ({st:?})");
            assert!(s.graph().is_structurally_symmetric(), "symmetry drifted");
        }
        assert_eq!(s.batches(), 4);
    }

    #[test]
    fn d2gc_session_grows_by_vertices() {
        let g0 = random_symmetric(40, 120, 33);
        let (mut s, _init) = DynamicSession::start(g0, Config::sim(schedule::V_N2, 4));
        let mut batch = UpdateBatch::default();
        batch.add_nets.push(vec![0, 1, 2]); // new vertex 40
        batch.add_nets.push(vec![40, 3]); // new vertex 41, touching 40
        let st = s.apply(&batch);
        assert!(s.verify().is_ok(), "{st:?}");
        // edit pairs, not directed incidences: (1 row + 3 members) +
        // (1 row + 2 members) — mirrored halves count once
        assert_eq!(st.batch_edits, 7, "{st:?}");
        assert_eq!(s.colors().len(), 42);
        assert!(s.colors().iter().all(|&c| c >= 0));
        assert!(s.graph().is_structurally_symmetric());
    }
}
