//! [`DeltaBipartite`] / [`DeltaSymmetric`] — mutable overlays over the
//! frozen CSR graphs the engines consume.
//!
//! The coloring engines consume an immutable CSR; a streaming client
//! mutates the graph. This type bridges the two: batched
//! [`DeltaBipartite::add_edge`] / [`DeltaBipartite::remove_edge`] /
//! [`DeltaBipartite::add_net`] edits accumulate in small per-row patch
//! lists (both incidence directions kept in sync), point queries merge
//! base + patch on the fly, and [`DeltaBipartite::compact`] splices the
//! patched rows back into a fresh CSR — clean rows are copied verbatim
//! via [`Csr::with_replaced_rows`], so compaction cost is a memcpy plus
//! the dirty-row footprint, not a re-sort of the whole graph.
//!
//! The overlay also tracks the *dirty frontier* the incremental engine
//! seeds from: nets whose member lists changed since the last
//! [`DeltaBipartite::take_dirty`], and the endpoints of changed edges.
//! Only those nets can hold a stale duplicate color (edge deletions
//! never invalidate a coloring), which is what makes repair cost scale
//! with the batch instead of the graph.
//!
//! [`DeltaSymmetric`] is the D2GC face of the same machinery: a thin
//! wrapper that mirrors every edit onto both incidence directions so
//! the square CSR stays structurally symmetric across the stream
//! (DESIGN.md §9). Its dirty nets double as D2GC's dirty *rows* — both
//! endpoints of an inserted undirected edge — which is exactly the set
//! [`crate::coloring::d2gc::conflict_phase_on`] must scan.

use std::collections::BTreeMap;

use crate::graph::{Bipartite, Csr};

/// Per-row patch: ids added to / removed from the frozen base row.
/// Invariant: `add` is disjoint from the base row, `remove` is a subset
/// of it, and both are duplicate-free (enforced by the edit methods).
#[derive(Clone, Debug, Default)]
struct Patch {
    add: Vec<u32>,
    remove: Vec<u32>,
}

impl Patch {
    fn is_empty(&self) -> bool {
        self.add.is_empty() && self.remove.is_empty()
    }
}

/// Mutable overlay over a frozen [`Bipartite`] (see module docs).
#[derive(Clone, Debug)]
pub struct DeltaBipartite {
    /// Frozen CSR snapshot (both incidence directions).
    base: Bipartite,
    /// Net-side patches (net id → member edits).
    net_patch: BTreeMap<u32, Patch>,
    /// Vertex-side mirror of the same edits (vertex id → net edits).
    vtx_patch: BTreeMap<u32, Patch>,
    /// Logical shape — may exceed the base shape until compaction.
    n_nets: usize,
    n_vertices: usize,
    /// Logical incidence count under the overlay.
    nnz: usize,
    /// Effective edits since the last compaction.
    pending: usize,
    /// Shape grew past the base (forces the next compaction).
    dims_dirty: bool,
    /// Auto-compact once this many edits accumulate.
    compact_threshold: usize,
    /// Nets with insertions (or newly created) since the last
    /// [`Self::take_dirty`] — new conflicts can only appear there.
    dirty_nets: Vec<u32>,
    /// Endpoints of changed edges since the last [`Self::take_dirty`].
    dirty_vertices: Vec<u32>,
}

impl DeltaBipartite {
    /// Wrap a frozen graph. The default compaction threshold keeps the
    /// overlay below ~25% of the base size.
    pub fn new(base: Bipartite) -> DeltaBipartite {
        let threshold = base.nnz() / 4 + 1024;
        DeltaBipartite {
            n_nets: base.n_nets(),
            n_vertices: base.n_vertices(),
            nnz: base.nnz(),
            base,
            net_patch: BTreeMap::new(),
            vtx_patch: BTreeMap::new(),
            pending: 0,
            dims_dirty: false,
            compact_threshold: threshold,
            dirty_nets: Vec::new(),
            dirty_vertices: Vec::new(),
        }
    }

    /// Override the auto-compaction threshold (edits between compactions).
    pub fn with_compact_threshold(mut self, edits: usize) -> DeltaBipartite {
        self.compact_threshold = edits.max(1);
        self
    }

    /// Logical number of nets (`|V_B|`), overlay included.
    pub fn n_nets(&self) -> usize {
        self.n_nets
    }

    /// Logical number of vertices (`|V_A|`), overlay included.
    pub fn n_vertices(&self) -> usize {
        self.n_vertices
    }

    /// Logical number of incidences, overlay included.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Effective edits buffered since the last compaction.
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Whether the overlay is empty (base CSR is exact).
    pub fn is_compact(&self) -> bool {
        self.pending == 0 && !self.dims_dirty
    }

    fn grow(&mut self, net: u32, vtx: u32) {
        let rn = net as usize + 1;
        let rv = vtx as usize + 1;
        if rn > self.n_nets {
            self.n_nets = rn;
            self.dims_dirty = true;
        }
        if rv > self.n_vertices {
            self.n_vertices = rv;
            self.dims_dirty = true;
        }
    }

    /// Membership in the frozen base only.
    fn in_base(&self, net: u32, vtx: u32) -> bool {
        (net as usize) < self.base.net_vtxs.n_rows
            && self.base.net_vtxs.row(net as usize).binary_search(&vtx).is_ok()
    }

    /// Membership under the overlay (base + patches).
    pub fn has_edge(&self, net: u32, vtx: u32) -> bool {
        match (self.in_base(net, vtx), self.net_patch.get(&net)) {
            (true, Some(p)) => !p.remove.contains(&vtx),
            (true, None) => true,
            (false, Some(p)) => p.add.contains(&vtx),
            (false, None) => false,
        }
    }

    /// Record "edge (key → other) now exists" in one patch direction.
    /// `in_base` tells which side of the patch encodes existence.
    fn patch_insert(map: &mut BTreeMap<u32, Patch>, key: u32, other: u32, in_base: bool) {
        let p = map.entry(key).or_default();
        if in_base {
            // was overlay-removed (the caller saw has_edge() == false)
            if let Some(i) = p.remove.iter().position(|&x| x == other) {
                p.remove.swap_remove(i);
            }
        } else {
            p.add.push(other);
        }
        if p.is_empty() {
            map.remove(&key);
        }
    }

    /// Record "edge (key → other) no longer exists" in one direction.
    fn patch_delete(map: &mut BTreeMap<u32, Patch>, key: u32, other: u32, in_base: bool) {
        let p = map.entry(key).or_default();
        if in_base {
            p.remove.push(other);
        } else if let Some(i) = p.add.iter().position(|&x| x == other) {
            p.add.swap_remove(i);
        }
        if p.is_empty() {
            map.remove(&key);
        }
    }

    /// Add incidence `(net, vtx)`; ids beyond the current shape grow it.
    /// Returns whether the graph actually changed (duplicates are no-ops).
    pub fn add_edge(&mut self, net: u32, vtx: u32) -> bool {
        self.grow(net, vtx);
        if self.has_edge(net, vtx) {
            return false;
        }
        let in_base = self.in_base(net, vtx);
        Self::patch_insert(&mut self.net_patch, net, vtx, in_base);
        Self::patch_insert(&mut self.vtx_patch, vtx, net, in_base);
        self.nnz += 1;
        self.pending += 1;
        self.dirty_nets.push(net);
        self.dirty_vertices.push(vtx);
        self.maybe_compact();
        true
    }

    /// Remove incidence `(net, vtx)`. Returns whether it existed.
    /// Deletions never invalidate a coloring, so the net does *not*
    /// enter the dirty-net detection set (scanning it would be
    /// guaranteed dead work); the endpoint is still recorded for the
    /// per-batch metrics.
    pub fn remove_edge(&mut self, net: u32, vtx: u32) -> bool {
        if !self.has_edge(net, vtx) {
            return false;
        }
        let in_base = self.in_base(net, vtx);
        Self::patch_delete(&mut self.net_patch, net, vtx, in_base);
        Self::patch_delete(&mut self.vtx_patch, vtx, net, in_base);
        self.nnz -= 1;
        self.pending += 1;
        self.dirty_vertices.push(vtx);
        self.maybe_compact();
        true
    }

    /// Append a fresh net with the given members; returns its id.
    /// Members beyond the current vertex shape grow it.
    pub fn add_net(&mut self, members: &[u32]) -> u32 {
        self.add_net_counted(members).0
    }

    /// [`Self::add_net`], also returning how many member incidences
    /// were actually inserted (duplicate members are no-ops) — the
    /// session layer's `batch_edits` unit.
    pub fn add_net_counted(&mut self, members: &[u32]) -> (u32, usize) {
        let id = self.n_nets as u32;
        self.n_nets += 1;
        self.dims_dirty = true;
        self.dirty_nets.push(id);
        let mut edits = 0;
        for &u in members {
            if self.add_edge(id, u) {
                edits += 1;
            }
        }
        (id, edits)
    }

    /// Base row merged with its patch: the overlay's view of one row.
    fn merged_row(csr: &Csr, patch: &BTreeMap<u32, Patch>, id: u32) -> Vec<u32> {
        let mut row: Vec<u32> = if (id as usize) < csr.n_rows {
            csr.row(id as usize).to_vec()
        } else {
            Vec::new()
        };
        if let Some(p) = patch.get(&id) {
            row.retain(|x| !p.remove.contains(x));
            row.extend_from_slice(&p.add);
            row.sort_unstable();
        }
        row
    }

    /// `vtxs(v)` under the overlay (allocates; hot paths should compact
    /// and use the CSR directly).
    pub fn vtxs(&self, v: u32) -> Vec<u32> {
        Self::merged_row(&self.base.net_vtxs, &self.net_patch, v)
    }

    /// `nets(u)` under the overlay.
    pub fn nets(&self, u: u32) -> Vec<u32> {
        Self::merged_row(&self.base.vtx_nets, &self.vtx_patch, u)
    }

    fn maybe_compact(&mut self) {
        if self.pending >= self.compact_threshold {
            self.compact();
        }
    }

    /// Fold the overlay back into a fresh CSR (no-op when clean). Dirty
    /// tracking is *not* cleared — it belongs to the repair cycle, not
    /// the storage cycle.
    pub fn compact(&mut self) {
        if self.is_compact() {
            return;
        }
        let mut replace: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
        for &v in self.net_patch.keys() {
            replace.insert(v, Self::merged_row(&self.base.net_vtxs, &self.net_patch, v));
        }
        let csr = self.base.net_vtxs.with_replaced_rows(self.n_nets, self.n_vertices, &replace);
        debug_assert_eq!(csr.nnz(), self.nnz, "overlay nnz bookkeeping out of sync");
        self.base = Bipartite::from_net_incidence(csr);
        self.net_patch.clear();
        self.vtx_patch.clear();
        self.pending = 0;
        self.dims_dirty = false;
    }

    /// Compact (if needed) and expose the CSR view the engines consume.
    pub fn graph(&mut self) -> &Bipartite {
        self.compact();
        &self.base
    }

    /// Drain the dirty sets accumulated since the last call:
    /// `(nets with insertions, endpoints of changed edges)`, sorted and
    /// deduped. Removal-only nets are excluded by construction — a
    /// deletion cannot create a duplicate, so detection there is dead
    /// work (the endpoints still show up in the second list).
    pub fn take_dirty(&mut self) -> (Vec<u32>, Vec<u32>) {
        let mut nets = std::mem::take(&mut self.dirty_nets);
        nets.sort_unstable();
        nets.dedup();
        let mut vtxs = std::mem::take(&mut self.dirty_vertices);
        vtxs.sort_unstable();
        vtxs.dedup();
        (nets, vtxs)
    }
}

/// Symmetric-update overlay for D2GC: a [`DeltaBipartite`] whose edits
/// are mirrored onto both incidence directions, so the underlying
/// square CSR stays structurally symmetric across
/// `add_edge`/`remove_edge`/`add_vertex` (the invariant
/// [`crate::coloring::verify::d2gc_valid`] and the D2GC kernels
/// assume). Edits are *undirected*: `add_edge(a, b)` records both
/// `(a, b)` and `(b, a)`, and growth through either endpoint keeps the
/// shape square because the mirror op grows the other side to match.
#[derive(Clone, Debug)]
pub struct DeltaSymmetric {
    inner: DeltaBipartite,
}

impl DeltaSymmetric {
    /// Wrap a frozen square symmetric graph.
    ///
    /// # Panics
    /// If `base` is not square or not structurally symmetric — the
    /// overlay preserves symmetry, it cannot create it.
    pub fn new(base: Csr) -> DeltaSymmetric {
        assert!(
            base.is_structurally_symmetric(),
            "DeltaSymmetric requires a square, structurally symmetric base"
        );
        DeltaSymmetric { inner: DeltaBipartite::new(Bipartite::from_net_incidence(base)) }
    }

    /// Override the auto-compaction threshold (edits between compactions).
    pub fn with_compact_threshold(mut self, edits: usize) -> DeltaSymmetric {
        self.inner = self.inner.with_compact_threshold(edits);
        self
    }

    /// Logical number of vertices (square shape), overlay included.
    pub fn n_vertices(&self) -> usize {
        self.inner.n_nets().max(self.inner.n_vertices())
    }

    /// Logical number of (directed) incidences, overlay included —
    /// off-diagonal undirected edges count twice.
    pub fn nnz(&self) -> usize {
        self.inner.nnz()
    }

    /// Whether the undirected edge `{a, b}` exists under the overlay.
    pub fn has_edge(&self, a: u32, b: u32) -> bool {
        self.inner.has_edge(a, b)
    }

    /// Neighbors of `v` under the overlay (allocates; hot paths should
    /// use the compacted CSR via [`Self::graph`]).
    pub fn row(&self, v: u32) -> Vec<u32> {
        self.inner.vtxs(v)
    }

    /// Insert the undirected edge `{a, b}` (both directions; a diagonal
    /// `a == b` is inserted once). Ids beyond the current shape grow
    /// it, square. Returns whether the graph changed.
    pub fn add_edge(&mut self, a: u32, b: u32) -> bool {
        let changed = self.inner.add_edge(a, b);
        if a != b {
            let mirrored = self.inner.add_edge(b, a);
            debug_assert_eq!(changed, mirrored, "symmetric overlay out of sync");
        }
        changed
    }

    /// Delete the undirected edge `{a, b}` (both directions). Returns
    /// whether it existed.
    pub fn remove_edge(&mut self, a: u32, b: u32) -> bool {
        let changed = self.inner.remove_edge(a, b);
        if a != b {
            let mirrored = self.inner.remove_edge(b, a);
            debug_assert_eq!(changed, mirrored, "symmetric overlay out of sync");
        }
        changed
    }

    /// Append a fresh vertex adjacent to `members` (a new Hessian row /
    /// mesh node): its diagonal entry plus the mirrored off-diagonal
    /// edges. Returns the new vertex id.
    pub fn add_vertex(&mut self, members: &[u32]) -> u32 {
        self.add_vertex_counted(members).0
    }

    /// [`Self::add_vertex`], also returning how many distinct member
    /// edges were inserted (duplicates are no-ops; the diagonal and
    /// the mirrored halves count as part of the row, not as member
    /// edits) — the session layer's `batch_edits` unit.
    pub fn add_vertex_counted(&mut self, members: &[u32]) -> (u32, usize) {
        let id = self.n_vertices() as u32;
        self.add_edge(id, id); // diagonal; grows both sides to id + 1
        let mut edits = 0;
        for &m in members {
            if m != id && self.add_edge(id, m) {
                edits += 1;
            }
        }
        (id, edits)
    }

    /// Compact (if needed) and expose the square CSR the D2GC kernels
    /// consume. Structural symmetry is a debug-checked invariant.
    pub fn graph(&mut self) -> &Csr {
        let g = &self.inner.graph().net_vtxs;
        debug_assert!(g.is_structurally_symmetric(), "symmetric overlay drifted");
        g
    }

    /// Drain the dirty sets accumulated since the last call:
    /// `(insertion-dirty rows, endpoints of changed edges)`, sorted and
    /// deduped. Because edits are mirrored, *both* endpoints of every
    /// inserted edge appear as dirty rows — the exact scan set the
    /// D2GC dirty-frontier detection needs.
    pub fn take_dirty(&mut self) -> (Vec<u32>, Vec<u32>) {
        self.inner.take_dirty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::random_bipartite;
    use crate::util::prng::Rng;
    use std::collections::BTreeSet;

    fn tiny() -> Bipartite {
        // n0 -> {0, 1}, n1 -> {1, 2}
        Bipartite::from_net_incidence(Csr::from_edges(2, 3, &[(0, 0), (0, 1), (1, 1), (1, 2)]))
    }

    #[test]
    fn add_remove_roundtrip_and_queries() {
        let mut d = DeltaBipartite::new(tiny());
        assert!(d.has_edge(0, 1));
        assert!(!d.has_edge(0, 2));
        assert!(d.add_edge(0, 2));
        assert!(!d.add_edge(0, 2), "duplicate add is a no-op");
        assert!(d.has_edge(0, 2));
        assert_eq!(d.vtxs(0), vec![0, 1, 2]);
        assert_eq!(d.nets(2), vec![0, 1]);
        assert!(d.remove_edge(0, 0));
        assert!(!d.remove_edge(0, 0), "double remove is a no-op");
        assert_eq!(d.vtxs(0), vec![1, 2]);
        assert_eq!(d.nets(0), Vec::<u32>::new());
        assert_eq!(d.nnz(), 4);
    }

    #[test]
    fn add_then_remove_cancels_cleanly() {
        let mut d = DeltaBipartite::new(tiny());
        let nnz0 = d.nnz();
        assert!(d.add_edge(1, 0));
        assert!(d.remove_edge(1, 0));
        assert_eq!(d.nnz(), nnz0);
        // base edge removed then re-added: back to base state
        assert!(d.remove_edge(0, 1));
        assert!(d.add_edge(0, 1));
        assert_eq!(d.nnz(), nnz0);
        assert_eq!(d.vtxs(0), vec![0, 1]);
        d.compact();
        let g = d.graph();
        g.validate().unwrap();
        assert_eq!(g.vtxs(0), &[0, 1]);
    }

    #[test]
    fn growth_via_new_nets_and_vertices() {
        let mut d = DeltaBipartite::new(tiny());
        let id = d.add_net(&[0, 4]); // vertex 4 is new
        assert_eq!(id, 2);
        assert_eq!(d.n_nets(), 3);
        assert_eq!(d.n_vertices(), 5);
        assert!(d.add_edge(5, 3)); // net 5 is new -> nets 3, 4 implicit empty
        assert_eq!(d.n_nets(), 6);
        let g = d.graph();
        g.validate().unwrap();
        assert_eq!(g.n_nets(), 6);
        assert_eq!(g.n_vertices(), 5);
        assert_eq!(g.vtxs(2), &[0, 4]);
        assert_eq!(g.vtxs(3), &[] as &[u32]);
        assert_eq!(g.vtxs(5), &[3]);
        assert_eq!(g.nets(4), &[2]);
    }

    #[test]
    fn dirty_tracking_is_batch_scoped() {
        let mut d = DeltaBipartite::new(tiny());
        d.add_edge(0, 2);
        d.remove_edge(1, 1); // removal: endpoint dirty, net NOT (no new conflicts)
        d.add_edge(0, 2); // no-op: no extra dirt
        let (nets, vtxs) = d.take_dirty();
        assert_eq!(nets, vec![0], "removal-only nets stay out of detection");
        assert_eq!(vtxs, vec![1, 2]);
        let (nets2, vtxs2) = d.take_dirty();
        assert!(nets2.is_empty() && vtxs2.is_empty(), "drained");
        d.add_edge(1, 0);
        let (nets3, _) = d.take_dirty();
        assert_eq!(nets3, vec![1]);
    }

    #[test]
    fn compaction_matches_ground_truth_edge_set() {
        // Random edit stream mirrored into a plain edge set; the
        // compacted CSR must equal Csr::from_edges of the mirror.
        let g0 = random_bipartite(20, 30, 150, 7);
        let mut rng = Rng::new(99);
        let mut mirror: BTreeSet<(u32, u32)> = BTreeSet::new();
        for v in 0..g0.n_nets() {
            for &u in g0.vtxs(v) {
                mirror.insert((v as u32, u));
            }
        }
        let mut d = DeltaBipartite::new(g0).with_compact_threshold(13);
        for _ in 0..400 {
            let v = rng.range(0, 20) as u32;
            let u = rng.range(0, 30) as u32;
            if rng.chance(0.5) {
                assert_eq!(d.add_edge(v, u), mirror.insert((v, u)));
            } else {
                assert_eq!(d.remove_edge(v, u), mirror.remove(&(v, u)));
            }
        }
        assert_eq!(d.nnz(), mirror.len());
        let edges: Vec<(u32, u32)> = mirror.iter().copied().collect();
        let truth = Csr::from_edges(20, 30, &edges);
        let got = d.graph();
        got.validate().unwrap();
        assert_eq!(got.net_vtxs.ptr, truth.ptr);
        assert_eq!(got.net_vtxs.adj, truth.adj);
    }

    #[test]
    fn threshold_triggers_periodic_compaction() {
        let mut d = DeltaBipartite::new(tiny()).with_compact_threshold(2);
        d.add_edge(0, 2);
        assert_eq!(d.pending(), 1);
        d.add_edge(1, 0); // second edit crosses the threshold
        assert!(d.is_compact(), "auto-compacted at the threshold");
        assert_eq!(d.pending(), 0);
        // dirty sets survive compaction (they belong to the repair cycle)
        let (nets, _) = d.take_dirty();
        assert_eq!(nets, vec![0, 1]);
    }

    fn tiny_sym() -> Csr {
        // triangle 0-1-2 plus isolated 3, diagonals present
        Csr::from_edges(
            4,
            4,
            &[
                (0, 0), (1, 1), (2, 2), (3, 3),
                (0, 1), (1, 0), (1, 2), (2, 1), (0, 2), (2, 0),
            ],
        )
    }

    #[test]
    fn symmetric_overlay_mirrors_every_edit() {
        let mut d = DeltaSymmetric::new(tiny_sym());
        assert!(d.has_edge(0, 1) && d.has_edge(1, 0));
        assert!(d.add_edge(3, 1));
        assert!(!d.add_edge(1, 3), "undirected duplicate is a no-op");
        assert!(d.has_edge(1, 3) && d.has_edge(3, 1));
        assert!(d.remove_edge(0, 2));
        assert!(!d.has_edge(2, 0), "mirror direction removed too");
        let g = d.graph();
        assert!(g.is_structurally_symmetric());
        assert_eq!(g.row(3), &[1, 3]);
        assert_eq!(g.row(1), &[0, 1, 2, 3]);
    }

    #[test]
    fn symmetric_growth_stays_square() {
        let mut d = DeltaSymmetric::new(tiny_sym());
        assert!(d.add_edge(6, 2)); // id 6 grows the shape to 7x7
        assert_eq!(d.n_vertices(), 7);
        let id = d.add_vertex(&[0, 6]);
        assert_eq!(id, 7);
        let g = d.graph();
        assert_eq!(g.n_rows, 8);
        assert_eq!(g.n_cols, 8);
        assert!(g.is_structurally_symmetric());
        assert_eq!(g.row(7), &[0, 6, 7], "diagonal + mirrored members");
        assert!(g.row(0).contains(&7));
    }

    #[test]
    fn symmetric_dirty_rows_are_both_endpoints() {
        let mut d = DeltaSymmetric::new(tiny_sym());
        d.add_edge(3, 0);
        d.remove_edge(1, 2); // removal: endpoints dirty, rows NOT
        let (rows, vtxs) = d.take_dirty();
        assert_eq!(rows, vec![0, 3], "both endpoints of the insertion");
        assert_eq!(vtxs, vec![0, 1, 2, 3]);
    }

    #[test]
    fn symmetric_overlay_tracks_ground_truth() {
        let base = crate::graph::generators::random_symmetric(24, 60, 17);
        let mut mirror: BTreeSet<(u32, u32)> = BTreeSet::new();
        for v in 0..base.n_rows {
            for &u in base.row(v) {
                mirror.insert((v as u32, u));
            }
        }
        let mut d = DeltaSymmetric::new(base).with_compact_threshold(9);
        let mut rng = Rng::new(5);
        for _ in 0..300 {
            let a = rng.range(0, 24) as u32;
            let b = rng.range(0, 24) as u32;
            if rng.chance(0.5) {
                let changed = d.add_edge(a, b);
                let m1 = mirror.insert((a, b));
                let m2 = if a != b { mirror.insert((b, a)) } else { m1 };
                assert_eq!(changed, m1);
                assert_eq!(m1, m2, "mirror set out of sync");
            } else {
                let changed = d.remove_edge(a, b);
                let m1 = mirror.remove(&(a, b));
                let m2 = if a != b { mirror.remove(&(b, a)) } else { m1 };
                assert_eq!(changed, m1);
                assert_eq!(m1, m2);
            }
        }
        assert_eq!(d.nnz(), mirror.len());
        let edges: Vec<(u32, u32)> = mirror.iter().copied().collect();
        let truth = Csr::from_edges(24, 24, &edges);
        let got = d.graph();
        assert!(got.is_structurally_symmetric());
        assert_eq!(got.ptr, truth.ptr);
        assert_eq!(got.adj, truth.adj);
    }
}
