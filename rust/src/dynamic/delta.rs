//! [`DeltaBipartite`] — a mutable overlay over the frozen CSR
//! [`Bipartite`].
//!
//! The coloring engines consume an immutable CSR; a streaming client
//! mutates the graph. This type bridges the two: batched
//! [`DeltaBipartite::add_edge`] / [`DeltaBipartite::remove_edge`] /
//! [`DeltaBipartite::add_net`] edits accumulate in small per-row patch
//! lists (both incidence directions kept in sync), point queries merge
//! base + patch on the fly, and [`DeltaBipartite::compact`] splices the
//! patched rows back into a fresh CSR — clean rows are copied verbatim
//! via [`Csr::with_replaced_rows`], so compaction cost is a memcpy plus
//! the dirty-row footprint, not a re-sort of the whole graph.
//!
//! The overlay also tracks the *dirty frontier* the incremental engine
//! seeds from: nets whose member lists changed since the last
//! [`DeltaBipartite::take_dirty`], and the endpoints of changed edges.
//! Only those nets can hold a stale duplicate color (edge deletions
//! never invalidate a coloring), which is what makes repair cost scale
//! with the batch instead of the graph.

use std::collections::BTreeMap;

use crate::graph::{Bipartite, Csr};

/// Per-row patch: ids added to / removed from the frozen base row.
/// Invariant: `add` is disjoint from the base row, `remove` is a subset
/// of it, and both are duplicate-free (enforced by the edit methods).
#[derive(Clone, Debug, Default)]
struct Patch {
    add: Vec<u32>,
    remove: Vec<u32>,
}

impl Patch {
    fn is_empty(&self) -> bool {
        self.add.is_empty() && self.remove.is_empty()
    }
}

/// Mutable overlay over a frozen [`Bipartite`] (see module docs).
#[derive(Clone, Debug)]
pub struct DeltaBipartite {
    /// Frozen CSR snapshot (both incidence directions).
    base: Bipartite,
    /// Net-side patches (net id → member edits).
    net_patch: BTreeMap<u32, Patch>,
    /// Vertex-side mirror of the same edits (vertex id → net edits).
    vtx_patch: BTreeMap<u32, Patch>,
    /// Logical shape — may exceed the base shape until compaction.
    n_nets: usize,
    n_vertices: usize,
    /// Logical incidence count under the overlay.
    nnz: usize,
    /// Effective edits since the last compaction.
    pending: usize,
    /// Shape grew past the base (forces the next compaction).
    dims_dirty: bool,
    /// Auto-compact once this many edits accumulate.
    compact_threshold: usize,
    /// Nets with insertions (or newly created) since the last
    /// [`Self::take_dirty`] — new conflicts can only appear there.
    dirty_nets: Vec<u32>,
    /// Endpoints of changed edges since the last [`Self::take_dirty`].
    dirty_vertices: Vec<u32>,
}

impl DeltaBipartite {
    /// Wrap a frozen graph. The default compaction threshold keeps the
    /// overlay below ~25% of the base size.
    pub fn new(base: Bipartite) -> DeltaBipartite {
        let threshold = base.nnz() / 4 + 1024;
        DeltaBipartite {
            n_nets: base.n_nets(),
            n_vertices: base.n_vertices(),
            nnz: base.nnz(),
            base,
            net_patch: BTreeMap::new(),
            vtx_patch: BTreeMap::new(),
            pending: 0,
            dims_dirty: false,
            compact_threshold: threshold,
            dirty_nets: Vec::new(),
            dirty_vertices: Vec::new(),
        }
    }

    /// Override the auto-compaction threshold (edits between compactions).
    pub fn with_compact_threshold(mut self, edits: usize) -> DeltaBipartite {
        self.compact_threshold = edits.max(1);
        self
    }

    /// Logical number of nets (`|V_B|`), overlay included.
    pub fn n_nets(&self) -> usize {
        self.n_nets
    }

    /// Logical number of vertices (`|V_A|`), overlay included.
    pub fn n_vertices(&self) -> usize {
        self.n_vertices
    }

    /// Logical number of incidences, overlay included.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Effective edits buffered since the last compaction.
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Whether the overlay is empty (base CSR is exact).
    pub fn is_compact(&self) -> bool {
        self.pending == 0 && !self.dims_dirty
    }

    fn grow(&mut self, net: u32, vtx: u32) {
        let rn = net as usize + 1;
        let rv = vtx as usize + 1;
        if rn > self.n_nets {
            self.n_nets = rn;
            self.dims_dirty = true;
        }
        if rv > self.n_vertices {
            self.n_vertices = rv;
            self.dims_dirty = true;
        }
    }

    /// Membership in the frozen base only.
    fn in_base(&self, net: u32, vtx: u32) -> bool {
        (net as usize) < self.base.net_vtxs.n_rows
            && self.base.net_vtxs.row(net as usize).binary_search(&vtx).is_ok()
    }

    /// Membership under the overlay (base + patches).
    pub fn has_edge(&self, net: u32, vtx: u32) -> bool {
        match (self.in_base(net, vtx), self.net_patch.get(&net)) {
            (true, Some(p)) => !p.remove.contains(&vtx),
            (true, None) => true,
            (false, Some(p)) => p.add.contains(&vtx),
            (false, None) => false,
        }
    }

    /// Record "edge (key → other) now exists" in one patch direction.
    /// `in_base` tells which side of the patch encodes existence.
    fn patch_insert(map: &mut BTreeMap<u32, Patch>, key: u32, other: u32, in_base: bool) {
        let p = map.entry(key).or_default();
        if in_base {
            // was overlay-removed (the caller saw has_edge() == false)
            if let Some(i) = p.remove.iter().position(|&x| x == other) {
                p.remove.swap_remove(i);
            }
        } else {
            p.add.push(other);
        }
        if p.is_empty() {
            map.remove(&key);
        }
    }

    /// Record "edge (key → other) no longer exists" in one direction.
    fn patch_delete(map: &mut BTreeMap<u32, Patch>, key: u32, other: u32, in_base: bool) {
        let p = map.entry(key).or_default();
        if in_base {
            p.remove.push(other);
        } else if let Some(i) = p.add.iter().position(|&x| x == other) {
            p.add.swap_remove(i);
        }
        if p.is_empty() {
            map.remove(&key);
        }
    }

    /// Add incidence `(net, vtx)`; ids beyond the current shape grow it.
    /// Returns whether the graph actually changed (duplicates are no-ops).
    pub fn add_edge(&mut self, net: u32, vtx: u32) -> bool {
        self.grow(net, vtx);
        if self.has_edge(net, vtx) {
            return false;
        }
        let in_base = self.in_base(net, vtx);
        Self::patch_insert(&mut self.net_patch, net, vtx, in_base);
        Self::patch_insert(&mut self.vtx_patch, vtx, net, in_base);
        self.nnz += 1;
        self.pending += 1;
        self.dirty_nets.push(net);
        self.dirty_vertices.push(vtx);
        self.maybe_compact();
        true
    }

    /// Remove incidence `(net, vtx)`. Returns whether it existed.
    /// Deletions never invalidate a coloring, so the net does *not*
    /// enter the dirty-net detection set (scanning it would be
    /// guaranteed dead work); the endpoint is still recorded for the
    /// per-batch metrics.
    pub fn remove_edge(&mut self, net: u32, vtx: u32) -> bool {
        if !self.has_edge(net, vtx) {
            return false;
        }
        let in_base = self.in_base(net, vtx);
        Self::patch_delete(&mut self.net_patch, net, vtx, in_base);
        Self::patch_delete(&mut self.vtx_patch, vtx, net, in_base);
        self.nnz -= 1;
        self.pending += 1;
        self.dirty_vertices.push(vtx);
        self.maybe_compact();
        true
    }

    /// Append a fresh net with the given members; returns its id.
    /// Members beyond the current vertex shape grow it.
    pub fn add_net(&mut self, members: &[u32]) -> u32 {
        let id = self.n_nets as u32;
        self.n_nets += 1;
        self.dims_dirty = true;
        self.dirty_nets.push(id);
        for &u in members {
            self.add_edge(id, u);
        }
        id
    }

    /// Base row merged with its patch: the overlay's view of one row.
    fn merged_row(csr: &Csr, patch: &BTreeMap<u32, Patch>, id: u32) -> Vec<u32> {
        let mut row: Vec<u32> = if (id as usize) < csr.n_rows {
            csr.row(id as usize).to_vec()
        } else {
            Vec::new()
        };
        if let Some(p) = patch.get(&id) {
            row.retain(|x| !p.remove.contains(x));
            row.extend_from_slice(&p.add);
            row.sort_unstable();
        }
        row
    }

    /// `vtxs(v)` under the overlay (allocates; hot paths should compact
    /// and use the CSR directly).
    pub fn vtxs(&self, v: u32) -> Vec<u32> {
        Self::merged_row(&self.base.net_vtxs, &self.net_patch, v)
    }

    /// `nets(u)` under the overlay.
    pub fn nets(&self, u: u32) -> Vec<u32> {
        Self::merged_row(&self.base.vtx_nets, &self.vtx_patch, u)
    }

    fn maybe_compact(&mut self) {
        if self.pending >= self.compact_threshold {
            self.compact();
        }
    }

    /// Fold the overlay back into a fresh CSR (no-op when clean). Dirty
    /// tracking is *not* cleared — it belongs to the repair cycle, not
    /// the storage cycle.
    pub fn compact(&mut self) {
        if self.is_compact() {
            return;
        }
        let mut replace: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
        for &v in self.net_patch.keys() {
            replace.insert(v, Self::merged_row(&self.base.net_vtxs, &self.net_patch, v));
        }
        let csr = self.base.net_vtxs.with_replaced_rows(self.n_nets, self.n_vertices, &replace);
        debug_assert_eq!(csr.nnz(), self.nnz, "overlay nnz bookkeeping out of sync");
        self.base = Bipartite::from_net_incidence(csr);
        self.net_patch.clear();
        self.vtx_patch.clear();
        self.pending = 0;
        self.dims_dirty = false;
    }

    /// Compact (if needed) and expose the CSR view the engines consume.
    pub fn graph(&mut self) -> &Bipartite {
        self.compact();
        &self.base
    }

    /// Drain the dirty sets accumulated since the last call:
    /// `(nets with insertions, endpoints of changed edges)`, sorted and
    /// deduped. Removal-only nets are excluded by construction — a
    /// deletion cannot create a duplicate, so detection there is dead
    /// work (the endpoints still show up in the second list).
    pub fn take_dirty(&mut self) -> (Vec<u32>, Vec<u32>) {
        let mut nets = std::mem::take(&mut self.dirty_nets);
        nets.sort_unstable();
        nets.dedup();
        let mut vtxs = std::mem::take(&mut self.dirty_vertices);
        vtxs.sort_unstable();
        vtxs.dedup();
        (nets, vtxs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::random_bipartite;
    use crate::util::prng::Rng;
    use std::collections::BTreeSet;

    fn tiny() -> Bipartite {
        // n0 -> {0, 1}, n1 -> {1, 2}
        Bipartite::from_net_incidence(Csr::from_edges(2, 3, &[(0, 0), (0, 1), (1, 1), (1, 2)]))
    }

    #[test]
    fn add_remove_roundtrip_and_queries() {
        let mut d = DeltaBipartite::new(tiny());
        assert!(d.has_edge(0, 1));
        assert!(!d.has_edge(0, 2));
        assert!(d.add_edge(0, 2));
        assert!(!d.add_edge(0, 2), "duplicate add is a no-op");
        assert!(d.has_edge(0, 2));
        assert_eq!(d.vtxs(0), vec![0, 1, 2]);
        assert_eq!(d.nets(2), vec![0, 1]);
        assert!(d.remove_edge(0, 0));
        assert!(!d.remove_edge(0, 0), "double remove is a no-op");
        assert_eq!(d.vtxs(0), vec![1, 2]);
        assert_eq!(d.nets(0), Vec::<u32>::new());
        assert_eq!(d.nnz(), 4);
    }

    #[test]
    fn add_then_remove_cancels_cleanly() {
        let mut d = DeltaBipartite::new(tiny());
        let nnz0 = d.nnz();
        assert!(d.add_edge(1, 0));
        assert!(d.remove_edge(1, 0));
        assert_eq!(d.nnz(), nnz0);
        // base edge removed then re-added: back to base state
        assert!(d.remove_edge(0, 1));
        assert!(d.add_edge(0, 1));
        assert_eq!(d.nnz(), nnz0);
        assert_eq!(d.vtxs(0), vec![0, 1]);
        d.compact();
        let g = d.graph();
        g.validate().unwrap();
        assert_eq!(g.vtxs(0), &[0, 1]);
    }

    #[test]
    fn growth_via_new_nets_and_vertices() {
        let mut d = DeltaBipartite::new(tiny());
        let id = d.add_net(&[0, 4]); // vertex 4 is new
        assert_eq!(id, 2);
        assert_eq!(d.n_nets(), 3);
        assert_eq!(d.n_vertices(), 5);
        assert!(d.add_edge(5, 3)); // net 5 is new -> nets 3, 4 implicit empty
        assert_eq!(d.n_nets(), 6);
        let g = d.graph();
        g.validate().unwrap();
        assert_eq!(g.n_nets(), 6);
        assert_eq!(g.n_vertices(), 5);
        assert_eq!(g.vtxs(2), &[0, 4]);
        assert_eq!(g.vtxs(3), &[] as &[u32]);
        assert_eq!(g.vtxs(5), &[3]);
        assert_eq!(g.nets(4), &[2]);
    }

    #[test]
    fn dirty_tracking_is_batch_scoped() {
        let mut d = DeltaBipartite::new(tiny());
        d.add_edge(0, 2);
        d.remove_edge(1, 1); // removal: endpoint dirty, net NOT (no new conflicts)
        d.add_edge(0, 2); // no-op: no extra dirt
        let (nets, vtxs) = d.take_dirty();
        assert_eq!(nets, vec![0], "removal-only nets stay out of detection");
        assert_eq!(vtxs, vec![1, 2]);
        let (nets2, vtxs2) = d.take_dirty();
        assert!(nets2.is_empty() && vtxs2.is_empty(), "drained");
        d.add_edge(1, 0);
        let (nets3, _) = d.take_dirty();
        assert_eq!(nets3, vec![1]);
    }

    #[test]
    fn compaction_matches_ground_truth_edge_set() {
        // Random edit stream mirrored into a plain edge set; the
        // compacted CSR must equal Csr::from_edges of the mirror.
        let g0 = random_bipartite(20, 30, 150, 7);
        let mut rng = Rng::new(99);
        let mut mirror: BTreeSet<(u32, u32)> = BTreeSet::new();
        for v in 0..g0.n_nets() {
            for &u in g0.vtxs(v) {
                mirror.insert((v as u32, u));
            }
        }
        let mut d = DeltaBipartite::new(g0).with_compact_threshold(13);
        for _ in 0..400 {
            let v = rng.range(0, 20) as u32;
            let u = rng.range(0, 30) as u32;
            if rng.chance(0.5) {
                assert_eq!(d.add_edge(v, u), mirror.insert((v, u)));
            } else {
                assert_eq!(d.remove_edge(v, u), mirror.remove(&(v, u)));
            }
        }
        assert_eq!(d.nnz(), mirror.len());
        let edges: Vec<(u32, u32)> = mirror.iter().copied().collect();
        let truth = Csr::from_edges(20, 30, &edges);
        let got = d.graph();
        got.validate().unwrap();
        assert_eq!(got.net_vtxs.ptr, truth.ptr);
        assert_eq!(got.net_vtxs.adj, truth.adj);
    }

    #[test]
    fn threshold_triggers_periodic_compaction() {
        let mut d = DeltaBipartite::new(tiny()).with_compact_threshold(2);
        d.add_edge(0, 2);
        assert_eq!(d.pending(), 1);
        d.add_edge(1, 0); // second edit crosses the threshold
        assert!(d.is_compact(), "auto-compacted at the threshold");
        assert_eq!(d.pending(), 0);
        // dirty sets survive compaction (they belong to the repair cycle)
        let (nets, _) = d.take_dirty();
        assert_eq!(nets, vec![0, 1]);
    }
}
