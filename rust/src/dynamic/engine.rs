//! Incremental repair: the paper's speculate → detect → repeat loop
//! seeded with only the dirty frontier — one implementation, generic
//! over the coloring [`Problem`].
//!
//! After a batch of edge insertions, a stale coloring can only be wrong
//! *near a changed neighborhood*: a deletion never creates a clash, and
//! every new clash runs through an inserted edge. So repair is exactly
//! the machinery the optimistic engine already has, pointed at the
//! dirty set:
//!
//! 1. **Detect** — the net/row-style removal pass restricted to the
//!    insertion-dirty units ([`Problem::conflict_phase_on`]: Algorithm
//!    7 on changed nets for BGPC, Algorithm 10 on changed rows for
//!    D2GC): keep each color's first occurrence per unit, uncolor later
//!    duplicates. Cost: the batch's neighborhood footprint, not
//!    `O(|E|)`.
//! 2. **Repair** — the standard vertex-based speculate/detect loop
//!    ([`Problem::color_phase`] / [`Problem::conflict_phase`],
//!    Algorithms 4–5 and their D2GC analogues) over the uncolored
//!    remainder: detection losers plus brand-new vertices. The work
//!    queue is the dirty vertex frontier's uncolored subset — typically
//!    a vanishing fraction of the vertex set, which is where the
//!    orders-of-magnitude win over full recoloring comes from (Rokos
//!    et al., arXiv:1505.04086, make the same observation for iterated
//!    speculation).
//! 3. The `MAX_ITERS` sequential safety net
//!    ([`Problem::sequential_finish`]) backstops adversarial streams,
//!    identical to the full engines.
//!
//! Why the loop is sound for any [`Problem`]: stale colors are
//! committed before repair begins, so a recolored vertex always sees
//! every kept neighbor color in its forbidden set — clashes can only
//! arise between vertices recolored in the same round, and both are in
//! the work queue, where the conflict phase's tie-break catches them.
//!
//! The caller owns the [`ThreadState`] bank *and* the driver, so the
//! B1/B2 balancing trackers (`col_max`, `col_next`) persist across
//! batches — color-set balance does not degrade as updates stream —
//! and under real threads every region here parks/wakes the caller's
//! persistent [`crate::par::WorkerPool`] team (the session pins one for
//! its lifetime; DESIGN.md §10) instead of spawning.

use crate::coloring::balance::Balance;
use crate::coloring::bgpc::{collect_next, MAX_ITERS};
use crate::coloring::forbidden::ThreadState;
use crate::coloring::schedule::AlgSpec;
use crate::par::{autosite, Chunk, ColorStore, Driver, SharedQueue};

use super::problem::Problem;
use super::BatchStats;

/// Dirty sets are usually far smaller than one chunk per thread; the
/// paper's chunk-64 exists to amortize cursor contention on big queues,
/// but on a tiny queue it serializes the whole repair onto one thread.
/// Fixed spec chunks are therefore routed through the self-tuning
/// [`Chunk::Auto`] repair sites, whose per-dispatch clamp
/// ([`crate::par::auto_effective`]) drops a tiny queue to chunk 1 —
/// what the old size-threshold fallback did by hand — while large
/// frontiers keep a chunk adapted from the observed imbalance of
/// earlier batches. Static scheduling (chunk 0) is kept as-is.
fn repair_chunk(spec_chunk: usize, site: usize) -> usize {
    match Chunk::decode(spec_chunk) {
        Chunk::Static => 0,
        _ => Chunk::Auto(site).encode(),
    }
}

/// Repair `prev` (a valid coloring of the graph *before* the batch)
/// into a valid coloring of `g` (the graph *after* the batch). Generic
/// over the coloring [`Problem`] — the same loop drives BGPC
/// ([`crate::graph::Bipartite`]) and D2GC (square symmetric
/// [`crate::graph::Csr`]).
///
/// * `dirty` — insertion-dirty detection units (nets for BGPC, rows
///   for D2GC; from the overlay's `take_dirty` — removal-only units
///   cannot hold new conflicts and are already excluded there).
/// * `seeds` — endpoints of changed edges; their uncolored subset
///   (brand-new vertices) joins the work queue.
/// * `ts` — caller-owned per-thread state; balancing trackers persist.
///
/// `prev` may be shorter than `g.n_vertices()` (vertex growth); the
/// whole growth tail starts uncolored and is enqueued. Returns the new
/// coloring plus per-batch metrics (`batch_edits` is left at 0 for the
/// session layer to fill).
///
/// Cost note: the *coloring work* scales with the batch footprint, but
/// each call still pays O(|V|) memcpy-class setup (store seeding,
/// scratch vectors, final snapshot) — same class as the session's
/// per-batch compaction, and excluded from the simulated repair time.
pub fn repair<P: Problem, D: Driver>(
    g: &P,
    prev: &[i32],
    dirty: &[u32],
    seeds: &[u32],
    spec: &AlgSpec,
    bal: Balance,
    d: &mut D,
    ts: &mut [ThreadState],
) -> (Vec<i32>, BatchStats) {
    let n = g.n_vertices();
    let t0 = std::time::Instant::now();

    // Seed the store with the stale coloring (commit time 0: visible to
    // every region of this run).
    let colors = d.new_colors(n);
    for (u, &c) in prev.iter().enumerate().take(n) {
        if c >= 0 {
            colors.write(u, c, 0);
        }
    }

    // Forbidden-domain safety: stale colors and persistent balancing
    // trackers may exceed the *new* graph's cap (e.g. after deletions),
    // and B1's safety first-fit can probe past both — size for the sum.
    let prev_max = prev.iter().copied().max().unwrap_or(-1);
    let ts_max = ts.iter().map(|s| s.col_max.max(s.col_next)).max().unwrap_or(0);
    let cap = g.color_cap() + prev_max.max(ts_max).max(0) as usize + 2;
    for s in ts.iter_mut() {
        s.forbidden.ensure(cap);
    }

    let mut sim_secs = 0.0f64;
    let mut work_units = 0u64;

    // --- phase 1: dirty-unit conflict detection (Alg. 7 / Alg. 10 on
    // the subset) ---
    let det_chunk = repair_chunk(spec.chunk, autosite::REPAIR_DETECT);
    let det = {
        let _sp = crate::obs::trace::span_n("repair.detect_dirty", dirty.len() as u64);
        g.conflict_phase_on(dirty, &colors, d, ts, det_chunk)
    };
    let is_sim = det.sim_ns.is_some();
    sim_secs += det.seconds();
    work_units += det.busy_units.iter().sum::<u64>();

    // Dirty vertex frontier: the neighborhoods of changed units, the
    // changed edges' endpoints, and the whole growth tail — id-gap
    // growth (e.g. adding vertex 95 to a 90-vertex graph) creates
    // vertices 90..95 that appear in no edit but still need a color.
    // The frontier's uncolored subset is the initial work queue.
    let mut frontier: Vec<u32> = Vec::with_capacity(seeds.len());
    g.extend_frontier(dirty, &mut frontier);
    frontier.extend_from_slice(seeds);
    frontier.extend(prev.len() as u32..n as u32);
    frontier.retain(|&u| (u as usize) < n);
    frontier.sort_unstable();
    frontier.dedup();
    let frontier_size = frontier.len();
    let mut w: Vec<u32> = frontier
        .iter()
        .copied()
        .filter(|&u| colors.committed(u as usize) == -1)
        .collect();
    let conflicts = w.len();

    // --- phase 2: vertex-based speculate/detect over the remainder ---
    let color_chunk = repair_chunk(spec.chunk, autosite::REPAIR_SPECULATE);
    let shared = SharedQueue::with_capacity(n);
    let mut recolored_mark = vec![false; n];
    let mut recolored = 0usize;
    let mut iterations = 0usize;
    while !w.is_empty() && iterations < MAX_ITERS {
        iterations += 1;
        for &u in &w {
            let u = u as usize;
            if !recolored_mark[u] {
                recolored_mark[u] = true;
                recolored += 1;
            }
        }
        let cr = {
            let _sp = crate::obs::trace::span_n("repair.speculate", w.len() as u64);
            g.color_phase(&w, &colors, d, ts, color_chunk, bal)
        };
        sim_secs += cr.seconds();
        work_units += cr.busy_units.iter().sum::<u64>();
        let rr = {
            let _sp = crate::obs::trace::span_n("repair.detect", w.len() as u64);
            g.conflict_phase(&w, &colors, d, ts, det_chunk, spec.lazy_queues, &shared)
        };
        sim_secs += rr.seconds();
        work_units += rr.busy_units.iter().sum::<u64>();
        w = collect_next(spec.lazy_queues, ts, &shared);
    }
    if !w.is_empty() {
        // adversarial stream: same safety net as the full engines
        for &u in &w {
            let u = u as usize;
            if !recolored_mark[u] {
                recolored_mark[u] = true;
                recolored += 1;
            }
        }
        let _sp = crate::obs::trace::span_n("repair.seq_finish", w.len() as u64);
        g.sequential_finish(&w, &colors, &mut ts[0], d.now());
    }

    let colors_vec = colors.to_vec();
    let n_colors = crate::coloring::stats::distinct_colors(&colors_vec);
    let prev_n_colors = crate::coloring::stats::distinct_colors(prev);
    let stats = BatchStats {
        batch_edits: 0,
        dirty_nets: dirty.len(),
        frontier: frontier_size,
        conflicts,
        recolored,
        colors_added: n_colors.saturating_sub(prev_n_colors),
        n_colors,
        iterations,
        seconds: if is_sim { sim_secs } else { t0.elapsed().as_secs_f64() },
        compact_seconds: 0.0,
        work_units,
    };
    (colors_vec, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::schedule;
    use crate::coloring::verify::{bgpc_valid, d2gc_valid};
    use crate::dynamic::{DeltaBipartite, DeltaSymmetric};
    use crate::graph::{Bipartite, Csr};
    use crate::par::ThreadsDriver;
    use crate::sim::{CostModel, SimDriver};

    #[test]
    fn repair_fixes_a_planted_edge_conflict() {
        // nets: n0 = {0,1}, n1 = {2,3}; valid coloring [0,1,0,1].
        let m = Csr::from_edges(2, 4, &[(0, 0), (0, 1), (1, 2), (1, 3)]);
        let mut delta = DeltaBipartite::new(Bipartite::from_net_incidence(m));
        let prev = vec![0, 1, 0, 1];
        // add (n0, 2): net 0 becomes {0,1,2} with colors {0,1,0} — clash.
        assert!(delta.add_edge(0, 2));
        let (dirty_nets, seeds) = delta.take_dirty();
        assert_eq!(dirty_nets, vec![0]);
        assert_eq!(seeds, vec![2]);
        let g = delta.graph().clone();
        let mut ts = ThreadState::bank(2, 64);
        let mut d = ThreadsDriver::new(2);
        let (colors, stats) = repair(
            &g,
            &prev,
            &dirty_nets,
            &seeds,
            &schedule::V_V_64D,
            Balance::None,
            &mut d,
            &mut ts,
        );
        assert!(bgpc_valid(&g, &colors).is_ok());
        assert_eq!(stats.conflicts, 1);
        assert_eq!(stats.recolored, 1, "only the clash loser is recolored");
        assert_eq!(colors[0], 0, "untouched vertices keep their colors");
        assert_eq!(colors[1], 1);
        assert_eq!(colors[3], 1);
        assert_eq!(colors[2], 2, "loser takes the first free color");
    }

    #[test]
    fn removal_only_batches_recolor_nothing() {
        let m = Csr::from_edges(2, 4, &[(0, 0), (0, 1), (1, 1), (1, 2), (1, 3)]);
        let mut delta = DeltaBipartite::new(Bipartite::from_net_incidence(m));
        let prev = vec![0, 1, 0, 2];
        assert!(delta.remove_edge(1, 3));
        let (dirty_nets, seeds) = delta.take_dirty();
        let g = delta.graph().clone();
        let mut ts = ThreadState::bank(1, 64);
        let mut d = ThreadsDriver::new(1);
        let (colors, stats) = repair(
            &g,
            &prev,
            &dirty_nets,
            &seeds,
            &schedule::V_V_64D,
            Balance::None,
            &mut d,
            &mut ts,
        );
        assert!(bgpc_valid(&g, &colors).is_ok());
        assert_eq!(stats.conflicts, 0);
        assert_eq!(stats.recolored, 0);
        assert_eq!(colors, prev, "deletions never perturb the coloring");
    }

    #[test]
    fn repair_is_deterministic_under_the_simulator() {
        let m = Csr::from_edges(3, 6, &[(0, 0), (0, 1), (0, 2), (1, 2), (1, 3), (2, 4), (2, 5)]);
        let g0 = Bipartite::from_net_incidence(m);
        let prev = vec![0, 1, 2, 0, 0, 1];
        let run = || {
            let mut delta = DeltaBipartite::new(g0.clone());
            delta.add_edge(0, 3);
            delta.add_edge(2, 0);
            let (dn, sd) = delta.take_dirty();
            let g = delta.graph().clone();
            let mut ts = ThreadState::bank(4, 64);
            let mut d = SimDriver::new(4, CostModel::default());
            repair(&g, &prev, &dn, &sd, &schedule::N1_N2, Balance::None, &mut d, &mut ts)
        };
        let (c1, s1) = run();
        let (c2, s2) = run();
        assert_eq!(c1, c2);
        assert_eq!(s1.seconds, s2.seconds);
        assert_eq!(s1.recolored, s2.recolored);
    }

    #[test]
    fn d2gc_repair_fixes_a_planted_distance2_clash() {
        // path 0-1-2 plus isolated 3 (diagonals present): [0,1,2,1] is
        // a valid distance-2 coloring. Inserting {2,3} puts 3 at
        // distance 2 from 1 through the new edge — c(3)=c(1)=1 is now
        // a clash the dirty-row scan must catch.
        let m = Csr::from_edges(
            4,
            4,
            &[(0, 0), (1, 1), (2, 2), (3, 3), (0, 1), (1, 0), (1, 2), (2, 1)],
        );
        let mut delta = DeltaSymmetric::new(m);
        let prev = vec![0, 1, 2, 1];
        assert!(delta.add_edge(2, 3));
        let (dirty, seeds) = delta.take_dirty();
        assert_eq!(dirty, vec![2, 3], "both endpoints are dirty rows");
        assert_eq!(seeds, vec![2, 3]);
        let g = delta.graph().clone();
        // single thread: row 2 is scanned before row 3, so exactly
        // vertex 3 loses (both dirty rows racing would also be valid,
        // just not bit-predictable)
        let mut ts = ThreadState::bank(1, 64);
        let mut d = ThreadsDriver::new(1);
        let (colors, stats) = repair(
            &g,
            &prev,
            &dirty,
            &seeds,
            &schedule::V_V_64D,
            Balance::None,
            &mut d,
            &mut ts,
        );
        assert!(d2gc_valid(&g, &colors).is_ok());
        assert_eq!(colors[0], 0, "vertices away from the edit keep their colors");
        assert_eq!(colors[1], 1);
        assert_eq!(colors[2], 2, "the scan of row 2 keeps the visited vertex");
        assert_eq!(stats.conflicts, 1);
        assert_eq!(stats.recolored, 1, "only the clash loser is recolored");
        assert_eq!(colors[3], 0, "3 avoids 2 (distance 1) and 1 (distance 2)");
    }

    #[test]
    fn d2gc_removal_only_batches_recolor_nothing() {
        let g0 = crate::graph::generators::random_symmetric(30, 80, 9);
        let order: Vec<u32> = (0..30u32).collect();
        let (prev, _) = crate::coloring::d2gc::seq_greedy(&g0, &order);
        let mut delta = DeltaSymmetric::new(g0);
        // remove a handful of existing off-diagonal edges
        let mut removed = 0;
        for v in 0..30u32 {
            if let Some(&u) = delta.row(v).iter().find(|&&u| u != v) {
                removed += usize::from(delta.remove_edge(v, u));
            }
            if removed >= 5 {
                break;
            }
        }
        assert!(removed >= 1);
        let (dirty, seeds) = delta.take_dirty();
        assert!(dirty.is_empty(), "removals never enter detection");
        let g = delta.graph().clone();
        let mut ts = ThreadState::bank(1, 256);
        let mut d = ThreadsDriver::new(1);
        let (colors, stats) = repair(
            &g,
            &prev,
            &dirty,
            &seeds,
            &schedule::V_V_64D,
            Balance::None,
            &mut d,
            &mut ts,
        );
        assert!(d2gc_valid(&g, &colors).is_ok());
        assert_eq!(stats.recolored, 0);
        assert_eq!(colors, prev, "deletions never perturb the coloring");
    }

    #[test]
    fn d2gc_repair_is_deterministic_under_the_simulator() {
        let g0 = crate::graph::generators::random_symmetric(40, 120, 3);
        let order: Vec<u32> = (0..40u32).collect();
        let (prev, _) = crate::coloring::d2gc::seq_greedy(&g0, &order);
        let run = || {
            let mut delta = DeltaSymmetric::new(g0.clone());
            delta.add_edge(0, 17);
            delta.add_edge(5, 33);
            let (dirty, seeds) = delta.take_dirty();
            let g = delta.graph().clone();
            let mut ts = ThreadState::bank(4, 256);
            let mut d = SimDriver::new(4, CostModel::default());
            repair(&g, &prev, &dirty, &seeds, &schedule::N1_N2, Balance::None, &mut d, &mut ts)
        };
        let (c1, s1) = run();
        let (c2, s2) = run();
        assert_eq!(c1, c2);
        assert_eq!(s1.seconds, s2.seconds);
        let mut dd = DeltaSymmetric::new(g0.clone());
        dd.add_edge(0, 17);
        dd.add_edge(5, 33);
        assert!(d2gc_valid(dd.graph(), &c1).is_ok());
    }
}
