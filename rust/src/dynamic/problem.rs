//! The problem seam: what [`super::engine::repair`] actually needs.
//!
//! The paper's central claim is that the optimistic speculate → detect
//! loop is *problem-agnostic*: §VI ports every BGPC phase variant to
//! distance-2 graph coloring by swapping the neighborhood definition
//! and keeping the loop. Rokos et al. (arXiv:1505.04086) make the same
//! point for the repair formulation — once conflict detection is
//! factored out, speculate-and-repair does not care which coloring
//! problem it is fixing. This module encodes that observation as two
//! traits instead of two parallel code paths:
//!
//! * [`Problem`] — implemented *on the graph type itself* ([`Bipartite`]
//!   for BGPC, a square symmetric [`Csr`] for D2GC), it bundles the
//!   five capabilities the incremental engine consumes: dirty-frontier
//!   conflict detection, frontier expansion, the vertex-based
//!   speculate/detect phases (balance-aware color selection included),
//!   the sequential safety net, and a full capped run for session
//!   bring-up. One generic [`super::engine::repair`] drives both.
//! * [`DeltaOps`] — the mutable overlay contract
//!   ([`super::DeltaBipartite`] / [`super::DeltaSymmetric`]): batched
//!   edits, dirty tracking, compaction back to the frozen graph the
//!   phase kernels consume. Each problem names its overlay via
//!   [`Problem::Delta`], so the overlay enforces the problem's
//!   structural invariant (both incidence directions in sync for BGPC;
//!   structural symmetry of the square CSR for D2GC).
//!
//! Note the type-level pun: the *trait* `dynamic::Problem` is the
//! capability seam; the *enum* [`crate::coloring::Problem`] (exposed
//! here as [`Problem::KIND`]) stays the plain tag the coordinator's
//! metrics and routing report.

use crate::coloring::balance::Balance;
use crate::coloring::forbidden::ThreadState;
use crate::coloring::schedule::AlgSpec;
use crate::coloring::verify::Violation;
use crate::coloring::{bgpc, d1gc, d2gc, ColoringResult, Problem as ProblemKind};
use crate::graph::{Bipartite, Csr, Ordering};
use crate::par::{ColorStore, Driver, RegionOut, SharedQueue};

use super::delta::{DeltaBipartite, DeltaSymmetric};

/// The mutable-overlay contract the session layer streams edits
/// through. Edits are *problem-shaped*: for BGPC `(a, b)` is the
/// incidence (net `a`, vertex `b`); for D2GC it is the undirected edge
/// `{a, b}` and the overlay mirrors it to keep the square CSR
/// structurally symmetric.
pub trait DeltaOps: Send {
    /// The frozen graph type the phase kernels consume.
    type Graph;

    /// Insert one edit unit; returns whether the graph changed
    /// (duplicates are no-ops). Ids beyond the current shape grow it.
    fn add_edge(&mut self, a: u32, b: u32) -> bool;

    /// Delete one edit unit; returns whether it existed.
    fn remove_edge(&mut self, a: u32, b: u32) -> bool;

    /// Append a fresh constraint row: a new net over `members` for
    /// BGPC, a new vertex adjacent to `members` for D2GC. Returns how
    /// many *member edits* were actually applied (duplicates are
    /// no-ops; the symmetric overlay's mirrored incidences and the
    /// fresh row's diagonal count as part of the row, not as member
    /// edits) — the unit of the session's `batch_edits` metric.
    fn add_net(&mut self, members: &[u32]) -> usize;

    /// Logical incidence count under the overlay (metrics). Directed:
    /// the symmetric overlay counts each off-diagonal undirected edge
    /// twice.
    fn nnz(&self) -> usize;

    /// Compact (if needed) and expose the frozen graph view.
    fn graph(&mut self) -> &Self::Graph;

    /// Drain the dirty sets accumulated since the last call:
    /// `(insertion-dirty detection units, endpoints of changed edges)`,
    /// sorted and deduped.
    fn take_dirty(&mut self) -> (Vec<u32>, Vec<u32>);
}

/// A coloring problem the incremental engine can repair — see the
/// module docs for why this is implemented on the graph type itself.
pub trait Problem: Clone + Send + Sync + Sized + 'static {
    /// The overlay that preserves this problem's structural invariant.
    type Delta: DeltaOps<Graph = Self>;

    /// The plain tag ([`crate::coloring::Problem`]) the service layer
    /// reports for sessions of this problem.
    const KIND: ProblemKind;

    /// Cheap structural validation, run by
    /// [`super::DynamicSession::start`] *before* any coloring work —
    /// fail fast with the problem's own message instead of deep inside
    /// a kernel. Default: every graph is acceptable.
    ///
    /// # Panics
    /// When the graph violates the problem's structural contract
    /// (D2GC: square and structurally symmetric).
    fn validate_input(&self) {}

    /// The *stateless-run* precondition — the strictly weaker check the
    /// one-shot [`crate::coloring::color`] entry point applies before a
    /// full run. Sessions use [`Problem::validate_input`] (which may be
    /// O(nnz), e.g. the structural-symmetry scan); a plain capped run
    /// historically only asserted shape, and keeping that split
    /// preserves both the old costs and the old panic messages.
    ///
    /// # Panics
    /// When the graph cannot be colored at all under this problem
    /// (D2GC/D1GC: a non-square adjacency).
    fn check_colorable(&self) {}

    /// Number of vertices to color.
    fn n_vertices(&self) -> usize;

    /// Upper bound on any color the engine can produce (forbidden-array
    /// sizing).
    fn color_cap(&self) -> usize;

    /// Wrap the frozen graph into its mutable overlay.
    fn into_delta(self) -> Self::Delta;

    /// Compute the initial visit order for a full run.
    fn order(&self, ordering: &Ordering) -> Vec<u32>;

    /// Dirty-frontier conflict detection: the net/row-style removal
    /// pass (Alg. 7 / Alg. 10) restricted to the insertion-dirty units,
    /// uncoloring every clash loser the batch could have created.
    fn conflict_phase_on<D: Driver>(
        &self,
        dirty: &[u32],
        colors: &D::Colors,
        d: &mut D,
        ts: &mut [ThreadState],
        chunk: usize,
    ) -> RegionOut;

    /// Expand the dirty units into the vertex frontier detection may
    /// have uncolored (net members for BGPC; the closed distance-1
    /// neighborhood of dirty rows for D2GC).
    fn extend_frontier(&self, dirty: &[u32], out: &mut Vec<u32>);

    /// Vertex-based speculative coloring over the work queue (Alg. 4 /
    /// its D2GC analogue), with balance-aware color selection.
    fn color_phase<D: Driver>(
        &self,
        w: &[u32],
        colors: &D::Colors,
        d: &mut D,
        ts: &mut [ThreadState],
        chunk: usize,
        bal: Balance,
    ) -> RegionOut;

    /// Vertex-based conflict detection over the work queue (Alg. 5 /
    /// its D2GC analogue), requeueing losers.
    fn conflict_phase<D: Driver>(
        &self,
        w: &[u32],
        colors: &D::Colors,
        d: &mut D,
        ts: &mut [ThreadState],
        chunk: usize,
        lazy: bool,
        shared: &SharedQueue,
    ) -> RegionOut;

    /// Exact sequential greedy over the remaining queue — the
    /// `MAX_ITERS` safety net.
    fn sequential_finish<C: ColorStore>(
        &self,
        w: &[u32],
        colors: &C,
        ts0: &mut ThreadState,
        now: u64,
    );

    /// Full engine run with a caller-owned [`ThreadState`] bank and an
    /// iteration cap (session bring-up; `cap = 0` is the sequential
    /// greedy baseline).
    fn run_capped<D: Driver>(
        &self,
        order: &[u32],
        spec: &AlgSpec,
        bal: Balance,
        d: &mut D,
        ts: &mut [ThreadState],
        max_iters: usize,
    ) -> ColoringResult;

    /// Ground-truth validity of `colors` against this graph.
    fn verify(&self, colors: &[i32]) -> Result<(), Violation>;
}

impl Problem for Bipartite {
    type Delta = DeltaBipartite;
    const KIND: ProblemKind = ProblemKind::Bgpc;

    fn n_vertices(&self) -> usize {
        self.vtx_nets.n_rows
    }

    fn color_cap(&self) -> usize {
        bgpc::color_cap(self)
    }

    fn into_delta(self) -> DeltaBipartite {
        DeltaBipartite::new(self)
    }

    fn order(&self, ordering: &Ordering) -> Vec<u32> {
        ordering.compute(self)
    }

    fn conflict_phase_on<D: Driver>(
        &self,
        dirty: &[u32],
        colors: &D::Colors,
        d: &mut D,
        ts: &mut [ThreadState],
        chunk: usize,
    ) -> RegionOut {
        bgpc::net::conflict_phase_on(self, dirty, colors, d, ts, chunk)
    }

    fn extend_frontier(&self, dirty: &[u32], out: &mut Vec<u32>) {
        // nets are not colored: the frontier is their member vertices
        for &v in dirty {
            out.extend_from_slice(self.vtxs(v as usize));
        }
    }

    fn color_phase<D: Driver>(
        &self,
        w: &[u32],
        colors: &D::Colors,
        d: &mut D,
        ts: &mut [ThreadState],
        chunk: usize,
        bal: Balance,
    ) -> RegionOut {
        bgpc::vertex::color_phase(self, w, colors, d, ts, chunk, bal)
    }

    fn conflict_phase<D: Driver>(
        &self,
        w: &[u32],
        colors: &D::Colors,
        d: &mut D,
        ts: &mut [ThreadState],
        chunk: usize,
        lazy: bool,
        shared: &SharedQueue,
    ) -> RegionOut {
        bgpc::vertex::conflict_phase(self, w, colors, d, ts, chunk, lazy, shared)
    }

    fn sequential_finish<C: ColorStore>(
        &self,
        w: &[u32],
        colors: &C,
        ts0: &mut ThreadState,
        now: u64,
    ) {
        bgpc::sequential_finish(self, w, colors, ts0, now)
    }

    fn run_capped<D: Driver>(
        &self,
        order: &[u32],
        spec: &AlgSpec,
        bal: Balance,
        d: &mut D,
        ts: &mut [ThreadState],
        max_iters: usize,
    ) -> ColoringResult {
        bgpc::run_capped(self, order, spec, bal, d, ts, max_iters)
    }

    fn verify(&self, colors: &[i32]) -> Result<(), Violation> {
        crate::coloring::verify::bgpc_valid(self, colors)
    }
}

impl Problem for Csr {
    type Delta = DeltaSymmetric;
    const KIND: ProblemKind = ProblemKind::D2gc;

    fn validate_input(&self) {
        assert!(
            self.is_structurally_symmetric(),
            "D2GC requires a square, structurally symmetric graph"
        );
    }

    fn check_colorable(&self) {
        assert_eq!(self.n_rows, self.n_cols, "D2GC needs a square graph");
    }

    fn n_vertices(&self) -> usize {
        self.n_rows
    }

    fn color_cap(&self) -> usize {
        d2gc::color_cap(self)
    }

    fn into_delta(self) -> DeltaSymmetric {
        DeltaSymmetric::new(self)
    }

    fn order(&self, ordering: &Ordering) -> Vec<u32> {
        match *ordering {
            Ordering::Natural => (0..self.n_rows as u32).collect(),
            // Orderings beyond natural are defined on the bipartite
            // view: reuse them by treating rows as nets over the same
            // vertex set (mirrors the one-shot D2GC entry point).
            ref o => o.compute(&Bipartite::from_net_incidence(self.clone())),
        }
    }

    fn conflict_phase_on<D: Driver>(
        &self,
        dirty: &[u32],
        colors: &D::Colors,
        d: &mut D,
        ts: &mut [ThreadState],
        chunk: usize,
    ) -> RegionOut {
        d2gc::conflict_phase_on(self, dirty, colors, d, ts, chunk)
    }

    fn extend_frontier(&self, dirty: &[u32], out: &mut Vec<u32>) {
        // rows are colored too: the closed distance-1 neighborhood
        for &v in dirty {
            out.push(v);
            out.extend_from_slice(self.row(v as usize));
        }
    }

    fn color_phase<D: Driver>(
        &self,
        w: &[u32],
        colors: &D::Colors,
        d: &mut D,
        ts: &mut [ThreadState],
        chunk: usize,
        bal: Balance,
    ) -> RegionOut {
        d2gc::vertex::color_phase(self, w, colors, d, ts, chunk, bal)
    }

    fn conflict_phase<D: Driver>(
        &self,
        w: &[u32],
        colors: &D::Colors,
        d: &mut D,
        ts: &mut [ThreadState],
        chunk: usize,
        lazy: bool,
        shared: &SharedQueue,
    ) -> RegionOut {
        d2gc::vertex::conflict_phase(self, w, colors, d, ts, chunk, lazy, shared)
    }

    fn sequential_finish<C: ColorStore>(
        &self,
        w: &[u32],
        colors: &C,
        ts0: &mut ThreadState,
        now: u64,
    ) {
        d2gc::sequential_finish(self, w, colors, ts0, now)
    }

    fn run_capped<D: Driver>(
        &self,
        order: &[u32],
        spec: &AlgSpec,
        bal: Balance,
        d: &mut D,
        ts: &mut [ThreadState],
        max_iters: usize,
    ) -> ColoringResult {
        d2gc::run_capped(self, order, spec, bal, d, ts, max_iters)
    }

    fn verify(&self, colors: &[i32]) -> Result<(), Violation> {
        crate::coloring::verify::d2gc_valid(self, colors)
    }
}

/// The distance-1 problem's graph type: a square structurally symmetric
/// [`Csr`] adjacency, wrapped so `Problem` can dispatch to the D1GC
/// phases (the bare `Csr` already means D2GC). `repr(transparent)`
/// guarantees the same layout as `Csr`, which [`D1Graph::from_ref`]
/// relies on to view a borrowed adjacency as a borrowed problem without
/// cloning (the post-pass helpers in `coloring::mod` use this).
#[derive(Clone, Debug)]
#[repr(transparent)]
pub struct D1Graph(pub Csr);

impl D1Graph {
    /// Wrap an owned adjacency.
    pub fn new(g: Csr) -> D1Graph {
        D1Graph(g)
    }

    /// View a borrowed adjacency as a borrowed problem. Sound because
    /// `D1Graph` is `repr(transparent)` over `Csr`.
    pub fn from_ref(g: &Csr) -> &D1Graph {
        unsafe { &*(g as *const Csr as *const D1Graph) }
    }

    /// The underlying adjacency.
    pub fn as_csr(&self) -> &Csr {
        &self.0
    }
}

/// The D1GC overlay: the symmetric overlay with the frozen view
/// re-wrapped as [`D1Graph`] — distance-1 coloring shares D2GC's
/// structural invariant (square, mirrored edges), only the coloring
/// distance differs.
pub struct DeltaD1(DeltaSymmetric);

impl Problem for D1Graph {
    type Delta = DeltaD1;
    const KIND: ProblemKind = ProblemKind::D1gc;

    fn validate_input(&self) {
        assert!(
            self.0.is_structurally_symmetric(),
            "D1GC requires a square, structurally symmetric graph"
        );
    }

    fn check_colorable(&self) {
        assert_eq!(self.0.n_rows, self.0.n_cols, "D1GC needs a square graph");
    }

    fn n_vertices(&self) -> usize {
        self.0.n_rows
    }

    fn color_cap(&self) -> usize {
        d1gc::color_cap(&self.0)
    }

    fn into_delta(self) -> DeltaD1 {
        DeltaD1(DeltaSymmetric::new(self.0))
    }

    fn order(&self, ordering: &Ordering) -> Vec<u32> {
        // same bipartite-view reuse as the D2GC impl
        Problem::order(&self.0, ordering)
    }

    fn conflict_phase_on<D: Driver>(
        &self,
        dirty: &[u32],
        colors: &D::Colors,
        d: &mut D,
        ts: &mut [ThreadState],
        chunk: usize,
    ) -> RegionOut {
        d1gc::conflict_phase_on(&self.0, dirty, colors, d, ts, chunk)
    }

    fn extend_frontier(&self, dirty: &[u32], out: &mut Vec<u32>) {
        // the closed distance-1 neighborhood, like D2GC: detection may
        // have uncolored any neighbor of a dirty row
        for &v in dirty {
            out.push(v);
            out.extend_from_slice(self.0.row(v as usize));
        }
    }

    fn color_phase<D: Driver>(
        &self,
        w: &[u32],
        colors: &D::Colors,
        d: &mut D,
        ts: &mut [ThreadState],
        chunk: usize,
        bal: Balance,
    ) -> RegionOut {
        d1gc::color_phase(&self.0, w, colors, d, ts, chunk, bal)
    }

    fn conflict_phase<D: Driver>(
        &self,
        w: &[u32],
        colors: &D::Colors,
        d: &mut D,
        ts: &mut [ThreadState],
        chunk: usize,
        lazy: bool,
        shared: &SharedQueue,
    ) -> RegionOut {
        d1gc::conflict_phase(&self.0, w, colors, d, ts, chunk, lazy, shared)
    }

    fn sequential_finish<C: ColorStore>(
        &self,
        w: &[u32],
        colors: &C,
        ts0: &mut ThreadState,
        now: u64,
    ) {
        d1gc::sequential_finish(&self.0, w, colors, ts0, now)
    }

    fn run_capped<D: Driver>(
        &self,
        order: &[u32],
        spec: &AlgSpec,
        bal: Balance,
        d: &mut D,
        ts: &mut [ThreadState],
        max_iters: usize,
    ) -> ColoringResult {
        d1gc::run_capped(&self.0, order, spec, bal, d, ts, max_iters)
    }

    fn verify(&self, colors: &[i32]) -> Result<(), Violation> {
        crate::coloring::verify::d1gc_valid(&self.0, colors)
    }
}

impl DeltaOps for DeltaBipartite {
    type Graph = Bipartite;

    fn add_edge(&mut self, a: u32, b: u32) -> bool {
        DeltaBipartite::add_edge(self, a, b)
    }

    fn remove_edge(&mut self, a: u32, b: u32) -> bool {
        DeltaBipartite::remove_edge(self, a, b)
    }

    fn add_net(&mut self, members: &[u32]) -> usize {
        DeltaBipartite::add_net_counted(self, members).1
    }

    fn nnz(&self) -> usize {
        DeltaBipartite::nnz(self)
    }

    fn graph(&mut self) -> &Bipartite {
        DeltaBipartite::graph(self)
    }

    fn take_dirty(&mut self) -> (Vec<u32>, Vec<u32>) {
        DeltaBipartite::take_dirty(self)
    }
}

impl DeltaOps for DeltaSymmetric {
    type Graph = Csr;

    fn add_edge(&mut self, a: u32, b: u32) -> bool {
        DeltaSymmetric::add_edge(self, a, b)
    }

    fn remove_edge(&mut self, a: u32, b: u32) -> bool {
        DeltaSymmetric::remove_edge(self, a, b)
    }

    fn add_net(&mut self, members: &[u32]) -> usize {
        DeltaSymmetric::add_vertex_counted(self, members).1
    }

    fn nnz(&self) -> usize {
        DeltaSymmetric::nnz(self)
    }

    fn graph(&mut self) -> &Csr {
        DeltaSymmetric::graph(self)
    }

    fn take_dirty(&mut self) -> (Vec<u32>, Vec<u32>) {
        DeltaSymmetric::take_dirty(self)
    }
}

impl DeltaOps for DeltaD1 {
    type Graph = D1Graph;

    fn add_edge(&mut self, a: u32, b: u32) -> bool {
        DeltaSymmetric::add_edge(&mut self.0, a, b)
    }

    fn remove_edge(&mut self, a: u32, b: u32) -> bool {
        DeltaSymmetric::remove_edge(&mut self.0, a, b)
    }

    fn add_net(&mut self, members: &[u32]) -> usize {
        DeltaSymmetric::add_vertex_counted(&mut self.0, members).1
    }

    fn nnz(&self) -> usize {
        DeltaSymmetric::nnz(&self.0)
    }

    fn graph(&mut self) -> &D1Graph {
        D1Graph::from_ref(DeltaSymmetric::graph(&mut self.0))
    }

    fn take_dirty(&mut self) -> (Vec<u32>, Vec<u32>) {
        DeltaSymmetric::take_dirty(&mut self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{random_bipartite, random_symmetric};

    #[test]
    fn kinds_and_caps_line_up() {
        let b = random_bipartite(10, 20, 60, 1);
        assert_eq!(<Bipartite as Problem>::KIND, ProblemKind::Bgpc);
        assert_eq!(Problem::color_cap(&b), bgpc::color_cap(&b));
        let s = random_symmetric(15, 40, 2);
        assert_eq!(<Csr as Problem>::KIND, ProblemKind::D2gc);
        assert_eq!(Problem::color_cap(&s), d2gc::color_cap(&s));
        assert_eq!(Problem::n_vertices(&s), 15);
    }

    #[test]
    fn frontier_shapes_match_the_problem() {
        // BGPC: members of the dirty nets only (nets are not colored).
        let b = random_bipartite(5, 8, 20, 3);
        let mut f = Vec::new();
        Problem::extend_frontier(&b, &[2], &mut f);
        assert_eq!(f, b.vtxs(2).to_vec());
        // D2GC: the dirty row itself plus its neighbors.
        let s = random_symmetric(10, 20, 4);
        let mut f = Vec::new();
        Problem::extend_frontier(&s, &[3], &mut f);
        assert_eq!(f[0], 3);
        assert_eq!(&f[1..], s.row(3));
    }

    #[test]
    fn add_net_counts_member_edits_in_problem_units() {
        let mut d = Problem::into_delta(random_bipartite(3, 5, 8, 1));
        // fresh net: both members effective, the duplicate is a no-op
        assert_eq!(DeltaOps::add_net(&mut d, &[0, 1, 1]), 2);
        let mut s = Problem::into_delta(random_symmetric(4, 6, 2));
        // mirrored pairs and the diagonal count as part of the row
        assert_eq!(DeltaOps::add_net(&mut s, &[0, 0, 2]), 2);
        assert_eq!(DeltaOps::add_net(&mut s, &[]), 0, "bare row: no member edits");
    }

    #[test]
    fn d1_graph_mirrors_the_csr_problem_shape() {
        let s = random_symmetric(15, 40, 2);
        let g = D1Graph::new(s.clone());
        assert_eq!(<D1Graph as Problem>::KIND, ProblemKind::D1gc);
        assert_eq!(Problem::color_cap(&g), d1gc::color_cap(&s));
        assert_eq!(Problem::n_vertices(&g), 15);
        // from_ref is a view, not a copy
        assert!(std::ptr::eq(D1Graph::from_ref(&s).as_csr(), &s));
        // frontier: closed distance-1 neighborhood, like D2GC
        let mut f = Vec::new();
        Problem::extend_frontier(&g, &[3], &mut f);
        assert_eq!(f[0], 3);
        assert_eq!(&f[1..], s.row(3));
        // the overlay streams symmetric edits and re-wraps the view
        let mut dl = Problem::into_delta(g);
        assert!(DeltaOps::add_edge(&mut dl, 0, 14));
        assert!(DeltaOps::graph(&mut dl).as_csr().row(0).contains(&14));
    }

    #[test]
    fn natural_order_is_identity_for_both() {
        let b = random_bipartite(6, 9, 25, 5);
        assert_eq!(Problem::order(&b, &Ordering::Natural), (0..9u32).collect::<Vec<_>>());
        let s = random_symmetric(7, 10, 6);
        assert_eq!(Problem::order(&s, &Ordering::Natural), (0..7u32).collect::<Vec<_>>());
    }
}
