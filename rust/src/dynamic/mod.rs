//! Incremental coloring — streaming graph updates against a live
//! coloring, generic over the coloring problem.
//!
//! The paper's optimistic speculate → detect → repeat loop (Algorithms
//! 1, 4–10) is naturally incremental: after a batch of edge insertions
//! and deletions, only vertices whose relevant neighborhoods changed
//! can conflict, so the same conflict-detection machinery that repairs
//! speculative races repairs a *stale* coloring at the cost of the
//! batch footprint instead of the graph. And because §VI of the paper
//! derives the D2GC phases from the BGPC ones by swapping the
//! neighborhood definition, the incremental engine is written once,
//! against a [`Problem`] seam, and drives both. This module packages
//! that observation as a subsystem:
//!
//! * [`Problem`] / [`DeltaOps`] ([`problem`]) — the seam: what
//!   [`engine::repair`] actually needs from a coloring problem
//!   (dirty-frontier detection, frontier expansion, the vertex-based
//!   speculate/detect phases with balance-aware selection, the
//!   sequential safety net), implemented on the graph types themselves
//!   — [`crate::graph::Bipartite`] for BGPC, a square symmetric
//!   [`crate::graph::Csr`] for D2GC.
//! * [`DeltaBipartite`] / [`DeltaSymmetric`] ([`delta`]) — mutable
//!   overlays over the frozen CSR: batched `add_edge` / `remove_edge` /
//!   `add_net` with dirty tracking and periodic compaction back to CSR.
//!   The symmetric overlay mirrors every edit so the square D2GC graph
//!   stays structurally symmetric across the stream.
//! * [`engine::repair`] — dirty-unit detection (Algorithm 7 / 10 on
//!   the changed subset) followed by the standard vertex-based repair
//!   loop over the uncolored remainder, reusing the phase variants,
//!   the `ThreadState` forbidden arrays and `verify` unchanged.
//! * [`DynamicSession`] — graph + coloring + persistent per-thread
//!   state; one [`DynamicSession::apply`] per batch, returning
//!   [`BatchStats`]. The B1/B2 balancing trackers live in the session,
//!   so color-set balance survives the stream. [`BgpcSession`] and
//!   [`D2gcSession`] and [`D1gcSession`] are the instantiations
//!   ([`D1Graph`] wraps the square adjacency so the distance-1 phases
//!   dispatch instead of D2GC's).
//! * The coordinator exposes sessions as a service:
//!   [`crate::coordinator::Service::open_session`] /
//!   [`crate::coordinator::Service::open_session_d2gc`] plus the
//!   [`crate::coordinator::JobInput::Update`] job kind.
//! * Downstream, [`crate::exec`] closes the loop for *consumers* of a
//!   streamed coloring: a [`crate::exec::ColorSchedule`] diff-refreshes
//!   against the repaired colors — rebuilding only the colors a batch
//!   dirtied — so colored execution resumes right after a repair
//!   (repair → rebuild dirty frontiers → re-run; DESIGN.md §11, and
//!   [`crate::coordinator::JobInput::Execute`] through the service).
//!
//! Motivation: coloring is a *recurring* cost in iterative solvers
//! (Çatalyürek et al., arXiv:1205.3809); Rokos et al. (arXiv:1505.04086)
//! show the speculate-and-iterate scheme converges in a handful of
//! rounds when the dirty set is small — and that the loop is
//! problem-agnostic once detection is factored out. `benches/dynamic.rs`
//! measures the repair-vs-recolor gap across batch sizes for both
//! problems.

pub mod delta;
pub mod engine;
pub mod problem;
pub mod session;

pub use delta::{DeltaBipartite, DeltaSymmetric};
pub use engine::repair;
pub use problem::{D1Graph, DeltaD1, DeltaOps, Problem};
pub use session::{BgpcSession, D1gcSession, D2gcSession, DynamicSession};

/// One batch of graph edits, applied atomically by
/// [`DynamicSession::apply`]. Edit pairs are *problem-shaped*: for a
/// BGPC session they are `(net, vertex)` incidences; for a D2GC
/// session they are undirected `{a, b}` edges (mirrored by the
/// symmetric overlay) and `add_nets` entries append new vertices
/// adjacent to the listed members.
#[derive(Clone, Debug, Default)]
pub struct UpdateBatch {
    /// Edit pairs to insert (duplicates are no-ops).
    pub add_edges: Vec<(u32, u32)>,
    /// Edit pairs to delete (absent ones are no-ops).
    pub remove_edges: Vec<(u32, u32)>,
    /// Fresh constraint rows to append, each given by its members.
    pub add_nets: Vec<Vec<u32>>,
}

impl UpdateBatch {
    /// Number of requested edits (before no-op filtering).
    pub fn len(&self) -> usize {
        self.add_edges.len()
            + self.remove_edges.len()
            + self.add_nets.iter().map(|m| m.len().max(1)).sum::<usize>()
    }

    /// True when the batch requests nothing.
    pub fn is_empty(&self) -> bool {
        self.add_edges.is_empty() && self.remove_edges.is_empty() && self.add_nets.is_empty()
    }
}

/// Per-batch repair metrics (the service reports these per update).
#[derive(Clone, Debug, Default)]
pub struct BatchStats {
    /// Edits that actually changed the graph (no-ops excluded).
    pub batch_edits: usize,
    /// Detection units with insertions — nets for BGPC, rows for D2GC
    /// (removal-only units cannot hold new conflicts and are excluded).
    pub dirty_nets: usize,
    /// Dirty vertex frontier: neighborhoods of changed units plus
    /// endpoints.
    pub frontier: usize,
    /// Vertices found in conflict (or brand-new) after detection.
    pub conflicts: usize,
    /// Distinct vertices recolored during repair.
    pub recolored: usize,
    /// Distinct colors gained relative to before the batch (0 if none).
    pub colors_added: usize,
    /// Distinct colors after the batch.
    pub n_colors: usize,
    /// Speculate/repair iterations the repair loop ran.
    pub iterations: usize,
    /// Repair time: simulated seconds under `ExecMode::Sim`, wall-clock
    /// under `ExecMode::Threads`.
    pub seconds: f64,
    /// Wall-clock seconds the session spent folding the overlay back
    /// into CSR for this batch (memcpy-speed splice + transpose; kept
    /// separate from the modeled repair cost above).
    pub compact_seconds: f64,
    /// Total simulator work units (0 under real threads).
    pub work_units: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_len_counts_all_edit_kinds() {
        let mut b = UpdateBatch::default();
        assert!(b.is_empty());
        assert_eq!(b.len(), 0);
        b.add_edges.push((0, 1));
        b.remove_edges.push((1, 2));
        b.add_nets.push(vec![3, 4]);
        b.add_nets.push(vec![]); // empty net still counts as one edit
        assert!(!b.is_empty());
        assert_eq!(b.len(), 5);
    }
}
