//! `bgpc` CLI — the L3 leader entrypoint.
//!
//! Subcommands (hand-rolled parsing; no arg crates resolve offline):
//!
//! ```text
//! bgpc info                                   # presets + artifact status
//! bgpc gen --preset coPapersDBLP --scale 0.1 --out g.mtx
//! bgpc color --graph mtx:bone010.mtx [--alg N1-N2] [--threads 16]
//!            [--preset bone010] [--mtx file]       # legacy instance flags
//!            [--balance b1] [--order natural|sl] [--engine sim|threads|pjrt]
//!            [--strategy ldf+fix]               # ordering + post pass in one knob
//!            [--chunk N|static|auto]            # override the schedule's chunk
//!                                               # (auto = self-tuning, DESIGN.md §Perf)
//! bgpc d2color --preset af_shell [--alg V-N2] [--threads 16]
//! bgpc serve --jobs 32 --workers 2 --pool 4   # coordinator demo loop
//!           [--strategy sl+fix]                 # strategy applied to every job
//!           [--trace out.json]                 # Chrome-trace export (needs --features trace)
//!           [--stats-interval 5]               # periodic registry snapshots
//! ```

use std::collections::HashMap;
use std::process::ExitCode;
use std::sync::Arc;

use bgpc::coloring::{self, schedule, Balance, Config, ExecMode};
use bgpc::coordinator::{EngineSel, Job, JobInput, Service, ServiceOpts, DEFAULT_POOL_THREADS};
use bgpc::graph::{
    generators::Preset, mtx, Bipartite, GraphSource, InstanceStats, Ordering, PRESETS,
};
use bgpc::runtime::Runtime;
use bgpc::sim::CostModel;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            m.insert(key.to_string(), val);
        }
        i += 1;
    }
    m
}

fn load_instance(flags: &HashMap<String, String>) -> Result<(String, Bipartite), String> {
    // --graph takes any GraphSource spec (preset name, preset:n@s@s,
    // mtx:path, mtxmem:path, csrb:path, random:NxMxK@s) and wins over
    // the legacy --mtx / --preset pair.
    if let Some(spec) = flags.get("graph") {
        let src = GraphSource::parse(spec).ok_or_else(|| {
            format!(
                "unknown graph source {spec} (preset name | preset:n@scale@seed | \
                 mtx:path | mtxmem:path | csrb:path | random:NxMxK@seed)"
            )
        })?;
        let g = src.load().map_err(|e| format!("{e:#}"))?;
        return Ok((src.name(), g));
    }
    if let Some(path) = flags.get("mtx") {
        let m = mtx::read_mtx(path).map_err(|e| format!("{e:#}"))?;
        return Ok((path.clone(), Bipartite::from_net_incidence(m)));
    }
    let name = flags.get("preset").cloned().unwrap_or_else(|| "coPapersDBLP".into());
    let preset = Preset::by_name(&name).ok_or_else(|| {
        format!("unknown preset {name}; known: {}", PRESETS.map(|p| p.name).join(", "))
    })?;
    let scale: f64 = flags.get("scale").map(|s| s.parse().unwrap_or(0.1)).unwrap_or(0.1);
    let seed: u64 = flags.get("seed").map(|s| s.parse().unwrap_or(1)).unwrap_or(1);
    Ok((name, preset.bipartite(scale, seed)))
}

fn build_config(flags: &HashMap<String, String>) -> Result<Config, String> {
    let alg = flags.get("alg").cloned().unwrap_or_else(|| "N1-N2".into());
    let mut spec = schedule::AlgSpec::by_name(&alg).ok_or(format!("unknown algorithm {alg}"))?;
    // --chunk overrides the schedule's chunk: N (fixed), static, or auto
    // (the self-tuning Chunk::Auto sentinel; engines re-aim it per phase)
    if let Some(c) = flags.get("chunk") {
        spec.chunk = match c.as_str() {
            "static" => 0,
            "auto" => bgpc::par::Chunk::Auto(bgpc::par::autosite::GENERIC).encode(),
            n => n
                .parse::<usize>()
                .ok()
                .filter(|&n| n >= 1)
                .ok_or(format!("unknown chunk {c} (N >= 1 | static | auto)"))?,
        };
    }
    let threads: usize =
        flags.get("threads").map(|s| s.parse().unwrap_or(16)).unwrap_or(16);
    let mode = match flags.get("engine").map(|s| s.as_str()).unwrap_or("sim") {
        "sim" => ExecMode::Sim(CostModel::default()),
        "threads" => ExecMode::Threads,
        other => return Err(format!("unknown engine {other} (sim|threads|pjrt)")),
    };
    let balance = flags
        .get("balance")
        .map(|s| Balance::parse(s).ok_or(format!("unknown balance {s}")))
        .transpose()?
        .unwrap_or(Balance::None);
    let ordering = flags
        .get("order")
        .map(|s| Ordering::parse(s).ok_or(format!("unknown ordering {s}")))
        .transpose()?
        .unwrap_or(Ordering::Natural);
    let mut cfg = Config {
        spec,
        balance,
        threads,
        mode,
        ordering,
        post_pass: coloring::PostPass::None,
    };
    // --strategy bundles ordering + post pass; it wins over --order
    if let Some(s) = flags.get("strategy") {
        let st = coloring::Strategy::parse(s)
            .ok_or(format!("unknown strategy {s} (e.g. natural, ldf, sl+fix, random+fix8)"))?;
        cfg = cfg.with_strategy(st);
    }
    Ok(cfg)
}

fn cmd_info() -> ExitCode {
    println!("bgpc — optimistic bipartite-graph partial coloring (Taş/Kaya/Saule 2017)\n");
    println!("presets (scaled Table II test-bed):");
    println!("{:<16} {:>9} {:>9} {:>10} {:>7} {:>10}", "name", "nets", "vertices", "nnz", "maxvdeg", "vdeg-std");
    for p in PRESETS.iter() {
        let g = p.bipartite(0.05, 1);
        let s = InstanceStats::compute(&g);
        println!("{}", s.table_row(p.name));
    }
    match Runtime::load(Runtime::default_dir()) {
        Ok(rt) => {
            println!("\nPJRT artifacts: {} buckets on {}", rt.buckets().len(), rt.platform);
            for b in rt.buckets() {
                println!("  net_step B={} K={}", b.b, b.k);
            }
        }
        Err(e) => println!("\nPJRT artifacts: unavailable ({e})"),
    }
    ExitCode::SUCCESS
}

fn cmd_color(flags: &HashMap<String, String>, d2: bool) -> ExitCode {
    let cfg = match build_config(flags) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let (name, g) = match load_instance(flags) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };

    if flags.get("engine").map(|s| s.as_str()) == Some("pjrt") {
        return cmd_color_pjrt(&name, &g);
    }

    let r = if d2 {
        let m = &g.net_vtxs;
        if !m.is_structurally_symmetric() {
            eprintln!("error: {name} is not structurally symmetric; D2GC needs a symmetric square graph");
            return ExitCode::FAILURE;
        }
        coloring::color(m, &cfg)
    } else {
        coloring::color(&g, &cfg)
    };
    let valid = if d2 {
        coloring::verify::d2gc_valid(&g.net_vtxs, &r.colors).is_ok()
    } else {
        coloring::verify::bgpc_valid(&g, &r.colors).is_ok()
    };
    let st = r.stats();
    println!(
        "{} {} alg={} t={} iters={} colors={} secs={:.4} valid={} card-avg={:.2} card-std={:.2}",
        if d2 { "d2gc" } else { "bgpc" },
        name,
        cfg.spec.name,
        cfg.threads,
        r.iterations,
        r.n_colors,
        r.seconds,
        valid,
        st.avg_cardinality,
        st.stddev_cardinality,
    );
    for (i, it) in r.trace.iters.iter().enumerate() {
        println!(
            "  iter {:>2} [{}{}] queue={:>8} color={:.4}s conflict={:.4}s",
            i + 1,
            it.color_kind,
            it.conflict_kind,
            it.queue_len,
            it.color_secs,
            it.conflict_secs
        );
    }
    if valid {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn cmd_color_pjrt(name: &str, g: &Bipartite) -> ExitCode {
    let rt = match Runtime::load(Runtime::default_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("error: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    let t0 = std::time::Instant::now();
    match bgpc::runtime::NetStepOffload::new(&rt).color(g, 50) {
        Ok((colors, stats)) => {
            let valid = coloring::verify::bgpc_valid(g, &colors).is_ok();
            println!(
                "bgpc {} engine=pjrt iters={} kernel_calls={} offloaded={} native={} colors={} secs={:.4} kernel_secs={:.4} valid={}",
                name,
                stats.iterations,
                stats.kernel_calls,
                stats.offloaded_nets,
                stats.native_nets,
                coloring::stats::distinct_colors(&colors),
                t0.elapsed().as_secs_f64(),
                stats.kernel_secs,
                valid
            );
            if valid {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn cmd_gen(flags: &HashMap<String, String>) -> ExitCode {
    let (name, g) = match load_instance(flags) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let out = flags.get("out").cloned().unwrap_or_else(|| format!("{name}.mtx"));
    if let Err(e) = mtx::write_mtx(&g.net_vtxs, &out) {
        eprintln!("error: {e:#}");
        return ExitCode::FAILURE;
    }
    let s = InstanceStats::compute(&g);
    println!("wrote {out}: {} nets x {} vertices, {} nnz", s.n_nets, s.n_vertices, s.nnz);
    ExitCode::SUCCESS
}

fn cmd_serve(flags: &HashMap<String, String>) -> ExitCode {
    let n_jobs: usize = flags.get("jobs").map(|s| s.parse().unwrap_or(16)).unwrap_or(16);
    let workers: usize = flags.get("workers").map(|s| s.parse().unwrap_or(2)).unwrap_or(2);
    let shards: usize = flags.get("shards").map(|s| s.parse().unwrap_or(1)).unwrap_or(1);
    let pool: usize = flags
        .get("pool")
        .map(|s| s.parse().unwrap_or(DEFAULT_POOL_THREADS))
        .unwrap_or(DEFAULT_POOL_THREADS);
    let trace_out = flags.get("trace").cloned();
    if trace_out.is_some() {
        if bgpc::obs::trace::available() {
            bgpc::obs::trace::set_enabled(true);
        } else {
            eprintln!("warning: --trace requires the `trace` feature (cargo run --features trace); ignoring");
        }
    }
    let stats_interval: u64 =
        flags.get("stats-interval").map(|s| s.parse().unwrap_or(0)).unwrap_or(0);
    let strategy = match flags.get("strategy") {
        Some(s) => match coloring::Strategy::parse(s) {
            Some(st) => Some(st),
            None => {
                eprintln!("error: unknown strategy {s} (e.g. natural, ldf, sl+fix, random+fix8)");
                return ExitCode::FAILURE;
            }
        },
        None => None,
    };
    let svc = Service::start_sharded(ServiceOpts {
        shards,
        dispatchers: workers,
        pool_threads: pool,
        artifacts: Some(Runtime::default_dir()),
        ..ServiceOpts::default()
    });
    println!(
        "coordinator up: {workers} dispatchers over {shards} shard(s) of {pool}-thread pools, pjrt={}",
        svc.has_pjrt()
    );
    // optional periodic registry snapshot printer (satellite: --stats-interval)
    let stats_stop = std::sync::atomic::AtomicBool::new(false);
    let mut failures = 0;
    std::thread::scope(|scope| {
        if stats_interval > 0 {
            let svc = &svc;
            let stop = &stats_stop;
            scope.spawn(move || {
                let period = std::time::Duration::from_secs(stats_interval);
                let tick = std::time::Duration::from_millis(50);
                let mut waited = std::time::Duration::ZERO;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    std::thread::sleep(tick);
                    waited += tick;
                    if waited >= period {
                        waited = std::time::Duration::ZERO;
                        println!("--- stats snapshot ---\n{}", svc.stats_text());
                    }
                }
            });
        }
        let mut handles = Vec::new();
        for i in 0..n_jobs {
            let p = PRESETS[i % PRESETS.len()];
            let g = Arc::new(p.bipartite(0.02, i as u64));
            let spec = schedule::ALL[i % schedule::ALL.len()];
            // every fourth job runs on the real shared pool; the rest use
            // the deterministic 16-thread simulator
            let mut cfg =
                if i % 4 == 1 { Config::threads(spec, pool) } else { Config::sim(spec, 16) };
            if let Some(st) = strategy {
                cfg = cfg.with_strategy(st);
            }
            handles.push(svc.submit_async(Job {
                name: format!("{}-{}", p.name, spec.name),
                input: JobInput::Bgpc(g),
                cfg,
                engine: if i % 4 == 0 { EngineSel::Auto } else { EngineSel::Native },
            }));
        }
        for h in handles {
            let o = h.wait();
            println!(
                "  {:<28} engine={:<6} colors={:>6} iters={} secs={:.4} valid={}",
                o.name, o.engine, o.n_colors, o.iterations, o.seconds, o.valid
            );
            if !o.valid {
                failures += 1;
            }
        }
        println!("metrics: {}", svc.metrics().summary());
        let m = svc.metrics();
        println!(
            "latency: wait p50={:.3}ms p99={:.3}ms | service p50={:.3}ms p99={:.3}ms",
            m.queue_wait_quantile(0.50) * 1e3,
            m.queue_wait_quantile(0.99) * 1e3,
            m.service_time_quantile(0.50) * 1e3,
            m.service_time_quantile(0.99) * 1e3,
        );
        println!("pool: {}", svc.pool_stats().summary());
        // final registry snapshot via the Stats job kind (flows through the
        // same admission queue as real work, so it observes committed state)
        let stats = svc
            .submit_async(Job {
                name: "stats".into(),
                input: JobInput::Stats,
                cfg: Config::sim(schedule::N1_N2, 1),
                engine: EngineSel::Native,
            })
            .wait();
        if let Some(text) = stats.text {
            println!("registry:\n{text}");
        }
        stats_stop.store(true, std::sync::atomic::Ordering::Relaxed);
    });
    svc.shutdown();
    if let Some(path) = trace_out {
        if bgpc::obs::trace::enabled() {
            bgpc::obs::trace::set_enabled(false);
            match bgpc::obs::trace::write_chrome(&path) {
                Ok(()) => println!("trace written to {path} (open in ui.perfetto.dev)"),
                Err(e) => eprintln!("error: writing trace {path}: {e}"),
            }
        }
    }
    if failures == 0 {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("usage: bgpc <info|gen|color|d2color|serve> [flags]  (see --help in README)");
        return ExitCode::FAILURE;
    };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "info" => cmd_info(),
        "gen" => cmd_gen(&flags),
        "color" => cmd_color(&flags, false),
        "d2color" => cmd_color(&flags, true),
        "serve" => cmd_serve(&flags),
        other => {
            eprintln!("unknown command {other}");
            ExitCode::FAILURE
        }
    }
}
