//! # bgpc — optimistic parallel bipartite-graph partial coloring
//!
//! A reproduction of Taş, Kaya & Saule, *"Greed is Good: Optimistic
//! Algorithms for Bipartite-Graph Partial Coloring on Multicore
//! Architectures"* (2017), built as a three-layer Rust + JAX + Pallas
//! system (see `DESIGN.md`).
//!
//! The crate provides:
//!
//! * [`graph`] — CSR bipartite/unipartite graphs, Matrix-Market I/O,
//!   calibrated synthetic generators for the paper's eight test matrices,
//!   and vertex orderings (natural / random / largest-first /
//!   smallest-last).
//! * [`par`] — an OpenMP-equivalent chunked dynamic-scheduling
//!   parallel-for (the paper's `schedule(dynamic, 64)` is a first-class
//!   knob) executed on a persistent worker pool ([`par::pool`]): one
//!   parked team per process, epoch-handoff regions, zero spawns on the
//!   hot path (DESIGN.md §10).
//! * [`sim`] — a deterministic discrete-event multicore simulator used to
//!   reproduce the paper's 16-thread experiments on arbitrary hosts.
//! * [`coloring`] — the paper's contribution: vertex- and net-based BGPC
//!   (Algorithms 4–8), D2GC (Algorithms 9–10), the hybrid schedules
//!   (`V-V` … `N1-N2`), the balancing heuristics B1/B2 (Algorithms
//!   11–12), plus D1GC, verification and color statistics.
//! * [`dynamic`] — incremental coloring for streaming graph updates,
//!   generic over the problem (BGPC, D2GC, and D1GC): mutable delta overlays
//!   over the frozen CSR (the D2GC one keeps the square pattern
//!   structurally symmetric), dirty-frontier repair that reuses the
//!   optimistic phase machinery through the [`dynamic::Problem`] seam,
//!   and long-lived sessions whose balancing trackers persist across
//!   update batches (DESIGN.md §8–§9).
//! * [`exec`] — the consumer side of a coloring: per-color execution
//!   frontiers ([`exec::ColorSchedule`], with incremental rebuild of
//!   only the colors a dynamic repair dirtied) and a color-by-color
//!   [`exec::Executor`] that drives user kernels lock-free within a
//!   color on the shared worker pool, barrier between colors
//!   (DESIGN.md §11).
//! * [`runtime`] — the PJRT bridge that loads the AOT-compiled
//!   JAX/Pallas net-step artifacts (`artifacts/*.hlo.txt`) and runs the
//!   batched coloring step from Rust; Python is never on this path.
//! * [`coordinator`] — a coloring job service: submit graphs + configs,
//!   route them to engines (sequential / threads / simulator / PJRT),
//!   open dynamic sessions and stream update batches, collect metrics.
//! * [`obs`] — unified observability: a registry of named counters /
//!   gauges / log2 histograms (the coordinator metrics are a façade
//!   over it) and a per-thread span tracer with Chrome-trace export
//!   (`--features trace`), instrumenting pool regions, coloring
//!   phases, dynamic repair, exec frontiers, and coordinator dispatch
//!   (DESIGN.md §13).
//! * [`testing`] — in-tree property-testing helpers (no external crates
//!   are available offline).

// The clippy gate (scripts/verify.sh) denies warnings; two repo-wide
// dispensations where the paper's pseudocode shapes the code:
// phase functions mirror the Alg. 4–8 parameter lists verbatim, and the
// CSR kernels index `ptr`/`adj` in lockstep.
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]

pub mod coloring;
pub mod coordinator;
pub mod dynamic;
pub mod exec;
pub mod graph;
pub mod obs;
pub mod par;
pub mod runtime;
pub mod sim;
pub mod testing;
pub mod util;

pub use coloring::{ColoringResult, Problem, Schedule, Strategy};
pub use dynamic::{
    BatchStats, BgpcSession, D1Graph, D1gcSession, D2gcSession, DynamicSession, UpdateBatch,
};
pub use exec::{ColorSchedule, ExecReport, Executor, SharedBuf};
pub use graph::{Bipartite, Csr};
