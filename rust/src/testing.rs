//! In-tree property-testing helpers.
//!
//! No external crates resolve offline (no `proptest`), so this module
//! provides the pieces the invariant tests need: seeded random instance
//! generators with size sweeps, a `forall`-style runner that reports
//! the failing case's parameters (seed + shape) so any failure is
//! reproducible with a one-liner, and [`SpawnDriver`] — the retired
//! spawn-per-region thread driver kept as the reference backend for the
//! pool-equivalence tests and the scheduler bench.

use std::sync::atomic::{AtomicUsize, Ordering as AOrd};

use crate::dynamic::UpdateBatch;
use crate::graph::generators::{random_bipartite, random_symmetric};
use crate::graph::{Bipartite, Csr};
use crate::par::{auto_effective, auto_seed, AtomicColors, Chunk, Cost, Driver, RegionOut};
use crate::util::prng::Rng;

/// The pre-pool `ThreadsDriver`: `std::thread::scope` workers per
/// region plus a shared atomic cursor. Retired from the hot path by the
/// persistent [`crate::par::WorkerPool`] (DESIGN.md §10); kept here,
/// bit-for-bit, as the reference implementation that
/// `tests/driver_equivalence.rs` certifies against and
/// `benches/scheduler.rs` measures against. Do not use in production
/// code — every region pays thread creation and join.
pub struct SpawnDriver {
    pub t: usize,
}

impl Driver for SpawnDriver {
    type Colors = AtomicColors;

    fn threads(&self) -> usize {
        self.t
    }

    fn new_colors(&self, n: usize) -> AtomicColors {
        AtomicColors::new(n)
    }

    fn region<TS, F>(&mut self, states: &mut [TS], n_items: usize, chunk: usize, body: F) -> RegionOut
    where
        TS: Send,
        F: Fn(usize, &mut TS, usize, u64) -> Cost + Sync,
    {
        assert!(states.len() >= self.t, "one scratch state per thread required");
        // Resolve a Chunk::Auto sentinel statelessly (always the seed
        // chunk): the reference driver has no cross-region tuner, it
        // only needs a valid dynamic chunk for this dispatch.
        let chunk = match Chunk::decode(chunk) {
            Chunk::Auto(_) => auto_effective(auto_seed(n_items, self.t), n_items, self.t),
            _ => chunk,
        };
        let t0 = std::time::Instant::now();
        if self.t == 1 {
            let ts = &mut states[0];
            for item in 0..n_items {
                body(0, ts, item, 0);
            }
        } else if chunk == 0 {
            // schedule(static): contiguous blocks
            let t = self.t;
            let body = &body;
            std::thread::scope(|s| {
                for (tid, ts) in states.iter_mut().enumerate().take(t) {
                    s.spawn(move || {
                        let lo = n_items * tid / t;
                        let hi = n_items * (tid + 1) / t;
                        for item in lo..hi {
                            body(tid, ts, item, 0);
                        }
                    });
                }
            });
        } else {
            let cursor = AtomicUsize::new(0);
            let body = &body;
            let cursor = &cursor;
            std::thread::scope(|s| {
                for (tid, ts) in states.iter_mut().enumerate().take(self.t) {
                    s.spawn(move || loop {
                        let start = cursor.fetch_add(chunk, AOrd::Relaxed);
                        if start >= n_items {
                            break;
                        }
                        let end = (start + chunk).min(n_items);
                        for item in start..end {
                            body(tid, ts, item, 0);
                        }
                    });
                }
            });
        }
        RegionOut { real_secs: t0.elapsed().as_secs_f64(), sim_ns: None, busy_units: Vec::new() }
    }
}

/// Shape of one random BGPC case.
#[derive(Clone, Copy, Debug)]
pub struct BgpcCase {
    pub n_nets: usize,
    pub n_vtxs: usize,
    pub nnz: usize,
    pub seed: u64,
}

/// Run `f` over `cases` random bipartite instances with varying shapes
/// (including degenerate ones: empty nets, dense nets, singleton sides).
/// Panics with the case description on failure.
pub fn forall_bipartite(cases: usize, master_seed: u64, f: impl Fn(&Bipartite, BgpcCase)) {
    let mut rng = Rng::new(master_seed);
    for i in 0..cases {
        let case = match i % 5 {
            // tiny / degenerate shapes first — they find the edge bugs
            0 => BgpcCase { n_nets: 1, n_vtxs: rng.range(1, 8), nnz: rng.range(0, 8), seed: rng.next_u64() },
            1 => BgpcCase { n_nets: rng.range(1, 8), n_vtxs: 1, nnz: rng.range(0, 8), seed: rng.next_u64() },
            2 => BgpcCase {
                n_nets: rng.range(2, 30),
                n_vtxs: rng.range(2, 30),
                nnz: rng.range(0, 60),
                seed: rng.next_u64(),
            },
            3 => BgpcCase {
                n_nets: rng.range(10, 120),
                n_vtxs: rng.range(10, 120),
                nnz: rng.range(50, 2000),
                seed: rng.next_u64(),
            },
            _ => BgpcCase {
                n_nets: rng.range(50, 400),
                n_vtxs: rng.range(50, 400),
                nnz: rng.range(200, 6000),
                seed: rng.next_u64(),
            },
        };
        let g = random_bipartite(case.n_nets, case.n_vtxs, case.nnz, case.seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&g, case);
        }));
        if let Err(e) = result {
            panic!("property failed on case #{i}: {case:?}\n{e:?}");
        }
    }
}

/// Same for square symmetric graphs (D2GC / D1GC invariants).
pub fn forall_symmetric(cases: usize, master_seed: u64, f: impl Fn(&Csr, u64)) {
    let mut rng = Rng::new(master_seed ^ 0xD2);
    for i in 0..cases {
        let n = match i % 3 {
            0 => rng.range(1, 10),
            1 => rng.range(10, 80),
            _ => rng.range(80, 400),
        };
        let m = rng.range(0, (n * 8).max(1));
        let seed = rng.next_u64();
        let g = random_symmetric(n, m, seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&g, seed);
        }));
        if let Err(e) = result {
            panic!("property failed on case #{i}: n={n} m={m} seed={seed}\n{e:?}");
        }
    }
}

/// A mixed update batch for a BGPC instance: `edits` incidences,
/// alternating remove-existing / add-random, deterministic in `rng`.
/// One definition shared by `benches/dynamic.rs` and the integration
/// tests, so the test-scale and bench-scale acceptance checks exercise
/// the same batch distribution.
pub fn random_update_batch(g: &Bipartite, edits: usize, rng: &mut Rng) -> UpdateBatch {
    let mut b = UpdateBatch::default();
    for i in 0..edits {
        if i % 2 == 0 {
            let v = rng.range(0, g.n_nets());
            let row = g.vtxs(v);
            if row.is_empty() {
                continue;
            }
            let u = row[rng.range(0, row.len())];
            b.remove_edges.push((v as u32, u));
        } else {
            b.add_edges.push((
                rng.range(0, g.n_nets()) as u32,
                rng.range(0, g.n_vertices()) as u32,
            ));
        }
    }
    b
}

/// The symmetric (D2GC) analogue of [`random_update_batch`]: `edits`
/// undirected pairs, alternating remove-existing-off-diagonal /
/// add-random.
pub fn random_symmetric_update_batch(g: &Csr, edits: usize, rng: &mut Rng) -> UpdateBatch {
    let mut b = UpdateBatch::default();
    for i in 0..edits {
        if i % 2 == 0 {
            let a = rng.range(0, g.n_rows);
            let off: Vec<u32> =
                g.row(a).iter().copied().filter(|&u| u as usize != a).collect();
            if off.is_empty() {
                continue;
            }
            b.remove_edges.push((a as u32, off[rng.range(0, off.len())]));
        } else {
            let a = rng.range(0, g.n_rows) as u32;
            let c = rng.range(0, g.n_rows) as u32;
            if a != c {
                b.add_edges.push((a, c));
            }
        }
    }
    b
}

/// A degree-skewed bipartite instance: Chung–Lu sampling with
/// power-law-ish weights on both sides (the generator behind the
/// skewed presets), so a handful of hub nets dominates the degree mass.
/// This is the shape ordering strategies have something to win on —
/// first-fit in natural order meets the hubs late and pays in colors,
/// degree-aware orders claim them first (`tests/strategy_properties.rs`,
/// `benches/strategy.rs`). Deterministic in `seed`.
pub fn skewed_bipartite(n_nets: usize, n_vtxs: usize, nnz: usize, seed: u64) -> Bipartite {
    let m = crate::graph::generators::chung_lu_bipartite(
        n_nets,
        n_vtxs,
        nnz,
        2.0,
        2.2,
        (n_vtxs / 2).max(4),
        (n_nets / 2).max(4),
        seed,
    );
    Bipartite::from_net_incidence(m)
}

/// The square symmetric analogue of [`skewed_bipartite`] (D1GC / D2GC
/// cases): Chung–Lu adjacency with power-law-ish degrees, hub degrees
/// capped at `n / 3`. Deterministic in `seed`.
pub fn skewed_symmetric(n: usize, m: usize, seed: u64) -> Csr {
    crate::graph::generators::chung_lu_symmetric(n, m, 2.4, (n / 3).max(4), seed)
}

/// A random partial coloring (mix of -1 and small colors) for fuzzing
/// repair/verify paths.
pub fn random_partial_colors(n: usize, max_color: i32, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            if rng.chance(0.3) {
                -1
            } else {
                rng.range(0, max_color.max(1) as usize) as i32
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_reports_failing_case() {
        let r = std::panic::catch_unwind(|| {
            forall_bipartite(3, 1, |_g, case| {
                assert!(case.n_nets == usize::MAX, "always fails");
            });
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| format!("{err:?}"));
        assert!(msg.contains("property failed on case #0"), "{msg}");
    }

    #[test]
    fn generators_cover_degenerate_shapes() {
        use std::cell::Cell;
        let saw_single_net = Cell::new(false);
        let saw_single_vtx = Cell::new(false);
        forall_bipartite(10, 2, |g, _case| {
            if g.n_nets() == 1 {
                saw_single_net.set(true);
            }
            if g.n_vertices() == 1 {
                saw_single_vtx.set(true);
            }
            g.validate().unwrap();
        });
        assert!(saw_single_net.get() && saw_single_vtx.get());
    }

    #[test]
    fn skewed_generators_actually_skew() {
        // the point of these helpers: the degree distribution must have
        // hubs far above the mean, or ordering strategies have nothing
        // to win on
        let g = skewed_bipartite(300, 400, 4000, 9);
        g.validate().unwrap();
        let stats = crate::graph::InstanceStats::compute(&g);
        assert!(
            (stats.max_net_deg as f64) > 4.0 * stats.avg_net_deg,
            "max={} avg={}",
            stats.max_net_deg,
            stats.avg_net_deg
        );
        let s = skewed_symmetric(300, 2400, 9);
        assert!(s.is_structurally_symmetric());
        let avg = s.nnz() as f64 / s.n_rows as f64;
        assert!((s.max_deg() as f64) > 3.0 * avg, "max={} avg={avg}", s.max_deg());
    }

    #[test]
    fn partial_colors_mix() {
        let c = random_partial_colors(1000, 5, 3);
        assert!(c.iter().any(|&x| x == -1));
        assert!(c.iter().any(|&x| x >= 0));
        assert!(c.iter().all(|&x| x >= -1 && x < 5));
    }
}
