//! Integration tests across the full engine: every schedule × balance ×
//! execution mode on realistic preset instances, the coordinator
//! service, orderings, and the D2GC path.

use std::sync::Arc;

use bgpc::coloring::verify::{bgpc_valid, d2gc_valid};
use bgpc::coloring::{color, schedule, Balance, Config, ExecMode};
use bgpc::coordinator::{EngineSel, Job, JobInput, Service};
use bgpc::graph::generators::Preset;
use bgpc::graph::Ordering;
use bgpc::sim::CostModel;

#[test]
fn every_schedule_valid_on_every_small_preset() {
    for p in bgpc::graph::PRESETS.iter() {
        let g = p.bipartite(0.01, 42);
        for spec in schedule::ALL {
            let r = color(&g, &Config::sim(spec, 16));
            assert!(
                bgpc_valid(&g, &r.colors).is_ok(),
                "{} on {} invalid",
                spec.name,
                p.name
            );
        }
    }
}

#[test]
fn thread_mode_matches_sim_mode_color_quality() {
    let g = Preset::by_name("bone010").unwrap().bipartite(0.02, 7);
    for spec in [schedule::V_V_64D, schedule::N1_N2] {
        let sim = color(&g, &Config::sim(spec, 8));
        let thr = color(&g, &Config::threads(spec, 4));
        assert!(bgpc_valid(&g, &sim.colors).is_ok());
        assert!(bgpc_valid(&g, &thr.colors).is_ok());
        // different nondeterminism, same ballpark of colors
        let (a, b) = (sim.n_colors as f64, thr.n_colors as f64);
        assert!(a <= 1.5 * b + 8.0 && b <= 1.5 * a + 8.0, "{}: {a} vs {b}", spec.name);
    }
}

#[test]
fn orderings_compose_with_engine() {
    let g = Preset::by_name("coPapersDBLP").unwrap().bipartite(0.01, 5);
    for ord in [
        Ordering::Natural,
        Ordering::Random(7),
        Ordering::LargestFirst,
        Ordering::SmallestLast,
    ] {
        let cfg = Config::sim(schedule::V_N2, 8).with_ordering(ord);
        let r = color(&g, &cfg);
        assert!(bgpc_valid(&g, &r.colors).is_ok(), "{ord:?}");
    }
}

#[test]
fn balance_reduces_cardinality_stddev_on_skewed_graph() {
    // Table VI's headline: B2 < B1 < U in stddev; colors grow slightly.
    let g = Preset::by_name("coPapersDBLP").unwrap().bipartite(0.03, 11);
    let base = color(&g, &Config::sim(schedule::V_N2, 16));
    let b1 = color(&g, &Config::sim(schedule::V_N2, 16).with_balance(Balance::B1));
    let b2 = color(&g, &Config::sim(schedule::V_N2, 16).with_balance(Balance::B2));
    for (name, r) in [("U", &base), ("B1", &b1), ("B2", &b2)] {
        assert!(bgpc_valid(&g, &r.colors).is_ok(), "{name}");
    }
    let (su, s1, s2) = (
        base.stats().stddev_cardinality,
        b1.stats().stddev_cardinality,
        b2.stats().stddev_cardinality,
    );
    assert!(s1 < su, "B1 should narrow stddev: {s1} vs {su}");
    assert!(s2 < su, "B2 should narrow stddev: {s2} vs {su}");
    assert!(
        b2.n_colors as f64 <= 1.6 * base.n_colors as f64,
        "B2 color growth bounded: {} vs {}",
        b2.n_colors,
        base.n_colors
    );
}

#[test]
fn d2gc_all_schedules_on_symmetric_presets() {
    for name in ["af_shell", "bone010", "channel", "coPapersDBLP", "nlpkkt120"] {
        let m = Preset::by_name(name).unwrap().net_incidence(0.005, 3);
        assert!(m.is_structurally_symmetric(), "{name}");
        for spec in schedule::D2GC_SET {
            let r = color(&m, &Config::sim(spec, 16));
            assert!(d2gc_valid(&m, &r.colors).is_ok(), "{} on {name}", spec.name);
        }
    }
}

#[test]
fn exec_mode_threads_stress_race_correctness() {
    // Oversubscribed real threads on a shared-heavy graph: the optimistic
    // loop must still converge to a valid coloring.
    let g = Preset::by_name("coPapersDBLP").unwrap().bipartite(0.02, 13);
    let cfg = Config {
        spec: schedule::N1_N2,
        balance: Balance::None,
        threads: 8,
        mode: ExecMode::Threads,
        ordering: Ordering::Natural,
        post_pass: bgpc::coloring::PostPass::None,
    };
    for _ in 0..3 {
        let r = color(&g, &cfg);
        assert!(bgpc_valid(&g, &r.colors).is_ok());
    }
}

#[test]
fn service_mixed_workload() {
    let svc = Service::start(2, None);
    let mut rxs = Vec::new();
    for (i, p) in bgpc::graph::PRESETS.iter().enumerate() {
        let g = Arc::new(p.bipartite(0.005, i as u64));
        rxs.push(svc.submit(Job {
            name: p.name.to_string(),
            input: JobInput::Bgpc(g.clone()),
            cfg: Config::sim(schedule::ALL[i % 8], 8),
            engine: EngineSel::Native,
        }));
        if p.symmetric {
            let m = Arc::new(p.net_incidence(0.005, i as u64));
            rxs.push(svc.submit(Job {
                name: format!("{}-d2", p.name),
                input: JobInput::D2gc(m),
                cfg: Config::sim(schedule::V_N2, 8),
                engine: EngineSel::Native,
            }));
        }
    }
    for rx in rxs {
        let o = rx.wait();
        assert!(o.valid, "{} failed: {:?}", o.name, o.error);
    }
    assert_eq!(svc.metrics().failures(), 0);
    svc.shutdown();
}

#[test]
fn cost_model_sim_time_scales_down_with_threads() {
    // headline sanity: N1-N2 at t=16 must be much faster (simulated) than
    // t=1, and faster than V-V at t=16 on a skewed graph.
    let g = Preset::by_name("coPapersDBLP").unwrap().bipartite(0.3, 17);
    let model = CostModel::default();
    let time = |spec, t| {
        let cfg = Config {
            spec,
            balance: Balance::None,
            threads: t,
            mode: ExecMode::Sim(model),
            ordering: Ordering::Natural,
            post_pass: bgpc::coloring::PostPass::None,
        };
        color(&g, &cfg).seconds
    };
    let n1n2_1 = time(schedule::N1_N2, 1);
    let n1n2_16 = time(schedule::N1_N2, 16);
    let vv_16 = time(schedule::V_V, 16);
    // The hub-conflict repair tail caps 16-thread scaling well below the
    // balanced-work ideal on this skewed graph (observed ~2.7-3.6x across
    // seeds), so assert a conservative 2x.
    assert!(n1n2_16 < n1n2_1 / 2.0, "scaling broken: {n1n2_1} -> {n1n2_16}");
    assert!(n1n2_16 < vv_16, "net-based must beat V-V at 16 threads");
}
