//! Cross-strategy property harness (ISSUE 8): every parseable
//! [`bgpc::Strategy`] — ordering × post-pass — must compose with every
//! problem (BGPC, D2GC, D1GC) under both execution drivers without
//! bending any invariant:
//!
//! 1. the coloring stays valid for the problem's conflict definition,
//! 2. every color stays below the problem's `color_cap`,
//! 3. `t = 1` runs are bit-for-bit deterministic per seed,
//! 4. `ColorAndFix` never *increases* the color count vs `PostPass::None`,
//! 5. the strategy seam threads through dynamic sessions (post-pass at
//!    bring-up, plain repair for batches) without invalidating repairs.

use bgpc::coloring::verify::{bgpc_valid, d1gc_valid, d2gc_valid};
use bgpc::coloring::{bgpc as bgpc_alg, color, d1gc, d2gc, schedule, Config, PostPass};
use bgpc::dynamic::{D1Graph, DynamicSession};
use bgpc::testing::{random_symmetric_update_batch, skewed_bipartite, skewed_symmetric};
use bgpc::util::prng::Rng;
use bgpc::Strategy;

/// Every spelling the CLI grammar accepts, covering all four orderings
/// with and without the fix pass (including explicit round counts).
const STRATEGIES: &[&str] = &[
    "natural",
    "random",
    "ldf",
    "sl",
    "natural+fix",
    "random+fix2",
    "ldf+fix",
    "sl+fix8",
];

fn strategies() -> Vec<Strategy> {
    STRATEGIES
        .iter()
        .map(|s| Strategy::parse(s).unwrap_or_else(|| panic!("grammar rejected {s}")))
        .collect()
}

#[test]
fn every_strategy_valid_and_capped_on_every_problem_under_both_drivers() {
    let g = skewed_bipartite(160, 220, 1800, 21);
    let m = skewed_symmetric(200, 1300, 21);
    for st in strategies() {
        for (driver, cfg) in [
            ("sim", Config::sim(schedule::N1_N2, 16)),
            ("threads", Config::threads(schedule::N1_N2, 4)),
        ] {
            let cfg = cfg.with_strategy(st);
            let ctx = format!("{} under {driver}", st.label());

            let r = color(&g, &cfg);
            assert!(bgpc_valid(&g, &r.colors).is_ok(), "{ctx}: BGPC invalid");
            let cap = bgpc_alg::color_cap(&g) as i32;
            assert!(
                r.colors.iter().all(|&c| c >= 0 && c < cap),
                "{ctx}: BGPC color out of cap {cap}"
            );

            let r = color(&m, &cfg);
            assert!(d2gc_valid(&m, &r.colors).is_ok(), "{ctx}: D2GC invalid");
            let cap = d2gc::color_cap(&m) as i32;
            assert!(
                r.colors.iter().all(|&c| c >= 0 && c < cap),
                "{ctx}: D2GC color out of cap {cap}"
            );

            let r = color(D1Graph::from_ref(&m), &cfg);
            assert!(d1gc_valid(&m, &r.colors).is_ok(), "{ctx}: D1GC invalid");
            let cap = d1gc::color_cap(&m) as i32;
            assert!(
                r.colors.iter().all(|&c| c >= 0 && c < cap),
                "{ctx}: D1GC color out of cap {cap}"
            );
        }
    }
}

#[test]
fn t1_runs_are_bit_for_bit_deterministic_per_seed() {
    // One worker means no racing writers anywhere in the pipeline —
    // ordering, optimistic rounds, and the fix pass must all replay
    // exactly, under the real-thread driver and the simulator alike.
    let g = skewed_bipartite(140, 180, 1500, 33);
    let m = skewed_symmetric(170, 1000, 33);
    for st in strategies() {
        for (driver, cfg) in [
            ("sim", Config::sim(schedule::V_N2, 1)),
            ("threads", Config::threads(schedule::V_N2, 1)),
        ] {
            let cfg = cfg.with_strategy(st);
            let ctx = format!("{} under {driver}", st.label());
            let (a, b) = (color(&g, &cfg), color(&g, &cfg));
            assert_eq!(a.colors, b.colors, "{ctx}: BGPC t=1 nondeterministic");
            let (a, b) = (color(&m, &cfg), color(&m, &cfg));
            assert_eq!(a.colors, b.colors, "{ctx}: D2GC t=1 nondeterministic");
            let (a, b) = (color(D1Graph::from_ref(&m), &cfg), color(D1Graph::from_ref(&m), &cfg));
            assert_eq!(a.colors, b.colors, "{ctx}: D1GC t=1 nondeterministic");
        }
    }
}

#[test]
fn color_and_fix_never_increases_the_color_count() {
    // The fix pass only keeps a recoloring round when the distinct
    // count strictly drops, so for every ordering and every problem the
    // fixed run is at most the unfixed run.
    let g = skewed_bipartite(180, 240, 2200, 5);
    let m = skewed_symmetric(220, 1500, 5);
    for base in ["natural", "random", "ldf", "sl"] {
        let plain = Config::sim(schedule::N1_N2, 8)
            .with_strategy(Strategy::parse(base).unwrap());
        let fixed = Config::sim(schedule::N1_N2, 8)
            .with_strategy(Strategy::parse(&format!("{base}+fix")).unwrap());
        let (p, f) = (color(&g, &plain), color(&g, &fixed));
        assert!(bgpc_valid(&g, &f.colors).is_ok(), "{base}+fix: BGPC invalid");
        assert!(f.n_colors <= p.n_colors, "{base}: BGPC fix grew {} -> {}", p.n_colors, f.n_colors);
        let (p, f) = (color(&m, &plain), color(&m, &fixed));
        assert!(d2gc_valid(&m, &f.colors).is_ok(), "{base}+fix: D2GC invalid");
        assert!(f.n_colors <= p.n_colors, "{base}: D2GC fix grew {} -> {}", p.n_colors, f.n_colors);
        let (p, f) = (color(D1Graph::from_ref(&m), &plain), color(D1Graph::from_ref(&m), &fixed));
        assert!(d1gc_valid(&m, &f.colors).is_ok(), "{base}+fix: D1GC invalid");
        assert!(f.n_colors <= p.n_colors, "{base}: D1GC fix grew {} -> {}", p.n_colors, f.n_colors);
    }
}

#[test]
fn sessions_apply_the_strategy_at_bring_up_and_stay_valid_over_batches() {
    // The session path: post-pass runs once at start (DESIGN.md §14),
    // batches go through plain repair. The coloring must stay valid
    // throughout, for both symmetric session problems.
    let m = skewed_symmetric(240, 1600, 13);
    let st = Strategy::parse("ldf+fix").unwrap();
    for cfg in [Config::sim(schedule::N1_N2, 8), Config::threads(schedule::N1_N2, 2)] {
        let cfg = cfg.with_strategy(st);
        let (mut s2, init) =
            DynamicSession::<bgpc::graph::Csr>::start(m.clone(), cfg.clone());
        assert!(d2gc_valid(s2.graph(), &init.colors).is_ok(), "D2GC bring-up invalid");
        let (mut s1, init) =
            DynamicSession::<bgpc::D1Graph>::start(bgpc::D1Graph::new(m.clone()), cfg.clone());
        assert!(d1gc_valid(s1.graph().as_csr(), &init.colors).is_ok(), "D1GC bring-up invalid");
        let mut rng = Rng::new(77);
        for round in 0..3 {
            let batch = random_symmetric_update_batch(s2.graph(), 40, &mut rng);
            s2.apply(&batch);
            assert!(s2.verify().is_ok(), "D2GC round {round} invalid after batch");
            let batch = random_symmetric_update_batch(s1.graph().as_csr(), 40, &mut rng);
            s1.apply(&batch);
            assert!(s1.verify().is_ok(), "D1GC round {round} invalid after batch");
        }
    }
}

#[test]
fn parse_label_roundtrip_and_default_post_pass() {
    for s in STRATEGIES {
        let st = Strategy::parse(s).unwrap();
        let relabeled = Strategy::parse(&st.label()).unwrap();
        assert_eq!(st, relabeled, "label {} does not roundtrip", st.label());
    }
    // bare orderings carry no post-pass; Config::sim defaults match
    assert_eq!(Strategy::parse("ldf").unwrap().post_pass, PostPass::None);
    assert_eq!(Config::sim(schedule::N1_N2, 4).post_pass, PostPass::None);
}
