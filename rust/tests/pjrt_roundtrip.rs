//! Integration: the loaded artifact buckets must agree with the native
//! Rust mirror of the net step on every tile shape, and the full
//! offloaded coloring path (gather → step → scatter → repair) must
//! produce valid colorings.
//!
//! NOTE: while `Bucket::step` is backed by the native mirror (no `xla`
//! crate resolves offline — DESIGN.md §3), the kernel-vs-mirror
//! comparisons are tautological; they still exercise artifact loading,
//! bucket selection and tile plumbing. They become a real cross-check
//! the moment an FFI-backed PJRT client is swapped into `Bucket::step`.
//!
//! Requires `make artifacts` (the Makefile test target runs it when the
//! Python toolchain is available). Without artifacts every test here
//! *skips cleanly* with a message — `cargo test -q` must pass on a clean
//! checkout with no Python/JAX installed.

use bgpc::coloring::verify::bgpc_valid;
use bgpc::graph::generators::{random_bipartite, Preset};
use bgpc::runtime::{offload, NetStepOffload, Runtime};
use bgpc::util::prng::Rng;

/// Load the artifacts, or `None` (with a visible skip message) when they
/// are absent. Set `BGPC_REQUIRE_ARTIFACTS=1` to turn skips into failures
/// (used by `make test-artifacts` after `make artifacts`).
fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    match Runtime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            let require = matches!(
                std::env::var("BGPC_REQUIRE_ARTIFACTS").as_deref(),
                Ok("1") | Ok("true")
            );
            if require {
                panic!("artifacts required but unavailable: {e}");
            }
            eprintln!("skipping PJRT roundtrip test: {e}");
            None
        }
    }
}

#[test]
fn kernel_matches_native_mirror_on_random_tiles() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(0xA0B1);
    for bucket in rt.buckets() {
        let (b, k) = (bucket.b, bucket.k);
        // random colors including -1 and duplicates; random degrees
        let mut colors: Vec<i32> =
            (0..b * k).map(|_| rng.range(0, k + 4) as i32 - 1).collect();
        let degs: Vec<i32> = (0..b).map(|_| rng.range(0, k + 1) as i32).collect();

        let (kernel_colors, kernel_keep) =
            bucket.step(&colors, &degs).expect("pjrt execute");

        let native_keep = offload::keep_rows_native(&colors, &degs, k);
        offload::step_rows_native(&mut colors, &degs, k);

        assert_eq!(kernel_keep, native_keep, "keep mask b={b} k={k}");
        assert_eq!(kernel_colors, colors, "colors b={b} k={k}");
    }
}

#[test]
fn kernel_matches_native_on_adversarial_rows() {
    let Some(rt) = runtime() else { return };
    let bucket = rt.buckets().first().unwrap();
    let (b, k) = (bucket.b, bucket.k);
    // all-uncolored, all-same-color, already-valid, degree 0 and full
    let mut colors = vec![-1i32; b * k];
    let mut degs = vec![0i32; b];
    for (i, d) in degs.iter_mut().enumerate().take(b) {
        *d = (i % (k + 1)) as i32;
    }
    for row in 0..b {
        for j in 0..k {
            colors[row * k + j] = match row % 4 {
                0 => -1,
                1 => 3,
                2 => j as i32,
                _ => (k - 1 - j) as i32,
            };
        }
    }
    let (kernel_colors, kernel_keep) = bucket.step(&colors, &degs).unwrap();
    let native_keep = offload::keep_rows_native(&colors, &degs, k);
    offload::step_rows_native(&mut colors, &degs, k);
    assert_eq!(kernel_keep, native_keep);
    assert_eq!(kernel_colors, colors);
}

#[test]
fn offloaded_coloring_is_valid_on_random_graph() {
    let Some(rt) = runtime() else { return };
    let g = random_bipartite(400, 600, 4000, 7);
    let (colors, stats) = NetStepOffload::new(&rt).color(&g, 50).unwrap();
    assert!(bgpc_valid(&g, &colors).is_ok());
    assert!(stats.kernel_calls > 0, "offload actually used the kernel");
    assert!(stats.offloaded_nets > 0);
}

#[test]
fn offloaded_coloring_handles_oversized_nets() {
    let Some(rt) = runtime() else { return };
    // one star net bigger than the largest bucket K forces the native path
    let big = rt.max_k() + 50;
    let mut edges: Vec<(u32, u32)> = (0..big as u32).map(|u| (0, u)).collect();
    // plus some bucket-sized nets
    for v in 1..40u32 {
        for j in 0..6u32 {
            edges.push((v, (v * 7 + j) % big as u32));
        }
    }
    let m = bgpc::graph::Csr::from_edges(40, big, &edges);
    let g = bgpc::graph::Bipartite::from_net_incidence(m);
    let (colors, stats) = NetStepOffload::new(&rt).color(&g, 50).unwrap();
    assert!(bgpc_valid(&g, &colors).is_ok());
    assert!(stats.native_nets > 0, "oversized net went native");
}

#[test]
fn offloaded_matches_engine_color_quality_on_preset() {
    // not equality — different optimism — but the color count should be
    // in the same ballpark as the native N1-N2 engine (within 2x).
    let Some(rt) = runtime() else { return };
    let g = Preset::by_name("bone010").unwrap().bipartite(0.01, 3);
    let (colors, _) = NetStepOffload::new(&rt).color(&g, 50).unwrap();
    assert!(bgpc_valid(&g, &colors).is_ok());
    let n_pjrt = bgpc::coloring::stats::distinct_colors(&colors);

    let cfg = bgpc::coloring::Config::sim(bgpc::coloring::schedule::N1_N2, 16);
    let r = bgpc::coloring::color(&g, &cfg);
    assert!(n_pjrt <= 2 * r.n_colors + 8, "pjrt {n_pjrt} vs native {}", r.n_colors);
    assert!(r.n_colors <= 2 * n_pjrt + 8, "native {} vs pjrt {n_pjrt}", r.n_colors);
}
