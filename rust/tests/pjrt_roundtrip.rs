//! Integration: the AOT JAX/Pallas artifacts loaded through PJRT must be
//! bit-identical to the native Rust mirror of the net step, and the full
//! offloaded coloring path must produce valid colorings.
//!
//! Requires `make artifacts` (the Makefile test target guarantees it).

use bgpc::coloring::verify::bgpc_valid;
use bgpc::graph::generators::{random_bipartite, Preset};
use bgpc::runtime::{offload, NetStepOffload, Runtime};
use bgpc::util::prng::Rng;

fn runtime() -> Runtime {
    Runtime::load(Runtime::default_dir())
        .expect("artifacts missing — run `make artifacts` first")
}

#[test]
fn kernel_matches_native_mirror_on_random_tiles() {
    let rt = runtime();
    let mut rng = Rng::new(0xA0B1);
    for bucket in rt.buckets() {
        let (b, k) = (bucket.b, bucket.k);
        // random colors including -1 and duplicates; random degrees
        let mut colors: Vec<i32> =
            (0..b * k).map(|_| rng.range(0, k + 4) as i32 - 1).collect();
        let degs: Vec<i32> = (0..b).map(|_| rng.range(0, k + 1) as i32).collect();

        let (kernel_colors, kernel_keep) =
            bucket.step(&colors, &degs).expect("pjrt execute");

        let native_keep = offload::keep_rows_native(&colors, &degs, k);
        offload::step_rows_native(&mut colors, &degs, k);

        assert_eq!(kernel_keep, native_keep, "keep mask b={b} k={k}");
        assert_eq!(kernel_colors, colors, "colors b={b} k={k}");
    }
}

#[test]
fn kernel_matches_native_on_adversarial_rows() {
    let rt = runtime();
    let bucket = rt.buckets().first().unwrap();
    let (b, k) = (bucket.b, bucket.k);
    // all-uncolored, all-same-color, already-valid, degree 0 and full
    let mut colors = vec![-1i32; b * k];
    let mut degs = vec![0i32; b];
    for (i, d) in degs.iter_mut().enumerate().take(b) {
        *d = (i % (k + 1)) as i32;
    }
    for row in 0..b {
        for j in 0..k {
            colors[row * k + j] = match row % 4 {
                0 => -1,
                1 => 3,
                2 => j as i32,
                _ => (k - 1 - j) as i32,
            };
        }
    }
    let (kernel_colors, kernel_keep) = bucket.step(&colors, &degs).unwrap();
    let native_keep = offload::keep_rows_native(&colors, &degs, k);
    offload::step_rows_native(&mut colors, &degs, k);
    assert_eq!(kernel_keep, native_keep);
    assert_eq!(kernel_colors, colors);
}

#[test]
fn offloaded_coloring_is_valid_on_random_graph() {
    let rt = runtime();
    let g = random_bipartite(400, 600, 4000, 7);
    let (colors, stats) = NetStepOffload::new(&rt).color(&g, 50).unwrap();
    assert!(bgpc_valid(&g, &colors).is_ok());
    assert!(stats.kernel_calls > 0, "offload actually used the kernel");
    assert!(stats.offloaded_nets > 0);
}

#[test]
fn offloaded_coloring_handles_oversized_nets() {
    let rt = runtime();
    // one star net bigger than the largest bucket K forces the native path
    let big = rt.max_k() + 50;
    let mut edges: Vec<(u32, u32)> = (0..big as u32).map(|u| (0, u)).collect();
    // plus some bucket-sized nets
    for v in 1..40u32 {
        for j in 0..6u32 {
            edges.push((v, (v * 7 + j) % big as u32));
        }
    }
    let m = bgpc::graph::Csr::from_edges(40, big, &edges);
    let g = bgpc::graph::Bipartite::from_net_incidence(m);
    let (colors, stats) = NetStepOffload::new(&rt).color(&g, 50).unwrap();
    assert!(bgpc_valid(&g, &colors).is_ok());
    assert!(stats.native_nets > 0, "oversized net went native");
}

#[test]
fn offloaded_matches_engine_color_quality_on_preset() {
    // not equality — different optimism — but the color count should be
    // in the same ballpark as the native N1-N2 engine (within 2x).
    let rt = runtime();
    let g = Preset::by_name("bone010").unwrap().bipartite(0.01, 3);
    let (colors, _) = NetStepOffload::new(&rt).color(&g, 50).unwrap();
    assert!(bgpc_valid(&g, &colors).is_ok());
    let n_pjrt = bgpc::coloring::stats::distinct_colors(&colors);

    let cfg = bgpc::coloring::Config::sim(bgpc::coloring::schedule::N1_N2, 16);
    let r = bgpc::coloring::color_bgpc(&g, &cfg);
    assert!(n_pjrt <= 2 * r.n_colors + 8, "pjrt {n_pjrt} vs native {}", r.n_colors);
    assert!(r.n_colors <= 2 * n_pjrt + 8, "native {} vs pjrt {n_pjrt}", r.n_colors);
}
