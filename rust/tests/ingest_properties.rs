//! Ingestion-tier properties (DESIGN.md §15): the streamed `.mtx` parser
//! must agree bit for bit with the in-memory one on every preset, the
//! mmap-backed `.csrb` store must round-trip exactly and color
//! identically to the heap graph, and the u64 index seam must reject
//! overflow loudly instead of truncating.

use std::sync::Arc;

use bgpc::coloring::{color, schedule, Config};
use bgpc::graph::storage::{checked_u32, checked_usize, IndexWidth};
use bgpc::graph::{mtx, storage, Bipartite, Csr, GraphSource, PRESETS};
use bgpc::par::WorkerPool;

fn tmp_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("bgpc_ingest_properties");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Streamed parse (default and deliberately tiny chunks, so the chunk
/// boundary / line-overhang machinery actually engages) ≡ the in-memory
/// parser, for every preset family.
#[test]
fn streamed_parse_matches_in_memory_on_every_preset() {
    let pool = WorkerPool::new(4);
    for p in PRESETS.iter() {
        let m = p.net_incidence(0.02, 7);
        let path = tmp_path(&format!("{}_stream.mtx", p.name));
        mtx::write_mtx(&m, &path).unwrap();

        let reference = mtx::read_mtx(&path).unwrap();
        assert_eq!(reference, m, "{}: in-memory parser regressed", p.name);

        let streamed = mtx::stream_mtx_to_csr(&path, &pool).unwrap();
        assert_eq!(streamed, reference, "{}: streamed != in-memory", p.name);

        // 256-byte chunks: every coordinate line straddles chunk math
        let tiny = mtx::stream_mtx_to_csr_chunked(&path, &pool, 256).unwrap();
        assert_eq!(tiny, reference, "{}: tiny-chunk streamed diverged", p.name);

        std::fs::remove_file(&path).unwrap();
    }
}

/// Stream-to-disk then mmap-open must reproduce the same pattern the
/// in-memory paths see, and the file header must describe it truthfully.
#[test]
fn streamed_file_store_round_trips_bit_for_bit() {
    let pool = WorkerPool::new(4);
    for p in PRESETS.iter().take(3) {
        let m = p.net_incidence(0.02, 13);
        let src = tmp_path(&format!("{}_store.mtx", p.name));
        let store = tmp_path(&format!("{}_store.csrb", p.name));
        mtx::write_mtx(&m, &src).unwrap();

        let info = mtx::stream_mtx_to_file_chunked(&src, &store, &pool, 512).unwrap();
        assert_eq!(info.n_rows as usize, m.n_rows, "{}", p.name);
        assert_eq!(info.n_cols as usize, m.n_cols, "{}", p.name);
        assert_eq!(info.nnz as usize, m.nnz(), "{}", p.name);
        assert_eq!(info.width, IndexWidth::U32, "preset dims fit u32");
        assert_eq!(storage::csr_file_info(&store).unwrap().nnz, info.nnz);

        let mapped = storage::open_csr(&store).unwrap();
        assert!(mapped.adj.is_mapped(), "open_csr should borrow the file");
        assert_eq!(mapped, m, "{}: mapped store != original", p.name);

        std::fs::remove_file(&src).unwrap();
        std::fs::remove_file(&store).unwrap();
    }
}

/// A mmap-backed graph must color *bit-identically* to the heap-backed
/// one at t=1 (single-thread runs are deterministic; the backing store
/// must be invisible to the kernels).
#[test]
fn mapped_graph_colors_bit_identically_to_heap_at_t1() {
    let pool = Arc::new(WorkerPool::new(1));
    let heap = bgpc::graph::generators::Preset::by_name("coPapersDBLP")
        .unwrap()
        .net_incidence(0.05, 21);
    let store = tmp_path("copapers_t1.csrb");
    storage::write_csr(&heap, &store).unwrap();
    let mapped = storage::open_csr(&store).unwrap();
    assert!(mapped.adj.is_mapped());

    let cfg = Config::threads(schedule::N1_N2, 1);
    let gh = Bipartite::from_net_incidence(heap);
    let gm = Bipartite::from_net_incidence(mapped);
    let rh = bgpc::coloring::Colorer::new(&cfg).on(&pool).color(&gh);
    let rm = bgpc::coloring::Colorer::new(&cfg).on(&pool).color(&gm);
    assert_eq!(rh.colors, rm.colors, "backing store leaked into the run");
    assert_eq!(rh.n_colors, rm.n_colors);

    // one-shot transient-pool path must agree too
    let ro = color(&gm, &cfg);
    assert_eq!(ro.colors, rh.colors);
    std::fs::remove_file(&store).unwrap();
}

/// The u64 seam: conversions are checked, never truncating, and the
/// error names the offending quantity.
#[test]
fn u64_conversions_reject_overflow_with_context() {
    assert_eq!(checked_u32(123, "x").unwrap(), 123);
    let e = checked_u32(u64::from(u32::MAX) + 1, "row id").unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("row id"), "error lost its context: {msg}");
    assert!(msg.contains("4294967296"), "error lost the value: {msg}");

    assert_eq!(checked_usize(7, "y").unwrap(), 7);
    assert_eq!(IndexWidth::for_dims(1000, 1000), IndexWidth::U32);
    assert_eq!(
        IndexWidth::for_dims(u64::from(u32::MAX) + 1, 10),
        IndexWidth::U64,
        "row space beyond u32 must widen the store"
    );
    assert_eq!(IndexWidth::for_dims(10, u64::from(u32::MAX) + 1), IndexWidth::U64);
}

/// A `.mtx` whose declared dims overflow the in-memory u32 kernels must
/// be rejected by the streaming parser with a contextual error — never
/// silently wrapped. (The header itself is legal: only the *in-memory*
/// destination is too narrow.)
#[test]
fn oversized_mtx_dims_rejected_by_in_memory_paths() {
    let pool = WorkerPool::new(2);
    let path = tmp_path("too_wide.mtx");
    std::fs::write(
        &path,
        "%%MatrixMarket matrix coordinate pattern general\n5000000000 3 2\n1 1\n2 3\n",
    )
    .unwrap();

    let h = mtx::read_mtx_header(&path).unwrap();
    assert_eq!(h.n_rows, 5_000_000_000);

    let e = mtx::stream_mtx_to_csr(&path, &pool).unwrap_err();
    assert!(format!("{e:#}").contains("n_rows"), "untyped error: {e:#}");
    let e = mtx::read_mtx(&path).unwrap_err();
    assert!(format!("{e:#}").contains("overflow"), "untyped error: {e:#}");
    std::fs::remove_file(&path).unwrap();
}

/// Truncated or corrupt `.csrb` stores fail to open instead of mapping
/// garbage.
#[test]
fn truncated_store_rejected_on_open() {
    let m = Csr::from_edges(4, 4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
    let store = tmp_path("truncated.csrb");
    storage::write_csr(&m, &store).unwrap();
    let full = std::fs::read(&store).unwrap();
    std::fs::write(&store, &full[..full.len() - 3]).unwrap();
    assert!(storage::open_csr(&store).is_err(), "short file must not open");
    // and a bad magic likewise
    let mut bad = full.clone();
    bad[0] ^= 0xFF;
    std::fs::write(&store, &bad).unwrap();
    assert!(storage::open_csr(&store).is_err(), "bad magic must not open");
    std::fs::remove_file(&store).unwrap();
}

/// The GraphSource front door agrees with itself across backends — the
/// same spec parsed back from its label loads the same graph.
#[test]
fn graph_source_label_round_trip_loads_identical_graphs() {
    for spec in ["preset:bone010@0.02@5", "random:300x400x2000@9"] {
        let src = GraphSource::parse(spec).unwrap();
        let again = GraphSource::parse(&src.label()).unwrap();
        assert_eq!(src, again, "{spec}: label round-trip changed the source");
        let a = src.load().unwrap();
        let b = again.load().unwrap();
        assert_eq!(a.net_vtxs, b.net_vtxs, "{spec}: round-trip loaded a different graph");
    }
}
