//! D1GC through the coordinator, end-to-end (ISSUE 8): the
//! distance-1 problem is a full session citizen. A session opens over a
//! symmetric graph, absorbs a 0.1% update batch with a cheap repair
//! (≤ 10% of vertices recolored), serves epoch-snapshot reads that stay
//! `d1gc_valid` against an independently maintained graph of record,
//! and drives colored execution that matches a sequential sweep
//! bit-for-bit — before and after a dynamic repair.

use std::sync::Arc;

use bgpc::coloring::verify::d1gc_valid;
use bgpc::coloring::{schedule, Config};
use bgpc::coordinator::{EngineSel, ExecKernel, Job, JobInput, Service};
use bgpc::exec::SharedBuf;
use bgpc::par::Cost;
use bgpc::testing::{random_symmetric_update_batch, skewed_symmetric};
use bgpc::util::prng::Rng;

/// Acceptance end-to-end: a coordinator D1GC session absorbs a 0.1%
/// edge batch via `JobInput::Update`; the repair touches ≤ 10% of the
/// vertices, the outcome reports the D1GC problem, the metrics count it
/// under its own kind, and the epoch snapshot stays valid against a
/// `DeltaSymmetric` mirror of the same edits.
#[test]
fn coordinator_d1gc_session_absorbs_batch_end_to_end() {
    let m = skewed_symmetric(2500, 20000, 7);
    let n = m.n_rows;
    let cfg = Config::sim(schedule::N1_N2, 16);
    let svc = Service::start(2, None);
    let (sid, init) = svc.open_session_d1gc("d1gc-e2e", &m, cfg.clone());
    assert!(init.valid, "{:?}", init.error);
    assert_eq!(init.problem, Some(bgpc::Problem::D1gc));
    let bring_up = svc.session_colors(sid).expect("session open");
    assert!(d1gc_valid(&m, &bring_up).is_ok(), "bring-up coloring invalid");

    let mut rng = Rng::new(99);
    let batch = random_symmetric_update_batch(&m, (m.nnz() / 2000).max(16), &mut rng);
    let o = svc
        .submit(Job {
            name: "upd".into(),
            input: JobInput::Update { session: sid, batch: Arc::new(batch.clone()) },
            cfg: cfg.clone(),
            engine: EngineSel::Auto,
        })
        .wait();
    assert!(o.valid, "{:?}", o.error);
    assert_eq!(o.problem, Some(bgpc::Problem::D1gc));
    let st = o.batch.expect("update outcome must carry batch stats");
    assert!(
        st.recolored * 10 <= n,
        "0.1% batch repaired {} of {n} vertices (> 10%)",
        st.recolored
    );
    assert_eq!(svc.metrics().updates_d1gc(), 1);
    assert_eq!(svc.metrics().updates_d2gc(), 0, "D1GC must not count as D2GC");
    assert_eq!(svc.metrics().updates_bgpc(), 0, "D1GC must not count as BGPC");

    // cross-check against an independently built post-batch graph
    let mut mirror = bgpc::dynamic::DeltaSymmetric::new(m);
    for &(a, b) in &batch.add_edges {
        mirror.add_edge(a, b);
    }
    for &(a, b) in &batch.remove_edges {
        mirror.remove_edge(a, b);
    }
    let colors = svc.session_colors(sid).expect("session open");
    assert!(d1gc_valid(mirror.graph(), &colors).is_ok(), "epoch snapshot invalid");
    assert!(svc.close_session(sid));
    svc.shutdown();
}

/// Colored execution over a D1GC session equals the sequential sweep
/// bit-for-bit: each item scatters into its own slot (disjoint by
/// construction; the schedule partitions the items), so any divergence
/// is a lost or doubled item in the color schedule / executor path.
/// Checked before and after a dynamic repair, so the incremental
/// schedule refresh is covered too.
#[test]
fn d1gc_colored_execute_matches_sequential_bit_for_bit() {
    let m = skewed_symmetric(400, 2600, 3);
    let n = m.n_rows;
    let cfg = Config::sim(schedule::V_N2, 8);
    let svc = Service::start(2, None);
    let (sid, init) = svc.open_session_d1gc("d1gc-exec", &m, cfg.clone());
    assert!(init.valid, "{:?}", init.error);

    let run_and_check = |rounds: usize, tag: &str| {
        let colors = svc.session_colors(sid).expect("session open");
        let want: Vec<u64> = (0..n)
            .map(|u| rounds as u64 * (u as u64 + 1) * (colors[u] as u64 + 1))
            .collect();
        let acc = Arc::new(SharedBuf::new(vec![0u64; n]));
        let acc_k = acc.clone();
        let kernel = ExecKernel::new(move |item, color| {
            // SAFETY: the schedule partitions items, so slot `item` is
            // touched by exactly one kernel invocation per round.
            unsafe {
                *acc_k.slot(item) += (item as u64 + 1) * (color as u64 + 1);
            }
            Cost::new(1)
        });
        let o = svc.execute(tag, sid, rounds, kernel).wait();
        assert!(o.valid, "{tag}: {:?}", o.error);
        // SAFETY: the job completed; no kernel is writing any more.
        let got: Vec<u64> = (0..n).map(|i| unsafe { *acc.peek(i) }).collect();
        assert_eq!(got, want, "{tag}: colored execute diverged from sequential");
    };

    run_and_check(1, "fresh-r1");
    run_and_check(3, "fresh-r3");

    // perturb the graph, then the refreshed schedule must still agree
    let mut rng = Rng::new(17);
    let batch = random_symmetric_update_batch(&m, 24, &mut rng);
    let o = svc
        .submit(Job {
            name: "perturb".into(),
            input: JobInput::Update { session: sid, batch: Arc::new(batch) },
            cfg: cfg.clone(),
            engine: EngineSel::Auto,
        })
        .wait();
    assert!(o.valid, "{:?}", o.error);
    run_and_check(2, "post-repair-r2");

    assert!(svc.close_session(sid));
    svc.shutdown();
}

/// The strategy seam reaches the coordinator: a D1GC session opened
/// with `ldf+fix` brings up a valid coloring no worse than the
/// default's, and stateless D1GC jobs route through the native engine
/// under `EngineSel::Auto`.
#[test]
fn d1gc_sessions_and_stateless_jobs_accept_strategies() {
    let m = skewed_symmetric(600, 4200, 11);
    let svc = Service::start(2, None);
    let plain = Config::sim(schedule::N1_N2, 8)
        .with_strategy(bgpc::Strategy::parse("ldf").unwrap());
    let fixed = Config::sim(schedule::N1_N2, 8)
        .with_strategy(bgpc::Strategy::parse("ldf+fix").unwrap());
    let (sa, ia) = svc.open_session_d1gc("plain-ldf", &m, plain.clone());
    let (sb, ib) = svc.open_session_d1gc("ldf-fixed", &m, fixed.clone());
    assert!(ia.valid && ib.valid);
    let fixed_colors = svc.session_colors(sb).expect("session open");
    assert!(d1gc_valid(&m, &fixed_colors).is_ok());
    assert!(
        ib.n_colors <= ia.n_colors,
        "ldf+fix used more colors than plain ldf: {} vs {}",
        ib.n_colors,
        ia.n_colors
    );
    let o = svc
        .submit(Job {
            name: "stateless-d1".into(),
            input: JobInput::D1gc(Arc::new(m.clone())),
            cfg: fixed,
            engine: EngineSel::Auto,
        })
        .wait();
    // run_stateless verifies with d1gc_valid before reporting valid
    assert!(o.valid, "{:?}", o.error);
    assert_eq!(o.problem, Some(bgpc::Problem::D1gc));
    assert!(o.n_colors > 0);
    assert!(svc.close_session(sa) && svc.close_session(sb));
    svc.shutdown();
}
