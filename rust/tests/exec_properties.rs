//! exec-subsystem properties (ISSUE 5 / DESIGN.md §11): every per-color
//! frontier a [`bgpc::exec::ColorSchedule`] builds is conflict-free —
//! for every preset × {None, B1, B2} × both problems — and the
//! [`bgpc::exec::Executor`] is equivalent to a sequential sweep at
//! t = 1 and t = 4. Plus: the incremental refresh after a dynamic
//! repair produces exactly the schedule a full rebuild would.

use std::sync::Arc;

use bgpc::coloring::{color, schedule, Balance, Config};
use bgpc::dynamic::DynamicSession;
use bgpc::exec::{ColorSchedule, Executor, SharedBuf};
use bgpc::graph::generators::Preset;
use bgpc::graph::PRESETS;
use bgpc::par::{Cost, WorkerPool};
use bgpc::testing::random_update_batch;
use bgpc::util::prng::Rng;

/// Bucket `c` sorted for order-insensitive comparison (empty when the
/// schedule has no such bucket — a refreshed schedule may differ from
/// a fresh build only by trailing empty buckets).
fn bucket_sorted(s: &ColorSchedule, c: usize) -> Vec<u32> {
    let mut v = Vec::new();
    if c < s.n_colors() {
        v.extend_from_slice(s.color_set(c));
    }
    v.sort_unstable();
    v
}

/// Bucket membership must mirror the coloring exactly: a partition,
/// each item in the bucket of its own color.
fn assert_partition(sched: &ColorSchedule, colors: &[i32], ctx: &str) {
    assert_eq!(sched.n_items(), colors.len(), "{ctx}: item count");
    let total: usize = sched.cardinalities().iter().sum();
    assert_eq!(total, colors.len(), "{ctx}: buckets must partition the items");
    for (c, set) in sched.frontiers() {
        for &u in set {
            assert_eq!(colors[u as usize], c as i32, "{ctx}: item {u} in the wrong bucket");
        }
    }
}

#[test]
fn prop_bgpc_frontiers_conflict_free_on_every_preset_and_balance() {
    // BGPC conflict definition: two columns conflict iff they share a
    // net. Stamp each net with the color of the frontier that last
    // touched it — a second touch within one frontier is a conflict.
    for p in PRESETS.iter() {
        let g = p.bipartite(0.02, 9);
        for bal in [Balance::None, Balance::B1, Balance::B2] {
            let r = color(&g, &Config::sim(schedule::V_N2, 8).with_balance(bal));
            let sched = ColorSchedule::from_colors(&r.colors);
            let ctx = format!("{} {bal:?}", p.name);
            assert_partition(&sched, &r.colors, &ctx);
            let mut stamp = vec![usize::MAX; g.n_nets()];
            for (c, set) in sched.frontiers() {
                for &u in set {
                    for &v in g.nets(u as usize) {
                        assert_ne!(
                            stamp[v as usize], c,
                            "{ctx}: two items of frontier {c} share net {v}"
                        );
                        stamp[v as usize] = c;
                    }
                }
            }
        }
    }
}

#[test]
fn prop_d2gc_frontiers_distance2_conflict_free_on_symmetric_presets() {
    // D2GC conflict definition: distance ≤ 2. For each frontier, mark
    // its members; no member may see another member among its
    // neighbors (distance 1) or its neighbors' neighbors (distance 2).
    for p in PRESETS.iter().filter(|p| p.symmetric) {
        let m = p.net_incidence(0.02, 9);
        for bal in [Balance::None, Balance::B1, Balance::B2] {
            let r = color(&m, &Config::sim(schedule::V_N2, 8).with_balance(bal));
            let sched = ColorSchedule::from_colors(&r.colors);
            let ctx = format!("{} {bal:?}", p.name);
            assert_partition(&sched, &r.colors, &ctx);
            let mut marked = vec![false; m.n_rows];
            for (c, set) in sched.frontiers() {
                for &u in set {
                    marked[u as usize] = true;
                }
                for &u in set {
                    let u = u as usize;
                    for &w in m.row(u) {
                        let w = w as usize;
                        if w == u {
                            continue; // diagonal entry
                        }
                        assert!(
                            !marked[w],
                            "{ctx}: frontier {c} holds adjacent items {u} and {w}"
                        );
                        for &x in m.row(w) {
                            let x = x as usize;
                            assert!(
                                x == u || x == w || !marked[x],
                                "{ctx}: frontier {c} holds {u} and {x} at distance 2 (via {w})"
                            );
                        }
                    }
                }
                for &u in set {
                    marked[u as usize] = false;
                }
            }
        }
    }
}

#[test]
fn executor_equals_sequential_sweep_at_t1_and_t4() {
    // An order-free integer scatter: the colored execution must equal
    // the natural-order sequential sweep bit-for-bit, at every thread
    // count and round count.
    for preset in ["20M_movielens", "coPapersDBLP"] {
        let g = Preset::by_name(preset).unwrap().bipartite(0.05, 3);
        let r = color(&g, &Config::sim(schedule::N1_N2, 8));
        let sched = ColorSchedule::from_colors(&r.colors);
        let mut base = vec![0u64; g.n_nets()];
        for u in 0..g.n_vertices() {
            for &v in g.nets(u) {
                base[v as usize] = base[v as usize].wrapping_add((u as u64 + 1) * (v as u64 + 1));
            }
        }
        for rounds in [1usize, 3] {
            let want: Vec<u64> = base.iter().map(|&x| x.wrapping_mul(rounds as u64)).collect();
            for t in [1usize, 4] {
                let pool = Arc::new(WorkerPool::new(t));
                let acc = SharedBuf::new(vec![0u64; g.n_nets()]);
                let mut ex = Executor::new(&pool);
                let rep = ex.run(&sched, rounds, |u, _color| {
                    let mut units = 0u64;
                    for &v in g.nets(u) {
                        // SAFETY: no two columns in one frontier share
                        // a net; colors are barrier-separated.
                        unsafe {
                            *acc.slot(v as usize) = (*acc.slot(v as usize))
                                .wrapping_add((u as u64 + 1) * (v as u64 + 1));
                        }
                        units += 1;
                    }
                    Cost::new(units)
                });
                assert_eq!(
                    acc.into_vec(),
                    want,
                    "{preset} rounds={rounds} t={t}: executor diverged from sequential"
                );
                assert_eq!(rep.items, (g.n_vertices() * rounds) as u64, "{preset} t={t}");
                assert_eq!(rep.busy_total(), (g.nnz() * rounds) as u64, "{preset} t={t}");
            }
        }
    }
}

#[test]
fn refresh_after_dynamic_repair_equals_full_rebuild() {
    let g = Preset::by_name("20M_movielens").unwrap().bipartite(0.05, 11);
    let (mut session, init) = DynamicSession::start(g, Config::sim(schedule::N1_N2, 8));
    let mut sched = ColorSchedule::from_colors(&init.colors);
    let mut rng = Rng::new(0xE8EC);
    for round in 0..4 {
        let edits = 30 + round * 10;
        let batch = random_update_batch(session.graph(), edits, &mut rng);
        let st = session.apply(&batch);
        assert!(session.verify().is_ok(), "round {round}: repair left an invalid coloring");
        let rs = sched.refresh(session.colors());
        assert!(!rs.rebuilt, "round {round}: same-size refresh must be incremental");
        assert!(
            rs.moved <= st.recolored,
            "round {round}: refresh moved {} items but the repair recolored only {}",
            rs.moved,
            st.recolored
        );
        // the incremental schedule equals a fresh counting sort,
        // bucket by bucket (order within a bucket aside)
        let fresh = ColorSchedule::from_colors(session.colors());
        for c in 0..sched.n_colors().max(fresh.n_colors()) {
            assert_eq!(
                bucket_sorted(&sched, c),
                bucket_sorted(&fresh, c),
                "round {round}: bucket {c} diverged from a full rebuild"
            );
        }
    }
}

/// Session-lifecycle race on the sharded service: a kernel panic
/// mid-execute must surface as that job's error while leaving the
/// session, its shard pool, the sibling shard, and the dispatchers all
/// healthy — updates and executes keep flowing afterwards.
#[test]
fn kernel_panic_mid_execute_leaves_shard_healthy() {
    use bgpc::coordinator::{EngineSel, ExecKernel, Job, JobInput, Service, ServiceOpts};
    use bgpc::dynamic::UpdateBatch;
    use bgpc::graph::generators::random_bipartite;
    let svc = Service::start_sharded(ServiceOpts {
        shards: 2,
        dispatchers: 2,
        pool_threads: 2,
        fuse_updates: 4,
        artifacts: None,
    });
    // two sessions land on the two distinct shards (id % shards)
    let ga = random_bipartite(60, 90, 600, 51);
    let gb = random_bipartite(50, 80, 500, 52);
    let cfg = Config::sim(schedule::N1_N2, 4);
    let (sa, ia) = svc.open_session("a", &ga, cfg.clone());
    let (sb, ib) = svc.open_session("b", &gb, cfg.clone());
    assert!(ia.valid && ib.valid);
    let bomb = ExecKernel::new(|item, _color| {
        assert!(item != 5, "planted kernel failure");
        Cost::new(1)
    });
    let o = svc.execute("boom", sa, 1, bomb).wait();
    assert!(!o.valid);
    assert!(o.error.unwrap().contains("kernel panicked"));
    // the panicking session still serves reads, executes, and updates
    assert!(svc.session_colors(sa).is_some());
    let ok = svc.execute("retry", sa, 1, ExecKernel::new(|_, _| Cost::new(1))).wait();
    assert!(ok.valid, "{:?}", ok.error);
    let mut batch = UpdateBatch::default();
    batch.add_edges.push((3, 7));
    let u = svc
        .submit_async(Job {
            name: "after-boom".into(),
            input: JobInput::Update { session: sa, batch: std::sync::Arc::new(batch) },
            cfg: cfg.clone(),
            engine: EngineSel::Auto,
        })
        .wait();
    assert!(u.valid, "{:?}", u.error);
    assert_eq!(u.epoch, Some(1));
    // the sibling shard never noticed
    let other = svc.execute("sibling", sb, 2, ExecKernel::new(|_, _| Cost::new(1))).wait();
    assert!(other.valid, "{:?}", other.error);
    assert!(svc.shard_stats().iter().all(|s| s.regions > 0));
    assert!(svc.close_session(sa) && svc.close_session(sb));
    svc.shutdown();
}
