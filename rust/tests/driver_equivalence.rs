//! Driver equivalence: the pool-backed [`ThreadsDriver`], the old
//! spawn-per-region driver (kept here as the reference implementation —
//! it no longer exists on any hot path), and the sequential baseline
//! must agree on every preset, for BGPC and D2GC.
//!
//! Single-threaded real execution is deterministic, so the three
//! backends must produce bit-identical colorings under a fixed seed;
//! multi-threaded runs are racy by design, so there the contract is
//! validity (plus determinism of repeated pool runs at `t = 1`, which
//! guards against state leaking between regions of a reused team).

use bgpc::coloring::verify::{bgpc_valid, d2gc_valid};
// aliased: importing the engine modules under their own names would make
// the first `use` segment `bgpc` ambiguous with the crate name
use bgpc::coloring::{bgpc as bg, d2gc as d2, schedule, Balance};
use bgpc::graph::PRESETS;
use bgpc::par::ThreadsDriver;
// the retired spawn-per-region driver, kept verbatim as the reference
use bgpc::testing::SpawnDriver;

const SCALE: f64 = 0.02;
const SEED: u64 = 7;

#[test]
fn bgpc_pool_spawn_and_sequential_agree_on_every_preset() {
    for p in PRESETS.iter() {
        let g = p.bipartite(SCALE, SEED);
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        for spec in [schedule::V_V, schedule::V_V_64D, schedule::V_V_AUTO, schedule::N1_N2] {
            // t = 1: all backends are deterministic and must agree bit-for-bit
            // (including the Chunk::Auto schedule — chunking is irrelevant
            // on a one-thread team, so Auto must change nothing at t=1)
            let r_pool = bg::run(&g, &order, &spec, Balance::None, &mut ThreadsDriver::new(1));
            let r_spawn = bg::run(&g, &order, &spec, Balance::None, &mut SpawnDriver { t: 1 });
            assert!(bgpc_valid(&g, &r_pool.colors).is_ok(), "{} {} pool", p.name, spec.name);
            assert_eq!(
                r_pool.colors, r_spawn.colors,
                "{} {}: pool vs spawn at t=1",
                p.name, spec.name
            );
            // multi-thread: races are legal, the coloring must be valid
            let r_pool4 = bg::run(&g, &order, &spec, Balance::None, &mut ThreadsDriver::new(4));
            let r_spawn4 = bg::run(&g, &order, &spec, Balance::None, &mut SpawnDriver { t: 4 });
            assert!(bgpc_valid(&g, &r_pool4.colors).is_ok(), "{} {} pool t=4", p.name, spec.name);
            assert!(bgpc_valid(&g, &r_spawn4.colors).is_ok(), "{} {} spawn t=4", p.name, spec.name);
        }
        // the engine's sequential greedy is the ground truth for V-V at t=1
        let r_vv = bg::run(&g, &order, &schedule::V_V, Balance::None, &mut ThreadsDriver::new(1));
        let (seq_colors, _) = bg::seq::greedy(&g, &order);
        assert_eq!(r_vv.colors, seq_colors, "{}: V-V t=1 must equal sequential greedy", p.name);
    }
}

#[test]
fn d2gc_pool_spawn_and_sequential_agree_on_symmetric_presets() {
    for p in PRESETS.iter().filter(|p| p.symmetric) {
        let m = p.net_incidence(SCALE, SEED);
        let order: Vec<u32> = (0..m.n_rows as u32).collect();
        for spec in [schedule::V_V_64D, schedule::V_V_AUTO, schedule::N1_N2] {
            let r_pool = d2::run(&m, &order, &spec, Balance::None, &mut ThreadsDriver::new(1));
            let r_spawn = d2::run(&m, &order, &spec, Balance::None, &mut SpawnDriver { t: 1 });
            assert!(d2gc_valid(&m, &r_pool.colors).is_ok(), "{} {} pool", p.name, spec.name);
            assert_eq!(
                r_pool.colors, r_spawn.colors,
                "{} {}: pool vs spawn at t=1",
                p.name, spec.name
            );
            let r_pool4 = d2::run(&m, &order, &spec, Balance::None, &mut ThreadsDriver::new(4));
            let r_spawn4 = d2::run(&m, &order, &spec, Balance::None, &mut SpawnDriver { t: 4 });
            assert!(d2gc_valid(&m, &r_pool4.colors).is_ok(), "{} {} pool t=4", p.name, spec.name);
            assert!(d2gc_valid(&m, &r_spawn4.colors).is_ok(), "{} {} spawn t=4", p.name, spec.name);
        }
    }
}

#[test]
fn reused_pool_runs_are_deterministic_at_t1() {
    // One driver (one pool, one scratch lifetime) run twice must not
    // leak state between runs: identical colorings.
    let p = PRESETS.iter().find(|p| p.name == "coPapersDBLP").unwrap();
    let g = p.bipartite(SCALE, SEED);
    let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
    let mut d = ThreadsDriver::new(1);
    let a = bg::run(&g, &order, &schedule::N1_N2, Balance::None, &mut d);
    let b = bg::run(&g, &order, &schedule::N1_N2, Balance::None, &mut d);
    assert_eq!(a.colors, b.colors);
    assert_eq!(a.iterations, b.iterations);
}
