//! Graph substrate integration tests: Matrix-Market I/O round-trips and
//! generator determinism. Every other test in the suite leans on these
//! two properties — a silent corruption here would invalidate all of
//! them, so they get their own gate.

use std::io::Cursor;

use bgpc::graph::generators::{random_bipartite, Preset};
use bgpc::graph::{mtx, Csr, PRESETS};

fn tmp_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("bgpc_graph_io_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn mtx_roundtrip_preserves_every_preset_csr() {
    for p in PRESETS.iter() {
        let m = p.net_incidence(0.01, 11);
        m.validate().unwrap();
        let path = tmp_path(&format!("{}.mtx", p.name));
        mtx::write_mtx(&m, &path).unwrap();
        let back = mtx::read_mtx(&path).unwrap();
        assert_eq!(back, m, "{} did not survive the mtx round-trip", p.name);
        std::fs::remove_file(&path).unwrap();
    }
}

#[test]
fn mtx_roundtrip_preserves_random_bipartite_and_empty_rows() {
    // includes empty nets, empty trailing columns, and a 0-edge graph
    for (n_nets, n_vtxs, nnz, seed) in
        [(1usize, 1usize, 1usize, 1u64), (7, 13, 0, 2), (40, 25, 300, 3), (128, 500, 2000, 4)]
    {
        let g = random_bipartite(n_nets, n_vtxs, nnz, seed);
        let path = tmp_path(&format!("rb_{n_nets}_{n_vtxs}_{nnz}.mtx"));
        mtx::write_mtx(&g.net_vtxs, &path).unwrap();
        let back = mtx::read_mtx(&path).unwrap();
        assert_eq!(back, g.net_vtxs);
        std::fs::remove_file(&path).unwrap();
    }
}

#[test]
fn mtx_written_header_is_parseable_pattern_general() {
    let m = Csr::from_edges(2, 3, &[(0, 0), (1, 2)]);
    let path = tmp_path("header.mtx");
    mtx::write_mtx(&m, &path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.starts_with("%%MatrixMarket matrix coordinate pattern general"));
    // 1-based indices on entry lines
    assert!(text.contains("\n1 1\n"));
    assert!(text.contains("\n2 3\n"));
    let back = mtx::read_mtx_from(Cursor::new(text)).unwrap();
    assert_eq!(back, m);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn symmetric_mtx_input_matches_explicit_general_form() {
    // the same matrix given as `symmetric` (lower triangle) and as
    // `general` (all entries) must parse to the same CSR
    let sym = "%%MatrixMarket matrix coordinate real symmetric\n3 3 4\n1 1 2.0\n2 1 1.0\n3 1 1.0\n3 2 1.0\n";
    let gen = "%%MatrixMarket matrix coordinate pattern general\n3 3 7\n1 1\n1 2\n1 3\n2 1\n2 3\n3 1\n3 2\n";
    let a = mtx::read_mtx_from(Cursor::new(sym)).unwrap();
    let b = mtx::read_mtx_from(Cursor::new(gen)).unwrap();
    assert_eq!(a, b);
    assert!(a.is_structurally_symmetric());
}

#[test]
fn generators_same_seed_same_graph_all_presets() {
    for p in PRESETS.iter() {
        let a = p.net_incidence(0.01, 7);
        let b = p.net_incidence(0.01, 7);
        assert_eq!(a, b, "{} is not deterministic", p.name);
        let c = p.net_incidence(0.01, 8);
        assert_ne!(a, c, "{} ignores its seed", p.name);
    }
}

#[test]
fn bipartite_view_is_consistent_with_incidence() {
    for p in PRESETS.iter() {
        let g = p.bipartite(0.01, 5);
        g.validate().unwrap();
        assert_eq!(g.net_vtxs, p.net_incidence(0.01, 5), "{}", p.name);
    }
}

#[test]
fn random_bipartite_deterministic_and_in_range() {
    let a = random_bipartite(50, 70, 400, 99);
    let b = random_bipartite(50, 70, 400, 99);
    assert_eq!(a.net_vtxs, b.net_vtxs);
    a.validate().unwrap();
    assert!(a.n_nets() == 50 && a.n_vertices() == 70);
    assert!(a.nnz() <= 400, "dedup can only shrink");
}

#[test]
fn skewed_generators_deterministic_per_seed() {
    // the degree-skewed helpers behind the strategy sweep must be pure
    // functions of their seed, like every other generator here
    let a = bgpc::testing::skewed_bipartite(120, 160, 1500, 42);
    let b = bgpc::testing::skewed_bipartite(120, 160, 1500, 42);
    assert_eq!(a.net_vtxs, b.net_vtxs, "skewed_bipartite is not deterministic");
    a.validate().unwrap();
    let c = bgpc::testing::skewed_bipartite(120, 160, 1500, 43);
    assert_ne!(a.net_vtxs, c.net_vtxs, "skewed_bipartite ignores its seed");

    let sa = bgpc::testing::skewed_symmetric(150, 1200, 42);
    let sb = bgpc::testing::skewed_symmetric(150, 1200, 42);
    assert_eq!(sa, sb, "skewed_symmetric is not deterministic");
    assert!(sa.is_structurally_symmetric());
    let sc = bgpc::testing::skewed_symmetric(150, 1200, 43);
    assert_ne!(sa, sc, "skewed_symmetric ignores its seed");
}
