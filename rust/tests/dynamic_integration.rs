//! Integration tests for the dynamic (incremental coloring) subsystem:
//! the acceptance behaviour on every preset generator for BGPC, its
//! D2GC streaming-parity mirror on the symmetric presets, and
//! structural-fidelity stream checks.

use bgpc::coloring::{color, schedule, Config};
use bgpc::dynamic::{DynamicSession, UpdateBatch};
use bgpc::graph::{Csr, PRESETS};
// One batch-distribution definition shared with benches/dynamic.rs, so
// the test-scale and bench-scale acceptance checks gate the same stream.
use bgpc::testing::{random_symmetric_update_batch, random_update_batch};
use bgpc::util::prng::Rng;

/// On every preset: a ≤1% edge-update batch repairs into a coloring
/// that verifies, recolors ≤10% of the vertices, and is clearly cheaper
/// than a full recolor under the simulator's 16-thread cost model.
#[test]
fn small_batches_repair_cheaply_on_every_preset() {
    let cfg = Config::sim(schedule::N1_N2, 16);
    let mut speedups = Vec::new();
    for p in PRESETS.iter() {
        let g = p.bipartite(0.02, 9);
        let n = g.n_vertices();
        let (mut session, init) = DynamicSession::start(g.clone(), cfg.clone());
        assert!(init.colors.iter().all(|&c| c >= 0), "{}", p.name);

        // 0.1% of the edges (min 16 edits) — a "≤1%" update batch
        let mut rng = Rng::new(41);
        let edits = (g.nnz() / 1000).max(16);
        let batch = random_update_batch(session.graph(), edits, &mut rng);
        let stats = session.apply(&batch);

        assert!(session.verify().is_ok(), "{}: invalid after repair", p.name);
        assert!(
            stats.recolored * 10 <= n,
            "{}: recolored {} of {n} vertices (>10%)",
            p.name,
            stats.recolored
        );
        assert!(
            stats.frontier <= n,
            "{}: frontier {} exceeds |V_A|={n}",
            p.name,
            stats.frontier
        );
        let full = color(session.graph(), &cfg);
        speedups.push(full.seconds / stats.seconds.max(1e-12));
    }
    // Repair must beat recoloring from scratch. The per-preset ≥5x
    // acceptance number lives in benches/dynamic.rs at bench scale; at
    // this tiny test scale the simulator's per-region fork-skew floor
    // and single hot-vertex recolors compress individual ratios, so the
    // test gates the aggregate (and a sanity floor per preset).
    let geo = bgpc::util::geomean(&speedups);
    assert!(geo >= 3.0, "geomean repair speedup only {geo:.2}x ({speedups:?})");
    for (p, s) in PRESETS.iter().zip(&speedups) {
        assert!(*s >= 0.8, "{}: repair slower than full recolor ({s:.2}x)", p.name);
    }
}

/// Streaming many batches keeps the coloring valid and the graph of
/// record faithful to an independently-maintained edge set.
#[test]
fn streamed_batches_track_ground_truth() {
    use std::collections::BTreeSet;
    let p = bgpc::graph::Preset::by_name("coPapersDBLP").unwrap();
    let g0 = p.bipartite(0.01, 3);
    let (n_nets, n_vtxs) = (g0.n_nets(), g0.n_vertices());
    let mut mirror: BTreeSet<(u32, u32)> = BTreeSet::new();
    for v in 0..n_nets {
        for &u in g0.vtxs(v) {
            mirror.insert((v as u32, u));
        }
    }
    let (mut session, _init) = DynamicSession::start(g0, Config::sim(schedule::V_N2, 8));
    let mut rng = Rng::new(1234);
    for round in 0..5 {
        let mut batch = UpdateBatch::default();
        for _ in 0..200 {
            let v = rng.range(0, n_nets) as u32;
            let u = rng.range(0, n_vtxs) as u32;
            if rng.chance(0.5) {
                batch.add_edges.push((v, u));
            } else {
                batch.remove_edges.push((v, u));
            }
        }
        // the mirror must mimic apply()'s order: all adds, then removes
        // (a pair both added and removed in one batch ends up absent)
        for &(v, u) in &batch.add_edges {
            mirror.insert((v, u));
        }
        for &(v, u) in &batch.remove_edges {
            mirror.remove(&(v, u));
        }
        let stats = session.apply(&batch);
        assert!(session.verify().is_ok(), "round {round} invalid ({stats:?})");
    }
    let edges: Vec<(u32, u32)> = mirror.iter().copied().collect();
    let truth = bgpc::graph::Csr::from_edges(n_nets, n_vtxs, &edges);
    let got = session.graph();
    assert_eq!(got.net_vtxs.ptr, truth.ptr, "graph of record diverged");
    assert_eq!(got.net_vtxs.adj, truth.adj);
}

/// A batch that only deletes edges must not recolor anything — and the
/// session must report exactly that.
#[test]
fn deletion_only_batches_are_free() {
    let p = bgpc::graph::Preset::by_name("af_shell").unwrap();
    let g = p.bipartite(0.01, 5);
    let (mut session, init) = DynamicSession::start(g.clone(), Config::sim(schedule::N1_N2, 8));
    let mut rng = Rng::new(77);
    let mut batch = UpdateBatch::default();
    for _ in 0..100 {
        let v = rng.range(0, g.n_nets());
        let row = g.vtxs(v);
        if row.is_empty() {
            continue;
        }
        batch.remove_edges.push((v as u32, row[rng.range(0, row.len())]));
    }
    let stats = session.apply(&batch);
    assert_eq!(stats.recolored, 0);
    assert_eq!(stats.conflicts, 0);
    assert_eq!(stats.colors_added, 0);
    assert_eq!(session.colors(), &init.colors[..], "coloring untouched");
    assert!(session.verify().is_ok());
}

/// Update batches that grow the graph (new nets over new vertices —
/// fresh constraint rows with fresh unknowns) repair incrementally.
#[test]
fn growth_batches_color_new_vertices() {
    let g = bgpc::graph::generators::random_bipartite(60, 90, 800, 13);
    let (mut session, _init) = DynamicSession::start(g, Config::sim(schedule::V_N2, 4));
    let mut batch = UpdateBatch::default();
    batch.add_nets.push(vec![0, 1, 90, 91]); // vertices 90/91 are new
    batch.add_nets.push(vec![91, 92]);
    let stats = session.apply(&batch);
    assert!(session.verify().is_ok());
    assert_eq!(session.colors().len(), 93);
    assert!(session.colors().iter().all(|&c| c >= 0));
    assert!(stats.recolored >= 3, "the new vertices were colored");
}

// ---- D2GC streaming parity (the problem-generic engine) ----

/// On every symmetric preset (Table V's D2GC-eligible column): a 0.1%
/// batch repairs into a coloring that satisfies `d2gc_valid`, recolors
/// ≤10% of the vertices, and beats full D2GC recoloring in aggregate
/// under the simulator's 16-thread cost model.
#[test]
fn d2gc_small_batches_repair_cheaply_on_symmetric_presets() {
    let cfg = Config::sim(schedule::N1_N2, 16);
    let mut speedups = Vec::new();
    for p in PRESETS.iter().filter(|p| p.symmetric) {
        let m = p.net_incidence(0.02, 9);
        let n = m.n_rows;
        let (mut session, init) = DynamicSession::start(m, cfg.clone());
        assert!(init.colors.iter().all(|&c| c >= 0), "{}", p.name);

        let mut rng = Rng::new(43);
        // 0.1% of the *undirected* edges (directed nnz counts pairs twice)
        let edits = (session.graph().nnz() / 2000).max(16);
        let batch = random_symmetric_update_batch(session.graph(), edits, &mut rng);
        let stats = session.apply(&batch);

        assert!(session.verify().is_ok(), "{}: invalid after D2GC repair", p.name);
        let repaired = session.colors().to_vec();
        assert!(
            bgpc::coloring::verify::d2gc_valid(session.graph(), &repaired).is_ok(),
            "{}: d2gc_valid disagrees with session.verify",
            p.name
        );
        assert!(
            stats.recolored * 10 <= n,
            "{}: recolored {} of {n} vertices (>10%)",
            p.name,
            stats.recolored
        );
        let full = color(session.graph(), &cfg);
        speedups.push(full.seconds / stats.seconds.max(1e-12));
    }
    // The per-preset ≥5x acceptance number lives in benches/dynamic.rs
    // at bench scale; at this tiny test scale the simulator's
    // per-region fork-skew floor compresses individual ratios, so the
    // test gates the aggregate (and a sanity floor per preset).
    let geo = bgpc::util::geomean(&speedups);
    assert!(geo >= 3.0, "geomean D2GC repair speedup only {geo:.2}x ({speedups:?})");
    for (p, s) in PRESETS.iter().filter(|p| p.symmetric).zip(&speedups) {
        assert!(*s >= 0.8, "{}: repair slower than full recolor ({s:.2}x)", p.name);
    }
}

/// `run_capped` with cap 0 sends the whole queue to the sequential
/// safety net, which must reproduce the D2GC sequential greedy
/// baseline bit-for-bit (the same property BGPC guarantees).
#[test]
fn d2gc_cap_zero_reproduces_sequential_greedy() {
    use bgpc::coloring::d2gc;
    use bgpc::coloring::{Balance, ThreadState};
    use bgpc::par::ThreadsDriver;
    let g = bgpc::graph::generators::random_symmetric(150, 500, 19);
    let order: Vec<u32> = (0..150u32).collect();
    let mut ts = ThreadState::bank(1, d2gc::color_cap(&g));
    let mut d = ThreadsDriver::new(1);
    let r = d2gc::run_capped(&g, &order, &schedule::V_V, Balance::None, &mut d, &mut ts, 0);
    let (seq_colors, _) = d2gc::seq_greedy(&g, &order);
    assert_eq!(r.colors, seq_colors, "cap=0 fallback must equal greedy");
    assert_eq!(r.iterations, 0);
    assert!(bgpc::coloring::verify::d2gc_valid(&g, &r.colors).is_ok());
}

/// Streaming D2GC batches keeps the coloring valid, the pattern
/// structurally symmetric, and the graph of record faithful to an
/// independently maintained undirected edge set.
#[test]
fn d2gc_streamed_batches_track_ground_truth() {
    use std::collections::BTreeSet;
    let p = bgpc::graph::Preset::by_name("bone010").unwrap();
    let g0 = p.net_incidence(0.02, 3);
    let n = g0.n_rows;
    let mut mirror: BTreeSet<(u32, u32)> = BTreeSet::new();
    for v in 0..n {
        for &u in g0.row(v) {
            mirror.insert((v as u32, u));
        }
    }
    let (mut session, _init) = DynamicSession::start(g0, Config::sim(schedule::V_N2, 8));
    let mut rng = Rng::new(4321);
    for round in 0..5 {
        let mut batch = UpdateBatch::default();
        for _ in 0..100 {
            let a = rng.range(0, n) as u32;
            let b = rng.range(0, n) as u32;
            if rng.chance(0.5) {
                batch.add_edges.push((a, b));
            } else {
                batch.remove_edges.push((a, b));
            }
        }
        // the mirror must mimic apply()'s order: all adds, then removes
        for &(a, b) in &batch.add_edges {
            mirror.insert((a, b));
            mirror.insert((b, a));
        }
        for &(a, b) in &batch.remove_edges {
            mirror.remove(&(a, b));
            mirror.remove(&(b, a));
        }
        let stats = session.apply(&batch);
        assert!(session.verify().is_ok(), "round {round} invalid ({stats:?})");
    }
    let edges: Vec<(u32, u32)> = mirror.iter().copied().collect();
    let truth = Csr::from_edges(n, n, &edges);
    let got = session.graph();
    assert!(got.is_structurally_symmetric(), "symmetry drifted");
    assert_eq!(got.ptr, truth.ptr, "graph of record diverged");
    assert_eq!(got.adj, truth.adj);
}

/// Acceptance end-to-end: a coordinator D2GC session absorbs a 0.1%
/// edge batch via `JobInput::Update`; the repaired coloring passes
/// `d2gc_valid` and the outcome reports the D2GC problem.
#[test]
fn coordinator_d2gc_session_absorbs_batch_end_to_end() {
    use bgpc::coordinator::{EngineSel, Job, JobInput, Service};
    use std::sync::Arc;
    let p = bgpc::graph::Preset::by_name("af_shell").unwrap();
    let m = p.net_incidence(0.02, 7);
    let cfg = Config::sim(schedule::N1_N2, 16);
    let svc = Service::start(2, None);
    let (sid, init) = svc.open_session_d2gc("d2gc-e2e", &m, cfg.clone());
    assert!(init.valid);
    assert_eq!(init.problem, Some(bgpc::Problem::D2gc));

    let mut rng = Rng::new(99);
    let batch = random_symmetric_update_batch(&m, (m.nnz() / 2000).max(16), &mut rng);
    let o = svc
        .submit(Job {
            name: "upd".into(),
            input: JobInput::Update { session: sid, batch: Arc::new(batch.clone()) },
            cfg: cfg.clone(),
            engine: EngineSel::Auto,
        })
        .wait();
    assert!(o.valid, "{:?}", o.error);
    assert_eq!(o.problem, Some(bgpc::Problem::D2gc));
    assert!(o.batch.is_some());
    assert_eq!(svc.metrics().updates_d2gc(), 1);

    // cross-check against an independently built post-batch graph
    let mut mirror = bgpc::dynamic::DeltaSymmetric::new(m);
    for &(a, b) in &batch.add_edges {
        mirror.add_edge(a, b);
    }
    for &(a, b) in &batch.remove_edges {
        mirror.remove_edge(a, b);
    }
    let colors = svc.session_colors(sid).expect("session open");
    assert!(bgpc::coloring::verify::d2gc_valid(mirror.graph(), &colors).is_ok());
    assert!(svc.close_session(sid));
    svc.shutdown();
}

/// Session-lifecycle race: closing a session with updates still queued
/// must complete every handle — the batches the drain already committed
/// report contiguous epochs in submit order, everything later fails
/// with a "closed" error, and no coloring is served afterwards.
#[test]
fn close_session_during_inflight_updates_fails_cleanly() {
    use bgpc::coordinator::{EngineSel, Job, JobInput, Service, ServiceOpts};
    use std::sync::Arc;
    let svc = Service::start_sharded(ServiceOpts {
        dispatchers: 2,
        fuse_updates: 1,
        ..ServiceOpts::default()
    });
    let g = bgpc::graph::generators::random_bipartite(60, 90, 600, 23);
    let cfg = Config::sim(schedule::N1_N2, 4);
    let (sid, init) = svc.open_session("racy", &g, cfg.clone());
    assert!(init.valid);
    let mut handles = Vec::new();
    for k in 0..10u32 {
        let mut batch = UpdateBatch::default();
        batch.add_edges.push((k % 60, (k * 13) % 90));
        handles.push(svc.submit_async(Job {
            name: format!("r{k}"),
            input: JobInput::Update { session: sid, batch: Arc::new(batch) },
            cfg: cfg.clone(),
            engine: EngineSel::Auto,
        }));
    }
    // Race the close against the drain: it blocks on the state lock
    // until any in-flight batch commits, then fails the leftovers.
    assert!(svc.close_session(sid));
    let mut next_epoch = 1u64;
    for h in handles {
        let o = h.wait();
        if o.valid {
            assert_eq!(
                o.epoch,
                Some(next_epoch),
                "committed batches form an in-order prefix"
            );
            next_epoch += 1;
        } else {
            let err = o.error.expect("failed updates carry an error");
            assert!(err.contains("closed"), "unexpected error: {err}");
        }
    }
    assert!(svc.session_colors(sid).is_none(), "closed session serves nothing");
    assert!(!svc.close_session(sid), "second close is a no-op");
    svc.shutdown();
}

/// Out-of-order pickup, in-order apply: three dispatchers over two
/// shards race to drain the same session, but the pending queue admits
/// in submit order and the drain applies FIFO — every outcome's commit
/// epoch equals its submit index + 1, no matter which dispatcher (or
/// stolen lane) picked it up.
#[test]
fn out_of_order_pickup_still_commits_in_submit_order() {
    use bgpc::coordinator::{EngineSel, Job, JobInput, Service, ServiceOpts};
    use std::sync::Arc;
    let svc = Service::start_sharded(ServiceOpts {
        shards: 2,
        dispatchers: 3,
        pool_threads: 1,
        fuse_updates: 1,
        artifacts: None,
    });
    let g = bgpc::graph::generators::random_bipartite(80, 120, 900, 29);
    let cfg = Config::sim(schedule::N1_N2, 4);
    let (sid, init) = svc.open_session("ordered", &g, cfg.clone());
    assert!(init.valid);
    let n = 20u32;
    let mut handles = Vec::new();
    for k in 0..n {
        let mut batch = UpdateBatch::default();
        batch.add_edges.push(((k * 3) % 80, (k * 7) % 120));
        handles.push(svc.submit_async(Job {
            name: format!("o{k}"),
            input: JobInput::Update { session: sid, batch: Arc::new(batch) },
            cfg: cfg.clone(),
            engine: EngineSel::Auto,
        }));
    }
    for (i, h) in handles.into_iter().enumerate() {
        let o = h.wait();
        assert!(o.valid, "o{i}: {:?}", o.error);
        assert_eq!(
            o.epoch,
            Some(i as u64 + 1),
            "batch {i} must commit as epoch {}",
            i + 1
        );
    }
    assert_eq!(svc.session_epoch(sid), Some(n as u64));
    let colors = svc.session_colors(sid).expect("session open");
    // cross-check against an independently built post-stream graph
    let mut mirror = bgpc::dynamic::DeltaBipartite::new(g);
    for k in 0..n {
        mirror.add_edge((k * 3) % 80, (k * 7) % 120);
    }
    assert!(bgpc::coloring::verify::bgpc_valid(mirror.graph(), &colors).is_ok());
    assert!(svc.close_session(sid));
    svc.shutdown();
}
