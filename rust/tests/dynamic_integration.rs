//! Integration tests for the dynamic (incremental BGPC) subsystem:
//! the ISSUE's acceptance behaviour on every preset generator, plus a
//! structural-fidelity stream check.

use bgpc::coloring::{color_bgpc, schedule, Config};
use bgpc::dynamic::{DynamicSession, UpdateBatch};
use bgpc::graph::{Bipartite, PRESETS};
use bgpc::util::prng::Rng;

/// Mixed batch: `edits` incidences, alternating remove-existing and
/// add-random, deterministic in `rng`.
fn random_batch(g: &Bipartite, edits: usize, rng: &mut Rng) -> UpdateBatch {
    let mut b = UpdateBatch::default();
    for i in 0..edits {
        if i % 2 == 0 {
            let v = rng.range(0, g.n_nets());
            let row = g.vtxs(v);
            if row.is_empty() {
                continue;
            }
            let u = row[rng.range(0, row.len())];
            b.remove_edges.push((v as u32, u));
        } else {
            b.add_edges.push((
                rng.range(0, g.n_nets()) as u32,
                rng.range(0, g.n_vertices()) as u32,
            ));
        }
    }
    b
}

/// On every preset: a ≤1% edge-update batch repairs into a coloring
/// that verifies, recolors ≤10% of the vertices, and is clearly cheaper
/// than a full recolor under the simulator's 16-thread cost model.
#[test]
fn small_batches_repair_cheaply_on_every_preset() {
    let cfg = Config::sim(schedule::N1_N2, 16);
    let mut speedups = Vec::new();
    for p in PRESETS.iter() {
        let g = p.bipartite(0.02, 9);
        let n = g.n_vertices();
        let (mut session, init) = DynamicSession::start(g.clone(), cfg.clone());
        assert!(init.colors.iter().all(|&c| c >= 0), "{}", p.name);

        // 0.1% of the edges (min 16 edits) — a "≤1%" update batch
        let mut rng = Rng::new(41);
        let edits = (g.nnz() / 1000).max(16);
        let batch = random_batch(session.graph(), edits, &mut rng);
        let stats = session.apply(&batch);

        assert!(session.verify().is_ok(), "{}: invalid after repair", p.name);
        assert!(
            stats.recolored * 10 <= n,
            "{}: recolored {} of {n} vertices (>10%)",
            p.name,
            stats.recolored
        );
        assert!(
            stats.frontier <= n,
            "{}: frontier {} exceeds |V_A|={n}",
            p.name,
            stats.frontier
        );
        let full = color_bgpc(session.graph(), &cfg);
        speedups.push(full.seconds / stats.seconds.max(1e-12));
    }
    // Repair must beat recoloring from scratch. The per-preset ≥5x
    // acceptance number lives in benches/dynamic.rs at bench scale; at
    // this tiny test scale the simulator's per-region fork-skew floor
    // and single hot-vertex recolors compress individual ratios, so the
    // test gates the aggregate (and a sanity floor per preset).
    let geo = bgpc::util::geomean(&speedups);
    assert!(geo >= 3.0, "geomean repair speedup only {geo:.2}x ({speedups:?})");
    for (p, s) in PRESETS.iter().zip(&speedups) {
        assert!(*s >= 0.8, "{}: repair slower than full recolor ({s:.2}x)", p.name);
    }
}

/// Streaming many batches keeps the coloring valid and the graph of
/// record faithful to an independently-maintained edge set.
#[test]
fn streamed_batches_track_ground_truth() {
    use std::collections::BTreeSet;
    let p = bgpc::graph::Preset::by_name("coPapersDBLP").unwrap();
    let g0 = p.bipartite(0.01, 3);
    let (n_nets, n_vtxs) = (g0.n_nets(), g0.n_vertices());
    let mut mirror: BTreeSet<(u32, u32)> = BTreeSet::new();
    for v in 0..n_nets {
        for &u in g0.vtxs(v) {
            mirror.insert((v as u32, u));
        }
    }
    let (mut session, _init) = DynamicSession::start(g0, Config::sim(schedule::V_N2, 8));
    let mut rng = Rng::new(1234);
    for round in 0..5 {
        let mut batch = UpdateBatch::default();
        for _ in 0..200 {
            let v = rng.range(0, n_nets) as u32;
            let u = rng.range(0, n_vtxs) as u32;
            if rng.chance(0.5) {
                batch.add_edges.push((v, u));
            } else {
                batch.remove_edges.push((v, u));
            }
        }
        // the mirror must mimic apply()'s order: all adds, then removes
        // (a pair both added and removed in one batch ends up absent)
        for &(v, u) in &batch.add_edges {
            mirror.insert((v, u));
        }
        for &(v, u) in &batch.remove_edges {
            mirror.remove(&(v, u));
        }
        let stats = session.apply(&batch);
        assert!(session.verify().is_ok(), "round {round} invalid ({stats:?})");
    }
    let edges: Vec<(u32, u32)> = mirror.iter().copied().collect();
    let truth = bgpc::graph::Csr::from_edges(n_nets, n_vtxs, &edges);
    let got = session.graph();
    assert_eq!(got.net_vtxs.ptr, truth.ptr, "graph of record diverged");
    assert_eq!(got.net_vtxs.adj, truth.adj);
}

/// A batch that only deletes edges must not recolor anything — and the
/// session must report exactly that.
#[test]
fn deletion_only_batches_are_free() {
    let p = bgpc::graph::Preset::by_name("af_shell").unwrap();
    let g = p.bipartite(0.01, 5);
    let (mut session, init) = DynamicSession::start(g.clone(), Config::sim(schedule::N1_N2, 8));
    let mut rng = Rng::new(77);
    let mut batch = UpdateBatch::default();
    for _ in 0..100 {
        let v = rng.range(0, g.n_nets());
        let row = g.vtxs(v);
        if row.is_empty() {
            continue;
        }
        batch.remove_edges.push((v as u32, row[rng.range(0, row.len())]));
    }
    let stats = session.apply(&batch);
    assert_eq!(stats.recolored, 0);
    assert_eq!(stats.conflicts, 0);
    assert_eq!(stats.colors_added, 0);
    assert_eq!(session.colors(), &init.colors[..], "coloring untouched");
    assert!(session.verify().is_ok());
}

/// Update batches that grow the graph (new nets over new vertices —
/// fresh constraint rows with fresh unknowns) repair incrementally.
#[test]
fn growth_batches_color_new_vertices() {
    let g = bgpc::graph::generators::random_bipartite(60, 90, 800, 13);
    let (mut session, _init) = DynamicSession::start(g, Config::sim(schedule::V_N2, 4));
    let mut batch = UpdateBatch::default();
    batch.add_nets.push(vec![0, 1, 90, 91]); // vertices 90/91 are new
    batch.add_nets.push(vec![91, 92]);
    let stats = session.apply(&batch);
    assert!(session.verify().is_ok());
    assert_eq!(session.colors().len(), 93);
    assert!(session.colors().iter().all(|&c| c >= 0));
    assert!(stats.recolored >= 3, "the new vertices were colored");
}
