//! Engine-level equivalence of the word-packed forbidden-set scans
//! (`StampSet::first_fit` / `reverse_fit` / `first_fit_from`) against
//! the retained scalar reference scans (`*_scalar`).
//!
//! The unit tests in `coloring/forbidden.rs` fuzz the scans over
//! randomized sets; this suite closes the loop at engine scale: the
//! forbidden populations here come from *real greedy colorings* of the
//! preset and skewed instances — dense hub nets, saturated low ranges,
//! generation reuse across thousands of vertices — exactly the
//! distributions the hot loops feed the packed tier. Colors must match
//! bit-for-bit; probe counts are intentionally different units (words
//! vs slots) and are not compared.

use bgpc::coloring::bgpc as bg;
use bgpc::coloring::forbidden::StampSet;
use bgpc::graph::{Bipartite, PRESETS};
use bgpc::testing::skewed_bipartite;

/// The sequential BGPC greedy with every color chosen by the *scalar*
/// first-fit — the pre-packed reference implementation of
/// [`bg::seq::greedy`]'s selection step.
fn scalar_greedy(g: &Bipartite, order: &[u32]) -> Vec<i32> {
    let mut colors = vec![-1i32; g.n_vertices()];
    let mut f = StampSet::new(1024);
    for &w in order {
        let w = w as usize;
        f.next_gen();
        for &v in g.nets(w) {
            for &u in g.vtxs(v as usize) {
                let u = u as usize;
                if u != w && colors[u] >= 0 {
                    f.insert(colors[u]);
                }
            }
        }
        let (c, _) = f.first_fit_scalar();
        colors[w] = c;
    }
    colors
}

#[test]
fn packed_first_fit_reproduces_scalar_greedy_on_every_preset() {
    for p in PRESETS.iter() {
        let g = p.bipartite(0.02, 7);
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        let (packed, _) = bg::seq::greedy(&g, &order);
        assert_eq!(packed, scalar_greedy(&g, &order), "{}: packed vs scalar first-fit", p.name);
    }
}

#[test]
fn packed_first_fit_reproduces_scalar_greedy_on_skewed_instances() {
    for seed in [3u64, 11, 29] {
        let g = skewed_bipartite(400, 600, 8000, seed);
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        let (packed, _) = bg::seq::greedy(&g, &order);
        assert_eq!(packed, scalar_greedy(&g, &order), "seed {seed}: packed vs scalar first-fit");
    }
}

#[test]
fn packed_directional_scans_match_scalar_on_engine_populations() {
    // Rebuild each net's forbidden population from a finished greedy
    // coloring — the exact state Algorithm 8's pass 2 sees — and compare
    // the reverse/forward scans at the starts the engine actually uses
    // (|net| - 1 downward, |net| + 1 upward) plus word-boundary probes.
    for seed in [5u64, 17] {
        let g = skewed_bipartite(300, 500, 6000, seed);
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        let (colors, _) = bg::seq::greedy(&g, &order);
        let mut f = StampSet::new(bg::color_cap(&g));
        for v in 0..g.n_nets() {
            f.next_gen();
            for &u in g.vtxs(v) {
                let c = colors[u as usize];
                if c >= 0 {
                    f.insert(c);
                }
            }
            let deg = g.vtxs(v).len() as i32;
            for start in [-1, 0, deg - 1, deg, deg + 1, 62, 63, 64, 65, 127, 128] {
                assert_eq!(
                    f.reverse_fit(start).0,
                    f.reverse_fit_scalar(start).0,
                    "seed {seed} net {v} reverse from {start}"
                );
                assert_eq!(
                    f.first_fit_from(start).0,
                    f.first_fit_from_scalar(start).0,
                    "seed {seed} net {v} forward from {start}"
                );
            }
            assert_eq!(f.first_fit().0, f.first_fit_scalar().0, "seed {seed} net {v} first-fit");
        }
    }
}
