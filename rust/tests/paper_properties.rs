//! Property-based invariants (in-tree mini-proptest; see
//! `bgpc::testing`). Each property sweeps dozens of random instances,
//! including degenerate shapes, and reports the failing case parameters.

use bgpc::coloring::verify::{bgpc_valid, d1gc_valid, d2gc_valid};
use bgpc::coloring::{color, schedule, Balance, Config};
use bgpc::graph::{Bipartite, Ordering};
use bgpc::par::ThreadsDriver;
use bgpc::runtime::offload;
use bgpc::sim::{CostModel, SimDriver};
use bgpc::testing::{forall_bipartite, forall_symmetric, random_partial_colors};
use bgpc::util::prng::Rng;

#[test]
fn prop_every_schedule_yields_valid_coloring() {
    forall_bipartite(40, 0xC0FFEE, |g, case| {
        for spec in schedule::ALL {
            let r = color(g, &Config::sim(spec, 4));
            assert!(
                bgpc_valid(g, &r.colors).is_ok(),
                "{} invalid on {case:?}",
                spec.name
            );
            // colors are bounded by the two-hop degree + 1 for first-fit
            // schedules; net-based adds at most the max net degree.
            assert!(r.n_colors <= g.n_vertices().max(1));
        }
    });
}

#[test]
fn prop_net_twopass_never_exceeds_degree_bound_per_net() {
    // Alg. 8's reverse first-fit keeps fresh colors below |vtxs(v)|.
    forall_bipartite(30, 0xBEEF, |g, case| {
        use bgpc::coloring::bgpc::net;
        use bgpc::coloring::{NetColorAlg, ThreadState};
        use bgpc::par::Driver;
        let mut d = ThreadsDriver::new(1);
        let colors = d.new_colors(g.n_vertices());
        let mut ts = ThreadState::bank(1, g.n_vertices() + 4);
        net::color_phase(
            g,
            &colors,
            &mut d,
            &mut ts,
            64,
            NetColorAlg::TwoPass,
            Balance::None,
        );
        let max_deg = g.net_vtxs.max_deg() as i32;
        for u in 0..g.n_vertices() {
            let c = bgpc::par::ColorStore::committed(&colors, u);
            if !g.nets(u).is_empty() {
                assert!(c < max_deg, "color {c} >= max net degree {max_deg} ({case:?})");
            }
        }
    });
}

#[test]
fn prop_seq_greedy_color_bound() {
    // greedy first-fit uses at most (max two-hop degree + 1) colors
    forall_bipartite(30, 0xABCD, |g, _case| {
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        let (c, _) = bgpc::coloring::bgpc::seq::greedy(g, &order);
        assert!(bgpc_valid(g, &c).is_ok());
        let bound = (0..g.n_vertices()).map(|u| g.two_hop_bound(u)).max().unwrap_or(0) + 1;
        let used = bgpc::coloring::stats::distinct_colors(&c);
        assert!(used <= bound, "used {used} > bound {bound}");
    });
}

#[test]
fn prop_orderings_are_permutations() {
    forall_bipartite(25, 0x0DDE, |g, case| {
        for ord in [Ordering::Natural, Ordering::Random(1), Ordering::LargestFirst, Ordering::SmallestLast] {
            let o = ord.compute(g);
            let mut s = o.clone();
            s.sort_unstable();
            assert_eq!(
                s,
                (0..g.n_vertices() as u32).collect::<Vec<_>>(),
                "{ord:?} not a permutation on {case:?}"
            );
        }
    });
}

#[test]
fn prop_net_step_native_idempotent_and_valid() {
    // applying the row step twice changes nothing the second time
    let mut rng = Rng::new(0xF00D);
    for _ in 0..120 {
        let k = [3usize, 5, 8, 17][rng.range(0, 4)];
        let b = rng.range(1, 8);
        let mut colors = random_partial_colors(b * k, k as i32 + 2, rng.next_u64());
        let degs: Vec<i32> = (0..b).map(|_| rng.range(0, k + 1) as i32).collect();
        offload::step_rows_native(&mut colors, &degs, k);
        let once = colors.clone();
        offload::step_rows_native(&mut colors, &degs, k);
        assert_eq!(once, colors, "step must be idempotent per row");
    }
}

#[test]
fn prop_d2gc_valid_and_tighter_than_d1gc() {
    forall_symmetric(25, 0x2222, |g, seed| {
        let order: Vec<u32> = (0..g.n_rows as u32).collect();
        let (c2, _) = bgpc::coloring::d2gc::seq_greedy(g, &order);
        assert!(d2gc_valid(g, &c2).is_ok(), "seed {seed}");
        let (c1, _) = bgpc::coloring::d1gc::seq_greedy(g, &order);
        assert!(d1gc_valid(g, &c1).is_ok());
        // a valid D2GC coloring is also a valid D1GC coloring
        assert!(d1gc_valid(g, &c2).is_ok());
        let n2 = bgpc::coloring::stats::distinct_colors(&c2);
        let n1 = bgpc::coloring::stats::distinct_colors(&c1);
        assert!(n2 >= n1, "distance-2 needs at least as many colors");
    });
}

#[test]
fn prop_sim_determinism_across_thread_counts() {
    forall_bipartite(15, 0x5EED5, |g, case| {
        for t in [2usize, 7, 16] {
            let run = || {
                let mut d = SimDriver::new(t, CostModel::default());
                let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
                bgpc::coloring::bgpc::run(g, &order, &schedule::N1_N2, Balance::None, &mut d)
            };
            let a = run();
            let b = run();
            assert_eq!(a.colors, b.colors, "t={t} {case:?}");
            assert!(
                (a.seconds - b.seconds).abs() < 1e-15,
                "sim time must be bit-stable"
            );
        }
    });
}

#[test]
fn prop_mvcc_vs_atomic_store_agree_when_sequential() {
    // With a single thread the MVCC store must behave exactly like the
    // atomic store: same colors from the same schedule.
    forall_bipartite(20, 0x31337, |g, case| {
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        let mut dt = ThreadsDriver::new(1);
        let rt = bgpc::coloring::bgpc::run(g, &order, &schedule::N1_N2, Balance::None, &mut dt);
        let mut ds = SimDriver::new(1, CostModel::default());
        let rs = bgpc::coloring::bgpc::run(g, &order, &schedule::N1_N2, Balance::None, &mut ds);
        assert_eq!(rt.colors, rs.colors, "single-thread stores diverged on {case:?}");
    });
}

#[test]
fn prop_verify_rejects_fuzzed_corruptions() {
    // corrupt one vertex of a valid coloring; the checker must notice a
    // planted within-net duplicate.
    forall_bipartite(25, 0x7777, |g, _case| {
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        let (mut c, _) = bgpc::coloring::bgpc::seq::greedy(g, &order);
        // find a net with >= 2 vertices and copy one color over another
        let Some(v) = (0..g.n_nets()).find(|&v| g.vtxs(v).len() >= 2) else {
            return;
        };
        let a = g.vtxs(v)[0] as usize;
        let b = g.vtxs(v)[1] as usize;
        c[b] = c[a];
        assert!(bgpc_valid(g, &c).is_err(), "corruption must be detected");
    });
}

#[test]
fn prop_balancing_on_presets_valid_capped_and_less_skewed() {
    // Table VI's claim, as properties over every calibrated preset:
    // balanced runs verify, stay inside the engine's color_cap bound,
    // and reduce color-cardinality skew relative to the unbalanced
    // baseline (per-preset with slack; strictly in aggregate).
    use bgpc::coloring::bgpc::color_cap;
    use bgpc::graph::PRESETS;
    let mut ratios = Vec::new();
    for p in PRESETS.iter() {
        let g = p.bipartite(0.02, 5);
        let cap = color_cap(&g) as i32;
        let base = color(&g, &Config::sim(schedule::V_N2, 16));
        assert!(bgpc_valid(&g, &base.colors).is_ok(), "{} baseline invalid", p.name);
        let u_std = base.stats().stddev_cardinality;
        let mut best = f64::INFINITY;
        for bal in [Balance::B1, Balance::B2] {
            let r = color(&g, &Config::sim(schedule::V_N2, 16).with_balance(bal));
            assert!(bgpc_valid(&g, &r.colors).is_ok(), "{} {bal:?} invalid", p.name);
            let max_c = r.colors.iter().copied().max().unwrap_or(-1);
            assert!(max_c < cap, "{} {bal:?}: color {max_c} >= cap {cap}", p.name);
            best = best.min(r.stats().stddev_cardinality);
        }
        assert!(
            best <= u_std * 1.05 + 1.0,
            "{}: balanced skew {best:.2} vs unbalanced {u_std:.2}",
            p.name
        );
        ratios.push(best.max(1e-9) / u_std.max(1e-9));
    }
    let geo = bgpc::util::geomean(&ratios);
    assert!(
        geo < 0.95,
        "balancing should lower cardinality skew in aggregate, got ratio {geo:.3}"
    );
}

#[test]
fn prop_d2gc_repair_with_balancing_keeps_skew_no_worse() {
    // The Table VI claim carried into the streaming path by the
    // problem-generic engine (DESIGN.md §9): after a D2GC session
    // absorbs an update batch, the B1/B2-balanced coloring's
    // cardinality skew is no worse than the unbalanced baseline's
    // (per symmetric preset, with slack for the tiny scale), and every
    // balanced repair still verifies.
    use bgpc::coloring::stats::ColorStats;
    use bgpc::dynamic::{DynamicSession, UpdateBatch};
    use bgpc::graph::PRESETS;
    for p in PRESETS.iter().filter(|p| p.symmetric) {
        let m = p.net_incidence(0.02, 5);
        let n = m.n_rows;
        let mk_batch = |seed: u64| {
            let mut rng = Rng::new(seed);
            let mut b = UpdateBatch::default();
            for _ in 0..(m.nnz() / 500).max(16) {
                let a = rng.range(0, n) as u32;
                let c = rng.range(0, n) as u32;
                if a != c {
                    b.add_edges.push((a, c));
                }
            }
            b
        };
        let run_with = |bal: Balance| {
            let cfg = Config::sim(schedule::V_N2, 16).with_balance(bal);
            let (mut s, _init) = DynamicSession::start(m.clone(), cfg);
            s.apply(&mk_batch(0xBA1A ^ n as u64));
            assert!(s.verify().is_ok(), "{} {bal:?}: invalid after repair", p.name);
            ColorStats::from_colors(s.colors()).stddev_cardinality
        };
        let unbalanced = run_with(Balance::None);
        let best = run_with(Balance::B1).min(run_with(Balance::B2));
        assert!(
            best <= unbalanced * 1.05 + 1.0,
            "{}: balanced repair skew {best:.2} vs unbalanced {unbalanced:.2}",
            p.name
        );
    }
}

#[test]
fn prop_balanced_runs_always_valid() {
    forall_bipartite(20, 0xBA1, |g, case| {
        for bal in [Balance::B1, Balance::B2] {
            for spec in [schedule::V_N2, schedule::N1_N2] {
                let r = color(g, &Config::sim(spec, 8).with_balance(bal));
                assert!(
                    bgpc_valid(g, &r.colors).is_ok(),
                    "{bal:?} {} invalid on {case:?}",
                    spec.name
                );
            }
        }
    });
}

#[test]
fn prop_relabeled_graph_same_color_count_seq() {
    // sequential greedy is order-dependent but relabeling + identical
    // visit order must give the same number of colors.
    forall_bipartite(15, 0x9999, |g, case| {
        let n = g.n_vertices();
        let order: Vec<u32> = (0..n as u32).collect();
        let (c, _) = bgpc::coloring::bgpc::seq::greedy(g, &order);
        // reverse relabel
        let perm: Vec<u32> = (0..n as u32).rev().collect();
        let rg: Bipartite = g.relabel_vertices(&perm);
        // visit in the order that matches the original natural order
        let rorder: Vec<u32> = (0..n as u32).rev().collect();
        let (rc, _) = bgpc::coloring::bgpc::seq::greedy(&rg, &rorder);
        let n1 = bgpc::coloring::stats::distinct_colors(&c);
        let n2 = bgpc::coloring::stats::distinct_colors(&rc);
        assert_eq!(n1, n2, "relabel changed color count on {case:?}");
    });
}
