//! Colored execution end-to-end: does B1/B2 balancing pay off in the
//! *execution* step, not just as a cardinality statistic?
//!
//! For every preset × {None, B1, B2}: color under the deterministic
//! 16-thread simulator, bucket the coloring into per-color frontiers
//! (`exec::ColorSchedule`) and drive a Jacobian-style column-compression
//! kernel — each column scatters into its incident rows, race-free
//! within a color by the BGPC guarantee — through `exec::Executor` on a
//! real `par::WorkerPool`, threads ∈ {1, 2, 4}. The per-color busy-unit
//! profile is deterministic (kernel work is data-dependent only), so the
//! skew numbers are thread-count independent; wall seconds are reported
//! per thread count.
//!
//! Gates:
//! * **validity** — the colored execution's accumulator equals the
//!   sequential sweep bit-for-bit (integer arithmetic), at every
//!   (balance, threads) point;
//! * **payoff** — on the skewed presets (unbalanced max-color-set busy
//!   ≥ 2× the uniform per-color share), best(B1, B2) reduces the
//!   max-color-set busy units vs `Balance::None`: ≤ 1.10× per preset
//!   (small-scale slack) and geomean < 0.95 across them — the same
//!   shape as the Table VI skew gate in tests/paper_properties.rs,
//!   measured in execution work units instead of cardinalities.
//!
//!   cargo bench --bench execute               # BGPC_SCALE=0.5 default
//!   BENCH_SMOKE=1 cargo bench --bench execute # CI smoke: scale 0.1,
//!                                             # threads {1,2}, 1 round
//!
//! CSV artifact: `execute.csv`. A closing segment runs a Gauss–Seidel
//! style relaxation on a D2GC-colored symmetric preset and checks the
//! executor is thread-count invariant for order-dependent kernels too
//! (within a color no neighbor is written, so any thread count matches
//! the color-order sequential reference exactly).

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use bgpc::coloring::{color, schedule, Balance, Config, ExecMode};
use bgpc::exec::{ColorSchedule, Executor, SharedBuf};
use bgpc::graph::{Bipartite, PRESETS};
use bgpc::par::{Cost, WorkerPool};
use bgpc::util::geomean;

/// The Jacobian column-compression kernel: column `u` scatters an
/// integer contribution into every incident row. Returns the work done.
fn scatter(g: &Bipartite, acc: &SharedBuf<u64>, u: usize) -> Cost {
    let mut units = 0u64;
    for &v in g.nets(u) {
        // SAFETY: no two columns in one color share a net, and colors
        // are separated by the executor's barrier.
        unsafe {
            *acc.slot(v as usize) =
                (*acc.slot(v as usize)).wrapping_add((u as u64 + 1) * (v as u64 + 1));
        }
        units += 1;
    }
    Cost::new(units)
}

fn main() {
    let smoke = common::smoke();
    let threads: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    let rounds = if smoke { 1usize } else { 2 };
    let balances = [("None", Balance::None), ("B1", Balance::B1), ("B2", Balance::B2)];

    println!(
        "=== execute: colored kernel over preset frontiers (rounds={rounds}, sim-colored t=16, N1-N2) ==="
    );
    println!(
        "{:<16} {:<5} {:>3} | {:>7} {:>8} | {:>12} {:>12} {:>7} | {:>10}",
        "graph", "bal", "t", "colors", "max_set", "busy_total", "max_col_busy", "crit%", "wall_s"
    );
    let mut csv = Vec::new();
    // (row index, unbalanced_max / best_balanced_max) for the skewed
    // presets — patched into the `flatten` CSV column before writing
    let mut flatten_at: Vec<(usize, f64)> = Vec::new();
    let mut skewed_ratios = Vec::new();
    for p in PRESETS.iter() {
        let g = p.bipartite(common::scale(), common::seed());
        common::trace_begin(); // BENCH_TRACE=1: one trace per preset
        // sequential reference for one sweep (integer, order-free)
        let mut seq = vec![0u64; g.n_nets()];
        for u in 0..g.n_vertices() {
            for &v in g.nets(u) {
                seq[v as usize] = seq[v as usize].wrapping_add((u as u64 + 1) * (v as u64 + 1));
            }
        }
        let want: Vec<u64> = seq.iter().map(|&x| x.wrapping_mul(rounds as u64)).collect();

        // busy profile per balance (deterministic, thread-independent)
        let mut max_busy = [0u64; 3];
        let mut t0_rows = [0usize; 3];
        let mut uniform_share = 0.0f64;
        for (bi, &(tag, bal)) in balances.iter().enumerate() {
            let r = common::run(&g, schedule::N1_N2, 16, bgpc::graph::Ordering::Natural, bal);
            let sched = ColorSchedule::from_colors(&r.colors);
            for &t in threads {
                let pool = Arc::new(WorkerPool::new(t));
                let acc = SharedBuf::new(vec![0u64; g.n_nets()]);
                let mut ex = Executor::new(&pool);
                let rep = ex.run(&sched, rounds, |item, _color| scatter(&g, &acc, item));
                // validity gate: colored execution ≡ sequential sweep
                let got = acc.into_vec();
                assert_eq!(
                    got, want,
                    "{} {tag} t={t}: colored execution diverged from the sequential sweep",
                    p.name
                );
                if t == threads[0] {
                    max_busy[bi] = rep.max_color_busy();
                    t0_rows[bi] = csv.len(); // the row pushed just below
                    if bal == Balance::None {
                        let nc = rep.per_color_busy.iter().filter(|&&b| b > 0).count().max(1);
                        uniform_share = rep.busy_total() as f64 / nc as f64;
                    }
                }
                println!(
                    "{:<16} {:<5} {:>3} | {:>7} {:>8} | {:>12} {:>12} {:>6.1}% | {:>10.4}",
                    p.name,
                    tag,
                    t,
                    r.n_colors,
                    sched.max_set_len(),
                    rep.busy_total(),
                    rep.max_color_busy(),
                    rep.critical_share() * 100.0,
                    rep.seconds
                );
                csv.push(format!(
                    "{},{},{},{},{},{},{},{:.4},{:.6e}",
                    p.name,
                    tag,
                    t,
                    r.n_colors,
                    sched.max_set_len(),
                    rep.busy_total(),
                    rep.max_color_busy(),
                    rep.critical_share(),
                    rep.seconds
                ));
            }
        }

        // payoff gate on the skewed presets: balancing must flatten the
        // costliest color set (the color-parallel critical-path term)
        let skewed = max_busy[0] as f64 >= 2.0 * uniform_share;
        let best = max_busy[1].min(max_busy[2]);
        if skewed {
            assert!(
                best as f64 <= max_busy[0] as f64 * 1.10 + 64.0,
                "{}: balanced max-color-set busy {best} vs unbalanced {} — B1/B2 must not \
                 worsen the critical path on a skewed preset",
                p.name,
                max_busy[0]
            );
            skewed_ratios.push(best.max(1) as f64 / max_busy[0].max(1) as f64);
            // flatten factor (inverse of the gated ratio) lands on the
            // best-balanced t=threads[0] row so scripts/bench_gate.sh can
            // floor exactly what the geomean gate below asserts
            let bi = if max_busy[1] <= max_busy[2] { 1 } else { 2 };
            flatten_at
                .push((t0_rows[bi], max_busy[0].max(1) as f64 / best.max(1) as f64));
        }
        println!(
            "  -> {:<14} skewed={} unbalanced_max={} best_balanced_max={}",
            p.name, skewed, max_busy[0], best
        );
        common::trace_end(&format!("execute_{}", p.name));
    }
    assert!(
        !skewed_ratios.is_empty(),
        "no preset qualified as skewed — the payoff gate did not run"
    );
    let geo = geomean(&skewed_ratios);
    assert!(
        geo < 0.95,
        "B1/B2 should reduce max-color-set busy on the skewed presets in aggregate, got {geo:.3}"
    );
    println!(
        "payoff gate: {} skewed presets, best-balanced/unbalanced geomean {:.3}",
        skewed_ratios.len(),
        geo
    );
    let csv: Vec<String> = csv
        .into_iter()
        .enumerate()
        .map(|(i, line)| match flatten_at.iter().find(|&&(ix, _)| ix == i) {
            Some(&(_, f)) => format!("{line},{f:.3}"),
            None => format!("{line},"),
        })
        .collect();
    common::write_csv(
        "execute.csv",
        "graph,balance,threads,n_colors,max_set,busy_total,max_color_busy,critical_share,wall_secs,flatten",
        &csv,
    );

    // === D2GC Gauss–Seidel segment: order-dependent kernel, thread-count
    // invariant under a distance-2 schedule (neighbors are never written
    // in the running color, so reads are stable) ===
    println!("\n--- D2GC Gauss–Seidel relaxation (thread-count invariance) ---");
    let p = PRESETS.iter().find(|p| p.symmetric).unwrap();
    let m = p.net_incidence((common::scale() * 0.5).max(0.01), common::seed());
    let cfg = Config {
        spec: schedule::N1_N2,
        balance: Balance::None,
        threads: 16,
        mode: ExecMode::Sim(common::model()),
        ordering: bgpc::graph::Ordering::Natural,
        post_pass: bgpc::coloring::PostPass::None,
    };
    let r = color(&m, &cfg);
    assert!(bgpc::coloring::verify::d2gc_valid(&m, &r.colors).is_ok());
    let sched = ColorSchedule::from_colors(&r.colors);
    // color-order sequential reference
    let mut reference: Vec<u64> = (0..m.n_rows as u64).collect();
    for _ in 0..rounds {
        for (_c, set) in sched.frontiers() {
            for &u in set {
                let u = u as usize;
                let mut acc = reference[u];
                for &w in m.row(u) {
                    if w as usize != u {
                        acc = acc.wrapping_add(reference[w as usize]);
                    }
                }
                reference[u] = acc / (m.deg(u) as u64 + 1);
            }
        }
    }
    for &t in threads {
        let pool = Arc::new(WorkerPool::new(t));
        let x = SharedBuf::new((0..m.n_rows as u64).collect());
        let rep = Executor::new(&pool).run(&sched, rounds, |u, _color| {
            // SAFETY: distance-2 schedule — `u` owns its own slot and no
            // neighbor of `u` is written during this color (peek-only).
            unsafe {
                let mut acc = *x.peek(u);
                let mut units = 1u64;
                for &w in m.row(u) {
                    if w as usize != u {
                        acc = acc.wrapping_add(*x.peek(w as usize));
                        units += 1;
                    }
                }
                *x.slot(u) = acc / (m.deg(u) as u64 + 1);
                Cost::new(units)
            }
        });
        let got = x.into_vec();
        assert_eq!(
            got, reference,
            "{} Gauss–Seidel t={t}: colored relaxation diverged from the color-order reference",
            p.name
        );
        println!(
            "  {:<16} t={} colors={} wall={:.3}ms utilization={:.2}",
            p.name,
            t,
            r.n_colors,
            rep.seconds * 1e3,
            rep.utilization()
        );
    }
    println!("ok");
}
