//! Table II — test-bed properties plus the sequential V-V execution time
//! and color count under the natural and smallest-last orderings.
//!
//! Shape to reproduce: smallest-last lowers #colors on most matrices and
//! raises the sequential coloring time.

#[path = "common/mod.rs"]
mod common;

use bgpc::graph::{InstanceStats, Ordering};

fn main() {
    println!("=== Table II: matrices, sequential V-V (natural & smallest-last) ===");
    println!(
        "{:<16} {:>8} {:>9} {:>9} {:>7} {:>9} | {:>9} {:>8} | {:>9} {:>8} | {}",
        "matrix", "nets", "vertices", "nnz", "maxvdeg", "vdeg-std", "nat-secs", "nat-col", "sl-secs", "sl-col", "d2gc"
    );
    let mut csv = Vec::new();
    for (p, g) in common::all_instances() {
        let s = InstanceStats::compute(&g);
        let nat_order = Ordering::Natural.compute(&g);
        let (_, nat_colors, nat_secs) = common::seq_baseline(&g, &nat_order);
        // smallest-last: ordering time reported separately (the paper's
        // Table II excludes it)
        let t0 = std::time::Instant::now();
        let sl_order = Ordering::SmallestLast.compute(&g);
        let sl_build = t0.elapsed().as_secs_f64();
        let (_, sl_colors, sl_secs) = common::seq_baseline(&g, &sl_order);
        println!(
            "{:<16} {:>8} {:>9} {:>9} {:>7} {:>9.2} | {:>9.4} {:>8} | {:>9.4} {:>8} | {}",
            p.name,
            s.n_nets,
            s.n_vertices,
            s.nnz,
            s.max_vertex_deg,
            s.vertex_deg_stddev,
            nat_secs,
            nat_colors,
            sl_secs,
            sl_colors,
            if p.symmetric { "yes" } else { "no" },
        );
        let _ = sl_build;
        csv.push(format!(
            "{},{},{},{},{},{:.3},{:.6},{},{:.6},{},{}",
            p.name, s.n_nets, s.n_vertices, s.nnz, s.max_vertex_deg, s.vertex_deg_stddev,
            nat_secs, nat_colors, sl_secs, sl_colors, p.symmetric
        ));
    }
    common::write_csv(
        "table2.csv",
        "matrix,nets,vertices,nnz,max_vdeg,vdeg_std,nat_secs,nat_colors,sl_secs,sl_colors,symmetric",
        &csv,
    );
}
