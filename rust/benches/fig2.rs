//! Figure 2 — per-matrix execution times on 2/4/8/16 threads (left axis)
//! and the number of colors (right axis) for all matrices and all eight
//! algorithms, natural order.

#[path = "common/mod.rs"]
mod common;

use bgpc::coloring::{schedule, Balance};
use bgpc::graph::Ordering;

fn main() {
    println!("=== Figure 2: per-matrix times (ms) and #colors, all algorithms ===");
    let mut csv = Vec::new();
    for (p, g) in common::all_instances() {
        let order = Ordering::Natural.compute(&g);
        let (_, seq_colors, seq_secs) = common::seq_baseline(&g, &order);
        println!(
            "\n-- {} (|V_A|={}, nnz={}; seq V-V {:.1} ms, {} colors)",
            p.name,
            g.n_vertices(),
            g.nnz(),
            seq_secs * 1e3,
            seq_colors
        );
        println!(
            "{:<10} {:>9} {:>9} {:>9} {:>9} {:>8}",
            "alg", "t=2(ms)", "t=4(ms)", "t=8(ms)", "t=16(ms)", "#colors"
        );
        for spec in schedule::ALL {
            let mut times = Vec::new();
            let mut colors = 0usize;
            for &t in &common::THREADS {
                let r = common::run(&g, spec, t, Ordering::Natural, Balance::None);
                times.push(r.seconds * 1e3);
                if t == 16 {
                    colors = r.n_colors;
                }
            }
            println!(
                "{:<10} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>8}",
                spec.name, times[0], times[1], times[2], times[3], colors
            );
            csv.push(format!(
                "{},{},{:.4},{:.4},{:.4},{:.4},{}",
                p.name, spec.name, times[0], times[1], times[2], times[3], colors
            ));
        }
    }
    common::write_csv("fig2.csv", "matrix,alg,t2_ms,t4_ms,t8_ms,t16_ms,colors16", &csv);
}
