//! Figure 3 — distribution of color-set cardinalities for V-N2 and
//! N1-N2, unbalanced vs B1 vs B2, on coPapersDBLP at 16 threads.
//! Printed as a log2-bucketed histogram (the paper plots per-set
//! cardinality curves); B2 must visibly compress the tail.

#[path = "common/mod.rs"]
mod common;

use bgpc::coloring::{schedule, Balance};
use bgpc::graph::{generators::Preset, Ordering};
use bgpc::util::stats::log2_histogram;

fn main() {
    let g = Preset::by_name("coPapersDBLP").unwrap().bipartite(common::scale(), common::seed());
    println!("=== Figure 3: color-set cardinality distributions, coPapersDBLP, t=16 ===");
    let mut csv = Vec::new();
    for spec in [schedule::V_N2, schedule::N1_N2] {
        for (tag, bal) in [("U", Balance::None), ("B1", Balance::B1), ("B2", Balance::B2)] {
            let r = common::run(&g, spec, 16, Ordering::Natural, bal);
            let st = r.stats();
            let hist = log2_histogram(&st.cards);
            print!(
                "{:<9} sets={:>6} avg={:>7.2} std={:>8.2} max={:>6} tiny={:>5} | hist:",
                format!("{}-{}", spec.name, tag),
                st.n_colors,
                st.avg_cardinality,
                st.stddev_cardinality,
                st.max_cardinality,
                st.tiny_sets
            );
            for (ub, count) in &hist {
                print!(" ≤{ub}:{count}");
            }
            println!();
            for (ub, count) in &hist {
                csv.push(format!("{},{},{},{}", spec.name, tag, ub, count));
            }
        }
    }
    common::write_csv("fig3.csv", "alg,balance,card_bucket_ub,n_sets", &csv);
}
