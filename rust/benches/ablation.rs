//! Ablations over the design knobs DESIGN.md calls out: dynamic chunk
//! size (the `-64` choice), the lazy-queue (`D`) option, the simulator's
//! fork-skew and atomic-contention constants, and how many net
//! iterations to run (`N1` vs `N2` vs `N3`). One graph (coPapersDBLP),
//! t = 16, everything else fixed — each row isolates one knob.

#[path = "common/mod.rs"]
mod common;

use bgpc::coloring::schedule::{AlgSpec, N1_N2, V_V_64D};
use bgpc::coloring::{color, Balance, Config, ExecMode};
use bgpc::graph::{generators::Preset, Ordering};
use bgpc::sim::CostModel;

fn run_with(g: &bgpc::graph::Bipartite, spec: AlgSpec, model: CostModel) -> (f64, usize, usize) {
    let cfg = Config {
        spec,
        balance: Balance::None,
        threads: 16,
        mode: ExecMode::Sim(model),
        ordering: Ordering::Natural,
        post_pass: bgpc::coloring::PostPass::None,
    };
    let r = color(g, &cfg);
    (r.seconds * 1e3, r.n_colors, r.iterations)
}

fn main() {
    let g = Preset::by_name("coPapersDBLP").unwrap().bipartite(common::scale(), common::seed());
    let base = CostModel::default();
    println!("=== Ablations (coPapersDBLP, t=16) ===");

    println!("\n-- dynamic chunk size (V-*-64D family; 0 = static) --");
    for chunk in [0usize, 1, 16, 64, 256, 2048] {
        let spec = AlgSpec { chunk, ..V_V_64D };
        let (ms, colors, iters) = run_with(&g, spec, base);
        println!("  chunk {:>5}: {:>8.2} ms  colors {}  iters {}", chunk, ms, colors, iters);
    }

    println!("\n-- lazy next-queues (the D option) --");
    for lazy in [false, true] {
        let spec = AlgSpec { lazy_queues: lazy, ..V_V_64D };
        let (ms, colors, _) = run_with(&g, spec, base);
        println!("  lazy {:>5}: {:>8.2} ms  colors {}", lazy, ms, colors);
    }

    println!("\n-- net-coloring iterations (Nk-N2-style schedules) --");
    for k in 0..=3usize {
        let spec = AlgSpec {
            name: "Nk-N2",
            net_color_iters: k,
            net_conflict_iters: k.max(2),
            ..N1_N2
        };
        let (ms, colors, iters) = run_with(&g, spec, base);
        println!("  k = {k}: {:>8.2} ms  colors {}  iters {}", ms, colors, iters);
    }

    println!("\n-- simulator fork-skew (race-window sensitivity, N1-N2) --");
    for skew in [0u64, 16, 64, 256, 1024] {
        let model = CostModel { fork_skew: skew, ..base };
        let (ms, colors, iters) = run_with(&g, N1_N2, model);
        println!("  skew {:>5}: {:>8.2} ms  colors {}  iters {}", skew, ms, colors, iters);
    }

    println!("\n-- atomic contention scale (chunk-1 V-V-64D sensitivity) --");
    for scale_x10 in [0u32, 30, 90, 270] {
        let model = CostModel { atomic_scale: scale_x10 as f64 / 10.0, ..base };
        let spec = AlgSpec { chunk: 1, ..V_V_64D };
        let (ms, _, _) = run_with(&g, spec, model);
        println!("  a1 {:>4.1}: {:>8.2} ms", scale_x10 as f64 / 10.0, ms);
    }
}
