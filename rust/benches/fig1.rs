//! Figure 1 — per-iteration execution times (msec) on coPapersDBLP with
//! 16 threads, for V-V-64D, V-N∞, V-N1, V-N2, N1-N2 and N2-N2, split
//! into coloring and conflict-removal phases.
//!
//! Shape to reproduce (paper §III): (1) most time in coloring, (2) most
//! time in the first iterations (78% iter-1, 89% iters-1..2 on average),
//! (3) V-N∞ pays for net-based removal in late iterations, (4) net-based
//! coloring wins iteration 1 (N1-N2), (5) a second net iteration does
//! not help (N2-N2).

#[path = "common/mod.rs"]
mod common;

use bgpc::coloring::{schedule, Balance};
use bgpc::graph::{generators::Preset, Ordering};

fn main() {
    let g = Preset::by_name("coPapersDBLP").unwrap().bipartite(common::scale(), common::seed());
    let specs = [
        schedule::V_V_64D,
        schedule::V_NINF,
        schedule::V_N1,
        schedule::V_N2,
        schedule::N1_N2,
        schedule::N2_N2,
    ];
    println!("=== Figure 1: per-iteration times (ms), coPapersDBLP, t=16 ===");
    let mut csv = Vec::new();
    for spec in specs {
        let r = common::run(&g, spec, 16, Ordering::Natural, Balance::None);
        print!("{:<8} total={:>8.2}ms |", spec.name, r.seconds * 1e3);
        for (i, it) in r.trace.iters.iter().enumerate().take(8) {
            print!(
                " it{}[{}{}] {:.2}+{:.2}",
                i + 1,
                it.color_kind,
                it.conflict_kind,
                it.color_secs * 1e3,
                it.conflict_secs * 1e3
            );
            csv.push(format!(
                "{},{},{}{},{:.4},{:.4},{}",
                spec.name,
                i + 1,
                it.color_kind,
                it.conflict_kind,
                it.color_secs * 1e3,
                it.conflict_secs * 1e3,
                it.queue_len
            ));
        }
        println!();
    }
    common::write_csv("fig1.csv", "alg,iter,kinds,color_ms,conflict_ms,queue", &csv);

    // the §III statistic: average first-iteration share across the bed
    let mut f1 = Vec::new();
    let mut f2 = Vec::new();
    for (_p, g) in common::all_instances() {
        let r = common::run(&g, schedule::V_N2, 16, Ordering::Natural, Balance::None);
        f1.push(r.trace.first_k_fraction(1));
        f2.push(r.trace.first_k_fraction(2));
    }
    let m1 = f1.iter().sum::<f64>() / f1.len() as f64;
    let m2 = f2.iter().sum::<f64>() / f2.len() as f64;
    println!(
        "\n§III check — avg share of runtime: first iter {:.0}% (paper 78%), first two {:.0}% (paper 89%)",
        m1 * 100.0,
        m2 * 100.0
    );
}
