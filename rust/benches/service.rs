//! Service throughput bench: the sharded async coordinator vs the seed
//! single-mutex design (DESIGN.md §12).
//!
//! The baseline below reproduces the retired coordinator's shape
//! faithfully: one global mpsc job queue behind `Arc<Mutex<Receiver>>`
//! (a worker holds the lock *while it waits* for work) and one big
//! per-session mutex that updates, executes, and colors reads all
//! serialize on, with every update paying its own compact + repair +
//! verify. The sharded service replaces that with lock-free-admission
//! `submit_async`, per-session pending queues that fuse tiny batches
//! into one repair, and epoch snapshots that keep reads/executes off
//! the repair lock.
//!
//! Workload: a mixed firehose over S dynamic sessions at 16 simulated
//! threads — per round and session, 12 tiny (2-edit) update batches,
//! one colored execute, one colors read. Acceptance: the sharded
//! service sustains ≥ 4× the single-mutex jobs/sec with p99 latency
//! bounded by 1.5× the baseline's.
//!
//!   cargo bench --bench service
//!
//! CSV artifact: `service.csv`.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;
use std::time::Instant;

use bgpc::coloring::{schedule, Config};
use bgpc::coordinator::{EngineSel, ExecKernel, Job, JobHandle, JobInput, Service, ServiceOpts};
use bgpc::dynamic::UpdateBatch;
use bgpc::graph::generators::random_bipartite;
use bgpc::graph::Bipartite;
use bgpc::par::Cost;
use bgpc::util::prng::Rng;

/// The seed coordinator's concurrency shape, kept as the measured
/// baseline (see the module doc — this is deliberately the *old*
/// design, including the lock-around-channel pickup idiom).
mod baseline {
    use std::sync::mpsc::{channel, Receiver, Sender};
    use std::sync::{Arc, Mutex};
    use std::thread::JoinHandle;

    use bgpc::coloring::Config;
    use bgpc::dynamic::{BgpcSession, DynamicSession, UpdateBatch};
    use bgpc::exec::{ColorSchedule, Executor};
    use bgpc::graph::Bipartite;
    use bgpc::par::{Cost, WorkerPool};

    pub struct Sess {
        session: BgpcSession,
        sched: Option<ColorSchedule>,
    }

    pub enum Req {
        Update { sid: usize, batch: UpdateBatch, done: Sender<bool> },
        Execute { sid: usize, rounds: usize, done: Sender<bool> },
        Stop,
    }

    pub struct MutexCoordinator {
        tx: Sender<Req>,
        workers: Vec<JoinHandle<()>>,
        sessions: Arc<Vec<Mutex<Sess>>>,
    }

    impl MutexCoordinator {
        pub fn start(graphs: &[Bipartite], cfg: &Config, n_workers: usize) -> MutexCoordinator {
            let pool = Arc::new(WorkerPool::new(1));
            let sessions: Arc<Vec<Mutex<Sess>>> = Arc::new(
                graphs
                    .iter()
                    .map(|g| {
                        let (session, _init) =
                            DynamicSession::start_on(g.clone(), cfg.clone(), &pool);
                        Mutex::new(Sess { session, sched: None })
                    })
                    .collect(),
            );
            let (tx, rx) = channel::<Req>();
            // the measured idiom: a mutex wrapped around the receiver,
            // held while a worker waits for the next job
            let rx = Arc::new(Mutex::new(rx));
            let mut workers = Vec::new();
            for _ in 0..n_workers {
                let rx: Arc<Mutex<Receiver<Req>>> = Arc::clone(&rx);
                let sessions = Arc::clone(&sessions);
                let pool = Arc::clone(&pool);
                workers.push(std::thread::spawn(move || loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Req::Update { sid, batch, done }) => {
                            let mut s = sessions[sid].lock().unwrap();
                            s.session.apply(&batch);
                            let ok = s.session.verify().is_ok();
                            let _ = done.send(ok);
                        }
                        Ok(Req::Execute { sid, rounds, done }) => {
                            let mut s = sessions[sid].lock().unwrap();
                            let colors = s.session.colors().to_vec();
                            match s.sched.as_mut() {
                                Some(sc) => {
                                    sc.refresh(&colors);
                                }
                                None => s.sched = Some(ColorSchedule::from_colors(&colors)),
                            }
                            let sched = s.sched.as_ref().unwrap();
                            let rep = Executor::new(&pool).run(sched, rounds, |_, _| Cost::new(1));
                            let _ = done.send(rep.items > 0);
                        }
                        Ok(Req::Stop) | Err(_) => break,
                    }
                }));
            }
            MutexCoordinator { tx, workers, sessions }
        }

        pub fn submit(&self, req: Req) {
            let _ = self.tx.send(req);
        }

        /// A colors read — serializes on the session mutex, exactly as
        /// the seed service did.
        pub fn colors(&self, sid: usize) -> Vec<i32> {
            self.sessions[sid].lock().unwrap().session.colors().to_vec()
        }

        pub fn shutdown(self) {
            for _ in 0..self.workers.len() {
                let _ = self.tx.send(Req::Stop);
            }
            drop(self.tx);
            for w in self.workers {
                let _ = w.join();
            }
        }
    }
}

fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let ix = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[ix]
}

struct RunStats {
    jobs: u64,
    secs: f64,
    p50: f64,
    p99: f64,
}

impl RunStats {
    fn jps(&self) -> f64 {
        self.jobs as f64 / self.secs.max(1e-12)
    }
}

fn finish(mut lat: Vec<f64>, jobs: u64, secs: f64) -> RunStats {
    lat.sort_by(f64::total_cmp);
    RunStats { jobs, secs, p50: quantile(&lat, 0.50), p99: quantile(&lat, 0.99) }
}

fn main() {
    let smoke = common::smoke();
    let n_sessions = if smoke { 3 } else { 6 };
    let rounds = if smoke { 4 } else { 8 };
    let upd_per_round = 12usize;
    let cfg = Config::sim(schedule::N1_N2, 16);

    let graphs: Vec<Bipartite> = (0..n_sessions)
        .map(|i| random_bipartite(300 + 40 * i, 450 + 60 * i, 5000 + 400 * i, 90 + i as u64))
        .collect();
    // one pre-generated batch stream, replayed identically on both sides
    let mut rng = Rng::new(0x5EC7);
    let stream: Vec<Vec<Vec<UpdateBatch>>> = graphs
        .iter()
        .map(|g| {
            (0..rounds)
                .map(|_| {
                    (0..upd_per_round)
                        .map(|_| {
                            let mut b = UpdateBatch::default();
                            for _ in 0..2 {
                                b.add_edges.push((
                                    rng.range(0, g.n_nets()) as u32,
                                    rng.range(0, g.n_vertices()) as u32,
                                ));
                            }
                            b
                        })
                        .collect()
                })
                .collect()
        })
        .collect();

    println!("=== service: sharded submit_async vs single-mutex baseline ===");
    println!(
        "sessions={n_sessions} rounds={rounds} updates/round={upd_per_round} (sim t=16, 1-thread pools)"
    );

    // ---- baseline: global mutex-guarded queue, per-session big lock ----
    let base = baseline::MutexCoordinator::start(&graphs, &cfg, 2);
    let t0 = Instant::now();
    let mut lat = Vec::new();
    let mut jobs = 0u64;
    for r in 0..rounds {
        let mut pending: Vec<(Instant, std::sync::mpsc::Receiver<bool>)> = Vec::new();
        for sid in 0..n_sessions {
            for batch in &stream[sid][r] {
                let (dtx, drx) = std::sync::mpsc::channel();
                pending.push((Instant::now(), drx));
                base.submit(baseline::Req::Update { sid, batch: batch.clone(), done: dtx });
            }
        }
        for sid in 0..n_sessions {
            let (dtx, drx) = std::sync::mpsc::channel();
            pending.push((Instant::now(), drx));
            base.submit(baseline::Req::Execute { sid, rounds: 1, done: dtx });
        }
        for (at, drx) in pending {
            assert!(drx.recv().unwrap(), "baseline job failed");
            lat.push(at.elapsed().as_secs_f64());
            jobs += 1;
        }
        for sid in 0..n_sessions {
            assert!(!base.colors(sid).is_empty());
        }
    }
    let base_stats = finish(lat, jobs, t0.elapsed().as_secs_f64());
    base.shutdown();

    // ---- sharded: lock-free admission, fused drains, epoch snapshots ----
    // BENCH_TRACE=1: trace the sharded half end-to-end — session bring-up
    // (coloring phases), the firehose (dynamic repair + coordinator
    // dispatch), and executes (pool regions + per-color frontiers)
    common::trace_begin();
    let svc = Service::start_sharded(ServiceOpts {
        shards: 2,
        dispatchers: 2,
        pool_threads: 1,
        fuse_updates: 64,
        artifacts: None,
    });
    let sids: Vec<_> = graphs
        .iter()
        .enumerate()
        .map(|(i, g)| {
            let (sid, init) = svc.open_session(&format!("fire{i}"), g, cfg.clone());
            assert!(init.valid, "session {i} bring-up failed");
            sid
        })
        .collect();
    let t0 = Instant::now();
    let mut lat = Vec::new();
    let mut jobs = 0u64;
    let mut fused_updates = 0u64;
    for r in 0..rounds {
        let mut pending: Vec<(Instant, JobHandle)> = Vec::new();
        for (i, &sid) in sids.iter().enumerate() {
            for batch in &stream[i][r] {
                let at = Instant::now();
                pending.push((
                    at,
                    svc.submit_async(Job {
                        name: String::new(),
                        input: JobInput::Update { session: sid, batch: Arc::new(batch.clone()) },
                        cfg: cfg.clone(),
                        engine: EngineSel::Native,
                    }),
                ));
            }
        }
        for &sid in &sids {
            let at = Instant::now();
            let h = svc.execute("", sid, 1, ExecKernel::new(|_, _| Cost::new(1)));
            pending.push((at, h));
        }
        for (at, h) in pending {
            let o = h.wait();
            assert!(o.valid, "{}: {:?}", o.name, o.error);
            if o.fused > 1 {
                fused_updates += 1;
            }
            lat.push(at.elapsed().as_secs_f64());
            jobs += 1;
        }
        for &sid in &sids {
            assert!(!svc.session_colors(sid).expect("session open").is_empty());
        }
    }
    let sh_stats = finish(lat, jobs, t0.elapsed().as_secs_f64());
    let qs = svc.queue_stats();
    let m = svc.metrics();
    println!(
        "sharded internals: fused_members={fused_updates} queue(pushed={} popped={} stolen={}) wait_p99={:.3}ms",
        qs.pushed,
        qs.popped,
        qs.stolen,
        m.queue_wait_quantile(0.99) * 1e3
    );
    svc.shutdown();
    common::trace_end("service_sharded");

    let ratio = sh_stats.jps() / base_stats.jps().max(1e-12);
    println!(
        "{:>8} {:>6} | {:>9} {:>9} | {:>9} {:>9} | {:>7}",
        "mode", "jobs", "secs", "jobs/s", "p50_ms", "p99_ms", "speedup"
    );
    println!(
        "{:>8} {:>6} | {:>9.4} {:>9.1} | {:>9.3} {:>9.3} | {:>7}",
        "mutex",
        base_stats.jobs,
        base_stats.secs,
        base_stats.jps(),
        base_stats.p50 * 1e3,
        base_stats.p99 * 1e3,
        ""
    );
    println!(
        "{:>8} {:>6} | {:>9.4} {:>9.1} | {:>9.3} {:>9.3} | {:>6.1}x",
        "sharded",
        sh_stats.jobs,
        sh_stats.secs,
        sh_stats.jps(),
        sh_stats.p50 * 1e3,
        sh_stats.p99 * 1e3,
        ratio
    );

    let csv = vec![
        format!(
            "mutex,1,2,{n_sessions},{},{:.6},{:.2},{:.4},{:.4},",
            base_stats.jobs,
            base_stats.secs,
            base_stats.jps(),
            base_stats.p50 * 1e3,
            base_stats.p99 * 1e3
        ),
        format!(
            "sharded,2,2,{n_sessions},{},{:.6},{:.2},{:.4},{:.4},{ratio:.3}",
            sh_stats.jobs,
            sh_stats.secs,
            sh_stats.jps(),
            sh_stats.p50 * 1e3,
            sh_stats.p99 * 1e3
        ),
    ];
    common::write_csv(
        "service.csv",
        "mode,shards,dispatchers,sessions,jobs,secs,jobs_per_sec,p50_ms,p99_ms,speedup_vs_mutex",
        &csv,
    );

    // acceptance: fused, snapshot-backed admission must beat the
    // single-mutex design by 4x on the mixed firehose, with tail
    // latency in the same neighbourhood (floor guards sub-ms jitter)
    assert!(
        ratio >= 4.0,
        "sharded submit_async only {ratio:.2}x over the single-mutex baseline"
    );
    assert!(
        sh_stats.p99 <= (base_stats.p99 * 1.5).max(0.05),
        "sharded p99 {:.3}ms vs baseline {:.3}ms — tail latency unbounded",
        sh_stats.p99 * 1e3,
        base_stats.p99 * 1e3
    );
    println!("ok");
}
