//! Micro-benchmarks for the perf pass (EXPERIMENTS.md §Perf): real
//! wall-clock of the native hot paths on this host, plus the PJRT kernel
//! latency per bucket. These are *measured* (not simulated) numbers,
//! except the auto-chunk sweep, which runs on the deterministic
//! simulator (a 16-thread schedule cannot be timed on a one-core host).
//!
//! Gated segments (enforced inline and via `BENCH_microbench.json` /
//! `BENCH_microchunk.json` + `scripts/bench_gate.sh`):
//!
//! * packed vs scalar forbidden-set scans on skewed instances — the
//!   word-mask tier must be ≥ 2× the retained scalar reference on the
//!   long saturated scans the speculation loop produces;
//! * `Chunk::Auto` vs the best fixed chunk {1, 64, static} over the
//!   1e2..1e6 region sweep — the tuner must land within 10% of the best
//!   fixed choice (geomean ≥ 0.9) after its warm-up epochs.
//!
//!   cargo bench --bench microbench
//!
//! CSV artifacts: `microbench.csv`, `microbench_chunk.csv`.

#[path = "common/mod.rs"]
mod common;

use bgpc::coloring::forbidden::StampSet;
use bgpc::coloring::{color, schedule, Config};
use bgpc::graph::generators::Preset;
use bgpc::par::{autosite, Chunk, Cost, Driver};
use bgpc::runtime::{offload, Runtime};
use bgpc::sim::{CostModel, SimDriver};
use bgpc::testing::skewed_bipartite;
use bgpc::util::geomean;
use bgpc::util::prng::Rng;
use bgpc::util::timer::time_min;
use std::hint::black_box;

fn main() {
    let g = Preset::by_name("coPapersDBLP").unwrap().bipartite(0.25, common::seed());
    println!("=== microbench (real wall-clock, host) ===");
    println!("graph: coPapersDBLP@0.25 |V_A|={} nnz={}", g.n_vertices(), g.nnz());

    // sequential greedy throughput (the calibration anchor)
    let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
    let secs = time_min(3, || bgpc::coloring::bgpc::seq::greedy(&g, &order));
    let (_, units) = bgpc::coloring::bgpc::seq::greedy(&g, &order);
    println!(
        "seq greedy: {:.1} ms, {:.2} ns/unit ({} units)",
        secs * 1e3,
        secs * 1e9 / units as f64,
        units
    );

    // engine end-to-end (1 real thread) — native-path overhead vs seq
    let secs = time_min(3, || color(&g, &Config::threads(schedule::N1_N2, 1)));
    println!("engine N1-N2 threads=1: {:.1} ms", secs * 1e3);

    // simulator overhead factor: sim-run wall-clock vs its simulated time
    let t0 = std::time::Instant::now();
    let r = color(&g, &Config::sim(schedule::N1_N2, 16));
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "sim N1-N2 t=16: simulated {:.2} ms, driver wall {:.1} ms ({:.1}x overhead)",
        r.seconds * 1e3,
        wall * 1e3,
        wall / r.seconds.max(1e-12)
    );

    // native row-step throughput
    let mut rng = Rng::new(9);
    let (b, k) = (1024usize, 32usize);
    let mut colors: Vec<i32> = (0..b * k).map(|_| rng.range(0, k + 3) as i32 - 1).collect();
    let degs: Vec<i32> = (0..b).map(|_| rng.range(1, k + 1) as i32).collect();
    let secs = time_min(10, || {
        let mut c = colors.clone();
        offload::step_rows_native(&mut c, &degs, k);
        c
    });
    println!(
        "native net-step [{}x{}]: {:.1} µs ({:.1} ns/slot)",
        b,
        k,
        secs * 1e6,
        secs * 1e9 / (b * k) as f64
    );
    let _ = &mut colors;

    // PJRT kernel latency per bucket (needs artifacts)
    match Runtime::load(Runtime::default_dir()) {
        Ok(rt) => {
            for bucket in rt.buckets() {
                let (b, k) = (bucket.b, bucket.k);
                let colors: Vec<i32> =
                    (0..b * k).map(|i| (i % (k + 2)) as i32 - 1).collect();
                let degs: Vec<i32> = (0..b).map(|i| (i % (k + 1)) as i32).collect();
                let secs = time_min(5, || bucket.step(&colors, &degs).unwrap());
                println!(
                    "pjrt net_step b={} k={}: {:.2} ms ({:.1} ns/slot)",
                    b,
                    k,
                    secs * 1e3,
                    secs * 1e9 / (b * k) as f64
                );
            }
        }
        Err(e) => println!("pjrt: skipped ({e})"),
    }

    packed_scan_segment();
    auto_chunk_segment();
    println!("ok");
}

/// Gated segment: the word-packed `StampSet` scans vs the retained
/// scalar references, on the populations the speculation loop actually
/// builds. For a vertex `w`, the distance-2 forbidden set holds the
/// colors of every vertex sharing a net with `w`; under first-fit greedy
/// that population is saturated up to `colors[w]` (greedy chose the
/// first gap), so the highest-colored vertices of a skewed instance own
/// the longest scans — the scalar path loads ~`colors[w]` stamps where
/// the packed path touches ~`colors[w]/64` words. Acceptance: packed
/// ≥ 2× scalar per instance (the floor file then gates the geomean).
fn packed_scan_segment() {
    println!("--- packed vs scalar forbidden-set scans (gated: >= 2x) ---");
    // (n_nets, n_vtxs, nnz, seed): hub nets force dense populations — a
    // net of degree d needs d distinct colors among its vertices
    let insts: &[(usize, usize, usize, u64)] = if common::smoke() {
        &[(400, 800, 20_000, 3)]
    } else {
        &[(400, 800, 20_000, 3), (600, 1200, 40_000, 11), (300, 2000, 36_000, 29)]
    };
    let mut csv = Vec::new();
    for &(n_nets, n_vtxs, nnz, seed) in insts {
        let g = skewed_bipartite(n_nets, n_vtxs, nnz, seed);
        let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
        let (colors, _) = bgpc::coloring::bgpc::seq::greedy(&g, &order);
        // the highest-colored vertices own the longest first-fit scans
        let mut by_color: Vec<usize> = (0..g.n_vertices()).collect();
        by_color.sort_by_key(|&w| std::cmp::Reverse(colors[w]));
        by_color.truncate(64);
        let cap = bgpc::coloring::bgpc::color_cap(&g);
        let sets: Vec<(StampSet, i32)> = by_color
            .iter()
            .map(|&w| {
                let mut f = StampSet::new(cap);
                f.next_gen();
                for &v in g.nets(w) {
                    for &u in g.vtxs(v as usize) {
                        let u = u as usize;
                        if u != w && colors[u] >= 0 {
                            f.insert(colors[u]);
                        }
                    }
                }
                (f, colors[w])
            })
            .collect();
        let n_sets = sets.len().max(1) as f64;
        let mean_color = sets.iter().map(|&(_, c)| c as f64).sum::<f64>() / n_sets;

        // one sweep = the three scan shapes the engines use, per set;
        // scans only — populations are prebuilt, both tiers paid insert
        let sweep_packed = || {
            let mut acc = 0i64;
            for (f, cw) in &sets {
                let cw = *cw;
                acc += f.first_fit().0 as i64;
                acc += f.first_fit_from(cw / 2).0 as i64;
                acc += f.reverse_fit(cw - 1).0.map_or(-1, i64::from);
            }
            acc
        };
        let sweep_scalar = || {
            let mut acc = 0i64;
            for (f, cw) in &sets {
                let cw = *cw;
                acc += f.first_fit_scalar().0 as i64;
                acc += f.first_fit_from_scalar(cw / 2).0 as i64;
                acc += f.reverse_fit_scalar(cw - 1).0.map_or(-1, i64::from);
            }
            acc
        };
        // the differential contract, re-checked on the bench populations
        assert_eq!(sweep_packed(), sweep_scalar(), "packed and scalar scans disagree");

        const ROUNDS: usize = 64;
        let packed_s = time_min(9, || {
            let mut a = 0i64;
            for _ in 0..ROUNDS {
                a ^= black_box(sweep_packed());
            }
            a
        });
        let scalar_s = time_min(9, || {
            let mut a = 0i64;
            for _ in 0..ROUNDS {
                a ^= black_box(sweep_scalar());
            }
            a
        });
        let n_scans = (sets.len() * 3 * ROUNDS) as f64;
        let packed_ns = packed_s * 1e9 / n_scans;
        let scalar_ns = scalar_s * 1e9 / n_scans;
        let speedup = scalar_s / packed_s.max(1e-12);
        println!(
            "{n_nets}x{n_vtxs} nnz={nnz}: mean color {mean_color:.0}, \
             packed {packed_ns:.1} ns/scan vs scalar {scalar_ns:.1} ns/scan ({speedup:.1}x)"
        );
        csv.push(format!(
            "{n_nets}x{n_vtxs},{nnz},{mean_color:.1},{packed_ns:.2},{scalar_ns:.2},{speedup:.2}"
        ));
        assert!(
            speedup >= 2.0,
            "packed scan only {speedup:.2}x scalar on {n_nets}x{n_vtxs} (limit 2.0)"
        );
    }
    common::write_csv(
        "microbench.csv",
        "instance,nnz,mean_color,packed_ns,scalar_ns,packed_speedup",
        &csv,
    );
}

/// Gated segment: `Chunk::Auto` vs the best fixed chunk {1, 64, static}
/// over the 1e2..1e6 region sweep of `benches/scheduler.rs`, on the
/// deterministic simulator at t = 16 (this host has one core; `sim_ns`
/// is exact and bit-reproducible where a real-thread sweep would time
/// noise). Per-item costs are skewed — hash-spread light items plus an
/// 8× heavy front, the degree-sorted-frontier shape where hubs cluster
/// at low indices — so no fixed chunk is free: chunk 1 pays the
/// contended cursor, large chunks swallow the heavy front whole, static
/// hands it all to thread 0. The tuner adapts over untimed warm-up
/// epochs (its feedback is `RegionOut::busy_units` from prior
/// dispatches), then the measured epochs must land within 10% of the
/// best fixed chunk (geomean ratio ≥ 0.9).
fn auto_chunk_segment() {
    println!("--- auto vs best fixed chunk (sim t=16; gated: geomean >= 0.9) ---");
    const T: usize = 16;
    const WARMUP: usize = 12;
    const MEASURE: usize = 6;
    let sizes: &[usize] = if common::smoke() {
        &[100, 1_000, 10_000]
    } else {
        &[100, 1_000, 10_000, 100_000, 1_000_000]
    };
    let fixed: [(usize, &str); 3] = [
        (Chunk::Fixed(1).encode(), "1"),
        (Chunk::Fixed(64).encode(), "64"),
        (Chunk::Static.encode(), "static"),
    ];
    // total measured sim_ns for one (size, chunk) cell; a fresh driver
    // per cell so tuner state never leaks across the sweep
    let run = |n: usize, chunk: usize| -> f64 {
        let mut d = SimDriver::new(T, CostModel::default());
        let mut states = vec![(); T];
        let mut measured = 0.0;
        for epoch in 0..WARMUP + MEASURE {
            let out = d.region(&mut states, n, chunk, |_tid, _ts, item, _now| {
                // deterministic skew: hash-spread light items, plus an 8x
                // heavy front (items below n/16) — the hub cluster of a
                // degree-sorted frontier
                let h = (item as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 33;
                let base = 50 + h % 101;
                Cost::new(if item < n / 16 { base * 8 } else { base })
            });
            if epoch >= WARMUP {
                measured += out.sim_ns.unwrap_or(0.0);
            }
        }
        measured
    };
    let mut csv = Vec::new();
    let mut ratios = Vec::new();
    for &n in sizes {
        let auto_ns = run(n, Chunk::Auto(autosite::GENERIC).encode());
        let (mut best_ns, mut best_label) = (f64::INFINITY, "");
        for &(c, label) in &fixed {
            let ns = run(n, c);
            if ns < best_ns {
                best_ns = ns;
                best_label = label;
            }
        }
        let ratio = best_ns / auto_ns.max(1e-9);
        println!(
            "{n:>9} | auto {:>11.0} ns vs best fixed ({best_label:>6}) {:>11.0} ns | {ratio:.3}",
            auto_ns, best_ns
        );
        csv.push(format!("{n},{auto_ns:.0},{best_label},{best_ns:.0},{ratio:.4}"));
        ratios.push(ratio);
        assert!(
            ratio >= 0.7,
            "auto chunk at {ratio:.3}x of best fixed ({best_label}) at n={n} (sanity floor 0.7)"
        );
    }
    let geo = geomean(&ratios);
    println!("auto-chunk geomean ratio: {geo:.3}");
    common::write_csv(
        "microbench_chunk.csv",
        "n_items,auto_sim_ns,best_fixed,best_fixed_sim_ns,auto_ratio",
        &csv,
    );
    assert!(geo >= 0.9, "auto chunk geomean {geo:.3} < 0.9 of best fixed");
}
