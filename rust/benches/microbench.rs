//! Micro-benchmarks for the perf pass (EXPERIMENTS.md §Perf): real
//! wall-clock of the native hot paths on this host, plus the PJRT kernel
//! latency per bucket. These are *measured* (not simulated) numbers.

#[path = "common/mod.rs"]
mod common;

use bgpc::coloring::{color_bgpc, schedule, Config};
use bgpc::graph::generators::Preset;
use bgpc::runtime::{offload, Runtime};
use bgpc::util::prng::Rng;
use bgpc::util::timer::time_min;

fn main() {
    let g = Preset::by_name("coPapersDBLP").unwrap().bipartite(0.25, common::seed());
    println!("=== microbench (real wall-clock, host) ===");
    println!("graph: coPapersDBLP@0.25 |V_A|={} nnz={}", g.n_vertices(), g.nnz());

    // sequential greedy throughput (the calibration anchor)
    let order: Vec<u32> = (0..g.n_vertices() as u32).collect();
    let secs = time_min(3, || bgpc::coloring::bgpc::seq::greedy(&g, &order));
    let (_, units) = bgpc::coloring::bgpc::seq::greedy(&g, &order);
    println!(
        "seq greedy: {:.1} ms, {:.2} ns/unit ({} units)",
        secs * 1e3,
        secs * 1e9 / units as f64,
        units
    );

    // engine end-to-end (1 real thread) — native-path overhead vs seq
    let secs = time_min(3, || color_bgpc(&g, &Config::threads(schedule::N1_N2, 1)));
    println!("engine N1-N2 threads=1: {:.1} ms", secs * 1e3);

    // simulator overhead factor: sim-run wall-clock vs its simulated time
    let t0 = std::time::Instant::now();
    let r = color_bgpc(&g, &Config::sim(schedule::N1_N2, 16));
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "sim N1-N2 t=16: simulated {:.2} ms, driver wall {:.1} ms ({:.1}x overhead)",
        r.seconds * 1e3,
        wall * 1e3,
        wall / r.seconds.max(1e-12)
    );

    // native row-step throughput
    let mut rng = Rng::new(9);
    let (b, k) = (1024usize, 32usize);
    let mut colors: Vec<i32> = (0..b * k).map(|_| rng.range(0, k + 3) as i32 - 1).collect();
    let degs: Vec<i32> = (0..b).map(|_| rng.range(1, k + 1) as i32).collect();
    let secs = time_min(10, || {
        let mut c = colors.clone();
        offload::step_rows_native(&mut c, &degs, k);
        c
    });
    println!(
        "native net-step [{}x{}]: {:.1} µs ({:.1} ns/slot)",
        b,
        k,
        secs * 1e6,
        secs * 1e9 / (b * k) as f64
    );
    let _ = &mut colors;

    // PJRT kernel latency per bucket (needs artifacts)
    match Runtime::load(Runtime::default_dir()) {
        Ok(rt) => {
            for bucket in rt.buckets() {
                let (b, k) = (bucket.b, bucket.k);
                let colors: Vec<i32> =
                    (0..b * k).map(|i| (i % (k + 2)) as i32 - 1).collect();
                let degs: Vec<i32> = (0..b).map(|i| (i % (k + 1)) as i32).collect();
                let secs = time_min(5, || bucket.step(&colors, &degs).unwrap());
                println!(
                    "pjrt net_step b={} k={}: {:.2} ms ({:.1} ns/slot)",
                    b,
                    k,
                    secs * 1e3,
                    secs * 1e9 / (b * k) as f64
                );
            }
        }
        Err(e) => println!("pjrt: skipped ({e})"),
    }
}
