//! Table I — the number of uncolored (remaining) vertices after the
//! first iteration for bone010 and coPapersDBLP with 16 threads, when
//! Algorithm 6 (`Net-v1`), Algorithm 6 + reverse, and Algorithm 8 are
//! used for the net-based first coloring iteration.
//!
//! Paper values (986k / 540k vertex originals):
//!   bone010       986,703: 863,785 / 806,264 / 610,924 remaining
//!   coPapersDBLP  540,486: 409,621 / 303,152 / 133,874 remaining
//! Shape to reproduce: V1 > V1+reverse > TwoPass, with TwoPass well
//! under half of V1 on coPapersDBLP.

#[path = "common/mod.rs"]
mod common;

use bgpc::coloring::schedule::{NetColorAlg, N1_N2};
use bgpc::coloring::Balance;
use bgpc::graph::{generators::Preset, Ordering};

fn main() {
    let algs = [
        ("Alg. 6 (v1)", NetColorAlg::V1),
        ("Alg. 6 + reverse", NetColorAlg::V1Reverse),
        ("Alg. 8 (two-pass)", NetColorAlg::TwoPass),
    ];
    println!("=== Table I: remaining |W_next| after the first iteration (t=16) ===");
    println!(
        "{:<16} {:>10} | {:>12} {:>16} {:>16}",
        "graph", "|V_A|", "Alg6", "Alg6+rev", "Alg8"
    );
    let mut csv = Vec::new();
    for name in ["bone010", "coPapersDBLP"] {
        let g = Preset::by_name(name).unwrap().bipartite(common::scale(), common::seed());
        let mut remaining = Vec::new();
        for (_, alg) in algs {
            let spec = N1_N2.with_net_alg(alg);
            let r = common::run(&g, spec, 16, Ordering::Natural, Balance::None);
            // queue entering iteration 2 == remaining after iteration 1
            let rem = r.trace.iters.get(1).map(|it| it.queue_len).unwrap_or(0);
            remaining.push(rem);
        }
        println!(
            "{:<16} {:>10} | {:>12} {:>16} {:>16}",
            name,
            g.n_vertices(),
            remaining[0],
            remaining[1],
            remaining[2]
        );
        csv.push(format!(
            "{name},{},{},{},{}",
            g.n_vertices(),
            remaining[0],
            remaining[1],
            remaining[2]
        ));
        assert!(
            remaining[2] <= remaining[0],
            "Alg8 must leave fewer conflicts than Alg6"
        );
    }
    common::write_csv("table1.csv", "graph,n_vertices,alg6,alg6_rev,alg8", &csv);
}
