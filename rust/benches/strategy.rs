//! Strategy payoff on the skewed presets: do the degree-aware orderings
//! and the color-and-fix post pass actually buy colors without giving
//! back the parallel speedup?
//!
//! For each skewed preset (`20M_movielens`, `coPapersDBLP`, `uk-2002`)
//! the bench colors under the deterministic 16-thread simulator with
//! every CLI strategy — {natural, random, ldf, sl} × {-, +fix} — and
//! compares against the sequential natural-order first-fit baseline
//! (`seq::greedy`): `color_ratio` = baseline colors / strategy colors
//! (> 1 means fewer colors than first-fit), `speedup16` = baseline
//! simulated seconds / strategy simulated seconds (post-pass time
//! included, so `+fix` pays for its rounds honestly).
//!
//! Gates:
//! * **validity** — every strategy run passes `bgpc_valid`;
//! * **no-loss slack (per preset)** — the best non-default strategy at
//!   ≥ 4× simulated speedup keeps `color_ratio` ≥ 0.95: parallel speed
//!   never costs more than 5% colors vs sequential first-fit, even on
//!   hub presets. (coPapersDBLP's count is pinned by its densest hub —
//!   no visit order can beat first-fit there — the same lesson as the
//!   execute bench: per-preset slack + aggregate geomean, never
//!   per-preset strict.)
//! * **payoff (aggregate)** — the geomean of the per-preset *best*
//!   ratios is ≥ 1.05: over the skewed presets taken together the
//!   strategy layer beats first-fit on colors by ≥ 5% (power-law-tail
//!   presets like uk-2002 are where orderings shine — double digits).
//!   Each preset's best row fills the `gate_improve` CSV column that
//!   `BENCH_strategy.json` floors.
//!
//!   cargo bench --bench strategy               # BGPC_SCALE=0.5 default
//!   BENCH_SMOKE=1 cargo bench --bench strategy # CI smoke: scale 0.1
//!
//! CSV artifact: `strategy.csv`. A closing segment sweeps the same
//! strategies through D2GC and D1GC on the symmetric skewed preset so
//! the parity surface stays covered at bench scale (validity-gated,
//! not floored).

#[path = "common/mod.rs"]
mod common;

use bgpc::coloring::verify::{bgpc_valid, d1gc_valid, d2gc_valid};
use bgpc::coloring::{color, schedule, Config};
use bgpc::dynamic::D1Graph;
use bgpc::graph::generators::Preset;
use bgpc::graph::Ordering;
use bgpc::Strategy;

const SKEWED: [&str; 3] = ["20M_movielens", "coPapersDBLP", "uk-2002"];
const STRATEGIES: [&str; 8] =
    ["natural", "random", "ldf", "sl", "natural+fix", "random+fix", "ldf+fix", "sl+fix"];

fn main() {
    let scale = common::scale();
    let seed = common::seed();
    println!("=== strategy: orderings + color-and-fix vs first-fit (sim t=16, scale {scale}) ===");
    println!(
        "{:<16} {:<12} | {:>7} {:>7} {:>7} | {:>8} {:>8}",
        "graph", "strategy", "colors", "base", "ratio", "speedup16", "gate"
    );
    let mut csv = Vec::new();
    let mut best_ratios = Vec::new();
    for name in SKEWED {
        let p = Preset::by_name(name).unwrap();
        let g = p.bipartite(scale, seed);
        let order = Ordering::Natural.compute(&g);
        let (_, base_colors, seq_secs) = common::seq_baseline(&g, &order);
        let mut rows: Vec<(&str, usize, f64, f64)> = Vec::new();
        let mut best: Option<usize> = None;
        let mut best_ratio = f64::NEG_INFINITY;
        for s in STRATEGIES {
            let st = Strategy::parse(s).unwrap();
            let cfg = Config::sim(schedule::N1_N2, 16).with_strategy(st);
            let r = color(&g, &cfg);
            assert!(
                bgpc_valid(&g, &r.colors).is_ok(),
                "{name}: strategy {s} produced an invalid coloring"
            );
            let ratio = base_colors as f64 / r.n_colors as f64;
            let speedup = seq_secs / r.seconds;
            // gate candidates: non-default strategies that keep the
            // parallel payoff; the best color ratio among them is this
            // preset's gate row
            if s != "natural" && speedup >= 4.0 && ratio > best_ratio {
                best = Some(rows.len());
                best_ratio = ratio;
            }
            rows.push((s, r.n_colors, ratio, speedup));
        }
        let bi = best.unwrap_or_else(|| {
            panic!("{name}: no non-default strategy kept a >= 4x simulated 16-thread speedup")
        });
        for (i, (s, n_colors, ratio, speedup)) in rows.iter().enumerate() {
            println!(
                "{:<16} {:<12} | {:>7} {:>7} {:>7.3} | {:>8.2} {:>8}",
                name,
                s,
                n_colors,
                base_colors,
                ratio,
                speedup,
                if i == bi { "best" } else { "-" }
            );
            let gate = if i == bi { format!("{ratio:.4}") } else { String::new() };
            csv.push(format!("{name},{s},{n_colors},{base_colors},{ratio:.4},{speedup:.3},{gate}"));
        }
        let (bs, _, bratio, bspeed) = rows[bi];
        assert!(
            bratio >= 0.95,
            "{name}: best strategy {bs} loses more than 5% colors vs sequential \
             first-fit (ratio {bratio:.3} at {bspeed:.1}x)"
        );
        best_ratios.push(bratio);
    }
    let geomean =
        (best_ratios.iter().map(|r| r.ln()).sum::<f64>() / best_ratios.len() as f64).exp();
    println!("\nper-preset best color ratios {best_ratios:?} -> geomean {geomean:.4}");
    assert!(
        geomean >= 1.05,
        "geomean of the per-preset best color ratios is {geomean:.4} — the strategy \
         layer must beat first-fit by >= 5% over the skewed presets taken together"
    );

    // symmetric parity segment: the same strategies through D2GC and
    // D1GC on the symmetric skewed preset (validity only — the color
    // floor above is the gated metric)
    let m = Preset::by_name("coPapersDBLP").unwrap().net_incidence(scale, seed);
    println!("\n--- symmetric parity (coPapersDBLP, D2GC/D1GC colors at sim t=16) ---");
    for s in STRATEGIES {
        let st = Strategy::parse(s).unwrap();
        let cfg = Config::sim(schedule::N1_N2, 16).with_strategy(st);
        let r2 = color(&m, &cfg);
        assert!(d2gc_valid(&m, &r2.colors).is_ok(), "D2GC {s} invalid");
        let r1 = color(D1Graph::from_ref(&m), &cfg);
        assert!(d1gc_valid(&m, &r1.colors).is_ok(), "D1GC {s} invalid");
        println!("{:<12} d2gc={:>4} d1gc={:>4}", s, r2.n_colors, r1.n_colors);
        csv.push(format!("coPapersDBLP-sym,{s},{},{},,,", r2.n_colors, r1.n_colors));
    }

    common::write_csv(
        "strategy.csv",
        "preset,strategy,n_colors,base_colors,color_ratio,speedup16,gate_improve",
        &csv,
    );
}
